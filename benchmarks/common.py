"""Shared benchmark utilities: worlds, timing, tables, result persistence.

Scale note (DESIGN.md §5): the paper runs 60k tweets / 2.3M stream triples
against DBpedia (368M triples) on 48 cores; this container is one CPU core,
so sizes here are scaled so each experiment finishes in seconds while
preserving every *relationship* the paper measures (KB-access dominance,
~linear used-KB scaling, split-query speedup).  Compile time is excluded —
the paper reports steady-state processing time per window.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.rdf import Vocab
from repro.core.session import ExecutionConfig, Session
from repro.data.dbpedia import KBConfig, generate_kb
from repro.data.tweets import (
    TweetSchema, TweetStreamConfig, generate_tweets, stream_chunks,
)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "bench_results")


@dataclasses.dataclass
class BenchWorld:
    vocab: Vocab
    kbd: object
    tweets: TweetSchema
    chunks: list


def build_world(
    num_tweets: int = 256,
    num_artists: int = 64,
    num_shows: int = 32,
    filler: int = 2000,
    chunk_capacity: int = 1024,
    co_mention: bool = True,
    seed: int = 0,
) -> BenchWorld:
    vocab = Vocab()
    kbd = generate_kb(
        vocab,
        KBConfig(num_artists=num_artists, num_shows=num_shows,
                 filler_triples=filler, seed=seed),
    )
    tweets = TweetSchema.create(vocab)
    pool = (
        np.concatenate([kbd.artist_ids, kbd.show_ids])
        if co_mention else kbd.artist_ids
    )
    rows = generate_tweets(
        vocab, tweets, pool,
        TweetStreamConfig(num_tweets=num_tweets, mentions_min=2,
                          mentions_max=4, seed=seed),
    )
    return BenchWorld(vocab, kbd, tweets, list(stream_chunks(rows, chunk_capacity)))


def make_session(world: BenchWorld, config: ExecutionConfig,
                 kb=None) -> Session:
    """A Session over this world's vocab + KB (``kb=`` overrides the KB —
    step1 swaps between the pruned used-KB slice and the full KB)."""
    return Session(config, vocab=world.vocab,
                   kb=kb if kb is not None else world.kbd.kb)


def _block(x):
    return jax.tree.map(
        lambda a: a.block_until_ready() if hasattr(a, "block_until_ready") else a, x
    )


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> Dict[str, float]:
    """Median/min wall time of ``fn(*args)`` with compile separated out.

    The warmup calls are *timed* too: the first one is reported as
    ``compile_s`` (trace + XLA compile + one execution — often orders of
    magnitude above steady state), so every benchmark records how much
    one-time cost the steady numbers exclude.
    """
    compile_s = 0.0
    for i in range(warmup):
        t0 = time.perf_counter()
        _block(fn(*args))
        if i == 0:
            compile_s = time.perf_counter() - t0
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        _block(fn(*args))
        times.append(time.perf_counter() - t0)
    return {
        "median_s": float(np.median(times)),
        "min_s": float(np.min(times)),
        "mean_s": float(np.mean(times)),
        "compile_s": float(compile_s),
        "iters": iters,
    }


# --------------------------------------------------------------------------
# reporting
# --------------------------------------------------------------------------

def format_table(title: str, headers: List[str], rows: List[List]) -> str:
    widths = [
        max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows else len(str(h))
        for i, h in enumerate(headers)
    ]
    def fmt_row(vals):
        return " | ".join(str(v).ljust(w) for v, w in zip(vals, widths))
    sep = "-+-".join("-" * w for w in widths)
    lines = [f"== {title} ==", fmt_row(headers), sep] + [fmt_row(r) for r in rows]
    return "\n".join(lines)


def save_results(name: str, payload: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    return path


def ms(x: float) -> str:
    return f"{x * 1e3:.1f} ms"
