"""Paper Figs. 5-7 (third step): how used-KB and total-KB size drive time.

* Fig. 5 (``--sweep used``): used == total, sweep the number of query-relevant
  triples; processing time should scale ~linearly (paper: 10x used -> ~10x
  time for QueryA; 7.5x -> ~6.5x for QueryB).
* Figs. 6/7 (``--sweep total``): fix the used slice, grow *unused* filler; the
  scan method's time grows with total size (paper: +30.2% for 10x unused on
  QueryA, +43.6% on QueryB), while the probe method stays ~flat — the paper's
  argument for partitioning the KB per sub-query.

QueryA/QueryB are the decomposition's artist/show operators from step 2.
"""
from __future__ import annotations

import numpy as np

from repro.core import paper_queries as PQ
from repro.core.planner import decompose, prune_kb_for
from repro.core.session import ExecutionConfig

from .common import (
    build_world, format_table, make_session, ms, save_results, time_fn,
)

WINDOW_CAP = 256
MAX_WINDOWS = 4


def _cfg(method: str) -> ExecutionConfig:
    return ExecutionConfig(
        mode="monolithic", window_capacity=WINDOW_CAP,
        max_windows=MAX_WINDOWS, bind_cap=2048, scan_cap=512, out_cap=2048,
        kb_method=method,
    )


def _subqueries(world):
    q = PQ.cquery1(world.vocab, world.tweets, world.kbd.schema)
    dag = decompose(q, world.vocab)
    subs = {}
    for name, sub in dag.subqueries.items():
        if sub.touches_kb:
            key = "QueryA" if "artist" in name else "QueryB"
            subs[key] = sub.query
    return subs


def sweep_used(iters: int = 3) -> dict:
    """Fig. 5: used == total; vary relevant-KB size via the entity universe.

    Sizes reach the scan-dominated regime (used-KB in the thousands) where
    the paper observes ~linear scaling; below that, fixed window-join work
    flattens the curve (visible in the first points).
    """
    sizes = [(64, 32), (192, 96), (512, 256), (1024, 512)]  # (artists, shows)
    out = {"QueryA": [], "QueryB": []}
    for n_art, n_show in sizes:
        world = build_world(num_tweets=96, num_artists=n_art, num_shows=n_show,
                            filler=0, co_mention=True, seed=7)
        chunk = world.chunks[0]
        for key, q in _subqueries(world).items():
            kb = prune_kb_for(q, world.kbd.kb)     # used == total
            reg = make_session(world, _cfg("scan"), kb=kb).register(q)
            t = time_fn(lambda c: reg.process_chunk(c)[0], chunk, iters=iters)
            out[key].append({
                "used_kb": int(np.asarray(kb.count())),
                "time_s": t["median_s"],
            })
    return out


def sweep_total(iters: int = 3) -> dict:
    """Figs. 6/7: fixed used slice, growing unused filler (both methods)."""
    fillers = [0, 1000, 4000, 16000]
    out = {"scan": {"QueryA": [], "QueryB": []},
           "probe": {"QueryA": [], "QueryB": []}}
    for filler in fillers:
        world = build_world(num_tweets=96, num_artists=64, num_shows=32,
                            filler=filler, co_mention=True, seed=7)
        chunk = world.chunks[0]
        for key, q in _subqueries(world).items():
            for method in ("scan", "probe"):
                reg = make_session(world, _cfg(method)).register(q)
                t = time_fn(lambda c: reg.process_chunk(c)[0], chunk, iters=iters)
                used = int(np.asarray(prune_kb_for(q, world.kbd.kb).count()))
                out[method][key].append({
                    "total_kb": int(np.asarray(world.kbd.kb.count())),
                    "used_kb": used,
                    "time_s": t["median_s"],
                })
    return out


def run(sweep: str = "both", iters: int = 3) -> dict:
    results = {}
    if sweep in ("used", "both"):
        used = sweep_used(iters)
        results["fig5_used"] = used
        rows = []
        for key, pts in used.items():
            base = pts[0]
            for p in pts:
                rows.append([
                    key, p["used_kb"], ms(p["time_s"]),
                    f"x{p['used_kb'] / max(1, base['used_kb']):.1f}",
                    f"x{p['time_s'] / base['time_s']:.1f}",
                ])
        print(format_table(
            "Fig. 5 — used-KB scaling (scan method, used == total)",
            ["query", "used KB", "time/chunk", "KB growth", "time growth"],
            rows,
        ))
        for key, pts in used.items():
            kb_ratio = pts[-1]["used_kb"] / max(1, pts[0]["used_kb"])
            t_ratio = pts[-1]["time_s"] / pts[0]["time_s"]
            print(f"[check] {key}: used-KB x{kb_ratio:.1f} -> time x{t_ratio:.1f} "
                  f"(paper: ~linear)")

    if sweep in ("total", "both"):
        total = sweep_total(iters)
        results["fig6_7_total"] = total
        rows = []
        for method in ("scan", "probe"):
            for key, pts in total[method].items():
                base = pts[0]
                for p in pts:
                    rows.append([
                        method, key, p["total_kb"], p["used_kb"], ms(p["time_s"]),
                        f"+{(p['time_s'] / base['time_s'] - 1) * 100:.0f}%",
                    ])
        print(format_table(
            "Figs. 6/7 — total-KB scaling (fixed used slice)",
            ["method", "query", "total KB", "used KB", "time/chunk", "vs no filler"],
            rows,
        ))
        for key in ("QueryA", "QueryB"):
            pts = total["scan"][key]
            grow = pts[-1]["time_s"] / pts[0]["time_s"] - 1
            kb_grow = pts[-1]["total_kb"] / pts[0]["total_kb"]
            ppts = total["probe"][key]
            pgrow = ppts[-1]["time_s"] / ppts[0]["time_s"] - 1
            print(f"[check] {key}: x{kb_grow:.0f} unused triples cost the scan "
                  f"method +{grow * 100:.0f}% (paper direction: unused KB costs "
                  f"scan, +30-44% at x10) while probe stays ~flat "
                  f"(+{pgrow * 100:.0f}%) — the partitioning argument")

    save_results("step3_figs5_7", results)
    return results


if __name__ == "__main__":
    import sys
    run(sys.argv[1] if len(sys.argv) > 1 else "both")
