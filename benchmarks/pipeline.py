"""Sustained-throughput benchmark for the dataflow runtime -> BENCH_pipeline.json.

Compares the three ``ExecutionConfig.mode`` settings of the same CQuery1
over the same multi-chunk stream, all driven through one ``Session`` API:

* ``monolithic`` — one operator, full KB, chunk-at-a-time (paper Table 2
  baseline);
* ``single_program`` — the whole DAG fused into one XLA program, chunks
  pushed synchronously one at a time;
* ``pipelined`` — per-operator jitted steps over bounded device channels,
  software-pipelined schedule with up to ``channel_capacity`` chunks in
  flight, sink-only blocking.

Asserts (a) zero overflowed windows in every mode — capacity overruns would
silently clip results, so the satellite observability hook is exercised here
— (b) the pipelined final stream is **bit-identical** to the single-program
runtime per chunk, and (c) the pipelined schedule actually overlapped:
``depth_hw >= 2`` chunks in flight and (given >= 2 devices) the round_robin
placement spread operators over >= 2 distinct devices.

    PYTHONPATH=src python -m benchmarks.pipeline            # full shapes
    PYTHONPATH=src python -m benchmarks.pipeline --smoke    # CI tiny shapes
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Optional

# Force a multi-device CPU backend BEFORE jax initializes: round_robin
# placement can only spread enrichment operators across devices when the
# host platform exposes more than one.  Honors a caller-provided flag.
from repro.launch.mesh import ensure_host_devices

ensure_host_devices(4)

import jax
import numpy as np

from repro.core import paper_queries as PQ
from repro.core.session import ExecutionConfig

from .common import build_world, format_table, make_session

CHANNEL_CAPACITY = 4

# second workload: the expanded frontend surface — SELECT projection, a
# variable-length closure path (compiled through the fused closure kernel
# into one pair-relation join) and a boolean FILTER tree.  The shipped
# example file is the single source of truth so the benchmarked query can
# never drift from what a reader reproduces.
ARTIST_CLASSES_RQ_PATH = os.path.join(
    os.path.dirname(__file__), "..", "examples", "queries",
    "artist_classes.rq")


def _throughput(run_pass, num_chunks: int, iters: int) -> dict:
    """Median sustained chunks/sec of ``run_pass()``, with the first
    (compile-inclusive) pass timed separately as ``compile_s`` so the
    one-time cost the steady numbers exclude is still on record."""
    t0 = time.perf_counter()
    jax.block_until_ready(run_pass())          # warmup / compile
    compile_s = time.perf_counter() - t0
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(run_pass())
        times.append(time.perf_counter() - t0)
    med = float(np.median(times))
    return {
        "median_s": med,
        "min_s": float(np.min(times)),
        "chunks_per_s": num_chunks / med,
        "compile_s": float(compile_s),
        "iters": iters,
    }


def _stage_breakdown(world, base, q, chunks, query: str,
                     passes: int = 2) -> dict:
    """Per-stage trace of the same workload on *separate* traced sessions.

    Tracing fences every stage boundary (``block_until_ready`` per span), so
    the headline throughput sessions above stay unfenced and these sessions
    exist only to answer *where* the time goes.  Two passes: the first is
    compile-inclusive (reported per span as ``first_s``), the second feeds
    the steady aggregates.
    """
    from repro.obs.report import bottleneck_stage, format_stage_table, to_json

    breakdown = {}
    for mode in ("monolithic", "single_program", "pipelined"):
        reg = make_session(world, base.replace(mode=mode, trace=True)).register(q)
        for _ in range(passes):
            reg.run(chunks)
        stats = reg.last_stats
        prefix = "stage" if mode == "pipelined" else "chunk"
        breakdown[mode] = {
            "spans": stats["spans"],
            "operators": stats["operators"],
            "channels": stats["channels"],
            "bottleneck_stage": bottleneck_stage(stats["spans"], prefix=prefix),
        }
        if mode == "pipelined":
            print(format_stage_table(
                stats["spans"],
                title="%s pipelined per-stage latency (traced sessions)" % query))
            print("[bench_pipeline] pipelined bottleneck stage: %s"
                  % breakdown[mode]["bottleneck_stage"])
        if mode == "pipelined":
            # full trace artifact (spans + metrics + channels + explain)
            trace_payload = to_json(stats, explain=reg.explain())
            path = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_trace_%s.json" % query)
            with open(path, "w") as f:
                json.dump(trace_payload, f, indent=2)
            print(f"[bench_pipeline] wrote {os.path.normpath(path)}")
    return breakdown


def _recovery_overhead(world, base, q, chunks, outs_single, iters,
                       plain_median_s: float) -> dict:
    """Cost of resilience: checkpoint-cadence overhead + time-to-recover.

    Sweeps ``RecoveryConfig.checkpoint_every`` over {0, 2, 8} on the same
    pipelined workload (0 = resilient bookkeeping but no mid-stream
    snapshots) and reports each cadence's throughput against the plain
    (recovery=None) pipelined baseline measured above.  Then injects one
    ``crash_stage`` on a mid-stream chunk and reports time-to-recover as
    the median faulted-pass minus median clean-pass wall time on the same
    warmed runtime — both steady-state, so the difference isolates
    checkpoint restore + replay.  Every pass is gated bit-exact against
    the single-program stream.
    """
    from repro.core.faults import FaultEvent, FaultInjector, FaultPlan
    from repro.core.recovery import RecoveryConfig

    def check(outs):
        assert len(outs) == len(outs_single)
        for i, (a, b) in enumerate(zip(outs_single, outs)):
            for col_a, col_b in zip(a, b):
                assert bool(np.all(np.asarray(col_a) == np.asarray(col_b))), (
                    "resilient chunk %d diverges from single-program" % i)

    cadence = {}
    for every in (0, 2, 8):
        reg = make_session(world, base.replace(
            mode="pipelined",
            recovery=RecoveryConfig(checkpoint_every=every))).register(q)
        outs, _ = reg.run(chunks)          # compile pass + correctness gate
        check(outs)
        ck_before = reg.last_stats["recovery"]["checkpoints"]
        r = _throughput(lambda s=reg: s.run(chunks)[0], len(chunks), iters)
        rec = reg.last_stats["recovery"]
        cadence[str(every)] = {
            **r,
            "overhead_vs_plain_pipelined":
                r["median_s"] / plain_median_s - 1.0,
            "checkpoints_per_pass":
                (rec["checkpoints"] - ck_before) / (iters + 1),
            "checkpoint_bytes": rec["checkpoint_bytes"],
        }
    rows = [
        [every, f"{r['median_s'] * 1e3:.1f} ms",
         f"{r['overhead_vs_plain_pipelined'] * 100:+.1f}%",
         f"{r['checkpoints_per_pass']:.1f}",
         f"{r['checkpoint_bytes'] / 1024:.0f} KiB"]
        for every, r in cadence.items()
    ]
    print(format_table(
        "resilient pipelined: checkpoint cadence overhead",
        ["checkpoint_every", "stream pass (median)", "vs plain piped",
         "ckpts/pass", "ckpt size"], rows))

    # -- time-to-recover from one injected mid-stream crash ------------------
    crash_chunk = max(1, len(chunks) // 2)
    plan = FaultPlan((FaultEvent("crash_stage", "source", crash_chunk),))
    reg = make_session(world, base.replace(
        mode="pipelined", faults=plan,
        recovery=RecoveryConfig(checkpoint_every=2))).register(q)
    check(reg.run(chunks)[0])              # compile pass (the crash fires here)
    n = max(2, iters)
    clean, faulted = [], []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(reg.run(chunks)[0])
        clean.append(time.perf_counter() - t0)
    restarts_before = reg.last_stats["recovery"]["restarts"]
    for _ in range(n):
        # each scheduled fault fires at most once per injector, so re-arm
        # the schedule each pass — rebased onto this pass's seq window,
        # because events key on the lifetime chunk seq, which keeps rising
        # across passes on the warmed runtime
        rebased = FaultPlan((FaultEvent(
            "crash_stage", "source",
            reg.runtime._next_seq + crash_chunk),))
        reg.runtime._injector = FaultInjector(rebased)
        t0 = time.perf_counter()
        outs = reg.run(chunks)[0]
        jax.block_until_ready(outs)
        faulted.append(time.perf_counter() - t0)
        check(outs)
    rec = reg.last_stats["recovery"]
    restarts = rec["restarts"] - restarts_before
    assert restarts == n, (
        "expected one restart per faulted pass, got %d over %d passes"
        % (restarts, n))
    crash = {
        "crash_chunk": crash_chunk,
        "checkpoint_every": 2,
        "clean_pass_median_s": float(np.median(clean)),
        "faulted_pass_median_s": float(np.median(faulted)),
        "time_to_recover_s":
            float(np.median(faulted) - np.median(clean)),
        "restarts_per_faulted_pass": restarts / n,
        "replayed_total": rec["replayed"],
        "bit_exact_after_recovery": True,
    }
    print("[bench_pipeline] crash on chunk %d: clean pass %.1f ms, "
          "faulted pass %.1f ms, time-to-recover %.1f ms"
          % (crash_chunk, crash["clean_pass_median_s"] * 1e3,
             crash["faulted_pass_median_s"] * 1e3,
             crash["time_to_recover_s"] * 1e3))
    return {
        "what": "resilience cost on the same pipelined workload: throughput "
                "per checkpoint cadence (0 = no mid-stream snapshots) vs "
                "the plain recovery=None baseline, plus time-to-recover "
                "from one injected mid-stream crash_stage (steady-state "
                "faulted-pass minus clean-pass median); every pass gated "
                "bit-exact against the single-program stream",
        "checkpoint_cadence": cadence,
        "crash_recovery": crash,
    }


def run(iters: Optional[int] = None, smoke: bool = False,
        query: str = "cquery1", kb_method: str = "auto"):
    if iters is None:
        iters = 1 if smoke else 3
    if smoke:
        world = build_world(num_tweets=32, num_artists=16, num_shows=8,
                            filler=100, chunk_capacity=192)
        base = ExecutionConfig(window_capacity=64, max_windows=4, bind_cap=512,
                               scan_cap=128, out_cap=512, intermediate_cap=256,
                               kb_method=kb_method,
                               channel_capacity=CHANNEL_CAPACITY)
    else:
        # >= 8 chunks: the pipelined runtime needs a stream long enough to
        # amortize ramp-up/drain before its steady-state overlap shows
        world = build_world(num_tweets=1280, num_artists=64, num_shows=32,
                            filler=2000, chunk_capacity=1024)
        base = ExecutionConfig(window_capacity=256, max_windows=4,
                               bind_cap=2048, scan_cap=512, out_cap=2048,
                               intermediate_cap=1024, kb_method=kb_method,
                               channel_capacity=CHANNEL_CAPACITY)

    if query == "cquery1":
        q = PQ.cquery1(world.vocab, world.tweets, world.kbd.schema)
    else:
        from repro.core.sparql import parse_query
        with open(ARTIST_CLASSES_RQ_PATH) as f:
            q = parse_query(f.read(), world.vocab)
    chunks = world.chunks
    assert smoke or len(chunks) >= 8, (
        "non-smoke stream too short to pipeline: %d chunks" % len(chunks))
    num_devices = len(jax.devices())
    print(f"[bench_pipeline] {query}, {len(chunks)} chunks, "
          f"smoke={smoke}, iters={iters}, kb_method={kb_method}, "
          f"devices={num_devices}")

    # one Session per execution mode — the unified API this benchmark compares
    mono = make_session(world, base.replace(mode="monolithic")).register(q)
    single = make_session(world, base.replace(mode="single_program")).register(q)
    piped = make_session(world, base.replace(mode="pipelined")).register(q)

    # -- correctness gate: bit-identical streams, zero overflow -------------
    outs_single, ovf_single = single.run(chunks)
    outs_piped, ovf_piped = piped.run(chunks)
    outs_mono, ovf_mono = mono.run(chunks)
    assert len(outs_single) == len(outs_piped) == len(outs_mono)
    for i, (a, b, c) in enumerate(zip(outs_single, outs_piped, outs_mono)):
        for col_a, col_b, col_c in zip(a, b, c):
            assert bool(np.all(np.asarray(col_a) == np.asarray(col_b))), (
                "pipelined chunk %d diverges from single-program" % i)
            assert bool(np.all(np.asarray(col_a) == np.asarray(col_c))), (
                "monolithic chunk %d diverges from single-program" % i)
    for label, ovf in [("monolithic", ovf_mono),
                       ("single_program", ovf_single),
                       ("pipelined", ovf_piped)]:
        clipped = {n: c for n, c in ovf.items() if c}
        assert not clipped, (
            "%s overflowed windows %s — raise capacities, the benchmark "
            "would be comparing clipped result sets" % (label, clipped))
    dropped = {e: s["overflows"]
               for e, s in piped.runtime.channel_stats().items()
               if s["overflows"]}
    assert not dropped, "channel drops under the deterministic schedule: %s" % dropped
    print("[bench_pipeline] all three modes bit-exact over "
          f"{len(chunks)} chunks, zero overflow in all modes")

    # -- schedule tripwires: the pipeline must actually pipeline -------------
    depth_hw = piped.runtime.depth_hw
    assert depth_hw >= 2, (
        "pipelined schedule never overlapped (depth_hw=%d) — the benchmark "
        "would be timing a serial execution under a pipelined label"
        % depth_hw)
    placement = {name: str(dev)
                 for name, dev in (piped.runtime.placement or {}).items()}
    if num_devices >= 2:
        assert len(set(placement.values())) >= 2, (
            "round_robin placement collapsed onto one device with %d "
            "available: %s" % (num_devices, placement))
    print(f"[bench_pipeline] depth_hw={depth_hw}, placement={placement}")

    # -- throughput ----------------------------------------------------------
    def mono_pass():
        return mono.run(chunks)[0]

    def single_pass():
        return single.run(chunks)[0]

    def piped_pass():
        # same drive loop as the correctness gate above (sink-only blocking
        # lives inside process_stream; _throughput's block is then a no-op)
        return piped.run(chunks)[0]

    results = {
        "monolithic": _throughput(mono_pass, len(chunks), iters),
        "single_program": _throughput(single_pass, len(chunks), iters),
        "pipelined": _throughput(piped_pass, len(chunks), iters),
    }

    rows = [
        [mode, f"{r['median_s'] * 1e3:.1f} ms", f"{r['chunks_per_s']:.2f}"]
        for mode, r in results.items()
    ]
    print(format_table("%s sustained throughput" % query,
                       ["mode", "stream pass (median)", "chunks/s"], rows))

    # -- KB-access comparison: scan vs probe vs auto on one runtime ----------
    # (the trajectory record for the cost-based access-method work: same
    # query, same stream, only kb_method varies; the gate asserts the three
    # methods stay bit-identical and overflow-free.  Measured on the
    # *monolithic* runtime — the full KB is attached there, so the access
    # method dominates; decomposed modes already shrink each operator's
    # partition via used-KB pruning, the paper's alternative cure)
    kb_access = {}
    for method in ("scan", "probe", "auto"):
        sess_m = make_session(
            world, base.replace(mode="monolithic", kb_method=method)
        ).register(q)
        outs_m, ovf_m = sess_m.run(chunks)
        for i, (a, b) in enumerate(zip(outs_single, outs_m)):
            for col_a, col_b in zip(a, b):
                assert bool(np.all(np.asarray(col_a) == np.asarray(col_b))), (
                    "kb_method=%s chunk %d diverges" % (method, i))
        clipped = {n: c for n, c in ovf_m.items() if c}
        assert not clipped, (
            "kb_method=%s overflowed windows %s" % (method, clipped))
        kb_access[method] = _throughput(
            lambda s=sess_m: s.run(chunks)[0], len(chunks), iters)
    rows = [
        [method, f"{r['median_s'] * 1e3:.1f} ms", f"{r['chunks_per_s']:.2f}"]
        for method, r in kb_access.items()
    ]
    print(format_table("%s KB-access methods (monolithic, full KB)" % query,
                       ["kb_method", "stream pass (median)", "chunks/s"],
                       rows))

    # -- resilience cost: checkpoint cadence + time-to-recover ---------------
    recovery_overhead = _recovery_overhead(
        world, base, q, chunks, outs_single, iters,
        plain_median_s=results["pipelined"]["median_s"])

    # -- per-stage breakdown: where does each runtime spend its time? --------
    stage_breakdown = _stage_breakdown(world, base, q, chunks, query)

    payload = {
        "what": "sustained chunks/sec over one stream pass, one Session per "
                "ExecutionConfig mode: monolithic vs single-program DAG vs "
                "pipelined dataflow (up to channel_capacity chunks in "
                "flight, sink-only blocking)",
        "query": query,
        "kb_method": kb_method,
        "num_chunks": len(chunks),
        "channel_capacity": CHANNEL_CAPACITY,
        "num_devices": num_devices,
        "placement": placement,
        "depth_hw": depth_hw,
        "split_sink": piped.runtime._split is not None,
        "smoke": smoke,
        "bit_exact_vs_single_program": True,
        "overflowed_windows": 0,
        "results": results,
        "kb_access": {
            "what": "same query/stream on the monolithic (full-KB) runtime "
                    "with only ExecutionConfig.kb_method varying; all "
                    "methods bit-identical and overflow-free",
            "bit_exact_across_methods": True,
            "results": kb_access,
        },
        "recovery_overhead": recovery_overhead,
        "stage_breakdown": {
            "what": "per-stage span aggregates from separate traced "
                    "sessions (tracing fences each stage, so the headline "
                    "throughput above stays unfenced); first_s is the "
                    "compile-inclusive first pass, steady excludes it",
            **stage_breakdown,
        },
    }
    name = ("BENCH_pipeline.json" if query == "cquery1"
            else "BENCH_pipeline_%s.json" % query)
    path = os.path.join(os.path.dirname(__file__), "..", name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    print(f"[bench_pipeline] wrote {os.path.normpath(path)}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + 1 iter (CI artifact mode)")
    ap.add_argument("--iters", type=int, default=None,
                    help="timing iterations (default: 3, or 1 with --smoke)")
    ap.add_argument("--query", default="cquery1",
                    choices=["cquery1", "artist_classes"],
                    help="workload: the paper's CQuery1, or the expanded "
                         "frontend surface (SELECT + closure path + boolean "
                         "FILTER)")
    ap.add_argument("--kb-method", default="auto",
                    choices=["scan", "probe", "auto"],
                    help="KB access method for the three benchmarked modes "
                         "(the kb_access section always compares all three "
                         "on the monolithic full-KB runtime)")
    args = ap.parse_args(argv)
    run(iters=args.iters, smoke=args.smoke, query=args.query,
        kb_method=args.kb_method)


if __name__ == "__main__":
    main()
