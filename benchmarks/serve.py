"""Serving throughput: queries/sec vs registered-query count.

The repo's first throughput-at-scale number (ROADMAP "multi-query,
multi-tenant serving"): one :class:`~repro.serve.engine.ServeEngine`
hosts N standing queries (a mix of exact duplicates, class variants
sharing a KB-join prefix, and filter-threshold variants — the population
:func:`repro.launch.dscep_run.serve_population` generates) and every
chunk streams through all of them.  Measured at N = 16 / 64 / 256 with
shared-plan dedup on vs off; at the smallest N the serving outputs are
additionally asserted bit-identical to N independent single-query
Sessions (and dedup-on vs dedup-off bit-identical at every N), so the
speedups compare equal result sets — ``"exact": true`` in the payload
records that the assertions ran.

    PYTHONPATH=src python benchmarks/serve.py [--smoke] [--iters K]

Writes BENCH_serve.json.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Optional

import numpy as np

from repro.core.session import ExecutionConfig
from repro.launch.dscep_run import serve_population

from .common import build_world, format_table, make_session
from .pipeline import _throughput

QUERY_COUNTS = (16, 64, 256)


def _assert_bit_identical(outs_a, outs_b, tag):
    assert len(outs_a) == len(outs_b), tag
    for i, (a, b) in enumerate(zip(outs_a, outs_b)):
        for col, ca, cb in zip(a._fields, a, b):
            assert bool(np.all(np.asarray(ca) == np.asarray(cb))), (
                "%s: chunk %d column %s diverges" % (tag, i, col))


def run(iters: Optional[int] = None, smoke: bool = False):
    if iters is None:
        iters = 1 if smoke else 2
    # smoke keeps the first two sweep points (the CI tripwire needs >= 2 and
    # the dedup-win claim is made at 64); the full run records all three
    counts = QUERY_COUNTS[:2] if smoke else QUERY_COUNTS
    if smoke:
        world = build_world(num_tweets=32, num_artists=16, num_shows=8,
                            filler=100, chunk_capacity=192)
        base = ExecutionConfig(mode="monolithic", window_capacity=64,
                               max_windows=4, bind_cap=1024, scan_cap=256,
                               out_cap=1024, out_stream_cap=2048)
    else:
        world = build_world(num_tweets=64, num_artists=32, num_shows=16,
                            filler=400, chunk_capacity=256)
        base = ExecutionConfig(mode="monolithic", window_capacity=96,
                               max_windows=4, bind_cap=1024, scan_cap=256,
                               out_cap=1024, out_stream_cap=2048)
    chunks = world.chunks
    print(f"[bench_serve] {len(chunks)} chunks of "
          f"{int(chunks[0].valid.shape[0])}, smoke={smoke}, iters={iters}, "
          f"N sweep={counts}")

    sweep = []
    for n in counts:
        texts = serve_population(n)
        outs_by = {}
        rates = {}
        stats_by = {}
        for dedup in (True, False):
            eng = make_session(world, base).serve(dedup=dedup)
            for t in texts:
                eng.register(t)
            outs, ovf = eng.run(chunks)
            outs_by[dedup] = (outs, ovf)
            r = _throughput(lambda e=eng: e.run(chunks)[0], len(chunks),
                            iters)
            r["queries_per_s"] = r["chunks_per_s"] * n
            rates[dedup] = r
            stats_by[dedup] = eng.last_stats

        # dedup on and off must publish identical streams at every N
        on_outs, on_ovf = outs_by[True]
        off_outs, off_ovf = outs_by[False]
        for qname in on_outs:
            _assert_bit_identical(on_outs[qname], off_outs[qname],
                                  "N=%d %s dedup-on vs off" % (n, qname))
        assert on_ovf == off_ovf, (n, on_ovf, off_ovf)

        independent = None
        if n == counts[0]:
            # the ground truth: every query in its own single-query Session
            regs = []
            for t in texts:
                reg = make_session(world, base).register(t)
                souts, sovf = reg.run(chunks)
                qname = reg.query.name
                _assert_bit_identical(on_outs[qname], souts,
                                      "N=%d %s serve vs single" % (n, qname))
                assert on_ovf[qname] == sovf[qname], (qname, on_ovf, sovf)
                regs.append(reg)
            r = _throughput(
                lambda: [reg.run(chunks)[0] for reg in regs],
                len(chunks), iters)
            r["queries_per_s"] = r["chunks_per_s"] * n
            independent = r

        st = stats_by[True]
        sweep.append({
            "queries": n,
            "dedup_on": rates[True],
            "dedup_off": rates[False],
            "independent_sessions": independent,
            "dedup_speedup": (rates[True]["queries_per_s"]
                              / rates[False]["queries_per_s"]),
            "distinct_plans": st["distinct_plans"],
            "cohort_batch_sizes": st["batch_sizes"],
            "prefix_groups": len(st["prefix_groups"]),
            "exact": True,
            "overflow_clipped": sum(on_ovf.values()),
        })
        print(f"[bench_serve] N={n}: dedup-on "
              f"{rates[True]['queries_per_s']:.1f} q/s, dedup-off "
              f"{rates[False]['queries_per_s']:.1f} q/s "
              f"({sweep[-1]['dedup_speedup']:.2f}x), "
              f"{st['distinct_plans']} distinct plans")

    rows = [
        [str(e["queries"]), e["distinct_plans"],
         f"{e['dedup_on']['queries_per_s']:.1f}",
         f"{e['dedup_off']['queries_per_s']:.1f}",
         f"{e['dedup_speedup']:.2f}x",
         (f"{e['independent_sessions']['queries_per_s']:.1f}"
          if e["independent_sessions"] else "--")]
        for e in sweep
    ]
    print(format_table(
        "serving throughput (query-evals/sec, steady state)",
        ["queries", "distinct plans", "dedup on", "dedup off",
         "dedup speedup", "independent"], rows))

    payload = {
        "what": "multi-query serving throughput: query-evaluations/sec of "
                "one ServeEngine hosting N standing queries (duplicates + "
                "class variants + filter variants) with shared-plan dedup "
                "on vs off; outputs asserted bit-identical to independent "
                "single-query Sessions at the smallest N and dedup-on == "
                "dedup-off at every N before timing",
        "population": "serve_population: 1/3 duplicates (plan dedup), 1/3 "
                      "class variants (shared KB-join prefix), 1/3 filter "
                      "thresholds (vmap cohort)",
        "num_chunks": len(chunks),
        "chunk_capacity": int(chunks[0].valid.shape[0]),
        "smoke": smoke,
        "exact": True,
        "sweep": sweep,
    }
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    print(f"[bench_serve] wrote {os.path.normpath(path)}")
    return sweep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + 1 iter (CI artifact mode)")
    ap.add_argument("--iters", type=int, default=None,
                    help="timing iterations (default: 2, or 1 with --smoke)")
    args = ap.parse_args(argv)
    run(iters=args.iters, smoke=args.smoke)


if __name__ == "__main__":
    main()
