"""Sliding-window evaluation benchmark -> BENCH_window.json.

Sweeps the overlap ratio of STEP count windows (``step/capacity`` in
{1.0, 0.5, 0.25, 0.125} -> 0%/50%/75%/87.5% overlap) and compares, at each
geometry, full per-window recomputation (``incremental=False``) against the
delta evaluator (``incremental=True``) that runs the join chain once per
chunk over span-tagged bindings and only finalizes per window.

The workload is a deliberately join-heavy, OPTIONAL-free query (delta-safe:
``plan_supports_delta`` must hold, asserted below): tweets mentioning an
entity that is a MusicalArtist by subclass reasoning AND has a
birthPlace/country/countryCode path — one stream scan plus a closure join
plus a three-hop KB path on the same variable.

``max_windows`` scales with the overlap (enough windows to cover one chunk
at the given STEP), which is exactly the regime where recomputation pays
W times for the same join work the delta evaluator does once.

Correctness gate per sweep point: delta output is **bit-identical** to
recompute and both are overflow-free — the recorded speedups compare equal
result sets or the benchmark refuses to write.

    PYTHONPATH=src python -m benchmarks.window            # full shapes
    PYTHONPATH=src python -m benchmarks.window --smoke    # CI tiny shapes
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Optional

import numpy as np

from repro.core.planner import plan_supports_delta
from repro.core.session import ExecutionConfig

from .common import build_world, format_table, make_session
from .pipeline import _throughput

WINDOW_RQ = """\
REGISTER QUERY winbench AS
PREFIX schema: <urn:dscep:schema>
PREFIX rdf: <http://www.w3.org/1999/02/22-rdf-syntax-ns#>
PREFIX rdfs: <http://www.w3.org/2000/01/rdf-schema#>
PREFIX dbo: <http://dbpedia.org/ontology/>
PREFIX out: <urn:dscep:out>
CONSTRUCT {
  ?tweet out:artistCode ?cc .
}
FROM STREAM <stream> [RANGE TRIPLES 1000 STEP 1]
FROM <kb>
WHERE {
  ?tweet schema:mentions ?ent .
  GRAPH <kb> {
    ?ent rdf:type/rdfs:subClassOf* dbo:MusicalArtist .
    ?ent dbo:birthPlace/dbo:country/dbo:countryCode ?cc .
  }
}
"""

STEP_FRACTIONS = (1.0, 0.5, 0.25, 0.125)


def _assert_bit_identical(outs_a, outs_b, tag):
    assert len(outs_a) == len(outs_b), tag
    for i, (a, b) in enumerate(zip(outs_a, outs_b)):
        for col, ca, cb in zip(a._fields, a, b):
            assert bool(np.all(np.asarray(ca) == np.asarray(cb))), (
                "%s: chunk %d column %s diverges" % (tag, i, col))


def run(iters: Optional[int] = None, smoke: bool = False):
    if iters is None:
        iters = 1 if smoke else 3
    if smoke:
        world = build_world(num_tweets=32, num_artists=16, num_shows=8,
                            filler=100, chunk_capacity=192)
        capacity, max_cover = 64, 16
        base = ExecutionConfig(window_capacity=capacity, bind_cap=1024,
                               scan_cap=256, out_cap=1024,
                               intermediate_cap=512)
    else:
        # sized for the container's single CPU core: big enough that the
        # join chain dominates, small enough that the W-window recompute
        # baseline (the expensive side) finishes in minutes
        world = build_world(num_tweets=128, num_artists=48, num_shows=24,
                            filler=1000, chunk_capacity=512)
        capacity, max_cover = 128, 16
        base = ExecutionConfig(window_capacity=capacity, bind_cap=2048,
                               scan_cap=512, out_cap=2048,
                               intermediate_cap=1024)
    chunks = world.chunks
    chunk_cap = int(chunks[0].valid.shape[0])
    print(f"[bench_window] {len(chunks)} chunks of {chunk_cap}, "
          f"window capacity {capacity}, smoke={smoke}, iters={iters}")

    sweep = []
    for frac in STEP_FRACTIONS:
        step = max(1, int(capacity * frac))
        # enough windows to slide across one chunk at this STEP (bounded so
        # tiny steps don't explode compile time)
        max_windows = min(max_cover, max(1, -(-chunk_cap // step)))
        cfg = base.replace(mode="monolithic", window_step=step,
                           max_windows=max_windows)

        recomp = make_session(world, cfg).register(WINDOW_RQ)
        delta = make_session(world, cfg.replace(incremental=True)
                             ).register(WINDOW_RQ)
        assert plan_supports_delta(delta.runtime.operator.plan), (
            "benchmark query fell off the delta path — it would time the "
            "recompute fallback twice")

        outs_r, ovf_r = recomp.run(chunks)
        outs_d, ovf_d = delta.run(chunks)
        tag = "step=%d" % step
        _assert_bit_identical(outs_r, outs_d, tag)
        for label, ovf in (("recompute", ovf_r), ("delta", ovf_d)):
            clipped = {n: c for n, c in ovf.items() if c}
            assert not clipped, (
                "%s %s overflowed windows %s — raise capacities, the "
                "speedup would compare clipped result sets"
                % (tag, label, clipped))

        r_rec = _throughput(lambda: recomp.run(chunks)[0], len(chunks), iters)
        r_del = _throughput(lambda: delta.run(chunks)[0], len(chunks), iters)
        overlap = 1.0 - step / capacity
        sweep.append({
            "step": step,
            "overlap": overlap,
            "max_windows": max_windows,
            "recompute": r_rec,
            "delta": r_del,
            "speedup": r_del["chunks_per_s"] / r_rec["chunks_per_s"],
            "exact": True,
            "overflowed_windows": 0,
        })

    rows = [
        ["%d (%d%%)" % (e["step"], round(e["overlap"] * 100)),
         e["max_windows"],
         f"{e['recompute']['chunks_per_s']:.2f}",
         f"{e['delta']['chunks_per_s']:.2f}",
         f"{e['speedup']:.2f}x"]
        for e in sweep
    ]
    print(format_table(
        "winbench delta vs recompute (capacity %d, monolithic)" % capacity,
        ["STEP (overlap)", "windows", "recompute chunks/s",
         "delta chunks/s", "speedup"], rows))

    payload = {
        "what": "STEP-overlap sweep: per-chunk chunks/sec of incremental "
                "delta evaluation vs full per-window recomputation on one "
                "monolithic Session; each point bit-identical and "
                "overflow-free before timing",
        "query": "winbench (mentions + subclass closure + 3-hop path)",
        "window_capacity": capacity,
        "num_chunks": len(chunks),
        "chunk_capacity": chunk_cap,
        "smoke": smoke,
        "exact": True,
        "sweep": sweep,
    }
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_window.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    print(f"[bench_window] wrote {os.path.normpath(path)}")
    return sweep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + 1 iter (CI artifact mode)")
    ap.add_argument("--iters", type=int, default=None,
                    help="timing iterations (default: 3, or 1 with --smoke)")
    args = ap.parse_args(argv)
    run(iters=args.iters, smoke=args.smoke)


if __name__ == "__main__":
    main()
