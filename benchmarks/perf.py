"""§Perf hillclimb comparer: roofline terms of tagged dry-run variants.

Workflow (one iteration of the hypothesis -> change -> measure loop):

    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b \\
        --shape train_4k --profile dp --tag dp
    PYTHONPATH=src python -m benchmarks.perf --cell olmo-1b/train_4k

prints baseline vs every tagged variant of that cell with the three roofline
terms, dominant-term delta, and per-collective byte breakdown — the numbers
that go into EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from .common import format_table, save_results
from .roofline import ARTIFACTS, analyze, fmt_s


def load_cell_variants(arch: str, shape: str, mesh_tag: str = "pod1") -> Dict[str, dict]:
    out = {}
    base = os.path.join(ARTIFACTS, f"{arch}__{shape}__{mesh_tag}")
    for path in sorted(glob.glob(base + "*.json")):
        name = os.path.basename(path)[:-5]
        parts = name.split("__")
        tag = parts[3] if len(parts) > 3 else "baseline"
        with open(path) as f:
            out[tag] = json.load(f)
    return out


def compare(arch: str, shape: str, mesh_tag: str = "pod1") -> dict:
    variants = load_cell_variants(arch, shape, mesh_tag)
    if "baseline" not in variants:
        print(f"no baseline artifact for {arch}/{shape}")
        return {}
    rows, result = [], {}
    base = analyze(variants["baseline"])
    for tag in sorted(variants, key=lambda t: (t != "baseline", t)):
        a = analyze(variants[tag])
        if a is None:
            rows.append([tag, variants[tag].get("status", "?"),
                         "--", "--", "--", "--", "--"])
            continue
        dom_t = {"compute": a["t_compute_s"], "memory": a["t_memory_s"],
                 "collective": a["t_collective_s"]}[a["dominant"]]
        base_bound = max(base["t_compute_s"], base["t_memory_s"],
                         base["t_collective_s"])
        bound = max(dom_t, a["t_compute_s"])
        speedup = base_bound / bound if bound else float("inf")
        mem = variants[tag].get("memory", {})
        hbm = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
               + mem.get("output_bytes", 0)) / 1e9
        result[tag] = {**a, "bound_s": bound, "speedup_vs_baseline": speedup,
                       "hbm_gb": hbm}
        rows.append([
            tag, a["dominant"], fmt_s(a["t_compute_s"]),
            fmt_s(a["t_memory_s"]), fmt_s(a["t_collective_s"]),
            f"{hbm:.1f}GB", f"x{speedup:.2f}",
        ])
    print(format_table(
        f"§Perf — {arch}/{shape} ({mesh_tag}) variants",
        ["variant", "bottleneck", "compute", "memory", "collective",
         "HBM/dev", "speedup"],
        rows,
    ))
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", action="append", default=[],
                    help="arch/shape (repeatable)")
    ap.add_argument("--mesh", default="pod1")
    args = ap.parse_args(argv)
    cells = args.cell or []
    all_results = {}
    for cell in cells:
        arch, shape = cell.split("/")
        all_results[cell] = compare(arch, shape, args.mesh)
    if all_results:
        save_results("perf_variants", all_results)
    return all_results


if __name__ == "__main__":
    main()
