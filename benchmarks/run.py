"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One benchmark per paper artifact (DESIGN.md §5):

* step1    — Table 1  (Q15/Q16 x two KB-access methods)
* step2    — Tables 2-3 (CQuery1 monolithic vs decomposed, both methods)
* step3    — Figs. 5-7 (used-KB and total-KB scaling)
* kernels  — Pallas kernel fidelity + shape sweeps
* join     — fused join->compaction before/after + scan-vs-probe KB-access
             microbenchmarks (also part of ``kernels``); records speedups
             to BENCH_join.json
* pipeline — sustained chunks/sec: monolithic vs single-program DAG vs
             pipelined dataflow runtime; records to BENCH_pipeline.json
* roofline — per-(arch x shape x mesh) roofline terms from the dry-run
             artifacts (run ``python -m repro.launch.dryrun`` first)
* serve    — multi-query serving throughput: queries/sec at 16/64/256
             registered queries, shared-plan dedup on vs off; records to
             BENCH_serve.json (not in the default set — run explicitly
             via ``--only serve``)

``--only step2,roofline`` selects a subset.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default="step1,step2,step3,kernels,pipeline,roofline")
    p.add_argument("--iters", type=int, default=3)
    args = p.parse_args(argv)
    want = [s.strip() for s in args.only.split(",") if s.strip()]

    failures = []
    t_start = time.time()
    for name in want:
        print(f"\n{'=' * 72}\n[benchmarks.run] {name}\n{'=' * 72}")
        t0 = time.time()
        try:
            if name == "step1":
                from . import step1
                step1.run(iters=args.iters)
            elif name == "step2":
                from . import step2
                step2.run(iters=args.iters)
            elif name == "step3":
                from . import step3
                step3.run(iters=args.iters)
            elif name == "kernels":
                from . import kernels
                kernels.run()
            elif name == "join":
                from . import kernels
                kernels.bench_join()
            elif name == "pipeline":
                from . import pipeline
                pipeline.run(iters=args.iters)
            elif name == "roofline":
                from . import roofline
                roofline.run()
            elif name == "serve":
                from . import serve
                serve.run(iters=args.iters)
            else:
                print(f"unknown benchmark {name!r}")
                failures.append(name)
                continue
            print(f"[benchmarks.run] {name} done in {time.time() - t0:.1f}s")
        except Exception:
            traceback.print_exc()
            failures.append(name)

    print(f"\n[benchmarks.run] total {time.time() - t_start:.1f}s; "
          f"{'ALL OK' if not failures else 'FAILED: ' + ', '.join(failures)}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
