"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod 16x16 mesh, derive the three terms:

    compute    = HLO_FLOPs / (chips x 197e12 FLOP/s)      [bf16 peak / chip]
    memory     = HLO_bytes / (chips x 819e9 B/s)          [HBM bw / chip]
    collective = collective_bytes_per_device / 50e9 B/s   [ICI link bw]

``cost_analysis()`` on the CPU dry-run backend reports whole-program FLOPs
and bytes; we divide by chip count for per-chip terms.  Collective bytes are
parsed from the post-SPMD HLO (they are per-device already).  The dominant
term is the bottleneck the §Perf loop iterates on; MODEL_FLOPS/HLO_FLOPs
flags remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from .common import format_table, save_results

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")

PEAK_FLOPS = 197e12          # TPU v5e bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link
CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops(rec: dict) -> float:
    """6·N_active·D for the step kind (train: x3 for fwd+bwd; decode: D=1·B)."""
    n = rec.get("params_active") or rec.get("params_total") or 0
    shape = rec["shape"]
    batch = {"train_4k": 256, "prefill_32k": 32, "decode_32k": 128,
             "long_500k": 1}[shape]
    seq = {"train_4k": 4096, "prefill_32k": 32768, "decode_32k": 1,
           "long_500k": 1}[shape]
    tokens = batch * seq
    mult = 6.0 if shape == "train_4k" else 2.0   # fwd+bwd vs fwd-only
    return mult * n * tokens


def analyze(rec: dict) -> Optional[dict]:
    if rec.get("status") != "OK":
        return None
    chips = CHIPS.get(rec["mesh"], 256)
    flops = rec["cost"].get("flops", 0.0)
    byts = rec["cost"].get("bytes accessed", 0.0)
    # cost_analysis flops on the dry-run backend are whole-program
    t_compute = flops / (chips * PEAK_FLOPS)
    t_memory = byts / (chips * HBM_BW)
    coll = rec.get("collectives", {})
    coll_bytes = sum(v for k, v in coll.items() if k != "count")
    t_coll = coll_bytes / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "hlo_flops": flops, "hlo_bytes": byts, "coll_bytes": coll_bytes,
        "model_flops": mf,
        "useful_ratio": (mf / (flops * chips)) if flops else 0.0,
        "roofline_frac": (
            min(1.0, terms["compute"] / max(terms.values()))
            if max(terms.values()) > 0 else 0.0
        ),
    }


def load_records(mesh_tag: str = "pod1") -> List[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, f"*__{mesh_tag}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def run() -> dict:
    recs = load_records("pod1")
    if not recs:
        print("no dry-run artifacts found — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun --both-meshes` first")
        return {}
    rows, out = [], {}
    skips = []
    for rec in recs:
        a = analyze(rec)
        key = f"{rec['arch']}/{rec['shape']}"
        if a is None:
            skips.append([rec["arch"], rec["shape"], rec.get("status", "?")])
            continue
        out[key] = a
        rows.append([
            rec["arch"], rec["shape"], fmt_s(a["t_compute_s"]),
            fmt_s(a["t_memory_s"]), fmt_s(a["t_collective_s"]),
            a["dominant"],
            f"{a['useful_ratio'] * 100:.0f}%",
            f"{a['roofline_frac'] * 100:.0f}%",
        ])
    print(format_table(
        "Roofline terms per (arch x shape), single-pod 16x16, v5e constants",
        ["arch", "shape", "compute", "memory", "collective", "bottleneck",
         "useful FLOPs", "roofline frac"],
        rows,
    ))
    if skips:
        print(format_table("Skipped cells", ["arch", "shape", "status"], skips))
    save_results("roofline", out)
    return out


if __name__ == "__main__":
    run()
