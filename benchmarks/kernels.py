"""Pallas kernel micro-benchmarks: fidelity + shape sweeps vs ref oracles.

This container executes kernels in ``interpret=True`` mode (Python on CPU),
so wall-clock here is NOT TPU performance — the numbers that matter for the
kernels are the roofline terms in EXPERIMENTS.md §Roofline.  What this bench
certifies per kernel: (a) allclose vs the pure-jnp oracle at benchmark
shapes, (b) the jnp fallback's wall time (the path XLA actually runs on CPU),
(c) arithmetic-intensity bookkeeping used by the roofline analysis.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kb import kb_from_triples
from repro.core.pattern import Bindings, CompiledPattern, Slot

from repro.kernels.closure import ops as cl_ops
from repro.kernels.closure.ref import closure_ref
from repro.kernels.decode_attention import ops as da_ops
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hash_join import ops as hj_ops
from repro.kernels.hash_join.ref import match_matrix_ref
from repro.kernels.ssd import ops as ssd_ops
from repro.kernels.ssd.ref import ssd_ref

from .common import format_table, ms, save_results, time_fn


def bench_flash_attention():
    rows, out = [], {}
    for (b, hq, hk, t, d), win in [((1, 4, 2, 256, 64), None),
                                   ((2, 8, 2, 512, 64), None),
                                   ((1, 4, 4, 512, 64), 128)]:
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(t), 3)
        q = jax.random.normal(k1, (b, hq, t, d), jnp.float32)
        k = jax.random.normal(k2, (b, hk, t, d), jnp.float32)
        v = jax.random.normal(k3, (b, hk, t, d), jnp.float32)
        got = fa_ops.flash_attention(q, k, v, causal=True, window=win)
        want = attention_ref(q, k, v, causal=True, window=win)
        err = float(jnp.max(jnp.abs(got - want)))
        ref_fn = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True,
                                                       window=win))
        tt = time_fn(ref_fn, q, k, v, iters=3)
        flops = 4 * b * hq * t * t * d   # qk + av
        key = f"b{b}h{hq}/{hk}t{t}d{d}" + (f"w{win}" if win else "")
        out[key] = {"max_err": err, "jnp_s": tt["median_s"], "flops": flops}
        rows.append(["flash_attention", key, f"{err:.2e}", ms(tt["median_s"])])
    return out, rows


def bench_decode_attention():
    rows, out = [], {}
    for b, hq, hk, s, d in [(4, 8, 2, 1024, 64), (8, 8, 8, 4096, 128)]:
        ks = jax.random.split(jax.random.PRNGKey(s), 3)
        q = jax.random.normal(ks[0], (b, hq, 1, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, hk, s, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, hk, s, d), jnp.float32)
        lengths = jnp.asarray(
            np.random.default_rng(s).integers(s // 2, s + 1, size=b), jnp.int32)
        got = da_ops.decode_attention(q, k, v, lengths)
        want = decode_attention_ref(q, k, v, lengths)
        err = float(jnp.max(jnp.abs(got - want)))
        ref_fn = jax.jit(decode_attention_ref)
        tt = time_fn(ref_fn, q, k, v, lengths, iters=3)
        key = f"b{b}h{hq}/{hk}s{s}d{d}"
        out[key] = {"max_err": err, "jnp_s": tt["median_s"]}
        rows.append(["decode_attention", key, f"{err:.2e}", ms(tt["median_s"])])
    return out, rows


def bench_ssd():
    rows, out = [], {}
    for b, t, nh, hd, s in [(1, 256, 4, 32, 32), (2, 512, 8, 32, 64)]:
        ks = jax.random.split(jax.random.PRNGKey(b + t), 5)
        x = jax.random.normal(ks[0], (b, t, nh, hd), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, nh), jnp.float32))
        A = -jnp.exp(jax.random.normal(ks[2], (nh,), jnp.float32) * 0.3)
        B = jax.random.normal(ks[3], (b, t, 1, s), jnp.float32)
        C = jax.random.normal(ks[4], (b, t, 1, s), jnp.float32)
        D = jnp.ones((nh,), jnp.float32)
        got = ssd_ops.ssd(x, dt, A, B, C, D, use_pallas=True)
        want, _ = ssd_ref(x, dt, A, B, C, D)
        err = float(jnp.max(jnp.abs(got - want)))
        ref_fn = jax.jit(lambda *a: ssd_ref(*a)[0])
        tt = time_fn(ref_fn, x, dt, A, B, C, D, iters=3)
        key = f"b{b}t{t}h{nh}p{hd}s{s}"
        out[key] = {"max_err": err, "jnp_s": tt["median_s"]}
        rows.append(["ssd_chunk_scan", key, f"{err:.2e}", ms(tt["median_s"])])
    return out, rows


def bench_closure():
    rows, out = [], {}
    for n in [128, 256, 512]:
        rng = np.random.default_rng(n)
        adj = jnp.asarray((rng.random((n, n)) < 0.02).astype(np.float32))
        got = cl_ops.transitive_closure(adj, max_depth=n, use_pallas=True)
        want = closure_ref(adj, steps=int(np.ceil(np.log2(n))))
        ok = bool(jnp.all(got == (want > 0.5)))
        ref_fn = jax.jit(lambda a: closure_ref(a, steps=int(np.ceil(np.log2(n)))))
        tt = time_fn(ref_fn, adj, iters=3)
        out[f"n{n}"] = {"exact": ok, "jnp_s": tt["median_s"]}
        rows.append(["closure", f"n{n}", "exact" if ok else "MISMATCH",
                     ms(tt["median_s"])])
    return out, rows


def _join_world(m, n, nv=3, seed=None):
    rng = np.random.default_rng(seed if seed is not None else m + n)
    base = 5000
    cols = rng.integers(base, base + 200, size=(m, nv)).astype(np.uint32)
    kb_rows = [
        (int(rng.integers(base, base + 200)), 1,
         int(rng.integers(base, base + 200)))
        for _ in range(n - 8)
    ]
    kb = kb_from_triples(kb_rows, capacity=n)
    bind = Bindings(jnp.asarray(cols), jnp.ones((m,), bool),
                    jnp.zeros((), bool))
    pat = CompiledPattern(Slot.bound(0), Slot.const_(1), Slot.free(1))
    return bind, kb, pat


def bench_join_fused():
    """Before/after for the fused join->compaction pipeline -> BENCH_join.json.

    *before* — the engine's unfused scan join: materialize the [M, N] match
    matrix, broadcast the [M, N, nv] row extension, compact M*N rows.
    *after* — the fused jnp path (the formulation XLA executes on this CPU
    host; identical algorithm to the Pallas kernel's count+scatter phases).
    The Pallas fused kernel itself runs in interpret mode here, so it is
    checked for bit-exactness but timed only as the jnp twin; the in-kernel
    scatter's Mosaic lowering must be validated before flipping
    ``interpret=False`` on real hardware (see hash_join/kernel.py), where
    the fusion targets the HBM-traffic ratio (O(M*N) -> O(M*N read-once +
    out_cap)).
    """
    from repro.core import algebra

    rows, out = [], {}
    for m, n, cap in [(128, 2048, 256), (256, 4096, 512), (256, 8192, 512)]:
        bind, kb, pat = _join_world(m, n)

        def run(c, v, fused):
            return algebra.kb_join_scan(
                Bindings(c, v, jnp.zeros((), bool)), kb, pat, cap,
                fuse_compaction=fused,
            )

        base_fn = jax.jit(lambda c, v: run(c, v, False))
        fused_fn = jax.jit(lambda c, v: run(c, v, True))
        want = base_fn(bind.cols, bind.valid)
        got = fused_fn(bind.cols, bind.valid)
        exact = bool(jnp.all(got.cols == want.cols)
                     & jnp.all(got.valid == want.valid))
        # Pallas fused kernel: parity only (interpret mode is not a timing)
        got_pl = algebra.kb_join_scan(bind, kb, pat, cap, use_pallas=True,
                                      fuse_compaction=True)
        exact &= bool(jnp.all(got_pl.cols == want.cols)
                      & jnp.all(got_pl.valid == want.valid))
        tb = time_fn(base_fn, bind.cols, bind.valid, iters=5)
        tf = time_fn(fused_fn, bind.cols, bind.valid, iters=5)
        speedup = tb["median_s"] / max(tf["median_s"], 1e-9)
        key = f"m{m}xn{n}cap{cap}"
        out[key] = {
            "exact": exact,
            "before_unfused_s": tb["median_s"],
            "after_fused_s": tf["median_s"],
            "speedup": speedup,
        }
        rows.append(["join_fused", f"{m}x{n}->cap{cap}",
                     "exact" if exact else "MISMATCH",
                     f"{ms(tb['median_s'])} -> {ms(tf['median_s'])} "
                     f"({speedup:.1f}x)"])
    return out, rows


def _probe_world(m, n, fanout, seed=None):
    """An anchored const-predicate join with controlled fan-out.

    ``n`` KB rows under one predicate, subjects drawn from a pool so every
    subject carries exactly ``fanout`` rows; binding rows anchor on pool
    subjects.  Subject-anchored probes and scans emit matches in the same
    (p,s)-view order, so scan-vs-probe results must be bit-identical.
    """
    rng = np.random.default_rng(seed if seed is not None else m + n)
    base = 5000
    pool = max(1, n // fanout)
    subs = base + np.repeat(np.arange(pool, dtype=np.int64), fanout)[: n - 8]
    kb_rows = [(int(s), 1, int(rng.integers(base, base + pool)))
               for s in subs]
    kb = kb_from_triples(kb_rows, capacity=n)
    cols = rng.integers(base, base + pool, size=(m, 3)).astype(np.uint32)
    bind = Bindings(jnp.asarray(cols), jnp.ones((m,), bool),
                    jnp.zeros((), bool))
    pat = CompiledPattern(Slot.bound(0), Slot.const_(1), Slot.free(1))
    return bind, kb, pat


def bench_probe_join():
    """Cost-based KB access: fused scan vs probe -> BENCH_join.json "probe".

    The paper's Figs. 5-7 relationship at kernel granularity: the scan pays
    the whole partition per join while the probe pays O(log N) + k_max
    gathers per binding row, so the gap widens linearly with KB size.  Each
    shape runs the planner's actual cost model
    (:func:`repro.core.planner._choose_kb_method` over
    :func:`repro.core.kb.collect_kb_stats`) to confirm "auto" picks the
    probe and to derive its ``k_max``; *exact* certifies the probe result
    (and the fused Pallas probe kernel in interpret mode) bit-identical to
    the fused scan — the CI tripwire asserts it stays true.
    """
    from repro.core import algebra
    from repro.core.kb import collect_kb_stats
    from repro.core.planner import _choose_kb_method

    rows, out = [], {}
    for m, n, fanout in [(256, 8192, 4), (256, 32768, 4), (256, 131072, 4)]:
        bind, kb, pat = _probe_world(m, n, fanout)
        cap = m * fanout
        stats = collect_kb_stats(kb)
        method, k_max = _choose_kb_method(pat, stats, 8)
        assert method == "probe", (method, stats.preds.get(1))

        def scan_run(c, v):
            return algebra.kb_join_scan(
                Bindings(c, v, jnp.zeros((), bool)), kb, pat, cap,
                fuse_compaction=True,
            )

        def probe_run(c, v, k=k_max):
            return algebra.kb_join_probe(
                Bindings(c, v, jnp.zeros((), bool)), kb, pat, cap, k)

        scan_fn = jax.jit(scan_run)
        probe_fn = jax.jit(probe_run)
        want = scan_fn(bind.cols, bind.valid)
        got = probe_fn(bind.cols, bind.valid)
        exact = bool(jnp.all(got.cols == want.cols)
                     & jnp.all(got.valid == want.valid)
                     & (got.overflow == want.overflow))
        # fused Pallas probe kernel: parity only (interpret mode, not timed)
        got_pl = algebra.kb_join_probe(bind, kb, pat, cap, k_max,
                                       use_pallas=True)
        exact &= bool(jnp.all(got_pl.cols == want.cols)
                      & jnp.all(got_pl.valid == want.valid)
                      & (got_pl.overflow == want.overflow))
        ts = time_fn(scan_fn, bind.cols, bind.valid, iters=5)
        tp = time_fn(probe_fn, bind.cols, bind.valid, iters=5)
        speedup = ts["median_s"] / max(tp["median_s"], 1e-9)
        key = f"m{m}xn{n}f{fanout}"
        out[key] = {
            "exact": exact,
            "auto_method": method,
            "derived_k_max": k_max,
            "fused_scan_s": ts["median_s"],
            "probe_s": tp["median_s"],
            "speedup": speedup,
        }
        rows.append(["probe_join", f"{m}x{n} fan{fanout} k{k_max}",
                     "exact" if exact else "MISMATCH",
                     f"{ms(ts['median_s'])} -> {ms(tp['median_s'])} "
                     f"({speedup:.1f}x)"])
    return out, rows


def write_bench_join(fused_out, probe_out):
    """Combine the scan-fusion and probe sections into BENCH_join.json."""
    import json

    payload = {
        "what": "scan-method KB join: unfused (materialize [M,N] + compact) "
                "vs fused join->compaction, jit on this host",
        "note": "Pallas fused kernels verified bit-exact in interpret mode; "
                "timings are the jnp paths XLA runs on CPU hosts.",
        "results": fused_out,
        "probe": {
            "what": "cost-based KB access: fused scan vs probe on an "
                    "anchored const-predicate join, k_max derived by the "
                    "planner's cost model from collect_kb_stats",
            "results": probe_out,
        },
    }
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_join.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    print(f"[bench_join] wrote {os.path.normpath(path)}")


def bench_join():
    """The ``--only join`` entry: both join sections + the combined file."""
    fused_out, fused_rows = bench_join_fused()
    probe_out, probe_rows = bench_probe_join()
    write_bench_join(fused_out, probe_out)
    return {"bench_join_fused": fused_out, "bench_probe_join": probe_out}, \
        fused_rows + probe_rows


def bench_hash_join():
    rows, out = [], {}
    for m, n in [(128, 1024), (256, 4096), (512, 8192)]:
        rng = np.random.default_rng(m + n)
        base = 5000
        cols = rng.integers(base, base + 200, size=(m, 2)).astype(np.uint32)
        kb_rows = [
            (int(rng.integers(base, base + 200)), 1,
             int(rng.integers(base, base + 200)))
            for _ in range(n - 8)
        ]
        kb = kb_from_triples(kb_rows, capacity=n)
        bind = Bindings(jnp.asarray(cols), jnp.ones((m,), bool),
                        jnp.zeros((), bool))
        pat = CompiledPattern(Slot.bound(0), Slot.const_(1), Slot.free(1))
        got = hj_ops.match_matrix(bind, kb, pat)
        want = match_matrix_ref(bind.cols, bind.valid, kb.s_ps, kb.p_ps,
                                kb.o_ps, kb.valid, pat)
        ok = bool(jnp.all(got == want))
        ref_fn = jax.jit(lambda c, v: match_matrix_ref(
            c, v, kb.s_ps, kb.p_ps, kb.o_ps, kb.valid, pat))
        tt = time_fn(ref_fn, bind.cols, bind.valid, iters=3)
        out[f"m{m}xn{n}"] = {"exact": ok, "jnp_s": tt["median_s"]}
        rows.append(["hash_join", f"{m}x{n}", "exact" if ok else "MISMATCH",
                     ms(tt["median_s"])])
    return out, rows


def run() -> dict:
    all_rows, results = [], {}
    for fn in (bench_hash_join, bench_join_fused, bench_probe_join,
               bench_closure, bench_flash_attention, bench_decode_attention,
               bench_ssd):
        out, rows = fn()
        results[fn.__name__] = out
        all_rows += rows
    write_bench_join(results["bench_join_fused"], results["bench_probe_join"])
    print(format_table(
        "Pallas kernels — fidelity sweeps (interpret mode) + jnp-path wall time",
        ["kernel", "shape", "vs ref", "jnp time"], all_rows,
    ))
    save_results("kernels", results)
    return results


if __name__ == "__main__":
    run()
