"""Paper Table 1 (first step): Q15 / Q16 under both KB-access methods.

* ``scan``  ≙ "C-SPARQL KB access"  — engine scans an attached (pre-extracted)
  KB file per window; its store holds only the query-relevant slice, so
  *total = used* (paper: 103,075 for both).
* ``probe`` ≙ "SPARQL subquery" (SERVICE) — indexed endpoint lookups against
  the FULL knowledge base (paper total: 368,720,213), cost ~independent of
  unused triples.

Reported per (query × method): total KB size, used KB size, and steady-state
processing time per window (compile excluded), mirroring the paper's table.
"""
from __future__ import annotations

import numpy as np

from repro.core import paper_queries as PQ
from repro.core.planner import prune_kb_for
from repro.core.session import ExecutionConfig

from .common import (
    BenchWorld, build_world, format_table, make_session, ms, save_results,
    time_fn,
)

WINDOW_CAP = 256
MAX_WINDOWS = 4


def _exec_cfg(method: str) -> ExecutionConfig:
    return ExecutionConfig(
        mode="monolithic", window_capacity=WINDOW_CAP,
        max_windows=MAX_WINDOWS, bind_cap=2048, scan_cap=512, out_cap=2048,
        kb_method=method,
    )


def run(world: BenchWorld = None, iters: int = 5) -> dict:
    world = world or build_world(num_tweets=192, num_artists=96, num_shows=48,
                                 filler=4000, co_mention=False)
    kbs, ts, vocab = world.kbd.schema, world.tweets, world.vocab
    full_kb = world.kbd.kb
    total_full = int(np.asarray(full_kb.count()))

    results = {}
    rows = []
    for qname, builder in (("Q15", PQ.q15), ("Q16", PQ.q16)):
        q = builder(vocab, ts, kbs)
        used_kb = prune_kb_for(q, full_kb)
        used = int(np.asarray(used_kb.count()))
        for method in ("scan", "probe"):
            # scan ≙ engine-attached extracted KB slice (total == used);
            # probe ≙ endpoint holding the full KB (total == |full KB|).
            kb = used_kb if method == "scan" else full_kb
            total = used if method == "scan" else total_full
            reg = make_session(world, _exec_cfg(method), kb=kb).register(q)
            chunk = world.chunks[0]
            t = time_fn(lambda c: reg.process_chunk(c)[0], chunk, iters=iters)
            n_valid = int(np.asarray(chunk.valid.sum()))
            n_windows = min(MAX_WINDOWS, -(-n_valid // WINDOW_CAP))
            per_window = t["median_s"] / n_windows
            label = "C-SPARQL KB access" if method == "scan" else "SPARQL subquery"
            results[f"{qname}/{method}"] = {
                "total_kb": total, "used_kb": used,
                "per_window_s": per_window, **t,
            }
            rows.append([qname, label, total, used, ms(per_window)])

    table = format_table(
        "Table 1 — first step: Q15/Q16 x KB-access method",
        ["query", "KB access method", "total KB", "used KB", "time/window"],
        rows,
    )
    print(table)
    # the paper's qualitative claims for this table
    q15_scan = results["Q15/scan"]["per_window_s"]
    q15_probe = results["Q15/probe"]["per_window_s"]
    q16_scan = results["Q16/scan"]["per_window_s"]
    q16_probe = results["Q16/probe"]["per_window_s"]
    print(f"[check] Q15 probe beats scan (paper: 1.3s < 5s): "
          f"{q15_probe < q15_scan} ({ms(q15_probe)} vs {ms(q15_scan)})")
    print(f"[note]  Q16 here: probe {ms(q16_probe)} vs scan {ms(q16_scan)} — "
          f"the paper's Q16 scan-win (0.64s < 1.61s) came from per-window "
          f"SERVICE network round-trips to a 368M-triple endpoint; our probe "
          f"is an in-memory indexed lookup with no RTT, so it wins on both "
          f"queries (relationship documented, not asserted)")
    print(f"[check] probe cost ~independent of unused KB "
          f"(total {results['Q15/probe']['total_kb']} vs used "
          f"{results['Q15/probe']['used_kb']}): probe/scan ratio "
          f"{q15_probe / q15_scan:.2f}")
    save_results("step1_table1", {"results": results, "table": table})
    return results


if __name__ == "__main__":
    run()
