"""Chaos smoke benchmark: seeded fault injection -> BENCH_chaos.json.

Runs the paper's CQuery1 through the pipelined runtime under a seeded
:class:`FaultPlan` (every fault kind aimed at the source stage, so the
plan needs no knowledge of the query DAG) and verifies the recovery
tripwires the CI chaos-smoke job asserts on:

* the recovered pipelined stream is **bit-identical** to a fault-free
  monolithic run — zero lost rows, zero duplicated rows;
* every scheduled fault fired exactly once (``injected == scheduled``)
  and at least one operator restart was actually exercised;
* the per-stage jaxprs of the chaotic runtime are byte-identical to a
  plain (recovery=None) pipelined runtime — all fault/recovery machinery
  lives on the host driver, never inside a traced program.

    PYTHONPATH=src python -m benchmarks.chaos              # default seed
    PYTHONPATH=src python -m benchmarks.chaos --seed 7
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time

from repro.launch.mesh import ensure_host_devices

ensure_host_devices(4)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import paper_queries as PQ
from repro.core.faults import FaultEvent, FaultPlan
from repro.core.recovery import RecoveryConfig
from repro.core.session import ExecutionConfig

from .common import build_world, format_table, make_session

DEFAULT_SEED = 1234


def _jaxpr_pin(plain, chaotic, chunk) -> bool:
    """True iff every per-stage traced program is byte-identical between a
    plain pipelined runtime and the fault-injected resilient one."""
    def jp(fn, *args):
        return str(jax.make_jaxpr(fn)(*args))

    if jp(plain._windows_impl, chunk) != jp(chaotic._windows_impl, chunk):
        return False
    _, opp_shape = jax.eval_shape(plain._windows_impl, chunk)
    op_payload = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                              opp_shape)
    for name in plain.upstream:
        pa, pb = plain.operators[name], chaotic.operators[name]
        if jp(functools.partial(plain._op_impl, name),
              op_payload, pa.kb, pa.env) != \
           jp(functools.partial(chaotic._op_impl, name),
              op_payload, pb.kb, pb.env):
            return False
    if plain._agg_win_ch is not None and chaotic._agg_win_ch is not None:
        fa = plain.operators[plain.final]
        fb = chaotic.operators[chaotic.final]
        if jp(plain._sink_impl, plain._agg_win_ch, plain._out_ch,
              fa.kb, fa.env) != \
           jp(chaotic._sink_impl, chaotic._agg_win_ch, chaotic._out_ch,
              fb.kb, fb.env):
            return False
    return True


def run(seed: int = DEFAULT_SEED):
    world = build_world(num_tweets=48, num_artists=16, num_shows=8,
                        filler=120, chunk_capacity=96)
    chunks = world.chunks
    assert len(chunks) >= 3, (
        "chaos stream too short for a mid-stream crash: %d chunks"
        % len(chunks))
    base = ExecutionConfig(window_capacity=64, max_windows=4, bind_cap=512,
                           scan_cap=128, out_cap=512, intermediate_cap=256,
                           channel_capacity=4)
    q = PQ.cquery1(world.vocab, world.tweets, world.kbd.schema)

    # a seeded schedule, hardened with one guaranteed mid-stream crash so
    # the restart tripwire below is exercised for every seed
    events = list(FaultPlan.seeded(seed, ("source",), len(chunks),
                                   n_events=4).events)
    if not any(ev.kind == "crash_stage" for ev in events):
        events.append(FaultEvent("crash_stage", "source",
                                 min(2, len(chunks) - 1)))
    plan = FaultPlan(tuple(events))
    print(f"[bench_chaos] seed={seed}, {len(chunks)} chunks, "
          f"plan={plan.counts()}")

    mono = make_session(world, base.replace(mode="monolithic")).register(q)
    outs_mono, ovf_mono = mono.run(chunks)

    # max_restarts sized above the worst case of the seeded plan (several
    # desync-triggering events can blame the same chunk), so the smoke
    # exercises full channel-path recovery rather than the degraded
    # fallback — degradation has its own coverage in tests/test_faults.py
    chaotic = make_session(world, base.replace(
        mode="pipelined", faults=plan,
        recovery=RecoveryConfig(checkpoint_every=2,
                                max_restarts=2 * len(plan.events)))).register(q)
    t0 = time.perf_counter()
    outs_chaos, ovf_chaos = chaotic.run(chunks)
    chaos_pass_s = time.perf_counter() - t0

    bit_exact = len(outs_chaos) == len(outs_mono)
    for a, b in zip(outs_mono, outs_chaos):
        for col_a, col_b in zip(a, b):
            bit_exact = bit_exact and bool(
                np.all(np.asarray(col_a) == np.asarray(col_b)))
    assert bit_exact, "recovered chaos stream diverges from fault-free run"
    clipped = {n: c for n, c in {**ovf_mono, **ovf_chaos}.items() if c}
    assert not clipped, "overflowed windows under chaos: %s" % clipped

    stats = chaotic.last_stats
    rec = stats["recovery"]
    assert rec["enabled"], "recovery surface missing from last_stats"
    assert rec["injected"] == plan.counts() == rec["scheduled"], (
        "injected %s != scheduled %s" % (rec["injected"], rec["scheduled"]))
    assert rec["restarts"] >= 1, "no restart exercised — tripwire dead"
    assert not stats["degraded"], (
        "chaos run degraded: %s" % rec["degraded_chunks"])

    plain = make_session(world, base.replace(mode="pipelined")).register(q)
    pin_ok = _jaxpr_pin(plain.runtime, chaotic.runtime, chunks[0])
    assert pin_ok, "fault machinery leaked into a traced stage program"

    rows = [[k, v] for k, v in sorted(rec["injected"].items()) if v]
    rows += [["restarts", rec["restarts"]], ["retries", rec["retries"]],
             ["replayed", rec["replayed"]], ["deduped", rec["deduped"]],
             ["checkpoints", rec["checkpoints"]]]
    print(format_table("chaos run (seed %d): injected faults + recovery"
                       % seed, ["event", "count"], rows))
    print("[bench_chaos] recovered bit-exact in %.1f ms "
          "(compile-inclusive first pass)" % (chaos_pass_s * 1e3))

    payload = {
        "what": "seeded chaos smoke: CQuery1 through the pipelined runtime "
                "under a FaultPlan covering every fault kind; recovered "
                "stream bit-identical to a fault-free monolithic run, all "
                "scheduled events fired, >=1 restart exercised, per-stage "
                "jaxprs pinned identical to a recovery-free runtime",
        "seed": seed,
        "num_chunks": len(chunks),
        "plan": [{"kind": ev.kind, "stage": ev.stage, "chunk": ev.chunk}
                 for ev in plan.events],
        "scheduled": rec["scheduled"],
        "injected": rec["injected"],
        "recovery": rec,
        "bit_exact_vs_fault_free": bool(bit_exact),
        "restart_exercised": rec["restarts"] >= 1,
        "jaxpr_pin_ok": bool(pin_ok),
        "degraded": stats["degraded"],
        "chaos_pass_s": chaos_pass_s,
    }
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_chaos.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    print(f"[bench_chaos] wrote {os.path.normpath(path)}")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=DEFAULT_SEED,
                    help="FaultPlan seed (the CI job pins this)")
    args = ap.parse_args(argv)
    run(seed=args.seed)


if __name__ == "__main__":
    main()
