"""Paper Tables 2-3 (second step): CQuery1 monolithic vs decomposed (Fig. 4).

Table 2: the whole CQuery1 in ONE operator against the full KB.
Table 3: the automatic decomposition — artist-KB operator (QueryA), show-KB
operator (QueryB) each against their pruned used-KB slice, plus the
aggregation operator (QueryG).  The paper's headline: 29% (scan) / 23%
(probe) processing-time reduction with identical results.

We report both the paper-faithful *critical path* (operators on separate
machines: ``max(QueryA, QueryB) + QueryG`` — upstream operators run in
parallel, Fig. 4) and the fused single-program time (beyond-paper: the whole
DAG traced into one XLA program, our TPU-native deployment mode).
"""
from __future__ import annotations

import numpy as np

from repro.core import paper_queries as PQ
from repro.core.rdf import to_host_rows
from repro.core.session import ExecutionConfig

from .common import (
    BenchWorld, build_world, format_table, make_session, ms, save_results,
    time_fn,
)

WINDOW_CAP = 256
MAX_WINDOWS = 4


def _cfg(method: str, mode: str) -> ExecutionConfig:
    return ExecutionConfig(
        mode=mode, window_capacity=WINDOW_CAP, max_windows=MAX_WINDOWS,
        bind_cap=2048, scan_cap=512, out_cap=2048, kb_method=method,
    )


def _results(out):
    return sorted(set((r[0], r[1], r[2]) for r in to_host_rows(out)))


def run(world: BenchWorld = None, iters: int = 5) -> dict:
    world = world or build_world(num_tweets=160, num_artists=64, num_shows=32,
                                 filler=3000, co_mention=True)
    q = PQ.cquery1(world.vocab, world.tweets, world.kbd.schema)
    chunk = world.chunks[0]
    total_kb = int(np.asarray(world.kbd.kb.count()))
    results = {}

    for method in ("scan", "probe"):
        cfg = _cfg(method, "single_program")
        mono = make_session(world, _cfg(method, "monolithic")).register(q)
        reg = make_session(world, cfg).register(q)
        split, dag = reg.runtime, reg.dag

        # -- results must be identical (paper: "All results are the same")
        res_m = _results(mono.process_chunk(chunk)[0])
        res_s = _results(reg.process_chunk(chunk)[0])
        assert res_m == res_s and len(res_m) > 0, "decomposition changed results!"

        # -- Table 2: monolithic
        t_mono = time_fn(lambda c: mono.process_chunk(c)[0], chunk, iters=iters)

        # -- Table 3: per-operator steady-state times (operators as deployed
        #    units on separate machines — each timed as its own jitted program)
        import jax
        from repro.core.stream import merge_streams
        from repro.core.window import count_windows

        merged = merge_streams([chunk])
        windows = count_windows(merged, cfg.window_capacity, cfg.max_windows)
        op_times = {}
        upstream = {}
        for name, op in split.operators.items():
            if name == dag.final:
                continue
            fn = jax.jit(lambda w, kb, env, op=op: op.process_windows(w, kb, env))
            op_times[name] = time_fn(fn, windows, op.kb, op.env, iters=iters)
            upstream[name] = op.process_windows(windows, op.kb, op.env)[0]

        # aggregation operator on the window-aligned augmented stream
        import jax.numpy as jnp
        from repro.core.rdf import TripleBatch
        from repro.core.window import Windows

        final_op = split.operators[dag.final]
        parts = [windows.triples] + [
            upstream[src] for src in dag.subqueries[dag.final].inputs
            if src != "stream"
        ]
        aug = TripleBatch(*(jnp.concatenate(c, axis=-1) for c in zip(*parts)))
        aug_w = Windows(aug, windows.window_valid)
        fn_agg = jax.jit(
            lambda w, kb, env: final_op.process_windows(w, kb, env))
        op_times[dag.final] = time_fn(fn_agg, aug_w, final_op.kb, final_op.env,
                                      iters=iters)

        # -- fused whole-DAG single program (beyond-paper deployment)
        t_fused = time_fn(lambda c: split.process_chunk(c)[0], chunk, iters=iters)

        kb_ops = [n for n in op_times if n != dag.final]
        critical = max(op_times[n]["median_s"] for n in kb_ops) \
            + op_times[dag.final]["median_s"]
        reduction = 1.0 - critical / t_mono["median_s"]
        fused_reduction = 1.0 - t_fused["median_s"] / t_mono["median_s"]

        used = {
            n: int(np.asarray(split.operators[n].kb.count()))
            for n in kb_ops if split.operators[n].kb is not None
        }
        results[method] = {
            "total_kb": total_kb,
            "used_kb": used,
            "mono_s": t_mono["median_s"],
            "op_times_s": {n: t["median_s"] for n, t in op_times.items()},
            "critical_path_s": critical,
            "fused_s": t_fused["median_s"],
            "reduction": reduction,
            "fused_reduction": fused_reduction,
            "n_results": len(res_m),
        }

    rows = []
    for method, r in results.items():
        label = "C-SPARQL KB access" if method == "scan" else "SPARQL subquery"
        rows.append([label, "CQuery1 (mono, Table 2)", r["total_kb"],
                     ms(r["mono_s"]), "--"])
        for n, t in r["op_times_s"].items():
            u = r["used_kb"].get(n, "--")
            rows.append([label, n, u, ms(t), "--"])
        rows.append([label, "critical path (Table 3)", "--",
                     ms(r["critical_path_s"]), f"-{r['reduction'] * 100:.0f}%"])
        rows.append([label, "fused DAG (beyond paper)", "--",
                     ms(r["fused_s"]), f"-{r['fused_reduction'] * 100:.0f}%"])
    table = format_table(
        "Tables 2-3 — CQuery1: monolithic vs decomposed (per chunk)",
        ["KB method", "configuration", "used/total KB", "time", "vs mono"],
        rows,
    )
    print(table)
    print(f"[check] results identical mono vs split: True")
    print(f"[check] scan reduction (paper: 29%): "
          f"{results['scan']['reduction'] * 100:.0f}%")
    print(f"[check] probe reduction (paper: 23%): "
          f"{results['probe']['reduction'] * 100:.0f}%")
    save_results("step2_tables2_3", results)
    return results


if __name__ == "__main__":
    run()
