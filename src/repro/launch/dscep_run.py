"""DSCEP pipeline driver — the paper's deployment entry point.

Builds a TweetsKB-like stream + DBpedia-like KB, registers the chosen query
with a :class:`~repro.core.session.Session` (a named paper query, or any
C-SPARQL ``.rq`` file via ``--rq``), and streams chunks through the
configured execution mode, reporting latency/throughput, result counts and
the used-KB partition sizes.

    PYTHONPATH=src python -m repro.launch.dscep_run --query cquery1
    PYTHONPATH=src python -m repro.launch.dscep_run --query q15 \\
        --mode monolithic --method probe --tweets 128
    PYTHONPATH=src python -m repro.launch.dscep_run --query cquery1 \\
        --mode pipelined
    PYTHONPATH=src python -m repro.launch.dscep_run --rq my_query.rq

``--mode pipelined`` selects the streaming dataflow runtime: one jitted step
per operator, bounded device channels on every DAG edge, operators placed on
devices by :func:`repro.launch.mesh.place_operators`, and an async
software-pipelined schedule that keeps ``--channel-capacity`` chunks in
flight (the host blocks only on the sink).  Reports sustained chunks/sec.

``--no-interpret`` compiles the Pallas kernels for the real accelerator
instead of the interpreter (requires actual TPU hardware).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core import paper_queries as PQ
from repro.core.rdf import Vocab, to_host_rows
from repro.core.session import ExecutionConfig, MODES, Session
from repro.core.sparql import SparqlError
from repro.data.dbpedia import KBConfig, generate_kb
from repro.data.tweets import (
    TweetSchema, TweetStreamConfig, generate_tweets, stream_chunks,
)

QUERIES = {"q15": PQ.Q15_RQ, "q16": PQ.Q16_RQ, "cquery1": PQ.CQUERY1_RQ}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--query", default="cquery1", choices=sorted(QUERIES),
                    help="one of the paper's shipped queries")
    ap.add_argument("--rq", default=None, metavar="FILE.rq",
                    help="run an arbitrary C-SPARQL query file instead of "
                         "a named paper query")
    ap.add_argument("--mode", default="single_program", choices=list(MODES),
                    help="execution mode: monolithic (no decomposition), "
                         "single_program (whole DAG in one XLA program) or "
                         "pipelined (per-operator steps over device channels)")
    ap.add_argument("--method", default="auto",
                    choices=["scan", "probe", "auto"],
                    help="KB access: the paper's scan/probe methods, or "
                         "cost-based per-join selection from used-KB "
                         "statistics (auto, the default)")
    ap.add_argument("--tweets", type=int, default=96)
    ap.add_argument("--artists", type=int, default=48)
    ap.add_argument("--shows", type=int, default=24)
    ap.add_argument("--filler", type=int, default=1000)
    ap.add_argument("--window-cap", type=int, default=256)
    ap.add_argument("--window-from-query", action="store_true",
                    help="let the query's [RANGE TRIPLES n STEP m] clause "
                         "drive its window geometry instead of --window-cap "
                         "(per-query windows)")
    ap.add_argument("--pallas", action="store_true",
                    help="use the Pallas hash-join kernel")
    ap.add_argument("--fuse", action="store_true",
                    help="fused join->compaction (no [M, N] candidate matrix)")
    ap.add_argument("--no-interpret", action="store_true",
                    help="compile Pallas kernels for real hardware instead "
                         "of the interpreter (needs an actual TPU)")
    ap.add_argument("--channel-capacity", type=int, default=2,
                    help="slots per inter-operator channel = chunks kept "
                         "in flight (pipelined mode only)")
    ap.add_argument("--placement", default="round_robin",
                    choices=["round_robin", "single"],
                    help="operator->device placement policy (pipelined only)")
    ap.add_argument("--explain", action="store_true",
                    help="print the planner EXPLAIN (join order, per-join "
                         "access method and k_max, estimated fan-out from "
                         "used-KB statistics) and exit without streaming")
    ap.add_argument("--trace", action="store_true",
                    help="enable stage-level tracing + engine metrics; "
                         "prints per-stage latency and per-operator counter "
                         "tables after the stream (fences stage boundaries, "
                         "so throughput numbers include sync overhead)")
    ap.add_argument("--serve", type=int, default=0, metavar="N",
                    help="multi-query serving mode: register N standing "
                         "queries (paper-query duplicates + filter/class "
                         "variants) with a ServeEngine and stream every "
                         "chunk through all of them, reporting queries/sec "
                         "and the dedup/batching schedule")
    ap.add_argument("--no-dedup", action="store_true",
                    help="serving mode: disable shared-plan dedup and "
                         "prefix sharing (the control arm)")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="pipelined mode: inject a seeded fault plan "
                         "(drops, duplicates, stalls, crashes, corruptions) "
                         "and recover; prints the recovery table after the "
                         "stream")
    ap.add_argument("--checkpoint-every", type=int, default=4, metavar="N",
                    help="chaos mode: operator-checkpoint cadence in "
                         "emitted chunks (0 disables checkpointing)")
    args = ap.parse_args(argv)
    if args.mode == "pipelined" and args.channel_capacity < 2:
        ap.error("--channel-capacity must be >= 2 (double buffering)")
    if args.chaos is not None and args.mode != "pipelined":
        ap.error("--chaos requires --mode pipelined (fault injection needs "
                 "per-operator failure boundaries)")

    vocab = Vocab()
    kbd = generate_kb(vocab, KBConfig(
        num_artists=args.artists, num_shows=args.shows,
        filler_triples=args.filler))
    tweets = TweetSchema.create(vocab)
    pool = np.concatenate([kbd.artist_ids, kbd.show_ids])
    rows = generate_tweets(vocab, tweets, pool, TweetStreamConfig(
        num_tweets=args.tweets, mentions_min=2, mentions_max=4))
    chunks = list(stream_chunks(rows, 4 * args.window_cap))

    faults = recovery = None
    if args.chaos is not None:
        from repro.core.faults import FaultPlan
        from repro.core.recovery import RecoveryConfig

        # every kind fires against "source" (corrupt_chunk auto-targets
        # "ingest"), so the plan is complete without knowing the query DAG
        faults = FaultPlan.seeded(args.chaos, ("source",),
                                  num_chunks=len(chunks), n_events=5)
        recovery = RecoveryConfig(checkpoint_every=args.checkpoint_every)

    cfg = ExecutionConfig(
        mode=args.mode, window_capacity=args.window_cap, max_windows=4,
        bind_cap=2048, scan_cap=512, out_cap=2048, kb_method=args.method,
        use_pallas=args.pallas, fuse_compaction=args.fuse,
        interpret=not args.no_interpret,
        placement=args.placement, channel_capacity=args.channel_capacity,
        window_from_query=args.window_from_query,
        trace=args.trace,
        faults=faults, recovery=recovery,
    )
    session = Session(cfg, vocab=vocab, kb=kbd.kb)
    if args.serve:
        return _run_serve(session, chunks, args)
    if args.rq:
        try:
            reg = session.register_file(args.rq)
        except SparqlError as err:
            _report_rq_error(args.rq, err)
            sys.exit(2)
        qname = reg.query.name
    else:
        qname = args.query
        reg = session.register(QUERIES[qname])

    if args.explain:
        from repro.obs.report import format_explain
        print(format_explain(reg.explain()))
        return 0

    total_kb = int(np.asarray(kbd.kb.count()))
    win, step = reg.window_geometry
    print(f"[dscep] query={qname} method={args.method} mode={args.mode} "
          f"stream={len(rows)} triples in {len(chunks)} chunks, KB={total_kb}")
    print(f"[dscep] window geometry: {win} triples"
          + (f" (STEP {step})" if step else "")
          + (" [from query RANGE clause]" if args.window_from_query else ""))

    if args.mode != "monolithic":
        dag = reg.dag
        print(f"[dscep] operator DAG ({len(dag.subqueries)} operators, "
              f"final={dag.final}):")
        placement = getattr(reg.runtime, "placement", None)
        for name, op in reg.operators.items():
            used = "--" if op.kb is None else int(np.asarray(op.kb.count()))
            place = f"  device: {placement[name]}" if placement else ""
            print(f"    {name:40s} used-KB: {used}{place}")

    if args.mode == "pipelined":
        # async driver: the whole stream is dispatched software-pipelined;
        # per-chunk latency is meaningless here (only the sink blocks), so
        # report sustained throughput instead
        t0 = time.perf_counter()
        outs, overflow = reg.run(chunks)
        t_total = time.perf_counter() - t0
        n_out = sum(len(to_host_rows(o)) for o in outs)
        clipped = {n: c for n, c in overflow.items() if c}
        print(f"[dscep] pipeline: {len(chunks)} chunks in {t_total:.2f}s "
              f"({len(chunks) / t_total:.2f} chunks/s, includes compile), "
              f"{args.channel_capacity} in flight")
        print(f"[dscep] overflowed windows per operator: {clipped or 'none'}")
        for edge, st in reg.runtime.channel_stats().items():
            print(f"    {edge:60s} size={st['size']} "
                  f"dropped={st['overflows']}")
        _report_trace(reg, args)
        _report_recovery(reg)
        print(f"[dscep] done: {n_out} output triples, {t_total:.2f}s total")
        return n_out

    n_out = 0
    t_total = 0.0
    for i, chunk in enumerate(chunks):
        t0 = time.perf_counter()
        out, overflow = reg.process_chunk(chunk)
        dt = time.perf_counter() - t0
        t_total += dt
        res = to_host_rows(out)
        n_out += len(res)
        tag = " (includes compile)" if i == 0 else ""
        ovf = sum(overflow.values())
        print(f"[dscep] chunk {i}: {len(res)} output triples "
              f"in {dt * 1e3:.1f} ms, {ovf} overflowed windows{tag}")
    _report_trace(reg, args)
    print(f"[dscep] done: {n_out} output triples, "
          f"{t_total:.2f}s total")
    return n_out


_SERVE_BASE = """\
REGISTER QUERY %(name)s AS
PREFIX schema: <urn:dscep:schema>
PREFIX rdf: <http://www.w3.org/1999/02/22-rdf-syntax-ns#>
PREFIX rdfs: <http://www.w3.org/2000/01/rdf-schema#>
PREFIX dbo: <http://dbpedia.org/ontology/>
PREFIX out: <urn:dscep:out>
CONSTRUCT { ?tweet out:entityCode ?cc . }
FROM STREAM <stream> [RANGE TRIPLES 1000 STEP 1]
FROM <kb>
WHERE {
  ?tweet schema:mentions ?ent .
  GRAPH <kb> {
    ?ent rdf:type/rdfs:subClassOf* dbo:%(cls)s .
    ?ent dbo:birthPlace/dbo:country/dbo:countryCode ?cc .
  }
}
"""

_SERVE_FILT = """\
REGISTER QUERY %(name)s AS
PREFIX schema: <urn:dscep:schema>
PREFIX out: <urn:dscep:out>
CONSTRUCT { ?tweet out:hot ?ent . }
FROM STREAM <stream> [RANGE TRIPLES 1000 STEP 1]
WHERE {
  ?tweet schema:mentions ?ent .
  ?tweet schema:likes ?l .
  FILTER(?l >= %(thresh)s)
}
"""


def serve_population(n: int):
    """``n`` standing-query texts exercising all three sharing tiers:
    exact duplicates (plan dedup), class variants (shared KB-join prefix)
    and filter-threshold variants (vmap cohort)."""
    texts = []
    classes = ("MusicalArtist", "TelevisionShow")
    for i in range(n):
        kind = i % 3
        if kind == 0:       # duplicates of one base query -> dedup
            texts.append(_SERVE_BASE % {"name": "dup%d" % i,
                                        "cls": "MusicalArtist"})
        elif kind == 1:     # alternating classes -> shared KB-join prefix
            texts.append(_SERVE_BASE % {"name": "cls%d" % i,
                                        "cls": classes[(i // 3) % 2]})
        else:               # distinct thresholds -> vmap cohort
            texts.append(_SERVE_FILT % {"name": "thr%d" % i,
                                        "thresh": "%.1f" % (1.0 + (i // 3))})
    return texts


def _run_serve(session, chunks, args):
    eng = session.serve(dedup=not args.no_dedup)
    texts = serve_population(args.serve)
    t0 = time.perf_counter()
    for t in texts:
        eng.register(t)
    t_reg = time.perf_counter() - t0
    t0 = time.perf_counter()
    outs, overflow = eng.run(chunks)
    t_run = time.perf_counter() - t0
    st = eng.last_stats
    n_out = sum(
        len(to_host_rows(o)) for per_q in outs.values() for o in per_q)
    qps = len(texts) * len(chunks) / t_run
    clipped = sum(overflow.values())
    print(f"[serve] {len(texts)} standing queries x {len(chunks)} chunks "
          f"(dedup={'off' if args.no_dedup else 'on'}): "
          f"registered in {t_reg:.2f}s, streamed in {t_run:.2f}s "
          f"= {qps:.1f} query-evals/s (includes compile)")
    print(f"[serve] schedule: {st['distinct_plans']} distinct plans for "
          f"{st['queries']} queries, shared_plan_hits={st['shared_plan_hits']}, "
          f"shared_prefix_hits={st['shared_prefix_hits']}, "
          f"cohort batch sizes={st['batch_sizes']}, "
          f"singleton operators={st['singletons']}")
    for pg in st["prefix_groups"]:
        print(f"    prefix group ({len(pg['queries'])} plans): "
              f"{pg['prefix_len']} shared steps "
              f"({pg['kb_joins_shared']} KB joins) -> "
              f"{', '.join(pg['queries'][:4])}"
              + ("..." if len(pg["queries"]) > 4 else ""))
    print(f"[serve] done: {n_out} output triples, "
          f"{clipped} overflowed windows")
    return n_out


def _report_rq_error(path, err):
    """Point at the offending ``.rq`` source line for a parse failure."""
    print(f"[dscep] cannot parse {path}: {err}", file=sys.stderr)
    if getattr(err, "line", 0):
        try:
            with open(path) as fh:
                src = fh.read().splitlines()
            bad = src[err.line - 1]
        except (OSError, IndexError):
            return
        print(f"  {err.line:4d} | {bad}", file=sys.stderr)
        print("       | " + " " * max(err.col - 1, 0) + "^", file=sys.stderr)


def _report_recovery(reg):
    """Print the recovery-event table for a fault-injected run."""
    st = reg.last_stats
    rec = st.get("recovery", {})
    if not rec.get("enabled"):
        return
    from repro.obs.report import format_recovery_table
    print(format_recovery_table(rec))
    if st.get("degraded"):
        print("[dscep] runtime is DEGRADED: chunks "
              f"{rec['degraded_chunks']} took the lossless monolithic "
              "fallback path")


def _report_trace(reg, args):
    """Print the stage-latency and engine-metric tables for a traced run."""
    if not args.trace:
        return
    from repro.obs.report import (
        bottleneck_stage, format_metrics_table, format_stage_table,
    )
    stats = reg.last_stats
    if stats["spans"]:
        print(format_stage_table(stats["spans"]))
        prefix = "stage" if args.mode == "pipelined" else "chunk"
        print("[dscep] bottleneck stage: "
              f"{bottleneck_stage(stats['spans'], prefix=prefix)}")
    if stats["operators"]:
        print(format_metrics_table(stats["operators"]))


if __name__ == "__main__":
    main()
