"""DSCEP pipeline driver — the paper's deployment entry point.

Builds a TweetsKB-like stream + DBpedia-like KB, compiles the chosen query
(monolithic or automatically decomposed into the Fig. 4 operator DAG), and
streams chunks through the runtime, reporting per-chunk latency, result
counts and the used-KB partition sizes.

    PYTHONPATH=src python -m repro.launch.dscep_run --query cquery1
    PYTHONPATH=src python -m repro.launch.dscep_run --query q15 --mono \\
        --method probe --tweets 128
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import paper_queries as PQ
from repro.core.planner import decompose
from repro.core.rdf import Vocab, to_host_rows
from repro.core.runtime import DSCEPRuntime, MonolithicRuntime, RuntimeConfig
from repro.data.dbpedia import KBConfig, generate_kb
from repro.data.tweets import (
    TweetSchema, TweetStreamConfig, generate_tweets, stream_chunks,
)

QUERIES = {"q15": PQ.q15, "q16": PQ.q16, "cquery1": PQ.cquery1}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--query", default="cquery1", choices=sorted(QUERIES))
    ap.add_argument("--method", default="scan", choices=["scan", "probe"])
    ap.add_argument("--mono", action="store_true",
                    help="monolithic execution (no decomposition)")
    ap.add_argument("--tweets", type=int, default=96)
    ap.add_argument("--artists", type=int, default=48)
    ap.add_argument("--shows", type=int, default=24)
    ap.add_argument("--filler", type=int, default=1000)
    ap.add_argument("--window-cap", type=int, default=256)
    ap.add_argument("--pallas", action="store_true",
                    help="use the Pallas hash-join kernel (interpret on CPU)")
    ap.add_argument("--fuse", action="store_true",
                    help="fused join->compaction (no [M, N] candidate matrix)")
    args = ap.parse_args(argv)

    vocab = Vocab()
    kbd = generate_kb(vocab, KBConfig(
        num_artists=args.artists, num_shows=args.shows,
        filler_triples=args.filler))
    tweets = TweetSchema.create(vocab)
    pool = np.concatenate([kbd.artist_ids, kbd.show_ids])
    rows = generate_tweets(vocab, tweets, pool, TweetStreamConfig(
        num_tweets=args.tweets, mentions_min=2, mentions_max=4))
    chunks = list(stream_chunks(rows, 4 * args.window_cap))
    q = QUERIES[args.query](vocab, tweets, kbd.schema)
    cfg = RuntimeConfig(
        window_capacity=args.window_cap, max_windows=4, bind_cap=2048,
        scan_cap=512, out_cap=2048, kb_method=args.method,
        use_pallas=args.pallas,
        fuse_compaction=args.fuse,
    )

    total_kb = int(np.asarray(kbd.kb.count()))
    print(f"[dscep] query={args.query} method={args.method} "
          f"mode={'mono' if args.mono else 'decomposed'} "
          f"stream={len(rows)} triples in {len(chunks)} chunks, KB={total_kb}")

    if args.mono:
        rt = MonolithicRuntime(q, kbd.kb, cfg)
    else:
        dag = decompose(q, vocab)
        rt = DSCEPRuntime(dag, kbd.kb, vocab, cfg)
        print(f"[dscep] operator DAG ({len(dag.subqueries)} operators, "
              f"final={dag.final}):")
        for name, op in rt.operators.items():
            used = "--" if op.kb is None else int(np.asarray(op.kb.count()))
            print(f"    {name:40s} used-KB: {used}")

    n_out = 0
    t_total = 0.0
    for i, chunk in enumerate(chunks):
        t0 = time.perf_counter()
        out, overflow = rt.process_chunk(chunk)
        dt = time.perf_counter() - t0
        t_total += dt
        res = to_host_rows(out)
        n_out += len(res)
        tag = " (includes compile)" if i == 0 else ""
        print(f"[dscep] chunk {i}: {len(res)} output triples "
              f"in {dt * 1e3:.1f} ms{tag}")
    print(f"[dscep] done: {n_out} output triples, "
          f"{t_total:.2f}s total")
    return n_out


if __name__ == "__main__":
    main()
