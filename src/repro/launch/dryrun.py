import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices stand in for 2 TPU v5e pods, the
production meshes are built exactly as they would be on the pod, and every
cell's ``train_step`` / ``serve_step`` must ``.lower().compile()`` under its
in/out shardings.  ``memory_analysis()`` (bytes per device) and
``cost_analysis()`` (FLOPs / bytes) are recorded per cell into a JSON
artifact that benchmarks/roofline.py turns into EXPERIMENTS.md §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod    # 2x16x16 mesh
"""
import argparse
import functools
import json
import re
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ALL_ARCHS, get_config, get_shape
from repro.configs.base import ModelConfig
from repro.configs.shapes import ALL_SHAPES, InputShape
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.sharding.partition import (
    batch_sharding, cache_shardings, param_shardings,
)
from repro.train.optimizer import (
    AdamWConfig, OptState, init_opt_state, opt_state_shardings,
)
from repro.train.train_loop import TrainConfig, make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "../../../benchmarks/artifacts/dryrun")


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no device allocation anywhere)
# --------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b = shape.global_batch
    t = shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.frontend:  # vlm/audio: frontend stub provides embeddings
            d = {
                "embeds": jax.ShapeDtypeStruct((b, t, cfg.d_model), jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct(
                    (b, t, cfg.num_codebooks) if cfg.num_codebooks else (b, t), i32),
            }
            if cfg.mrope_sections:
                d["positions"] = jax.ShapeDtypeStruct((3, b, t), i32)
            return d
        return {
            "tokens": jax.ShapeDtypeStruct((b, t), i32),
            "labels": jax.ShapeDtypeStruct((b, t), i32),
        }
    if shape.kind == "prefill":
        tok_shape = (b, t, cfg.num_codebooks) if cfg.num_codebooks else (b, t)
        return {"tokens": jax.ShapeDtypeStruct(tok_shape, i32)}
    # decode: one new token against a cache of seq_len
    tok_shape = (b, 1, cfg.num_codebooks) if cfg.num_codebooks else (b, 1)
    return {"tokens": jax.ShapeDtypeStruct(tok_shape, i32)}


def abstract_params(cfg: ModelConfig):
    params, spec = jax.eval_shape(
        functools.partial(lm.init_model, cfg=cfg), jax.random.PRNGKey(0)
    )
    # eval_shape returns ShapeDtypeStructs but ParamSpec is a real object
    # captured during tracing; re-run init in eval_shape can't return it, so
    # build it via a side channel:
    return params, spec


def abstract_params_with_spec(cfg: ModelConfig):
    from repro.models.common import ParamSpec
    holder = {}

    def build(key):
        params, spec = lm.init_model(key, cfg)
        holder["spec"] = spec
        return params

    params = jax.eval_shape(build, jax.random.PRNGKey(0))
    return params, holder["spec"]


# --------------------------------------------------------------------------
# collective-bytes accounting from post-SPMD HLO
# --------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(?:[a-z]+\d*)\[([\d,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16, "f8": 1,
}
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every tensor shape literal in ``text``."""
    total = 0
    for m in re.finditer(r"([a-z]+\d*)\[([\d,]*)\]", text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COLL_RE = re.compile(
    r"=\s*((?:\(?[a-z]+\d*\[[\d,]*\](?:\{[\d,]*\})?(?:,\s*)?)+\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes (per device) from HLO text.

    Matches sync and async ``-start`` forms (``-done`` just consumes the
    started op's result and is skipped to avoid double counting); shape
    literals may carry layout suffixes like ``{2,1,0}``.
    """
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for m in _COLL_RE.finditer(hlo_text):
        shape_text, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue
        out[kind] += _shape_bytes(shape_text)
        out["count"] += 1
    return out


# --------------------------------------------------------------------------
# cell lowering
# --------------------------------------------------------------------------

def _ep_combine_axes(cfg: ModelConfig, mesh, moe_groups: int):
    """EP combine all-to-all axes: only when experts shard the model axis."""
    if (moe_groups > 1 and cfg.moe is not None
            and "model" in mesh.shape
            and cfg.moe.num_experts % mesh.shape["model"] == 0):
        return ("model",)
    return None


def should_skip(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return "SKIP(long-context policy: pure full-attention arch)"
    return None


def lower_train_cell(cfg: ModelConfig, shape: InputShape, mesh, zero1=True,
                     microbatches: int = 1, remat: str = "dots",
                     scan_unroll: int = 1, profile: str = "tp",
                     seq_parallel: bool = False, moe_groups: int = 1,
                     ep_combine: bool = True):
    from repro.sharding.partition import PROFILES
    prof = PROFILES[profile]
    dp_axes = tuple(a for a in prof.batch_axes if a in mesh.shape)
    act_shard = (dp_axes, "model", None) if seq_parallel else None
    tcfg = TrainConfig(opt=AdamWConfig(), microbatches=microbatches,
                       remat=remat, scan_unroll=scan_unroll,
                       act_shard=act_shard, moe_groups=moe_groups,
                       moe_group_axes=dp_axes if moe_groups > 1 else None,
                       moe_combine_axes=(_ep_combine_axes(cfg, mesh, moe_groups)
                                         if ep_combine else None))
    train_step = make_train_step(cfg, tcfg)
    params_s, spec = abstract_params_with_spec(cfg)
    opt_s = jax.eval_shape(init_opt_state, params_s)
    batch_s = input_specs(cfg, shape)

    p_shard = param_shardings(spec.axes, params_s, mesh, rules=prof.rules)
    o_shard = opt_state_shardings(p_shard, params_s, mesh, zero1=zero1,
                                  data_axes=prof.zero1_axes)
    b_shard = {
        k: batch_sharding(mesh, v.shape,
                          batch_dim=1 if k == "positions" else 0,
                          batch_axes=prof.batch_axes)
        for k, v in batch_s.items()
    }

    with mesh:
        jf = jax.jit(
            train_step,
            in_shardings=(p_shard, o_shard, b_shard),
            donate_argnums=(0, 1),
        )
        lowered = jf.lower(params_s, opt_s, batch_s)
        compiled = lowered.compile()
    return lowered, compiled


def lower_decode_cell(cfg: ModelConfig, shape: InputShape, mesh,
                      scan_unroll: int = 1, profile: str = "tp",
                      mla_absorbed: bool = False, moe_groups: int = 1,
                      loop: str = "scan"):
    import dataclasses as _dc
    from repro.sharding.partition import PROFILES
    prof = PROFILES[profile]
    if mla_absorbed and cfg.mla is not None:
        cfg = _dc.replace(cfg, mla_absorbed=True)
    params_s, spec = abstract_params_with_spec(cfg)
    cache_len = shape.seq_len
    caches_s = jax.eval_shape(
        functools.partial(lm.init_cache, cfg, shape.global_batch, cache_len)
    )
    batch_s = input_specs(cfg, shape)
    pos_s = jax.ShapeDtypeStruct((), jnp.int32)

    grp_axes = (tuple(a for a in prof.batch_axes if a in mesh.shape)
                if moe_groups > 1 else None)

    def serve_step(params, batch, caches, pos):
        return lm.decode_step(params, cfg, batch, caches, pos,
                              unroll=scan_unroll, moe_groups=moe_groups,
                              moe_axes=grp_axes,
                              moe_combine=_ep_combine_axes(cfg, mesh,
                                                           moe_groups),
                              loop=loop)

    p_shard = param_shardings(spec.axes, params_s, mesh, rules=prof.rules)
    c_shard = cache_shardings(cfg, caches_s, mesh)
    b_shard = {
        k: batch_sharding(mesh, v.shape,
                          batch_dim=1 if k == "positions" else 0,
                          batch_axes=prof.batch_axes)
        for k, v in batch_s.items()
    }

    with mesh:
        jf = jax.jit(
            serve_step,
            in_shardings=(p_shard, b_shard, c_shard, NamedSharding(mesh, P())),
            donate_argnums=(2,),
        )
        lowered = jf.lower(params_s, batch_s, caches_s, pos_s)
        compiled = lowered.compile()
    return lowered, compiled


def lower_prefill_cell(cfg: ModelConfig, shape: InputShape, mesh,
                       scan_unroll: int = 1, profile: str = "tp",
                       last_only: bool = False, moe_groups: int = 1):
    from repro.sharding.partition import PROFILES
    prof = PROFILES[profile]
    grp_axes = (tuple(a for a in prof.batch_axes if a in mesh.shape)
                if moe_groups > 1 else None)
    params_s, spec = abstract_params_with_spec(cfg)
    batch_s = input_specs(cfg, shape)

    def prefill(params, batch):
        if last_only:
            # serve-time prefill needs the LAST position's logits only:
            # project [B, 1, d] instead of materializing [B, T, V]
            h, _ = lm.forward_hidden(params, cfg, batch, unroll=scan_unroll,
                                     moe_groups=moe_groups, moe_axes=grp_axes,
                                     moe_combine=_ep_combine_axes(cfg, mesh,
                                                                  moe_groups))
            return lm.lm_logits(params, cfg, h[:, -1:])[:, 0]
        logits, _ = lm.forward(params, cfg, batch, unroll=scan_unroll,
                               moe_groups=moe_groups, moe_axes=grp_axes,
                               moe_combine=_ep_combine_axes(cfg, mesh,
                                                            moe_groups))
        return logits[:, -1]

    p_shard = param_shardings(spec.axes, params_s, mesh, rules=prof.rules)
    b_shard = {
        k: batch_sharding(mesh, v.shape,
                          batch_dim=1 if k == "positions" else 0,
                          batch_axes=prof.batch_axes)
        for k, v in batch_s.items()
    }
    with mesh:
        jf = jax.jit(prefill, in_shardings=(p_shard, b_shard))
        lowered = jf.lower(params_s, batch_s)
        compiled = lowered.compile()
    return lowered, compiled


_COST_KEYS = ("flops", "bytes accessed", "transcendentals", "optimal_seconds")


def _lower_cell(cfg, shape, mesh, overrides, scan_unroll: int):
    overrides = dict(overrides or {})
    overrides["scan_unroll"] = scan_unroll
    if shape.kind == "train":
        overrides.pop("last_only", None)
        overrides.pop("mla_absorbed", None)
        overrides.pop("loop", None)
        return lower_train_cell(cfg, shape, mesh, **overrides)
    profile = overrides.get("profile", "tp")
    groups = overrides.get("moe_groups", 1)
    if shape.kind == "prefill":
        return lower_prefill_cell(cfg, shape, mesh, scan_unroll=scan_unroll,
                                  profile=profile, moe_groups=groups,
                                  last_only=overrides.get("last_only", False))
    return lower_decode_cell(cfg, shape, mesh, scan_unroll=scan_unroll,
                             profile=profile, moe_groups=groups,
                             mla_absorbed=overrides.get("mla_absorbed", False),
                             loop=overrides.get("loop", "scan"))


def _measure(compiled) -> Dict:
    cost = compiled.cost_analysis()
    return {
        "cost": {
            k: float(v) for k, v in cost.items()
            if k in _COST_KEYS or k.startswith("bytes accessed")
        },
        "collectives": collective_bytes(compiled.as_text()),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: Optional[Dict] = None) -> Dict:
    """Lower + compile one (arch x shape x mesh) cell and extract its costs.

    XLA's cost analysis counts a while-loop body ONCE regardless of trip
    count, so a scanned 60-layer stack reports ~1 period of FLOPs.  We lower
    the cell twice (period-scan ``unroll=1`` and ``unroll=2``): the unroll=2
    body holds exactly one extra period, so ``per_period = cost(u2) -
    cost(u1)`` and the corrected whole-step cost is
    ``cost(u1) + (num_periods - 1) * per_period``.  Memory analysis is taken
    from the unroll=1 build (the deployable program).
    """
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    skip = should_skip(cfg, shape)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "multi_pod": multi_pod,
        "num_periods": cfg.num_periods,
    }
    if skip:
        result["status"] = skip
        return result
    t0 = time.time()
    lowered, compiled = _lower_cell(cfg, shape, mesh, overrides, scan_unroll=1)

    # a fori_loop body can't be unrolled for the two-point cost correction;
    # its math is identical to the scan path, so COST terms come from the
    # scan-equivalent lowering while memory_analysis() keeps the fori build
    cost_overrides = dict(overrides or {})
    if cost_overrides.get("loop") == "fori":
        cost_overrides["loop"] = "scan"
        _, compiled_cost = _lower_cell(cfg, shape, mesh, cost_overrides,
                                       scan_unroll=1)
        m1 = _measure(compiled_cost)
    else:
        m1 = _measure(compiled)

    mem = compiled.memory_analysis()
    result["memory"] = {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "code_bytes": int(mem.generated_code_size_in_bytes),
    }

    n = cfg.num_periods
    result["cost_u1"] = m1["cost"]
    result["collectives_u1"] = m1["collectives"]
    if n >= 2:
        _, compiled2 = _lower_cell(cfg, shape, mesh, cost_overrides,
                                   scan_unroll=2)
        m2 = _measure(compiled2)
        result["cost_u2"] = m2["cost"]

        def corrected(d1, d2):
            out = {}
            for k, v1 in d1.items():
                v2 = d2.get(k, v1)
                per_period = max(0.0, float(v2) - float(v1))
                out[k] = float(v1) + (n - 1) * per_period
            return out

        result["cost"] = corrected(m1["cost"], m2["cost"])
        result["collectives"] = {
            k: int(v) for k, v in corrected(
                {k: float(v) for k, v in m1["collectives"].items()},
                {k: float(v) for k, v in m2["collectives"].items()},
            ).items()
        }
    else:
        result["cost"] = m1["cost"]
        result["collectives"] = m1["collectives"]

    result["compile_s"] = round(time.time() - t0, 1)
    result["status"] = "OK"
    counts = cfg.param_counts()
    result["params_total"] = counts["total"]
    result["params_active"] = counts["active"]
    return result


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    # hillclimb levers (recorded under --tag so baselines stay untouched)
    ap.add_argument("--profile", default=None,
                    help="sharding profile: tp | dp | ep (default tp)")
    ap.add_argument("--remat", default=None, choices=["none", "dots", "full"])
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--last-only", action="store_true",
                    help="prefill: project only the last position's logits")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="train: sequence-parallel residual stream")
    ap.add_argument("--mla-absorbed", action="store_true",
                    help="decode: latent-space (absorbed) MLA attention")
    ap.add_argument("--moe-groups", type=int, default=None,
                    help="hierarchical MoE dispatch groups (align with DP)")
    ap.add_argument("--decode-fori", action="store_true",
                    help="decode: in-place fori_loop cache carry")
    ap.add_argument("--no-ep-combine", action="store_true",
                    help="train: disable the EP-combine all-to-all constraint")
    ap.add_argument("--tag", default=None,
                    help="artifact suffix for perf experiments")
    args = ap.parse_args()

    out_dir = args.out or os.path.normpath(ARTIFACT_DIR)
    os.makedirs(out_dir, exist_ok=True)

    overrides: Dict = {}
    if args.profile:
        overrides["profile"] = args.profile
    if args.remat:
        overrides["remat"] = args.remat
    if args.microbatches:
        overrides["microbatches"] = args.microbatches
    if args.no_zero1:
        overrides["zero1"] = False
    if args.last_only:
        overrides["last_only"] = True
    if args.seq_parallel:
        overrides["seq_parallel"] = True
    if args.mla_absorbed:
        overrides["mla_absorbed"] = True
    if args.moe_groups:
        overrides["moe_groups"] = args.moe_groups
    if args.decode_fori:
        overrides["loop"] = "fori"
    if args.no_ep_combine:
        overrides["ep_combine"] = False

    archs = [args.arch] if args.arch else list(ALL_ARCHS)
    shapes = [args.shape] if args.shape else [s.name for s in ALL_SHAPES]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for multi_pod in meshes:
        for arch in archs:
            for shape_name in shapes:
                tag = "%s__%s__%s" % (arch, shape_name, "pod2" if multi_pod else "pod1")
                if args.tag:
                    tag += "__" + args.tag
                path = os.path.join(out_dir, tag + ".json")
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        prev = json.load(f)
                    print("[cached] %-55s %s" % (tag, prev.get("status")))
                    continue
                try:
                    result = run_cell(arch, shape_name, multi_pod,
                                      overrides=overrides or None)
                except Exception as e:  # a failure here is a bug in our system
                    failures += 1
                    result = {
                        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                        "status": "FAIL: %s" % e,
                        "traceback": traceback.format_exc()[-2000:],
                    }
                result["overrides"] = {**overrides}
                with open(path, "w") as f:
                    json.dump(result, f, indent=1)
                print("[%6.1fs] %-55s %s" % (
                    result.get("compile_s", 0.0), tag, result["status"][:80]))
    if failures:
        print("%d FAILURES" % failures)
        sys.exit(1)
    print("dry-run complete: all cells OK")


if __name__ == "__main__":
    main()
