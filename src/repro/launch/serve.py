"""Serving driver: continuous-batched generation over any pool architecture.

Synthetic ragged requests flow through the ContinuousBatcher (slot lanes =
the Aggregator of the LM-serving SCEP operator), each engine tick decodes
every active slot in one fixed-shape step.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \\
        --requests 12 --slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.models import lm
from repro.serve.lm import ContinuousBatcher, Request


def make_slot_fns(cfg, max_len: int):
    """(prefill_one, decode_all) with per-slot cache lanes (per_seq lens)."""

    @jax.jit
    def prefill_one(params, tokens, caches, slot):
        # run the prompt through decode_step on a single-slot cache view, then
        # scatter that slot's lane back into the batched cache.  Every cache
        # leaf is stacked [period, B, ...]: the slot lane is axis 1.
        # The lane is ZEROED first — a reused slot must not leak the previous
        # request's cache length or SSM/conv state.
        sub = jax.tree.map(
            lambda c: jnp.zeros_like(
                jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1)), caches)
        logits, new_sub = lm.decode_step(
            params, cfg, {"tokens": tokens}, sub, jnp.zeros((1,), jnp.int32))
        caches = jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_slice_in_dim(
                c, n.astype(c.dtype), slot, axis=1), caches, new_sub)
        return logits[:, -1], caches

    @jax.jit
    def decode_all(params, tokens, caches, pos):
        logits, caches = lm.decode_step(params, cfg, {"tokens": tokens},
                                        caches, pos)
        return logits[:, -1], caches

    return prefill_one, decode_all


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    assert not cfg.num_codebooks, "driver demo targets token LMs"
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    caches = lm.init_cache(cfg, args.slots, args.max_len, per_seq=True)
    prefill_one, decode_all = make_slot_fns(cfg, args.max_len)
    batcher = ContinuousBatcher(args.slots, prefill_one, decode_all)

    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        plen = int(rng.integers(4, 12))
        batcher.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new=int(rng.integers(4, args.max_new)),
        ))

    t0 = time.time()
    caches, ticks = batcher.run_until_drained(params, caches)
    dt = time.time() - t0
    done = len(batcher.completed)
    toks = sum(len(r.generated) for r in batcher.completed)
    print(f"[serve] {args.arch}: {done}/{args.requests} requests drained in "
          f"{ticks} ticks, {toks} tokens, {toks / max(dt, 1e-9):.1f} tok/s")
    for r in batcher.completed[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.generated[:8]}...")
    assert done == args.requests
    return done


if __name__ == "__main__":
    main()
