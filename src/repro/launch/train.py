"""Training driver: config -> mesh -> sharded train loop with fault tolerance.

End-to-end path exercised: synthetic token pipeline -> jit(train_step) under
the mesh's param/opt/batch shardings -> atomic async checkpoints -> restart
(elastic: restore re-shards onto whatever mesh the relaunch built) ->
injected-failure retry loop.

Usage (container-scale smoke; the same driver lowers the full configs on a
real pod):

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \\
        --steps 20 --batch 8 --seq 64
    # fault tolerance demo: crash at step 12, relaunch resumes from ckpt
    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \\
        --steps 20 --fail-at 12 --retries 1
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.data.tokens import TokenDatasetConfig, batch_at_step
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.sharding.partition import batch_sharding, param_shardings
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import AdamWConfig, init_opt_state, opt_state_shardings
from repro.train.train_loop import TrainConfig, make_train_step


class InjectedFailure(RuntimeError):
    pass


def build(arch: str, smoke: bool, batch: int, seq: int, microbatches: int,
          remat: str, lr: float, steps: int):
    cfg = get_config(arch)
    if smoke:
        cfg = smoke_variant(cfg)
    tcfg = TrainConfig(
        opt=AdamWConfig(peak_lr=lr, warmup_steps=max(2, steps // 10),
                        total_steps=steps),
        microbatches=microbatches, remat=remat,
    )
    mesh = make_host_mesh()
    params, spec = lm.init_model(jax.random.PRNGKey(0), cfg)
    p_shard = param_shardings(spec.axes, params, mesh)
    params = jax.tree.map(jax.device_put, params, p_shard)
    opt_state = init_opt_state(params)
    o_shard = opt_state_shardings(p_shard, params, mesh, zero1=True)
    dcfg = TokenDatasetConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                              global_batch=batch)
    example = batch_at_step(dcfg, 0)
    b_shard = jax.tree.map(lambda x: batch_sharding(mesh, np.shape(x)), example)
    with mesh:
        step_fn = jax.jit(
            make_train_step(cfg, tcfg),
            in_shardings=(p_shard, o_shard, b_shard),
            donate_argnums=(0, 1),
        )
    return cfg, mesh, params, opt_state, p_shard, step_fn, dcfg


def train(args) -> dict:
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    attempt = 0
    while True:
        attempt += 1
        try:
            return _train_once(args, ckpt, attempt)
        except InjectedFailure as e:
            if attempt > args.retries:
                raise
            print(f"[train] node failure injected: {e}; "
                  f"restarting (attempt {attempt + 1}) from latest checkpoint")


def _train_once(args, ckpt: CheckpointManager, attempt: int) -> dict:
    cfg, mesh, params, opt_state, p_shard, step_fn, dcfg = build(
        args.arch, args.smoke, args.batch, args.seq, args.microbatches,
        args.remat, args.lr, args.steps,
    )
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    start = 0
    if ckpt.latest_step() is not None:
        # elastic restore: device_put with THIS mesh's shardings regardless of
        # the mesh the checkpoint was written under
        o_shard = opt_state_shardings(p_shard, params, mesh, zero1=True)
        (params, opt_state), manifest = ckpt.restore(
            (params, opt_state), shardings=(p_shard, o_shard))
        start = manifest["step"] + 1
        print(f"[train] restored step {manifest['step']} "
              f"(mesh then: {manifest.get('mesh_shape')}, "
              f"mesh now: {dict(zip(mesh.axis_names, mesh.devices.shape))})")

    print(f"[train] {args.arch} params={n_params / 1e6:.1f}M "
          f"batch={args.batch}x{args.seq} steps={start}->{args.steps}")
    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        if args.fail_at is not None and step == args.fail_at and attempt == 1:
            raise InjectedFailure(f"simulated node loss at step {step}")
        batch = {k: jnp.asarray(v) for k, v in
                 batch_at_step(dcfg, step).items()}
        with mesh:
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            tok_s = (step - start + 1) * args.batch * args.seq / max(dt, 1e-9)
            print(f"[train] step {step:5d} loss {loss:7.4f} "
                  f"grad_norm {float(metrics['grad_norm']):8.3f} "
                  f"lr {float(metrics['lr']):.2e} tok/s {tok_s:,.0f}")
        if step % args.ckpt_every == 0 and step > start:
            ckpt.save(step, (params, opt_state),
                      mesh_shape=dict(zip(mesh.axis_names, mesh.devices.shape)))
    ckpt.save(args.steps - 1, (params, opt_state),
              mesh_shape=dict(zip(mesh.axis_names, mesh.devices.shape)),
              blocking=True)
    result = {"first_loss": losses[0] if losses else None,
              "last_loss": losses[-1] if losses else None,
              "steps_run": len(losses), "params": n_params}
    if losses:
        print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (container scale)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a node failure at this step (first attempt)")
    ap.add_argument("--retries", type=int, default=1)
    args = ap.parse_args(argv)
    train(args)


if __name__ == "__main__":
    main()
