"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.

Mesh shapes (TPU v5e, 256 chips/pod):

* single pod:  (16, 16)      axes ("data", "model")
* multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") — DP across pods
  (the slow inter-pod links carry only gradient all-reduces, which overlap
  with backward compute and can run compressed, train/grad_compress.py).
"""
from __future__ import annotations

import os

import jax

from repro.compat import make_mesh


def ensure_host_devices(n: int = 4) -> None:
    """Make the CPU backend expose ``n`` devices via
    ``--xla_force_host_platform_device_count``.

    Must run before the jax backend initializes (importing jax is fine —
    XLA_FLAGS is read at first backend use).  A caller-provided count in
    ``XLA_FLAGS`` always wins; if the backend is already up with fewer
    devices the flag is left alone so jax never sees a mid-process change.
    Benchmarks and CI call this so ``place_operators`` round_robin has
    real devices to spread enrichment operators over.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=%d" % n
        ).strip()


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    return make_mesh((n // model, model), ("data", "model"))


# --------------------------------------------------------------------------
# operator placement (the dataflow runtime's device assignment policy)
# --------------------------------------------------------------------------

def place_operators(
    names, final, devices=None, strategy: str = "round_robin"
):
    """Assign each SCEP operator of a decomposed DAG to a device.

    The :class:`~repro.core.pipeline.PipelinedRuntime` places each operator's
    step (KB slice, env, inbound channels) on its assigned device; channel
    pushes across an edge become device-to-device copies — the mesh analogue
    of the paper's one-container-per-operator deployment.

    Strategies:

    * ``"single"``      — everything on ``devices[0]`` (the degenerate but
      always-valid placement; transport is a no-op).
    * ``"round_robin"`` — the aggregation operator (``final``) is pinned to
      ``devices[0]`` (it owns the sink the host blocks on); upstream
      enrichment operators cycle over the *remaining* devices so independent
      branches land on distinct hardware (falls back to ``devices[0]`` when
      only one device exists).

    Accepts a mesh-slice style device list (e.g. one row of a production
    mesh) via ``devices``; defaults to ``jax.devices()``.
    """
    devices = list(devices) if devices is not None else list(jax.devices())
    if not devices:
        raise ValueError("no devices to place operators on")
    names = list(names)
    if final not in names:
        raise ValueError("final operator %r not in %r" % (final, names))
    if strategy == "single":
        return {n: devices[0] for n in names}
    if strategy != "round_robin":
        raise ValueError("unknown placement strategy %r" % strategy)
    placement = {final: devices[0]}
    workers = devices[1:] or devices
    for i, name in enumerate(n for n in names if n != final):
        placement[name] = workers[i % len(workers)]
    return placement
