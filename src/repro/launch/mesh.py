"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.

Mesh shapes (TPU v5e, 256 chips/pod):

* single pod:  (16, 16)      axes ("data", "model")
* multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") — DP across pods
  (the slow inter-pod links carry only gradient all-reduces, which overlap
  with backward compute and can run compressed, train/grad_compress.py).
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    return make_mesh((n // model, model), ("data", "model"))
