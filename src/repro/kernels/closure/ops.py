"""Public wrapper: padded transitive closure with early-exit fixpoint."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from . import kernel, ref


def _pad_square(x: jax.Array, mult: int) -> jax.Array:
    n = x.shape[0]
    rem = (-n) % mult
    if rem == 0:
        return x
    return jnp.pad(x, ((0, rem), (0, rem)))


def transitive_closure(
    adj: jax.Array, max_depth: int | None = None, block: int = 128,
    use_pallas: bool = True, interpret: bool = True,
) -> jax.Array:
    """Reflexive-transitive closure of ``adj`` (bool/float in {0,1}).

    ``log2(max_depth)`` squaring steps; each step a Pallas boolean matmul
    (or the jnp oracle when ``use_pallas=False``).
    """
    n = adj.shape[0]
    steps = max(1, int(np.ceil(np.log2(max(2, max_depth or n)))))
    reach = jnp.minimum(
        adj.astype(jnp.float32) + jnp.eye(n, dtype=jnp.float32), 1.0
    )
    reach = _pad_square(reach, block)
    for _ in range(steps):
        if use_pallas:
            reach = kernel.closure_step_pallas(reach, interpret=interpret)
        else:
            reach = ref.closure_step_ref(reach)
    return reach[:n, :n] > 0.5


def closure_descendants(
    adj: jax.Array, root: int, out_cap: int, max_depth: int | None = None,
    block: int = 128, use_pallas: bool = True, interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Descendant set of class ``root``: fused closure + compaction.

    Runs ``steps - 1`` squarings on the padded reach matrix, then the fused
    final step (:func:`kernel.descendants_pallas`): a matvec against the
    root's column plus in-kernel compaction of the set row indices.  Returns
    ``(ids [out_cap] int32, count [] int32)``; ``count > out_cap`` means the
    id list was clipped.  Padding rows can never reach ``root`` (their
    off-diagonal entries are zero), so the result is unaffected.
    """
    n = adj.shape[0]
    steps = max(1, int(np.ceil(np.log2(max(2, max_depth or n)))))
    reach = jnp.minimum(
        adj.astype(jnp.float32) + jnp.eye(n, dtype=jnp.float32), 1.0
    )
    reach = _pad_square(reach, block)
    for _ in range(steps - 1):
        if use_pallas:
            reach = kernel.closure_step_pallas(reach, interpret=interpret)
        else:
            reach = ref.closure_step_ref(reach)
    ids, count = kernel.descendants_pallas(
        reach, reach[:, root], out_cap, bm=block, interpret=interpret
    )
    # padded rows are unreachable, so ids never exceed n - 1
    return ids, count


def closure_ancestors(
    adj: jax.Array, root: int, out_cap: int, max_depth: int | None = None,
    block: int = 128, use_pallas: bool = True, interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Ancestor set of node ``root``: everything ``root`` reaches.

    The dual of :func:`closure_descendants` — descendants are the rows of
    the closure column ``R*[:, root]`` (x reaches root), ancestors the
    columns of the row ``R*[root, :]`` (root reaches y), which is exactly
    the descendants computation on the transposed adjacency.  Same fused
    final squaring + in-kernel compaction, same ``(ids, count)`` contract.
    """
    return closure_descendants(
        jnp.swapaxes(adj, -1, -2), root, out_cap, max_depth=max_depth,
        block=block, use_pallas=use_pallas, interpret=interpret)
