"""Pallas TPU kernel: boolean matrix product (transitive-closure step).

RDFS subclass reasoning is pointer-chasing on a CPU engine; on TPU the class
hierarchy becomes a dense boolean adjacency matrix and closure is log(depth)
repeated squarings — each squaring one MXU matmul with a saturating cast.

Classic three-loop tiling: grid ``(n/bm, n/bn, n/bk)`` with the K dimension
innermost so the f32 accumulator tile stays resident in VMEM; matmul tiles
are 128-aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bool_matmul_kernel(nk: int, a_ref, b_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _saturate():
        out_ref[...] = jnp.minimum(out_ref[...], 1.0)


def closure_step_pallas(
    reach: jax.Array,           # [n, n] f32 in {0, 1}, n multiple of block
    bm: int = 128, bn: int = 128, bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    n = reach.shape[0]
    assert reach.shape == (n, n) and n % bm == 0 and n % bn == 0 and n % bk == 0
    nk = n // bk
    kern = functools.partial(_bool_matmul_kernel, nk)
    return pl.pallas_call(
        kern,
        grid=(n // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(reach, reach)
