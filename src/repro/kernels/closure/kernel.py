"""Pallas TPU kernel: boolean matrix product (transitive-closure step).

RDFS subclass reasoning is pointer-chasing on a CPU engine; on TPU the class
hierarchy becomes a dense boolean adjacency matrix and closure is log(depth)
repeated squarings — each squaring one MXU matmul with a saturating cast.

Classic three-loop tiling: grid ``(n/bm, n/bn, n/bk)`` with the K dimension
innermost so the f32 accumulator tile stays resident in VMEM; matmul tiles
are 128-aligned for the MXU.

:func:`descendants_pallas` fuses the *final* squaring with closure-set
extraction: reasoning queries only consume one column of the closure (the
descendants of a root class), so the last step collapses to a matvec whose
set entries are compacted in-kernel into a bounded id list — the squared
``[n, n]`` matrix of the final step never reaches HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bool_matmul_kernel(nk: int, a_ref, b_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _saturate():
        out_ref[...] = jnp.minimum(out_ref[...], 1.0)


def _descendants_kernel(out_cap: int, reach_ref, rootcol_ref, ids_ref,
                        count_ref):
    """Fused final squaring + compaction for one root class.

    One ``[bm, n]`` row block per grid step: the block's slice of the final
    closure *column* is a matvec ``reach_block @ reach[:, root]`` (the full
    ``reach @ reach`` product for the last squaring never exists), and set
    rows scatter their global indices straight into the capacity-bounded id
    list.  ``count_ref`` carries the running count across the sequential
    grid; slot ``out_cap`` of ``ids_ref`` is the dump slot for overflow.
    """
    i = pl.program_id(0)
    bm = reach_ref.shape[0]

    @pl.when(i == 0)
    def _init():
        ids_ref[...] = jnp.zeros_like(ids_ref)

    col = jnp.minimum(reach_ref[...] @ rootcol_ref[...], 1.0)     # [bm]
    mask = col > 0.5
    base = jnp.where(i == 0, 0, count_ref[0])
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
    tgt = jnp.where(mask & (base + rank < out_cap), base + rank, out_cap)
    ids = (i * bm + jnp.arange(bm)).astype(jnp.int32)
    ids_ref[...] = ids_ref[...].at[tgt].set(ids)
    count_ref[0] = base + jnp.sum(mask.astype(jnp.int32))


def descendants_pallas(
    reach: jax.Array,       # [n, n] f32 in {0, 1}: closure before last squaring
    rootcol: jax.Array,     # [n] f32: reach[:, root]
    out_cap: int,
    bm: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns ``(ids [out_cap] int32, count [] int32)``.

    ``ids[:min(count, out_cap)]`` are the ascending row indices i with
    ``min(reach @ reach, 1)[i, root] > 0.5`` — the root's descendant set,
    compacted in-kernel without materializing the final squared matrix.
    """
    n = reach.shape[0]
    assert reach.shape == (n, n) and n % bm == 0, (reach.shape, bm)
    ids, count = pl.pallas_call(
        functools.partial(_descendants_kernel, out_cap),
        grid=(n // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((out_cap + 1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((out_cap + 1,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(reach, rootcol)
    return ids[:out_cap], count[0]


def closure_step_pallas(
    reach: jax.Array,           # [n, n] f32 in {0, 1}, n multiple of block
    bm: int = 128, bn: int = 128, bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    n = reach.shape[0]
    assert reach.shape == (n, n) and n % bm == 0 and n % bn == 0 and n % bk == 0
    nk = n // bk
    kern = functools.partial(_bool_matmul_kernel, nk)
    return pl.pallas_call(
        kern,
        grid=(n // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(reach, reach)
