"""jnp oracle: one boolean-matmul squaring step and the full closure."""
from __future__ import annotations

import jax.numpy as jnp


def closure_step_ref(reach: jnp.ndarray) -> jnp.ndarray:
    """One repeated-squaring step: reach | reach @ reach (boolean)."""
    r = reach.astype(jnp.float32)
    return jnp.minimum(r @ r, 1.0).astype(reach.dtype)


def closure_ref(adj: jnp.ndarray, steps: int) -> jnp.ndarray:
    n = adj.shape[-1]
    reach = jnp.minimum(adj.astype(jnp.float32) + jnp.eye(n, dtype=jnp.float32), 1.0)
    for _ in range(steps):
        reach = jnp.minimum(reach @ reach, 1.0)
    return reach


def descendants_ref(adj: jnp.ndarray, root: int, steps: int, out_cap: int):
    """Oracle for the fused descendant extraction.

    Returns ``(ids [out_cap] int32, count [] int32)``: ascending indices of
    the rows reaching ``root`` in the full closure, zero-padded past
    ``count`` and clipped at ``out_cap``.
    """
    reach = closure_ref(adj, steps)
    mask = reach[:, root] > 0.5
    count = jnp.sum(mask.astype(jnp.int32))
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
    tgt = jnp.where(mask & (rank < out_cap), rank, out_cap)
    ids = jnp.zeros((out_cap + 1,), jnp.int32).at[tgt].set(
        jnp.arange(adj.shape[0], dtype=jnp.int32)
    )
    return ids[:out_cap], count
