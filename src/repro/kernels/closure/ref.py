"""jnp oracle: one boolean-matmul squaring step and the full closure."""
from __future__ import annotations

import jax.numpy as jnp


def closure_step_ref(reach: jnp.ndarray) -> jnp.ndarray:
    """One repeated-squaring step: reach | reach @ reach (boolean)."""
    r = reach.astype(jnp.float32)
    return jnp.minimum(r @ r, 1.0).astype(reach.dtype)


def closure_ref(adj: jnp.ndarray, steps: int) -> jnp.ndarray:
    n = adj.shape[-1]
    reach = jnp.minimum(adj.astype(jnp.float32) + jnp.eye(n, dtype=jnp.float32), 1.0)
    for _ in range(steps):
        reach = jnp.minimum(reach @ reach, 1.0)
    return reach
