"""Pure-jnp oracle: GQA scaled-dot-product attention with causal and
sliding-window masking, f32 softmax accumulation."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,            # [B, Hq, Tq, D]
    k: jax.Array,            # [B, Hk, Tk, D]
    v: jax.Array,            # [B, Hk, Tk, D]
    causal: bool = True,
    window: int | None = None,   # sliding window size (keys >= qpos-window+1)
    q_offset: int = 0,           # absolute position of q[0] (decode: Tk - Tq)
) -> jax.Array:
    b, hq, tq, d = q.shape
    hk = k.shape[1]
    assert hq % hk == 0
    group = hq // hk
    kg = jnp.repeat(k, group, axis=1)
    vg = jnp.repeat(v, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kg.astype(jnp.float32)) * scale
    qpos = jnp.arange(tq)[:, None] + q_offset
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((tq, k.shape[2]), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)   # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vg.astype(jnp.float32)).astype(q.dtype)
