"""Public wrapper: shape policy, padding, and the decode fast path."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel, ref


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    causal: bool = True, window: int | None = None, q_offset: int = 0,
    bq: int | None = None, bk: int | None = None, interpret: bool = True,
) -> jax.Array:
    """GQA flash attention; pads Tq/Tk to block multiples and slices back."""
    b, hq, tq, d = q.shape
    tk = k.shape[2]
    bq = bq or min(kernel.DEFAULT_BQ, max(8, tq))
    bk = bk or min(kernel.DEFAULT_BK, max(8, tk))

    pad_q = (-tq) % bq
    pad_k = (-tk) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    # padded key positions must never win the max: rely on causal/window mask
    # when present, else mask via a huge negative bias on padded keys.
    if pad_k and not causal:
        # append -inf bias by masking inside ref path; kernel path handles
        # it through the causal/window mask, so fall back to masked ref.
        out = ref.attention_ref(q, k, v, causal=causal, window=window,
                                q_offset=q_offset)
        return out
    out = kernel.flash_attention_pallas(
        qp, kp, vp, causal=causal, window=window, q_offset=q_offset,
        bq=bq, bk=bk, interpret=interpret,
    )
    return out[:, :, :tq]
