"""Pallas TPU kernel: flash attention forward (GQA, causal, sliding window).

Online-softmax tiling for the TPU memory hierarchy: the KV sequence is a
*grid dimension* (TPU grids execute sequentially on a core, innermost axis
fastest), so each ``[bk, d]`` KV block is DMA'd HBM->VMEM by the BlockSpec
machinery while the ``[bq, d]`` query tile and the f32 running statistics
(max / denominator / accumulator) persist in VMEM scratch across the KV loop.
GQA maps query head -> kv head inside the index_map (no KV repeat in HBM).

Grid: ``(B*Hq, Tq/bq, Tk/bk)``.  Fully-masked (causal / sliding-window) KV
blocks are skipped with ``pl.when`` — block-level mask skipping.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(
    causal: bool, window: int | None, q_offset: int, scale: float,
    bq: int, bk: int, nk: int,
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
):
    qi = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = q_offset + qi * bq + jax.lax.iota(jnp.int32, bq)          # [bq]
    k_pos = kb * bk + jax.lax.iota(jnp.int32, bk)                     # [bk]

    # block-level skipping: causal => kv block must start at/before last q pos;
    # sliding window => kv block must end inside the window of the first q pos
    live = jnp.asarray(True)
    if causal:
        live &= k_pos[0] <= q_pos[bq - 1]
    if window is not None:
        live &= k_pos[bk - 1] > q_pos[0] - window

    @pl.when(live)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32) * scale                   # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)                           # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)       # [bq, bk]
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...][:, 0]                                     # [bq]
        l_prev = l_ref[...][:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = (l_prev * alpha + jnp.sum(p, axis=-1))[:, None]
        m_ref[...] = m_new[:, None]
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )

    @pl.when(kb == nk - 1)
    def _finalize():
        l = l_ref[...][:, 0]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,           # [B, Hq, Tq, D]
    k: jax.Array,           # [B, Hk, Tk, D]
    v: jax.Array,           # [B, Hk, Tk, D]
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = True,  # CPU container: interpret; flip off on real TPU
) -> jax.Array:
    b, hq, tq, d = q.shape
    _, hk, tk, _ = k.shape
    assert hq % hk == 0 and tq % bq == 0 and tk % bk == 0, (hq, hk, tq, bq, tk, bk)
    group = hq // hk
    nk = tk // bk
    scale = 1.0 / (d ** 0.5)
    kern = functools.partial(
        _flash_kernel, causal, window, q_offset, scale, bq, bk, nk
    )
    grid = (b * hq, tq // bq, nk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda h, i, j: (h // hq, h % hq, i, 0)),
            pl.BlockSpec(
                (1, 1, bk, d), lambda h, i, j: (h // hq, (h % hq) // group, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, d), lambda h, i, j: (h // hq, (h % hq) // group, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda h, i, j: (h // hq, h % hq, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, tq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denominator
        ],
        interpret=interpret,
    )(q, k, v)
