"""Public wrapper: cache-length padding and layout adaptation."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def decode_attention(
    q: jax.Array,           # [B, Hq, 1, D]
    k: jax.Array,           # [B, Hk, S, D]
    v: jax.Array,           # [B, Hk, S, D]
    lengths: jax.Array,     # [B] int32
    bk: int | None = None,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """GQA decode attention; pads S to a block multiple and dispatches."""
    if not use_pallas:
        return ref.decode_attention_ref(q, k, v, lengths)
    s = k.shape[2]
    bk = bk or min(kernel.DEFAULT_BK, max(8, s))
    pad = (-s) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    # padded tail positions sit at index >= s >= length: masked by `lengths`
    return kernel.decode_attention_pallas(q, k, v, lengths, bk=bk,
                                          interpret=interpret)
