"""Pure-jnp oracle for GQA decode attention with per-sequence lengths."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(
    q: jax.Array,           # [B, Hq, 1, D]
    k: jax.Array,           # [B, Hk, S, D]
    v: jax.Array,           # [B, Hk, S, D]
    lengths: jax.Array,     # [B] int32
) -> jax.Array:
    b, hq, tq, d = q.shape
    _, hk, s, _ = k.shape
    group = hq // hk
    qf = q.reshape(b, hk, group, tq, d).astype(jnp.float32)
    scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum("bhgtd,bhsd->bhgts", qf,
                        k.astype(jnp.float32)) * scale
    mask = jnp.arange(s)[None, :] < lengths[:, None]            # [B, S]
    logits = jnp.where(mask[:, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows (length 0) must produce zeros, not NaNs
    probs = jnp.where(mask[:, None, None, None, :], probs, 0.0)
    out = jnp.einsum("bhgts,bhsd->bhgtd", probs, v.astype(jnp.float32))
    return out.reshape(b, hq, tq, d).astype(q.dtype)
