"""Pallas TPU kernel: GQA decode attention (one query token vs a KV cache).

The serving hot loop: every decode step attends ONE query row per sequence
against a long cached KV prefix.  Tiling for the TPU memory hierarchy:

* grid = ``(B*Hq, S/bk)`` — the KV axis is the innermost (sequential) grid
  dimension, so each ``[bk, d]`` cache block is DMA'd HBM->VMEM once while
  the single query row and the f32 online-softmax statistics live in VMEM
  scratch across the whole KV sweep;
* GQA maps query head -> kv head inside the BlockSpec index_map (the cache
  is never repeated in HBM);
* per-sequence valid lengths: blocks entirely past ``len`` are skipped with
  ``pl.when`` (no DMA wasted on dead cache tail), partial blocks are masked.

``ref.py`` holds the jnp oracle; ``ops.py`` the padding/jit wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 256
NEG_INF = -1e30


def _decode_kernel(
    scale: float, bk: int, nk: int,
    q_ref, k_ref, v_ref, len_ref, o_ref,
    acc_ref, m_ref, l_ref,
):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0]
    k_pos = kb * bk + jax.lax.iota(jnp.int32, bk)                 # [bk]

    @pl.when(k_pos[0] < length)                                    # block skip
    def _block():
        q = q_ref[0, 0].astype(jnp.float32) * scale                # [1, d]
        k = k_ref[0, 0].astype(jnp.float32)                        # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)    # [1, bk]
        mask = (k_pos < length)[None, :]
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[0, 0]
        l_prev = l_ref[0, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)               # [1, bk]
        alpha = jnp.exp(m_prev - m_new)
        l_ref[0, 0] = l_prev * alpha + jnp.sum(p)
        m_ref[0, 0] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )

    @pl.when(kb == nk - 1)
    def _finalize():
        l = l_ref[0, 0]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,           # [B, Hq, 1, D]
    k: jax.Array,           # [B, Hk, S, D]
    v: jax.Array,           # [B, Hk, S, D]
    lengths: jax.Array,     # [B] int32 — valid cache prefix per sequence
    bk: int = DEFAULT_BK,
    interpret: bool = True,  # CPU container: interpret; flip off on real TPU
) -> jax.Array:
    b, hq, tq, d = q.shape
    _, hk, s, _ = k.shape
    assert tq == 1 and hq % hk == 0 and s % bk == 0, (tq, hq, hk, s, bk)
    group = hq // hk
    nk = s // bk
    scale = 1.0 / (d ** 0.5)
    kern = functools.partial(_decode_kernel, scale, bk, nk)
    grid = (b * hq, nk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda h, j: (h // hq, h % hq, 0, 0)),
            pl.BlockSpec(
                (1, 1, bk, d), lambda h, j: (h // hq, (h % hq) // group, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, d), lambda h, j: (h // hq, (h % hq) // group, j, 0)
            ),
            pl.BlockSpec((1,), lambda h, j: (h // hq,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda h, j: (h // hq, h % hq, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),     # output accumulator
            pltpu.VMEM((1, 1), jnp.float32),     # running max
            pltpu.VMEM((1, 1), jnp.float32),     # running denominator
        ],
        interpret=interpret,
    )(q, k, v, lengths.astype(jnp.int32))
