"""Public wrapper: padding to chunk multiples, D skip-connection, dtype."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def ssd(
    x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array, Cm: jax.Array,
    D: jax.Array | None = None, chunk: int | None = None,
    use_pallas: bool = True, interpret: bool = True,
) -> jax.Array:
    """Mamba-2 SSD scan; returns y [B,T,H,P]."""
    b, t, h, p = x.shape
    if not use_pallas:
        y, _ = ref.ssd_ref(x, dt, A, Bm, Cm, D)
        return y
    chunk = chunk or min(kernel.DEFAULT_CHUNK, t)
    pad = (-t) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))   # dt=0 => a=1, no update
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, _ = kernel.ssd_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
    y = y[:, :t]
    if D is not None:
        y = y + (D.astype(jnp.float32)[None, None, :, None]
                 * x[:, :t].astype(jnp.float32)).astype(y.dtype)
    return y
