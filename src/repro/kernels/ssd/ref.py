"""Pure-jnp oracle for the Mamba-2 SSD recurrence (sequential scan).

State-space model with scalar-identity A per head (the SSD restriction):

    a_t      = exp(dt_t * A_h)                      (decay, A_h < 0)
    S_t      = a_t * S_{t-1} + dt_t * B_t x_t^T     (state [dstate, headdim])
    y_t      = C_t^T S_t + D_h * x_t

B/C are shared across the heads of a group (G groups, H heads, H % G == 0).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(
    x: jax.Array,    # [B, T, H, P]
    dt: jax.Array,   # [B, T, H]  (positive)
    A: jax.Array,    # [H]        (negative)
    Bm: jax.Array,   # [B, T, G, S]
    Cm: jax.Array,   # [B, T, G, S]
    D: jax.Array | None = None,   # [H]
    init_state: jax.Array | None = None,  # [B, H, S, P]
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [B,T,H,P], final_state [B,H,S,P])."""
    b, t, h, p = x.shape
    g, s = Bm.shape[2], Bm.shape[3]
    assert h % g == 0
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=2)   # [B,T,H,S]
    Ch = jnp.repeat(Cm, rep, axis=2)

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bh.astype(jnp.float32)
    Cf = Ch.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def scan_one(state, inputs):
        xt, dtt, bt, ct = inputs            # [H,P], [H], [H,S], [H,S]
        a = jnp.exp(dtt * Af)               # [H]
        upd = (dtt[:, None] * bt)[..., None] * xt[:, None, :]   # [H,S,P]
        state = a[:, None, None] * state + upd
        y = jnp.einsum("hs,hsp->hp", ct, state)
        return state, y

    def per_batch(xb, dtb, bb, cb, s0):
        state0 = s0
        final, ys = jax.lax.scan(scan_one, state0, (xb, dtb, bb, cb))
        return ys, final

    s0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((b, h, s, p), jnp.float32)
    )
    ys, final = jax.vmap(per_batch)(xf, dtf, Bf, Cf, s0)
    if D is not None:
        ys = ys + D.astype(jnp.float32)[None, None, :, None] * xf
    return ys.astype(x.dtype), final
