"""Pallas TPU kernel: Mamba-2 SSD chunked scan (state-space duality).

The SSD insight is that the scalar-decay SSM recurrence factorizes into
chunk-local *matmuls* (the "duality" with masked attention) plus a tiny
cross-chunk state recurrence — exactly the decomposition the MXU wants:

  per chunk c of length L (all f32, per (batch, head) grid cell):
    la          = cumsum(dt * A)                       # [L] log-decay
    intra       = ((C B^T) ∘ Γ) @ (dt * x)             # [L,L]@[L,P] matmuls
                  Γ[t,s] = exp(la_t - la_s) for s<=t (causal decay mask)
    inter       = (C ∘ exp(la)) @ S_prev               # [L,S]@[S,P]
    S_next      = exp(la_L) S_prev + (B ∘ dt ∘ exp(la_L - la))^T @ x

Grid ``(B*H, T/L)``: the chunk axis is the innermost sequential grid dim, so
the ``[S, P]`` state lives in VMEM scratch across chunks; each chunk's x/dt/
B/C blocks are DMA'd by BlockSpec.  All chunk math is 128-alignable matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssd_kernel(
    nl: int, L: int,
    x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
    state_ref,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # [L, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # [L]
    A = a_ref[0].astype(jnp.float32)                 # scalar
    Bc = b_ref[0, :, 0, :].astype(jnp.float32)       # [L, S]
    Cc = c_ref[0, :, 0, :].astype(jnp.float32)       # [L, S]

    la = jnp.cumsum(dt * A)                          # [L] (non-increasing)
    la_last = la[L - 1]

    # intra-chunk: masked decay attention
    scores = jnp.dot(Cc, Bc.T, preferred_element_type=jnp.float32)   # [L, L]
    t_idx = jax.lax.iota(jnp.int32, L)
    causal = t_idx[:, None] >= t_idx[None, :]
    gamma = jnp.where(causal, jnp.exp(la[:, None] - la[None, :]), 0.0)
    y_intra = jnp.dot(scores * gamma * dt[None, :], x,
                      preferred_element_type=jnp.float32)            # [L, P]

    # inter-chunk: contribution of the carried state
    state = state_ref[...]                                           # [S, P]
    y_inter = jnp.dot(Cc * jnp.exp(la)[:, None], state,
                      preferred_element_type=jnp.float32)            # [L, P]

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    # state recurrence
    w = jnp.exp(la_last - la) * dt                                   # [L]
    state_ref[...] = jnp.exp(la_last) * state + jnp.dot(
        (Bc * w[:, None]).T, x, preferred_element_type=jnp.float32
    )

    @pl.when(ci == nl - 1)
    def _emit_state():
        state_out_ref[0, 0] = state_ref[...]


def ssd_pallas(
    x: jax.Array,    # [B, T, H, P]
    dt: jax.Array,   # [B, T, H]
    A: jax.Array,    # [H]
    Bm: jax.Array,   # [B, T, G, S]
    Cm: jax.Array,   # [B, T, G, S]
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = True,  # CPU container: interpret; flip off on real TPU
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [B,T,H,P], final_state [B,H,S,P]). T % chunk == 0."""
    b, t, h, p = x.shape
    g, s = Bm.shape[2], Bm.shape[3]
    assert t % chunk == 0 and h % g == 0
    nl = t // chunk
    rep = h // g
    kern = functools.partial(_ssd_kernel, nl, chunk)
    grid = (b * h, nl)
    y, state = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda i, c: (i // h, c, i % h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, c: (i // h, c, i % h)),
            pl.BlockSpec((1,), lambda i, c: (i % h,)),
            pl.BlockSpec((1, chunk, 1, s), lambda i, c: (i // h, c, (i % h) // rep, 0)),
            pl.BlockSpec((1, chunk, 1, s), lambda i, c: (i // h, c, (i % h) // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda i, c: (i // h, c, i % h, 0)),
            pl.BlockSpec((1, 1, s, p), lambda i, c: (i // h, i % h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, s, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((s, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y, state
