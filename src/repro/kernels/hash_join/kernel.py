"""Pallas TPU kernel: tiled window-vs-KB match matrix.

TPU adaptation of DSCEP's KB-scan join.  A CPU engine (C-SPARQL) walks hash
maps pointer-by-pointer; the TPU-native formulation streams the KB partition
through VMEM in ``bn``-wide blocks and evaluates all ``bm x bn`` slot-equality
predicates as vector compares (VPU), emitting an int8 candidate matrix that
the caller compacts.  Arithmetic intensity is low (compare-bound), so block
shapes are chosen to keep the KB stream resident: one ``[bm]`` binding column
per BOUND slot and three ``[bn]`` KB columns per block.

Grid: ``(M / bm, N / bn)``; each program writes one ``[bm, bn]`` output tile.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pattern import CompiledPattern, SlotMode

DEFAULT_BM = 128
DEFAULT_BN = 1024


def _match_kernel(pat: CompiledPattern, cols_ref, bvalid_ref, ks_ref, kp_ref,
                  ko_ref, kvalid_ref, out_ref):
    """One [bm, bn] tile: all-slot equality under the static pattern."""
    kcols = {0: ks_ref[...], 1: kp_ref[...], 2: ko_ref[...]}      # each [bn]
    m = bvalid_ref[...][:, None] & kvalid_ref[...][None, :]       # [bm, bn]
    for i, slot in enumerate((pat.s, pat.p, pat.o)):
        kv = kcols[i][None, :]
        if slot.mode == SlotMode.CONST:
            m = m & (kv == jnp.uint32(slot.const))
        elif slot.mode == SlotMode.BOUND:
            m = m & (kv == cols_ref[:, slot.var][:, None])
    slots = (pat.s, pat.p, pat.o)
    for i in range(3):
        for j in range(i + 1, 3):
            if (
                slots[i].mode != SlotMode.CONST
                and slots[j].mode != SlotMode.CONST
                and slots[i].var == slots[j].var
            ):
                m = m & (kcols[i][None, :] == kcols[j][None, :])
    out_ref[...] = m.astype(jnp.int8)


def match_matrix_pallas(
    cols: jax.Array,        # [M, NV] uint32 (M multiple of bm)
    bvalid: jax.Array,      # [M] bool
    ks: jax.Array, kp: jax.Array, ko: jax.Array,   # [N] uint32 (N mult of bn)
    kvalid: jax.Array,      # [N] bool
    pat: CompiledPattern,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    interpret: bool = True,  # CPU container: interpret; flip off on real TPU
) -> jax.Array:
    m, nv = cols.shape
    n = ks.shape[0]
    assert m % bm == 0 and n % bn == 0, (m, bm, n, bn)
    grid = (m // bm, n // bn)
    kern = functools.partial(_match_kernel, pat)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, nv), lambda i, j: (i, 0)),    # binding tile
            pl.BlockSpec((bm,), lambda i, j: (i,)),         # binding validity
            pl.BlockSpec((bn,), lambda i, j: (j,)),         # KB subject block
            pl.BlockSpec((bn,), lambda i, j: (j,)),         # KB predicate block
            pl.BlockSpec((bn,), lambda i, j: (j,)),         # KB object block
            pl.BlockSpec((bn,), lambda i, j: (j,)),         # KB validity block
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int8),
        interpret=interpret,
    )(cols, bvalid, ks, kp, ko, kvalid)
