"""Pallas TPU kernels: tiled window-vs-KB join (match + fused compaction).

TPU adaptation of DSCEP's KB-scan join.  A CPU engine (C-SPARQL) walks hash
maps pointer-by-pointer; the TPU-native formulation streams the KB partition
through VMEM in ``bn``-wide blocks and evaluates all ``bm x bn`` slot-equality
predicates as vector compares (VPU).  Two entry points:

* :func:`match_matrix_pallas` — the original kernel: emits the full int8
  candidate matrix ``[M, N]`` that the caller compacts.  O(M*N) HBM traffic.
* :func:`join_compact_pallas` — the fused pipeline: match tiles never leave
  VMEM; each grid tile scatters its compacted, variable-extended binding rows
  straight into a capacity-bounded ``[out_cap, nv]`` output.  HBM traffic is
  O(M*N / tile-resident) reads + O(out_cap) writes, and the output positions
  are *globally row-major deterministic* — bit-identical to materializing the
  candidate matrix and running :func:`repro.core.pattern.compact_rows`.
* :func:`probe_compact_pallas` — the probe-method analogue: per binding row
  a binary search over the resident sorted composite-key view, a bounded
  ``k_max``-wide gather, the exact anchor re-check, and the same
  scatter-compaction — all in one kernel pass whose cost is independent of
  unused-KB size (the planner's ``kb_method="auto"`` picks this whenever
  the pattern is anchored and the observed fan-out is small).

The fused pipeline is classic two-phase stream compaction:

1. **count** — grid ``(M/bm, N/bn)`` accumulates per-binding-row match
   counts into an ``[M]`` int32 vector (the only intermediate that touches
   HBM; 4 bytes/row vs N bytes/row for the candidate matrix).
2. host-side exclusive cumsum of the ``[M]`` counts -> global row offsets.
3. **scatter** — same grid; each tile recomputes its match block (compare
   ops are ~free; recompute beats an HBM round-trip), ranks matches within
   the row via a running per-row base carried across ``j`` steps, extends
   binding rows with the pattern's FREE variables from the KB columns, and
   scatters them to ``offset[row] + rank``.  Rows past ``out_cap`` land in a
   dump slot; the caller turns ``sum(counts) > out_cap`` into the overflow
   flag.

Grids iterate ``j`` fastest (Pallas row-major order), which the running
per-row base in phase 3 relies on; the scatter itself is position-exact, so
tile order never changes the result.

Lowering note: the scatter step uses a runtime-indexed ``.at[].set`` into
the resident output block.  This is exercised in interpret mode (this
container) and is the one op whose Mosaic lowering must be validated before
flipping ``interpret=False`` on real hardware; if unsupported on a target
TPU generation, replace it with a one-hot-matmul scatter (MXU) or a
per-row ``fori_loop`` of dynamic-slice stores — the count/offset phases and
the output contract are unchanged.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pattern import CompiledPattern, SlotMode

DEFAULT_BM = 128
DEFAULT_BN = 1024


def _tile_match(pat: CompiledPattern, cols, bvalid, ks, kp, ko, kvalid):
    """All-slot equality for one [bm, bn] tile under the static pattern."""
    kcols = {0: ks, 1: kp, 2: ko}
    m = bvalid[:, None] & kvalid[None, :]
    for i, slot in enumerate((pat.s, pat.p, pat.o)):
        kv = kcols[i][None, :]
        if slot.mode == SlotMode.CONST:
            m = m & (kv == jnp.uint32(slot.const))
        elif slot.mode == SlotMode.BOUND:
            m = m & (kv == cols[:, slot.var][:, None])
    slots = (pat.s, pat.p, pat.o)
    for i in range(3):
        for j in range(i + 1, 3):
            if (
                slots[i].mode != SlotMode.CONST
                and slots[j].mode != SlotMode.CONST
                and slots[i].var == slots[j].var
            ):
                m = m & (kcols[i][None, :] == kcols[j][None, :])
    return m


def _extend_tile(pat: CompiledPattern, cols, ks, kp, ko):
    """[bm, nv] binding rows -> [bm, bn, nv] rows with FREE vars from the KB."""
    bm, nv = cols.shape
    bn = ks.shape[0]
    ext = jnp.broadcast_to(cols[:, None, :], (bm, bn, nv))
    kcols = {0: ks, 1: kp, 2: ko}
    for i, slot in enumerate((pat.s, pat.p, pat.o)):
        if slot.mode == SlotMode.FREE:
            ext = ext.at[..., slot.var].set(
                jnp.broadcast_to(kcols[i][None, :], (bm, bn))
            )
    return ext


# --------------------------------------------------------------------------
# original kernel: full candidate matrix
# --------------------------------------------------------------------------

def _match_kernel(pat: CompiledPattern, cols_ref, bvalid_ref, ks_ref, kp_ref,
                  ko_ref, kvalid_ref, out_ref):
    """One [bm, bn] tile: all-slot equality under the static pattern."""
    m = _tile_match(pat, cols_ref[...], bvalid_ref[...], ks_ref[...],
                    kp_ref[...], ko_ref[...], kvalid_ref[...])
    out_ref[...] = m.astype(jnp.int8)


def match_matrix_pallas(
    cols: jax.Array,        # [M, NV] uint32 (M multiple of bm)
    bvalid: jax.Array,      # [M] bool
    ks: jax.Array, kp: jax.Array, ko: jax.Array,   # [N] uint32 (N mult of bn)
    kvalid: jax.Array,      # [N] bool
    pat: CompiledPattern,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    interpret: bool = True,  # CPU container: interpret; flip off on real TPU
) -> jax.Array:
    m, nv = cols.shape
    n = ks.shape[0]
    assert m % bm == 0 and n % bn == 0, (m, bm, n, bn)
    grid = (m // bm, n // bn)
    kern = functools.partial(_match_kernel, pat)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, nv), lambda i, j: (i, 0)),    # binding tile
            pl.BlockSpec((bm,), lambda i, j: (i,)),         # binding validity
            pl.BlockSpec((bn,), lambda i, j: (j,)),         # KB subject block
            pl.BlockSpec((bn,), lambda i, j: (j,)),         # KB predicate block
            pl.BlockSpec((bn,), lambda i, j: (j,)),         # KB object block
            pl.BlockSpec((bn,), lambda i, j: (j,)),         # KB validity block
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int8),
        interpret=interpret,
    )(cols, bvalid, ks, kp, ko, kvalid)


# --------------------------------------------------------------------------
# fused kernel: join -> compaction without the [M, N] round-trip
# --------------------------------------------------------------------------

def _count_kernel(pat: CompiledPattern, cols_ref, bvalid_ref, ks_ref, kp_ref,
                  ko_ref, kvalid_ref, counts_ref):
    """Phase 1: accumulate per-binding-row match counts across KB blocks."""
    j = pl.program_id(1)
    m = _tile_match(pat, cols_ref[...], bvalid_ref[...], ks_ref[...],
                    kp_ref[...], ko_ref[...], kvalid_ref[...])
    rc = jnp.sum(m.astype(jnp.int32), axis=1)
    counts_ref[...] = jnp.where(j == 0, jnp.zeros_like(rc),
                                counts_ref[...]) + rc


def _scatter_kernel(pat: CompiledPattern, out_cap: int, cols_ref, bvalid_ref,
                    ks_ref, kp_ref, ko_ref, kvalid_ref, offs_ref, out_ref,
                    rowbase_ref):
    """Phase 2: scatter compacted extended rows to offset[row] + rank.

    ``out_ref`` is the whole ``[out_cap + 1, nv]`` output (constant index
    map — the TPU grid is sequential, so revisiting accumulates); row
    ``out_cap`` is the dump slot for overflowing matches.  ``rowbase_ref``
    carries each binding row's running match count across ``j`` steps.
    """
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    cols = cols_ref[...]
    ks, kp, ko = ks_ref[...], kp_ref[...], ko_ref[...]
    m = _tile_match(pat, cols, bvalid_ref[...], ks, kp, ko, kvalid_ref[...])
    rc = jnp.sum(m.astype(jnp.int32), axis=1)                     # [bm]
    base = jnp.where(j == 0, jnp.zeros_like(rc), rowbase_ref[...])
    rank = jnp.cumsum(m.astype(jnp.int32), axis=1) - 1            # [bm, bn]
    tgt = offs_ref[...][:, None] + base[:, None] + rank
    tgt = jnp.where(m & (tgt < out_cap), tgt, out_cap)            # dump slot

    ext = _extend_tile(pat, cols, ks, kp, ko)                     # [bm, bn, nv]
    bm, bn, nv = ext.shape
    out_ref[...] = out_ref[...].at[tgt.reshape(bm * bn)].set(
        ext.reshape(bm * bn, nv)
    )
    rowbase_ref[...] = base + rc


# --------------------------------------------------------------------------
# fused probe kernel: searchsorted + bounded gather + re-check + compaction
# --------------------------------------------------------------------------

def _probe_match(pat: CompiledPattern, cols, bvalid, ms, mp, mo, ok):
    """Anchor/const re-check on gathered ``[bm, k]`` candidate rows.

    Exact parity with :func:`repro.core.algebra.kb_join_probe`'s
    verification loop: the composite probe key hashes numeric literals, so
    anchors must be re-checked with true equality, and the non-anchored
    endpoint is verified here too.
    """
    m = ok & bvalid[:, None]
    kcols = {0: ms, 1: mp, 2: mo}
    for i, slot in enumerate((pat.s, pat.p, pat.o)):
        if slot.mode == SlotMode.CONST:
            m = m & (kcols[i] == jnp.uint32(slot.const))
        elif slot.mode == SlotMode.BOUND:
            m = m & (kcols[i] == cols[:, slot.var][:, None])
    return m


def _probe_extend(pat: CompiledPattern, cols, ms, mp, mo):
    """[bm, nv] binding rows -> [bm, k, nv] rows with FREE vars gathered."""
    bm, nv = cols.shape
    k = ms.shape[1]
    ext = jnp.broadcast_to(cols[:, None, :], (bm, k, nv))
    kcols = {0: ms, 1: mp, 2: mo}
    for i, slot in enumerate((pat.s, pat.p, pat.o)):
        if slot.mode == SlotMode.FREE:
            ext = ext.at[..., slot.var].set(kcols[i])
    return ext


def _probe_kernel(pat: CompiledPattern, anchor_is_s: bool, k_max: int,
                  out_cap: int, cols_ref, bvalid_ref, ks_ref, kp_ref, ko_ref,
                  keys_ref, out_ref, counts_ref, fan_ref, base_ref):
    """One ``[bm]`` binding tile: probe, gather, re-check, scatter-compact.

    The grid is 1-D over binding tiles and TPU grids run sequentially, so
    ``base_ref`` (a ``[1]`` output revisited by every tile) carries the
    global running match count — output positions are globally row-major
    over the virtual ``[M, k_max]`` candidate block, bit-identical to
    compacting the unfused probe's extension.  Row ``out_cap`` of the
    resident output is the dump slot for overflowing matches.

    Lowering note: like the scan-path scatter, this kernel leans on
    runtime-indexed ``.at[].set`` plus ``jnp.searchsorted``/``jnp.take``
    gathers; all are exercised in interpret mode here and must be validated
    under Mosaic before flipping ``interpret=False`` on real hardware.
    """
    from repro.core.rdf import composite_key

    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        base_ref[...] = jnp.zeros_like(base_ref)

    cols = cols_ref[...]
    keys = keys_ref[...]
    bm = cols.shape[0]
    anchor = pat.s if anchor_is_s else pat.o
    if anchor.mode == SlotMode.CONST:
        aval = jnp.full((bm,), jnp.uint32(anchor.const))
    else:
        aval = cols[:, anchor.var]
    qk = composite_key(jnp.uint32(pat.p.const), aval)
    lo = jnp.searchsorted(keys, qk, side="left")
    hi = jnp.searchsorted(keys, qk, side="right")
    idx = lo[:, None] + jnp.arange(k_max, dtype=lo.dtype)
    ok = idx < hi[:, None]
    idx_safe = jnp.minimum(idx, keys.shape[0] - 1)
    ms = jnp.take(ks_ref[...], idx_safe, axis=0)
    mp = jnp.take(kp_ref[...], idx_safe, axis=0)
    mo = jnp.take(ko_ref[...], idx_safe, axis=0)
    m = _probe_match(pat, cols, bvalid_ref[...], ms, mp, mo, ok)

    rc = jnp.sum(m.astype(jnp.int32), axis=1)                     # [bm]
    ext = _probe_extend(pat, cols, ms, mp, mo)                    # [bm, k, nv]
    flat_m = m.reshape(bm * k_max)
    rank = jnp.cumsum(flat_m.astype(jnp.int32)) - 1
    base = base_ref[0]
    tgt = base + rank
    tgt = jnp.where(flat_m & (tgt < out_cap), tgt, out_cap)       # dump slot
    nv = cols.shape[1]
    out_ref[...] = out_ref[...].at[tgt].set(ext.reshape(bm * k_max, nv))
    counts_ref[...] = rc
    fan_ref[...] = ((hi - lo) > k_max).astype(jnp.int32)
    base_ref[0] = base + jnp.sum(rc)


def probe_compact_pallas(
    cols: jax.Array,        # [M, NV] uint32 (M multiple of bm)
    bvalid: jax.Array,      # [M] bool
    ks: jax.Array, kp: jax.Array, ko: jax.Array,   # [N] view columns
    keys: jax.Array,        # [N] uint32 sorted composite keys (pads = max)
    pat: CompiledPattern,
    anchor_is_s: bool,
    out_cap: int,
    k_max: int = 8,
    bm: int = DEFAULT_BM,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused probe join.  Returns ``(rows [out_cap, nv], counts [M],
    fan_overflow [M])``.

    ``rows[k]`` is the k-th match of the virtual row-major ``[M, k_max]``
    candidate block, extended with the pattern's FREE variables;
    ``fan_overflow[r]`` flags probe ranges wider than ``k_max`` (clipped
    gathers).  The sorted view stays resident in VMEM (one block), so each
    tile pays O(bm log N) compares + O(bm * k_max) gathers — no O(N) scan.
    """
    m, nv = cols.shape
    n = ks.shape[0]
    assert m % bm == 0, (m, bm)
    grid = (m // bm,)
    kern = functools.partial(_probe_kernel, pat, anchor_is_s, k_max, out_cap)
    out, counts, fan, _ = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, nv), lambda i: (i, 0)),   # binding tile
            pl.BlockSpec((bm,), lambda i: (i,)),        # binding validity
            pl.BlockSpec((n,), lambda i: (0,)),         # view subjects
            pl.BlockSpec((n,), lambda i: (0,)),         # view predicates
            pl.BlockSpec((n,), lambda i: (0,)),         # view objects
            pl.BlockSpec((n,), lambda i: (0,)),         # sorted keys
        ],
        out_specs=[
            pl.BlockSpec((out_cap + 1, nv), lambda i: (0, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),         # running base
        ],
        out_shape=[
            jax.ShapeDtypeStruct((out_cap + 1, nv), jnp.uint32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(cols, bvalid, ks, kp, ko, keys)
    return out[:out_cap], counts, fan


def join_compact_pallas(
    cols: jax.Array,        # [M, NV] uint32 (M multiple of bm)
    bvalid: jax.Array,      # [M] bool
    ks: jax.Array, kp: jax.Array, ko: jax.Array,   # [N] uint32 (N mult of bn)
    kvalid: jax.Array,      # [N] bool
    pat: CompiledPattern,
    out_cap: int,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Fused join+compaction.  Returns ``(rows [out_cap, nv], counts [M])``.

    ``rows[k]`` is the k-th match of the (virtual) row-major candidate
    matrix, extended with the pattern's FREE variables; slots past the total
    match count hold garbage (callers mask with ``sum(counts)``).  The
    candidate matrix itself never exists in HBM.
    """
    m, nv = cols.shape
    n = ks.shape[0]
    assert m % bm == 0 and n % bn == 0, (m, bm, n, bn)
    grid = (m // bm, n // bn)
    in_specs = [
        pl.BlockSpec((bm, nv), lambda i, j: (i, 0)),
        pl.BlockSpec((bm,), lambda i, j: (i,)),
        pl.BlockSpec((bn,), lambda i, j: (j,)),
        pl.BlockSpec((bn,), lambda i, j: (j,)),
        pl.BlockSpec((bn,), lambda i, j: (j,)),
        pl.BlockSpec((bn,), lambda i, j: (j,)),
    ]
    counts = pl.pallas_call(
        functools.partial(_count_kernel, pat),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        interpret=interpret,
    )(cols, bvalid, ks, kp, ko, kvalid)

    offsets = (jnp.cumsum(counts) - counts).astype(jnp.int32)   # [M], tiny

    out, _ = pl.pallas_call(
        functools.partial(_scatter_kernel, pat, out_cap),
        grid=grid,
        in_specs=in_specs + [pl.BlockSpec((bm,), lambda i, j: (i,))],
        out_specs=[
            pl.BlockSpec((out_cap + 1, nv), lambda i, j: (0, 0)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((out_cap + 1, nv), jnp.uint32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
        ],
        interpret=interpret,
    )(cols, bvalid, ks, kp, ko, kvalid, offsets)
    return out[:out_cap], counts
