"""Pallas TPU kernels: tiled window-vs-KB join (match + fused compaction).

TPU adaptation of DSCEP's KB-scan join.  A CPU engine (C-SPARQL) walks hash
maps pointer-by-pointer; the TPU-native formulation streams the KB partition
through VMEM in ``bn``-wide blocks and evaluates all ``bm x bn`` slot-equality
predicates as vector compares (VPU).  Two entry points:

* :func:`match_matrix_pallas` — the original kernel: emits the full int8
  candidate matrix ``[M, N]`` that the caller compacts.  O(M*N) HBM traffic.
* :func:`join_compact_pallas` — the fused pipeline: match tiles never leave
  VMEM; each grid tile scatters its compacted, variable-extended binding rows
  straight into a capacity-bounded ``[out_cap, nv]`` output.  HBM traffic is
  O(M*N / tile-resident) reads + O(out_cap) writes, and the output positions
  are *globally row-major deterministic* — bit-identical to materializing the
  candidate matrix and running :func:`repro.core.pattern.compact_rows`.

The fused pipeline is classic two-phase stream compaction:

1. **count** — grid ``(M/bm, N/bn)`` accumulates per-binding-row match
   counts into an ``[M]`` int32 vector (the only intermediate that touches
   HBM; 4 bytes/row vs N bytes/row for the candidate matrix).
2. host-side exclusive cumsum of the ``[M]`` counts -> global row offsets.
3. **scatter** — same grid; each tile recomputes its match block (compare
   ops are ~free; recompute beats an HBM round-trip), ranks matches within
   the row via a running per-row base carried across ``j`` steps, extends
   binding rows with the pattern's FREE variables from the KB columns, and
   scatters them to ``offset[row] + rank``.  Rows past ``out_cap`` land in a
   dump slot; the caller turns ``sum(counts) > out_cap`` into the overflow
   flag.

Grids iterate ``j`` fastest (Pallas row-major order), which the running
per-row base in phase 3 relies on; the scatter itself is position-exact, so
tile order never changes the result.

Lowering note: the scatter step uses a runtime-indexed ``.at[].set`` into
the resident output block.  This is exercised in interpret mode (this
container) and is the one op whose Mosaic lowering must be validated before
flipping ``interpret=False`` on real hardware; if unsupported on a target
TPU generation, replace it with a one-hot-matmul scatter (MXU) or a
per-row ``fori_loop`` of dynamic-slice stores — the count/offset phases and
the output contract are unchanged.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pattern import CompiledPattern, SlotMode

DEFAULT_BM = 128
DEFAULT_BN = 1024


def _tile_match(pat: CompiledPattern, cols, bvalid, ks, kp, ko, kvalid):
    """All-slot equality for one [bm, bn] tile under the static pattern."""
    kcols = {0: ks, 1: kp, 2: ko}
    m = bvalid[:, None] & kvalid[None, :]
    for i, slot in enumerate((pat.s, pat.p, pat.o)):
        kv = kcols[i][None, :]
        if slot.mode == SlotMode.CONST:
            m = m & (kv == jnp.uint32(slot.const))
        elif slot.mode == SlotMode.BOUND:
            m = m & (kv == cols[:, slot.var][:, None])
    slots = (pat.s, pat.p, pat.o)
    for i in range(3):
        for j in range(i + 1, 3):
            if (
                slots[i].mode != SlotMode.CONST
                and slots[j].mode != SlotMode.CONST
                and slots[i].var == slots[j].var
            ):
                m = m & (kcols[i][None, :] == kcols[j][None, :])
    return m


def _extend_tile(pat: CompiledPattern, cols, ks, kp, ko):
    """[bm, nv] binding rows -> [bm, bn, nv] rows with FREE vars from the KB."""
    bm, nv = cols.shape
    bn = ks.shape[0]
    ext = jnp.broadcast_to(cols[:, None, :], (bm, bn, nv))
    kcols = {0: ks, 1: kp, 2: ko}
    for i, slot in enumerate((pat.s, pat.p, pat.o)):
        if slot.mode == SlotMode.FREE:
            ext = ext.at[..., slot.var].set(
                jnp.broadcast_to(kcols[i][None, :], (bm, bn))
            )
    return ext


# --------------------------------------------------------------------------
# original kernel: full candidate matrix
# --------------------------------------------------------------------------

def _match_kernel(pat: CompiledPattern, cols_ref, bvalid_ref, ks_ref, kp_ref,
                  ko_ref, kvalid_ref, out_ref):
    """One [bm, bn] tile: all-slot equality under the static pattern."""
    m = _tile_match(pat, cols_ref[...], bvalid_ref[...], ks_ref[...],
                    kp_ref[...], ko_ref[...], kvalid_ref[...])
    out_ref[...] = m.astype(jnp.int8)


def match_matrix_pallas(
    cols: jax.Array,        # [M, NV] uint32 (M multiple of bm)
    bvalid: jax.Array,      # [M] bool
    ks: jax.Array, kp: jax.Array, ko: jax.Array,   # [N] uint32 (N mult of bn)
    kvalid: jax.Array,      # [N] bool
    pat: CompiledPattern,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    interpret: bool = True,  # CPU container: interpret; flip off on real TPU
) -> jax.Array:
    m, nv = cols.shape
    n = ks.shape[0]
    assert m % bm == 0 and n % bn == 0, (m, bm, n, bn)
    grid = (m // bm, n // bn)
    kern = functools.partial(_match_kernel, pat)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, nv), lambda i, j: (i, 0)),    # binding tile
            pl.BlockSpec((bm,), lambda i, j: (i,)),         # binding validity
            pl.BlockSpec((bn,), lambda i, j: (j,)),         # KB subject block
            pl.BlockSpec((bn,), lambda i, j: (j,)),         # KB predicate block
            pl.BlockSpec((bn,), lambda i, j: (j,)),         # KB object block
            pl.BlockSpec((bn,), lambda i, j: (j,)),         # KB validity block
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int8),
        interpret=interpret,
    )(cols, bvalid, ks, kp, ko, kvalid)


# --------------------------------------------------------------------------
# fused kernel: join -> compaction without the [M, N] round-trip
# --------------------------------------------------------------------------

def _count_kernel(pat: CompiledPattern, cols_ref, bvalid_ref, ks_ref, kp_ref,
                  ko_ref, kvalid_ref, counts_ref):
    """Phase 1: accumulate per-binding-row match counts across KB blocks."""
    j = pl.program_id(1)
    m = _tile_match(pat, cols_ref[...], bvalid_ref[...], ks_ref[...],
                    kp_ref[...], ko_ref[...], kvalid_ref[...])
    rc = jnp.sum(m.astype(jnp.int32), axis=1)
    counts_ref[...] = jnp.where(j == 0, jnp.zeros_like(rc),
                                counts_ref[...]) + rc


def _scatter_kernel(pat: CompiledPattern, out_cap: int, cols_ref, bvalid_ref,
                    ks_ref, kp_ref, ko_ref, kvalid_ref, offs_ref, out_ref,
                    rowbase_ref):
    """Phase 2: scatter compacted extended rows to offset[row] + rank.

    ``out_ref`` is the whole ``[out_cap + 1, nv]`` output (constant index
    map — the TPU grid is sequential, so revisiting accumulates); row
    ``out_cap`` is the dump slot for overflowing matches.  ``rowbase_ref``
    carries each binding row's running match count across ``j`` steps.
    """
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    cols = cols_ref[...]
    ks, kp, ko = ks_ref[...], kp_ref[...], ko_ref[...]
    m = _tile_match(pat, cols, bvalid_ref[...], ks, kp, ko, kvalid_ref[...])
    rc = jnp.sum(m.astype(jnp.int32), axis=1)                     # [bm]
    base = jnp.where(j == 0, jnp.zeros_like(rc), rowbase_ref[...])
    rank = jnp.cumsum(m.astype(jnp.int32), axis=1) - 1            # [bm, bn]
    tgt = offs_ref[...][:, None] + base[:, None] + rank
    tgt = jnp.where(m & (tgt < out_cap), tgt, out_cap)            # dump slot

    ext = _extend_tile(pat, cols, ks, kp, ko)                     # [bm, bn, nv]
    bm, bn, nv = ext.shape
    out_ref[...] = out_ref[...].at[tgt.reshape(bm * bn)].set(
        ext.reshape(bm * bn, nv)
    )
    rowbase_ref[...] = base + rc


def join_compact_pallas(
    cols: jax.Array,        # [M, NV] uint32 (M multiple of bm)
    bvalid: jax.Array,      # [M] bool
    ks: jax.Array, kp: jax.Array, ko: jax.Array,   # [N] uint32 (N mult of bn)
    kvalid: jax.Array,      # [N] bool
    pat: CompiledPattern,
    out_cap: int,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Fused join+compaction.  Returns ``(rows [out_cap, nv], counts [M])``.

    ``rows[k]`` is the k-th match of the (virtual) row-major candidate
    matrix, extended with the pattern's FREE variables; slots past the total
    match count hold garbage (callers mask with ``sum(counts)``).  The
    candidate matrix itself never exists in HBM.
    """
    m, nv = cols.shape
    n = ks.shape[0]
    assert m % bm == 0 and n % bn == 0, (m, bm, n, bn)
    grid = (m // bm, n // bn)
    in_specs = [
        pl.BlockSpec((bm, nv), lambda i, j: (i, 0)),
        pl.BlockSpec((bm,), lambda i, j: (i,)),
        pl.BlockSpec((bn,), lambda i, j: (j,)),
        pl.BlockSpec((bn,), lambda i, j: (j,)),
        pl.BlockSpec((bn,), lambda i, j: (j,)),
        pl.BlockSpec((bn,), lambda i, j: (j,)),
    ]
    counts = pl.pallas_call(
        functools.partial(_count_kernel, pat),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        interpret=interpret,
    )(cols, bvalid, ks, kp, ko, kvalid)

    offsets = (jnp.cumsum(counts) - counts).astype(jnp.int32)   # [M], tiny

    out, _ = pl.pallas_call(
        functools.partial(_scatter_kernel, pat, out_cap),
        grid=grid,
        in_specs=in_specs + [pl.BlockSpec((bm,), lambda i, j: (i,))],
        out_specs=[
            pl.BlockSpec((out_cap + 1, nv), lambda i, j: (0, 0)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((out_cap + 1, nv), jnp.uint32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
        ],
        interpret=interpret,
    )(cols, bvalid, ks, kp, ko, kvalid, offsets)
    return out[:out_cap], counts
