"""Public wrappers: pad to block multiples, run the kernel, slice back.

Three join surfaces:

* :func:`match_matrix` — original path; returns the bool ``[M, N]`` candidate
  matrix that the caller compacts (kept for parity tests and as a fallback).
* :func:`join_compact` / :func:`join_compact_jnp` — fused path; returns the
  compacted, variable-extended :class:`Bindings` directly.  The Pallas
  version never materializes the candidate matrix in HBM; the jnp version
  (the path XLA actually runs on CPU hosts) still forms the bool matrix but
  gathers only the ``out_cap`` winning rows instead of materializing and
  compacting the ``[M, N, nv]`` extension — the dominant memory traffic of
  the unfused path.
* :func:`probe_compact` / :func:`probe_compact_jnp` — the probe-method
  analogue (``kb_method="probe"``/``"auto"``): searchsorted + bounded
  gather + anchor re-check + compaction fused into one kernel pass (or the
  winner-gather jnp twin), bit-identical to the unfused
  ``algebra.kb_join_probe`` pipeline.

Both fused paths are bit-identical to the unfused
``match -> extend -> compact_rows`` pipeline, including row order (global
row-major), zeroed invalid rows, and the overflow flag.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.kb import (
    KnowledgeBase, gather_matches, probe_range, probe_view,
)
from repro.core.pattern import Bindings, CompiledPattern, SlotMode
from repro.core.rdf import composite_key

from . import kernel
from .ref import match_matrix_ref


def _pad_to(x: jax.Array, mult: int, axis: int = 0, fill=0):
    n = x.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=fill)


def autotune_block_shapes(
    m: int, n: int, nv: int, vmem_budget: int = 4 * 1024 * 1024
) -> Tuple[int, int]:
    """Pick (bm, bn) for the fused join so a tile's working set fits VMEM.

    Deterministic heuristic (no measurement): the scatter phase holds the
    ``[bm, bn, nv]`` uint32 extension plus two ``[bm, bn]`` int32 temporaries
    (rank/target) per tile, so tile bytes ~= 4 * bm * bn * (nv + 2).  KB
    blocks want to be wide (lane dim 128-aligned) to amortize streaming;
    binding blocks deep enough to reuse each KB block across many rows.
    """
    bn = max(128, min(kernel.DEFAULT_BN, ((n + 127) // 128) * 128))
    bm = vmem_budget // max(1, 4 * bn * (nv + 2))
    bm = max(8, min(kernel.DEFAULT_BM, (bm // 8) * 8, ((m + 7) // 8) * 8))
    return int(bm), int(bn)


def match_matrix(
    bind: Bindings, kb: KnowledgeBase, pat: CompiledPattern,
    bm: int | None = None, bn: int | None = None, interpret: bool = True,
) -> jax.Array:
    """Drop-in replacement for the engine's scan-method match matrix.

    Returns bool ``[bind.capacity, kb.capacity]``; callers compact it exactly
    as with the jnp path.
    """
    m, n = bind.capacity, kb.capacity
    bm = bm or min(kernel.DEFAULT_BM, max(8, m))
    bn = bn or min(kernel.DEFAULT_BN, max(128, n))
    cols = _pad_to(bind.cols, bm, axis=0)
    bvalid = _pad_to(bind.valid, bm, axis=0, fill=False)
    ks = _pad_to(kb.s_ps, bn)
    kp = _pad_to(kb.p_ps, bn)
    ko = _pad_to(kb.o_ps, bn)
    kvalid = _pad_to(kb.valid, bn, fill=False)
    out = kernel.match_matrix_pallas(
        cols, bvalid, ks, kp, ko, kvalid, pat, bm=bm, bn=bn, interpret=interpret
    )
    return out[:m, :n].astype(bool)


def join_compact(
    bind: Bindings, kb: KnowledgeBase, pat: CompiledPattern, out_cap: int,
    bm: int | None = None, bn: int | None = None, interpret: bool = True,
) -> Bindings:
    """Fused Pallas join: compacted extended bindings, no [M, N] in HBM."""
    m, n = bind.capacity, kb.capacity
    if bm is None or bn is None:
        abm, abn = autotune_block_shapes(m, n, bind.num_vars)
        bm, bn = bm or abm, bn or abn
    cols = _pad_to(bind.cols, bm, axis=0)
    bvalid = _pad_to(bind.valid, bm, axis=0, fill=False)
    ks = _pad_to(kb.s_ps, bn)
    kp = _pad_to(kb.p_ps, bn)
    ko = _pad_to(kb.o_ps, bn)
    kvalid = _pad_to(kb.valid, bn, fill=False)
    rows, counts = kernel.join_compact_pallas(
        cols, bvalid, ks, kp, ko, kvalid, pat, out_cap, bm=bm, bn=bn,
        interpret=interpret,
    )
    total = jnp.sum(counts)
    valid = jnp.arange(out_cap) < jnp.minimum(total, out_cap)
    rows = jnp.where(valid[:, None], rows, jnp.zeros_like(rows))
    return Bindings(rows, valid, (total > out_cap) | bind.overflow)


def _anchor_values(bind: Bindings, anchor) -> jax.Array:
    if anchor.mode == SlotMode.CONST:
        return jnp.full((bind.capacity,), jnp.uint32(anchor.const))
    return bind.cols[:, anchor.var]


def probe_compact(
    bind: Bindings, kb: KnowledgeBase, pat: CompiledPattern, out_cap: int,
    k_max: int = 8, bm: int | None = None, interpret: bool = True,
) -> Bindings:
    """Fused Pallas probe join: one kernel pass, no per-stage HBM hops.

    Bit-identical to the unfused :func:`repro.core.algebra.kb_join_probe`
    pipeline (probe_range -> gather_matches -> re-check -> compact_rows),
    including row order, zeroed invalid rows and both overflow sources
    (compaction past ``out_cap`` and probe ranges wider than ``k_max``).
    """
    keys, (cs, cp, co), _, anchor_is_s = probe_view(kb, pat)
    m = bind.capacity
    bm = bm or min(kernel.DEFAULT_BM, max(8, m))
    cols = _pad_to(bind.cols, bm, axis=0)
    bvalid = _pad_to(bind.valid, bm, axis=0, fill=False)
    # lane-align the resident view; pads carry the max sort key, which no
    # real probe key reaches, so searchsorted results are unchanged
    keys_p = _pad_to(keys, 128, fill=jnp.uint32(0xFFFFFFFF))
    cs_p, cp_p, co_p = (_pad_to(c, 128) for c in (cs, cp, co))
    rows, counts, fan = kernel.probe_compact_pallas(
        cols, bvalid, cs_p, cp_p, co_p, keys_p, pat, anchor_is_s, out_cap,
        k_max=k_max, bm=bm, interpret=interpret,
    )
    total = jnp.sum(counts)
    valid = jnp.arange(out_cap) < jnp.minimum(total, out_cap)
    rows = jnp.where(valid[:, None], rows, jnp.zeros_like(rows))
    fan_ovf = jnp.any((fan[:m] > 0) & bind.valid)
    return Bindings(rows, valid, (total > out_cap) | fan_ovf | bind.overflow)


def probe_compact_jnp(
    bind: Bindings, kb: KnowledgeBase, pat: CompiledPattern, out_cap: int,
    k_max: int = 8,
) -> Bindings:
    """Fused jnp probe twin: gather the ``out_cap`` winners directly.

    Same move as :func:`join_compact_jnp` applied to the probe method: the
    k-th output row is located by binary search on the cumulative match
    count over the ``[cap, k_max]`` candidate block, so the row extension
    is built only for rows that actually publish.
    """
    keys_sorted, kcols_v, anchor, _ = probe_view(kb, pat)
    ca = bind.capacity
    qk = composite_key(jnp.uint32(pat.p.const), _anchor_values(bind, anchor))
    lo, hi = probe_range(keys_sorted, qk)
    (ms, mp, mo), ok, fan_rows = gather_matches(kcols_v, lo, hi, k_max)
    gathered = {0: ms, 1: mp, 2: mo}
    # the kernel's re-check helper keeps the verification semantics in one
    # place for both fused paths (ref.py stays independent as the oracle)
    m = kernel._probe_match(pat, bind.cols, bind.valid, ms, mp, mo, ok)
    cum = jnp.cumsum(m.reshape(-1).astype(jnp.int32))
    total = cum[-1]
    k = jnp.arange(out_cap, dtype=jnp.int32)
    src = jnp.searchsorted(cum, k + 1, side="left").astype(jnp.int32)
    valid = k < jnp.minimum(total, out_cap)
    src = jnp.minimum(src, ca * k_max - 1)
    rows = jnp.take(bind.cols, src // k_max, axis=0)
    for i, slot in enumerate((pat.s, pat.p, pat.o)):
        if slot.mode == SlotMode.FREE:
            rows = rows.at[:, slot.var].set(gathered[i].reshape(-1)[src])
    rows = jnp.where(valid[:, None], rows, jnp.zeros_like(rows))
    overflow = ((total > out_cap) | jnp.any(fan_rows & bind.valid)
                | bind.overflow)
    return Bindings(rows, valid, overflow)


def join_compact_jnp(
    bind: Bindings, kb: KnowledgeBase, pat: CompiledPattern, out_cap: int,
) -> Bindings:
    """Fused jnp join: gather the out_cap winners instead of compacting M*N.

    The k-th output row is located by binary search on the cumulative match
    count (``searchsorted`` over the flattened row-major matrix), so only
    ``out_cap`` extended rows are ever built.
    """
    m = match_matrix_ref(bind.cols, bind.valid, kb.s_ps, kb.p_ps, kb.o_ps,
                         kb.valid, pat)
    ca, n = m.shape
    cs = jnp.cumsum(m.reshape(-1).astype(jnp.int32))
    total = cs[-1]
    k = jnp.arange(out_cap, dtype=jnp.int32)
    src = jnp.searchsorted(cs, k + 1, side="left").astype(jnp.int32)
    valid = k < jnp.minimum(total, out_cap)
    src = jnp.minimum(src, ca * n - 1)
    bi, kr = src // n, src % n
    rows = jnp.take(bind.cols, bi, axis=0)
    kcols = {0: kb.s_ps, 1: kb.p_ps, 2: kb.o_ps}
    for i, slot in enumerate((pat.s, pat.p, pat.o)):
        if slot.mode == SlotMode.FREE:
            rows = rows.at[:, slot.var].set(jnp.take(kcols[i], kr))
    rows = jnp.where(valid[:, None], rows, jnp.zeros_like(rows))
    return Bindings(rows, valid, (total > out_cap) | bind.overflow)
