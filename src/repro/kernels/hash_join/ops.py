"""Public wrapper: pad to block multiples, run the kernel, slice back."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kb import KnowledgeBase
from repro.core.pattern import Bindings, CompiledPattern

from . import kernel


def _pad_to(x: jax.Array, mult: int, axis: int = 0, fill=0):
    n = x.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=fill)


def match_matrix(
    bind: Bindings, kb: KnowledgeBase, pat: CompiledPattern,
    bm: int | None = None, bn: int | None = None, interpret: bool = True,
) -> jax.Array:
    """Drop-in replacement for the engine's scan-method match matrix.

    Returns bool ``[bind.capacity, kb.capacity]``; callers compact it exactly
    as with the jnp path.
    """
    m, n = bind.capacity, kb.capacity
    bm = bm or min(kernel.DEFAULT_BM, max(8, m))
    bn = bn or min(kernel.DEFAULT_BN, max(128, n))
    cols = _pad_to(bind.cols, bm, axis=0)
    bvalid = _pad_to(bind.valid, bm, axis=0, fill=False)
    ks = _pad_to(kb.s_ps, bn)
    kp = _pad_to(kb.p_ps, bn)
    ko = _pad_to(kb.o_ps, bn)
    kvalid = _pad_to(kb.valid, bn, fill=False)
    out = kernel.match_matrix_pallas(
        cols, bvalid, ks, kp, ko, kvalid, pat, bm=bm, bn=bn, interpret=interpret
    )
    return out[:m, :n].astype(bool)
