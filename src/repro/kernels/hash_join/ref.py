"""Pure-jnp oracles for the window-vs-KB join.

Semantics (shared with the kernels): given a binding table ``cols [M, NV]``
with row validity ``bvalid [M]``, KB columns ``(s, p, o) [N]`` with validity
``kvalid [N]``, and a static :class:`CompiledPattern`:

* :func:`match_matrix_ref` — the boolean candidate matrix ``match [M, N]``
  where entry (i, r) is True iff KB row r satisfies the pattern under
  binding row i.
* :func:`join_compact_ref` — the fused-pipeline oracle: materialize the
  candidate matrix, extend matching binding rows with the pattern's FREE
  variables from the KB columns, and compact in global row-major order into
  ``out_cap`` rows.  The fused kernel must match this bit-exactly.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.pattern import CompiledPattern, SlotMode, compact_rows


def match_matrix_ref(cols, bvalid, ks, kp, ko, kvalid, pat: CompiledPattern):
    kcols = {0: ks, 1: kp, 2: ko}
    m = bvalid[:, None] & kvalid[None, :]
    for i, slot in enumerate((pat.s, pat.p, pat.o)):
        kv = kcols[i][None, :]
        if slot.mode == SlotMode.CONST:
            m = m & (kv == jnp.uint32(slot.const))
        elif slot.mode == SlotMode.BOUND:
            m = m & (kv == cols[:, slot.var][:, None])
    slots = (pat.s, pat.p, pat.o)
    for i in range(3):
        for j in range(i + 1, 3):
            if (
                slots[i].mode != SlotMode.CONST
                and slots[j].mode != SlotMode.CONST
                and slots[i].var == slots[j].var
            ):
                m = m & (kcols[i][None, :] == kcols[j][None, :])
    return m


def probe_compact_ref(
    cols, bvalid, vs, vp, vo, keys, pat: CompiledPattern, anchor_is_s: bool,
    out_cap: int, k_max: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for the fused probe: returns ``(rows, valid, overflow)``.

    Materializes the bounded ``[M, k_max]`` gather (probe ranges on the
    sorted composite-key ``keys`` over view columns ``vs/vp/vo``), re-checks
    every CONST/BOUND slot exactly, extends FREE variables, and compacts in
    global row-major order — the unfused formulation the fused kernel and
    jnp twin must match bit-exactly.  ``overflow`` includes clipped probe
    ranges (fan-out past ``k_max``) on valid binding rows.
    """
    from repro.core.rdf import composite_key

    m, nv = cols.shape
    anchor = pat.s if anchor_is_s else pat.o
    if anchor.mode == SlotMode.CONST:
        aval = jnp.full((m,), jnp.uint32(anchor.const))
    else:
        aval = cols[:, anchor.var]
    qk = composite_key(jnp.uint32(pat.p.const), aval)
    lo = jnp.searchsorted(keys, qk, side="left")
    hi = jnp.searchsorted(keys, qk, side="right")
    idx = lo[:, None] + jnp.arange(k_max, dtype=lo.dtype)
    ok = idx < hi[:, None]
    idx_safe = jnp.minimum(idx, keys.shape[0] - 1)
    gathered = {i: jnp.take(c, idx_safe, axis=0)
                for i, c in enumerate((vs, vp, vo))}
    match = ok & bvalid[:, None]
    for i, slot in enumerate((pat.s, pat.p, pat.o)):
        if slot.mode == SlotMode.CONST:
            match = match & (gathered[i] == jnp.uint32(slot.const))
        elif slot.mode == SlotMode.BOUND:
            match = match & (gathered[i] == cols[:, slot.var][:, None])
    ext = jnp.broadcast_to(cols[:, None, :], (m, k_max, nv))
    for i, slot in enumerate((pat.s, pat.p, pat.o)):
        if slot.mode == SlotMode.FREE:
            ext = ext.at[..., slot.var].set(gathered[i])
    rows, valid, overflow = compact_rows(
        ext.reshape(m * k_max, nv), match.reshape(m * k_max), out_cap)
    fan = jnp.any(((hi - lo) > k_max) & bvalid)
    return rows, valid, overflow | fan


def join_compact_ref(
    cols, bvalid, ks, kp, ko, kvalid, pat: CompiledPattern, out_cap: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for the fused join: returns ``(rows, valid, overflow)``."""
    m = match_matrix_ref(cols, bvalid, ks, kp, ko, kvalid, pat)
    ca, n = m.shape
    nv = cols.shape[1]
    ext = jnp.broadcast_to(cols[:, None, :], (ca, n, nv))
    kcols = {0: ks, 1: kp, 2: ko}
    for i, slot in enumerate((pat.s, pat.p, pat.o)):
        if slot.mode == SlotMode.FREE:
            ext = ext.at[..., slot.var].set(
                jnp.broadcast_to(kcols[i][None, :], (ca, n))
            )
    return compact_rows(ext.reshape(ca * n, nv), m.reshape(ca * n), out_cap)
