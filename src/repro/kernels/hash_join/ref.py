"""Pure-jnp oracle for the window-vs-KB match matrix.

Semantics (shared with the kernel): given a binding table ``cols [M, NV]``
with row validity ``bvalid [M]``, KB columns ``(s, p, o) [N]`` with validity
``kvalid [N]``, and a static :class:`CompiledPattern`, produce the boolean
candidate matrix ``match [M, N]`` where entry (i, r) is True iff KB row r
satisfies the pattern under binding row i.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.pattern import CompiledPattern, SlotMode


def match_matrix_ref(cols, bvalid, ks, kp, ko, kvalid, pat: CompiledPattern):
    kcols = {0: ks, 1: kp, 2: ko}
    m = bvalid[:, None] & kvalid[None, :]
    for i, slot in enumerate((pat.s, pat.p, pat.o)):
        kv = kcols[i][None, :]
        if slot.mode == SlotMode.CONST:
            m = m & (kv == jnp.uint32(slot.const))
        elif slot.mode == SlotMode.BOUND:
            m = m & (kv == cols[:, slot.var][:, None])
    slots = (pat.s, pat.p, pat.o)
    for i in range(3):
        for j in range(i + 1, 3):
            if (
                slots[i].mode != SlotMode.CONST
                and slots[j].mode != SlotMode.CONST
                and slots[i].var == slots[j].var
            ):
                m = m & (kcols[i][None, :] == kcols[j][None, :])
    return m
