"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel package ships three files:

* ``kernel.py`` — ``pl.pallas_call`` + explicit ``BlockSpec`` VMEM tiling
  (TPU is the target; ``interpret=True`` validates on CPU),
* ``ops.py``    — the jit'd public wrapper (padding, dtype policy, vmap),
* ``ref.py``    — the pure-jnp oracle every test sweeps against.

Kernels:

* ``hash_join``       — DSCEP's window-vs-KB match matrix (the scan-method
  hotspot: slot-mode equality compares tiled over the KB partition),
* ``closure``         — boolean-matmul transitive-closure step (RDFS
  subclass reasoning on the MXU),
* ``flash_attention`` — GQA flash attention fwd (causal / sliding-window),
* ``ssd``             — Mamba-2 state-space-duality chunked scan.
"""
