"""Assigned input shapes (one set, shared by all 10 LM-family archs).

``train_*`` lowers ``train_step``; ``prefill_*`` lowers a full-sequence
``serve_prefill``; ``decode_*``/``long_*`` lower ``serve_step`` (one new token
against a KV cache of the stated length).  ``long_500k`` requires a
sub-quadratic attention family (SSM / hybrid / SWA) — pure full-attention
archs skip it per DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


TRAIN_4K = InputShape("train_4k", "train", 4_096, 256)
PREFILL_32K = InputShape("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = InputShape("decode_32k", "decode", 32_768, 128)
LONG_500K = InputShape("long_500k", "decode", 524_288, 1)

ALL_SHAPES: Tuple[InputShape, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def get_shape(name: str) -> InputShape:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
