"""Architecture registry: one module per assigned arch (+ the paper's own
DSCEP pipeline config in :mod:`repro.configs.dscep`)."""
from . import (  # noqa: F401
    deepseek_v2_236b,
    h2o_danube_1_8b,
    jamba_v0_1_52b,
    mamba2_130m,
    minicpm3_4b,
    mixtral_8x22b,
    musicgen_large,
    olmo_1b,
    qwen2_1_5b,
    qwen2_vl_7b,
)
from .base import ModelConfig, get_config, registered, smoke_variant  # noqa: F401
from .shapes import ALL_SHAPES, InputShape, get_shape  # noqa: F401

ALL_ARCHS = (
    "qwen2-vl-7b", "deepseek-v2-236b", "mixtral-8x22b", "h2o-danube-1.8b",
    "minicpm3-4b", "qwen2-1.5b", "olmo-1b", "mamba2-130m", "jamba-v0.1-52b",
    "musicgen-large",
)
