"""DeepSeek-V2 236B [arXiv:2405.04434; hf].

MLA (kv_lora_rank=512, q_lora_rank=1536, decoupled rope dim 64) + MoE with
2 shared + 160 routed experts, top-6, expert d_ff=1536.  The assignment pins
all layers to the MoE pattern (the HF model's first dense layer is folded
into the pattern — noted in DESIGN.md).
"""
from .base import LayerSpec, MLAConfig, ModelConfig, MoEConfig, register


@register("deepseek-v2-236b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,
        head_dim=192,                      # nope 128 + rope 64
        d_ff=1536,
        vocab_size=102400,
        rope_theta=10_000.0,
        moe=MoEConfig(num_experts=160, top_k=6, expert_ff=1536,
                      num_shared=2, shared_ff=1536),
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                      rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
        layer_pattern=(LayerSpec("attn", "moe"),),
        supports_long_context=False,       # full attention
    )
