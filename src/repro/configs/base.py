"""Model/config schema and the architecture registry.

One :class:`ModelConfig` describes any architecture in the assigned pool:
dense / MoE / SSM / hybrid decoder LMs with GQA/MLA/SWA attention, M-RoPE,
multi-codebook audio heads, etc.  ``layer_pattern`` expresses heterogeneous
stacks (Jamba's 1:7 attention:mamba interleave with alternating MoE) as a
repeating *period* of sub-layer specs, which the model assembles as a
``lax.scan`` over periods — keeping HLO size O(period), not O(layers).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int                   # intermediate size per routed expert
    num_shared: int = 0              # always-on shared experts (DeepSeek-V2)
    shared_ff: int = 0               # intermediate size of the shared block
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""

    kv_lora_rank: int                # compressed KV latent width (cache object)
    q_lora_rank: int = 0             # 0 = full-rank queries
    rope_head_dim: int = 64          # decoupled RoPE sub-dim (shared key)
    nope_head_dim: int = 128         # non-rotary sub-dim per head
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    version: int = 2                 # 1 = selective scan, 2 = SSD
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    ngroups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def nheads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str                       # "attn" | "mamba"
    ffn: Optional[str]               # "dense" | "moe" | None


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    qkv_bias: bool = False
    swa_window: Optional[int] = None # sliding-window attention size
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"            # rmsnorm | layernorm_nonparam
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    layer_pattern: Tuple[LayerSpec, ...] = (LayerSpec("attn", "dense"),)
    mrope_sections: Optional[Tuple[int, int, int]] = None   # qwen2-vl M-RoPE
    num_codebooks: int = 0           # musicgen audio codebooks (0 = text LM)
    frontend: Optional[str] = None   # "vision" | "audio" stub frontends
    dtype: str = "bfloat16"
    # which input shapes this arch supports (long_500k policy, DESIGN §3)
    supports_long_context: bool = False
    # MLA serve-time absorption: run cached attention in latent space instead
    # of re-expanding the whole [B,S,r] cache through wkv_b every step
    # (§Perf lever; numerically equivalent, tested)
    mla_absorbed: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the vocab axis TP-shards on
        any mesh (Megatron-style); padded logits are masked in the head."""
        return -(-self.vocab_size // 256) * 256

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def num_periods(self) -> int:
        assert self.num_layers % self.period == 0, (
            "num_layers %d must divide the layer pattern period %d"
            % (self.num_layers, self.period)
        )
        return self.num_layers // self.period

    # -- parameter counting (for roofline MODEL_FLOPS = 6*N*D) ---------------
    def param_counts(self) -> Dict[str, float]:
        """Returns {'total': N, 'active': N_active} (active = per-token)."""
        d = self.d_model
        hd = self.resolved_head_dim
        total = 0.0
        active = 0.0

        def add(n, always_active=True):
            nonlocal total, active
            total += n
            if always_active:
                active += n

        add(self.vocab_size * d)                     # embed
        if not self.tie_embeddings:
            add(self.vocab_size * d)                 # lm head
        if self.num_codebooks:
            add((self.num_codebooks - 1) * self.vocab_size * d)

        for spec in self.layer_pattern:
            reps = self.num_periods
            if spec.mixer == "attn":
                if self.mla is not None:
                    m = self.mla
                    qdim = self.num_heads * (m.nope_head_dim + m.rope_head_dim)
                    if m.q_lora_rank:
                        attn_p = d * m.q_lora_rank + m.q_lora_rank * qdim
                    else:
                        attn_p = d * qdim
                    attn_p += d * m.kv_lora_rank + d * m.rope_head_dim
                    attn_p += m.kv_lora_rank * self.num_heads * (
                        m.nope_head_dim + m.v_head_dim
                    )
                    attn_p += self.num_heads * m.v_head_dim * d
                else:
                    attn_p = d * (self.num_heads * hd) \
                        + 2 * d * (self.num_kv_heads * hd) \
                        + (self.num_heads * hd) * d
                add(attn_p * reps)
            else:
                mc = self.mamba or MambaConfig()
                di = mc.d_inner(d)
                nh = mc.nheads(d)
                m_p = d * (2 * di + 2 * mc.ngroups * mc.d_state + nh)  # in_proj
                m_p += mc.d_conv * (di + 2 * mc.ngroups * mc.d_state)  # conv
                m_p += nh * 2 + di                                     # A, D, dt_bias-ish
                m_p += di * d                                          # out_proj
                add(m_p * reps)
            if spec.ffn == "dense":
                add(3 * d * self.d_ff * reps)
            elif spec.ffn == "moe":
                mo = self.moe
                assert mo is not None
                routed = 3 * d * mo.expert_ff
                add(routed * mo.num_experts * reps, always_active=False)
                active += routed * mo.top_k * reps
                add(d * mo.num_experts * reps)       # router
                if mo.num_shared:
                    add(3 * d * (mo.shared_ff or mo.expert_ff) * mo.num_shared * reps)
        return {"total": total, "active": active}


_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        from . import ALL_ARCHS  # ensure modules imported
        if name not in _REGISTRY:
            raise KeyError("unknown arch %r; known: %s" % (name, sorted(_REGISTRY)))
    return _REGISTRY[name]()


def registered() -> Tuple[str, ...]:
    from . import ALL_ARCHS  # noqa: F401
    return tuple(sorted(_REGISTRY))


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    changes: Dict = dict(
        num_layers=cfg.period * 2,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        dtype="float32",
    )
    if cfg.moe:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2, expert_ff=64,
            num_shared=min(cfg.moe.num_shared, 1), shared_ff=64,
        )
    if cfg.mla:
        changes["mla"] = MLAConfig(
            kv_lora_rank=32, q_lora_rank=(32 if cfg.mla.q_lora_rank else 0),
            rope_head_dim=8, nope_head_dim=16, v_head_dim=16,
        )
    if cfg.mamba:
        changes["mamba"] = dataclasses.replace(
            cfg.mamba, d_state=16, headdim=16, ngroups=1,
        )
    if cfg.swa_window:
        changes["swa_window"] = 16
    if cfg.mrope_sections:
        changes["mrope_sections"] = (2, 3, 3)   # sums to half of head_dim=16
    return dataclasses.replace(cfg, **changes)
