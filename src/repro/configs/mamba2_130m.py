"""Mamba2-130M [arXiv:2405.21060]: attention-free SSD stack."""
from .base import LayerSpec, MambaConfig, ModelConfig, register


@register("mamba2-130m")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        num_layers=24,
        d_model=768,
        num_heads=1,                        # unused (attention-free)
        num_kv_heads=1,
        d_ff=0,
        vocab_size=50280,
        tie_embeddings=True,
        mamba=MambaConfig(version=2, d_state=128, d_conv=4, expand=2,
                          headdim=64, ngroups=1),
        layer_pattern=(LayerSpec("mamba", None),),
        supports_long_context=True,         # O(1) decode state
    )
