"""MusicGen-large [arXiv:2306.05284; hf].

Decoder-only over EnCodec tokens: 4 codebooks, vocab 2048 each, per-codebook
output heads.  The EnCodec/delay-pattern frontend is a stub —
``input_specs()`` provides token ids (or precomputed frame embeddings).
RoPE replaces the original sinusoidal embedding (TPU-idiomatic; DESIGN.md).
"""
from .base import ModelConfig, register


@register("musicgen-large")
def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="audio",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=2048,
        num_codebooks=4,
        frontend="audio",
        supports_long_context=False,
    )
