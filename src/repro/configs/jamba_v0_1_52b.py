"""Jamba v0.1 52B [arXiv:2403.19887; hf].

Hybrid period of 8 layers: Mamba-1 everywhere except one attention layer
(index 4), MoE (16 experts top-2) on every other layer — the 1:7
attention:mamba interleave with alternating MoE of the paper.
"""
from .base import LayerSpec, MambaConfig, ModelConfig, MoEConfig, register

_PERIOD = tuple(
    LayerSpec(
        mixer="attn" if i == 4 else "mamba",
        ffn="moe" if i % 2 == 1 else "dense",
    )
    for i in range(8)
)


@register("jamba-v0.1-52b")
def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=65536,
        moe=MoEConfig(num_experts=16, top_k=2, expert_ff=14336),
        mamba=MambaConfig(version=1, d_state=16, d_conv=4, expand=2),
        layer_pattern=_PERIOD,
        supports_long_context=True,         # hybrid: O(1) mamba + sparse attn
    )
