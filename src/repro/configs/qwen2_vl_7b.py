"""Qwen2-VL-7B backbone [arXiv:2409.12191; hf].

VLM entry: the transformer backbone only — the vision frontend is a stub
(``input_specs()`` supplies precomputed patch embeddings + 3D M-RoPE position
ids).  M-RoPE sections follow the HF config (16/24/24 over half head_dim=64).
"""
from .base import ModelConfig, register


@register("qwen2-vl-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b",
        family="vlm",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),
        frontend="vision",
        supports_long_context=False,   # full attention -> long_500k skipped
    )
