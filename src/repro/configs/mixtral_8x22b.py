"""Mixtral 8x22B [arXiv:2401.04088; hf]: 8 experts top-2, SWA."""
from .base import LayerSpec, ModelConfig, MoEConfig, register


@register("mixtral-8x22b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        num_layers=56,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=32768,
        swa_window=4096,
        rope_theta=1_000_000.0,
        moe=MoEConfig(num_experts=8, top_k=2, expert_ff=16384),
        layer_pattern=(LayerSpec("attn", "moe"),),
        supports_long_context=True,        # SWA -> bounded KV, sub-quadratic
    )
