"""DSCEP deployment configs — the paper's own 'architecture'.

Where the 10 LM configs describe neural stacks, these presets describe SCEP
pipeline deployments: window geometry (paper §4.4: "window size is a maximum
of 1000 RDF triples"), engine capacities, KB-access method and the execution
mode — all as one frozen :class:`~repro.core.session.ExecutionConfig`.
``build_runtime`` assembles a registered :class:`~repro.core.session.Session`
query from a preset, a query and a KB, mirroring how
``launch/dscep_run.py`` deploys.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.session import ExecutionConfig, Session


@dataclasses.dataclass(frozen=True)
class DSCEPDeployment:
    name: str
    config: ExecutionConfig
    description: str = ""

    # legacy accessors (pre-Session presets exposed a RuntimeConfig + a
    # `decomposed` bool; both are now derived from the ExecutionConfig)
    @property
    def runtime(self):
        return self.config.runtime_config()

    @property
    def decomposed(self) -> bool:
        return self.config.mode != "monolithic"


_PRESETS: Dict[str, DSCEPDeployment] = {}


def register_deployment(d: DSCEPDeployment) -> DSCEPDeployment:
    _PRESETS[d.name] = d
    return d


# the paper's evaluation setup (§4.4): 1000-triple windows, scan KB access
register_deployment(DSCEPDeployment(
    name="paper-eval",
    config=ExecutionConfig(mode="single_program",
                           window_capacity=1000, max_windows=8,
                           bind_cap=4096, scan_cap=1024, out_cap=4096,
                           kb_method="scan"),
    description="Paper §4.4 settings: 1000-triple windows, C-SPARQL-style "
                "attached-KB scans, automatic Fig. 4 decomposition.",
))

# SERVICE-style endpoint access (the paper's second measured method)
register_deployment(DSCEPDeployment(
    name="paper-eval-subquery",
    config=ExecutionConfig(mode="single_program",
                           window_capacity=1000, max_windows=8,
                           bind_cap=4096, scan_cap=1024, out_cap=4096,
                           kb_method="probe"),
    description="Paper §4.4 settings with SPARQL-subquery (indexed endpoint) "
                "KB access.",
))

# cost-based KB access: the default for every non-baseline preset below.
# Each operator's used-KB slice is profiled at build time; every KB join
# independently picks probe (with a derived k_max covering the observed
# fan-out) or the fused scan, and the join sequence is selectivity-ordered.
register_deployment(DSCEPDeployment(
    name="paper-eval-auto",
    config=ExecutionConfig(mode="single_program",
                           window_capacity=1000, max_windows=8,
                           bind_cap=4096, scan_cap=1024, out_cap=4096,
                           kb_method="auto"),
    description="Paper §4.4 settings with cost-based per-join KB access "
                "(probe where anchored fan-out is small, fused scan "
                "otherwise) and selectivity-ordered joins.",
))

# container-scale smoke (tests/examples)
register_deployment(DSCEPDeployment(
    name="smoke",
    config=ExecutionConfig(mode="single_program",
                           window_capacity=128, max_windows=4,
                           bind_cap=1024, scan_cap=128, out_cap=1024,
                           kb_method="auto"),
    description="Reduced capacities for CPU smoke runs.",
))

# monolithic baseline (paper Table 2)
register_deployment(DSCEPDeployment(
    name="monolithic",
    config=ExecutionConfig(mode="monolithic",
                           window_capacity=1000, max_windows=8,
                           bind_cap=4096, scan_cap=1024, out_cap=4096),
    description="Single-operator execution against the full KB (Table 2 "
                "baseline).",
))

# heterogeneous windows: each registered .rq's RANGE clause is its geometry
register_deployment(DSCEPDeployment(
    name="per-query-windows",
    config=ExecutionConfig(mode="single_program",
                           window_capacity=1000, max_windows=8,
                           bind_cap=4096, scan_cap=1024, out_cap=4096,
                           kb_method="auto", window_from_query=True),
    description="One Session, many queries: each registered query's "
                "[RANGE TRIPLES n STEP m] clause drives its own window "
                "geometry (window_capacity is only the default for queries "
                "without a RANGE clause).",
))

# streaming dataflow deployment (operators over device channels)
register_deployment(DSCEPDeployment(
    name="pipelined",
    config=ExecutionConfig(mode="pipelined",
                           window_capacity=1000, max_windows=8,
                           bind_cap=4096, scan_cap=1024, out_cap=4096,
                           kb_method="auto", channel_capacity=2),
    description="Per-operator jitted steps over bounded device channels, "
                "software-pipelined schedule (2 chunks in flight).",
))


def get_deployment(name: str) -> DSCEPDeployment:
    return _PRESETS[name]


def deployments() -> Dict[str, DSCEPDeployment]:
    return dict(_PRESETS)


def build_runtime(preset: str, query, kb, vocab, mesh=None):
    """Register ``query`` in a Session deploying ``preset``.

    Returns the :class:`~repro.core.session.RegisteredQuery` — the unified
    drive handle (``process_chunk`` / ``run`` / ``stream``) regardless of
    the preset's execution mode.
    """
    d = get_deployment(preset)
    cfg = d.config if mesh is None else d.config.replace(mesh=mesh)
    return Session(cfg, vocab=vocab, kb=kb).register(query)
