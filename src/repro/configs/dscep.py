"""DSCEP deployment configs — the paper's own 'architecture'.

Where the 10 LM configs describe neural stacks, these presets describe SCEP
pipeline deployments: window geometry (paper §4.4: "window size is a maximum
of 1000 RDF triples"), engine capacities, KB-access method and the
parallelism mode.  ``build_runtime`` assembles the full runtime from a
preset, a query and a KB, mirroring how ``launch/dscep_run.py`` deploys.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.runtime import RuntimeConfig


@dataclasses.dataclass(frozen=True)
class DSCEPDeployment:
    name: str
    runtime: RuntimeConfig
    decomposed: bool = True        # inter-operator parallelism (Fig. 4)
    description: str = ""


_PRESETS: Dict[str, DSCEPDeployment] = {}


def register_deployment(d: DSCEPDeployment) -> DSCEPDeployment:
    _PRESETS[d.name] = d
    return d


# the paper's evaluation setup (§4.4): 1000-triple windows, scan KB access
register_deployment(DSCEPDeployment(
    name="paper-eval",
    runtime=RuntimeConfig(window_capacity=1000, max_windows=8,
                          bind_cap=4096, scan_cap=1024, out_cap=4096,
                          kb_method="scan"),
    decomposed=True,
    description="Paper §4.4 settings: 1000-triple windows, C-SPARQL-style "
                "attached-KB scans, automatic Fig. 4 decomposition.",
))

# SERVICE-style endpoint access (the paper's second measured method)
register_deployment(DSCEPDeployment(
    name="paper-eval-subquery",
    runtime=RuntimeConfig(window_capacity=1000, max_windows=8,
                          bind_cap=4096, scan_cap=1024, out_cap=4096,
                          kb_method="probe"),
    decomposed=True,
    description="Paper §4.4 settings with SPARQL-subquery (indexed endpoint) "
                "KB access.",
))

# container-scale smoke (tests/examples)
register_deployment(DSCEPDeployment(
    name="smoke",
    runtime=RuntimeConfig(window_capacity=128, max_windows=4,
                          bind_cap=1024, scan_cap=128, out_cap=1024),
    decomposed=True,
    description="Reduced capacities for CPU smoke runs.",
))

# monolithic baseline (paper Table 2)
register_deployment(DSCEPDeployment(
    name="monolithic",
    runtime=RuntimeConfig(window_capacity=1000, max_windows=8,
                          bind_cap=4096, scan_cap=1024, out_cap=4096),
    decomposed=False,
    description="Single-operator execution against the full KB (Table 2 "
                "baseline).",
))


def get_deployment(name: str) -> DSCEPDeployment:
    return _PRESETS[name]


def deployments() -> Dict[str, DSCEPDeployment]:
    return dict(_PRESETS)


def build_runtime(preset: str, query, kb, vocab, mesh=None):
    """Assemble the runtime a launcher would deploy for ``preset``."""
    from repro.core.planner import decompose
    from repro.core.runtime import DSCEPRuntime, MonolithicRuntime

    d = get_deployment(preset)
    if d.decomposed:
        return DSCEPRuntime(decompose(query, vocab), kb, vocab, d.runtime,
                            mesh=mesh)
    return MonolithicRuntime(query, kb, d.runtime)
