"""H2O-Danube 1.8B [arXiv:2401.16818; hf]: llama+mistral mix, SWA."""
from .base import ModelConfig, register


@register("h2o-danube-1.8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b",
        family="dense",
        num_layers=24,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        head_dim=80,
        d_ff=6912,
        vocab_size=32000,
        swa_window=4096,
        rope_theta=10_000.0,
        supports_long_context=True,        # SWA
    )
