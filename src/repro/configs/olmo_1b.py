"""OLMo-1B [arXiv:2402.00838; hf]: non-parametric LayerNorm."""
from .base import ModelConfig, register


@register("olmo-1b")
def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b",
        family="dense",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=8192,
        vocab_size=50304,
        norm="layernorm_nonparam",
        rope_theta=10_000.0,
        tie_embeddings=True,
        supports_long_context=False,
    )
