"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B]: dense with MLA."""
from .base import MLAConfig, ModelConfig, register


@register("minicpm3-4b")
def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        num_layers=62,
        d_model=2560,
        num_heads=40,
        num_kv_heads=40,
        head_dim=96,                       # nope 64 + rope 32
        d_ff=6400,
        vocab_size=73448,
        rope_theta=10_000.0,
        mla=MLAConfig(kv_lora_rank=256, q_lora_rank=768,
                      rope_head_dim=32, nope_head_dim=64, v_head_dim=64),
        supports_long_context=False,
    )
