"""Logical-axis -> mesh-axis partitioning rules.

Model code annotates every parameter with logical axes
(:mod:`repro.models.common`); this module turns those into
``PartitionSpec``s for a concrete mesh with **divisibility-aware fallback**:
a logical axis only claims a mesh axis if the dimension divides evenly and
the mesh axis is not already used by an earlier dimension of the same tensor.
That one rule lets the same model code shard
 * TP (heads / ff / vocab on ``model``),
 * EP (experts on ``model`` — falls back to ff-sharding when num_experts
   doesn't divide, e.g. Mixtral's 8 experts on a 16-way axis),
 * ZeRO-1 (optimizer state over ``data``),
on any mesh shape, including the multi-pod ``(pod, data, model)`` mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import common as C

# priority list of mesh axes per logical axis
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    C.VOCAB: ("model",),
    C.HEADS: ("model",),
    C.KV_HEADS: ("model",),
    C.FF: ("model",),
    C.EXPERT: ("model",),
    C.SSM_INNER: ("model",),
    C.LORA: (),
    C.EMBED: (),           # keep d_model replicated (row dim of col-parallel)
    C.HEAD_DIM: (),
    C.SSM_STATE: (),
    C.LAYERS: (),          # scan axis never sharded
}

# pure data parallelism: nothing claims `model`; the batch claims it instead
DP_RULES: Dict[str, Tuple[str, ...]] = {k: () for k in DEFAULT_RULES}

# expert parallelism only: expert (and vocab — the other giant table) state
# stays partitioned over `model`, dense compute goes data-parallel
EP_RULES: Dict[str, Tuple[str, ...]] = {
    **DP_RULES, C.EXPERT: ("model",), C.VOCAB: ("model",),
}


@dataclasses.dataclass(frozen=True)
class ShardingProfile:
    """A named end-to-end sharding strategy (the §Perf hillclimb lever)."""

    name: str
    rules: Dict[str, Tuple[str, ...]]
    batch_axes: Tuple[str, ...]
    zero1_axes: Tuple[str, ...]


PROFILES: Dict[str, ShardingProfile] = {
    # paper-faithful baseline: Megatron-style TP over `model`, DP over
    # pod x data (the "divide the state across machines" default)
    "tp": ShardingProfile("tp", DEFAULT_RULES, ("pod", "data"),
                          ("pod", "data")),
    # pure DP: replicate params, shard batch over every axis, ZeRO-1 the
    # optimizer state over all axes (small models: kills TP collectives)
    "dp": ShardingProfile("dp", DP_RULES, ("pod", "data", "model"),
                          ("pod", "data", "model")),
    # EP + DP: experts/vocab partitioned (the KB-partition analogue), dense
    # layers data-parallel
    "ep": ShardingProfile("ep", EP_RULES, ("pod", "data", "model"),
                          ("pod", "data", "model")),
}


def spec_for(
    axes: Tuple[Optional[str], ...],
    shape: Tuple[int, ...],
    mesh: Mesh,
    rules: Optional[Dict[str, Tuple[str, ...]]] = None,
) -> P:
    rules = rules or DEFAULT_RULES
    used = set()
    out = []
    for dim, ax in zip(shape, axes):
        pick = None
        for cand in rules.get(ax or "", ()):
            if cand in used or cand not in mesh.shape:
                continue
            if dim % mesh.shape[cand] == 0:
                pick = cand
                used.add(cand)
                break
        out.append(pick)
    return P(*out)


def param_shardings(
    spec_axes: Dict[str, Tuple[Optional[str], ...]],
    params,
    mesh: Mesh,
    rules: Optional[Dict[str, Tuple[str, ...]]] = None,
):
    """NamedSharding pytree matching ``params`` via the recorded ParamSpec.

    Paths in ``spec_axes`` are '/'-joined from init; we rebuild them by
    walking the pytree with jax.tree_util key paths.
    """

    def path_str(kp) -> str:
        parts = []
        for k in kp:
            if isinstance(k, jax.tree_util.DictKey):
                parts.append(str(k.key))
            else:
                parts.append(str(k))
        # init recorded paths like "blocks/sub0/attn/wq"; pytree paths include
        # the same keys, so join and match.
        return "/".join(parts)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    shardings = []
    for kp, leaf in flat:
        path = path_str(kp)
        axes = spec_axes.get(path)
        if axes is None:
            # unknown leaf: replicate
            shardings.append(NamedSharding(mesh, P()))
            continue
        if len(axes) != leaf.ndim:
            # stacked (scan) leaves recorded without/with LAYERS mismatch
            if len(axes) == leaf.ndim - 1:
                axes = (C.LAYERS,) + tuple(axes)
            else:
                axes = tuple([None] * leaf.ndim)
        shardings.append(NamedSharding(mesh, spec_for(tuple(axes), leaf.shape, mesh, rules)))
    return jax.tree_util.tree_unflatten(treedef, shardings)


def dp_axes_for(mesh: Mesh, dim: int,
                batch_axes: Tuple[str, ...] = ("pod", "data")) -> Tuple[str, ...]:
    """Longest prefix of data-parallel axes whose product divides ``dim``."""
    axes = []
    prod = 1
    for a in batch_axes:
        if a in mesh.shape and dim % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    return tuple(axes)


def batch_sharding(mesh: Mesh, shape: Tuple[int, ...], batch_dim: int = 0,
                   batch_axes: Tuple[str, ...] = ("pod", "data")):
    """Shard the batch dim over every data-parallel axis that divides it.

    Divisibility-aware: a batch of 1 (``long_500k``) stays replicated — the
    sequence-sharded cache carries the parallelism instead.  ``batch_dim``
    handles inputs whose batch is not dim0 (M-RoPE ``positions [3, B, T]``).
    """
    axes = dp_axes_for(mesh, shape[batch_dim], batch_axes)
    spec = [None] * len(shape)
    if axes:
        spec[batch_dim] = axes if len(axes) > 1 else axes[0]
    return NamedSharding(mesh, P(*spec))


def cache_shardings(cfg, caches, mesh: Mesh, seq_axis: str = "data"):
    """Decode-cache shardings for the stacked ``[period, B, ...]`` layout.

    * batch (dim 1) over the data axes when divisible — SPMD decode;
    * else the sequence dim (dim 2 of ``[n, B, S, ...]`` attention caches)
      over ``data`` — context parallelism for the batch=1 ``long_500k`` cell;
    * kv-heads of full KV caches ``[n, B, S, Hk, D]`` over ``model`` when
      divisible (TP'd attention reads its local heads only).
    """
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    data_size = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1

    def one(leaf):
        if leaf.ndim <= 1:               # stacked scalar state, e.g. len [n]
            return NamedSharding(mesh, P())
        spec = [None] * leaf.ndim
        b = leaf.shape[1]
        if data_axes and b % data_size == 0 and b >= data_size:
            spec[1] = data_axes if len(data_axes) > 1 else data_axes[0]
        elif leaf.ndim >= 4 and seq_axis in mesh.shape:
            # [n, B, S, ...] with tiny batch: shard the sequence dim
            if leaf.shape[2] % mesh.shape[seq_axis] == 0:
                spec[2] = seq_axis
        if leaf.ndim == 5 and "model" in mesh.shape:
            # [n, B, S, Hk, D]: kv heads over model if divisible
            if leaf.shape[3] % mesh.shape["model"] == 0:
                spec[3] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, caches)
