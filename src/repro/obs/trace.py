"""Span-based host tracer with device-time fencing and a compile split.

Latency attribution in a JAX pipeline has two classic traps:

1. **Async dispatch** — ``jax.jit`` calls return before the device finishes,
   so a naive ``perf_counter`` pair around a stage times the *dispatch*, not
   the work.  A span can therefore carry a **fence**: a pytree of device
   arrays that is ``block_until_ready``-ed at span exit, so the recorded
   duration covers the device work that produced it.  Fencing serializes
   stages that would otherwise overlap — it changes *timing*, never
   *results* — which is exactly what per-stage attribution needs (the same
   trade MaxText's decode microbenchmarks make).
2. **JIT warmup** — the first execution of every jitted step pays tracing +
   XLA compilation, often orders of magnitude above steady state.  The
   tracer keeps the **first sample of every span path separate**
   (``first_s``) and aggregates only subsequent samples into the steady
   statistics, so one compile never pollutes a latency table.

Spans nest: a span opened while another is active records under the path
``outer/inner``, giving per-stage attribution inside a chunk-level span.

The tracer can also bridge into ``jax.profiler``: ``annotations=True`` wraps
every span in a :class:`jax.profiler.TraceAnnotation` (visible on the XLA
trace timeline), and ``profiler_dir=...`` brackets the stream between
``jax.profiler.start_trace``/``stop_trace`` via
:meth:`Tracer.start_profiler`/:meth:`Tracer.stop_profiler`.  Both are
best-effort: absent profiler support degrades to plain host spans.

This module deliberately imports nothing from :mod:`repro.core` — it is a
leaf utility the core wires in (see ``ExecutionConfig(trace=...)``), and
with tracing off the runtimes never touch it on the hot path.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Dict, List, Optional, Union

import jax


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Frozen observability knobs (hashable, safe as a jit-static field).

    ``spans``       — record host wall-time spans;
    ``metrics``     — collect device-side engine metrics (binding/scan
                      occupancy high-water, probe saturation, retractions)
                      in the jitted step's carry;
    ``fence``       — ``block_until_ready`` span fences so durations cover
                      device work (serializes overlapped stages);
    ``annotations`` — wrap spans in ``jax.profiler.TraceAnnotation``;
    ``profiler_dir``— directory for ``jax.profiler.start_trace`` output
                      (enables :meth:`Tracer.start_profiler`).
    """

    spans: bool = True
    metrics: bool = True
    fence: bool = True
    annotations: bool = False
    profiler_dir: Optional[str] = None


def resolve_trace(trace: Union[None, bool, TraceConfig]) -> Optional[TraceConfig]:
    """Normalize the ``ExecutionConfig.trace`` field: None/False = off,
    True = default :class:`TraceConfig`, a config passes through."""
    if trace is None or trace is False:
        return None
    if trace is True:
        return TraceConfig()
    if isinstance(trace, TraceConfig):
        return trace
    raise TypeError(
        "trace= takes None/False, True, or a TraceConfig, got %r"
        % type(trace).__name__)


class _SpanHandle:
    """The in-flight span: ``fence(value)`` marks device results to block on
    at exit, so the span's duration attributes device time to this stage."""

    __slots__ = ("_fence",)

    def __init__(self) -> None:
        self._fence: Any = None

    def fence(self, value: Any) -> Any:
        self._fence = value
        return value


class _NullSpan:
    """No-op handle returned when tracing is off (keeps call sites branch-free)."""

    __slots__ = ()

    def fence(self, value: Any) -> Any:
        return value


_NULL_SPAN = _NullSpan()


@contextlib.contextmanager
def _null_span():
    yield _NULL_SPAN


def span_or_null(tracer: Optional["Tracer"], name: str, **meta):
    """Span on ``tracer`` when present, else a no-op span context — lets
    runtime call sites stay branch-free whether or not tracing is wired."""
    if tracer is None:
        return _null_span()
    return tracer.span(name, **meta)


class Tracer:
    """Records nested host spans with per-path compile/steady separation.

    Samples are kept as raw duration lists per span path (sample 0 is the
    first call — compile-inclusive for spans around jitted steps); ``stats``
    folds them into JSON-ready aggregates.
    """

    def __init__(self, config: Optional[TraceConfig] = None):
        self.config = config if config is not None else TraceConfig()
        self._samples: Dict[str, List[float]] = {}
        self._meta: Dict[str, Dict[str, Any]] = {}
        self._stack: List[str] = []
        self._profiling = False

    @property
    def enabled(self) -> bool:
        return self.config.spans

    # -- recording -----------------------------------------------------------
    def span(self, name: str, **meta):
        """Context manager for one timed span; nests under the active span.

        Usage::

            with tracer.span("sink") as sp:
                out = sink_step(...)
                sp.fence(out)        # block on the device result at exit
        """
        if not self.config.spans:
            return _null_span()
        return self._span_cm(name, meta)

    @contextlib.contextmanager
    def _span_cm(self, name: str, meta: Dict[str, Any]):
        path = "/".join(self._stack + [name])
        self._stack.append(name)
        handle = _SpanHandle()
        ann = None
        if self.config.annotations:
            try:
                ann = jax.profiler.TraceAnnotation(path)
                ann.__enter__()
            except Exception:
                ann = None
        t0 = time.perf_counter()
        try:
            yield handle
        finally:
            if handle._fence is not None and self.config.fence:
                jax.block_until_ready(handle._fence)
            dur = time.perf_counter() - t0
            if ann is not None:
                ann.__exit__(None, None, None)
            self._stack.pop()
            self._samples.setdefault(path, []).append(dur)
            if meta:
                self._meta.setdefault(path, {}).update(meta)

    # -- jax.profiler bridge ------------------------------------------------
    def start_profiler(self) -> bool:
        """Begin a ``jax.profiler`` trace into ``config.profiler_dir``
        (best-effort; returns whether a trace actually started)."""
        if not self.config.profiler_dir or self._profiling:
            return False
        try:
            jax.profiler.start_trace(self.config.profiler_dir)
            self._profiling = True
        except Exception:
            return False
        return True

    def stop_profiler(self) -> None:
        if self._profiling:
            try:
                jax.profiler.stop_trace()
            finally:
                self._profiling = False

    # -- aggregation ---------------------------------------------------------
    def reset(self) -> None:
        self._samples.clear()
        self._meta.clear()

    def stats(self) -> Dict[str, Dict[str, Any]]:
        """Per-path aggregates with the compile/steady split.

        ``first_s`` is the path's first sample (compile-inclusive when the
        span wraps a jitted step's first execution); ``steady`` aggregates
        every later sample.  All plain floats/ints — JSON-ready.
        """
        out: Dict[str, Dict[str, Any]] = {}
        for path, samples in self._samples.items():
            steady = samples[1:]
            entry: Dict[str, Any] = {
                "count": len(samples),
                "first_s": samples[0],
                "steady": {
                    "count": len(steady),
                    "total_s": sum(steady),
                    "mean_s": (sum(steady) / len(steady)) if steady else 0.0,
                    "min_s": min(steady) if steady else 0.0,
                    "max_s": max(steady) if steady else 0.0,
                },
            }
            if path in self._meta:
                entry["meta"] = dict(self._meta[path])
            out[path] = entry
        return out
