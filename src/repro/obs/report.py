"""Structured and human-readable reporting over tracer spans and metrics.

Consumes the plain-dict surfaces the rest of the subsystem produces —
``Tracer.stats()`` span aggregates, finalized per-operator metric counters
(:func:`repro.obs.metrics.finalize_stats` / :func:`~repro.obs.metrics.saturation`)
and the planner's ``explain`` artifact — and renders them as one JSON
payload (:func:`to_json`) or terminal tables (:func:`format_stage_table`,
:func:`format_metrics_table`, :func:`format_explain`).

:func:`bottleneck_stage` is the headline consumer: given span stats it
names the stage with the largest steady-state total — the measured answer
to "where does the pipelined runtime actually spend its time".
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Mapping, Optional, Sequence

from .metrics import CATALOG, RECOVERY_CATALOG, saturation


def _table(title: str, headers: Sequence[str], rows: List[List[Any]]) -> str:
    widths = [
        max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows else len(str(h))
        for i, h in enumerate(headers)
    ]

    def fmt(vals):
        return " | ".join(str(v).ljust(w) for v, w in zip(vals, widths))

    sep = "-+-".join("-" * w for w in widths)
    return "\n".join([f"== {title} ==", fmt(headers), sep]
                     + [fmt(r) for r in rows])


def _ms(x: float) -> str:
    return f"{x * 1e3:.1f}"


def bottleneck_stage(span_stats: Mapping[str, Dict[str, Any]],
                     prefix: Optional[str] = None) -> Optional[str]:
    """The span path with the largest steady-state total time.

    ``prefix`` restricts candidates (e.g. ``"stage"`` for the pipelined
    runtime's per-stage spans, skipping the enclosing chunk span).  Paths
    without steady samples (only a compile-inclusive first call) compete on
    that first sample so a single-pass trace still answers.
    """
    best, best_t = None, -1.0
    for path, s in span_stats.items():
        if prefix is not None and not path.split("/")[-1].startswith(prefix):
            continue
        t = s["steady"]["total_s"] if s["steady"]["count"] else s["first_s"]
        if t > best_t:
            best, best_t = path, t
    return best


def format_stage_table(span_stats: Mapping[str, Dict[str, Any]],
                       title: str = "stage latency") -> str:
    """Per-stage latency table with compile time in its own column."""
    rows = []
    for path in sorted(span_stats):
        s = span_stats[path]
        st = s["steady"]
        rows.append([
            path, s["count"], _ms(s["first_s"]),
            _ms(st["mean_s"]), _ms(st["min_s"]), _ms(st["max_s"]),
            _ms(st["total_s"]),
        ])
    return _table(title, ["stage", "samples", "first (compile) ms",
                          "steady mean ms", "min ms", "max ms", "total ms"],
                  rows)


def format_metrics_table(op_metrics: Mapping[str, Dict[str, Any]],
                         title: str = "engine metrics") -> str:
    """Per-operator counter/gauge table with saturation percentages."""
    rows = []
    for op in sorted(op_metrics):
        entry = op_metrics[op]
        counters = entry.get("counters", {})
        sat = entry.get("saturation", {})
        for key in sorted(counters):
            pct = ("%.0f%%" % (sat[key] * 100)) if key in sat else "--"
            rows.append([op, key, counters[key], pct,
                         CATALOG.get(key, "")])
    return _table(title, ["operator", "metric", "value", "saturation",
                          "meaning"], rows)


def format_explain(artifact: Mapping[str, Any]) -> str:
    """Render a planner ``explain`` artifact as per-operator step tables."""
    lines = [
        "EXPLAIN %s (mode=%s, kb_method=%s)"
        % (artifact.get("query"), artifact.get("mode"),
           artifact.get("kb_method")),
    ]
    for op_name, op in artifact.get("operators", {}).items():
        caps = op.get("caps", {})
        lines.append("")
        lines.append(
            "operator %s  (kb_rows=%s, scan_cap=%s, bind_cap=%s, out_cap=%s)"
            % (op_name, op.get("kb_rows", "--"), caps.get("scan_cap"),
               caps.get("bind_cap"), caps.get("out_cap")))
        rows = []
        for i, step in enumerate(op.get("steps", [])):
            est = step.get("est_fanout")
            rows.append([
                i, step["step"], step.get("pattern", ""),
                step.get("method", "--"),
                step.get("k_max", "--"),
                ("%.1f" % est) if est is not None else "--",
            ])
        lines.append(_table("join order", ["#", "step", "pattern", "method",
                                           "k_max", "est fan-out"], rows))
    return "\n".join(lines)


def format_recovery_table(recovery: Mapping[str, Any],
                          title: str = "recovery") -> str:
    """Render ``last_stats["recovery"]`` as a counter table.

    Injected-fault counts appear as ``injected:<kind>`` rows (with the
    scheduled count alongside, so a divergence — an event that never found
    its stage/chunk — is visible); the ladder counters carry their
    :data:`~repro.obs.metrics.RECOVERY_CATALOG` legends."""
    rows: List[List[Any]] = []
    scheduled = recovery.get("scheduled", {})
    for kind in sorted(recovery.get("injected", {})):
        fired = recovery["injected"][kind]
        want = scheduled.get(kind, 0)
        if fired or want:
            rows.append(["injected:%s" % kind, fired,
                         "scheduled %d" % want])
    for key in sorted(RECOVERY_CATALOG):
        if key in recovery:
            rows.append([key, recovery[key], RECOVERY_CATALOG[key]])
    degraded = recovery.get("degraded_chunks", [])
    rows.append(["degraded_chunks", len(degraded),
                 ("seqs %s (lossless monolithic fallback)" % degraded)
                 if degraded else "none"])
    return _table(title, ["event", "count", "meaning"], rows)


def to_json(last_stats: Mapping[str, Any],
            explain: Optional[Mapping[str, Any]] = None) -> Dict[str, Any]:
    """One JSON-ready observability payload: the uniform ``last_stats``
    surface (spans, per-operator metrics, channels, overflow) plus an
    optional planner explain artifact."""
    payload = dict(last_stats)
    if explain is not None:
        payload["explain"] = dict(explain)
    # round-trip through json to guarantee the payload is serializable
    return json.loads(json.dumps(payload, default=float))


def attach_saturation(counters: Dict[str, int],
                      caps: Mapping[str, int]) -> Dict[str, Any]:
    """Bundle finalized counters with their capacities and saturation —
    the per-operator entry shape ``format_metrics_table`` consumes."""
    return {
        "counters": counters,
        "caps": dict(caps),
        "saturation": saturation(counters, caps),
    }
