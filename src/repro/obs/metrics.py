"""Counters and gauges for quantities the engine computes and discards.

The engine's fixed-capacity design means every step *already* knows the
numbers an operator would want on a dashboard — how full the binding table
got versus ``bind_cap``, how wide the widest probe range was versus the
derived ``k_max``, how many rows an eager retraction killed — and then
throws them away.  With ``TraceConfig.metrics`` on, the instrumented engine
paths (``stats=`` in :mod:`repro.core.engine`) emit them as a flat
``{key: int32 scalar}`` dict per step, and the runtimes fold those dicts
into **device-resident accumulators** exactly like the existing overflow
counters: per-chunk merging is a couple of fused scalar ops dispatched
asynchronously, and the host syncs once when a report is built — enabling
metrics adds no host round-trips to the steady path.

Key convention (the merge rule is in the name, so accumulators need no
schema):

* ``hw_*`` — high-water gauges, merged with ``max`` (e.g. ``hw_bind``,
  ``hw_scan``, ``hw_probe_k``);
* ``n_*``  — monotone counters, merged with ``+`` (e.g. ``n_windows``,
  ``n_retract``).

The same convention reduces a vmapped per-window stats dict to chunk
scalars (:func:`reduce_stats`) and merges chunk scalars into lifetime
accumulators (:func:`merge_stats`).  :func:`saturation` relates the
high-water marks to their configured capacities — the number that says
"this stage is about to clip" before overflow ever fires.

Like :mod:`repro.obs.trace`, this module imports nothing from
:mod:`repro.core`.
"""
from __future__ import annotations

from typing import Any, Dict, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

# metric catalog: key -> what the value measures (docs/observability.md
# mirrors this table; report.py uses it for human-readable legends)
CATALOG: Dict[str, str] = {
    "hw_bind": "binding-table occupancy high-water (rows, vs bind_cap)",
    "hw_scan": "pattern-scan result high-water (rows, vs scan_cap)",
    "hw_out": "pre-publish constructed-output high-water (rows, vs out_cap)",
    "hw_probe_k": "widest KB probe range encountered (rows, vs k_max)",
    "n_windows": "windows finalized (valid windows published)",
    "n_retract": "bindings eagerly retracted by the delta evaluator",
}

# recovery-counter legend (repro.core.recovery): host-side facts surfaced
# through last_stats["recovery"], not device accumulators — listed here so
# report.py renders them with the same one-line meanings as engine metrics
RECOVERY_CATALOG: Dict[str, str] = {
    "retries": "stage dispatches retried after a timeout (with backoff)",
    "restarts": "checkpoint restores (crash / exhausted retries / desync)",
    "replayed": "chunks re-fed from the replay buffer during restores",
    "deduped": "replayed outputs discarded by sequence-number dedup",
    "checkpoints": "checkpoints taken (cadence: checkpoint_every emissions)",
    "checkpoint_bytes": "bytes in the latest checkpoint's device snapshots",
    "rejected": "chunks refused by the ingest validation gate",
    "corrupt_recovered": "in-transit corruptions healed from the replay buffer",
}

# the capacity each high-water gauge saturates against
_SATURATES_AGAINST = {
    "hw_bind": "bind_cap",
    "hw_scan": "scan_cap",
    "hw_out": "out_cap",
    "hw_probe_k": "k_max",
}


def _is_high_water(key: str) -> bool:
    return key.startswith("hw_")


def stat_max(stats: Optional[Dict[str, Any]], key: str, value) -> None:
    """Raise the high-water gauge ``key`` to at least ``value`` (no-op dict
    absent — the engine's stats-off path passes ``None``)."""
    if stats is None:
        return
    stats[key] = jnp.maximum(stats[key], value) if key in stats else value


def stat_add(stats: Optional[Dict[str, Any]], key: str, value) -> None:
    """Add ``value`` to the counter ``key``."""
    if stats is None:
        return
    stats[key] = stats[key] + value if key in stats else value


def reduce_stats(stats: Mapping[str, jax.Array]) -> Dict[str, jax.Array]:
    """Collapse vmapped per-window stats ``[W]`` to chunk scalars (max for
    ``hw_*``, sum for ``n_*``) — still on device."""
    return {
        k: (jnp.max(v) if _is_high_water(k) else jnp.sum(v))
        for k, v in stats.items()
    }


def split_stats(stats: Mapping[str, jax.Array], index: int) -> Dict[str, jax.Array]:
    """Select one lane of a ``[Q]``-leading-axis stats dict.

    The serving layer's cohort step vmaps one plan over a per-query axis,
    so every chunk scalar comes back as a ``[Q]`` vector; this slices out
    query ``index``'s lane for per-query attribution (still on device)."""
    return {k: v[index] for k, v in stats.items()}


def merge_stats(acc: Dict[str, jax.Array], stats: Mapping[str, Any]) -> None:
    """Fold one chunk's stat scalars into a lifetime accumulator dict,
    in place (device-side when values are device arrays)."""
    for k, v in stats.items():
        if k not in acc:
            acc[k] = v
        elif _is_high_water(k):
            acc[k] = jnp.maximum(acc[k], v)
        else:
            acc[k] = acc[k] + v


def finalize_stats(acc: Mapping[str, Any]) -> Dict[str, int]:
    """Sync an accumulator dict to plain ints (the one host round-trip)."""
    return {k: int(np.asarray(v)) for k, v in acc.items()}


def saturation(counters: Mapping[str, int],
               caps: Mapping[str, int]) -> Dict[str, float]:
    """High-water marks as a fraction of their configured capacity.

    ``caps`` maps capacity names (``bind_cap``, ``scan_cap``, ``out_cap``,
    ``k_max``) to their values; gauges whose capacity is absent or zero are
    skipped.  1.0 means the stage ran exactly full — the next row would
    have tripped overflow.
    """
    out: Dict[str, float] = {}
    for key, value in counters.items():
        cap_name = _SATURATES_AGAINST.get(key)
        if cap_name and caps.get(cap_name):
            out[key] = float(value) / float(caps[cap_name])
    return out
