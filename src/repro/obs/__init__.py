"""Observability: span tracing, engine metrics and plan EXPLAIN reporting.

Wire-in point: ``ExecutionConfig(trace=True)`` (or a custom
:class:`~repro.obs.trace.TraceConfig`) — every runtime then records
per-stage spans and device-side engine metrics, surfaced uniformly through
``RegisteredQuery.last_stats`` and ``RegisteredQuery.explain()``.  With
tracing off (the default) the runtimes compile the exact pre-observability
programs — pinned by tests/test_obs.py.
"""
from .trace import TraceConfig, Tracer, resolve_trace, span_or_null
from .metrics import (
    CATALOG, finalize_stats, merge_stats, reduce_stats, saturation,
    stat_add, stat_max,
)
from .report import (
    attach_saturation, bottleneck_stage, format_explain,
    format_metrics_table, format_stage_table, to_json,
)

__all__ = [
    "TraceConfig", "Tracer", "resolve_trace", "span_or_null",
    "CATALOG", "finalize_stats", "merge_stats", "reduce_stats",
    "saturation", "stat_add", "stat_max",
    "attach_saturation", "bottleneck_stage", "format_explain",
    "format_metrics_table", "format_stage_table", "to_json",
]
