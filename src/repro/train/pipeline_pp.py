"""Pipeline parallelism (GPipe schedule) as a GSPMD-native rolling pipeline.

The classic PP implementations drive per-stage processes with explicit
send/recv.  The JAX-native formulation keeps everything SPMD: stage
parameters are STACKED on a leading ``[S, ...]`` axis (sharded over a mesh
axis — ``pod`` for inter-pod pipelining, or a dedicated ``stage`` axis), the
in-flight microbatch activations live in a ``[S, mb, ...]`` rolling buffer
sharded the same way, and each tick

    1. rolls the buffer one stage forward (``jnp.roll`` on the stage axis —
       XLA lowers this to ``collective-permute`` between stage owners),
    2. feeds the next microbatch into stage 0,
    3. applies every stage to its current activation **in parallel** (one
       vmap over the stacked stage axis).

``M`` microbatches drain in ``M + S - 1`` ticks — the GPipe schedule with
bubble fraction ``(S-1)/(M+S-1)``; utilization and bubble are reported by
:func:`pipeline_stats`.  On one device the roll is a copy and results are
bit-identical to the sequential stack — property-tested in
tests/test_pipeline_pp.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    num_stages: int
    stage_axis: Optional[str] = None     # mesh axis owning the stage dim


def pipeline_stats(num_stages: int, num_microbatches: int) -> dict:
    ticks = num_microbatches + num_stages - 1
    bubble = (num_stages - 1) / ticks
    return {
        "ticks": ticks,
        "bubble_fraction": bubble,
        "utilization": num_microbatches / ticks,
    }


def _pin(x, axes):
    if axes is None:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, P(axes, *([None] * (x.ndim - 1))))


def pipeline_apply(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stacked_params: Any,                  # pytree with leading [S, ...] axis
    microbatches: jax.Array,              # [M, mb, ...]
    cfg: PipelineConfig,
) -> jax.Array:
    """Run ``M`` microbatches through ``S`` pipeline stages.

    ``stage_fn(params_s, x) -> y`` must preserve the activation shape
    (classic transformer-stage contract).  Returns ``[M, mb, ...]`` outputs
    in microbatch order.
    """
    S = cfg.num_stages
    M = microbatches.shape[0]
    x_shape = microbatches.shape[1:]
    axes = cfg.stage_axis

    state = _pin(jnp.zeros((S,) + x_shape, microbatches.dtype), axes)
    pad = jnp.zeros((1,) + x_shape, microbatches.dtype)
    # feed schedule: microbatch t enters at tick t; junk drains after M
    feeds = jnp.concatenate([microbatches,
                             jnp.broadcast_to(pad, (S - 1,) + x_shape)], 0) \
        if S > 1 else microbatches

    def tick(state, feed):
        # advance the pipeline: stage s takes stage s-1's output
        # (collective-permute when the stage axis is mesh-sharded)
        state = jnp.roll(state, 1, axis=0)
        state = state.at[0].set(feed)
        state = _pin(state, axes)
        state = jax.vmap(stage_fn)(stacked_params, state)   # all stages step
        return _pin(state, axes), state[S - 1]

    _, tail = jax.lax.scan(tick, state, feeds)              # [M+S-1, mb, ...]
    return tail[S - 1:] if S > 1 else tail


def stack_stages(param_list) -> Any:
    """Stack per-stage parameter pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *param_list)


def sequential_reference(stage_fn, stacked_params, microbatches) -> jax.Array:
    """Oracle: apply the stages back-to-back per microbatch (no pipeline)."""
    S = jax.tree.leaves(stacked_params)[0].shape[0]

    def one(x):
        for s in range(S):
            p_s = jax.tree.map(lambda a: a[s], stacked_params)
            x = stage_fn(p_s, x)
        return x

    return jax.vmap(one)(microbatches)
