from . import checkpoint, elastic, grad_compress, optimizer, train_loop  # noqa: F401
