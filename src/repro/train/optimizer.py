"""AdamW from scratch (no optax in this environment) with grad clipping,
warmup+cosine schedule, and ZeRO-1-ready f32 state.

The optimizer state mirrors the parameter pytree (m, v in float32 regardless
of param dtype — bf16 training with f32 master statistics), so the sharding
layer can lay m/v out exactly like the weights, or additionally shard them
over the ``data`` axis (ZeRO-1) via :func:`zero1_shardings`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * (step + 1.0) / max(1, cfg.warmup_steps)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps),
        0.0, 1.0,
    )
    cos = cfg.peak_lr * (
        cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    )
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(
    cfg: AdamWConfig, grads, state: OptState, params,
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, state.step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr,
    }


# --------------------------------------------------------------------------
# ZeRO-1: shard optimizer moments over the data axis
# --------------------------------------------------------------------------

def zero1_shardings(param_shardings, params, mesh: Mesh,
                    data_axes: Tuple[str, ...] = ("pod", "data")):
    """Moment shardings = param shardings + the data axes on the first
    unsharded, divisible dimension (classic optimizer-state sharding)."""
    axes = tuple(a for a in data_axes if a in mesh.shape)
    dp = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1

    def one(sh: NamedSharding, p):
        if dp <= 1:
            return sh
        spec = list(sh.spec) + [None] * (p.ndim - len(sh.spec))
        for d in range(p.ndim):
            if spec[d] is None and p.shape[d] % dp == 0 and p.shape[d] >= dp:
                spec[d] = axes if len(axes) > 1 else axes[0]
                return NamedSharding(mesh, P(*spec))
        return sh

    return jax.tree.map(one, param_shardings, params)


def opt_state_shardings(param_shardings, params, mesh: Mesh, zero1: bool = True,
                        data_axes: Tuple[str, ...] = ("pod", "data")):
    moment = (
        zero1_shardings(param_shardings, params, mesh, data_axes)
        if zero1 else param_shardings
    )
    return OptState(
        step=NamedSharding(mesh, P()),
        m=moment,
        v=moment,
    )
