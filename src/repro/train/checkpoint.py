"""Fault-tolerant checkpointing: atomic, sharded-aware, async, elastic.

Design for 1000-node runs:

* **atomic commit** — write into ``step_XXXXXX.tmp`` then ``os.rename`` so a
  crash mid-write never corrupts the latest checkpoint;
* **manifest** — step, pytree structure, per-leaf shape/dtype and the mesh
  the run used, so restore can *re-shard elastically* onto a different mesh;
* **async** — leaves are fetched to host and written by a background thread;
  the train loop only blocks on the previous save (one-deep pipeline);
* **retention** — keep the newest K checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        parts = []
        for k in kp:
            if isinstance(k, jax.tree_util.DictKey):
                parts.append(str(k.key))
            elif isinstance(k, jax.tree_util.SequenceKey):
                parts.append(str(k.idx))
            elif isinstance(k, jax.tree_util.GetAttrKey):
                parts.append(str(k.name))
            else:
                parts.append(str(k))
        out.append(("/".join(parts), leaf))
    return out


def _leaf_filename(path: str) -> str:
    return path.replace("/", "__") + ".npy"


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._executor = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree, mesh_shape: Optional[Dict[str, int]] = None,
             blocking: bool = False):
        """Snapshot ``tree`` at ``step``.  Fetches to host synchronously (cheap
        vs device compute), writes asynchronously."""
        self.wait()
        host_leaves = [
            (path, np.asarray(jax.device_get(leaf)))
            for path, leaf in _flatten_with_paths(tree)
        ]
        manifest = {
            "step": int(step),
            "mesh_shape": mesh_shape or {},
            "leaves": {
                path: {"shape": list(arr.shape), "dtype": str(arr.dtype)}
                for path, arr in host_leaves
            },
        }
        self._pending = self._executor.submit(
            self._write, int(step), host_leaves, manifest
        )
        if blocking:
            self.wait()

    def _write(self, step: int, host_leaves, manifest):
        tmp = os.path.join(self.directory, "step_%08d.tmp" % step)
        final = os.path.join(self.directory, "step_%08d" % step)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for path, arr in host_leaves:
            np.save(os.path.join(tmp, _leaf_filename(path)), arr)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                     # atomic commit
        self._gc()

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, "step_%08d" % s),
                          ignore_errors=True)

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    # -- restore ---------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None, shardings=None):
        """Restore into the structure of ``template``.

        ``shardings`` (optional pytree of NamedSharding) enables **elastic
        restore**: leaves are device_put with the *new* mesh's shardings, so a
        checkpoint from a 512-chip run reloads onto 256 chips (or 1 CPU).
        """
        step = step if step is not None else self.latest_step()
        assert step is not None, "no checkpoint found in %s" % self.directory
        d = os.path.join(self.directory, "step_%08d" % step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        flat_t = _flatten_with_paths(template)
        flat_s = _flatten_with_paths(shardings) if shardings is not None else None
        leaves = []
        for i, (path, tmpl) in enumerate(flat_t):
            arr = np.load(os.path.join(d, _leaf_filename(path)))
            want_shape = tuple(np.shape(tmpl))
            if want_shape and tuple(arr.shape) != want_shape:
                raise ValueError(
                    "shape mismatch for %s: ckpt %s vs template %s"
                    % (path, arr.shape, want_shape)
                )
            if flat_s is not None:
                leaves.append(jax.device_put(arr, flat_s[i][1]))
            else:
                leaves.append(jax.numpy.asarray(arr))
        treedef = jax.tree_util.tree_structure(template)
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest
