"""Gradient compression for data-parallel all-reduce: int8 quantization with
error feedback (EF-SGD style), expressed with shard_map + psum.

At 1000-node scale the DP all-reduce of a 100B-param model dominates step
time on slow inter-pod links; 4x compression (f32->int8) cuts wire bytes 4x
at the cost of quantization noise, which error feedback re-injects next step
so convergence is preserved (tested in tests/test_train.py).
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean over the mesh axis with int8 wire format.

    Each shard quantizes locally; the int8 payload is all-reduced as int32
    (sum of int8 fits easily), scales are all-gathered (tiny), and the mean is
    reconstructed as sum_i q_i * s_i / n.
    """
    q, scale = quantize_int8(x)
    qsum_times_scale = jax.lax.psum(q.astype(jnp.int32).astype(jnp.float32) * scale,
                                    axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return qsum_times_scale / n


def make_compressed_allreduce(mesh: Mesh, axis_name: str = "data"):
    """Returns allreduce(grads, residual) -> (mean_grads, new_residual).

    ``residual`` is the error-feedback memory (same pytree as grads).  Usage
    in a shard_map'd DP train step:

        grads_c = grads + residual
        mean, new_residual = allreduce(grads_c)
    """

    def one(g, r):
        gc = g.astype(jnp.float32) + r
        q, scale = quantize_int8(gc)
        local_decoded = dequantize_int8(q, scale)
        new_r = gc - local_decoded                      # error feedback
        mean = compressed_psum_mean(gc, axis_name)
        return mean, new_r

    def allreduce(grads, residual):
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_r = jax.tree_util.tree_leaves(residual)
        out = [one(g, r) for g, r in zip(flat_g, flat_r)]
        means = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        resid = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        return means, resid

    return allreduce


def dp_train_step_compressed(loss_fn, opt_update, mesh: Mesh,
                             axis_name: str = "data"):
    """A shard_map DP training step with compressed gradient exchange.

    ``loss_fn(params, batch) -> loss`` (per-shard), ``opt_update(grads,
    state, params) -> (params, state, metrics)``.  Params replicated; batch
    sharded on dim0 over ``axis_name``; residual carried in opt-state slot.
    """

    def step(params, opt_state, residual, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        gc = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, grads, residual)
        mean = jax.tree.map(lambda g: compressed_psum_mean(g, axis_name), gc)
        new_resid = jax.tree.map(
            lambda g: g - dequantize_int8(*quantize_int8(g)), gc
        )
        params, opt_state, metrics = opt_update(mean, opt_state, params)
        metrics["loss"] = jax.lax.pmean(loss, axis_name)
        return params, opt_state, new_resid, metrics

    from repro.compat import shard_map

    in_specs = (P(), P(), P(), P(axis_name))
    out_specs = (P(), P(), P(), P())
    return shard_map(
        step, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )
