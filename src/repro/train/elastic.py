"""Elastic runtime: failure detection, straggler mitigation, mesh resizing.

On a real pod-scale deployment the launcher (launch/train.py) wraps the step
loop with this controller:

* **failure injection / detection** — step exceptions (device loss, NaN
  loss, heartbeat timeout) trigger a restore-and-resume from the newest
  checkpoint; repeated failures shrink the mesh (elastic downsizing) because
  checkpoints are mesh-agnostic (see CheckpointManager.restore).
* **straggler mitigation** — per-step wall-time EWMA; a step slower than
  ``straggler_factor``x the EWMA is logged and counted; persistent straggling
  triggers the same resize path (on TPU pods a straggling host is replaced by
  re-slicing).
* **deterministic data resume** — the data pipeline is keyed by absolute step
  (repro.data.tokens), so resumed runs consume exactly the batches the failed
  run would have.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class ElasticConfig:
    max_restarts: int = 3
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    checkpoint_every: int = 50
    nan_is_failure: bool = True


@dataclasses.dataclass
class StepRecord:
    step: int
    wall_time: float
    loss: float
    straggler: bool
    restart_count: int


class ElasticRunner:
    """Drives train_step with checkpoint/restart + straggler accounting."""

    def __init__(self, cfg: ElasticConfig, ckpt_mgr, mesh_shapes: List[Dict[str, int]]):
        """``mesh_shapes``: preference-ordered list of mesh shapes; a resize
        moves down the list (e.g. [(2,16,16), (16,16), (8,16)])."""
        self.cfg = cfg
        self.ckpt = ckpt_mgr
        self.mesh_shapes = mesh_shapes
        self.mesh_index = 0
        self.restart_count = 0
        self.ewma: Optional[float] = None
        self.history: List[StepRecord] = []

    def current_mesh_shape(self) -> Dict[str, int]:
        return self.mesh_shapes[self.mesh_index]

    def should_resize(self) -> bool:
        return (
            self.restart_count >= self.cfg.max_restarts
            and self.mesh_index + 1 < len(self.mesh_shapes)
        )

    def resize(self) -> Dict[str, int]:
        self.mesh_index += 1
        self.restart_count = 0
        return self.current_mesh_shape()

    def run(
        self,
        state: Tuple,
        step_fn: Callable[[Tuple, int], Tuple[Tuple, Dict]],
        start_step: int,
        num_steps: int,
        save_fn: Callable[[Tuple, int], None],
        restore_fn: Callable[[], Tuple[Tuple, int]],
        failure_schedule: Optional[Dict[int, Exception]] = None,
    ) -> Tuple[Tuple, List[StepRecord]]:
        """Run ``num_steps`` with recovery.  ``failure_schedule`` injects
        exceptions at given steps (testing hook for node-failure simulation);
        each scheduled failure fires once."""
        failure_schedule = dict(failure_schedule or {})
        step = start_step
        end = start_step + num_steps
        while step < end:
            t0 = time.monotonic()
            try:
                if step in failure_schedule:
                    raise failure_schedule.pop(step)
                state, metrics = step_fn(state, step)
                loss = float(metrics.get("loss", np.nan))
                if self.cfg.nan_is_failure and not np.isfinite(loss):
                    raise FloatingPointError("non-finite loss at step %d" % step)
            except Exception:
                self.restart_count += 1
                if self.should_resize():
                    self.resize()
                state, step = restore_fn()
                continue
            wall = time.monotonic() - t0
            prev = self.ewma
            self.ewma = wall if prev is None else (
                self.cfg.ewma_alpha * wall + (1 - self.cfg.ewma_alpha) * prev
            )
            straggler = prev is not None and wall > self.cfg.straggler_factor * prev
            self.history.append(
                StepRecord(step, wall, loss, straggler, self.restart_count)
            )
            step += 1
            if step % self.cfg.checkpoint_every == 0 or step == end:
                save_fn(state, step)
        return state, self.history
