"""train_step factory: loss + grad + AdamW, with microbatch accumulation and
configurable remat — the function the dry-run lowers and the driver jits."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from .optimizer import AdamWConfig, OptState, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    microbatches: int = 1            # gradient accumulation steps
    remat: str = "dots"              # none | dots | full
    impl: str = "xla"                # attention/ssm impl: xla | pallas
    scan_unroll: int = 1             # period-scan unroll (dry-run accounting)
    # sequence-parallel residual stream: PartitionSpec entries (as a tuple,
    # e.g. (("pod","data"), "model", None)) constraining activations after
    # every sub-layer — turns TP boundary all-reduces into bf16 RS+AG
    act_shard: Optional[Tuple] = None
    # hierarchical MoE dispatch groups (1 = global dispatch); align with the
    # data-parallel shard count so sort/gather/scatter stay device-local
    moe_groups: int = 1
    # mesh axes the MoE group dim is pinned to (e.g. ("data",))
    moe_group_axes: Optional[Tuple[str, ...]] = None
    # mesh axes of the EP combine all-to-all (e.g. ("model",)) — only when
    # the expert count divides that axis
    moe_combine_axes: Optional[Tuple[str, ...]] = None


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""
    from jax.sharding import PartitionSpec as P
    act_shard = P(*tcfg.act_shard) if tcfg.act_shard is not None else None

    grad_fn = jax.value_and_grad(
        functools.partial(lm.loss_fn, impl=tcfg.impl, remat=tcfg.remat,
                          unroll=tcfg.scan_unroll, act_shard=act_shard,
                          moe_groups=tcfg.moe_groups,
                          moe_axes=tcfg.moe_group_axes,
                          moe_combine=tcfg.moe_combine_axes),
        has_aux=True,
    )

    def compute_grads(params, batch):
        if tcfg.microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, cfg, batch)
            return loss, metrics, grads

        # unrolled accumulation (not lax.scan): microbatch counts are small,
        # XLA schedules the chunks back-to-back, and — decisive for the
        # dry-run methodology — cost analysis sees every chunk instead of
        # counting a while-loop body once
        n = tcfg.microbatches
        mbs = jax.tree.map(
            lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch
        )
        grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        loss = jnp.zeros(())
        metrics = None
        for i in range(n):
            mb = jax.tree.map(lambda x: x[i], mbs)
            (loss, metrics), g = grad_fn(params, cfg, mb)
            grads = jax.tree.map(jnp.add, grads, g)
        grads = jax.tree.map(lambda g: g / n, grads)
        return loss, metrics, grads

    def train_step(params, opt_state: OptState, batch: Dict[str, jax.Array]):
        loss, metrics, grads = compute_grads(params, batch)
        params, opt_state, opt_metrics = adamw_update(
            tcfg.opt, grads, opt_state, params
        )
        return params, opt_state, {**metrics, **opt_metrics, "loss": loss}

    return train_step
