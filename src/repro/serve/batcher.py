"""Continuous batcher: slot-based request scheduling for the serving engine.

The TPU engine wants fixed shapes; requests arrive ragged.  The batcher owns
``num_slots`` decode lanes: arriving requests claim free slots (prefill),
finished sequences release them, and every engine call decodes all active
slots in one fixed-shape step — continuous batching à la vLLM/Orca, reduced
to its SPMD-friendly core.  This is the Aggregator of the LM-serving SCEP
operator (DESIGN.md §3): window = one decode step across active slots.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [T] int32
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class SlotState:
    request: Optional[Request] = None
    pos: int = 0                  # next absolute position


class ContinuousBatcher:
    """Host-side slot manager around jitted (prefill_one, decode_all) fns.

    For simplicity each slot has its own cache pytree entry along dim0 of the
    batched cache; prefill writes one slot (masked), decode advances all.
    """

    def __init__(
        self,
        num_slots: int,
        prefill_fn: Callable,        # (params, tokens[1,T], caches, slot) -> (logits, caches)
        decode_fn: Callable,         # (params, tokens[S,1], caches, pos[S]) -> (logits, caches)
        eos_id: int = -1,
    ):
        self.num_slots = num_slots
        self.slots = [SlotState() for _ in range(num_slots)]
        self.queue: Deque[Request] = deque()
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.eos_id = eos_id
        self.completed: List[Request] = []

    # -- request lifecycle -----------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s.request is None:
                return i
        return None

    def _admit(self, params, caches):
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return caches
            req = self.queue.popleft()
            tokens = jnp.asarray(req.prompt, jnp.int32)[None]
            logits, caches = self.prefill_fn(params, tokens, caches, slot)
            tok = int(jnp.argmax(logits[0]))
            req.generated.append(tok)
            self.slots[slot] = SlotState(req, pos=len(req.prompt) + 1)
        return caches

    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.request is not None]

    # -- one engine tick ---------------------------------------------------------
    def step(self, params, caches):
        caches = self._admit(params, caches)
        act = self.active()
        if not act:
            return caches, False
        tokens = np.zeros((self.num_slots, 1), np.int32)
        pos = np.zeros((self.num_slots,), np.int32)
        for i in act:
            s = self.slots[i]
            tokens[i, 0] = s.request.generated[-1]
            pos[i] = s.pos
        logits, caches = self.decode_fn(
            params, jnp.asarray(tokens), caches, jnp.asarray(pos)
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i in act:
            s = self.slots[i]
            tok = int(nxt[i])
            s.request.generated.append(tok)
            s.pos += 1
            if tok == self.eos_id or len(s.request.generated) >= s.request.max_new:
                s.request.done = True
                self.completed.append(s.request)
                self.slots[i] = SlotState()
        return caches, True

    def run_until_drained(self, params, caches, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or self.active()) and ticks < max_ticks:
            caches, _ = self.step(params, caches)
            ticks += 1
        return caches, ticks
