"""Admission front-end for the multi-query serving engine.

The serving engine wants a bounded standing-query population and steady
chunk feed; tenants arrive ragged.  :class:`QueryAdmission` owns
``num_slots`` query slots — the standing-query analogue of the LM decode
lanes in :class:`repro.serve.lm.ContinuousBatcher`, whose slot lifecycle
(claim-on-free, retire-on-done, fixed-shape engine tick) it repurposes:

* **query slots** — ``submit`` enqueues a registration request; ``admit``
  moves queued requests into free slots by registering them with the
  :class:`~repro.serve.engine.ServeEngine`; ``retire`` unregisters and
  frees the slot.  A full admission queue rejects (backpressure, counted).
* **per-tenant chunk queues** — ``offer_chunk`` appends to the tenant's
  bounded queue and returns ``False`` (plus a rejection counter) when the
  queue is full, so producers see backpressure instead of unbounded memory.
* **round-robin ticks** — each ``tick`` drains one chunk from the next
  non-empty tenant queue through ``engine.process_chunk``, so no tenant can
  starve the others however fast it produces.
* **validation + quarantine** — an optional ingest ``validator`` (defaulted
  by :meth:`repro.serve.engine.ServeEngine.admission` to
  :func:`repro.core.faults.validate_chunk` over the session vocab) rejects
  malformed chunks at the queue boundary with counted per-tenant reasons,
  and a tenant whose ticks *fault* ``max_tenant_faults`` times in a row is
  quarantined — its queries retired, its queue dropped, further traffic
  refused — instead of taking the whole :class:`ServeEngine` down.

Everything here is host-side bookkeeping; the device work happens inside
the engine's deduplicated/batched step functions.
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Set, Tuple


@dataclasses.dataclass
class QueryRequest:
    """A standing-query admission request (text or AST, per tenant)."""

    query: Any                     # C-SPARQL text or repro.core.query.Query
    tenant: str = "default"
    name: Optional[str] = None     # fallback name for text without REGISTER


@dataclasses.dataclass
class QuerySlot:
    request: Optional[QueryRequest] = None
    name: Optional[str] = None     # registered query name while occupied


class QueryAdmission:
    """Slot-based admission + per-tenant chunk queues over a ServeEngine."""

    def __init__(self, engine, num_slots: int = 64,
                 queue_cap: int = 256, chunk_queue_cap: int = 8,
                 validator: Optional[Callable[[Any], List[str]]] = None,
                 max_tenant_faults: int = 3):
        self.engine = engine
        self.num_slots = num_slots
        self.slots = [QuerySlot() for _ in range(num_slots)]
        self.queue: Deque[QueryRequest] = deque()
        self.queue_cap = queue_cap
        self.chunk_queue_cap = chunk_queue_cap
        self.chunk_queues: Dict[str, Deque] = {}
        self._rr: List[str] = []          # round-robin tenant order
        self._rr_next = 0
        # ingest gate: chunk -> list of rejection reasons ([] = valid)
        self.validator = validator
        # consecutive *faulting* ticks (engine exceptions) a tenant is
        # allowed before quarantine; successes reset the count
        self.max_tenant_faults = max_tenant_faults
        self.quarantined: Set[str] = set()
        self._consec_faults: Dict[str, int] = {}
        self.invalid_reasons: Dict[str, List[str]] = {}   # last per tenant
        self.counters: Dict[str, int] = {
            "submitted": 0, "admitted": 0, "retired": 0,
            "rejected_queries": 0, "chunks_offered": 0,
            "chunks_rejected": 0, "chunks_processed": 0,
            "chunks_dropped": 0, "ticks": 0,
            "chunks_invalid": 0, "tenant_faults": 0,
            "quarantined_tenants": 0,
        }

    # -- query lifecycle -----------------------------------------------------
    def submit(self, req: QueryRequest, admit: bool = True) -> bool:
        """Queue a standing-query registration; ``False`` = queue full (or
        the tenant is quarantined)."""
        self.counters["submitted"] += 1
        if req.tenant in self.quarantined:
            self.counters["rejected_queries"] += 1
            return False
        if len(self.queue) >= self.queue_cap:
            self.counters["rejected_queries"] += 1
            return False
        self.queue.append(req)
        if admit:
            self.admit()
        return True

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s.request is None:
                return i
        return None

    def admit(self) -> List[str]:
        """Register queued requests into free slots; returns new names."""
        admitted: List[str] = []
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                break
            req = self.queue.popleft()
            unit = self.engine.register(req.query, name=req.name)
            self.slots[slot] = QuerySlot(req, name=unit.name)
            self.counters["admitted"] += 1
            admitted.append(unit.name)
        return admitted

    def retire(self, name: str, drain: bool = True) -> None:
        """Unregister a standing query and free its slot.

        When this was the tenant's **last** admitted query (and the tenant
        has nothing waiting in the admission queue), the tenant's chunk
        queue and round-robin membership are torn down with it: with
        ``drain=True`` (default) its queued chunks are processed through the
        engine *before* unregistering — the retiring query still sees its
        tenant's final chunks — with ``drain=False`` they are discarded and
        counted as ``chunks_dropped``.  The round-robin cursor is
        re-anchored around the removal so the rotation resumes at the same
        neighbour — leaving the cursor untouched would skip or double-serve
        a tenant, and leaving retired tenants in the rotation forever would
        burn a tick slot on every revolution.
        """
        for i, s in enumerate(self.slots):
            if s.name == name:
                tenant = s.request.tenant if s.request else None
                last = tenant is not None and not (
                    any(o.request is not None and o.request.tenant == tenant
                        for j, o in enumerate(self.slots) if j != i)
                    or any(r.tenant == tenant for r in self.queue))
                if last:
                    self._teardown_tenant(tenant, drain)
                self.engine.unregister(name)
                self.slots[i] = QuerySlot()
                self.counters["retired"] += 1
                self.admit()               # backfill from the queue
                return
        raise KeyError("no admitted query named %r" % name)

    def _teardown_tenant(self, tenant: str, drain: bool) -> None:
        q = self.chunk_queues.pop(tenant, None)
        if q:
            if drain:
                while q:
                    self.engine.process_chunk(q.popleft())
                    self.counters["chunks_processed"] += 1
            else:
                self.counters["chunks_dropped"] += len(q)
                q.clear()
        if tenant in self._rr:
            idx = self._rr.index(tenant)
            pos = self._rr_next % len(self._rr)
            self._rr.remove(tenant)
            if not self._rr:
                self._rr_next = 0
            else:
                self._rr_next = (pos - 1 if idx < pos else pos) % len(self._rr)

    def active(self) -> List[str]:
        return [s.name for s in self.slots if s.name is not None]

    # -- chunk feed ------------------------------------------------------------
    def offer_chunk(self, chunk, tenant: str = "default") -> bool:
        """Bounded per-tenant enqueue; ``False`` = backpressure, a
        quarantined tenant, or a chunk the ingest validator rejected
        (each counted separately)."""
        self.counters["chunks_offered"] += 1
        if tenant in self.quarantined:
            self.counters["chunks_rejected"] += 1
            return False
        if self.validator is not None:
            reasons = self.validator(chunk)
            if reasons:
                self.counters["chunks_invalid"] += 1
                self.invalid_reasons[tenant] = list(reasons)
                return False
        q = self.chunk_queues.get(tenant)
        if q is None:
            q = self.chunk_queues[tenant] = deque()
            self._rr.append(tenant)
        if len(q) >= self.chunk_queue_cap:
            self.counters["chunks_rejected"] += 1
            return False
        q.append(chunk)
        return True

    def pending_chunks(self) -> int:
        return sum(len(q) for q in self.chunk_queues.values())

    def tick(self) -> Optional[Tuple[str, Dict[str, Any]]]:
        """One engine tick: pop one chunk from the next non-empty tenant
        queue (round-robin) and push it through every admitted query.
        Returns ``(tenant, outputs)`` or ``None`` when all queues are empty.

        A tick that *faults* (the engine raises on this tenant's chunk) is
        contained: the exception is counted against the tenant, and after
        ``max_tenant_faults`` consecutive faults the tenant is quarantined
        — its standing queries retired, its queued chunks dropped, further
        traffic refused — so one poisoned feed cannot take down the shared
        engine.  Successful ticks reset the tenant's fault count.
        """
        self.counters["ticks"] += 1
        for _ in range(len(self._rr)):
            tenant = self._rr[self._rr_next % len(self._rr)]
            self._rr_next += 1
            q = self.chunk_queues[tenant]
            if q:
                chunk = q.popleft()
                try:
                    outs = self.engine.process_chunk(chunk)
                except Exception:
                    self.counters["tenant_faults"] += 1
                    n = self._consec_faults.get(tenant, 0) + 1
                    self._consec_faults[tenant] = n
                    if n >= self.max_tenant_faults:
                        self.quarantine(tenant)
                    return None
                self._consec_faults[tenant] = 0
                self.counters["chunks_processed"] += 1
                return tenant, outs
        return None

    def quarantine(self, tenant: str) -> None:
        """Isolate a repeatedly-faulting tenant: retire its admitted
        queries (without draining — its chunks are suspect), purge its
        waiting registrations, drop its queue, and refuse future traffic."""
        if tenant in self.quarantined:
            return
        self.quarantined.add(tenant)
        self.counters["quarantined_tenants"] += 1
        # purge waiting registrations first so retire()'s last-query check
        # sees no pending work for the tenant and tears its queue down
        purged = [r for r in self.queue if r.tenant == tenant]
        for r in purged:
            self.queue.remove(r)
            self.counters["rejected_queries"] += 1
        for name in [s.name for s in self.slots
                     if s.request is not None and s.request.tenant == tenant
                     and s.name is not None]:
            self.retire(name, drain=False)
        # a tenant with chunks but no admitted query: tear down directly
        if tenant in self.chunk_queues:
            self._teardown_tenant(tenant, drain=False)
        self._consec_faults.pop(tenant, None)

    def drain(self) -> List[Tuple[str, Dict[str, Any]]]:
        """Tick until every tenant queue is empty."""
        outs: List[Tuple[str, Dict[str, Any]]] = []
        while self.pending_chunks():
            res = self.tick()
            if res is not None:
                outs.append(res)
        return outs

    # -- observability ---------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        return {
            **self.counters,
            "slots": self.num_slots,
            "occupied_slots": len(self.active()),
            "queued_queries": len(self.queue),
            "chunk_queue_depths": {
                t: len(q) for t, q in self.chunk_queues.items()
            },
            "quarantined": sorted(self.quarantined),
            "invalid_reasons": {t: list(r)
                                for t, r in self.invalid_reasons.items()},
        }


# --------------------------------------------------------------------------
# deprecation shims — the LM batcher moved to repro.serve.lm
# --------------------------------------------------------------------------

_LM_NAMES = ("ContinuousBatcher", "Request", "SlotState")


def __getattr__(name: str):
    if name in _LM_NAMES:
        warnings.warn(
            "repro.serve.batcher.%s moved to repro.serve.lm (this module is "
            "now the SCEP query-admission layer)" % name,
            DeprecationWarning, stacklevel=2,
        )
        from . import lm
        return getattr(lm, name)
    raise AttributeError("module %r has no attribute %r" % (__name__, name))
