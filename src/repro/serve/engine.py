"""Serving engine: prefill / decode steps over the pool architectures.

``serve_prefill`` consumes the whole prompt (filling KV / SSM caches);
``serve_step`` emits one token per sequence per call.  Both are pure
functions of (params, caches) so they jit/pjit and dry-run-lower cleanly.

This is also where DSCEP composes with the LM stack: an LM serving pipeline
is an SCEP operator whose Aggregator is the request batcher, whose engine is
``serve_step``, and whose Publisher is the detokenizer (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm


def make_serve_fns(cfg: ModelConfig, max_len: int, impl: str = "xla"):
    """Returns (prefill, step):

    prefill(params, batch, caches) -> (logits_last, caches)
    step(params, tokens, caches, pos) -> (logits, caches)
    """

    def prefill(params, batch: Dict, caches):
        # fori cache carry: in-place per-period updates keep decode temps at
        # ~1x cache instead of scan's ~3x (EXPERIMENTS.md §Perf cell 3)
        logits, caches = lm.decode_step(
            params, cfg, batch, caches, jnp.zeros((), jnp.int32), impl,
            loop="fori",
        )
        return logits[:, -1], caches

    def step(params, batch: Dict, caches, pos):
        logits, caches = lm.decode_step(params, cfg, batch, caches, pos, impl,
                                        loop="fori")
        return logits[:, -1], caches

    return prefill, step


def greedy_token(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_token(logits: jax.Array, key: jax.Array, temperature: float = 1.0):
    if temperature == 0.0:
        return greedy_token(logits)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


def generate(
    params, cfg: ModelConfig, prompt: jax.Array, max_new: int,
    max_len: Optional[int] = None, temperature: float = 0.0,
    key: Optional[jax.Array] = None, impl: str = "xla",
) -> jax.Array:
    """Simple batched generation (greedy by default) — example/test surface."""
    b, t = prompt.shape[:2]
    max_len = max_len or (t + max_new)
    caches = lm.init_cache(cfg, b, max_len)
    prefill, step = make_serve_fns(cfg, max_len, impl)
    logits, caches = prefill(params, {"tokens": prompt}, caches)
    key = key if key is not None else jax.random.PRNGKey(0)
    toks = []
    tok = sample_token(logits, key, temperature)
    toks.append(tok)
    pos = jnp.asarray(t, jnp.int32)
    for i in range(max_new - 1):
        if cfg.num_codebooks:
            batch = {"tokens": tok[:, None, :]}     # [B, 1, K]
        else:
            batch = {"tokens": tok[:, None]}        # [B, 1]
        logits, caches = step(params, batch, caches, pos)
        key, sub = jax.random.split(key)
        tok = sample_token(logits, sub, temperature)
        toks.append(tok)
        pos = pos + 1
    return jnp.stack(toks, axis=1)
