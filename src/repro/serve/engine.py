"""ServeEngine: many standing C-SPARQL queries over one Session.

A :class:`~repro.core.session.Session` gives every registered query its own
isolated runtime; fine for a handful, hopeless for the "millions of users"
regime where most registrations are copies or near-copies of each other.
``ServeEngine`` keeps ONE compiled population and shares work at three
granularities, strictly preserving bit-identity with per-query single
sessions (pinned by tests/test_serve_engine.py and the differential suite):

1. **plan dedup** — registrations whose compiled plans have equal
   :func:`~repro.core.planner.plan_fingerprint` (the plan minus its name)
   on the same KB/env evaluate ONCE; the published chunk fans out to every
   member.  Closure-pair KB augmentations, ``kb_method="auto"`` statistics
   and reasoning closure sets are likewise built once per distinct spec and
   shared by construction (``_kb_cache`` / ``_env_cache``), so KB probe
   views (precomputed on the shared KB object) are shared too.
2. **shared KB-join prefixes** — distinct plans that start with the same
   step run (same caps; deterministic compilation means equal prefixes bind
   equal columns) and whose common prefix contains at least one KB join
   execute as one jitted program: the prefix binds once per window, then
   each member runs only its suffix + finalize tail
   (:func:`repro.core.engine.run_steps` /
   :func:`~repro.core.engine.finalize_bindings` — the exact ops
   ``run_plan`` uses).
3. **vmap cohorts** — plans with equal :func:`~repro.core.planner.plan_shape`
   (identical modulo constants) become one program ``vmap``-ed over a
   ``[Q, K]`` constant matrix and stacked env arrays
   (:func:`~repro.core.planner.bind_plan_consts` substitutes the traced
   constants inside the trace), so 64 filter variants cost one fixed-shape
   dispatch instead of 64.

Windowing (merge + count_windows) happens once per distinct window
geometry per chunk.  Registrations the batched paths cannot serve
losslessly (``incremental=True``, Pallas kernel configs) fall back to their
own :class:`~repro.core.operator.SCEPOperator` — dedup fan-out still
applies.  ``ServeEngine.last_stats`` reports the schedule (distinct plans,
shared-prefix hits, per-cohort batch sizes) plus per-query engine metrics
when the session config enables tracing.

The LM serving scaffolding that used to live here moved to
:mod:`repro.serve.lm`; module-level ``__getattr__`` shims keep the old
imports working with a ``DeprecationWarning``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import query as Q
from repro.core.engine import finalize_bindings, run_plan_windows, run_steps
from repro.core.kb import KnowledgeBase, collect_kb_stats, pad_to
from repro.core.operator import OperatorConfig, SCEPOperator, publish_chunk
from repro.core.pattern import universe_bindings
from repro.core.planner import (
    augment_kb_with_closures, bind_plan_consts, closure_env_entry,
    closure_path_specs, compile_query, count_kb_joins, plan_caps,
    plan_consts, plan_fingerprint, plan_set_names, plan_shape,
    shared_prefix_len,
)
from repro.core.rdf import TripleBatch
from repro.core.runtime import RuntimeConfig
from repro.core.session import Session
from repro.core.sparql import ParseInfo, parse_query_info, serialize_query
from repro.core.stream import merge_streams
from repro.core.window import count_windows
from repro.obs.metrics import finalize_stats, merge_stats, split_stats
from repro.obs.report import attach_saturation
from repro.obs.trace import resolve_trace


# --------------------------------------------------------------------------
# a registered serving unit
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ServeUnit:
    """One standing query as the engine sees it: compiled plan + shared
    KB/env + window geometry + a per-unit fallback operator."""

    name: str
    query: Q.Query
    info: Optional[ParseInfo]
    text: str
    plan: Any
    kb: Optional[KnowledgeBase]
    env: Dict[str, jax.Array]
    rcfg: RuntimeConfig
    op: SCEPOperator

    @property
    def geometry(self) -> Tuple:
        r = self.rcfg
        return (r.window_capacity, r.max_windows, r.window_step,
                r.incremental)

    @property
    def env_sig(self) -> Tuple:
        # env arrays come from the engine's shared cache, so identity
        # equality is exactly value equality here
        return tuple(sorted((k, id(v)) for k, v in self.env.items()))


@dataclasses.dataclass
class _Group:
    """A dedup group: one representative evaluation, fanned out."""

    rep: ServeUnit
    members: List[ServeUnit]


# --------------------------------------------------------------------------
# executables — one device program each
# --------------------------------------------------------------------------

class _OpExec:
    """Fallback / singleton: the group's own SCEPOperator step."""

    kind = "operator"

    def __init__(self, group: _Group):
        self.groups = [group]

    def run(self, engine: "ServeEngine", chunk: TripleBatch, wcache: Dict):
        g = self.groups[0]
        if engine._collect:
            out, ovf, stats = g.rep.op.process_stats([chunk])
            merge_stats(engine._stats_acc.setdefault(g.rep.name, {}), stats)
        else:
            out, ovf = g.rep.op.process([chunk])
        return [(g, out, ovf)]


class _PrefixExec:
    """Distinct plans sharing a KB-join-bearing step prefix: the prefix
    binds once per window, each member runs suffix + finalize + publish —
    all inside one jitted program."""

    kind = "prefix"

    def __init__(self, groups: List[_Group], prefix_len: int):
        self.groups = groups
        self.prefix_len = prefix_len
        rep0 = groups[0].rep
        self.kb_joins_shared = count_kb_joins(rep0.plan.steps[:prefix_len])
        plans = [g.rep.plan for g in groups]
        out_stream_cap = rep0.rcfg.out_stream_cap
        p = prefix_len

        def impl(windows, kb, envs):
            w = windows.num_windows

            def one(window, wid, wvalid):
                cur = universe_bindings(rep0.plan.bind_cap,
                                        rep0.plan.num_vars)
                cur = run_steps(rep0.plan, cur, rep0.plan.steps[:p],
                                window, kb, envs[0])
                ts = jnp.max(jnp.where(window.valid, window.ts, 0))
                outs = []
                for plan, env in zip(plans, envs):
                    c = run_steps(plan, cur, plan.steps[p:], window, kb, env)
                    out, ovf = finalize_bindings(
                        plan, c, ts, wid.astype(jnp.uint32) * plan.bind_cap)
                    outs.append((out._replace(valid=out.valid & wvalid), ovf))
                return tuple(outs)

            res = jax.vmap(one, in_axes=(0, 0, 0))(
                windows.triples, jnp.arange(w), windows.window_valid)
            return tuple(
                (publish_chunk(out_w, out_stream_cap), ovf)
                for out_w, ovf in res
            )

        self._fn = jax.jit(impl)

    def run(self, engine: "ServeEngine", chunk: TripleBatch, wcache: Dict):
        rep0 = self.groups[0].rep
        windows = engine._windows_for(rep0.geometry, chunk, wcache)
        envs = tuple(g.rep.env for g in self.groups)
        res = self._fn(windows, rep0.kb, envs)
        return [(g, out, ovf) for g, (out, ovf) in zip(self.groups, res)]


class _CohortExec:
    """Same-shaped plans as one program vmapped over the per-query
    constant axis (+ stacked env arrays)."""

    kind = "cohort"

    def __init__(self, groups: List[_Group]):
        self.groups = groups
        rep = groups[0].rep
        self._rep = rep
        out_stream_cap = rep.rcfg.out_stream_cap
        self.const_mat = jnp.asarray(
            np.stack([plan_consts(g.rep.plan) for g in groups]))  # [Q, K]
        # stacked closure-set envs under canonical __set%d keys: each
        # member's sorted array is edge-padded with its own max element,
        # which leaves searchsorted membership semantics unchanged
        self.env_stack: Dict[str, jax.Array] = {}
        names = [plan_set_names(g.rep.plan) for g in groups]
        for j in range(len(names[0])):
            arrays = [np.asarray(g.rep.env[names[i][j]])
                      for i, g in enumerate(groups)]
            width = max(a.shape[0] for a in arrays)
            self.env_stack["__set%d" % j] = jnp.asarray(np.stack([
                np.pad(a, (0, width - a.shape[0]), mode="edge")
                for a in arrays
            ]))

        def impl(windows, kb, const_mat, env_stack, with_stats=False):
            def per_query(consts, env):
                plan_q = bind_plan_consts(rep.plan, consts)
                res = run_plan_windows(plan_q, windows, kb, env,
                                       with_stats=with_stats)
                if with_stats:
                    out_w, ovf, stats = res
                    return publish_chunk(out_w, out_stream_cap), ovf, stats
                out_w, ovf = res
                return publish_chunk(out_w, out_stream_cap), ovf

            return jax.vmap(per_query, in_axes=(0, 0))(const_mat, env_stack)

        self._fn = jax.jit(impl, static_argnames=("with_stats",))

    def run(self, engine: "ServeEngine", chunk: TripleBatch, wcache: Dict):
        rep = self._rep
        windows = engine._windows_for(rep.geometry, chunk, wcache)
        if engine._collect:
            out_q, ovf_q, stats_q = self._fn(
                windows, rep.kb, self.const_mat, self.env_stack,
                with_stats=True)
            for i, g in enumerate(self.groups):
                merge_stats(engine._stats_acc.setdefault(g.rep.name, {}),
                            split_stats(stats_q, i))
        else:
            out_q, ovf_q = self._fn(
                windows, rep.kb, self.const_mat, self.env_stack)
        return [
            (g, jax.tree.map(lambda a, i=i: a[i], out_q), ovf_q[i])
            for i, g in enumerate(self.groups)
        ]


@dataclasses.dataclass
class _Schedule:
    groups: List[_Group]
    execs: List[Any]

    def prefix_execs(self) -> List[_PrefixExec]:
        return [e for e in self.execs if e.kind == "prefix"]

    def cohort_execs(self) -> List[_CohortExec]:
        return [e for e in self.execs if e.kind == "cohort"]


# --------------------------------------------------------------------------
# the engine
# --------------------------------------------------------------------------

class ServeEngine:
    """Multi-query serving over one Session's vocab/KB/config.

    ``dedup=False`` disables fingerprint dedup AND prefix sharing (every
    registration evaluates; the benchmark's control arm); ``batch=False``
    additionally disables cohort vmap-batching, reducing the engine to N
    independent operators sharing only the windowing step.
    """

    def __init__(self, session: Session, dedup: bool = True,
                 batch: bool = True):
        self.session = session
        self.dedup = dedup
        self.batch = batch
        self.units: Dict[str, ServeUnit] = {}
        self._schedule: Optional[_Schedule] = None
        self._kb_cache: Dict[Tuple, KnowledgeBase] = {}
        self._kb_pad_cache: Dict[Tuple, KnowledgeBase] = {}
        self._kb_stats_cache: Dict[int, Any] = {}
        self._env_cache: Dict[Tuple, jax.Array] = {}
        self._win_fns: Dict[Tuple, Any] = {}
        self._ovf_acc: Dict[str, jax.Array] = {}
        self._stats_acc: Dict[str, Dict[str, jax.Array]] = {}
        self._admission = None
        tcfg = resolve_trace(session.config.trace)
        self._collect = bool(tcfg and tcfg.metrics)
        self.counters: Dict[str, int] = {
            "chunks": 0, "shared_plan_hits": 0, "shared_prefix_hits": 0,
        }

    # -- registration --------------------------------------------------------
    def register(self, query: Union[str, Q.Query], name: Optional[str] = None,
                 replace: bool = False) -> ServeUnit:
        """Register a standing query (C-SPARQL text or AST) into the serving
        population.  Duplicate names raise ``ValueError`` with both
        serializations unless ``replace=True`` (same contract as
        ``Session.register``)."""
        info: Optional[ParseInfo] = None
        if isinstance(query, str):
            query, info = parse_query_info(query, self.session.vocab, name)
        elif not isinstance(query, Q.Query):
            raise TypeError(
                "register() takes C-SPARQL text or a repro.core.query.Query, "
                "got %r" % type(query).__name__)
        prefixes = dict(info.prefixes) if info else None
        text = serialize_query(query, self.session.vocab, prefixes, info=info)
        existing = self.units.get(query.name)
        if existing is not None and not replace:
            raise ValueError(
                "query %r is already registered.\n"
                "existing:\n%s\nnew:\n%s\n"
                "Pass replace=True to substitute the new registration."
                % (query.name, existing.text, text))
        unit = self._build_unit(query, info, text)
        self.units[unit.name] = unit
        self._ovf_acc.setdefault(unit.name, jnp.zeros((), jnp.int32))
        self._schedule = None
        return unit

    def unregister(self, name: str) -> None:
        """Drop a standing query from the population."""
        del self.units[name]
        self._ovf_acc.pop(name, None)
        self._stats_acc.pop(name, None)
        self._schedule = None

    def _build_unit(self, query: Q.Query, info: Optional[ParseInfo],
                    text: str) -> ServeUnit:
        cfg = self.session.config
        if cfg.window_from_query and info is not None and info.window_triples:
            cfg = cfg.replace(window_capacity=info.window_triples,
                              window_step=info.window_step)
        rcfg = cfg.runtime_config()
        kb = self.session.kb
        if kb is None and query.kb_predicates():
            raise ValueError(
                "query %r touches the KB (GRAPH <kb> patterns) but the "
                "Session has no kb= attached" % query.name)
        # shared closure-pair augmentation: one materialization per distinct
        # closure-spec tuple; every query with the same paths reuses the
        # same KB object (and its precomputed probe-view arrays)
        akb = kb
        kb_stats = None
        if kb is not None:
            specs = tuple(closure_path_specs(query))
            akb = self._kb_cache.get(specs)
            if akb is None:
                akb = augment_kb_with_closures(
                    query, kb, use_pallas=rcfg.use_pallas,
                    interpret=rcfg.interpret)
                self._kb_cache[specs] = akb
            if rcfg.kb_method == "auto":
                kb_stats = self._kb_stats_cache.get(id(akb))
                if kb_stats is None:
                    kb_stats = collect_kb_stats(akb)
                    self._kb_stats_cache[id(akb)] = kb_stats
        join_bm, join_bn = rcfg.join_block_shapes or (None, None)
        plan = compile_query(
            query, kb_method=rcfg.kb_method, scan_cap=rcfg.scan_cap,
            bind_cap=rcfg.bind_cap, out_cap=rcfg.out_cap,
            use_pallas=rcfg.use_pallas,
            fuse_compaction=rcfg.fuse_compaction,
            join_bm=join_bm, join_bn=join_bn, interpret=rcfg.interpret,
            kb_stats=kb_stats,
        )
        # shared reasoning closure sets: one array per distinct
        # (subclass_pred, super_class); env dicts alias them
        env: Dict[str, jax.Array] = {}
        for item in query.where:
            if isinstance(item, Q.FilterSubclass):
                ck = (item.subclass_pred, item.super_class,
                      rcfg.use_pallas, rcfg.interpret)
                if ck not in self._env_cache:
                    _, arr = closure_env_entry(
                        akb, item.subclass_pred, item.super_class,
                        rcfg.use_pallas, rcfg.interpret)
                    self._env_cache[ck] = arr
                env["closure:%d" % item.super_class] = self._env_cache[ck]
        if rcfg.kb_capacity and akb is not None:
            pk = (id(akb), rcfg.kb_capacity)
            if pk not in self._kb_pad_cache:
                self._kb_pad_cache[pk] = pad_to(akb, rcfg.kb_capacity)
            akb = self._kb_pad_cache[pk]
        op = SCEPOperator(
            query.name, plan, akb, env,
            OperatorConfig(rcfg.window_capacity, rcfg.max_windows,
                           rcfg.out_stream_cap,
                           window_step=rcfg.window_step,
                           incremental=rcfg.incremental),
        )
        return ServeUnit(name=query.name, query=query, info=info, text=text,
                         plan=plan, kb=akb, env=env, rcfg=rcfg, op=op)

    # -- scheduling ----------------------------------------------------------
    def _build_schedule(self) -> _Schedule:
        units = list(self.units.values())
        groups: List[_Group] = []
        if self.dedup:
            by_fp: Dict[Tuple, _Group] = {}
            for u in units:
                key = (plan_fingerprint(u.plan), id(u.kb), u.env_sig,
                       u.geometry, u.rcfg.out_stream_cap)
                g = by_fp.get(key)
                if g is None:
                    g = by_fp[key] = _Group(rep=u, members=[])
                    groups.append(g)
                g.members.append(u)
        else:
            groups = [_Group(rep=u, members=[u]) for u in units]

        execs: List[Any] = []
        batchable: List[_Group] = []
        for g in groups:
            r = g.rep.rcfg
            # the batched paths re-trace the plan outside SCEPOperator;
            # kernel configs (Pallas / fused compaction) and incremental
            # evaluation keep their per-unit operator programs
            if (g.rep.geometry[3] or r.use_pallas or r.fuse_compaction
                    or not self.batch):
                execs.append(_OpExec(g))
            else:
                batchable.append(g)

        remaining = batchable
        if self.dedup:
            clusters, remaining = self._cluster_prefixes(batchable)
            execs.extend(_PrefixExec(gs, p) for gs, p in clusters)

        by_shape: Dict[Tuple, List[_Group]] = {}
        for g in remaining:
            key = (plan_shape(g.rep.plan), id(g.rep.kb), g.rep.geometry,
                   g.rep.rcfg.out_stream_cap)
            by_shape.setdefault(key, []).append(g)
        for gs in by_shape.values():
            if len(gs) >= 2:
                execs.append(_CohortExec(gs))
            else:
                execs.append(_OpExec(gs[0]))
        return _Schedule(groups=groups, execs=execs)

    @staticmethod
    def _cluster_prefixes(
        groups: List[_Group],
    ) -> Tuple[List[Tuple[List[_Group], int]], List[_Group]]:
        """Greedy clustering of distinct plans by common leading step run.

        A cluster only forms when the shared prefix contains a KB join (the
        work worth amortizing) and the plans agree on the binding-table
        geometry the prefix runs under; everything else falls through to
        cohort/singleton scheduling."""
        clusters: List[Dict[str, Any]] = []
        rest: List[_Group] = []
        for g in groups:
            u = g.rep
            placed = False
            for cl in clusters:
                seed = cl["members"][0].rep
                if (seed.plan.num_vars != u.plan.num_vars
                        or seed.plan.scan_cap != u.plan.scan_cap
                        or seed.plan.bind_cap != u.plan.bind_cap
                        or seed.geometry != u.geometry
                        or id(seed.kb) != id(u.kb)):
                    continue
                p = min(cl["prefix"], shared_prefix_len(seed.plan, u.plan))
                if p >= 1 and count_kb_joins(seed.plan.steps[:p]) >= 1:
                    cl["members"].append(g)
                    cl["prefix"] = p
                    placed = True
                    break
            if not placed:
                clusters.append({"members": [g], "prefix": len(u.plan.steps)})
        out: List[Tuple[List[_Group], int]] = []
        for cl in clusters:
            if len(cl["members"]) >= 2:
                out.append((cl["members"], cl["prefix"]))
            else:
                rest.extend(cl["members"])
        return out, rest

    def _windows_for(self, geometry: Tuple, chunk: TripleBatch,
                     cache: Dict) -> Any:
        """Windows for one geometry, computed once per chunk and shared by
        every batched program with that geometry (merge + count_windows —
        the same ops SCEPOperator's step starts with)."""
        if geometry not in cache:
            fn = self._win_fns.get(geometry)
            if fn is None:
                cap, max_w, step, _ = geometry

                def fn(c, cap=cap, max_w=max_w, step=step):
                    return count_windows(merge_streams((c,)), cap, max_w,
                                         step)

                fn = jax.jit(fn)
                self._win_fns[geometry] = fn
            cache[geometry] = fn(chunk)
        return cache[geometry]

    # -- drive surface -------------------------------------------------------
    @property
    def schedule(self) -> _Schedule:
        if self._schedule is None:
            self._schedule = self._build_schedule()
        return self._schedule

    def process_chunk(self, chunk: TripleBatch) -> Dict[str, TripleBatch]:
        """Push one chunk through every registered query; returns
        ``{query name: published output chunk}`` — each entry bit-identical
        to the query's own single-session output for this chunk."""
        sched = self.schedule
        outs: Dict[str, TripleBatch] = {}
        wcache: Dict = {}
        for ex in sched.execs:
            for g, out, ovf in ex.run(self, chunk, wcache):
                n_ovf = jnp.sum(ovf.astype(jnp.int32))
                for u in g.members:
                    outs[u.name] = out
                    self._ovf_acc[u.name] = self._ovf_acc[u.name] + n_ovf
        self.counters["chunks"] += 1
        self.counters["shared_plan_hits"] += sum(
            len(g.members) - 1 for g in sched.groups)
        self.counters["shared_prefix_hits"] += sum(
            (len(ex.groups) - 1) * ex.prefix_len
            for ex in sched.prefix_execs())
        return outs

    def run(self, chunks: Sequence[TripleBatch]
            ) -> Tuple[Dict[str, List[TripleBatch]], Dict[str, int]]:
        """Whole-stream drive: one output chunk per input chunk per query,
        plus per-query overflow totals (the same contract
        ``RegisteredQuery.run`` gives each member in its own session)."""
        outs: Dict[str, List[TripleBatch]] = {n: [] for n in self.units}
        for c in chunks:
            for n, o in self.process_chunk(c).items():
                outs[n].append(o)
        return outs, self.overflow_totals()

    def admission(self, **opts):
        """A :class:`~repro.serve.batcher.QueryAdmission` front-end bound to
        this engine (slot-based admission, per-tenant chunk queues,
        backpressure counters, ingest validation + tenant quarantine).

        Unless the caller supplies a ``validator``, the front-end gates
        chunks with :func:`repro.core.faults.validate_chunk` bound to this
        session's vocab, so malformed ingest is refused at the boundary
        instead of poisoning the shared engine."""
        import functools

        from ..core.faults import validate_chunk
        from .batcher import QueryAdmission

        if "validator" not in opts:
            opts["validator"] = functools.partial(
                validate_chunk, vocab=self.session.vocab)
        self._admission = QueryAdmission(self, **opts)
        return self._admission

    # -- observability -------------------------------------------------------
    def overflow_totals(self) -> Dict[str, int]:
        return {n: int(np.asarray(v)) for n, v in self._ovf_acc.items()}

    @property
    def last_stats(self) -> Dict[str, Any]:
        """Schedule + sharing effectiveness + per-query engine metrics::

            {
              "queries", "dedup", "batch", "distinct_plans",
              "shared_plan_hits", "shared_prefix_hits",   # cumulative
              "prefix_groups": [{"queries", "prefix_len",
                                 "kb_joins_shared"}, ...],
              "cohorts": [{"size", "queries"}, ...],
              "batch_sizes": [...],                       # per-cohort sizes
              "singletons", "chunks", "overflow_totals",
              "admission": {...},                         # when attached
              "operators": {name: {...}},                 # trace on only
            }
        """
        sched = self.schedule
        ops: Dict[str, Any] = {}
        for name, acc in self._stats_acc.items():
            unit = self.units.get(name)
            caps = plan_caps(unit.plan) if unit is not None else {}
            ops[name] = attach_saturation(finalize_stats(acc), caps)
        return {
            "queries": len(self.units),
            "dedup": self.dedup,
            "batch": self.batch,
            "distinct_plans": len(sched.groups),
            "shared_plan_hits": self.counters["shared_plan_hits"],
            "shared_prefix_hits": self.counters["shared_prefix_hits"],
            "prefix_groups": [
                {
                    "queries": [g.rep.name for g in ex.groups],
                    "prefix_len": ex.prefix_len,
                    "kb_joins_shared": ex.kb_joins_shared,
                }
                for ex in sched.prefix_execs()
            ],
            "cohorts": [
                {"size": len(ex.groups),
                 "queries": [g.rep.name for g in ex.groups]}
                for ex in sched.cohort_execs()
            ],
            "batch_sizes": [len(ex.groups) for ex in sched.cohort_execs()],
            "singletons": sum(1 for e in sched.execs if e.kind == "operator"),
            "chunks": self.counters["chunks"],
            "overflow_totals": self.overflow_totals(),
            "admission": (self._admission.stats()
                          if self._admission is not None else {}),
            "operators": ops,
        }


# --------------------------------------------------------------------------
# deprecation shims — the LM prefill/decode scaffolding moved to serve/lm.py
# --------------------------------------------------------------------------

_LM_NAMES = ("make_serve_fns", "greedy_token", "sample_token", "generate")


def __getattr__(name: str):
    if name in _LM_NAMES:
        warnings.warn(
            "repro.serve.engine.%s moved to repro.serve.lm (this module is "
            "now the SCEP multi-query serving engine)" % name,
            DeprecationWarning, stacklevel=2,
        )
        from . import lm
        return getattr(lm, name)
    raise AttributeError("module %r has no attribute %r" % (__name__, name))
