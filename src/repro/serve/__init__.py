"""Serving stack: multi-query SCEP serving (engine/batcher) + LM lanes (lm)."""
from . import batcher, engine, lm  # noqa: F401
