from . import batcher, engine  # noqa: F401
