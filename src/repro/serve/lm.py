"""LM serving scaffolding: prefill/decode steps + continuous batcher.

This module is the language-model half of the serving stack — re-homed from
``serve/batcher.py`` / ``serve/engine.py`` when those modules became the
SCEP query-serving subsystem (:class:`repro.serve.engine.ServeEngine` and
:class:`repro.serve.batcher.QueryAdmission`).  The slot-lifecycle pattern
pioneered here (fixed lanes, admit-on-free, retire-on-done) is what the
query admission layer repurposes for standing queries.

``serve_prefill`` consumes the whole prompt (filling KV / SSM caches);
``serve_step`` emits one token per sequence per call.  Both are pure
functions of (params, caches) so they jit/pjit and dry-run-lower cleanly.
``ContinuousBatcher`` owns ``num_slots`` decode lanes: arriving requests
claim free slots (prefill), finished sequences release them, and every
engine call decodes all active slots in one fixed-shape step — continuous
batching à la vLLM/Orca, reduced to its SPMD-friendly core.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm


# --------------------------------------------------------------------------
# prefill / decode step functions
# --------------------------------------------------------------------------

def make_serve_fns(cfg: ModelConfig, max_len: int, impl: str = "xla"):
    """Returns (prefill, step):

    prefill(params, batch, caches) -> (logits_last, caches)
    step(params, tokens, caches, pos) -> (logits, caches)
    """

    def prefill(params, batch: Dict, caches):
        # fori cache carry: in-place per-period updates keep decode temps at
        # ~1x cache instead of scan's ~3x (EXPERIMENTS.md §Perf cell 3)
        logits, caches = lm.decode_step(
            params, cfg, batch, caches, jnp.zeros((), jnp.int32), impl,
            loop="fori",
        )
        return logits[:, -1], caches

    def step(params, batch: Dict, caches, pos):
        logits, caches = lm.decode_step(params, cfg, batch, caches, pos, impl,
                                        loop="fori")
        return logits[:, -1], caches

    return prefill, step


def greedy_token(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_token(logits: jax.Array, key: jax.Array, temperature: float = 1.0):
    if temperature == 0.0:
        return greedy_token(logits)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


def generate(
    params, cfg: ModelConfig, prompt: jax.Array, max_new: int,
    max_len: Optional[int] = None, temperature: float = 0.0,
    key: Optional[jax.Array] = None, impl: str = "xla",
) -> jax.Array:
    """Simple batched generation (greedy by default) — example/test surface."""
    b, t = prompt.shape[:2]
    max_len = max_len or (t + max_new)
    caches = lm.init_cache(cfg, b, max_len)
    prefill, step = make_serve_fns(cfg, max_len, impl)
    logits, caches = prefill(params, {"tokens": prompt}, caches)
    key = key if key is not None else jax.random.PRNGKey(0)
    toks = []
    tok = sample_token(logits, key, temperature)
    toks.append(tok)
    pos = jnp.asarray(t, jnp.int32)
    for i in range(max_new - 1):
        if cfg.num_codebooks:
            batch = {"tokens": tok[:, None, :]}     # [B, 1, K]
        else:
            batch = {"tokens": tok[:, None]}        # [B, 1]
        logits, caches = step(params, batch, caches, pos)
        key, sub = jax.random.split(key)
        tok = sample_token(logits, sub, temperature)
        toks.append(tok)
        pos = pos + 1
    return jnp.stack(toks, axis=1)


# --------------------------------------------------------------------------
# continuous batcher (slot lanes over jitted prefill/decode)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [T] int32
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class SlotState:
    request: Optional[Request] = None
    pos: int = 0                  # next absolute position


class ContinuousBatcher:
    """Host-side slot manager around jitted (prefill_one, decode_all) fns.

    For simplicity each slot has its own cache pytree entry along dim0 of the
    batched cache; prefill writes one slot (masked), decode advances all.
    """

    def __init__(
        self,
        num_slots: int,
        prefill_fn: Callable,        # (params, tokens[1,T], caches, slot) -> (logits, caches)
        decode_fn: Callable,         # (params, tokens[S,1], caches, pos[S]) -> (logits, caches)
        eos_id: int = -1,
    ):
        self.num_slots = num_slots
        self.slots = [SlotState() for _ in range(num_slots)]
        self.queue: Deque[Request] = deque()
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.eos_id = eos_id
        self.completed: List[Request] = []

    # -- request lifecycle -----------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s.request is None:
                return i
        return None

    def _admit(self, params, caches):
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return caches
            req = self.queue.popleft()
            tokens = jnp.asarray(req.prompt, jnp.int32)[None]
            logits, caches = self.prefill_fn(params, tokens, caches, slot)
            tok = int(jnp.argmax(logits[0]))
            req.generated.append(tok)
            self.slots[slot] = SlotState(req, pos=len(req.prompt) + 1)
        return caches

    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.request is not None]

    # -- one engine tick ---------------------------------------------------------
    def step(self, params, caches):
        caches = self._admit(params, caches)
        act = self.active()
        if not act:
            return caches, False
        tokens = np.zeros((self.num_slots, 1), np.int32)
        pos = np.zeros((self.num_slots,), np.int32)
        for i in act:
            s = self.slots[i]
            tokens[i, 0] = s.request.generated[-1]
            pos[i] = s.pos
        logits, caches = self.decode_fn(
            params, jnp.asarray(tokens), caches, jnp.asarray(pos)
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i in act:
            s = self.slots[i]
            tok = int(nxt[i])
            s.request.generated.append(tok)
            s.pos += 1
            if tok == self.eos_id or len(s.request.generated) >= s.request.max_new:
                s.request.done = True
                self.completed.append(s.request)
                self.slots[i] = SlotState()
        return caches, True

    def run_until_drained(self, params, caches, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or self.active()) and ticks < max_ticks:
            caches, _ = self.step(params, caches)
            ticks += 1
        return caches, ticks
