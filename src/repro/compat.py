"""Version-tolerant jax shims.

The repo targets current jax but must run on the 0.4.x line this image
ships.  Three surfaces moved between 0.4 and 0.5+:

* ``shard_map``: ``jax.experimental.shard_map.shard_map`` -> ``jax.shard_map``,
  and the replication-check kwarg was renamed ``check_rep`` -> ``check_vma``.
* ``jax.sharding.AxisType``: new in 0.5+ (explicit-sharding meshes); 0.4.x
  meshes take no ``axis_types``.

Import from here instead of special-casing at every call site.
"""
from __future__ import annotations

import inspect
from typing import Any

import jax

try:                                        # jax >= 0.5
    from jax import shard_map as _shard_map
except ImportError:                         # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_HAS_CHECK_VMA = "check_vma" in inspect.signature(_shard_map).parameters


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the replication-check kwarg spelled per version."""
    kw = ({"check_vma": check_vma} if _HAS_CHECK_VMA
          else {"check_rep": check_vma})
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def make_mesh(shape, axis_names) -> Any:
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axis_names)
    return jax.make_mesh(
        shape, axis_names, axis_types=(axis_type.Auto,) * len(axis_names)
    )
