"""Pipelined inter-operator dataflow runtime.

:class:`~repro.core.runtime.DSCEPRuntime` traces the whole operator DAG into
**one** XLA program and pushes chunks through it strictly one at a time.
This module is the alternative execution mode the paper actually deploys:
operators as *independently scheduled units* connected by bounded queues
("process part of the data and send it to other operators"), so the
aggregation operator can consume window *t* while the upstream enrichment
operators are already producing *t+1*.

Structure:

* every operator compiles to **its own jitted step** whose inbound/outbound
  :class:`~repro.core.channel.Channel` state is donated (ring buffers are
  updated in place — no per-chunk allocation on the steady path);
* every *buffering* DAG edge is a first-class capacity-bounded device
  channel (:mod:`repro.core.channel`): the ``source → aggregator`` edge
  carries window-aligned :class:`~repro.core.window.Windows`,
  ``op → aggregator`` edges carry the operator's
  ``(TripleBatch[W, out_cap], overflow[W])`` publication — the
  Publisher→Aggregator hop that the single-program runtime hides inside
  XLA.  Upstream operators consume their windows in the same tick they are
  produced, so that hand-off is a direct device transfer, not a queue —
  adding a pass-through channel there would only cost dispatches;
* a **placement** maps operators to devices
  (:func:`repro.launch.mesh.place_operators`); channels live on the
  *consumer's* device, so a producer→consumer ``device_put`` of the payload
  is the transport (a no-op on one device, a D2D copy across devices);
* the host driver runs a **software-pipelined schedule**: it feeds chunk
  *t+1* into the producer stages before draining chunk *t* from the sink,
  keeping ``depth`` chunks in flight (up to the channel capacity, default
  4).  All dispatch is async; only the sink output is ever blocked on.

Results are bit-identical to :class:`DSCEPRuntime` and
:class:`MonolithicRuntime` (tests/test_pipeline_runtime.py): the stages run
the exact same window/engine/publish computations, merely cut at the channel
boundaries instead of fused into one program.
"""
from __future__ import annotations

import functools
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.obs.metrics import finalize_stats, merge_stats
from repro.obs.trace import Tracer, span_or_null

from . import channel
from .channel import Channel
from .kb import KnowledgeBase
from .planner import OperatorDAG
from .rdf import TripleBatch, Vocab, empty_triples
from .runtime import (
    RuntimeConfig, _warn_legacy_constructor, augment_windows, build_operators,
    prepare_split_sink,
)
from .stream import merge_streams
from .window import (
    SlideView, Windows, count_slides, window_slides, windows_from_slides,
)


def _zeros_windows(num_windows: int, capacity: int) -> Windows:
    """A shape/dtype example for sizing source→operator channel slots."""
    z = jax.tree.map(
        lambda col: jnp.zeros((num_windows,) + col.shape, col.dtype),
        empty_triples(capacity),
    )
    return Windows(z, jnp.zeros((num_windows,), bool))


def _zeros_publication(num_windows: int, out_cap: int) -> Tuple[TripleBatch, jax.Array]:
    """Shape/dtype example for an operator→aggregator channel slot."""
    tb = jax.tree.map(
        lambda col: jnp.zeros((num_windows,) + col.shape, col.dtype),
        empty_triples(out_cap),
    )
    return tb, jnp.zeros((num_windows,), bool)


class PipelinedRuntime:
    """Streaming execution of a decomposed query DAG over device channels.

    Drop-in alternative to :class:`~repro.core.runtime.DSCEPRuntime` with the
    same constructor shape plus:

    * ``placement`` — optional ``{operator_name: jax.Device}`` (see
      :func:`repro.launch.mesh.place_operators`); ``None`` leaves every stage
      on the default device (still pipelined, transport becomes a no-op);
    * ``channel_capacity`` — slots per edge channel (≥ 2 for the
      double-buffered schedule; capacity bounds the chunks in flight —
      default 4, deep enough to hide a slow stage behind three fast ones).

    The driver decouples ``feed()`` from execution with dispatch queues:
    chunks land in a host-side source queue and a per-operator dispatch
    queue, and ``_pump()`` advances every stage whose outbound edge has
    room.  ``feed()`` therefore never raises on a full pipeline — excess
    chunks wait in the source queue until ``drain()`` frees a slot.
    """

    def __init__(
        self,
        dag: OperatorDAG,
        kb: KnowledgeBase,
        vocab: Vocab,
        config: Optional[RuntimeConfig] = None,
        mesh=None,
        data_axis: str = "data",
        placement: Optional[Dict[str, Any]] = None,
        channel_capacity: int = 4,
        tracer: Optional[Tracer] = None,
    ):
        _warn_legacy_constructor("PipelinedRuntime", "pipelined")
        if channel_capacity < 2:
            raise ValueError(
                "pipelining needs channel_capacity >= 2 (double buffering), "
                "got %d" % channel_capacity
            )
        if mesh is not None:
            # SPMD window sharding belongs to the single-program runtime;
            # here single-device channel buffers would silently undo it.
            # Use `placement` for cross-device (inter-operator) parallelism.
            raise NotImplementedError(
                "PipelinedRuntime does not shard windows over a mesh; "
                "pass placement= instead (or use DSCEPRuntime with mesh=)"
            )
        self.dag = dag
        self.vocab = vocab
        self.config = cfg = config if config is not None else RuntimeConfig()
        self.mesh = mesh
        self.data_axis = data_axis
        self.channel_capacity = channel_capacity
        self.operators = build_operators(dag, kb, cfg)
        self.final = dag.final
        # upstream operators in DAG insertion order — the same order
        # DSCEPRuntime._dag_impl iterates (augment_windows keys by name, so
        # results do not depend on this order; the channels merely pair up)
        self.upstream: List[str] = [
            n for n in dag.subqueries if n != self.final
        ]
        self.placement = dict(placement) if placement else None
        if self.placement is not None:
            missing = set(self.operators) - set(self.placement)
            if missing:
                raise ValueError("placement missing operators: %s" % sorted(missing))
            # pin each operator's KB slice and env onto its assigned device so
            # its step executes there (jit follows committed input placement)
            for name, op in self.operators.items():
                dev = self.placement[name]
                if op.kb is not None:
                    op.kb = jax.device_put(op.kb, dev)
                op.env = jax.device_put(op.env, dev)

        # --- split aggregation sink: upstream stages publish binding
        # *tables*, the sink joins them directly (None -> augmented path).
        # Swap the sink operator's plan so EXPLAIN/last_stats report the
        # plan that actually runs.
        self._split = prepare_split_sink(dag, self.operators, cfg, mesh)
        if self._split is not None:
            self.operators[self.final].plan = self._split.plan

        # --- per-edge channels (allocated on the consumer's device).  Only
        # the aggregator's inbound edges buffer across ticks; upstream
        # operators consume windows the tick they are produced, so they get
        # a direct transfer instead of a pass-through queue.
        # physical window width is R * slide_capacity (== window_capacity
        # when tumbling, rounded up for a non-dividing STEP)
        slide_cap, slides_per_win = window_slides(
            cfg.window_capacity, cfg.window_step)
        win_example = _zeros_windows(
            cfg.max_windows, slide_cap * slides_per_win)
        if self._split is not None and self._split.delta:
            # the sink consumes the chunk-level SlideView, whose stream leaf
            # is sized by the *chunk* — unknown until the first feed, so the
            # window channel is allocated lazily (see _ensure_win_channel)
            self._agg_win_ch: Optional[Channel] = None
            self._win_sig = None
        else:
            self._agg_win_ch = self._on_device(
                channel.make_channel(win_example, channel_capacity),
                self.final)
        up_out_cap = min(cfg.intermediate_cap, cfg.out_cap)
        self._out_ch: Dict[str, Channel] = {}
        for name in self.upstream:
            if self._split is not None:
                spec = self._split.pub[name]
                k = len(spec.cols)
                if self._split.delta:
                    table = (jnp.zeros((spec.slide_rows_cap, k + 2),
                                       jnp.uint32),
                             jnp.zeros((spec.slide_rows_cap,), bool))
                else:
                    table = (jnp.zeros((cfg.max_windows, spec.rows_cap, k),
                                       jnp.uint32),
                             jnp.zeros((cfg.max_windows, spec.rows_cap),
                                       bool))
                pub_example = (table, jnp.zeros((cfg.max_windows,), bool))
            else:
                pub_example = _zeros_publication(cfg.max_windows, up_out_cap)
            self._out_ch[name] = self._on_device(
                channel.make_channel(pub_example, channel_capacity),
                self.final)

        # --- one jitted step per operator (channel state donated where a
        # step owns channels; windows are shared across consumers and are
        # therefore never donated)
        self._win_step = jax.jit(self._windows_impl)
        self._op_step = {
            name: jax.jit(functools.partial(self._op_impl, name))
            for name in self.upstream
        }
        self._sink_step = jax.jit(self._sink_impl, donate_argnums=(0, 1))
        self._in_flight = 0
        # high-water mark of chunks simultaneously in flight — the achieved
        # pipeline depth (benchmarks/CI assert >= 2, i.e. actual overlap)
        self.depth_hw = 0
        # dispatch queues: feed() only enqueues; _pump() advances any stage
        # whose outbound edge has room.  _src_q holds raw chunks not yet
        # windowed; _disp_q[name] holds windowed payloads operator `name`
        # has not yet executed (decouples upstream execution from feed()).
        self._src_q: Deque[TripleBatch] = deque()
        self._disp_q: Dict[str, Deque[Any]] = {
            name: deque() for name in self.upstream
        }
        # device-side running counters of clipped windows per operator —
        # O(1) state however long the stream runs, and no host sync on the
        # drain path (the driver reads them only at stream boundaries)
        self._overflow_acc: Dict[str, jax.Array] = {
            n: jnp.zeros((), jnp.int32) for n in self.operators
        }
        self._last_overflow: Dict[str, jax.Array] = {}

        # --- observability (off by default: the stats-collecting twins are
        # only *built* — and therefore only compiled — when a metrics tracer
        # is attached, so the plain steps keep their exact programs)
        self.tracer = tracer
        self._collect = bool(tracer is not None and tracer.config.metrics)
        self._stats_acc: Dict[str, Dict[str, jax.Array]] = {
            n: {} for n in self.operators
        }
        self._op_step_stats = self._sink_step_stats = None
        if self._collect:
            self._op_step_stats = {
                name: jax.jit(
                    functools.partial(self._op_impl, name, with_stats=True))
                for name in self.upstream
            }
            self._sink_step_stats = jax.jit(
                functools.partial(self._sink_impl, with_stats=True),
                donate_argnums=(0, 1))
        # host-side per-edge schedule counters (pushes/pops happen on the
        # host driver, so these cost nothing on device)
        self._edge_stats: Dict[str, Dict[str, int]] = {
            e: {"pushes": 0, "pops": 0, "depth_hw": 0} for e in self._edges()
        }

    def _edges(self) -> List[str]:
        return ["source->%s" % self.final] + [
            "%s->%s" % (name, self.final) for name in self.upstream
        ]

    # -- placement helpers ----------------------------------------------------
    def _on_device(self, tree, op_name: str):
        if self.placement is None:
            return tree
        return jax.device_put(tree, self.placement[op_name])

    # -- host-side edge accounting (schedule facts, not device state) ----------
    def _edge_pushed(self, edge: str) -> None:
        e = self._edge_stats[edge]
        e["pushes"] += 1
        e["depth_hw"] = max(e["depth_hw"], e["pushes"] - e["pops"])

    def _edge_popped(self, edge: str) -> None:
        self._edge_stats[edge]["pops"] += 1

    # -- stage implementations (each traces into its own XLA program) ----------
    def _windows_impl(self, chunk: TripleBatch):
        """Source stage: the shared Aggregator front-end (merge + window).

        Returns ``(sink payload, operator payload)``: the materialized
        windows feed the aggregator's window channel while upstream steps
        consume either the windows or — in incremental mode — the slide
        view.  With a delta split sink, *both* sides consume the view and
        the windows are never materialized at all.
        """
        cfg = self.config
        merged = merge_streams([chunk])
        view = count_slides(
            merged, cfg.window_capacity, cfg.max_windows, cfg.window_step)
        if self._split is not None and self._split.delta:
            return view, view
        windows = windows_from_slides(
            view, cfg.window_capacity, cfg.max_windows, cfg.window_step)
        return windows, (view if cfg.incremental else windows)

    def _op_impl(
        self, name: str, win_or_view, kb: Optional[KnowledgeBase],
        env: Dict[str, jax.Array], with_stats: bool = False,
    ):
        """Enrichment operator step: engine over this tick's windows (or
        slide view, in incremental mode).  With ``with_stats`` (a separate
        jitted twin) the publication is returned alongside a flat dict of
        chunk-scalar engine metrics — the publication pushed onto the
        channel is unchanged either way."""
        op = self.operators[name]
        if self._split is not None:
            spec = self._split.pub[name]
            if self._split.delta:
                res = op.process_slide_tables(
                    win_or_view, spec.cols, spec.slide_rows_cap, kb, env,
                    with_stats)
            else:
                res = op.process_window_tables(
                    win_or_view, spec.cols, spec.rows_cap, kb, env,
                    with_stats)
            if with_stats:
                table, ovf, stats = res
            else:
                table, ovf = res
            if ovf.ndim == 0:     # delta tables are chunk-level
                ovf = jnp.broadcast_to(ovf, (self.config.max_windows,))
            if with_stats:
                return (table, ovf), stats
            return table, ovf
        if isinstance(win_or_view, SlideView):
            res = op.process_slides(win_or_view, kb, env, with_stats)
        else:
            res = op.process_windows(win_or_view, kb, env, with_stats)
        if with_stats:
            out_w, ovf, stats = res
            return (out_w, ovf), stats
        return res

    def _sink_impl(
        self, win_ch: Channel, out_chs: Dict[str, Channel],
        kb: Optional[KnowledgeBase], env: Dict[str, jax.Array],
        with_stats: bool = False,
    ):
        """Aggregation operator step: pop every inbound edge, join, publish."""
        win_ch, sink_payload, has = channel.pop(win_ch)
        final_op = self.operators[self.final]
        overflow: Dict[str, jax.Array] = {}
        if self._split is not None:
            tables: Dict[str, Tuple[jax.Array, jax.Array]] = {}
            for name in self.upstream:
                out_chs[name], (table, ovf), h = channel.pop(out_chs[name])
                tables[name] = table
                overflow[name] = ovf & h
            if self._split.delta:
                res = final_op.process_sink_slides(
                    sink_payload, tables, kb, env, with_stats)
            else:
                res = final_op.process_sink_windows(
                    sink_payload, tables, kb, env, with_stats)
        else:
            upstream_out: Dict[str, TripleBatch] = {}
            for name in self.upstream:
                out_chs[name], (tb, ovf), h = channel.pop(out_chs[name])
                upstream_out[name] = tb
                overflow[name] = ovf & h
            aug = augment_windows(self.dag, sink_payload, upstream_out)
            res = final_op.process_windows(aug, kb, env, with_stats)
        if with_stats:
            out_w, ovf_f, stats = res
        else:
            out_w, ovf_f = res
        overflow[self.final] = ovf_f & has
        out = final_op._publish(out_w)
        out = out._replace(valid=out.valid & has)
        if with_stats:
            return win_ch, out_chs, out, overflow, stats
        return win_ch, out_chs, out, overflow

    # -- host-side async driver -------------------------------------------------
    def _edge_room(self, edge: str) -> bool:
        e = self._edge_stats[edge]
        return e["pushes"] - e["pops"] < self.channel_capacity

    def _ensure_win_channel(self, payload) -> None:
        """Lazily allocate the sink's window channel from the first payload
        (split-delta mode ships the SlideView, whose stream leaf is sized by
        the chunk — unknown at construction time)."""
        sig = tuple((leaf.shape, leaf.dtype) for leaf in jax.tree.leaves(payload))
        if self._agg_win_ch is None:
            example = jax.tree.map(jnp.zeros_like, payload)
            self._agg_win_ch = self._on_device(
                channel.make_channel(example, self.channel_capacity),
                self.final)
            self._win_sig = sig
        elif getattr(self, "_win_sig", sig) != sig:
            raise RuntimeError(
                "split-delta pipelining requires uniform chunk shapes: the "
                "window channel was sized for a different chunk capacity")

    def _pump(self) -> None:
        """Advance every stage whose outbound edge has room.

        The schedule's one rule: a stage runs iff it has queued work AND a
        free slot to publish into.  With equal edge capacities the operator
        dispatch queues always empty within the same pump that windows their
        chunk; they exist so ``feed()`` never blocks on (or raises for) a
        full pipeline, and so per-edge capacities can diverge later without
        touching the driver.
        """
        tr = self.tracer
        src_edge = "source->%s" % self.final
        while self._src_q and self._edge_room(src_edge):
            chunk = self._src_q.popleft()
            with span_or_null(tr, "stage:source") as sp:
                sink_payload, op_payload = self._win_step(chunk)
                sp.fence(sink_payload)
            self._ensure_win_channel(sink_payload)
            self._agg_win_ch = channel.push_jit(
                self._agg_win_ch, self._on_device(sink_payload, self.final))
            self._edge_pushed(src_edge)
            for name in self.upstream:
                self._disp_q[name].append(op_payload)
            self._in_flight += 1
            self.depth_hw = max(self.depth_hw, self._in_flight)
        for name in self.upstream:
            edge = "%s->%s" % (name, self.final)
            q = self._disp_q[name]
            op = self.operators[name]
            while q and self._edge_room(edge):
                payload = q.popleft()
                with span_or_null(tr, "stage:%s" % name) as sp:
                    if self._collect:
                        publication, stats = self._op_step_stats[name](
                            self._on_device(payload, name), op.kb, op.env)
                        merge_stats(self._stats_acc[name], stats)
                    else:
                        publication = self._op_step[name](
                            self._on_device(payload, name), op.kb, op.env)
                    sp.fence(publication)
                self._out_ch[name] = channel.push_jit(
                    self._out_ch[name],
                    self._on_device(publication, self.final))
                self._edge_pushed(edge)

    def feed(self, chunk: TripleBatch) -> None:
        """Accept one chunk and dispatch every stage with room (async).

        Never raises on a full pipeline: chunks beyond the channel capacity
        wait in the host-side source queue and are windowed/dispatched as
        ``drain()`` frees slots.  Nothing here blocks on device values.
        """
        self._src_q.append(chunk)
        self._pump()

    def drain(self) -> TripleBatch:
        """Dispatch the sink stage for the oldest in-flight chunk.

        Returns the final published chunk (a device array — block on it only
        when the host needs the values).  Per-operator overflow flags are
        accumulated device-side; read them with :meth:`overflow_totals`.
        """
        self._pump()
        if self._in_flight == 0:
            raise RuntimeError("nothing in flight; feed() first")
        # equal edge capacities guarantee the operator stages kept pace with
        # the source stage — the sink never pops an unmatched window
        assert all(not q for q in self._disp_q.values()), (
            "operator dispatch queues lag the window edge; per-edge "
            "capacities require a schedule-aware sink")
        final_op = self.operators[self.final]
        with span_or_null(self.tracer, "stage:%s" % self.final) as sp:
            if self._collect:
                (self._agg_win_ch, self._out_ch, out, overflow,
                 stats) = self._sink_step_stats(
                    self._agg_win_ch, self._out_ch, final_op.kb, final_op.env)
                merge_stats(self._stats_acc[self.final], stats)
            else:
                self._agg_win_ch, self._out_ch, out, overflow = self._sink_step(
                    self._agg_win_ch, self._out_ch, final_op.kb, final_op.env)
            sp.fence(out)
        for edge in self._edges():
            self._edge_popped(edge)
        for name, flags in overflow.items():
            self._overflow_acc[name] = (
                self._overflow_acc[name] + jnp.sum(flags.astype(jnp.int32))
            )
        self._last_overflow = overflow
        self._in_flight -= 1
        self._pump()          # the pop freed a slot on every edge
        return out

    def _require_idle(self, what: str) -> None:
        # the whole-stream entry points own the schedule end to end; chunks
        # left in flight by manual feed() calls would surface as *this*
        # call's outputs/overflow and break the per-call contract
        if self._in_flight or self._src_q:
            raise RuntimeError(
                "%s with %d chunk(s) already in flight — drain() them first"
                % (what, self._in_flight + len(self._src_q))
            )

    def process_chunk(self, chunk: TripleBatch) -> Tuple[TripleBatch, Dict[str, jax.Array]]:
        """Synchronous single-chunk convenience (no overlap): feed + drain."""
        self._require_idle("process_chunk")
        self.feed(chunk)
        out = self.drain()
        return out, dict(self._last_overflow)

    def process_stream(
        self, chunks: Sequence[TripleBatch], depth: Optional[int] = None
    ) -> Tuple[List[TripleBatch], Dict[str, int]]:
        """Software-pipelined stream execution.

        ``depth`` chunks (default: the channel capacity, ≥ 2) are kept in
        flight: the sink consumes chunk *t* only after chunk *t+1*'s producer
        stages have been dispatched.  Only the last output is blocked on —
        every intermediate hand-off stays on device.  A ``depth`` beyond the
        channel capacity is allowed: the excess waits in the host-side
        source queue (accepted, not yet windowed), so in-flight device state
        never exceeds the channels.
        Returns ``(outputs, overflow)`` like ``DSCEPRuntime.process_stream``:
        the overflow counts cover exactly the chunks of *this* call.
        """
        depth = self.channel_capacity if depth is None else depth
        if depth < 1:
            raise ValueError("depth must be >= 1, got %d" % depth)
        self._require_idle("process_stream")
        target = min(depth, self.channel_capacity)
        before = dict(self._overflow_acc)    # device scalars, no sync
        outs: List[TripleBatch] = []
        for c in chunks:
            if self._in_flight >= target:
                outs.append(self.drain())
            self.feed(c)
        while self._in_flight or self._src_q:
            outs.append(self.drain())
        if outs:
            jax.block_until_ready(outs[-1])  # sink-only synchronization
        overflow = {
            n: int(self._overflow_acc[n] - before[n]) for n in self.operators
        }
        return outs, overflow

    # -- observability ------------------------------------------------------
    def overflow_totals(self) -> Dict[str, int]:
        """Lifetime windows clipped per operator (blocks on a few scalars)."""
        return {n: int(v) for n, v in self._overflow_acc.items()}

    def channel_stats(self) -> Dict[str, Dict[str, int]]:
        """Occupancy, dropped pushes and schedule counters for every edge.

        ``size``/``overflows`` come from device channel state; ``pushes``/
        ``pops``/``depth_hw`` are host-side schedule facts (the depth
        high-water says how much pipelining the driver actually achieved
        against ``capacity``).
        """
        stats: Dict[str, Dict[str, int]] = {}

        def one(edge: str, ch: Optional[Channel]) -> None:
            stats[edge] = {
                # a lazily-sized window channel reports its configured
                # capacity before the first feed allocates it
                "capacity": ch.capacity if ch is not None
                else self.channel_capacity,
                "size": int(ch.size) if ch is not None else 0,
                "overflows": int(ch.overflows) if ch is not None else 0,
                **self._edge_stats[edge],
            }

        one("source->%s" % self.final, self._agg_win_ch)
        for name, ch in self._out_ch.items():
            one("%s->%s" % (name, self.final), ch)
        return stats

    def op_metrics(self) -> Dict[str, Dict[str, int]]:
        """Finalized per-operator engine metric counters (empty unless the
        runtime was built with a metrics-collecting tracer)."""
        return {n: finalize_stats(a) for n, a in self._stats_acc.items() if a}
