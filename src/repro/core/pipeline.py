"""Pipelined inter-operator dataflow runtime.

:class:`~repro.core.runtime.DSCEPRuntime` traces the whole operator DAG into
**one** XLA program and pushes chunks through it strictly one at a time.
This module is the alternative execution mode the paper actually deploys:
operators as *independently scheduled units* connected by bounded queues
("process part of the data and send it to other operators"), so the
aggregation operator can consume window *t* while the upstream enrichment
operators are already producing *t+1*.

Structure:

* every operator compiles to **its own jitted step** whose inbound/outbound
  :class:`~repro.core.channel.Channel` state is donated (ring buffers are
  updated in place — no per-chunk allocation on the steady path);
* every *buffering* DAG edge is a first-class capacity-bounded device
  channel (:mod:`repro.core.channel`): the ``source → aggregator`` edge
  carries window-aligned :class:`~repro.core.window.Windows`,
  ``op → aggregator`` edges carry the operator's
  ``(TripleBatch[W, out_cap], overflow[W])`` publication — the
  Publisher→Aggregator hop that the single-program runtime hides inside
  XLA.  Upstream operators consume their windows in the same tick they are
  produced, so that hand-off is a direct device transfer, not a queue —
  adding a pass-through channel there would only cost dispatches;
* a **placement** maps operators to devices
  (:func:`repro.launch.mesh.place_operators`); channels live on the
  *consumer's* device, so a producer→consumer ``device_put`` of the payload
  is the transport (a no-op on one device, a D2D copy across devices);
* the host driver runs a **software-pipelined schedule**: it feeds chunk
  *t+1* into the producer stages before draining chunk *t* from the sink,
  keeping ``depth`` chunks in flight (up to the channel capacity, default
  4).  All dispatch is async; only the sink output is ever blocked on.

Results are bit-identical to :class:`DSCEPRuntime` and
:class:`MonolithicRuntime` (tests/test_pipeline_runtime.py): the stages run
the exact same window/engine/publish computations, merely cut at the channel
boundaries instead of fused into one program.
"""
from __future__ import annotations

import functools
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp

from repro.obs.metrics import finalize_stats, merge_stats
from repro.obs.trace import Tracer, span_or_null

from . import channel
from .channel import Channel
from .faults import FaultInjector, FaultPlan, InjectedCrash, corrupt_batch, validate_chunk
from .kb import KnowledgeBase
from .planner import OperatorDAG
from .rdf import TripleBatch, Vocab, empty_triples
from .recovery import (
    ChannelDesyncError, Checkpoint, ChunkRejectedError, PipelineStalledError,
    RecoveryConfig, RecoveryExhaustedError, StageTimeoutError,
    copy_edge_stats, empty_recovery_stats, restore_tree, snapshot_stats_acc,
    snapshot_tree, tree_bytes, wait_until_ready,
)
from .runtime import (
    RuntimeConfig, _warn_legacy_constructor, augment_windows, build_operators,
    prepare_split_sink,
)
from .stream import merge_streams
from .window import (
    SlideView, Windows, count_slides, window_slides, windows_from_slides,
)


def _zeros_windows(num_windows: int, capacity: int) -> Windows:
    """A shape/dtype example for sizing source→operator channel slots."""
    z = jax.tree.map(
        lambda col: jnp.zeros((num_windows,) + col.shape, col.dtype),
        empty_triples(capacity),
    )
    return Windows(z, jnp.zeros((num_windows,), bool))


def _zeros_publication(num_windows: int, out_cap: int) -> Tuple[TripleBatch, jax.Array]:
    """Shape/dtype example for an operator→aggregator channel slot."""
    tb = jax.tree.map(
        lambda col: jnp.zeros((num_windows,) + col.shape, col.dtype),
        empty_triples(out_cap),
    )
    return tb, jnp.zeros((num_windows,), bool)


class PipelinedRuntime:
    """Streaming execution of a decomposed query DAG over device channels.

    Drop-in alternative to :class:`~repro.core.runtime.DSCEPRuntime` with the
    same constructor shape plus:

    * ``placement`` — optional ``{operator_name: jax.Device}`` (see
      :func:`repro.launch.mesh.place_operators`); ``None`` leaves every stage
      on the default device (still pipelined, transport becomes a no-op);
    * ``channel_capacity`` — slots per edge channel (≥ 2 for the
      double-buffered schedule; capacity bounds the chunks in flight —
      default 4, deep enough to hide a slow stage behind three fast ones).

    The driver decouples ``feed()`` from execution with dispatch queues:
    chunks land in a host-side source queue and a per-operator dispatch
    queue, and ``_pump()`` advances every stage whose outbound edge has
    room.  ``feed()`` therefore never raises on a full pipeline — excess
    chunks wait in the source queue until ``drain()`` frees a slot.
    """

    def __init__(
        self,
        dag: OperatorDAG,
        kb: KnowledgeBase,
        vocab: Vocab,
        config: Optional[RuntimeConfig] = None,
        mesh=None,
        data_axis: str = "data",
        placement: Optional[Dict[str, Any]] = None,
        channel_capacity: int = 4,
        tracer: Optional[Tracer] = None,
        faults: Optional[FaultPlan] = None,
        recovery: Optional[RecoveryConfig] = None,
    ):
        _warn_legacy_constructor("PipelinedRuntime", "pipelined")
        if channel_capacity < 2:
            raise ValueError(
                "pipelining needs channel_capacity >= 2 (double buffering), "
                "got %d" % channel_capacity
            )
        if mesh is not None:
            # SPMD window sharding belongs to the single-program runtime;
            # here single-device channel buffers would silently undo it.
            # Use `placement` for cross-device (inter-operator) parallelism.
            raise NotImplementedError(
                "PipelinedRuntime does not shard windows over a mesh; "
                "pass placement= instead (or use DSCEPRuntime with mesh=)"
            )
        self.dag = dag
        self.vocab = vocab
        self.config = cfg = config if config is not None else RuntimeConfig()
        self.mesh = mesh
        self.data_axis = data_axis
        self.channel_capacity = channel_capacity
        self.operators = build_operators(dag, kb, cfg)
        self.final = dag.final
        # upstream operators in DAG insertion order — the same order
        # DSCEPRuntime._dag_impl iterates (augment_windows keys by name, so
        # results do not depend on this order; the channels merely pair up)
        self.upstream: List[str] = [
            n for n in dag.subqueries if n != self.final
        ]
        self.placement = dict(placement) if placement else None
        if self.placement is not None:
            missing = set(self.operators) - set(self.placement)
            if missing:
                raise ValueError("placement missing operators: %s" % sorted(missing))
            # pin each operator's KB slice and env onto its assigned device so
            # its step executes there (jit follows committed input placement)
            for name, op in self.operators.items():
                dev = self.placement[name]
                if op.kb is not None:
                    op.kb = jax.device_put(op.kb, dev)
                op.env = jax.device_put(op.env, dev)

        # --- split aggregation sink: upstream stages publish binding
        # *tables*, the sink joins them directly (None -> augmented path).
        # Swap the sink operator's plan so EXPLAIN/last_stats report the
        # plan that actually runs.
        self._split = prepare_split_sink(dag, self.operators, cfg, mesh)
        if self._split is not None:
            self.operators[self.final].plan = self._split.plan

        # --- per-edge channels (allocated on the consumer's device).  Only
        # the aggregator's inbound edges buffer across ticks; upstream
        # operators consume windows the tick they are produced, so they get
        # a direct transfer instead of a pass-through queue.
        # physical window width is R * slide_capacity (== window_capacity
        # when tumbling, rounded up for a non-dividing STEP)
        slide_cap, slides_per_win = window_slides(
            cfg.window_capacity, cfg.window_step)
        win_example = _zeros_windows(
            cfg.max_windows, slide_cap * slides_per_win)
        if self._split is not None and self._split.delta:
            # the sink consumes the chunk-level SlideView, whose stream leaf
            # is sized by the *chunk* — unknown until the first feed, so the
            # window channel is allocated lazily (see _ensure_win_channel)
            self._agg_win_ch: Optional[Channel] = None
            self._win_sig = None
            self._win_example = None
        else:
            self._win_sig = None
            self._win_example = win_example
            self._agg_win_ch = self._on_device(
                channel.make_channel(win_example, channel_capacity),
                self.final)
        up_out_cap = min(cfg.intermediate_cap, cfg.out_cap)
        self._out_ch: Dict[str, Channel] = {}
        # per-edge payload examples are retained so a degraded rebuild can
        # re-allocate fresh empty channels with identical shapes
        self._pub_examples: Dict[str, Any] = {}
        for name in self.upstream:
            if self._split is not None:
                spec = self._split.pub[name]
                k = len(spec.cols)
                if self._split.delta:
                    table = (jnp.zeros((spec.slide_rows_cap, k + 2),
                                       jnp.uint32),
                             jnp.zeros((spec.slide_rows_cap,), bool))
                else:
                    table = (jnp.zeros((cfg.max_windows, spec.rows_cap, k),
                                       jnp.uint32),
                             jnp.zeros((cfg.max_windows, spec.rows_cap),
                                       bool))
                pub_example = (table, jnp.zeros((cfg.max_windows,), bool))
            else:
                pub_example = _zeros_publication(cfg.max_windows, up_out_cap)
            self._pub_examples[name] = pub_example
            self._out_ch[name] = self._on_device(
                channel.make_channel(pub_example, channel_capacity),
                self.final)

        # --- one jitted step per operator (channel state donated where a
        # step owns channels; windows are shared across consumers and are
        # therefore never donated)
        self._win_step = jax.jit(self._windows_impl)
        self._op_step = {
            name: jax.jit(functools.partial(self._op_impl, name))
            for name in self.upstream
        }
        self._sink_step = jax.jit(self._sink_impl, donate_argnums=(0, 1))
        self._in_flight = 0
        # high-water mark of chunks simultaneously in flight — the achieved
        # pipeline depth (benchmarks/CI assert >= 2, i.e. actual overlap)
        self.depth_hw = 0
        # dispatch queues: feed() only enqueues; _pump() advances any stage
        # whose outbound edge has room.  _src_q holds raw chunks not yet
        # windowed; _disp_q[name] holds windowed payloads operator `name`
        # has not yet executed (decouples upstream execution from feed()).
        self._src_q: Deque[TripleBatch] = deque()
        self._disp_q: Dict[str, Deque[Any]] = {
            name: deque() for name in self.upstream
        }
        # device-side running counters of clipped windows per operator —
        # O(1) state however long the stream runs, and no host sync on the
        # drain path (the driver reads them only at stream boundaries)
        self._overflow_acc: Dict[str, jax.Array] = {
            n: jnp.zeros((), jnp.int32) for n in self.operators
        }
        self._last_overflow: Dict[str, jax.Array] = {}

        # --- observability (off by default: the stats-collecting twins are
        # only *built* — and therefore only compiled — when a metrics tracer
        # is attached, so the plain steps keep their exact programs)
        self.tracer = tracer
        self._collect = bool(tracer is not None and tracer.config.metrics)
        self._stats_acc: Dict[str, Dict[str, jax.Array]] = {
            n: {} for n in self.operators
        }
        self._op_step_stats = self._sink_step_stats = None
        if self._collect:
            self._op_step_stats = {
                name: jax.jit(
                    functools.partial(self._op_impl, name, with_stats=True))
                for name in self.upstream
            }
            self._sink_step_stats = jax.jit(
                functools.partial(self._sink_impl, with_stats=True),
                donate_argnums=(0, 1))
        # host-side per-edge schedule counters (pushes/pops happen on the
        # host driver, so these cost nothing on device)
        self._edge_stats: Dict[str, Dict[str, int]] = {
            e: {"pushes": 0, "pops": 0, "depth_hw": 0} for e in self._edges()
        }

        # --- fault tolerance (repro.core.faults / repro.core.recovery).
        # Everything below is host-side bookkeeping: the jitted stage steps
        # above are built identically whether or not faults/recovery are
        # enabled (zero-overhead pin in tests/test_faults.py).
        self._injector = FaultInjector(faults) if faults is not None else None
        if recovery is None and faults is not None:
            recovery = RecoveryConfig()      # chaos implies the default ladder
        self._rcfg = recovery
        self._resilient = recovery is not None
        # lifetime chunk sequence numbers: assigned at feed(), monotonically
        # increasing, never reused — the dedup key for replayed outputs
        self._next_seq = 0
        self._emitted_hw = -1                # highest seq whose output left drain()
        self._inflight_seqs: List[int] = []  # seqs windowed into channels, FIFO
        # bounded replay buffer: pristine fed chunks past the last
        # checkpoint's emitted watermark (pruned at every checkpoint)
        self._retained: Dict[int, TripleBatch] = {}
        self._degraded: Set[int] = set()     # seqs past max_restarts
        self._degraded_out: Dict[int, Tuple[TripleBatch, Dict[str, jax.Array]]] = {}
        self._fail_counts: Dict[int, int] = {}
        self._ckpt: Optional[Checkpoint] = None
        self._fallback_step = None           # channel-free per-chunk program
        # global restart budget: injected events fire once each, so any
        # recovery loop terminates well inside this bound — exceeding it
        # means a persistent non-chunk-attributable fault
        self._restart_budget = 64 + 4 * (len(faults.events) if faults else 0)
        self._rec: Dict[str, int] = {
            "retries": 0, "restarts": 0, "replayed": 0, "deduped": 0,
            "checkpoints": 0, "checkpoint_bytes": 0, "rejected": 0,
            "corrupt_recovered": 0,
        }

    def _edges(self) -> List[str]:
        return ["source->%s" % self.final] + [
            "%s->%s" % (name, self.final) for name in self.upstream
        ]

    # -- placement helpers ----------------------------------------------------
    def _on_device(self, tree, op_name: str):
        if self.placement is None:
            return tree
        return jax.device_put(tree, self.placement[op_name])

    # -- host-side edge accounting (schedule facts, not device state) ----------
    def _edge_pushed(self, edge: str) -> None:
        e = self._edge_stats[edge]
        e["pushes"] += 1
        e["depth_hw"] = max(e["depth_hw"], e["pushes"] - e["pops"])

    def _edge_popped(self, edge: str) -> None:
        self._edge_stats[edge]["pops"] += 1

    # -- stage implementations (each traces into its own XLA program) ----------
    def _windows_impl(self, chunk: TripleBatch):
        """Source stage: the shared Aggregator front-end (merge + window).

        Returns ``(sink payload, operator payload)``: the materialized
        windows feed the aggregator's window channel while upstream steps
        consume either the windows or — in incremental mode — the slide
        view.  With a delta split sink, *both* sides consume the view and
        the windows are never materialized at all.
        """
        cfg = self.config
        merged = merge_streams([chunk])
        view = count_slides(
            merged, cfg.window_capacity, cfg.max_windows, cfg.window_step)
        if self._split is not None and self._split.delta:
            return view, view
        windows = windows_from_slides(
            view, cfg.window_capacity, cfg.max_windows, cfg.window_step)
        return windows, (view if cfg.incremental else windows)

    def _op_impl(
        self, name: str, win_or_view, kb: Optional[KnowledgeBase],
        env: Dict[str, jax.Array], with_stats: bool = False,
    ):
        """Enrichment operator step: engine over this tick's windows (or
        slide view, in incremental mode).  With ``with_stats`` (a separate
        jitted twin) the publication is returned alongside a flat dict of
        chunk-scalar engine metrics — the publication pushed onto the
        channel is unchanged either way."""
        op = self.operators[name]
        if self._split is not None:
            spec = self._split.pub[name]
            if self._split.delta:
                res = op.process_slide_tables(
                    win_or_view, spec.cols, spec.slide_rows_cap, kb, env,
                    with_stats)
            else:
                res = op.process_window_tables(
                    win_or_view, spec.cols, spec.rows_cap, kb, env,
                    with_stats)
            if with_stats:
                table, ovf, stats = res
            else:
                table, ovf = res
            if ovf.ndim == 0:     # delta tables are chunk-level
                ovf = jnp.broadcast_to(ovf, (self.config.max_windows,))
            if with_stats:
                return (table, ovf), stats
            return table, ovf
        if isinstance(win_or_view, SlideView):
            res = op.process_slides(win_or_view, kb, env, with_stats)
        else:
            res = op.process_windows(win_or_view, kb, env, with_stats)
        if with_stats:
            out_w, ovf, stats = res
            return (out_w, ovf), stats
        return res

    def _sink_impl(
        self, win_ch: Channel, out_chs: Dict[str, Channel],
        kb: Optional[KnowledgeBase], env: Dict[str, jax.Array],
        with_stats: bool = False,
    ):
        """Aggregation operator step: pop every inbound edge, join, publish."""
        win_ch, sink_payload, has = channel.pop(win_ch)
        final_op = self.operators[self.final]
        overflow: Dict[str, jax.Array] = {}
        if self._split is not None:
            tables: Dict[str, Tuple[jax.Array, jax.Array]] = {}
            for name in self.upstream:
                out_chs[name], (table, ovf), h = channel.pop(out_chs[name])
                tables[name] = table
                overflow[name] = ovf & h
            if self._split.delta:
                res = final_op.process_sink_slides(
                    sink_payload, tables, kb, env, with_stats)
            else:
                res = final_op.process_sink_windows(
                    sink_payload, tables, kb, env, with_stats)
        else:
            upstream_out: Dict[str, TripleBatch] = {}
            for name in self.upstream:
                out_chs[name], (tb, ovf), h = channel.pop(out_chs[name])
                upstream_out[name] = tb
                overflow[name] = ovf & h
            aug = augment_windows(self.dag, sink_payload, upstream_out)
            res = final_op.process_windows(aug, kb, env, with_stats)
        if with_stats:
            out_w, ovf_f, stats = res
        else:
            out_w, ovf_f = res
        overflow[self.final] = ovf_f & has
        out = final_op._publish(out_w)
        out = out._replace(valid=out.valid & has)
        if with_stats:
            return win_ch, out_chs, out, overflow, stats
        return win_ch, out_chs, out, overflow

    # -- host-side async driver -------------------------------------------------
    def _edge_room(self, edge: str) -> bool:
        e = self._edge_stats[edge]
        return e["pushes"] - e["pops"] < self.channel_capacity

    def _ensure_win_channel(self, payload) -> None:
        """Lazily allocate the sink's window channel from the first payload
        (split-delta mode ships the SlideView, whose stream leaf is sized by
        the chunk — unknown at construction time)."""
        sig = tuple((leaf.shape, leaf.dtype) for leaf in jax.tree.leaves(payload))
        if self._agg_win_ch is None:
            example = jax.tree.map(jnp.zeros_like, payload)
            self._agg_win_ch = self._on_device(
                channel.make_channel(example, self.channel_capacity),
                self.final)
            self._win_sig = sig
        elif self._win_sig is not None and self._win_sig != sig:
            raise RuntimeError(
                "split-delta pipelining requires uniform chunk shapes: the "
                "window channel was sized for a different chunk capacity")

    # -- fault-tolerant dispatch wrappers ------------------------------------
    def _run_stage(self, stage: str, seq: int, thunk, retryable: bool = True):
        """Dispatch one stage step through the fault ladder.

        Without recovery enabled this is a plain ``thunk()`` — zero
        overhead.  With it: injected crashes raise :class:`InjectedCrash`
        (handled by checkpoint restore), injected stalls and real per-stage
        timeouts surface as :class:`StageTimeoutError` and are retried with
        bounded exponential backoff.  ``retryable=False`` (the sink, whose
        step *donates* its channel state — re-invoking would read deleted
        buffers) escalates a real timeout straight to restore; injected
        stalls fire before dispatch and are always retryable.
        """
        if not self._resilient:
            return thunk()
        inj, rc = self._injector, self._rcfg
        if inj is not None and inj.take("crash_stage", stage, seq):
            raise InjectedCrash(stage, seq)
        attempts = 0
        while True:
            try:
                if inj is not None and inj.take("stall_stage", stage, seq):
                    raise StageTimeoutError(
                        stage, seq, rc.stage_timeout_s, injected=True)
                out = thunk()
                if rc.stage_timeout_s is not None and not wait_until_ready(
                        out, rc.stage_timeout_s):
                    raise StageTimeoutError(stage, seq, rc.stage_timeout_s)
            except StageTimeoutError as err:
                attempts += 1
                if attempts > rc.max_retries or (
                        not err.injected and not retryable):
                    raise
                self._rec["retries"] += 1
                time.sleep(rc.backoff_s * (2 ** (attempts - 1)))
                continue
            return out

    def _push_payload(self, stage: str, edge: str, seq: int, payload) -> None:
        """Push a stage's outbound payload, subject to transport faults.

        ``drop_payload`` skips both the push and the ledger — the host
        ledger mirrors device truth, and the loss surfaces as a
        :class:`ChannelDesyncError` when the sink's pre-pop audit compares
        the ledger against the chunks in flight.  ``duplicate_payload``
        pushes (and ledgers) twice — at-least-once transport without dedup.
        """
        inj = self._injector
        if inj is not None and inj.take("drop_payload", stage, seq):
            return
        dev_payload = self._on_device(payload, self.final)
        dup = inj is not None and inj.take("duplicate_payload", stage, seq)
        for _ in range(2 if dup else 1):
            if stage == "source":
                self._agg_win_ch = channel.push_jit(
                    self._agg_win_ch, dev_payload)
            else:
                self._out_ch[stage] = channel.push_jit(
                    self._out_ch[stage], dev_payload)
            self._edge_pushed(edge)

    def _check_desync(self) -> None:
        """Pre-pop audit: every edge must hold exactly one payload per chunk
        in flight, or the sink would join mismatched windows."""
        expected = self._in_flight
        for edge in self._edges():
            e = self._edge_stats[edge]
            actual = e["pushes"] - e["pops"]
            if actual != expected:
                raise ChannelDesyncError(edge, actual, expected)

    def _pump(self) -> None:
        """Advance every stage whose outbound edge has room.

        The schedule's one rule: a stage runs iff it has queued work AND a
        free slot to publish into.  With equal edge capacities the operator
        dispatch queues always empty within the same pump that windows their
        chunk; they exist so ``feed()`` never blocks on (or raises for) a
        full pipeline, and so per-edge capacities can diverge later without
        touching the driver.
        """
        tr = self.tracer
        src_edge = "source->%s" % self.final
        while self._src_q and self._edge_room(src_edge):
            seq, chunk = self._src_q.popleft()
            with span_or_null(tr, "stage:source") as sp:
                sink_payload, op_payload = self._run_stage(
                    "source", seq, lambda: self._win_step(chunk))
                sp.fence(sink_payload)
            self._ensure_win_channel(sink_payload)
            self._push_payload("source", src_edge, seq, sink_payload)
            for name in self.upstream:
                self._disp_q[name].append((seq, op_payload))
            self._in_flight += 1
            self._inflight_seqs.append(seq)
            self.depth_hw = max(self.depth_hw, self._in_flight)
        for name in self.upstream:
            edge = "%s->%s" % (name, self.final)
            q = self._disp_q[name]
            op = self.operators[name]
            while q and self._edge_room(edge):
                seq, payload = q.popleft()
                with span_or_null(tr, "stage:%s" % name) as sp:
                    def step(name=name, payload=payload, op=op):
                        if self._collect:
                            return self._op_step_stats[name](
                                self._on_device(payload, name), op.kb, op.env)
                        return self._op_step[name](
                            self._on_device(payload, name), op.kb, op.env), None
                    publication, stats = self._run_stage(name, seq, step)
                    if stats is not None:
                        merge_stats(self._stats_acc[name], stats)
                    sp.fence(publication)
                self._push_payload(name, edge, seq, publication)

    def _pump_guarded(self) -> None:
        """``_pump`` under the recovery ladder: a stage fault during pumping
        restores the last checkpoint and pumps again (bounded by the global
        restart budget inside :meth:`_handle_fault`)."""
        if not self._resilient:
            self._pump()
            return
        while True:
            try:
                self._pump()
                return
            except (InjectedCrash, StageTimeoutError) as err:
                self._handle_fault(getattr(err, "stage", None),
                                   getattr(err, "seq", None))

    def feed(self, chunk: TripleBatch) -> None:
        """Accept one chunk and dispatch every stage with room (async).

        Never raises on a full pipeline: chunks beyond the channel capacity
        wait in the host-side source queue and are windowed/dispatched as
        ``drain()`` frees slots.  Nothing here blocks on device values.

        With recovery enabled the chunk first passes the
        :func:`~repro.core.faults.validate_chunk` ingest gate (a malformed
        chunk raises :class:`ChunkRejectedError` and leaves the pipeline
        untouched) and a pristine copy enters the bounded replay buffer
        before the — possibly corrupted-in-transit — ingest copy is queued.
        """
        if not self._resilient:
            self._src_q.append((self._next_seq, chunk))
            self._next_seq += 1
            self._pump()
            return
        rc = self._rcfg
        if rc.validate:
            reasons = validate_chunk(chunk, self.vocab, rc.max_graph_size)
            if reasons:
                self._rec["rejected"] += 1
                raise ChunkRejectedError(reasons)
        if self._ckpt is None:
            self._take_checkpoint()       # clean-state checkpoint 0
        seq = self._next_seq
        self._next_seq += 1
        self._retained[seq] = chunk       # pristine, pre-transit
        ingest = chunk
        inj = self._injector
        if inj is not None and inj.take("corrupt_chunk", "ingest", seq):
            ingest = corrupt_batch(chunk)
        if ingest is not chunk and validate_chunk(
                ingest, self.vocab, rc.max_graph_size):
            # the gate caught in-transit corruption: recover the pristine
            # replay-buffer copy instead of poisoning the jitted steps
            self._rec["corrupt_recovered"] += 1
            ingest = self._retained[seq]
        self._src_q.append((seq, ingest))
        self._pump_guarded()

    def drain(self) -> TripleBatch:
        """Dispatch the sink stage for the oldest in-flight chunk.

        Returns the final published chunk (a device array — block on it only
        when the host needs the values).  Per-operator overflow flags are
        accumulated device-side; read them with :meth:`overflow_totals`.
        """
        if self._resilient:
            return self._drain_resilient()
        self._pump()
        if self._in_flight == 0:
            if self._src_q:
                raise PipelineStalledError(self._stall_detail())
            raise RuntimeError("nothing in flight; feed() first")
        _seq, out = self._drain_once()
        self._pump()          # the pop freed a slot on every edge
        return out

    def _drain_once(self) -> Tuple[int, TripleBatch]:
        """The sink dispatch shared by the plain and resilient drains:
        pop every edge, join, accumulate overflow, retire the head seq."""
        # equal edge capacities guarantee the operator stages kept pace with
        # the source stage — the sink never pops an unmatched window
        assert all(not q for q in self._disp_q.values()), (
            "operator dispatch queues lag the window edge; per-edge "
            "capacities require a schedule-aware sink")
        seq = self._inflight_seqs[0] if self._inflight_seqs else -1
        final_op = self.operators[self.final]
        with span_or_null(self.tracer, "stage:%s" % self.final) as sp:
            def step():
                if self._collect:
                    return self._sink_step_stats(
                        self._agg_win_ch, self._out_ch, final_op.kb,
                        final_op.env)
                return self._sink_step(
                    self._agg_win_ch, self._out_ch, final_op.kb,
                    final_op.env) + (None,)
            res = self._run_stage(self.final, seq, step, retryable=False)
            self._agg_win_ch, self._out_ch, out, overflow, stats = res
            if stats is not None:
                merge_stats(self._stats_acc[self.final], stats)
            sp.fence(out)
        for edge in self._edges():
            self._edge_popped(edge)
        self._accumulate_overflow(overflow)
        self._last_overflow = overflow
        self._in_flight -= 1
        if self._inflight_seqs:
            self._inflight_seqs.pop(0)
        return seq, out

    def _accumulate_overflow(self, overflow: Dict[str, jax.Array]) -> None:
        for name, flags in overflow.items():
            self._overflow_acc[name] = (
                self._overflow_acc[name] + jnp.sum(flags.astype(jnp.int32))
            )

    def _drain_resilient(self) -> TripleBatch:
        """Recovery-aware drain: emit the lowest pending seq exactly once.

        Replayed drains of already-emitted seqs advance channel state and
        re-accumulate their overflow (the accumulators were restored to the
        checkpoint, so totals stay exact) but their outputs are *discarded*
        — the sequence-number dedup that makes recovery bit-exact.
        Degraded seqs bypass the channels entirely via the fallback program.
        """
        self._pump_guarded()
        while True:
            # flush degraded outputs whose seqs were already emitted
            for s in [s for s in self._degraded_out
                      if s <= self._emitted_hw]:
                _out, ovf = self._degraded_out.pop(s)
                self._accumulate_overflow(ovf)
                self._rec["deduped"] += 1
            cand = []
            if self._inflight_seqs:
                cand.append(self._inflight_seqs[0])
            if self._degraded_out:
                cand.append(min(self._degraded_out))
            if not cand:
                if self._src_q:
                    raise PipelineStalledError(self._stall_detail())
                raise RuntimeError("nothing in flight; feed() first")
            s = min(cand)
            if s in self._degraded_out and (
                    not self._inflight_seqs or s < self._inflight_seqs[0]):
                out, ovf = self._degraded_out.pop(s)
                self._accumulate_overflow(ovf)
                self._last_overflow = ovf
                self._emitted_hw = s
                self._maybe_checkpoint()
                return out
            try:
                self._check_desync()
                seq, out = self._drain_once()
            except (InjectedCrash, StageTimeoutError,
                    ChannelDesyncError) as err:
                self._handle_fault(getattr(err, "stage", None),
                                   getattr(err, "seq", None))
                self._pump_guarded()
                continue
            if seq <= self._emitted_hw:
                self._rec["deduped"] += 1     # replayed output: discard
                self._pump_guarded()
                continue
            self._emitted_hw = seq
            self._maybe_checkpoint()
            self._pump_guarded()
            return out

    def _stall_detail(self) -> str:
        blocked = [e for e in self._edges() if not self._edge_room(e)]
        return (
            "%d chunk(s) queued at the source but nothing is in flight to "
            "drain and no stage can advance; blocked edge(s): %s"
            % (len(self._src_q),
               ", ".join(blocked) if blocked else
               "none (driver accounting bug)"))

    # -- checkpoint / restore ------------------------------------------------
    def _take_checkpoint(self) -> None:
        """Snapshot a consistent cut of driver + device state to host.

        Channel rings are deep-copied (their buffers are donated to the next
        step); queue payloads and raw chunks are produced by non-donating
        steps, so references suffice.  The replay buffer is pruned to seqs
        past the new checkpoint's emitted watermark.
        """
        ck = Checkpoint(
            fed=self._next_seq,
            emitted=self._emitted_hw,
            in_flight=self._in_flight,
            inflight_seqs=list(self._inflight_seqs),
            src_q=list(self._src_q),
            disp_q={n: list(q) for n, q in self._disp_q.items()},
            win_ch=snapshot_tree(self._agg_win_ch),
            win_sig=self._win_sig,
            out_ch={n: snapshot_tree(c) for n, c in self._out_ch.items()},
            overflow_acc=snapshot_tree(self._overflow_acc),
            stats_acc=snapshot_stats_acc(self._stats_acc),
            edge_stats=copy_edge_stats(self._edge_stats),
            envs={n: op.state() for n, op in self.operators.items()},
            degraded_out=dict(self._degraded_out),
        )
        ck.nbytes = (tree_bytes(ck.win_ch)
                     + tree_bytes(list(ck.out_ch.values()))
                     + tree_bytes(ck.envs))
        self._ckpt = ck
        self._rec["checkpoints"] += 1
        self._rec["checkpoint_bytes"] = ck.nbytes
        for s in [s for s in self._retained if s <= ck.emitted]:
            del self._retained[s]

    def _maybe_checkpoint(self) -> None:
        ce = self._rcfg.checkpoint_every
        if ce and (self._emitted_hw + 1) % ce == 0:
            self._take_checkpoint()

    def _final_device(self):
        return self.placement[self.final] if self.placement else None

    def _restore_common(self, ck: Checkpoint) -> None:
        self._overflow_acc = restore_tree(ck.overflow_acc)
        self._stats_acc = {
            n: (dict(restore_tree(a)) if a else {})
            for n, a in ck.stats_acc.items()
        }
        for n, op in self.operators.items():
            op.restore_state(
                ck.envs[n], self.placement[n] if self.placement else None)

    def _restore_full(self, ck: Checkpoint) -> None:
        """Restore the checkpoint state verbatim and re-feed every retained
        chunk that entered after it — the plain restart path."""
        fdev = self._final_device()
        self._agg_win_ch = restore_tree(ck.win_ch, fdev)
        self._win_sig = ck.win_sig
        self._out_ch = {n: restore_tree(c, fdev)
                        for n, c in ck.out_ch.items()}
        self._edge_stats = copy_edge_stats(ck.edge_stats)
        self._in_flight = ck.in_flight
        self._inflight_seqs = list(ck.inflight_seqs)
        self._src_q = deque(ck.src_q)
        self._disp_q = {n: deque(q) for n, q in ck.disp_q.items()}
        self._degraded_out = dict(ck.degraded_out)
        self._restore_common(ck)
        refed = sorted(s for s in self._retained
                       if ck.fed <= s < self._next_seq)
        for s in refed:
            if s in self._degraded:
                self._degraded_out[s] = self._run_fallback(s)
            else:
                self._src_q.append((s, self._retained[s]))
        self._rec["replayed"] += len(refed)

    def _rebuild_degraded(self, ck: Checkpoint) -> None:
        """Restart with a degraded seq pending: the faulting chunk cannot be
        allowed back into the channels (it would fault the same stage
        again), so the channels are rebuilt empty, every non-emitted seq is
        re-fed from the replay buffer, and degraded seqs are evaluated
        through the channel-free fallback program instead."""
        if self._win_example is None:
            self._agg_win_ch = None          # lazy split-delta: re-sized on
            self._win_sig = None             # the next source dispatch
        else:
            self._agg_win_ch = self._on_device(
                channel.make_channel(self._win_example, self.channel_capacity),
                self.final)
        self._out_ch = {
            n: self._on_device(
                channel.make_channel(self._pub_examples[n],
                                     self.channel_capacity), self.final)
            for n in self.upstream
        }
        self._edge_stats = copy_edge_stats(ck.edge_stats)
        for e in self._edge_stats.values():
            e["pushes"] = e["pops"]          # rebuilt channels are empty
        self._in_flight = 0
        self._inflight_seqs = []
        self._src_q = deque()
        self._disp_q = {n: deque() for n in self.upstream}
        self._degraded_out = {}
        self._restore_common(ck)
        pending = sorted(s for s in self._retained
                         if ck.emitted < s < self._next_seq)
        for s in pending:
            if s in self._degraded:
                self._degraded_out[s] = self._run_fallback(s)
            else:
                self._src_q.append((s, self._retained[s]))
        self._rec["replayed"] += len(pending)

    def _handle_fault(self, stage: Optional[str], seq: Optional[int]) -> None:
        """One rung down the degradation ladder: account the failure to a
        seq, degrade it once it exhausts ``max_restarts``, and restore the
        last checkpoint (full restore, or the degraded rebuild when a
        pending seq is being routed around the channels)."""
        if self._ckpt is None:               # fault before any feed
            raise RecoveryExhaustedError(
                "fault in stage %r before any checkpoint exists" % stage)
        self._restart_budget -= 1
        if self._restart_budget < 0:
            raise RecoveryExhaustedError(
                "restart budget exhausted recovering stage %r (seq %s) — "
                "the fault is persistent and not attributable to one chunk"
                % (stage, seq))
        key = seq if seq is not None and seq >= 0 else (
            self._inflight_seqs[0] if self._inflight_seqs else -1)
        if key >= 0:
            self._fail_counts[key] = self._fail_counts.get(key, 0) + 1
            if self._fail_counts[key] > self._rcfg.max_restarts:
                self._degraded.add(key)
        self._rec["restarts"] += 1
        ck = self._ckpt
        if any(s > ck.emitted for s in self._degraded):
            self._rebuild_degraded(ck)
        else:
            self._restore_full(ck)

    # -- graceful degradation: the channel-free fallback program --------------
    def _fallback_impl(self, chunk: TripleBatch, kbs, envs):
        """The pipeline's per-chunk computation with the channels cut out:
        windows → every upstream step → sink join → publish, composed from
        the *same* stage implementations in one program.  For a real chunk
        every pop-validity mask in :meth:`_sink_impl` is True, so omitting
        them here is value-identical — degraded output matches the piped
        (and monolithic) bytes exactly."""
        sink_payload, op_payload = self._windows_impl(chunk)
        final_op = self.operators[self.final]
        overflow: Dict[str, jax.Array] = {}
        if self._split is not None:
            tables: Dict[str, Any] = {}
            for name in self.upstream:
                table, ovf = self._op_impl(
                    name, op_payload, kbs[name], envs[name])
                tables[name] = table
                overflow[name] = ovf
            if self._split.delta:
                out_w, ovf_f = final_op.process_sink_slides(
                    sink_payload, tables, kbs[self.final], envs[self.final])
            else:
                out_w, ovf_f = final_op.process_sink_windows(
                    sink_payload, tables, kbs[self.final], envs[self.final])
        else:
            upstream_out: Dict[str, TripleBatch] = {}
            for name in self.upstream:
                tb, ovf = self._op_impl(
                    name, op_payload, kbs[name], envs[name])
                upstream_out[name] = tb
                overflow[name] = ovf
            aug = augment_windows(self.dag, sink_payload, upstream_out)
            out_w, ovf_f = final_op.process_windows(
                aug, kbs[self.final], envs[self.final])
        overflow[self.final] = ovf_f
        out = final_op._publish(out_w)
        return out, overflow

    def _run_fallback(self, seq: int):
        """Evaluate one degraded seq through the fallback program (compiled
        on first degradation; the happy path never builds it)."""
        if self._fallback_step is None:
            self._fallback_step = jax.jit(self._fallback_impl)
        chunk = self._retained[seq]
        kbs = {n: op.kb for n, op in self.operators.items()}
        envs = {n: op.env for n, op in self.operators.items()}
        if self.placement is not None:
            # one program cannot span devices: gather onto the sink's device
            fdev = self._final_device()
            chunk = jax.device_put(chunk, fdev)
            kbs = {n: (jax.device_put(kb, fdev) if kb is not None else None)
                   for n, kb in kbs.items()}
            envs = jax.device_put(envs, fdev)
        return self._fallback_step(chunk, kbs, envs)

    def _pending_count(self) -> int:
        """Chunks accepted but not yet emitted (drives the stream loops)."""
        degraded_pending = sum(
            1 for s in self._degraded_out if s > self._emitted_hw)
        return self._in_flight + len(self._src_q) + degraded_pending

    def _require_idle(self, what: str) -> None:
        # the whole-stream entry points own the schedule end to end; chunks
        # left in flight by manual feed() calls would surface as *this*
        # call's outputs/overflow and break the per-call contract
        if self._pending_count():
            raise RuntimeError(
                "%s with %d chunk(s) already in flight — drain() them first"
                % (what, self._pending_count())
            )

    def process_chunk(self, chunk: TripleBatch) -> Tuple[TripleBatch, Dict[str, jax.Array]]:
        """Synchronous single-chunk convenience (no overlap): feed + drain."""
        self._require_idle("process_chunk")
        self.feed(chunk)
        out = self.drain()
        return out, dict(self._last_overflow)

    def process_stream(
        self, chunks: Sequence[TripleBatch], depth: Optional[int] = None
    ) -> Tuple[List[TripleBatch], Dict[str, int]]:
        """Software-pipelined stream execution.

        ``depth`` chunks (default: the channel capacity, ≥ 2) are kept in
        flight: the sink consumes chunk *t* only after chunk *t+1*'s producer
        stages have been dispatched.  Only the last output is blocked on —
        every intermediate hand-off stays on device.  A ``depth`` beyond the
        channel capacity is allowed: the excess waits in the host-side
        source queue (accepted, not yet windowed), so in-flight device state
        never exceeds the channels.
        Returns ``(outputs, overflow)`` like ``DSCEPRuntime.process_stream``:
        the overflow counts cover exactly the chunks of *this* call.
        """
        depth = self.channel_capacity if depth is None else depth
        if depth < 1:
            raise ValueError("depth must be >= 1, got %d" % depth)
        self._require_idle("process_stream")
        target = min(depth, self.channel_capacity)
        before = dict(self._overflow_acc)    # device scalars, no sync
        outs: List[TripleBatch] = []
        for c in chunks:
            if self._in_flight >= target:
                outs.append(self.drain())
            self.feed(c)
        while self._pending_count():
            # no-progress watchdog: every drain must retire exactly one
            # chunk; anything else would formerly spin this loop forever
            pending = self._pending_count()
            outs.append(self.drain())
            if self._pending_count() >= pending:
                raise PipelineStalledError(
                    "drain() retired no chunk (%d still pending) — "
                    "wedged schedule; %s" % (pending, self._stall_detail()))
        if outs:
            jax.block_until_ready(outs[-1])  # sink-only synchronization
        if self._resilient:
            # stream-boundary checkpoint: prunes the replay buffer so
            # retained chunks never outlive their usefulness
            self._take_checkpoint()
        overflow = {
            n: int(self._overflow_acc[n] - before[n]) for n in self.operators
        }
        return outs, overflow

    # -- observability ------------------------------------------------------
    def overflow_totals(self) -> Dict[str, int]:
        """Lifetime windows clipped per operator (blocks on a few scalars)."""
        return {n: int(v) for n, v in self._overflow_acc.items()}

    def channel_stats(self) -> Dict[str, Dict[str, int]]:
        """Occupancy, dropped pushes and schedule counters for every edge.

        ``size``/``overflows`` come from device channel state; ``pushes``/
        ``pops``/``depth_hw`` are host-side schedule facts (the depth
        high-water says how much pipelining the driver actually achieved
        against ``capacity``).
        """
        stats: Dict[str, Dict[str, int]] = {}

        def one(edge: str, ch: Optional[Channel]) -> None:
            stats[edge] = {
                # a lazily-sized window channel reports its configured
                # capacity before the first feed allocates it
                "capacity": ch.capacity if ch is not None
                else self.channel_capacity,
                "size": int(ch.size) if ch is not None else 0,
                "overflows": int(ch.overflows) if ch is not None else 0,
                **self._edge_stats[edge],
            }

        one("source->%s" % self.final, self._agg_win_ch)
        for name, ch in self._out_ch.items():
            one("%s->%s" % (name, self.final), ch)
        return stats

    def op_metrics(self) -> Dict[str, Dict[str, int]]:
        """Finalized per-operator engine metric counters (empty unless the
        runtime was built with a metrics-collecting tracer)."""
        return {n: finalize_stats(a) for n, a in self._stats_acc.items() if a}

    @property
    def degraded(self) -> bool:
        """True when any chunk was routed around the channels through the
        lossless monolithic fallback (output still bit-exact)."""
        return bool(self._degraded)

    def recovery_stats(self) -> Dict[str, Any]:
        """The uniform fault-tolerance surface (``last_stats["recovery"]``):
        injected event counts per kind, retries/restarts/replays/dedups,
        checkpoint cadence + bytes, degraded seqs, ingest rejections."""
        st = empty_recovery_stats(self._resilient)
        st.update(self._rec)
        st["degraded_chunks"] = sorted(self._degraded)
        if self._injector is not None:
            st["injected"] = dict(self._injector.fired)
            st["scheduled"] = self._injector.plan.counts()
        return st
