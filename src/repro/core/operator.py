"""SCEP Operator = Aggregator -> RSP engine(s) -> Publisher (paper §2, Fig 2a).

The operator owns a compiled plan, its pruned KB partition and the static
window geometry.  ``process`` is the jit-compiled whole-operator step:
merge/order input chunks, window them, vmap the engine over windows
(intra-operator parallelism), and publish the constructed output stream.

When a mesh is attached, windows are sharded across the ``data`` axis and the
KB partition is replicated or row-sharded across ``model`` (see
:mod:`repro.core.runtime` for the distributed wiring).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .engine import (
    Plan, run_plan_slide_tables, run_plan_slides, run_plan_window_tables,
    run_plan_windows, run_sink_slides, run_sink_windows,
)
from .kb import KnowledgeBase, pad_to
from .planner import plan_supports_delta
from .rdf import TripleBatch
from .stream import merge_streams
from .window import (
    SlideView, Windows, count_slides, count_windows, window_slides,
    windows_from_slides,
)


def publish_chunk(out_w: TripleBatch, out_stream_cap: int) -> TripleBatch:
    """Publisher: flatten ``[W, cap]`` window outputs into one ordered chunk
    (order-preserving compaction of valid triples to the front).  Module
    level so the serving layer's batched steps publish with exactly the
    ops :class:`SCEPOperator` uses — publication is part of the
    bit-identity contract."""
    from .pattern import compact_rows

    flat = jax.tree.map(lambda col: col.reshape(-1), out_w)
    rows = jnp.stack([flat.s, flat.p, flat.o, flat.ts, flat.graph], axis=1)
    out, valid, _ = compact_rows(rows, flat.valid, out_stream_cap)
    return TripleBatch(
        s=out[:, 0], p=out[:, 1], o=out[:, 2], ts=out[:, 3], graph=out[:, 4],
        valid=valid,
    )


@dataclasses.dataclass(frozen=True)
class OperatorConfig:
    """Frozen so a default instance can never become shared mutable state
    across operator constructions (and so configs are hashable/jit-static)."""

    window_capacity: int = 1000      # paper: "window size is a maximum of 1000 RDF triples"
    max_windows: int = 8             # windows per processed chunk
    out_stream_cap: int = 2048       # published stream chunk capacity
    window_step: Optional[int] = None  # STEP m slide; None / >= capacity = tumbling
    incremental: bool = False        # delta evaluation over slides (when plan allows)


class SCEPOperator:
    """One deployable SCEP operator."""

    def __init__(
        self,
        name: str,
        plan: Plan,
        kb: Optional[KnowledgeBase],
        env: Dict[str, jax.Array],
        config: Optional[OperatorConfig] = None,
    ):
        self.name = name
        self.plan = plan
        self.kb = kb
        self.env = dict(env)
        self.config = config if config is not None else OperatorConfig()
        self._step = jax.jit(self._process_impl)
        self._step_stats = None   # stats-collecting twin, built on first use

    # -- the jitted operator step -------------------------------------------
    def _process_impl(
        self, chunks: Tuple[TripleBatch, ...], kb: Optional[KnowledgeBase],
        env: Dict[str, jax.Array], with_stats: bool = False,
    ):
        # ``with_stats`` is python-static: False (the default everywhere)
        # traces the exact pre-observability program; True additionally
        # returns a flat dict of chunk-scalar engine metrics.
        cfg = self.config
        merged = merge_streams(chunks)                       # Aggregator: merge+order
        if cfg.incremental:
            view = count_slides(
                merged, cfg.window_capacity, cfg.max_windows, cfg.window_step)
            res = self._engine_slides(view, kb, env, with_stats)
        else:
            windows = count_windows(
                merged, cfg.window_capacity, cfg.max_windows, cfg.window_step)
            res = run_plan_windows(self.plan, windows, kb, env, with_stats)  # engines
        if with_stats:
            out_w, overflow, stats = res
            return self._publish(out_w), overflow, stats
        out_w, overflow = res
        return self._publish(out_w), overflow

    def process_windows(
        self, windows: Windows, kb: Optional[KnowledgeBase] = None,
        env: Optional[Dict[str, jax.Array]] = None, with_stats: bool = False,
    ):
        """Window-aligned engine step: ``[W, C]`` in -> ``[W, out_cap]`` out.

        Used by the DAG runtime so downstream operators see upstream results
        in the *same* window (the paper pipelines whole windows between
        operators; re-windowing intermediates would break result equivalence).
        """
        return run_plan_windows(
            self.plan, windows, kb if kb is not None else self.kb,
            env if env is not None else self.env, with_stats,
        )

    def process_slides(
        self, view: SlideView, kb: Optional[KnowledgeBase] = None,
        env: Optional[Dict[str, jax.Array]] = None, with_stats: bool = False,
    ):
        """Slide-aligned engine step for incremental mode: evaluates the
        chunk once with delta state when the plan is delta-safe, else
        materializes the overlapping windows and recomputes per window —
        either way the ``[W, out_cap]`` output is bit-identical."""
        return self._engine_slides(
            view, kb if kb is not None else self.kb,
            env if env is not None else self.env, with_stats,
        )

    def _engine_slides(
        self, view: SlideView, kb: Optional[KnowledgeBase],
        env: Dict[str, jax.Array], with_stats: bool = False,
    ):
        cfg = self.config
        _, r = window_slides(cfg.window_capacity, cfg.window_step)
        if plan_supports_delta(self.plan):
            return run_plan_slides(
                self.plan, view, r, cfg.max_windows, kb, env, with_stats)
        windows = windows_from_slides(
            view, cfg.window_capacity, cfg.max_windows, cfg.window_step)
        return run_plan_windows(self.plan, windows, kb, env, with_stats)

    # -- split-sink surfaces (see engine's split-sink section) ----------------
    def process_window_tables(
        self, windows: Windows, pub_cols: Tuple[int, ...], rows_cap: int,
        kb: Optional[KnowledgeBase] = None,
        env: Optional[Dict[str, jax.Array]] = None, with_stats: bool = False,
    ):
        """Table-producing twin of :meth:`process_windows`: the operator's
        final binding table per window instead of its triple publication —
        what the split aggregation sink joins directly."""
        return run_plan_window_tables(
            self.plan, windows, pub_cols, rows_cap,
            kb if kb is not None else self.kb,
            env if env is not None else self.env, with_stats,
        )

    def process_slide_tables(
        self, view: SlideView, pub_cols: Tuple[int, ...], rows_cap: int,
        kb: Optional[KnowledgeBase] = None,
        env: Optional[Dict[str, jax.Array]] = None, with_stats: bool = False,
    ):
        """Incremental table producer: one chunk-level span-tagged table
        (requires a delta-safe plan — the split-sink builder gates on it)."""
        cfg = self.config
        _, r = window_slides(cfg.window_capacity, cfg.window_step)
        return run_plan_slide_tables(
            self.plan, view, pub_cols, rows_cap, r,
            kb if kb is not None else self.kb,
            env if env is not None else self.env, with_stats,
        )

    def process_sink_windows(
        self, windows: Windows, tables, kb: Optional[KnowledgeBase] = None,
        env: Optional[Dict[str, jax.Array]] = None, with_stats: bool = False,
    ):
        """Split-sink step over RAW windows + per-window upstream tables
        (``self.plan`` must be the rewritten plan with BindingJoin steps)."""
        return run_sink_windows(
            self.plan, windows, tables,
            kb if kb is not None else self.kb,
            env if env is not None else self.env, with_stats,
        )

    def process_sink_slides(
        self, view: SlideView, tables, kb: Optional[KnowledgeBase] = None,
        env: Optional[Dict[str, jax.Array]] = None, with_stats: bool = False,
    ):
        """Split-sink step on the delta path: the sink's own chain runs once
        per chunk over span-tagged upstream tables, finalizing per window."""
        cfg = self.config
        _, r = window_slides(cfg.window_capacity, cfg.window_step)
        return run_sink_slides(
            self.plan, view, tables, r, cfg.max_windows,
            kb if kb is not None else self.kb,
            env if env is not None else self.env, with_stats,
        )

    def _publish(self, out_w: TripleBatch) -> TripleBatch:
        """Publisher: flatten [W, cap] window outputs into one ordered chunk."""
        return publish_chunk(out_w, self.config.out_stream_cap)

    # -- checkpoint surface (repro.core.recovery) ------------------------------
    def state(self) -> Dict[str, jax.Array]:
        """Host snapshot of the operator's device-resident state — the env
        tables its steps read (published bindings, delta carry).  Blocks
        until pending computation on them completes, so a checkpoint is
        always a consistent cut."""
        return jax.device_get(self.env)

    def restore_state(self, snap: Dict[str, jax.Array], device=None) -> None:
        """Re-materialize a :meth:`state` snapshot (optionally committed to
        the operator's placed device, matching construction)."""
        self.env = (jax.device_put(snap, device) if device is not None
                    else jax.device_put(snap))

    # -- public API -----------------------------------------------------------
    def process(self, chunks: Sequence[TripleBatch]) -> Tuple[TripleBatch, jax.Array]:
        """Process one round of input chunks; returns (output chunk, overflow[W])."""
        return self._step(tuple(chunks), self.kb, self.env)

    def process_stats(self, chunks: Sequence[TripleBatch]):
        """``process`` with engine metrics: returns ``(output chunk,
        overflow[W], stats)`` where ``stats`` is a flat dict of device
        scalars (see repro.obs.metrics) — a separate jitted twin, so
        ``process`` keeps its pre-observability compiled program."""
        if self._step_stats is None:
            self._step_stats = jax.jit(
                functools.partial(self._process_impl, with_stats=True))
        return self._step_stats(tuple(chunks), self.kb, self.env)
