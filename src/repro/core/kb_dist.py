"""Distributed KB join: the KB partition itself divided across devices.

The paper's central deployment move is "divide the KB through different
machines".  Within one SCEP operator this becomes: row-shard the (sorted)
triple store over the ``model`` mesh axis (``kb.shard_rows``), evaluate the
window⋈KB join **locally per shard** with ``shard_map``, and union the
per-shard binding rows.  Because the union is a concatenation along the
sharded row axis, the join itself needs NO collectives — only the overflow
flag is ``psum``-reduced (a single bool).  Each shard owns a contiguous key
range (both KB views are key-sorted), so the probe method's ``searchsorted``
stays correct per shard.

Capacity semantics: each shard compacts its local matches into
``out_cap // n_shards`` rows; a shard-local overflow is reported even when a
global join would have fit (the price of the static layout — size
``out_cap`` to the expected match skew, exactly like sizing Kafka partition
consumers in the paper's deployment).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from . import algebra
from .kb import KnowledgeBase
from .pattern import Bindings, CompiledPattern


def kb_join_sharded(
    bind: Bindings,
    kb_blocks: KnowledgeBase,      # leaves [n_shards, per] (kb.shard_rows)
    pat: CompiledPattern,
    out_cap: int,
    mesh: Mesh,
    axis: str = "model",
    method: str = "scan",
    k_max: int = 8,
    use_pallas: bool = False,
    fuse_compaction: bool = False,
    bm: int | None = None,
    bn: int | None = None,
    interpret: bool = True,
) -> Bindings:
    """Join replicated bindings against a row-sharded KB partition.

    ``fuse_compaction`` runs the fused join->compaction pipeline *inside*
    each shard's local join: every device compacts its own matches into its
    ``out_cap // n_shards`` slice, so the no-collective union (a reshape
    along the sharded row axis) is unchanged — fusion is purely shard-local.
    """
    n = mesh.shape[axis]
    assert out_cap % n == 0, (out_cap, n)
    per_cap = out_cap // n

    def local(cols, valid, overflow, kb_block):
        kb_local = jax.tree.map(lambda a: a[0], kb_block)
        b = Bindings(cols, valid, overflow)
        out = algebra.kb_join(b, kb_local, pat, per_cap, method=method,
                              k_max=k_max, use_pallas=use_pallas,
                              fuse_compaction=fuse_compaction, bm=bm, bn=bn,
                              interpret=interpret)
        # overflow is global info: reduce the one bool over the KB axis
        ovf = jax.lax.psum(out.overflow.astype(jnp.int32), axis) > 0
        return out.cols[None], out.valid[None], ovf

    kb_spec = jax.tree.map(lambda _: P(axis), kb_blocks)
    cols, valid, overflow = shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(), P(), kb_spec),
        out_specs=(P(axis), P(axis), P()),
        check_vma=False,
    )(bind.cols, bind.valid, bind.overflow, kb_blocks)
    # shard-major union: [n, per_cap, nv] -> [out_cap, nv]
    return Bindings(cols.reshape(out_cap, bind.num_vars),
                    valid.reshape(out_cap), overflow)


def kb_join_blocks_reference(
    bind: Bindings, kb_blocks: KnowledgeBase, pat: CompiledPattern,
    out_cap: int, n: int, method: str = "scan", k_max: int = 8,
    use_pallas: bool = False, fuse_compaction: bool = False,
) -> Bindings:
    """Oracle: the same per-block join/union evaluated sequentially."""
    per_cap = out_cap // n
    cols, valids, ovf = [], [], bind.overflow
    for i in range(n):
        kb_local = jax.tree.map(lambda a: a[i], kb_blocks)
        out = algebra.kb_join(bind, kb_local, pat, per_cap, method=method,
                              k_max=k_max, use_pallas=use_pallas,
                              fuse_compaction=fuse_compaction)
        cols.append(out.cols)
        valids.append(out.valid)
        ovf = ovf | out.overflow
    return Bindings(jnp.concatenate(cols, axis=0),
                    jnp.concatenate(valids, axis=0), ovf)
