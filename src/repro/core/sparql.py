"""Textual C-SPARQL frontend: lexer, recursive-descent parser, serializer.

The paper's interface is a *semantic* continuous query stated in a
C-SPARQL-style text language (CONSTRUCT over stream windows + a background
KB), which the infrastructure decomposes into distributed SCEP operators.
This module makes that text the first-class query surface: ``parse_query``
compiles the subset the paper exercises into the existing
:mod:`repro.core.query` AST via the shared :class:`~repro.core.rdf.Vocab`
term resolver, and ``serialize_query`` emits canonical text such that
``parse_query(serialize_query(q)) == q`` (structural dataclass equality).

Supported subset (§4.3's query characteristics, Tables 1-3):

* ``REGISTER QUERY <name> AS`` prologue (C-SPARQL registration — names the
  continuous query),
* ``PREFIX pfx: <iri>`` declarations (prefixed names are resolved against
  the vocab by their ``pfx:local`` spelling; the IRI documents provenance),
* ``CONSTRUCT { ... }`` templates (vars, constants, ``_:rowN`` row nodes
  for the decomposer's binding-graph protocol) or the ``SELECT ?x ?y``
  query form (projection; lowered onto the same binding-graph protocol —
  one ``(_:row0, ?:var, ?var)`` template per projected variable),
* ``FROM STREAM <...> [RANGE TRIPLES n STEP m]`` / ``FROM <...>`` dataset
  clauses (parsed into :class:`ParseInfo`; with
  ``ExecutionConfig(window_from_query=True)`` the RANGE clause drives the
  registered query's own window geometry, and ``STEP m < n`` is real
  overlap: windows slide by ``m`` triples over slides the aggregator packs
  graph-preservingly — see :mod:`repro.core.window`),
* ``WHERE`` with: stream triple patterns, ``GRAPH <kb> { ... }`` blocks
  (plain KB patterns, fixed-length property paths ``p1/p2/p3`` with
  length <= 3, variable-length closure paths ``p+`` / ``p*`` compiled
  through the fused closure kernel, hierarchy reasoning
  ``type/subClassOf*``), ``OPTIONAL``, ``{...} UNION {...}``, and
  ``FILTER`` with numeric comparisons (negative literals included) and
  ``=`` / ``!=`` term equality on IRI/string ids, combined by ``&&`` /
  ``||`` / ``!`` (SPARQL three-valued semantics).

Term resolution is positional, matching the hand-built query builders:
names in predicate position intern via ``vocab.pred``; subject/object
position via ``vocab.term``; numeric literals via ``Vocab.number`` (the
fixed-point id encoding).  ``<dscep:id:N>`` denotes a raw interned id — the
serializer's escape hatch for ids whose vocab spelling is not a clean
prefixed name (e.g. the decomposer's ``?:var`` binding-protocol predicates),
which keeps serialization total over every AST the planner produces.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Mapping, Optional, Tuple

from . import query as Q
from .rdf import NUM_BASE, NUM_SCALE, Vocab

# default prefix -> IRI table for serialization; unknown prefixes fall back
# to a synthetic urn (resolution only keys off the prefixed-name spelling,
# but emitted declarations should document real provenance where known)
WELL_KNOWN_PREFIXES: Dict[str, str] = {
    "rdf": "http://www.w3.org/1999/02/22-rdf-syntax-ns#",
    "rdfs": "http://www.w3.org/2000/01/rdf-schema#",
    "owl": "http://www.w3.org/2002/07/owl#",
    "xsd": "http://www.w3.org/2001/XMLSchema#",
    "dbo": "http://dbpedia.org/ontology/",
    "dbr": "http://dbpedia.org/resource/",
    "schema": "http://schema.org/",
    "onyx": "http://www.gsi.upm.es/ontologies/onyx/ns#",
}


class SparqlError(ValueError):
    """Parse/serialize failure with source position context."""

    def __init__(self, message: str, line: int = 0, col: int = 0):
        self.line, self.col = line, col
        where = f" (line {line}, column {col})" if line else ""
        super().__init__(message + where)


# --------------------------------------------------------------------------
# lexer
# --------------------------------------------------------------------------

# one colon, word-ish prefix and local part: the spellings Vocab interns
# (``schema:mentions``, ``dbo:MusicalArtist``); anything else round-trips
# through the <dscep:id:N> escape.
PNAME_RE = re.compile(r"^[A-Za-z][A-Za-z0-9_.-]*:[A-Za-z0-9_.-]+$")

_TOKEN_RE = re.compile(
    r"""
    (?P<ws>\s+|\#[^\n]*)
  | (?P<var>\?[A-Za-z_][A-Za-z0-9_]*)
  | (?P<row>_:row[0-9]+)
  | (?P<iri><[^<>\s]*>)
  | (?P<num>-?[0-9]+(?:\.[0-9]+)?)
  | (?P<pname>[A-Za-z][A-Za-z0-9_.-]*:[A-Za-z0-9_.-]+)
  | (?P<nsdecl>[A-Za-z][A-Za-z0-9_.-]*:)
  | (?P<word>[A-Za-z_][A-Za-z0-9_]*)
  | (?P<op><=|>=|!=|=|<|>)
  | (?P<lop>&&|\|\|)
  | (?P<punct>[{}().\[\]/*+!])
    """,
    re.VERBOSE,
)

_KEYWORDS = {
    "REGISTER", "QUERY", "AS", "PREFIX", "CONSTRUCT", "SELECT", "FROM",
    "STREAM", "RANGE", "TRIPLES", "STEP", "WHERE", "GRAPH", "OPTIONAL",
    "UNION", "FILTER",
}


@dataclasses.dataclass(frozen=True)
class Token:
    kind: str   # var | row | iri | num | pname | nsdecl | word | op | lop | punct | eof
    text: str
    line: int
    col: int


def tokenize(text: str) -> List[Token]:
    toks: List[Token] = []
    pos, line, line_start = 0, 1, 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if m is None:
            raise SparqlError(
                "unexpected character %r" % text[pos],
                line, pos - line_start + 1,
            )
        kind = m.lastgroup
        tok_text = m.group()
        if kind != "ws":
            toks.append(Token(kind, tok_text, line, m.start() - line_start + 1))
        nl = tok_text.count("\n")
        if nl:
            line += nl
            line_start = m.start() + tok_text.rindex("\n") + 1
        pos = m.end()
    toks.append(Token("eof", "<end of query>", line, pos - line_start + 1))
    return toks


# --------------------------------------------------------------------------
# parse result metadata
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParseInfo:
    """Non-AST query metadata (C-SPARQL registration + dataset clauses)."""

    name: Optional[str] = None              # REGISTER QUERY <name> AS
    prefixes: Tuple[Tuple[str, str], ...] = ()   # (prefix, iri) declarations
    stream_iri: Optional[str] = None        # FROM STREAM <...>
    window_triples: Optional[int] = None    # [RANGE TRIPLES n ...]
    window_step: Optional[int] = None       # [... STEP m]
    kb_iris: Tuple[str, ...] = ()           # FROM <...>


_ID_IRI_RE = re.compile(r"^<dscep:id:([0-9]+)>$")
_CMP_TO_OP = {"<": "lt", "<=": "le", ">": "gt", ">=": "ge", "=": "eq", "!=": "ne"}
_OP_TO_CMP = {v: k for k, v in _CMP_TO_OP.items()}


class _Parser:
    def __init__(self, text: str, vocab: Vocab):
        self.toks = tokenize(text)
        self.i = 0
        self.vocab = vocab
        self.prefixes: Dict[str, str] = {}

    # -- token plumbing ----------------------------------------------------
    def peek(self, ahead: int = 0) -> Token:
        return self.toks[min(self.i + ahead, len(self.toks) - 1)]

    def next(self) -> Token:
        t = self.peek()
        if t.kind != "eof":
            self.i += 1
        return t

    def error(self, message: str, tok: Optional[Token] = None) -> SparqlError:
        tok = tok or self.peek()
        return SparqlError(message, tok.line, tok.col)

    def at_word(self, *words: str) -> bool:
        t = self.peek()
        return t.kind == "word" and t.text.upper() in words

    def expect_word(self, word: str) -> Token:
        if not self.at_word(word):
            raise self.error("expected %r, found %r" % (word, self.peek().text))
        return self.next()

    def expect_punct(self, ch: str) -> Token:
        t = self.peek()
        if t.kind != "punct" or t.text != ch:
            raise self.error("expected %r, found %r" % (ch, t.text))
        return self.next()

    def at_punct(self, ch: str) -> bool:
        t = self.peek()
        return t.kind == "punct" and t.text == ch

    # -- term resolution ---------------------------------------------------
    def _resolve_pname(self, tok: Token, position: str) -> int:
        prefix = tok.text.split(":", 1)[0]
        if prefix not in self.prefixes:
            raise self.error(
                "unknown prefix %r in %r — add a 'PREFIX %s: <...>' "
                "declaration" % (prefix, tok.text, prefix), tok)
        if position == "pred":
            return self.vocab.pred(tok.text)
        return self.vocab.term(tok.text)

    def term(self, position: str) -> Q.Term:
        """One subject/object term: var, pname, number, row node, or id IRI."""
        tok = self.next()
        if tok.kind == "var":
            return Q.Var(tok.text[1:])
        if tok.kind == "pname":
            return Q.Const(self._resolve_pname(tok, position))
        if tok.kind == "num":
            return Q.Const(Vocab.number(float(tok.text)))
        if tok.kind == "row":
            return Q.RowId(ns=int(tok.text[len("_:row"):]))
        if tok.kind == "iri":
            m = _ID_IRI_RE.match(tok.text)
            if m:
                return Q.Const(int(m.group(1)))
            raise self.error(
                "IRI %s is not addressable — use a PREFIXed name or "
                "<dscep:id:N>" % tok.text, tok)
        raise self.error("expected a term, found %r" % tok.text, tok)

    def _pred_segment(self) -> Tuple[int, str]:
        """One path segment: pname or <dscep:id:N>, optionally '*' / '+'."""
        tok = self.next()
        if tok.kind == "pname":
            pid = self._resolve_pname(tok, "pred")
        elif tok.kind == "iri" and _ID_IRI_RE.match(tok.text):
            pid = int(_ID_IRI_RE.match(tok.text).group(1))
        else:
            raise self.error(
                "expected a predicate name, found %r" % tok.text, tok)
        mod = ""
        if self.at_punct("*") or self.at_punct("+"):
            mod = self.next().text
        return pid, mod

    # -- prologue ----------------------------------------------------------
    def parse_prologue(self, info: dict) -> None:
        if self.at_word("REGISTER"):
            self.next()
            self.expect_word("QUERY")
            name_tok = self.next()
            if name_tok.kind not in ("word", "pname"):
                raise self.error("expected a query name after REGISTER QUERY",
                                 name_tok)
            info["name"] = name_tok.text
            self.expect_word("AS")
        while self.at_word("PREFIX"):
            self.next()
            ns = self.next()
            if ns.kind != "nsdecl":
                raise self.error("expected 'prefix:' after PREFIX", ns)
            iri = self.next()
            if iri.kind != "iri":
                raise self.error("expected <iri> in PREFIX declaration", iri)
            self.prefixes[ns.text[:-1]] = iri.text[1:-1]

    def parse_from_clauses(self, info: dict) -> None:
        while self.at_word("FROM"):
            self.next()
            if self.at_word("STREAM"):
                self.next()
                iri = self.next()
                if iri.kind != "iri":
                    raise self.error("expected <stream iri> after FROM STREAM",
                                     iri)
                info["stream_iri"] = iri.text[1:-1]
                if self.at_punct("["):
                    self.next()
                    self.expect_word("RANGE")
                    self.expect_word("TRIPLES")
                    n = self.next()
                    if (n.kind != "num" or "." in n.text or "-" in n.text
                            or int(n.text) < 1):
                        raise self.error(
                            "RANGE TRIPLES takes a positive integer", n)
                    info["window_triples"] = int(n.text)
                    if self.at_word("STEP"):
                        self.next()
                        s = self.next()
                        if (s.kind != "num" or "." in s.text or "-" in s.text
                                or int(s.text) < 1):
                            raise self.error("STEP takes a positive integer", s)
                        info["window_step"] = int(s.text)
                    self.expect_punct("]")
            else:
                iri = self.next()
                if iri.kind != "iri":
                    raise self.error("expected <iri> after FROM", iri)
                info.setdefault("kb_iris", []).append(iri.text[1:-1])

    # -- SELECT ------------------------------------------------------------
    def parse_select(
        self,
    ) -> Tuple[Tuple[str, ...], Tuple[Q.ConstructTemplate, ...]]:
        """``SELECT ?x ?y`` — lowered onto the binding-graph protocol.

        Each projected variable becomes one ``(_:row0, ?:var, ?var)``
        template, so every runtime publishes SELECT rows exactly like the
        decomposer publishes intermediate binding streams (one RDF-graph
        event per result row, keyed by a synthetic row node).
        """
        self.expect_word("SELECT")
        names: List[str] = []
        while self.peek().kind == "var":
            name = self.next().text[1:]
            if name in names:
                raise self.error("duplicate SELECT variable ?%s" % name)
            names.append(name)
        if not names:
            raise self.error("SELECT needs at least one ?variable")
        construct = tuple(
            Q.ConstructTemplate(Q.RowId(0),
                                Q.Const(self.vocab.pred("?:" + v)), Q.Var(v))
            for v in names
        )
        return tuple(names), construct

    # -- CONSTRUCT ---------------------------------------------------------
    def parse_construct(self) -> Tuple[Q.ConstructTemplate, ...]:
        self.expect_word("CONSTRUCT")
        self.expect_punct("{")
        templates: List[Q.ConstructTemplate] = []
        while not self.at_punct("}"):
            s = self.term("term")
            p = self.term("pred")
            o = self.term("term")
            templates.append(Q.ConstructTemplate(s, p, o))
            self.expect_punct(".")
        self.expect_punct("}")
        if not templates:
            raise self.error("CONSTRUCT must emit at least one template")
        return tuple(templates)

    # -- WHERE -------------------------------------------------------------
    def parse_where(self) -> Tuple[Q.WhereItem, ...]:
        self.expect_word("WHERE")
        self.expect_punct("{")
        items: List[Q.WhereItem] = []
        while not self.at_punct("}"):
            if self.at_word("GRAPH"):
                items.extend(self.parse_graph_kb())
            elif self.at_word("OPTIONAL"):
                items.append(self.parse_optional())
            elif self.at_word("FILTER"):
                items.append(self.parse_filter())
            elif self.at_punct("{"):
                items.append(self.parse_union())
            else:
                items.append(self.parse_stream_triple())
        self.expect_punct("}")
        return tuple(items)

    def parse_stream_triple(self, src: str = Q.STREAM) -> Q.Pattern:
        s = self.term("term")
        p = self.term("pred")
        o = self.term("term")
        self.expect_punct(".")
        return Q.Pattern(s, p, o, src)

    def parse_graph_kb(self) -> List[Q.WhereItem]:
        self.expect_word("GRAPH")
        iri = self.next()
        if iri.kind != "iri":
            raise self.error("expected <kb iri> after GRAPH", iri)
        self.expect_punct("{")
        items: List[Q.WhereItem] = []
        while not self.at_punct("}"):
            items.append(self.parse_kb_statement())
        self.expect_punct("}")
        return items

    def parse_kb_statement(self) -> Q.WhereItem:
        subj_tok = self.peek()
        s = self.term("term")
        # a parenthesized or '/'-chained verb is a property path / hierarchy
        # filter; a bare verb is a plain KB pattern
        if self.at_punct("("):
            self.next()
            segs = [self._pred_segment()]
            while self.at_punct("/"):
                self.next()
                segs.append(self._pred_segment())
            self.expect_punct(")")
            return self._finish_path(s, segs, subj_tok, forced_path=True)
        verb_tok = self.peek()
        if verb_tok.kind == "var":
            raise self.error(
                "variable predicates are not supported in GRAPH <kb> "
                "patterns", verb_tok)
        segs = [self._pred_segment()]
        while self.at_punct("/"):
            self.next()
            segs.append(self._pred_segment())
        return self._finish_path(s, segs, subj_tok, forced_path=False)

    def _finish_path(
        self, s: Q.Term, segs: List[Tuple[int, str]], subj_tok: Token,
        forced_path: bool,
    ) -> Q.WhereItem:
        o = self.term("term")
        self.expect_punct(".")
        mods = [mod for _, mod in segs]
        if len(segs) == 1 and mods[0]:
            # variable-length closure path `?x p+ ?y` / `?x p* ?y`
            if isinstance(s, Q.RowId) or isinstance(o, Q.RowId):
                raise self.error("row nodes cannot anchor a property path",
                                 subj_tok)
            return Q.PathClosure(s, segs[0][0], o,
                                 min_hops=0 if mods[0] == "*" else 1)
        if any(mods):
            # multi-segment modifiers: only the paper's hierarchy form
            # `type/subClassOf*` (variable instance, constant super-class)
            if len(segs) != 2 or mods != ["", "*"]:
                raise self.error(
                    "path modifiers are only supported as a single-segment "
                    "closure path '?x p+ ?y' / '?x p* ?y' or the hierarchy "
                    "form '?x type/subClassOf* Class' (exactly two "
                    "segments, star on the second)", subj_tok)
            if not isinstance(s, Q.Var):
                raise self.error(
                    "hierarchy filter subject must be a variable", subj_tok)
            if not isinstance(o, Q.Const):
                raise self.error(
                    "hierarchy filter super-class must be a constant class",
                    subj_tok)
            return Q.FilterSubclass(s.name, segs[0][0], segs[1][0], o.id)
        if len(segs) == 1 and not forced_path:
            return Q.Pattern(s, Q.Const(segs[0][0]), o, Q.KB)
        if len(segs) > 3:
            raise self.error(
                "property path of length %d exceeds the paper's maximum of 3"
                % len(segs), subj_tok)
        if isinstance(s, Q.RowId) or isinstance(o, Q.RowId):
            raise self.error("row nodes cannot anchor a property path",
                             subj_tok)
        return Q.PathKB(s, tuple(pid for pid, _ in segs), o)

    def parse_optional(self) -> Q.OptionalGroup:
        self.expect_word("OPTIONAL")
        self.expect_punct("{")
        pats: List[Q.Pattern] = []
        while not self.at_punct("}"):
            if self.at_word("GRAPH"):
                items = self.parse_graph_kb()
                for it in items:
                    if not isinstance(it, Q.Pattern):
                        raise self.error(
                            "OPTIONAL supports only plain patterns "
                            "(stream or single-predicate KB), not %s"
                            % type(it).__name__)
                    pats.append(it)
            else:
                pats.append(self.parse_stream_triple())
        self.expect_punct("}")
        if not pats:
            raise self.error("OPTIONAL group is empty")
        return Q.OptionalGroup(tuple(pats))

    def parse_union(self) -> Q.UnionGroup:
        left = self._union_branch()
        self.expect_word("UNION")
        right = self._union_branch()
        return Q.UnionGroup(left, right)

    def _union_branch(self) -> Tuple[Q.Pattern, ...]:
        self.expect_punct("{")
        pats: List[Q.Pattern] = []
        while not self.at_punct("}"):
            if self.at_word("GRAPH"):
                for it in self.parse_graph_kb():
                    if not isinstance(it, Q.Pattern):
                        raise self.error(
                            "UNION branches support only plain patterns, "
                            "not %s" % type(it).__name__)
                    pats.append(it)
            else:
                pats.append(self.parse_stream_triple())
        self.expect_punct("}")
        if not pats:
            raise self.error("UNION branch is empty")
        return tuple(pats)

    def parse_filter(self) -> Union[Q.FilterNum, Q.FilterBool]:
        """``FILTER( <bool expr> )`` — ``||`` < ``&&`` < ``!`` precedence.

        Operand lists at one precedence level become one n-ary
        :class:`~repro.core.query.FilterBool` node (``a && b && c`` is a
        single 3-ary ``and``); explicit parentheses nest instead, so every
        tree shape round-trips.  A bare comparison stays a
        :class:`~repro.core.query.FilterNum`.
        """
        self.expect_word("FILTER")
        self.expect_punct("(")
        expr = self._filter_or()
        self.expect_punct(")")
        return expr

    def _filter_or(self) -> Q.FilterExpr:
        parts = [self._filter_and()]
        while self.peek().kind == "lop" and self.peek().text == "||":
            self.next()
            parts.append(self._filter_and())
        return parts[0] if len(parts) == 1 else Q.FilterBool("or", tuple(parts))

    def _filter_and(self) -> Q.FilterExpr:
        parts = [self._filter_unary()]
        while self.peek().kind == "lop" and self.peek().text == "&&":
            self.next()
            parts.append(self._filter_unary())
        return parts[0] if len(parts) == 1 else Q.FilterBool("and", tuple(parts))

    def _filter_unary(self) -> Q.FilterExpr:
        if self.at_punct("!"):
            self.next()
            return Q.FilterBool("not", (self._filter_unary(),))
        if self.at_punct("("):
            self.next()
            expr = self._filter_or()
            self.expect_punct(")")
            return expr
        return self._filter_cmp()

    def _filter_cmp(self) -> Q.FilterNum:
        var_tok = self.next()
        if var_tok.kind != "var":
            raise self.error(
                "FILTER supports numeric comparisons on a variable, e.g. "
                "FILTER(?x >= 1.5)", var_tok)
        cmp_tok = self.next()
        if cmp_tok.kind != "op":
            raise self.error(
                "expected a comparison operator (< <= > >= = !=)", cmp_tok)
        op = _CMP_TO_OP[cmp_tok.text]
        rhs = self.next()
        if rhs.kind == "num":
            return Q.FilterNum(var_tok.text[1:], op,
                               Vocab.number(float(rhs.text)))
        # term equality: `=` / `!=` against an IRI/string id — SPARQL term
        # equality, no numeric-type coercion (and no ordering comparisons)
        if op not in ("eq", "ne"):
            raise self.error(
                "ordering comparisons (< <= > >=) need a numeric literal; "
                "IRIs and strings only support = and !=", rhs)
        if rhs.kind == "pname":
            tid = self._resolve_pname(rhs, "term")
        elif rhs.kind == "iri" and _ID_IRI_RE.match(rhs.text):
            tid = int(_ID_IRI_RE.match(rhs.text).group(1))
        else:
            raise self.error(
                "expected a numeric literal, prefixed name or <dscep:id:N> "
                "in FILTER", rhs)
        return Q.FilterNum(var_tok.text[1:], op, tid)

    # -- top level ---------------------------------------------------------
    def parse(self, default_name: Optional[str]) -> Tuple[Q.Query, ParseInfo]:
        info: dict = {}
        self.parse_prologue(info)
        select: Tuple[str, ...] = ()
        if self.at_word("SELECT"):
            select, construct = self.parse_select()
        else:
            construct = self.parse_construct()
        self.parse_from_clauses(info)
        where = self.parse_where()
        t = self.peek()
        if t.kind != "eof":
            raise self.error("unexpected trailing input %r" % t.text, t)
        name = info.get("name") or default_name or "query"
        q = Q.Query(name=name, where=where, construct=construct,
                    select=select)
        _validate(q, self)
        return q, ParseInfo(
            name=info.get("name"),
            prefixes=tuple(sorted(self.prefixes.items())),
            stream_iri=info.get("stream_iri"),
            window_triples=info.get("window_triples"),
            window_step=info.get("window_step"),
            kb_iris=tuple(info.get("kb_iris", ())),
        )


def _where_variables(q: Q.Query) -> set:
    out = set()
    for item in q.where:
        if isinstance(item, Q.Pattern):
            out |= set(item.vars())
        elif isinstance(item, (Q.PathKB, Q.PathClosure)):
            out |= {t.name for t in (item.start, item.end)
                    if isinstance(t, Q.Var)}
        elif isinstance(item, (Q.FilterNum, Q.FilterSubclass)):
            out.add(item.var)
        elif isinstance(item, Q.FilterBool):
            out |= set(item.vars())
        elif isinstance(item, Q.OptionalGroup):
            for p in item.patterns:
                out |= set(p.vars())
        elif isinstance(item, Q.UnionGroup):
            for p in item.left + item.right:
                out |= set(p.vars())
    return out


def _validate(q: Q.Query, parser: Optional[_Parser] = None) -> None:
    bound = _where_variables(q)
    kind = "SELECT" if q.select else "CONSTRUCT"
    for tpl in q.construct:
        for t in (tpl.s, tpl.p, tpl.o):
            if isinstance(t, Q.Var) and t.name not in bound:
                err = ("%s variable ?%s is not bound by any WHERE "
                       "pattern" % (kind, t.name))
                raise (parser.error(err) if parser else SparqlError(err))


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------

def parse_query_info(
    text: str, vocab: Vocab, name: Optional[str] = None
) -> Tuple[Q.Query, ParseInfo]:
    """Parse C-SPARQL text into ``(Query AST, ParseInfo metadata)``.

    ``name`` is the fallback query name when the text carries no
    ``REGISTER QUERY <name> AS`` prologue.
    """
    return _Parser(text, vocab).parse(name)


def parse_query(text: str, vocab: Vocab, name: Optional[str] = None) -> Q.Query:
    """Parse C-SPARQL text into the :class:`repro.core.query.Query` AST."""
    return parse_query_info(text, vocab, name)[0]


# --------------------------------------------------------------------------
# serializer (canonical text; parse(serialize(q)) == q)
# --------------------------------------------------------------------------

# decimals implied by the fixed-point scale (rdf.py owns the encoding); the
# formatting must track NUM_SCALE or parse(serialize(q)) == q silently breaks
_NUM_DECIMALS = max(1, int(round(math.log10(NUM_SCALE))))


def _num_text(term_id: int) -> str:
    return "%.*f" % (_NUM_DECIMALS, Vocab.decode_number(term_id))


class _Serializer:
    def __init__(self, vocab: Vocab,
                 prefix_iris: Optional[Mapping[str, str]] = None):
        self.vocab = vocab
        self.prefix_iris = dict(WELL_KNOWN_PREFIXES)
        if prefix_iris:
            self.prefix_iris.update(prefix_iris)
        self.prefixes: Dict[str, None] = {}

    def const(self, term_id: int, position: str) -> str:
        term_id = int(term_id)
        if term_id >= int(NUM_BASE):
            return _num_text(term_id)
        from .rdf import PRED_SPACE
        s = self.vocab.to_str(term_id)
        # a prefixed name only round-trips if re-parsing it in this position
        # re-interns to the same id: predicate position resolves via
        # vocab.pred (ids below PRED_SPACE), term position via vocab.term
        in_band = (term_id < PRED_SPACE) == (position == "pred")
        if in_band and PNAME_RE.match(s):
            self.prefixes.setdefault(s.split(":", 1)[0])
            return s
        return "<dscep:id:%d>" % term_id

    def term(self, t: Q.Term, position: str = "term") -> str:
        if isinstance(t, Q.Var):
            return "?%s" % t.name
        if isinstance(t, Q.RowId):
            return "_:row%d" % t.ns
        return self.const(t.id, position)

    def item(self, item: Q.WhereItem, indent: str) -> str:
        if isinstance(item, Q.Pattern):
            return "%s%s %s %s ." % (
                indent, self.term(item.s), self.term(item.p, "pred"),
                self.term(item.o))
        if isinstance(item, Q.PathKB):
            path = "/".join(self.const(p, "pred") for p in item.preds)
            if len(item.preds) == 1:
                path = "(%s)" % path     # disambiguate from a plain pattern
            return "%s%s %s %s ." % (
                indent, self.term(item.start), path, self.term(item.end))
        if isinstance(item, Q.PathClosure):
            return "%s%s %s%s %s ." % (
                indent, self.term(item.start), self.const(item.pred, "pred"),
                "*" if item.min_hops == 0 else "+", self.term(item.end))
        if isinstance(item, Q.FilterSubclass):
            return "%s?%s %s/%s* %s ." % (
                indent, item.var, self.const(item.type_pred, "pred"),
                self.const(item.subclass_pred, "pred"),
                self.const(item.super_class, "term"))
        raise SparqlError("cannot serialize %r inside a graph block" % item)

    def filter_text(self, e: Q.FilterExpr) -> str:
        """Canonical boolean-filter text; parses back to the same tree.

        Minimal parenthesization under ``|| < && < !`` precedence: nested
        same-op nodes and ``or`` under ``and`` keep explicit parens (the
        parser builds n-ary nodes from each syntactic operand list, so the
        parens are what preserve the nesting); ``!`` always parenthesizes
        its argument.
        """
        if isinstance(e, Q.FilterNum):
            rhs = (_num_text(e.value_id) if e.value_id >= int(NUM_BASE)
                   else self.const(e.value_id, "term"))
            return "?%s %s %s" % (e.var, _OP_TO_CMP[e.op], rhs)
        if e.op == "not":
            return "!(%s)" % self.filter_text(e.args[0])
        sep = " && " if e.op == "and" else " || "
        parts = []
        for a in e.args:
            text = self.filter_text(a)
            if isinstance(a, Q.FilterBool) and a.op != "not" and (
                    a.op == e.op or (e.op == "and" and a.op == "or")):
                text = "(%s)" % text
            parts.append(text)
        return sep.join(parts)

    def serialize(self, q: Q.Query, info: Optional[ParseInfo] = None) -> str:
        body: List[str] = []
        kb_kinds = (Q.PathKB, Q.PathClosure, Q.FilterSubclass)
        i = 0
        where = list(q.where)
        while i < len(where):
            item = where[i]
            is_kb = isinstance(item, kb_kinds) or (
                isinstance(item, Q.Pattern) and item.src == Q.KB)
            if is_kb:
                # consecutive KB items share one GRAPH <kb> block
                block = []
                while i < len(where):
                    it = where[i]
                    if isinstance(it, kb_kinds) or (
                            isinstance(it, Q.Pattern) and it.src == Q.KB):
                        block.append(self.item(it, "    "))
                        i += 1
                    else:
                        break
                body.append("  GRAPH <kb> {")
                body.extend(block)
                body.append("  }")
            elif isinstance(item, Q.Pattern):
                body.append(self.item(item, "  "))
                i += 1
            elif isinstance(item, (Q.FilterNum, Q.FilterBool)):
                body.append("  FILTER(%s)" % self.filter_text(item))
                i += 1
            elif isinstance(item, Q.OptionalGroup):
                body.append("  OPTIONAL {")
                for p in item.patterns:
                    if p.src == Q.KB:
                        body.append("    GRAPH <kb> { %s }"
                                    % self.item(p, "").strip())
                    else:
                        body.append(self.item(p, "    "))
                body.append("  }")
                i += 1
            elif isinstance(item, Q.UnionGroup):
                def branch(pats: Tuple[Q.Pattern, ...]) -> str:
                    parts = []
                    for p in pats:
                        text = self.item(p, "").strip()
                        if p.src == Q.KB:
                            text = "GRAPH <kb> { %s }" % text
                        parts.append(text)
                    return "{ %s }" % " ".join(parts)
                body.append("  %s UNION %s" % (branch(item.left),
                                               branch(item.right)))
                i += 1
            else:
                raise SparqlError("cannot serialize where item %r" % (item,))

        if q.select:
            # SELECT is sugar for the binding-graph templates the parser
            # synthesizes; anything else cannot re-parse to the same AST
            expected = tuple(
                Q.ConstructTemplate(Q.RowId(0),
                                    Q.Const(self.vocab.pred("?:" + v)),
                                    Q.Var(v))
                for v in q.select
            )
            if q.construct != expected:
                raise SparqlError(
                    "SELECT query %r carries construct templates that do "
                    "not match its projection — cannot serialize" % q.name)
            construct = []
        else:
            construct = ["  %s %s %s ." % (self.term(t.s),
                                           self.term(t.p, "pred"),
                                           self.term(t.o))
                         for t in q.construct]
        lines = ["REGISTER QUERY %s AS" % q.name]
        for pfx in sorted(self.prefixes):
            iri = self.prefix_iris.get(pfx, "urn:dscep:%s" % pfx)
            lines.append("PREFIX %s: <%s>" % (pfx, iri))
        if q.select:
            lines.append("SELECT " + " ".join("?%s" % v for v in q.select))
        else:
            lines.append("CONSTRUCT {")
            lines.extend(construct)
            lines.append("}")
        if info is not None:
            if info.stream_iri:
                clause = "FROM STREAM <%s>" % info.stream_iri
                if info.window_triples:
                    clause += " [RANGE TRIPLES %d" % info.window_triples
                    if info.window_step:
                        clause += " STEP %d" % info.window_step
                    clause += "]"
                lines.append(clause)
            for kb_iri in info.kb_iris:
                lines.append("FROM <%s>" % kb_iri)
        lines.append("WHERE {")
        lines.extend(body)
        lines.append("}")
        return "\n".join(lines) + "\n"


def serialize_query(
    q: Q.Query, vocab: Vocab,
    prefix_iris: Optional[Mapping[str, str]] = None,
    info: Optional[ParseInfo] = None,
) -> str:
    """Serialize a Query AST to canonical C-SPARQL text.

    The output always re-parses to a structurally equal AST:
    ``parse_query(serialize_query(q, v), v) == q``.  Constants whose vocab
    spelling is not a clean prefixed name are emitted as ``<dscep:id:N>``.
    ``prefix_iris`` overrides the emitted ``PREFIX`` IRIs (e.g. the
    declarations captured in :class:`ParseInfo`); well-known namespaces
    default to their real IRIs, anything else to ``urn:dscep:<prefix>``.
    ``info`` additionally emits the registration's dataset clauses
    (``FROM STREAM <...> [RANGE TRIPLES n STEP m]`` / ``FROM <...>``), so
    per-query window geometry survives a serialize/parse round trip.
    """
    return _Serializer(vocab, prefix_iris).serialize(q, info)
