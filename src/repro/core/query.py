"""Continuous-query AST (the user-facing query surface).

Covers every SPARQL characteristic the paper's CQuery1 exercises (§4.3):
property paths (len <= 3), CONSTRUCT, UNION, OPTIONAL, hierarchy reasoning
(rdfs:subClassOf via closure sets), and KB access.  Patterns are tagged with
their source: the windowed stream or the background KB.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union


@dataclasses.dataclass(frozen=True)
class Var:
    name: str


@dataclasses.dataclass(frozen=True)
class Const:
    id: int


@dataclasses.dataclass(frozen=True)
class RowId:
    """CONSTRUCT subject that materializes a fresh per-binding row node.

    Used by the decomposer's binding-graph protocol: each result row of a
    sub-query is published as one RDF-graph event keyed by a synthetic node
    (``rdf.ROW_BASE + ns·2^18 + row index``), so the aggregation operator
    joins the published variables of the SAME binding row — never a cross
    product of independently published values.  ``ns`` namespaces the id
    range per operator: two operators publishing the same variable must not
    alias each other's rows.
    """

    ns: int = 0


Term = Union[Var, Const]

STREAM = "stream"
KB = "kb"


@dataclasses.dataclass(frozen=True)
class Pattern:
    s: Term
    p: Term
    o: Term
    src: str = STREAM      # STREAM or KB

    def vars(self) -> Tuple[str, ...]:
        return tuple(t.name for t in (self.s, self.p, self.o) if isinstance(t, Var))


@dataclasses.dataclass(frozen=True)
class PathKB:
    """Property path of fixed length <= 3 through the KB: start -p1/p2/p3-> end."""

    start: Term
    preds: Tuple[int, ...]
    end: Term

    def __post_init__(self):
        assert 1 <= len(self.preds) <= 3, "paper paths have max length 3"


@dataclasses.dataclass(frozen=True)
class PathClosure:
    """Variable-length property path through the KB: ``start p+ end`` /
    ``start p* end``.

    ``min_hops=1`` is SPARQL ``p+`` (one or more edges); ``min_hops=0`` is
    ``p*`` (zero or more).  The zero-length case is reflexive over the nodes
    of the predicate's edge graph plus any constant endpoint of the path
    expression — not over the unbounded universe of terms (SPARQL's ``p*``
    over all graph terms has no bounded-tensor analogue).  The planner
    compiles this through the fused :mod:`repro.kernels.closure` ops into a
    materialized closure-pair relation, never an unrolled join chain.
    """

    start: Term
    pred: int
    end: Term
    min_hops: int = 1       # 1 = p+, 0 = p*

    def __post_init__(self):
        assert self.min_hops in (0, 1), "closure paths are p+ or p*"


@dataclasses.dataclass(frozen=True)
class FilterNum:
    """One FILTER comparison leaf.

    ``value_id >= rdf.NUM_BASE`` is a fixed-point numeric literal and admits
    every ordering operator; a ``value_id`` below the numeric band is an
    IRI/string term id and the comparison is SPARQL *term equality* —
    ``eq``/``ne`` only (the parser enforces this), unbound variables are a
    type error either way.
    """

    var: str
    op: str           # lt | le | gt | ge | eq | ne
    value_id: int     # fixed-point numeric literal id, or an IRI/string id


@dataclasses.dataclass(frozen=True)
class FilterBool:
    """Boolean FILTER combination over numeric comparisons.

    ``op`` is ``and`` / ``or`` (n-ary, >= 2 args) or ``not`` (1 arg); leaves
    are :class:`FilterNum`.  Evaluation follows SPARQL's three-valued logic:
    a comparison on a non-numeric binding is an *error*, errors absorb
    through ``!``/``&&``/``||`` unless a definite ``false`` (for ``&&``) or
    ``true`` (for ``||``) decides the value, and rows whose filter result is
    not definitely true are dropped.
    """

    op: str                                       # and | or | not
    args: Tuple["FilterExpr", ...]

    def __post_init__(self):
        assert self.op in ("and", "or", "not"), self.op
        assert len(self.args) == 1 if self.op == "not" else len(self.args) >= 2

    def vars(self) -> Tuple[str, ...]:
        out: Dict[str, None] = {}

        def walk(e):
            if isinstance(e, FilterNum):
                out.setdefault(e.var, None)
            else:
                for a in e.args:
                    walk(a)

        walk(self)
        return tuple(out)


FilterExpr = Union[FilterNum, FilterBool]


@dataclasses.dataclass(frozen=True)
class FilterSubclass:
    """var rdf:type / rdfs:subClassOf* super_class — hierarchy reasoning."""

    var: str
    type_pred: int
    subclass_pred: int
    super_class: int


@dataclasses.dataclass(frozen=True)
class OptionalGroup:
    patterns: Tuple[Pattern, ...]


@dataclasses.dataclass(frozen=True)
class UnionGroup:
    left: Tuple[Pattern, ...]
    right: Tuple[Pattern, ...]


WhereItem = Union[Pattern, PathKB, PathClosure, FilterNum, FilterBool,
                  FilterSubclass, OptionalGroup, UnionGroup]


@dataclasses.dataclass(frozen=True)
class ConstructTemplate:
    s: Term
    p: Term
    o: Term


@dataclasses.dataclass(frozen=True)
class Query:
    """CONSTRUCT (or SELECT) query over (stream window, KB).

    ``select`` is the projection of the SELECT query form: when non-empty,
    ``construct`` holds the equivalent binding-graph templates (one
    ``(_:row0, ?:var, ?var)`` triple per projected variable — the same
    row-node protocol the decomposer publishes intermediate streams with),
    so every runtime executes SELECT queries unchanged.
    """

    name: str
    where: Tuple[WhereItem, ...]
    construct: Tuple[ConstructTemplate, ...]
    select: Tuple[str, ...] = ()

    def variables(self) -> List[str]:
        # dict-as-ordered-set: membership is O(1), first-seen order preserved
        # (machine-generated queries from the parser can carry thousands of
        # variable occurrences — `name not in list` scans made this O(n²))
        out: Dict[str, None] = {}

        def add(t: Term):
            if isinstance(t, Var):
                out.setdefault(t.name, None)

        for item in self.where:
            if isinstance(item, Pattern):
                for t in (item.s, item.p, item.o):
                    add(t)
            elif isinstance(item, (PathKB, PathClosure)):
                add(item.start)
                add(item.end)
            elif isinstance(item, (FilterNum, FilterSubclass)):
                out.setdefault(item.var, None)
            elif isinstance(item, FilterBool):
                for v in item.vars():
                    out.setdefault(v, None)
            elif isinstance(item, OptionalGroup):
                for p in item.patterns:
                    for t in (p.s, p.p, p.o):
                        add(t)
            elif isinstance(item, UnionGroup):
                for p in item.left + item.right:
                    for t in (p.s, p.p, p.o):
                        add(t)
        for tpl in self.construct:
            for t in (tpl.s, tpl.p, tpl.o):
                add(t)
        return list(out)

    def kb_predicates(self) -> List[int]:
        preds: List[int] = []

        def visit(item):
            if isinstance(item, Pattern) and item.src == KB and isinstance(item.p, Const):
                preds.append(item.p.id)
            elif isinstance(item, PathKB):
                preds.extend(item.preds)
            elif isinstance(item, PathClosure):
                preds.append(item.pred)
            elif isinstance(item, FilterSubclass):
                preds.extend([item.type_pred, item.subclass_pred])
            elif isinstance(item, OptionalGroup):
                for p in item.patterns:
                    visit(p)
            elif isinstance(item, UnionGroup):
                for p in item.left + item.right:
                    visit(p)

        for item in self.where:
            visit(item)
        return sorted(set(preds))
