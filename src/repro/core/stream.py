"""RDF stream plumbing: the Aggregator's merge/order stage.

The paper's Aggregator "will merge all input RDF streams into one, order the
events on the new resulting stream, divide it into windows and send it to the
attached RSP engine" (§2).  Merging and ordering are jit-compiled here; window
division lives in :mod:`repro.core.window`.
"""
from __future__ import annotations

from typing import Callable, Iterator, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .rdf import TripleBatch, concat_triples, sort_by_timestamp


def merge_streams(chunks: Sequence[TripleBatch]) -> TripleBatch:
    """Merge K stream chunks into one timestamp-ordered chunk.

    Each input is monotone in ``ts`` (paper assumption 3); the merged output is
    globally ordered, invalid rows compacted to the tail.  Implemented as
    concat + stable lexsort — an O(n log n) vectorized merge that XLA fuses
    well; per-stream monotonicity is *not* required for correctness, only for
    the paper's latency semantics.

    Two hot-path fast paths (K=1 is the per-chunk case in the runtimes):

    * a single input skips the concatenation entirely;
    * the lexsort runs under ``lax.cond`` on an O(n) already-ordered check,
      so an input that is already in merge order (valid-first, then
      non-decreasing ``(ts, graph)``) pays a scan instead of a sort.  The
      check is exact — when it passes, the stable lexsort is the identity —
      so results are bit-identical either way.
    """
    batch = chunks[0] if len(chunks) == 1 else concat_triples(list(chunks))
    big = jnp.uint32(0xFFFFFFFF)
    ts_key = jnp.where(batch.valid, batch.ts, big)
    ordered = jnp.all(
        (ts_key[1:] > ts_key[:-1])
        | ((ts_key[1:] == ts_key[:-1]) & (batch.graph[1:] >= batch.graph[:-1]))
    ) if batch.capacity > 1 else jnp.bool_(True)
    return jax.lax.cond(ordered, lambda b: b, sort_by_timestamp, batch)


merge_streams_jit = jax.jit(merge_streams)


class StreamSource:
    """Host-side pull source wrapping a chunk iterator (a *Stream Generator*).

    ``capacity`` is the static chunk width every pulled TripleBatch is padded
    to, so downstream jit programs see one shape.
    """

    def __init__(self, it: Iterator[TripleBatch], capacity: int):
        self._it = it
        self.capacity = capacity
        self._done = False

    def pull(self) -> TripleBatch | None:
        if self._done:
            return None
        try:
            chunk = next(self._it)
        except StopIteration:
            self._done = True
            return None
        cap = chunk.capacity
        if cap > self.capacity:
            raise ValueError("chunk capacity %d > source capacity %d" % (cap, self.capacity))
        if cap < self.capacity:
            pad = self.capacity - cap
            chunk = jax.tree.map(
                lambda col: jnp.pad(col, ((0, pad),)), chunk
            )
        return chunk


def round_robin_chunks(sources: List[StreamSource]) -> Iterator[TripleBatch]:
    """Interleave several sources into merged, ordered chunks (Aggregator in)."""
    while True:
        chunks = [c for c in (s.pull() for s in sources) if c is not None]
        if not chunks:
            return
        yield merge_streams_jit(chunks)
