"""Capacity-bounded device channels: the inter-operator transport.

The paper wires SCEP operators together with Kafka topics — bounded queues
of RDF events between independently scheduled processes.  This module is the
TPU/JAX analogue: a **fixed-shape ring buffer living in device memory** whose
push/pop are pure jittable ops.  An operator step embeds the pop of its
inbound edge in its own XLA program; pushes onto an edge run as their own
small program on the *consumer's* device (channels live with their
consumer, and one XLA program cannot span devices).  Channel state is
donated in either case — updated in place, never re-allocated.

A :class:`Channel` carries any fixed-shape pytree payload; in the DSCEP
pipeline the payloads are window-aligned batches — :class:`~repro.core.window.Windows`
on the source→aggregator edge and ``(TripleBatch[W, out_cap], overflow[W])``
on operator→aggregator edges (the Publisher→Aggregator edge made
first-class).

Semantics (all shapes static, all state device-resident):

* ``push`` into a **full** channel drops the *new* payload and increments the
  ``overflows`` counter — bounded-queue backpressure is observable, never
  silent (Kafka analogue: producer overrun on a size-capped topic).
* ``pop`` from an **empty** channel returns the zero payload with
  ``valid=False`` and leaves the state untouched.
* ``size``/``overflows`` are ``int32`` scalars on device; the host driver
  reads them only for monitoring/asserts, never to schedule (the schedule is
  deterministic, see :mod:`repro.core.pipeline`).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Channel(NamedTuple):
    """A bounded ring buffer over a pytree payload.

    ``slots`` holds ``capacity`` payloads stacked on a new leading axis;
    ``head`` indexes the oldest element; ``size`` is the occupancy.  The
    NamedTuple is itself a pytree, so channels pass through ``jax.jit``
    (including as donated arguments) and ``jax.device_put`` unchanged.
    """

    slots: Any            # payload pytree; every leaf is [capacity, ...]
    head: jax.Array       # int32 scalar — ring index of the oldest element
    size: jax.Array       # int32 scalar — occupancy in [0, capacity]
    overflows: jax.Array  # int32 scalar — pushes dropped because full

    @property
    def capacity(self) -> int:
        return int(jax.tree.leaves(self.slots)[0].shape[0])


def make_channel(payload_example: Any, capacity: int) -> Channel:
    """Allocate an empty channel shaped to hold ``capacity`` payloads.

    ``payload_example`` fixes the per-slot shapes/dtypes (its values are not
    stored); every slot starts zeroed so a pop-when-empty yields PAD rows.
    """
    if capacity < 1:
        raise ValueError("channel capacity must be >= 1, got %d" % capacity)
    slots = jax.tree.map(
        lambda leaf: jnp.zeros((capacity,) + jnp.shape(leaf), jnp.asarray(leaf).dtype),
        payload_example,
    )
    # three *distinct* zero buffers: the channel is donated as one pytree,
    # and XLA rejects donating one buffer through several arguments
    return Channel(
        slots=slots,
        head=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32),
        overflows=jnp.zeros((), jnp.int32),
    )


def push(ch: Channel, payload: Any) -> Channel:
    """Enqueue ``payload``; a full channel drops it and counts the overflow.

    The slot write is under ``lax.cond``: a push into a full channel — every
    backpressure event on the hot inter-operator path — must not pay the
    [capacity, ...]-sized scatter for a payload it is about to drop.  The
    drop-new semantics are unchanged (pinned by tests/test_channel.py).
    """
    cap = ch.capacity
    full = ch.size >= cap
    tail = jax.lax.rem(ch.head + ch.size, jnp.int32(cap))

    def write(slots):
        return jax.tree.map(lambda buf, x: buf.at[tail].set(x), slots, payload)

    slots = jax.lax.cond(full, lambda slots: slots, write, ch.slots)
    return Channel(
        slots=slots,
        head=ch.head,
        size=jnp.where(full, ch.size, ch.size + 1),
        overflows=ch.overflows + full.astype(jnp.int32),
    )


def pop(ch: Channel) -> Tuple[Channel, Any, jax.Array]:
    """Dequeue the oldest payload; returns ``(channel', payload, valid)``.

    An empty channel is left unchanged and yields the zero payload with
    ``valid=False`` (shape-stable: callers mask, they never branch).
    """
    cap = ch.capacity
    empty = ch.size <= 0
    payload = jax.tree.map(lambda buf: buf[ch.head], ch.slots)
    payload = jax.tree.map(
        lambda x: jnp.where(empty, jnp.zeros_like(x), x), payload
    )
    new = Channel(
        slots=ch.slots,
        head=jnp.where(empty, ch.head, jax.lax.rem(ch.head + 1, jnp.int32(cap))),
        size=jnp.maximum(ch.size - 1, 0),
        overflows=ch.overflows,
    )
    return new, payload, ~empty


def occupancy(ch: Channel) -> jax.Array:
    """Current number of queued payloads (int32 scalar, device-resident)."""
    return ch.size


def snapshot(ch: Channel) -> Channel:
    """Deep host copy of a channel's ring state (checkpoint ingredient).

    Channel buffers are *donated* to every push/pop step — holding a device
    reference across a step reads deleted buffers, so a checkpoint must
    materialize the ring on host.  ``device_get`` blocks until in-flight
    writes land, making the copy a consistent cut."""
    return jax.device_get(ch)


def restore(snap: Channel, device=None) -> Channel:
    """Re-materialize a :func:`snapshot` on device (the consumer's device
    under placement, mirroring :func:`make_channel` allocation)."""
    return jax.device_put(snap, device) if device is not None \
        else jax.device_put(snap)


# jitted conveniences with in-place (donated) channel state — an operator
# step embeds push/pop in its own program instead, but tests and host-side
# drivers use these directly.
push_jit = jax.jit(push, donate_argnums=0)
pop_jit = jax.jit(pop, donate_argnums=0)
