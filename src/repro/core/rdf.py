"""Dictionary-encoded RDF terms and triple tensors.

DSCEP streams RDF triples annotated with timestamps (paper §2).  On TPU we
cannot move strings; every term (URI, blank node, literal) is interned into a
``uint32`` id space by :class:`Vocab`.  The id space is split so that composite
sort keys fit in 32 bits without requiring x64:

* predicates:      ``[1, PRED_SPACE)``            (< 2**12 ids)
* URIs / strings:  ``[PRED_SPACE, NUM_BASE)``     (< 2**20 ids)
* numeric literals: ``[NUM_BASE, 2**32)`` encoded as
  ``NUM_BASE + NUM_OFFSET + round(v * NUM_SCALE)`` — the ``NUM_OFFSET``
  zero point keeps ids order-isomorphic to values while admitting negative
  literals (``v >= -NUM_OFFSET / NUM_SCALE``)

id 0 is the reserved PAD/NULL term (also the SPARQL unbound value produced by
OPTIONAL).  Composite probe keys are ``(p << TERM_BITS) | term`` which fits in
an unsigned 32-bit integer because predicates use 12 bits and terms 20 bits.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

PAD_ID = 0
PRED_BITS = 12
TERM_BITS = 20
PRED_SPACE = 1 << PRED_BITS          # predicate ids live in [1, 4096)
# top of the predicate band is reserved for per-query synthetic predicates:
# the planner materializes each variable-length path (p+/p*) as a closure
# pair relation under CLOSURE_PRED_BASE + spec_index (see planner.py), so
# vocab-interned predicates must stay below the band
CLOSURE_PRED_BASE = PRED_SPACE - 64
TERM_SPACE = 1 << TERM_BITS          # term ids live in [PRED_SPACE, 2**20)
NUM_BASE = np.uint32(1 << 30)        # numeric literals live above this
NUM_SCALE = 100.0                    # fixed-point scale for numeric literals
# fixed-point zero: value v encodes as NUM_BASE + NUM_OFFSET + round(v*SCALE),
# so ids above NUM_BASE stay order-isomorphic to values and negative literals
# (FILTER(?v > -5)) encode below the zero point instead of being rejected
NUM_OFFSET = 1 << 29
# synthetic per-binding row nodes (the binding-graph protocol between SCEP
# operators) live in the free band between URI terms and numeric literals
ROW_BASE = np.uint32(1 << 21)

TermLike = Union[str, int, float]


class VocabError(ValueError):
    pass


class Vocab:
    """Bidirectional interning of RDF terms into the split uint32 id space."""

    def __init__(self) -> None:
        self._pred_to_id: Dict[str, int] = {}
        self._term_to_id: Dict[str, int] = {}
        self._id_to_str: Dict[int, str] = {PAD_ID: "<pad>"}
        self._next_pred = 1
        self._next_term = PRED_SPACE

    # -- encoding ----------------------------------------------------------
    def pred(self, name: str) -> int:
        pid = self._pred_to_id.get(name)
        if pid is None:
            if self._next_pred >= CLOSURE_PRED_BASE:
                raise VocabError(
                    "predicate space exhausted (max %d; the top band is "
                    "reserved for synthetic closure predicates)"
                    % CLOSURE_PRED_BASE)
            pid = self._next_pred
            self._next_pred += 1
            self._pred_to_id[name] = pid
            self._id_to_str[pid] = name
        return pid

    def term(self, name: TermLike) -> int:
        if isinstance(name, (int, float)) and not isinstance(name, bool):
            return self.number(float(name))
        tid = self._term_to_id.get(name)
        if tid is None:
            if self._next_term >= PRED_SPACE + TERM_SPACE:
                raise VocabError("term space exhausted (max %d)" % TERM_SPACE)
            tid = self._next_term
            self._next_term += 1
            self._term_to_id[name] = tid
            self._id_to_str[tid] = name
        return tid

    @staticmethod
    def number(value: float) -> int:
        """Encode a numeric literal as a fixed-point id (order-isomorphic)."""
        q = int(round(value * NUM_SCALE)) + NUM_OFFSET
        if q < 0:
            raise VocabError(
                "literal %r below the encodable range (min %s)"
                % (value, -NUM_OFFSET / NUM_SCALE))
        if int(NUM_BASE) + q > 0xFFFFFFFF:
            raise VocabError(
                "literal %r above the encodable range (max %s)"
                % (value, (0xFFFFFFFF - int(NUM_BASE) - NUM_OFFSET) / NUM_SCALE))
        return int(NUM_BASE) + q

    @staticmethod
    def is_number(term_id: int) -> bool:
        return int(term_id) >= int(NUM_BASE)

    @staticmethod
    def decode_number(term_id: int) -> float:
        return (int(term_id) - int(NUM_BASE) - NUM_OFFSET) / NUM_SCALE

    # -- decoding ----------------------------------------------------------
    def to_str(self, term_id: int) -> str:
        term_id = int(term_id)
        if term_id >= int(NUM_BASE):
            return repr(self.decode_number(term_id))
        return self._id_to_str.get(term_id, "<unk:%d>" % term_id)

    @property
    def num_preds(self) -> int:
        return self._next_pred

    @property
    def num_terms(self) -> int:
        return self._next_term - PRED_SPACE


def composite_key(p, term):
    """``(p << TERM_BITS) | low_bits(term)`` probe key, uint32-safe.

    Terms are offset by PRED_SPACE so they fit in TERM_BITS bits; numeric
    literals are hashed into the same width (probes on numeric objects are
    never used for KB access in the shipped query plans, but collisions only
    cost verification work — the join always re-checks equality exactly).
    """
    p = jnp.asarray(p, jnp.uint32)
    t = jnp.asarray(term, jnp.uint32)
    low = jnp.where(
        t >= jnp.uint32(NUM_BASE),
        (t ^ (t >> jnp.uint32(TERM_BITS))) & jnp.uint32(TERM_SPACE - 1),
        (t - jnp.uint32(PRED_SPACE)) & jnp.uint32(TERM_SPACE - 1),
    )
    low = jnp.where(t == jnp.uint32(PAD_ID), jnp.uint32(0), low)
    return (p << jnp.uint32(TERM_BITS)) | low


class TripleBatch(NamedTuple):
    """Struct-of-arrays batch of timestamped triples (a stream chunk).

    All arrays share shape ``[N]``; ``valid`` masks real rows.  ``graph``
    groups triples into RDF-graph events (paper §2: graph events carry a
    timestamp on every member triple).
    """

    s: jax.Array      # uint32 subject ids
    p: jax.Array      # uint32 predicate ids
    o: jax.Array      # uint32 object ids
    ts: jax.Array     # uint32 event timestamps (monotonic per stream)
    graph: jax.Array  # uint32 graph/event ids
    valid: jax.Array  # bool

    @property
    def capacity(self) -> int:
        return int(self.s.shape[-1])

    def count(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32), axis=-1)


def empty_triples(capacity: int) -> TripleBatch:
    z = jnp.zeros((capacity,), jnp.uint32)
    return TripleBatch(z, z, z, z, z, jnp.zeros((capacity,), bool))


def make_triples(
    rows: Sequence[Tuple[int, int, int, int, int]], capacity: Optional[int] = None
) -> TripleBatch:
    """Build a TripleBatch from host-side ``(s, p, o, ts, graph)`` rows."""
    n = len(rows)
    cap = capacity if capacity is not None else max(n, 1)
    if n > cap:
        raise ValueError("rows (%d) exceed capacity (%d)" % (n, cap))
    arr = np.zeros((cap, 5), np.uint32)
    if n:
        arr[:n] = np.asarray(rows, np.uint32)
    valid = np.zeros((cap,), bool)
    valid[:n] = True
    return TripleBatch(
        jnp.asarray(arr[:, 0]), jnp.asarray(arr[:, 1]), jnp.asarray(arr[:, 2]),
        jnp.asarray(arr[:, 3]), jnp.asarray(arr[:, 4]), jnp.asarray(valid),
    )


def concat_triples(batches: Sequence[TripleBatch]) -> TripleBatch:
    return TripleBatch(*(jnp.concatenate(cols, axis=-1) for cols in zip(*batches)))


def sort_by_timestamp(batch: TripleBatch) -> TripleBatch:
    """Stable sort by (invalid-last, ts, graph) — the Aggregator's merge order."""
    big = jnp.uint32(0xFFFFFFFF)
    ts_key = jnp.where(batch.valid, batch.ts, big)
    order = jnp.lexsort((batch.graph, ts_key))
    return jax.tree.map(lambda col: jnp.take(col, order, axis=-1), batch)


def take_rows(batch: TripleBatch, idx: jax.Array) -> TripleBatch:
    """Gather rows by index; idx == -1 yields an invalid PAD row."""
    safe = jnp.where(idx < 0, 0, idx)
    out = jax.tree.map(lambda col: jnp.take(col, safe, axis=-1), batch)
    ok = (idx >= 0) & out.valid
    return out._replace(valid=ok)


def to_host_rows(batch: TripleBatch) -> List[Tuple[int, int, int, int, int]]:
    """Debug/Publisher helper: valid rows as python tuples."""
    s, p, o, ts, g, v = (np.asarray(x) for x in batch)
    return [
        (int(s[i]), int(p[i]), int(o[i]), int(ts[i]), int(g[i]))
        for i in range(len(v))
        if v[i]
    ]
