"""Ontology reasoning support: rdfs:subClassOf hierarchies and owl:sameAs.

The paper's Q15/CQuery1 use hierarchical reasoning ("all tweets that mention
any entity that is a subclass of MusicalArtist").  Two complementary forms:

* **plan-time**: host-side closure sets (sorted id arrays) consumed by
  ``filter_in`` and by KB pruning — this is how DSCEP distributes reasoning
  work into each operator's used-KB slice;
* **jit-time**: transitive closure as iterated boolean matrix product —
  MXU-shaped; :mod:`repro.kernels.closure` provides the Pallas kernel and
  ``closure_matmul`` is the jnp oracle used by default.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kb import KnowledgeBase, host_rows


# --------------------------------------------------------------------------
# plan-time closure sets
# --------------------------------------------------------------------------

def subclass_edges(kb: KnowledgeBase, subclass_pred: int) -> List[Tuple[int, int]]:
    rows = host_rows(kb)
    m = rows[:, 1] == np.uint32(subclass_pred)
    return [(int(s), int(o)) for s, _, o in rows[m]]


def descendants(
    edges: Sequence[Tuple[int, int]], root: int, include_root: bool = True
) -> np.ndarray:
    """All classes c with c rdfs:subClassOf* root — sorted uint32 ids."""
    children: Dict[int, List[int]] = defaultdict(list)
    for child, parent in edges:
        children[parent].append(child)
    seen: Set[int] = {root} if include_root else set()
    frontier = [root]
    while frontier:
        nxt = []
        for node in frontier:
            for ch in children.get(node, ()):  # DAG-safe BFS
                if ch not in seen:
                    seen.add(ch)
                    nxt.append(ch)
        frontier = nxt
    return np.asarray(sorted(seen), np.uint32)


def same_as_canonical(kb: KnowledgeBase, sameas_pred: int) -> Dict[int, int]:
    """Union-find canonicalization map for owl:sameAs cliques (plan-time)."""
    rows = host_rows(kb)
    m = rows[:, 1] == np.uint32(sameas_pred)
    parent: Dict[int, int] = {}

    def find(x: int) -> int:
        while parent.get(x, x) != x:
            parent[x] = parent.get(parent[x], parent[x])
            x = parent[x]
        return x

    for s, _, o in rows[m]:
        rs, ro = find(int(s)), find(int(o))
        if rs != ro:
            parent[max(rs, ro)] = min(rs, ro)
    return {x: find(x) for x in list(parent)}


# --------------------------------------------------------------------------
# jit-time transitive closure (boolean matmul fixpoint)
# --------------------------------------------------------------------------

def closure_matmul(adj: jax.Array, max_depth: int | None = None) -> jax.Array:
    """Reflexive-transitive closure of a boolean adjacency matrix.

    Repeated squaring: log2(diameter) boolean matmuls, each an MXU-friendly
    ``float32`` product + threshold.  ``adj[i, j]`` = class i subClassOf j.
    """
    n = adj.shape[-1]
    reach = adj.astype(jnp.float32) + jnp.eye(n, dtype=jnp.float32)
    steps = max(1, int(np.ceil(np.log2(max(2, max_depth or n)))))
    for _ in range(steps):
        reach = jnp.minimum(reach @ reach, 1.0)
    return reach > 0.5


def closure_set_from_matrix(reach: jax.Array, root_index: int) -> jax.Array:
    """Row mask of classes reaching ``root_index`` (i.e. its descendants)."""
    return reach[:, root_index]


def build_class_index(edges: Sequence[Tuple[int, int]]) -> Tuple[Dict[int, int], np.ndarray]:
    """Dense index for class ids appearing in subclass edges."""
    ids = sorted({x for e in edges for x in e})
    idx = {cid: i for i, cid in enumerate(ids)}
    return idx, np.asarray(ids, np.uint32)


def adjacency_from_edges(
    edges: Sequence[Tuple[int, int]], idx: Dict[int, int]
) -> np.ndarray:
    n = len(idx)
    adj = np.zeros((max(n, 1), max(n, 1)), np.float32)
    for child, parent in edges:
        adj[idx[child], idx[parent]] = 1.0
    return adj
