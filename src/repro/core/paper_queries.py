"""The paper's evaluation queries (§4.3) as Query ASTs.

* ``q15`` / ``q16`` — SRBench-adapted first-step queries: hierarchy reasoning
  (rdfs:subClassOf) and a length-3 property path, respectively (Table 1).
* ``cquery1`` — the second-step complex query: "how television-show entities
  affect the sentiment analysis of each musical artist when mentioned on the
  same tweet", exercising every SPARQL characteristic the paper lists —
  property path (len 3), CONSTRUCT, UNION, OPTIONAL, hierarchy reasoning and
  KB access (Tables 2-3, Fig. 4).

Builders take the shared vocabulary plus the stream/KB schemas so tests,
benchmarks and examples all use the identical queries.
"""
from __future__ import annotations

from repro.core import query as Q
from repro.core.rdf import Vocab
from repro.data.dbpedia import KBSchema
from repro.data.tweets import TweetSchema


def q15(vocab: Vocab, ts: TweetSchema, kbs: KBSchema) -> Q.Query:
    """All tweets mentioning any entity that is a subclass of MusicalArtist."""
    return Q.Query(
        name="q15",
        where=(
            Q.Pattern(Q.Var("tweet"), Q.Const(ts.mentions), Q.Var("ent"), Q.STREAM),
            Q.FilterSubclass("ent", kbs.rdf_type, kbs.subclass_of,
                             kbs.musical_artist),
        ),
        construct=(
            Q.ConstructTemplate(Q.Var("tweet"),
                                Q.Const(vocab.pred("out:artistTweet")),
                                Q.Var("ent")),
        ),
    )


def q16(vocab: Vocab, ts: TweetSchema, kbs: KBSchema) -> Q.Query:
    """For tweets mentioning a musical artist: birthplace -> country -> code."""
    return Q.Query(
        name="q16",
        where=(
            Q.Pattern(Q.Var("tweet"), Q.Const(ts.mentions), Q.Var("ent"), Q.STREAM),
            Q.PathKB(Q.Var("ent"), (kbs.birth_place, kbs.country, kbs.country_code),
                     Q.Var("cc")),
        ),
        construct=(
            Q.ConstructTemplate(Q.Var("tweet"), Q.Const(vocab.pred("out:code")),
                                Q.Var("cc")),
        ),
    )


def cquery1(vocab: Vocab, ts: TweetSchema, kbs: KBSchema) -> Q.Query:
    """The paper's CQuery1 (§4.3, second step).

    Correlates musical artists with television shows co-mentioned on the same
    tweet, carrying the tweet's sentiment, the artist's country code (property
    path of length 3), engagement from likes OR shares (UNION), and the
    optional share count (OPTIONAL).  The automatic decomposition
    (:func:`repro.core.planner.decompose`) splits it into the paper's Fig. 4
    shape: an artist-anchored KB operator (QueryA analogue — subclass
    reasoning + property path, the large used-KB slice), a show-anchored KB
    operator (QueryB analogue — subclass reasoning only), and a final
    aggregation operator (QueryG) joining the intermediate binding streams
    with the sentiment/engagement stream patterns (the QueryC-F analogues run
    as dataflow branches inside the aggregator's compiled plan).
    """
    return Q.Query(
        name="cquery1",
        where=(
            # -- stream side: co-mention + sentiment --------------------------
            Q.Pattern(Q.Var("tweet"), Q.Const(ts.mentions), Q.Var("artist"), Q.STREAM),
            Q.Pattern(Q.Var("tweet"), Q.Const(ts.mentions), Q.Var("show"), Q.STREAM),
            Q.Pattern(Q.Var("tweet"), Q.Const(ts.sentiment_pos), Q.Var("pos"), Q.STREAM),
            Q.Pattern(Q.Var("tweet"), Q.Const(ts.sentiment_neg), Q.Var("neg"), Q.STREAM),
            # -- KB side: hierarchy reasoning for both classes ----------------
            Q.FilterSubclass("artist", kbs.rdf_type, kbs.subclass_of,
                             kbs.musical_artist),
            Q.FilterSubclass("show", kbs.rdf_type, kbs.subclass_of,
                             kbs.television_show),
            # -- KB side: property path of length 3 ---------------------------
            Q.PathKB(Q.Var("artist"),
                     (kbs.birth_place, kbs.country, kbs.country_code),
                     Q.Var("cc")),
            # -- UNION: engagement signal from likes or shares ----------------
            Q.UnionGroup(
                left=(Q.Pattern(Q.Var("tweet"), Q.Const(ts.likes),
                                Q.Var("eng"), Q.STREAM),),
                right=(Q.Pattern(Q.Var("tweet"), Q.Const(ts.shares),
                                 Q.Var("eng"), Q.STREAM),),
            ),
            # -- OPTIONAL: share count may be absent ---------------------------
            Q.OptionalGroup(
                patterns=(Q.Pattern(Q.Var("tweet"), Q.Const(ts.shares),
                                    Q.Var("sh"), Q.STREAM),),
            ),
            # -- FILTER: meaningful sentiment only -----------------------------
            Q.FilterNum("pos", "ge", Vocab.number(0.0)),
        ),
        construct=(
            Q.ConstructTemplate(Q.Var("artist"),
                                Q.Const(vocab.pred("out:coMentionedWith")),
                                Q.Var("show")),
            Q.ConstructTemplate(Q.Var("artist"),
                                Q.Const(vocab.pred("out:posSentiment")),
                                Q.Var("pos")),
            Q.ConstructTemplate(Q.Var("artist"),
                                Q.Const(vocab.pred("out:negSentiment")),
                                Q.Var("neg")),
            Q.ConstructTemplate(Q.Var("artist"),
                                Q.Const(vocab.pred("out:countryCode")),
                                Q.Var("cc")),
        ),
    )
