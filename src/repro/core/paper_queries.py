"""The paper's evaluation queries (§4.3) as C-SPARQL text, parsed at load.

* ``q15`` / ``q16`` — SRBench-adapted first-step queries: hierarchy reasoning
  (rdfs:subClassOf) and a length-3 property path, respectively (Table 1).
* ``cquery1`` — the second-step complex query: "how television-show entities
  affect the sentiment analysis of each musical artist when mentioned on the
  same tweet", exercising every SPARQL characteristic the paper lists —
  property path (len 3), CONSTRUCT, UNION, OPTIONAL, hierarchy reasoning and
  KB access (Tables 2-3, Fig. 4).

The ``.rq`` text below is the source of truth; each builder parses it with
:func:`repro.core.sparql.parse_query` against the shared vocabulary, so the
resulting ASTs are guaranteed equal to the former hand-built dataclass
builders (tests/test_sparql.py pins both the AST equality and the
``parse(serialize(q)) == q`` round trip).  Builders keep their historical
``(vocab, tweet_schema, kb_schema)`` signature: the schema objects intern
exactly the prefixed names the text references, so creating them against the
same vocab is what makes the parsed ids line up with the stream/KB encoders.
"""
from __future__ import annotations

from repro.core import query as Q
from repro.core.rdf import Vocab
from repro.core.sparql import parse_query
from repro.data.dbpedia import KBSchema
from repro.data.tweets import TweetSchema

Q15_RQ = """\
REGISTER QUERY q15 AS
PREFIX schema: <urn:dscep:schema>
PREFIX rdf: <http://www.w3.org/1999/02/22-rdf-syntax-ns#>
PREFIX rdfs: <http://www.w3.org/2000/01/rdf-schema#>
PREFIX dbo: <http://dbpedia.org/ontology/>
PREFIX out: <urn:dscep:out>
CONSTRUCT {
  ?tweet out:artistTweet ?ent .
}
FROM STREAM <stream> [RANGE TRIPLES 1000 STEP 1]
FROM <kb>
WHERE {
  ?tweet schema:mentions ?ent .
  GRAPH <kb> {
    ?ent rdf:type/rdfs:subClassOf* dbo:MusicalArtist .
  }
}
"""

Q16_RQ = """\
REGISTER QUERY q16 AS
PREFIX schema: <urn:dscep:schema>
PREFIX dbo: <http://dbpedia.org/ontology/>
PREFIX out: <urn:dscep:out>
CONSTRUCT {
  ?tweet out:code ?cc .
}
FROM STREAM <stream> [RANGE TRIPLES 1000 STEP 1]
FROM <kb>
WHERE {
  ?tweet schema:mentions ?ent .
  GRAPH <kb> {
    ?ent dbo:birthPlace/dbo:country/dbo:countryCode ?cc .
  }
}
"""

CQUERY1_RQ = """\
REGISTER QUERY cquery1 AS
PREFIX schema: <urn:dscep:schema>
PREFIX onyx: <urn:dscep:onyx>
PREFIX rdf: <http://www.w3.org/1999/02/22-rdf-syntax-ns#>
PREFIX rdfs: <http://www.w3.org/2000/01/rdf-schema#>
PREFIX dbo: <http://dbpedia.org/ontology/>
PREFIX out: <urn:dscep:out>
CONSTRUCT {
  ?artist out:coMentionedWith ?show .
  ?artist out:posSentiment ?pos .
  ?artist out:negSentiment ?neg .
  ?artist out:countryCode ?cc .
}
FROM STREAM <stream> [RANGE TRIPLES 1000 STEP 1]
FROM <kb>
WHERE {
  ?tweet schema:mentions ?artist .
  ?tweet schema:mentions ?show .
  ?tweet onyx:positiveEmotion ?pos .
  ?tweet onyx:negativeEmotion ?neg .
  GRAPH <kb> {
    ?artist rdf:type/rdfs:subClassOf* dbo:MusicalArtist .
    ?show rdf:type/rdfs:subClassOf* dbo:TelevisionShow .
    ?artist dbo:birthPlace/dbo:country/dbo:countryCode ?cc .
  }
  { ?tweet schema:likes ?eng . } UNION { ?tweet schema:shares ?eng . }
  OPTIONAL { ?tweet schema:shares ?sh . }
  FILTER(?pos >= 0.00)
}
"""

RQ_TEXTS = {"q15": Q15_RQ, "q16": Q16_RQ, "cquery1": CQUERY1_RQ}


def _check_schemas(vocab: Vocab, ts: TweetSchema, kbs: KBSchema) -> None:
    # the query text resolves prefixed names against `vocab`; the schema
    # handles must have been interned in that same vocab or the parsed ids
    # would silently mismatch the stream/KB encoding
    if (vocab.pred("schema:mentions") != ts.mentions
            or vocab.pred("rdf:type") != kbs.rdf_type):
        raise ValueError(
            "tweet/KB schema was created against a different Vocab than the "
            "one given — paper queries need the shared vocabulary")


def q15(vocab: Vocab, ts: TweetSchema, kbs: KBSchema) -> Q.Query:
    """All tweets mentioning any entity that is a subclass of MusicalArtist."""
    _check_schemas(vocab, ts, kbs)
    return parse_query(Q15_RQ, vocab)


def q16(vocab: Vocab, ts: TweetSchema, kbs: KBSchema) -> Q.Query:
    """For tweets mentioning a musical artist: birthplace -> country -> code."""
    _check_schemas(vocab, ts, kbs)
    return parse_query(Q16_RQ, vocab)


def cquery1(vocab: Vocab, ts: TweetSchema, kbs: KBSchema) -> Q.Query:
    """The paper's CQuery1 (§4.3, second step).

    Correlates musical artists with television shows co-mentioned on the same
    tweet, carrying the tweet's sentiment, the artist's country code (property
    path of length 3), engagement from likes OR shares (UNION), and the
    optional share count (OPTIONAL).  The automatic decomposition
    (:func:`repro.core.planner.decompose`) splits it into the paper's Fig. 4
    shape: an artist-anchored KB operator (QueryA analogue — subclass
    reasoning + property path, the large used-KB slice), a show-anchored KB
    operator (QueryB analogue — subclass reasoning only), and a final
    aggregation operator (QueryG) joining the intermediate binding streams
    with the sentiment/engagement stream patterns (the QueryC-F analogues run
    as dataflow branches inside the aggregator's compiled plan).
    """
    _check_schemas(vocab, ts, kbs)
    return parse_query(CQUERY1_RQ, vocab)
