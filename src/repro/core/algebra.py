"""Vectorized relational algebra over triple windows and KB partitions.

This is the RSP-engine compute core: every SPARQL feature the paper's
evaluation uses (§4.3 CQuery1 characteristics) has a static-shape, jit-able
operator here:

* basic graph patterns      -> ``scan_pattern`` + ``join``
* KB access (two methods)   -> ``kb_join`` (``method="scan" | "probe"``;
                               the planner's ``kb_method="auto"`` cost model
                               resolves the choice per join at plan time)
* FILTER (numeric / term-eq / set) -> ``filter_num`` / ``filter_in``
* UNION                     -> ``union``
* OPTIONAL                  -> ``optional_join``
* property paths (len<=3)   -> chained ``kb_join`` steps (planner emits them)
* CONSTRUCT                 -> ``construct``
* hierarchy reasoning       -> closure sets from :mod:`repro.core.reasoner`
                               consumed via ``filter_in`` / pruned KBs

Everything is deterministic and order-preserving so that the decomposed and
monolithic executions of a query produce identical results (paper: "All
results are the same" — property-tested in tests/test_equivalence.py).

The O(|bind| x |KB|) candidate matrix of the scan method is the compute
hotspot; :mod:`repro.kernels.hash_join` provides the Pallas TPU kernel with
identical semantics (``use_pallas=True`` switches the engine over), and
``fuse_compaction=True`` additionally fuses match + compaction so the
candidate matrix never round-trips through HBM (see kb_join_scan).
"""
from __future__ import annotations

import functools
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.obs.metrics import stat_max

from .kb import KnowledgeBase, gather_matches, probe_range
from .pattern import Bindings, CompiledPattern, SlotMode, compact_rows
from .rdf import NUM_BASE, PAD_ID, TripleBatch, composite_key


# --------------------------------------------------------------------------
# pattern scan over a window
# --------------------------------------------------------------------------

def _slot_match(slot, col_vals, bind_row=None):
    if slot.mode == SlotMode.CONST:
        return col_vals == jnp.uint32(slot.const)
    if slot.mode == SlotMode.BOUND:
        assert bind_row is not None
        return col_vals == bind_row[..., slot.var]
    return jnp.ones_like(col_vals, dtype=bool)


def scan_pattern(
    window: TripleBatch, pat: CompiledPattern, num_vars: int, out_cap: int
) -> Bindings:
    """Match one triple pattern against the window; emit fresh bindings."""
    cols = {0: window.s, 1: window.p, 2: window.o}
    m = window.valid
    for i, slot in enumerate((pat.s, pat.p, pat.o)):
        m = m & _slot_match(slot, cols[i])
    # repeated free variables inside one pattern must agree
    slots = (pat.s, pat.p, pat.o)
    for i in range(3):
        for j in range(i + 1, 3):
            if (
                slots[i].mode != SlotMode.CONST
                and slots[j].mode != SlotMode.CONST
                and slots[i].var == slots[j].var
            ):
                m = m & (cols[i] == cols[j])

    n = window.capacity
    out = jnp.zeros((n, num_vars), jnp.uint32)
    for i, slot in enumerate(slots):
        if slot.mode != SlotMode.CONST:
            out = out.at[:, slot.var].set(cols[i])
    rows, valid, overflow = compact_rows(out, m, out_cap)
    return Bindings(rows, valid, overflow)


# --------------------------------------------------------------------------
# natural join (used by BGP conjunction and by the final aggregation operator)
# --------------------------------------------------------------------------

def join(a: Bindings, b: Bindings, shared: Tuple[int, ...], out_cap: int) -> Bindings:
    """Natural join on the static shared-variable columns."""
    ca, cb = a.capacity, b.capacity
    m = a.valid[:, None] & b.valid[None, :]
    for c in shared:
        m = m & (a.cols[:, None, c] == b.cols[None, :, c])
    merged = jnp.maximum(a.cols[:, None, :], b.cols[None, :, :])  # PAD=0 ⇒ max merges
    flat_rows = merged.reshape(ca * cb, a.num_vars)
    flat_mask = m.reshape(ca * cb)
    rows, valid, overflow = compact_rows(flat_rows, flat_mask, out_cap)
    return Bindings(rows, valid, overflow | a.overflow | b.overflow)


def union(a: Bindings, b: Bindings, out_cap: int) -> Bindings:
    rows = jnp.concatenate([a.cols, b.cols], axis=0)
    mask = jnp.concatenate([a.valid, b.valid], axis=0)
    out, valid, overflow = compact_rows(rows, mask, out_cap)
    return Bindings(out, valid, overflow | a.overflow | b.overflow)


def optional_join(
    a: Bindings, b: Bindings, shared: Tuple[int, ...], out_cap: int
) -> Bindings:
    """SPARQL OPTIONAL: left outer join; unmatched left rows keep PAD columns."""
    ca, cb = a.capacity, b.capacity
    m = a.valid[:, None] & b.valid[None, :]
    for c in shared:
        m = m & (a.cols[:, None, c] == b.cols[None, :, c])
    matched_any = jnp.any(m, axis=1)
    merged = jnp.maximum(a.cols[:, None, :], b.cols[None, :, :])
    flat_rows = jnp.concatenate(
        [merged.reshape(ca * cb, a.num_vars), a.cols], axis=0
    )
    flat_mask = jnp.concatenate([m.reshape(ca * cb), a.valid & ~matched_any], axis=0)
    rows, valid, overflow = compact_rows(flat_rows, flat_mask, out_cap)
    return Bindings(rows, valid, overflow | a.overflow | b.overflow)


# --------------------------------------------------------------------------
# KB access — the paper's two measured methods
# --------------------------------------------------------------------------

def _kb_scan_match(bind: Bindings, kb: KnowledgeBase, pat: CompiledPattern):
    """O(cap x N) candidate matrix — the C-SPARQL "KB access" method."""
    kcols = {0: kb.s_ps, 1: kb.p_ps, 2: kb.o_ps}
    m = bind.valid[:, None] & kb.valid[None, :]
    for i, slot in enumerate((pat.s, pat.p, pat.o)):
        kv = kcols[i][None, :]
        if slot.mode == SlotMode.CONST:
            m = m & (kv == jnp.uint32(slot.const))
        elif slot.mode == SlotMode.BOUND:
            m = m & (kv == bind.cols[:, slot.var][:, None])
    slots = (pat.s, pat.p, pat.o)
    for i in range(3):
        for j in range(i + 1, 3):
            if (
                slots[i].mode != SlotMode.CONST
                and slots[j].mode != SlotMode.CONST
                and slots[i].var == slots[j].var
            ):
                m = m & (kcols[i][None, :] == kcols[j][None, :])
    return m


def _extend_rows(bind_cols, kb_row_cols, pat: CompiledPattern):
    """Extend binding rows with the pattern's FREE vars taken from KB rows."""
    out = bind_cols
    for i, slot in enumerate((pat.s, pat.p, pat.o)):
        if slot.mode == SlotMode.FREE:
            out = out.at[..., slot.var].set(kb_row_cols[i])
    return out


def kb_join_scan(
    bind: Bindings, kb: KnowledgeBase, pat: CompiledPattern, out_cap: int,
    use_pallas: bool = False, fuse_compaction: bool = False,
    bm: Optional[int] = None, bn: Optional[int] = None,
    interpret: bool = True,
) -> Bindings:
    """Join bindings against a KB partition by full scan.

    Cost is linear in the *total* partition size — this is precisely the
    behaviour of paper Figs. 6/7 (unused triples still cost time), and the
    reason KB pruning/partitioning wins.

    ``fuse_compaction=True`` selects the fused join->compaction pipeline
    (:mod:`repro.kernels.hash_join.ops`): with ``use_pallas`` the Pallas
    kernel compacts matches tile-by-tile so the ``[cap, N]`` candidate
    matrix never reaches HBM; without it, a gather-based jnp formulation
    skips the ``[cap, N, nv]`` row-extension materialization.  All four
    paths are bit-identical.
    """
    if fuse_compaction:
        from repro.kernels.hash_join import ops as hj_ops
        if use_pallas:
            return hj_ops.join_compact(bind, kb, pat, out_cap, bm=bm, bn=bn,
                                       interpret=interpret)
        return hj_ops.join_compact_jnp(bind, kb, pat, out_cap)
    if use_pallas:
        from repro.kernels.hash_join import ops as hj_ops
        m = hj_ops.match_matrix(bind, kb, pat, bm=bm, bn=bn,
                                interpret=interpret)
    else:
        m = _kb_scan_match(bind, kb, pat)
    ca, n = m.shape
    bind_exp = jnp.broadcast_to(bind.cols[:, None, :], (ca, n, bind.num_vars))
    kb_rows = (kb.s_ps[None, :], kb.p_ps[None, :], kb.o_ps[None, :])
    kb_rows = tuple(jnp.broadcast_to(c, (ca, n)) for c in kb_rows)
    ext = _extend_rows(bind_exp, kb_rows, pat)
    rows, valid, overflow = compact_rows(
        ext.reshape(ca * n, bind.num_vars), m.reshape(ca * n), out_cap
    )
    return Bindings(rows, valid, overflow | bind.overflow)


def _probe_width_hw(bind: Bindings, kb: KnowledgeBase, pat: CompiledPattern):
    """Widest probe range (``hi - lo``) over valid binding rows — the number
    ``k_max`` must dominate for the probe to be lossless.  Used by the fused
    probe paths, which never materialize ``lo``/``hi`` outside the kernel;
    only traced when metrics are enabled."""
    from .kb import probe_view

    ca = bind.capacity

    def anchor_val(slot):
        if slot.mode == SlotMode.CONST:
            return jnp.full((ca,), jnp.uint32(slot.const))
        return bind.cols[:, slot.var]

    sorted_keys, _, anchor, _ = probe_view(kb, pat)
    keys = composite_key(jnp.uint32(pat.p.const), anchor_val(anchor))
    lo, hi = probe_range(sorted_keys, keys)
    width = (hi - lo).astype(jnp.int32)
    return jnp.max(jnp.where(bind.valid, width, 0))


def kb_join_probe(
    bind: Bindings, kb: KnowledgeBase, pat: CompiledPattern, out_cap: int,
    k_max: int = 8, use_pallas: bool = False, fuse_compaction: bool = False,
    bm: Optional[int] = None, interpret: bool = True,
    stats: Optional[Dict[str, Any]] = None,
) -> Bindings:
    """Join bindings against the KB via sorted-index probes.

    The SPARQL-subquery/SERVICE analogue: per binding row one O(log N)
    searchsorted + <= k_max gathers, independent of unused-KB size.  Requires
    a CONST predicate and at least one CONST/BOUND endpoint (the planner
    guarantees this or falls back to scan).

    ``use_pallas=True`` runs the fused Pallas probe kernel
    (:func:`repro.kernels.hash_join.ops.probe_compact`: searchsorted +
    bounded gather + anchor re-check + compaction in one kernel pass);
    ``fuse_compaction=True`` without Pallas selects the winner-gather jnp
    twin.  All three paths are bit-identical, including both overflow
    sources (``out_cap`` clipping and probe ranges wider than ``k_max``).
    """
    if use_pallas or fuse_compaction:
        if stats is not None:
            stat_max(stats, "hw_probe_k", _probe_width_hw(bind, kb, pat))
        from repro.kernels.hash_join import ops as hj_ops
        if use_pallas:
            return hj_ops.probe_compact(bind, kb, pat, out_cap, k_max,
                                        bm=bm, interpret=interpret)
        return hj_ops.probe_compact_jnp(bind, kb, pat, out_cap, k_max)

    from .kb import probe_view

    p_const = jnp.uint32(pat.p.const)
    ca = bind.capacity

    def anchor_val(slot):
        if slot.mode == SlotMode.CONST:
            return jnp.full((ca,), jnp.uint32(slot.const))
        return bind.cols[:, slot.var]

    sorted_keys, cols, anchor, _ = probe_view(kb, pat)
    keys = composite_key(p_const, anchor_val(anchor))

    lo, hi = probe_range(sorted_keys, keys)
    if stats is not None:
        stat_max(stats, "hw_probe_k",
                 jnp.max(jnp.where(bind.valid, (hi - lo).astype(jnp.int32), 0)))
    (ms, mp, mo), ok, overflow_rows = gather_matches(cols, lo, hi, k_max)
    kcols = {0: ms, 1: mp, 2: mo}
    m = ok & bind.valid[:, None]
    # verify the non-anchored endpoint (and re-check anchors exactly: the
    # composite key hashes numeric literals, so equality must be confirmed)
    for i, slot in enumerate((pat.s, pat.p, pat.o)):
        if slot.mode == SlotMode.CONST:
            m = m & (kcols[i] == jnp.uint32(slot.const))
        elif slot.mode == SlotMode.BOUND:
            m = m & (kcols[i] == bind.cols[:, slot.var][:, None])

    bind_exp = jnp.broadcast_to(bind.cols[:, None, :], (ca, k_max, bind.num_vars))
    ext = _extend_rows(bind_exp, (ms, mp, mo), pat)
    rows, valid, overflow = compact_rows(
        ext.reshape(ca * k_max, bind.num_vars), m.reshape(ca * k_max), out_cap
    )
    any_overflow = overflow | jnp.any(overflow_rows & bind.valid) | bind.overflow
    return Bindings(rows, valid, any_overflow)


def kb_join(
    bind: Bindings, kb: KnowledgeBase, pat: CompiledPattern, out_cap: int,
    method: str = "scan", k_max: int = 8, use_pallas: bool = False,
    fuse_compaction: bool = False, bm: Optional[int] = None,
    bn: Optional[int] = None, interpret: bool = True,
    stats: Optional[Dict[str, Any]] = None,
) -> Bindings:
    """Dispatch one KB join to its access method.

    ``method`` arrives resolved from the plan: the planner's
    ``kb_method="auto"`` cost model has already replaced itself with
    ``"scan"`` or ``"probe"`` (plus a derived ``k_max``) per
    :class:`~repro.core.engine.KBJoin` step, so no cost decision happens at
    trace time.  An ineligible probe (variable predicate or no anchored
    endpoint) still falls back to the scan, preserving semantics for
    hand-built plans.
    """
    if method == "probe" and pat.p.mode == SlotMode.CONST and not (
        pat.s.mode == SlotMode.FREE and pat.o.mode == SlotMode.FREE
    ):
        return kb_join_probe(bind, kb, pat, out_cap, k_max,
                             use_pallas=use_pallas,
                             fuse_compaction=fuse_compaction, bm=bm,
                             interpret=interpret, stats=stats)
    return kb_join_scan(bind, kb, pat, out_cap, use_pallas=use_pallas,
                        fuse_compaction=fuse_compaction, bm=bm, bn=bn,
                        interpret=interpret)


# --------------------------------------------------------------------------
# filters / projection / dedup
# --------------------------------------------------------------------------

_NUM_OPS = ("lt", "le", "gt", "ge", "eq", "ne")


class BatchedConst(NamedTuple):
    """A filter literal whose *value* may be a traced uint32 scalar while its
    term-vs-numeric classification stays python-static.

    The comparison semantics below branch on ``value_id < NUM_BASE`` at
    trace time; cohort batching (repro.serve) vmaps one plan over a
    per-query constant axis, so the value becomes a tracer.  The planner's
    ``bind_plan_consts`` records the representative's static classification
    here (it is part of the cohort shape key, so every member agrees), and
    the traced ops stay identical to the unbatched plan's.
    """

    val: Any            # python int or traced uint32 scalar
    is_term: bool       # static: term-equality vs numeric-comparison leaf


def _num_cmp(bind: Bindings, var: int, op: str, value_id):
    """Shared comparison leaf: ``(true mask, error mask)``.

    Numeric right-hand sides (``value_id >= NUM_BASE``) compare fixed-point
    ids; the error mask marks non-numeric bindings (SPARQL type error).
    Term right-hand sides (IRI/string ids) are SPARQL *term equality* —
    only ``eq``/``ne``, no type coercion; the error mask marks unbound
    bindings.  Both ``filter_num`` and the boolean-tree evaluator consume
    this, so the comparison semantics live in exactly one place.
    """
    assert op in _NUM_OPS, op
    if isinstance(value_id, BatchedConst):
        value_id, is_term = value_id.val, value_id.is_term
    else:
        is_term = int(value_id) < int(NUM_BASE)
    v = bind.cols[:, var]
    t = jnp.uint32(value_id)
    if is_term:
        assert op in ("eq", "ne"), (
            "term comparisons support only eq/ne, got %r" % op)
        err = v == jnp.uint32(PAD_ID)
        cmp = (v == t) if op == "eq" else (v != t)
        return cmp & ~err, err
    is_num = v >= jnp.uint32(NUM_BASE)
    cmp = {
        "lt": v < t, "le": v <= t, "gt": v > t,
        "ge": v >= t, "eq": v == t, "ne": v != t,
    }[op]
    return cmp & is_num, ~is_num


def filter_num(bind: Bindings, var: int, op: str, value_id: int) -> Bindings:
    """Numeric FILTER — fixed-point literal ids are order-isomorphic to values."""
    val, err = _num_cmp(bind, var, op, value_id)
    return bind._replace(valid=bind.valid & val & ~err)


def _bool_eval(bind: Bindings, expr: Tuple) -> Tuple[jax.Array, jax.Array]:
    """Evaluate a compiled boolean filter tree to ``(true, error)`` row masks.

    SPARQL three-valued logic over fixed-shape masks: a comparison on a
    non-numeric binding is an *error*; ``!`` preserves errors; ``&&`` is
    false if any arg is definitely false (errors notwithstanding), ``||``
    true if any arg is definitely true; otherwise any arg error makes the
    result an error.  The representation keeps ``true & error == 0``.
    """
    kind = expr[0]
    if kind == "cmp":
        _, var, op, value_id = expr
        return _num_cmp(bind, var, op, value_id)
    if kind == "not":
        val, err = _bool_eval(bind, expr[1])
        return ~val & ~err, err
    vals, errs = zip(*(_bool_eval(bind, a) for a in expr[1:]))
    any_err = functools.reduce(jnp.logical_or, errs)
    if kind == "and":
        any_false = functools.reduce(
            jnp.logical_or, (~v & ~e for v, e in zip(vals, errs)))
        all_true = functools.reduce(jnp.logical_and, vals)
        return all_true & ~any_err, any_err & ~any_false
    if kind == "or":
        any_true = functools.reduce(jnp.logical_or, vals)
        return any_true, any_err & ~any_true
    raise ValueError("unknown filter expr %r" % (expr,))


def filter_bool(bind: Bindings, expr: Tuple) -> Bindings:
    """Boolean FILTER combination (compiled ``("and"|"or"|"not"|"cmp", ...)``
    tuple tree); keeps rows whose filter evaluates to definite true."""
    val, err = _bool_eval(bind, expr)
    return bind._replace(valid=bind.valid & val & ~err)


def filter_in(bind: Bindings, var: int, sorted_ids: jax.Array) -> Bindings:
    """Set-membership FILTER (e.g. subclass-closure sets from the reasoner)."""
    v = bind.cols[:, var]
    pos = jnp.searchsorted(sorted_ids, v)
    pos = jnp.minimum(pos, sorted_ids.shape[0] - 1)
    member = jnp.take(sorted_ids, pos) == v
    return bind._replace(valid=bind.valid & member)


def filter_bound(bind: Bindings, var: int) -> Bindings:
    return bind._replace(valid=bind.valid & (bind.cols[:, var] != PAD_ID))


def project(bind: Bindings, keep: Tuple[int, ...]) -> Bindings:
    mask = jnp.zeros((bind.num_vars,), bool).at[jnp.asarray(keep, jnp.int32)].set(True)
    return bind._replace(cols=jnp.where(mask[None, :], bind.cols, jnp.uint32(PAD_ID)))


def canonical_order(bind: Bindings, sig_cols: Tuple[int, ...]) -> Bindings:
    """Sort valid rows lexicographically by ``sig_cols`` (invalid last).

    Join order is an execution detail (monolithic vs decomposed plans visit
    patterns differently), but the *published* stream must not depend on it:
    the runtimes' bit-identical-across-modes guarantee needs one canonical
    row order for equal binding sets, not whatever order the joins happened
    to emit.  ``sig_cols`` lists the output columns most-significant first
    and must be derived from something plans share — the engine passes
    template columns ordered by *variable name*, since column numbering
    itself differs between a monolithic plan and a decomposed aggregator.
    Applied after the pre-CONSTRUCT distinct, where rows are the
    deduplicated projection onto template variables.
    """
    keys = tuple(bind.cols[:, c] for c in reversed(sig_cols))
    inv = (~bind.valid).astype(jnp.uint32)
    order = jnp.lexsort(keys + (inv,))
    return Bindings(
        jnp.take(bind.cols, order, axis=0), jnp.take(bind.valid, order),
        bind.overflow,
    )


def distinct(bind: Bindings, out_cap: Optional[int] = None) -> Bindings:
    """Deduplicate valid rows (order of first occurrence preserved)."""
    out_cap = out_cap or bind.capacity
    nv = bind.num_vars
    # lexsort by columns with invalids last, stable on original index
    keys = [bind.cols[:, c] for c in range(nv - 1, -1, -1)]
    inv = (~bind.valid).astype(jnp.uint32)
    order = jnp.lexsort(tuple(keys) + (inv,))
    sorted_cols = jnp.take(bind.cols, order, axis=0)
    sorted_valid = jnp.take(bind.valid, order)
    prev = jnp.concatenate([jnp.zeros((1, nv), jnp.uint32), sorted_cols[:-1]], axis=0)
    first_at0 = jnp.arange(bind.capacity) == 0
    is_new = jnp.any(sorted_cols != prev, axis=1) | first_at0
    keep = sorted_valid & is_new
    # restore original order for determinism
    restore = jnp.argsort(order)
    keep_orig = jnp.take(keep, restore)
    rows, valid, overflow = compact_rows(bind.cols, keep_orig, out_cap)
    return Bindings(rows, valid, overflow | bind.overflow)


# --------------------------------------------------------------------------
# CONSTRUCT — derive the output RDF stream
# --------------------------------------------------------------------------

def construct(
    bind: Bindings,
    templates: Sequence[Tuple],   # ((mode,val), (mode,val), (mode,val)) per triple
    ts: jax.Array,
    out_cap: int,
    graph_base: jax.Array | int = 0,
) -> Tuple[TripleBatch, jax.Array]:
    """Emit one RDF-graph event per binding row from CONSTRUCT templates.

    Template slots are ``("const", id)`` or ``("var", col)``.  The Publisher
    stamps every produced triple with ``ts`` (paper §2: the Publisher adds
    timestamps when the engine's output lacks them) and assigns graph ids so
    downstream operators see well-formed graph events.  Returns the output
    batch plus an overflow flag (set when ``out_cap`` clipped valid rows).
    """
    cap = bind.capacity
    t = len(templates)

    def slot_vals(spec):
        kind, val = spec
        if kind == "const":
            return jnp.full((cap,), jnp.uint32(val))
        if kind == "row":     # synthetic per-binding row node (ROW_BASE band,
            from .rdf import ROW_BASE           # val = operator namespace)
            return (jnp.arange(cap, dtype=jnp.uint32) + jnp.uint32(val)
                    + jnp.uint32(graph_base) + ROW_BASE)
        return bind.cols[:, val]

    s_list, p_list, o_list = [], [], []
    for spec_s, spec_p, spec_o in templates:
        s_list.append(slot_vals(spec_s))
        p_list.append(slot_vals(spec_p))
        o_list.append(slot_vals(spec_o))
    s = jnp.stack(s_list, axis=1).reshape(cap * t)      # row-major: graph-contiguous
    p = jnp.stack(p_list, axis=1).reshape(cap * t)
    o = jnp.stack(o_list, axis=1).reshape(cap * t)
    graph = (jnp.arange(cap, dtype=jnp.uint32)[:, None] + jnp.uint32(graph_base))
    graph = jnp.broadcast_to(graph, (cap, t)).reshape(cap * t)
    mask = jnp.repeat(bind.valid, t)
    rows = jnp.stack([s, p, o, jnp.broadcast_to(jnp.uint32(ts), s.shape), graph], axis=1)
    out, valid, overflow = compact_rows(rows, mask, out_cap)
    return TripleBatch(
        s=out[:, 0], p=out[:, 1], o=out[:, 2], ts=out[:, 3], graph=out[:, 4],
        valid=valid,
    ), overflow


# --------------------------------------------------------------------------
# incremental (delta) evaluation — slide-span tracking
# --------------------------------------------------------------------------
#
# Sliding count windows overlap on whole slides (window w = slides
# w..w+R-1, see core/window.py), and every plan step the planner emits for
# a window-alignable query is *monotone* in the stream triples it consumes:
# a joined binding row exists in window w iff all its contributing stream
# triples do.  So instead of re-running the join chain per window, the
# engine can evaluate the merged chunk ONCE, tracking for every binding row
# the interval [min_slide, max_slide] of contributing slides, and then
# select window w's rows with an interval test — the insert half of a
# classic delta evaluation.  The retract half is just as cheap: spans only
# grow under joins, so any row whose span already exceeds R-1 slides can
# never again belong to a window and is retracted eagerly
# (``delta_retract``), and per-window retraction of expired rows is the
# ``min_slide >= w`` side of the membership test (``delta_window_mask``).
#
# The interval rides in two extra uint32 columns appended after the
# ``num_vars`` variable columns, encoded so that the elementwise
# ``jnp.maximum`` merge ``join`` already performs combines spans correctly:
#
#   col nv     = max_slide + 1                  ("enc_max"; 0 = no triples)
#   col nv + 1 = SPAN_ENC_K - (min_slide + 1)   ("enc_min" complement)
#
# max of enc_max is the span's max; max of the complement is the span's
# min.  A row with no stream triples yet (the universe row, or KB-only
# derivations) has both columns 0 and belongs to every window.  All other
# operators (kb_join, filters, union, compaction) treat binding columns
# opaquely, so the span columns flow through the full step vocabulary
# except OPTIONAL (non-monotone — plans containing it fall back to
# per-window recompute; see planner.plan_supports_delta).

SPAN_ENC_K = 0xFFFFFFFF


def delta_universe(capacity: int, num_vars: int) -> Bindings:
    """The BGP identity with empty span columns attached."""
    from .pattern import universe_bindings
    return universe_bindings(capacity, num_vars + 2)


def scan_pattern_delta(
    stream: TripleBatch, pat: CompiledPattern, num_vars: int, out_cap: int,
    slide_of_row: jax.Array,
) -> Bindings:
    """``scan_pattern`` twin over the whole merged chunk: emits bindings
    with ``num_vars + 2`` columns, the extra two holding the row's slide as
    a degenerate span.  Rows the slide packing dropped (``slide_of_row ==
    -1``) are excluded, matching the window materialization."""
    cols = {0: stream.s, 1: stream.p, 2: stream.o}
    m = stream.valid & (slide_of_row >= 0)
    slots = (pat.s, pat.p, pat.o)
    for i, slot in enumerate(slots):
        m = m & _slot_match(slot, cols[i])
    for i in range(3):
        for j in range(i + 1, 3):
            if (
                slots[i].mode != SlotMode.CONST
                and slots[j].mode != SlotMode.CONST
                and slots[i].var == slots[j].var
            ):
                m = m & (cols[i] == cols[j])

    n = stream.capacity
    out = jnp.zeros((n, num_vars + 2), jnp.uint32)
    for i, slot in enumerate(slots):
        if slot.mode != SlotMode.CONST:
            out = out.at[:, slot.var].set(cols[i])
    enc = (jnp.maximum(slide_of_row, 0) + 1).astype(jnp.uint32)
    out = out.at[:, num_vars].set(enc)
    out = out.at[:, num_vars + 1].set(jnp.uint32(SPAN_ENC_K) - enc)
    rows, valid, overflow = compact_rows(out, m, out_cap)
    return Bindings(rows, valid, overflow)


def delta_retract(bind: Bindings, num_vars: int, max_span: int) -> Bindings:
    """Eagerly retract rows whose slide span exceeds ``max_span`` slides
    (0-based: a span of k means max_slide - min_slide == k).  Spans only
    grow under joins, so such rows can never re-enter any window."""
    enc_max = bind.cols[:, num_vars]
    enc_min = bind.cols[:, num_vars + 1]
    # uint32 wraparound makes this exact: (mx+1) + (K-(mn+1)) - K == mx - mn
    span = enc_max + enc_min - jnp.uint32(SPAN_ENC_K)
    keep = (enc_max == 0) | (span <= jnp.uint32(max_span))
    return bind._replace(valid=bind.valid & keep)


def delta_window_mask(
    bind: Bindings, num_vars: int, window: jax.Array, slides_per_window: int,
) -> jax.Array:
    """Validity mask of the rows belonging to window ``window`` (= slides
    ``window .. window + R - 1``): the row's slide span must sit inside
    that contiguous range.  Span-free rows (both columns 0) pass."""
    w = jnp.asarray(window).astype(jnp.uint32)
    enc_max = bind.cols[:, num_vars]
    enc_min = bind.cols[:, num_vars + 1]
    in_w = (enc_max <= w + jnp.uint32(slides_per_window)) \
        & (jnp.uint32(SPAN_ENC_K) - 1 - enc_min >= w)
    return bind.valid & in_w
