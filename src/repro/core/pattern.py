"""Triple patterns and binding tables (the engine's relations).

A *compiled* plan fixes the variable universe: every variable gets a column in
a fixed-width binding table.  ``PAD_ID`` (0) doubles as SPARQL's *unbound*
value, which makes OPTIONAL's outer join a ``jnp.maximum`` merge.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from .rdf import PAD_ID


class SlotMode(enum.IntEnum):
    CONST = 0       # slot is a fixed term id
    BOUND = 1       # slot is a variable already bound at this plan step
    FREE = 2        # slot is a variable first bound by this pattern


@dataclasses.dataclass(frozen=True)
class Slot:
    mode: SlotMode
    const: int = 0      # term id when CONST
    var: int = -1       # variable column when BOUND/FREE

    @staticmethod
    def const_(term_id: int) -> "Slot":
        return Slot(SlotMode.CONST, const=int(term_id))

    @staticmethod
    def bound(var_col: int) -> "Slot":
        return Slot(SlotMode.BOUND, var=int(var_col))

    @staticmethod
    def free(var_col: int) -> "Slot":
        return Slot(SlotMode.FREE, var=int(var_col))


@dataclasses.dataclass(frozen=True)
class CompiledPattern:
    """One triple pattern with slot modes resolved against the plan state."""

    s: Slot
    p: Slot
    o: Slot

    def free_vars(self) -> Tuple[int, ...]:
        return tuple(
            sl.var for sl in (self.s, self.p, self.o) if sl.mode == SlotMode.FREE
        )

    def predicates(self) -> Tuple[int, ...]:
        return (self.p.const,) if self.p.mode == SlotMode.CONST else ()


class Bindings(NamedTuple):
    """Fixed-capacity solution-mapping table.

    ``cols``: ``[cap, num_vars]`` uint32, PAD_ID = unbound.
    ``valid``: ``[cap]`` bool.
    ``overflow``: scalar bool — capacity was exceeded somewhere upstream, so
    the result is a (deterministic, prefix-preserving) under-approximation.
    """

    cols: jax.Array
    valid: jax.Array
    overflow: jax.Array

    @property
    def capacity(self) -> int:
        return int(self.cols.shape[-2])

    @property
    def num_vars(self) -> int:
        return int(self.cols.shape[-1])

    def count(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32), axis=-1)


def empty_bindings(capacity: int, num_vars: int) -> Bindings:
    return Bindings(
        cols=jnp.zeros((capacity, num_vars), jnp.uint32),
        valid=jnp.zeros((capacity,), bool),
        overflow=jnp.zeros((), bool),
    )


def universe_bindings(capacity: int, num_vars: int) -> Bindings:
    """A single all-unbound solution (the BGP identity element)."""
    b = empty_bindings(capacity, num_vars)
    return b._replace(valid=b.valid.at[0].set(True))


def compact_rows(
    rows: jax.Array, mask: jax.Array, out_cap: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Order-preserving compaction of masked ``[n, ...]`` rows into ``out_cap``.

    Returns ``(rows_out [out_cap, ...], valid [out_cap], overflow [])``.
    """
    n = rows.shape[0]
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
    total = jnp.sum(mask.astype(jnp.int32))
    tgt = jnp.where(mask & (pos < out_cap), pos, out_cap)
    idx = jnp.full((out_cap + 1,), -1, jnp.int32)
    idx = idx.at[tgt].set(jnp.where(mask, jnp.arange(n, dtype=jnp.int32), -1), mode="drop")
    idx = idx[:out_cap]
    safe = jnp.maximum(idx, 0)
    out = jnp.take(rows, safe, axis=0)
    valid = idx >= 0
    out = jnp.where(
        valid.reshape((out_cap,) + (1,) * (rows.ndim - 1)), out, jnp.zeros_like(out)
    )
    return out, valid, total > out_cap
