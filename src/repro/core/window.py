"""Window management — the Aggregator's second half.

The paper (§4.4) uses count-based windows measured in *triples* but never
splits an RDF-graph event across windows: "DSCEP aggregates as many RDF graphs
that their sum of triples is a maximum of 1000 RDF triples".  We reproduce
exactly that packing, plus time-based tumbling/sliding windows.

Windows are materialized as a dense ``[num_windows, window_capacity]`` gather
of the ordered stream — the layout the SPMD engine shards across the ``data``
mesh axis (intra-operator parallelism: each device processes a window slice,
the TPU analogue of Kafka consumer groups).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .rdf import TripleBatch, take_rows


class Windows(NamedTuple):
    """A batch of triple windows: every field is ``[W, C]``."""

    triples: TripleBatch      # leaf arrays have shape [W, C]
    window_valid: jax.Array   # [W] bool — windows that contain >= 1 event

    @property
    def num_windows(self) -> int:
        return int(self.window_valid.shape[0])

    @property
    def capacity(self) -> int:
        return int(self.triples.s.shape[-1])


def _segment_first(values: jax.Array, seg_starts: jax.Array) -> jax.Array:
    return jnp.take(values, seg_starts, axis=-1)


def count_windows(
    stream: TripleBatch, window_capacity: int, max_windows: int
) -> Windows:
    """Greedy graph-preserving count windows (paper §4.4 semantics).

    The stream must be timestamp-ordered with invalid rows at the tail (the
    merge stage guarantees this).  Graph events are contiguous runs of equal
    ``graph`` id; a graph moves to the next window when it would overflow the
    current one.  Graphs larger than ``window_capacity`` get a window of their
    own (truncated to capacity, matching a bounded-buffer engine).
    """
    n = stream.capacity
    valid = stream.valid
    g = stream.graph

    # --- per-row graph boundaries on the ordered stream
    prev_g = jnp.concatenate([g[:1], g[:-1]])
    new_graph = (jnp.arange(n) == 0) | (g != prev_g)
    new_graph = new_graph & valid

    graph_idx = jnp.cumsum(new_graph.astype(jnp.int32)) - 1          # [n] graph ordinal
    graph_idx = jnp.where(valid, graph_idx, -1)

    # --- graph sizes via segment sum over graph ordinals
    num_graphs = n  # upper bound
    sizes = jax.ops.segment_sum(
        valid.astype(jnp.int32), jnp.where(graph_idx < 0, num_graphs - 1, graph_idx),
        num_segments=num_graphs,
    )
    graph_live = sizes > 0

    # --- greedy packing of graph sizes into windows (scan over graphs)
    def pack(carry, size_live):
        fill, wid = carry
        size, live = size_live
        size_c = jnp.minimum(size, window_capacity)
        overflow = fill + size_c > window_capacity
        new_wid = jnp.where(overflow, wid + 1, wid)
        new_fill = jnp.where(overflow, size_c, fill + size_c)
        new_wid_out = jnp.where(live, new_wid, wid)
        carry = (
            jnp.where(live, new_fill, fill),
            new_wid_out,
        )
        # offset of this graph inside its window
        offset = jnp.where(overflow, 0, fill)
        return carry, (new_wid_out, offset)

    (_, _), (graph_wid, graph_off) = jax.lax.scan(
        pack, (jnp.int32(0), jnp.int32(0)), (sizes, graph_live)
    )

    # --- scatter rows into [W, C]
    # position of a row within its graph = row index - index of graph start
    graph_start = jnp.where(new_graph, jnp.arange(n), 0)
    graph_start = jax.lax.associative_scan(jnp.maximum, graph_start)
    pos_in_graph = jnp.arange(n) - graph_start

    wid = jnp.where(graph_idx >= 0, jnp.take(graph_wid, jnp.maximum(graph_idx, 0)), -1)
    off = jnp.where(graph_idx >= 0, jnp.take(graph_off, jnp.maximum(graph_idx, 0)), 0)
    col = off + pos_in_graph
    in_cap = col < window_capacity
    ok = valid & (wid >= 0) & (wid < max_windows) & in_cap

    flat_target = jnp.where(ok, wid * window_capacity + col, max_windows * window_capacity)
    slot_of_row = jnp.full((max_windows * window_capacity + 1,), -1, jnp.int32)
    slot_of_row = slot_of_row.at[flat_target].set(
        jnp.where(ok, jnp.arange(n, dtype=jnp.int32), -1), mode="drop"
    )
    gather_idx = slot_of_row[: max_windows * window_capacity].reshape(
        max_windows, window_capacity
    )
    wt = take_rows(stream, gather_idx)
    window_valid = jnp.any(wt.valid, axis=-1)
    return Windows(wt, window_valid)


def time_windows(
    stream: TripleBatch,
    t0: int,
    width: int,
    slide: int,
    window_capacity: int,
    max_windows: int,
) -> Windows:
    """Time-based windows ``[t0 + w*slide, t0 + w*slide + width)``.

    Sliding windows (slide < width) duplicate rows across overlapping windows;
    tumbling windows are the slide == width special case.  Row placement per
    window is order-preserving; overflow beyond capacity is dropped (bounded
    buffer) — overflow is detectable via ``count == capacity``.
    """
    n = stream.capacity
    ts = stream.ts.astype(jnp.int32)  # synthetic timestamps stay well below 2**31
    valid = stream.valid

    windows = []
    valids = []
    for w in range(max_windows):
        lo = t0 + w * slide
        hi = lo + width
        inw = valid & (ts >= lo) & (ts < hi)
        # order-preserving compaction of member rows to the front
        pos = jnp.cumsum(inw.astype(jnp.int32)) - 1
        tgt = jnp.where(inw & (pos < window_capacity), pos, window_capacity)
        idx = jnp.full((window_capacity + 1,), -1, jnp.int32)
        idx = idx.at[tgt].set(jnp.where(inw, jnp.arange(n, dtype=jnp.int32), -1), mode="drop")
        windows.append(idx[:window_capacity])
        valids.append(jnp.any(inw))
    gather_idx = jnp.stack(windows)          # [W, C]
    wt = take_rows(stream, gather_idx)
    return Windows(wt, jnp.stack(valids))


count_windows_jit = jax.jit(count_windows, static_argnums=(1, 2))
time_windows_jit = jax.jit(time_windows, static_argnums=(2, 3, 4, 5))
