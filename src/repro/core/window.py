"""Window management — the Aggregator's second half.

The paper (§4.4) uses count-based windows measured in *triples* but never
splits an RDF-graph event across windows: "DSCEP aggregates as many RDF graphs
that their sum of triples is a maximum of 1000 RDF triples".  We reproduce
exactly that packing, generalized to sliding count windows
(``[RANGE TRIPLES n STEP m]``), plus time-based tumbling/sliding windows.

Sliding count windows factor through *slides*: the stream is greedily packed
graph-by-graph into slides of ``m`` triples, and window ``w`` is the
concatenation of slides ``w .. w + R - 1`` with ``R = ceil(n / m)``.  The
slide is the packing unit — a graph never splits across slides, and a graph
larger than ``m`` is truncated to ``m`` in a slide of its own, the same
bounded-buffer rule tumbling windows apply at capacity ``n``.  When ``m``
does not divide ``n`` the effective window capacity rounds up to ``R * m``.
``STEP >= RANGE`` (or no STEP) degenerates to tumbling: one slide per window,
bit-identical to the historical single-level packing.

Windows are materialized as a dense ``[num_windows, window_capacity]`` gather
of the ordered stream — the layout the SPMD engine shards across the ``data``
mesh axis (intra-operator parallelism: each device processes a window slice,
the TPU analogue of Kafka consumer groups).  Incremental (delta) evaluation
skips that materialization: :class:`SlideView` keeps the per-row slide
assignment so the engine can evaluate the whole chunk once and select each
window's results by slide-span intervals (see ``engine.run_plan_slides``).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .rdf import TripleBatch, take_rows


class Windows(NamedTuple):
    """A batch of triple windows: every field is ``[W, C]``."""

    triples: TripleBatch      # leaf arrays have shape [W, C]
    window_valid: jax.Array   # [W] bool — windows that contain >= 1 event

    @property
    def num_windows(self) -> int:
        return int(self.window_valid.shape[0])

    @property
    def capacity(self) -> int:
        return int(self.triples.s.shape[-1])


class SlideView(NamedTuple):
    """Slide-level view of a merged stream (sliding count windows).

    Produced by :func:`count_slides`; consumed either by
    :func:`windows_from_slides` (materialize overlapping windows for
    per-window recompute) or by ``engine.run_plan_slides`` (incremental
    evaluation with slide-span tracking).  All geometry (slide capacity,
    slides per window) is static and recomputed from the config where
    needed, so this tuple carries arrays only and vmaps/jits cleanly.
    """

    stream: TripleBatch       # merged, ts-ordered stream [n]
    slide_of_row: jax.Array   # [n] int32 — slide ordinal, -1 = dropped/invalid
    slide_col: jax.Array      # [n] int32 — position of the row in its slide
    slide_valid: jax.Array    # [S] bool — slides holding >= 1 triple
    slide_ts: jax.Array       # [S] uint32 — max ts per slide (0 when empty)

    @property
    def num_slides(self) -> int:
        return int(self.slide_valid.shape[0])


def window_slides(window_capacity: int, step: Optional[int] = None) -> Tuple[int, int]:
    """Resolve ``STEP`` geometry to ``(slide_capacity, slides_per_window)``.

    ``step is None`` or ``step >= window_capacity`` means tumbling — one
    slide of the full capacity per window.  Otherwise the slide holds
    ``step`` triples and a window spans ``R = ceil(window_capacity / step)``
    consecutive slides.
    """
    if step is None or step >= window_capacity:
        return window_capacity, 1
    if step < 1:
        raise ValueError("window step must be >= 1, got %d" % step)
    return step, -(-window_capacity // step)


def _segment_first(values: jax.Array, seg_starts: jax.Array) -> jax.Array:
    return jnp.take(values, seg_starts, axis=-1)


def _pack_rows(
    stream: TripleBatch, capacity: int, max_units: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Greedy graph-preserving packing of the stream into capacity-bounded
    units (windows or slides).

    The stream must be timestamp-ordered with invalid rows at the tail (the
    merge stage guarantees this).  Graph events are contiguous runs of equal
    ``graph`` id; a graph moves to the next unit when it would overflow the
    current one.  Graphs larger than ``capacity`` get a unit of their own
    (truncated to capacity, matching a bounded-buffer engine).

    Returns ``(unit, col, ok)`` per row: unit ordinal, column within the
    unit, and whether the row landed (valid, within ``max_units``, within
    capacity).
    """
    n = stream.capacity
    valid = stream.valid
    g = stream.graph

    # --- per-row graph boundaries on the ordered stream
    prev_g = jnp.concatenate([g[:1], g[:-1]])
    new_graph = (jnp.arange(n) == 0) | (g != prev_g)
    new_graph = new_graph & valid

    graph_idx = jnp.cumsum(new_graph.astype(jnp.int32)) - 1          # [n] graph ordinal
    graph_idx = jnp.where(valid, graph_idx, -1)

    # --- graph sizes via segment sum over graph ordinals
    num_graphs = n  # upper bound
    sizes = jax.ops.segment_sum(
        valid.astype(jnp.int32), jnp.where(graph_idx < 0, num_graphs - 1, graph_idx),
        num_segments=num_graphs,
    )
    graph_live = sizes > 0

    # --- greedy packing of graph sizes into units (scan over graphs)
    def pack(carry, size_live):
        fill, wid = carry
        size, live = size_live
        size_c = jnp.minimum(size, capacity)
        overflow = fill + size_c > capacity
        new_wid = jnp.where(overflow, wid + 1, wid)
        new_fill = jnp.where(overflow, size_c, fill + size_c)
        new_wid_out = jnp.where(live, new_wid, wid)
        carry = (
            jnp.where(live, new_fill, fill),
            new_wid_out,
        )
        # offset of this graph inside its unit
        offset = jnp.where(overflow, 0, fill)
        return carry, (new_wid_out, offset)

    (_, _), (graph_wid, graph_off) = jax.lax.scan(
        pack, (jnp.int32(0), jnp.int32(0)), (sizes, graph_live)
    )

    # position of a row within its graph = row index - index of graph start
    graph_start = jnp.where(new_graph, jnp.arange(n), 0)
    graph_start = jax.lax.associative_scan(jnp.maximum, graph_start)
    pos_in_graph = jnp.arange(n) - graph_start

    wid = jnp.where(graph_idx >= 0, jnp.take(graph_wid, jnp.maximum(graph_idx, 0)), -1)
    off = jnp.where(graph_idx >= 0, jnp.take(graph_off, jnp.maximum(graph_idx, 0)), 0)
    col = off + pos_in_graph
    in_cap = col < capacity
    ok = valid & (wid >= 0) & (wid < max_units) & in_cap
    return wid, col, ok


def _scatter_units(
    stream: TripleBatch, unit: jax.Array, col: jax.Array, ok: jax.Array,
    capacity: int, max_units: int,
) -> jax.Array:
    """Row-placement ``(unit, col, ok)`` -> dense ``[max_units, capacity]``
    gather indices (-1 = empty slot)."""
    n = stream.capacity
    flat_target = jnp.where(ok, unit * capacity + col, max_units * capacity)
    slot_of_row = jnp.full((max_units * capacity + 1,), -1, jnp.int32)
    slot_of_row = slot_of_row.at[flat_target].set(
        jnp.where(ok, jnp.arange(n, dtype=jnp.int32), -1), mode="drop"
    )
    return slot_of_row[: max_units * capacity].reshape(max_units, capacity)


def count_slides(
    stream: TripleBatch, window_capacity: int, max_windows: int,
    step: Optional[int] = None,
) -> SlideView:
    """Pack the stream into ``max_windows + R - 1`` slides of ``step``
    triples (paper §4.4 packing at slide granularity)."""
    slide_cap, r = window_slides(window_capacity, step)
    num_slides = max_windows + r - 1
    sid, col, ok = _pack_rows(stream, slide_cap, num_slides)
    seg = jnp.where(ok, sid, num_slides)
    slide_valid = jax.ops.segment_sum(
        ok.astype(jnp.int32), seg, num_segments=num_slides + 1)[:num_slides] > 0
    # uint32 segment max: empty segments fill with the dtype min == 0, the
    # same "no triples" ts the recompute path uses for empty windows
    slide_ts = jax.ops.segment_max(
        jnp.where(ok, stream.ts, 0), seg, num_segments=num_slides + 1)[:num_slides]
    return SlideView(
        stream=stream,
        slide_of_row=jnp.where(ok, sid, -1),
        slide_col=jnp.where(ok, col, 0),
        slide_valid=slide_valid,
        slide_ts=slide_ts,
    )


def windows_from_slides(
    view: SlideView, window_capacity: int, max_windows: int,
    step: Optional[int] = None,
) -> Windows:
    """Materialize overlapping windows: window ``w`` = slides ``w..w+R-1``.

    The physical window capacity is ``R * slide_capacity`` (== the window
    capacity when STEP divides RANGE, rounded up otherwise); rows duplicate
    across the up-to-``R`` windows sharing each slide.
    """
    slide_cap, r = window_slides(window_capacity, step)
    num_slides = max_windows + r - 1
    ok = view.slide_of_row >= 0
    slide_idx = _scatter_units(
        view.stream, view.slide_of_row, view.slide_col, ok, slide_cap, num_slides
    )                                                     # [S, slide_cap]
    widx = jnp.arange(max_windows)[:, None] + jnp.arange(r)[None, :]   # [W, R]
    gather_idx = jnp.take(slide_idx, widx, axis=0).reshape(
        max_windows, r * slide_cap
    )
    wt = take_rows(view.stream, gather_idx)
    window_valid = jnp.any(jnp.take(view.slide_valid, widx, axis=0), axis=1)
    return Windows(wt, window_valid)


def count_windows(
    stream: TripleBatch, window_capacity: int, max_windows: int,
    step: Optional[int] = None,
) -> Windows:
    """Greedy graph-preserving count windows (paper §4.4 semantics).

    Without ``step`` (or ``step >= window_capacity``) windows tumble exactly
    as the paper describes.  With ``step < window_capacity`` windows overlap:
    the stream packs into slides of ``step`` triples and each window holds
    ``ceil(window_capacity / step)`` consecutive slides (see module
    docstring for the truncation/rounding rules).
    """
    slide_cap, r = window_slides(window_capacity, step)
    if r == 1:
        wid, col, ok = _pack_rows(stream, window_capacity, max_windows)
        gather_idx = _scatter_units(
            stream, wid, col, ok, window_capacity, max_windows
        )
        wt = take_rows(stream, gather_idx)
        return Windows(wt, jnp.any(wt.valid, axis=-1))
    view = count_slides(stream, window_capacity, max_windows, step)
    return windows_from_slides(view, window_capacity, max_windows, step)


def time_windows(
    stream: TripleBatch,
    t0: int,
    width: int,
    slide: int,
    window_capacity: int,
    max_windows: int,
) -> Windows:
    """Time-based windows ``[t0 + w*slide, t0 + w*slide + width)``.

    Sliding windows (slide < width) duplicate rows across overlapping windows;
    tumbling windows are the slide == width special case.  Row placement per
    window is order-preserving; overflow beyond capacity is dropped (bounded
    buffer) — overflow is detectable via ``count == capacity``.

    All windows are placed by one batched scatter (no python-level unrolling
    over ``max_windows``), so the traced program size is independent of the
    window count.
    """
    n = stream.capacity
    ts = stream.ts.astype(jnp.int32)  # synthetic timestamps stay well below 2**31
    valid = stream.valid

    lo = t0 + jnp.arange(max_windows, dtype=jnp.int32) * slide          # [W]
    inw = valid[None, :] & (ts[None, :] >= lo[:, None]) \
        & (ts[None, :] < (lo + width)[:, None])                         # [W, n]
    # order-preserving compaction of member rows to the front (per window)
    pos = jnp.cumsum(inw.astype(jnp.int32), axis=1) - 1
    tgt = jnp.where(inw & (pos < window_capacity), pos, window_capacity)
    src = jnp.where(inw, jnp.arange(n, dtype=jnp.int32)[None, :], -1)
    widx = jnp.broadcast_to(
        jnp.arange(max_windows, dtype=jnp.int32)[:, None], (max_windows, n)
    )
    idx = jnp.full((max_windows, window_capacity + 1), -1, jnp.int32)
    idx = idx.at[widx, tgt].set(src, mode="drop")
    wt = take_rows(stream, idx[:, :window_capacity])
    return Windows(wt, jnp.any(inw, axis=1))


count_windows_jit = jax.jit(count_windows, static_argnums=(1, 2, 3))
time_windows_jit = jax.jit(time_windows, static_argnums=(2, 3, 4, 5))
