"""Partitioned background knowledge base (the paper's central object).

The paper's evaluation (§4.4, Figs. 5-7) shows that query processing time is
dominated by KB access and scales ~linearly with the number of KB triples the
engine scans.  DSCEP's answer is to split queries so each sub-query touches
only its "used KB" slice.  This module provides:

* :class:`KnowledgeBase` — an immutable sorted triple store with two probe
  views (``(p,s)``-sorted and ``(p,o)``-sorted) so lookups cost O(log N)
  searchsorted + bounded gather instead of an O(N) scan,
* ``prune`` — plan-time used-KB extraction by predicate/object signature
  (the paper's future-work "automatic KB division", delivered),
* ``pad_to`` / ``shard_rows`` — padding + row-sharding so a KB partition can
  be distributed across the ``model`` mesh axis with ``shard_map``.

Two access methods mirror the paper's two measured methods:

* ``method="scan"``  ≙ C-SPARQL *KB access* (the engine scans the whole
  attached KB slice per window) — cost grows with *total* partition size;
* ``method="probe"`` ≙ *SPARQL subquery/SERVICE* (indexed endpoint lookup)
  — cost ~independent of unused triples.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .rdf import PAD_ID, TERM_BITS, TripleBatch, composite_key


class KnowledgeBase(NamedTuple):
    """Immutable KB partition. All row arrays share shape ``[N]``.

    ``*_ps`` arrays are row-sorted by the composite key ``(p, s)``;
    ``*_po`` by ``(p, o)``.  Both views store full rows (s, p, o) so a probe
    gathers everything it needs from one view.
    """

    s_ps: jax.Array
    p_ps: jax.Array
    o_ps: jax.Array
    key_ps: jax.Array   # uint32 composite (p << TERM_BITS) | enc(s)
    s_po: jax.Array
    p_po: jax.Array
    o_po: jax.Array
    key_po: jax.Array   # uint32 composite (p << TERM_BITS) | enc(o)
    valid: jax.Array    # [N] bool (same count in both views; pads sort last)

    @property
    def capacity(self) -> int:
        return int(self.valid.shape[-1])

    def count(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32), axis=-1)


_PAD_KEY = np.uint32(0xFFFFFFFF)


def build_kb(s: np.ndarray, p: np.ndarray, o: np.ndarray, capacity: Optional[int] = None) -> KnowledgeBase:
    """Host-side constructor from raw id columns (plan-time, not jitted)."""
    s = np.asarray(s, np.uint32)
    p = np.asarray(p, np.uint32)
    o = np.asarray(o, np.uint32)
    n = len(s)
    cap = capacity if capacity is not None else max(n, 1)
    if n > cap:
        raise ValueError("KB rows (%d) exceed capacity (%d)" % (n, cap))

    def padded(col, fill=0):
        out = np.full((cap,), fill, np.uint32)
        out[:n] = col
        return out

    valid = np.zeros((cap,), bool)
    valid[:n] = True

    key_ps = np.array(composite_key(padded(p), padded(s)), copy=True)
    key_po = np.array(composite_key(padded(p), padded(o)), copy=True)
    key_ps[~valid] = _PAD_KEY
    key_po[~valid] = _PAD_KEY

    ps_order = np.argsort(key_ps, kind="stable")
    po_order = np.argsort(key_po, kind="stable")

    sp, pp, op_ = padded(s), padded(p), padded(o)
    return KnowledgeBase(
        s_ps=jnp.asarray(sp[ps_order]),
        p_ps=jnp.asarray(pp[ps_order]),
        o_ps=jnp.asarray(op_[ps_order]),
        key_ps=jnp.asarray(key_ps[ps_order]),
        s_po=jnp.asarray(sp[po_order]),
        p_po=jnp.asarray(pp[po_order]),
        o_po=jnp.asarray(op_[po_order]),
        key_po=jnp.asarray(key_po[po_order]),
        # pad keys sort last in both views, so valid rows occupy the first n slots
        valid=jnp.asarray(np.arange(cap) < n),
    )


def kb_from_triples(rows: Sequence[Tuple[int, int, int]], capacity: Optional[int] = None) -> KnowledgeBase:
    if rows:
        arr = np.asarray(rows, np.uint32)
        return build_kb(arr[:, 0], arr[:, 1], arr[:, 2], capacity)
    return build_kb(np.zeros(0), np.zeros(0), np.zeros(0), capacity or 1)


def host_rows(kb: KnowledgeBase) -> np.ndarray:
    """Valid (s,p,o) rows in (p,s)-sorted order — plan-time helper."""
    v = np.asarray(kb.valid)
    return np.stack(
        [np.asarray(kb.s_ps)[v], np.asarray(kb.p_ps)[v], np.asarray(kb.o_ps)[v]], axis=1
    )


# --------------------------------------------------------------------------
# plan-time KB statistics (the planner's cost model inputs)
# --------------------------------------------------------------------------

class PredStat(NamedTuple):
    """Per-predicate access statistics of one (static) KB partition.

    ``k_ps`` / ``k_po`` are the widest probe range any composite key spans in
    the corresponding sorted view — i.e. the max fan-out of a subject- /
    object-anchored probe on this predicate, *including* composite-key hash
    collisions (a probe must gather the whole range before re-checking), so
    a ``k_max`` at or above this bound can never overflow.
    """

    rows: int
    k_ps: int
    k_po: int


class KBStats(NamedTuple):
    """Host-side statistics of a KB partition, computed once at plan time
    (the KB is static) and fed to the planner's KB-access cost model."""

    total_rows: int
    preds: dict            # {pred_id: PredStat}


def collect_kb_stats(kb: KnowledgeBase) -> KBStats:
    """Scan one partition's sorted views into :class:`KBStats` (host-side).

    Both views keep valid rows in their first ``count()`` slots (pads carry
    the max sort key), so per-predicate cardinalities and max probe-range
    widths fall out of two ``np.unique`` passes over the valid key prefix.
    """
    v = np.asarray(kb.valid)
    preds_col = np.asarray(kb.p_ps)[v]
    stats: dict = {}
    pids, counts = np.unique(preds_col, return_counts=True)
    rows_by_pred = {int(p): int(c) for p, c in zip(pids, counts)}
    widest = {int(p): [0, 0] for p in pids}
    for i, keys in enumerate((np.asarray(kb.key_ps)[v],
                              np.asarray(kb.key_po)[v])):
        uk, uc = np.unique(keys, return_counts=True)
        key_pred = (uk >> np.uint32(TERM_BITS)).astype(np.int64)
        for p in widest:
            m = key_pred == p
            if m.any():
                widest[p][i] = int(uc[m].max())
    for p, n in rows_by_pred.items():
        stats[p] = PredStat(rows=n, k_ps=widest[p][0], k_po=widest[p][1])
    return KBStats(total_rows=int(preds_col.shape[0]), preds=stats)


# --------------------------------------------------------------------------
# The paper's technique: used-KB pruning (plan-time, host-side)
# --------------------------------------------------------------------------

def prune(
    kb: KnowledgeBase,
    predicates: Sequence[int],
    objects_by_pred: Optional[dict] = None,
    capacity: Optional[int] = None,
) -> KnowledgeBase:
    """Extract the "used KB" for a sub-query signature.

    ``predicates``: predicate ids the sub-query's KB patterns mention.
    ``objects_by_pred``: optional ``{pred_id: set(object_ids)}`` narrowing —
    e.g. `rdf:type` restricted to a subclass-closure set.  Rows with a listed
    predicate but non-matching object are dropped; predicates without an
    entry keep all their rows.
    """
    rows = host_rows(kb)
    if len(rows) == 0:
        return kb_from_triples([], capacity or 1)
    mask = np.isin(rows[:, 1], np.asarray(sorted(predicates), np.uint32))
    if objects_by_pred:
        for pid, objs in objects_by_pred.items():
            prow = rows[:, 1] == np.uint32(pid)
            ok = np.isin(rows[:, 2], np.asarray(sorted(objs), np.uint32))
            mask &= ~prow | ok
    kept = rows[mask]
    return build_kb(kept[:, 0], kept[:, 1], kept[:, 2], capacity)


def pad_to(kb: KnowledgeBase, capacity: int) -> KnowledgeBase:
    """Pad every row array to ``capacity`` (pads carry the max sort key)."""
    cur = kb.capacity
    if cur == capacity:
        return kb
    if cur > capacity:
        raise ValueError("cannot shrink KB %d -> %d" % (cur, capacity))
    ext = capacity - cur

    def pad_col(col, fill):
        return jnp.concatenate([col, jnp.full((ext,), fill, col.dtype)])

    return KnowledgeBase(
        s_ps=pad_col(kb.s_ps, 0), p_ps=pad_col(kb.p_ps, 0), o_ps=pad_col(kb.o_ps, 0),
        key_ps=pad_col(kb.key_ps, jnp.uint32(_PAD_KEY)),
        s_po=pad_col(kb.s_po, 0), p_po=pad_col(kb.p_po, 0), o_po=pad_col(kb.o_po, 0),
        key_po=pad_col(kb.key_po, jnp.uint32(_PAD_KEY)),
        valid=pad_col(kb.valid, False),
    )


def shard_rows(kb: KnowledgeBase, num_shards: int) -> KnowledgeBase:
    """Reshape ``[N] -> [num_shards, N/num_shards]`` row-block layout.

    Because both views are key-sorted, contiguous row blocks are contiguous
    key ranges: a probe on shard k either fully hits or fully misses, and a
    `searchsorted` per shard stays correct.  Used with ``shard_map`` over the
    ``model`` axis (each device owns one block = the paper's "divide the KB
    through different machines").
    """
    cap = kb.capacity
    if cap % num_shards:
        kb = pad_to(kb, ((cap + num_shards - 1) // num_shards) * num_shards)
        cap = kb.capacity
    per = cap // num_shards
    return jax.tree.map(lambda col: col.reshape(num_shards, per), kb)


# --------------------------------------------------------------------------
# jit-side probes
# --------------------------------------------------------------------------

def probe_view(kb: KnowledgeBase, pat) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array, jax.Array], object, bool]:
    """``(sorted keys, (s, p, o) columns, anchor slot, anchor_is_subject)``
    for a probe on ``pat`` (const predicate + anchored endpoint required).

    Subject anchors are preferred when both endpoints are anchored — every
    probe implementation (:func:`repro.core.algebra.kb_join_probe` and the
    fused :mod:`repro.kernels.hash_join` paths) derives its view from this
    one function, so they can never disagree on row order.
    """
    from .pattern import SlotMode

    assert pat.p.mode == SlotMode.CONST, "probe requires a constant predicate"
    if pat.s.mode != SlotMode.FREE:
        return kb.key_ps, (kb.s_ps, kb.p_ps, kb.o_ps), pat.s, True
    assert pat.o.mode != SlotMode.FREE, "probe needs an anchored endpoint"
    return kb.key_po, (kb.s_po, kb.p_po, kb.o_po), pat.o, False


def probe_range(keys_sorted: jax.Array, query_key: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """[lo, hi) row range whose composite key equals ``query_key``."""
    lo = jnp.searchsorted(keys_sorted, query_key, side="left")
    hi = jnp.searchsorted(keys_sorted, query_key, side="right")
    return lo, hi


def gather_matches(
    kb_cols: Tuple[jax.Array, jax.Array, jax.Array],
    lo: jax.Array,
    hi: jax.Array,
    k_max: int,
) -> Tuple[Tuple[jax.Array, jax.Array, jax.Array], jax.Array, jax.Array]:
    """Gather up to ``k_max`` rows from [lo, hi); returns (cols, valid, overflow)."""
    idx = lo[..., None] + jnp.arange(k_max, dtype=lo.dtype)
    ok = idx < hi[..., None]
    idx_safe = jnp.minimum(idx, kb_cols[0].shape[-1] - 1)
    cols = tuple(jnp.take(c, idx_safe, axis=-1) for c in kb_cols)
    overflow = (hi - lo) > k_max
    return cols, ok, overflow
