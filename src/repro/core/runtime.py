"""Distributed DSCEP runtime: operator DAG execution over a device mesh.

Maps the paper's deployment (Docker containers + Kafka topics) onto SPMD:

* **inter-query parallelism** — independent `DSCEPRuntime`s (or operator
  subsets) run independent queries;
* **inter-operator parallelism** — sub-queries of one decomposed query are
  traced into one XLA program as independent dataflow branches (XLA's
  scheduler runs them concurrently) and/or placed on submeshes;
* **intra-operator parallelism** — the window batch of each operator is
  sharded across the ``data`` mesh axis; every device runs the identical
  engine program on its window slice (TPU analogue of Kafka consumer groups).

The runtime also provides the *straggler mitigation* hook: window packing is
load-aware (``balance_windows``) so devices receive equal triple counts, the
SPMD equivalent of work-stealing from a backlog.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.obs.metrics import finalize_stats, merge_stats
from repro.obs.trace import Tracer, span_or_null

from .engine import Plan, run_plan_windows
from .kb import KnowledgeBase, collect_kb_stats, pad_to
from .operator import OperatorConfig, SCEPOperator
from .planner import (
    OperatorDAG, SubQuery, augment_kb_with_closures, compile_query,
    plan_supports_delta, prepare_env, prune_kb_for, split_agg_plan,
)
from .rdf import TripleBatch, Vocab, empty_triples
from .stream import merge_streams
from .window import (
    Windows, count_slides, count_windows, window_slides, windows_from_slides,
)


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    # frozen: a default-constructed config is shared freely across runtimes
    # without aliasing mutable state (and field edits go through
    # ``dataclasses.replace``, never in-place mutation).
    window_capacity: int = 1000
    max_windows: int = 8
    out_stream_cap: int = 2048
    # sliding count windows: STEP m slide size (None / >= capacity tumbles)
    window_step: Optional[int] = None
    # incremental (delta) evaluation: evaluate each chunk once with
    # slide-span tracking and select per-window results, instead of
    # re-running the join chain per window.  Bit-identical output; plans
    # with OPTIONAL (non-monotone) fall back per operator, and a sharding
    # mesh disables it (windows must be materialized to shard).
    incremental: bool = False
    # KB-access method: the paper's two measured methods plus cost-based
    # per-join selection — "scan" | "probe" | "auto" ("auto" profiles each
    # operator's used-KB slice at build time, picks probe-with-derived-k_max
    # or fused scan per join, and selectivity-orders the join sequence)
    kb_method: str = "scan"
    kb_capacity: Optional[int] = None
    scan_cap: int = 128
    bind_cap: int = 256
    out_cap: int = 512
    # capacity of window-aligned intermediate binding streams between
    # operators: the aggregator's scan cost grows with the augmented window
    # width (window_capacity + sum of upstream caps), so intermediates are
    # kept tighter than the final output (overflow is flagged per operator)
    intermediate_cap: int = 512
    use_pallas: bool = False
    # fused join->compaction for scan-method KB joins: the candidate matrix
    # never round-trips through HBM (kernels/hash_join).  Orthogonal to
    # ``use_pallas`` (fused jnp path when False, fused Pallas when True).
    fuse_compaction: bool = False
    # explicit (bm, bn) block shapes for the fused kernel; None autotunes
    # per join from the actual (bind_cap, used-KB capacity, num_vars) via
    # kernels.hash_join.ops.autotune_block_shapes at trace time.
    join_block_shapes: Optional[Tuple[int, int]] = None
    # Pallas interpret mode for the fused join/closure kernels: True runs
    # the kernels through the interpreter (works on CPU hosts), False
    # compiles them for the real accelerator.  Only consulted when
    # ``use_pallas`` selects a Pallas path.
    interpret: bool = True


# --------------------------------------------------------------------------
# legacy-constructor deprecation (the Session facade is the public surface)
# --------------------------------------------------------------------------

_INTERNAL = threading.local()


@contextlib.contextmanager
def _internal_construction():
    """Marks runtime construction driven by :class:`repro.core.session.Session`
    (or other in-package facades) so it skips the deprecation warning."""
    prev = getattr(_INTERNAL, "on", False)
    _INTERNAL.on = True
    try:
        yield
    finally:
        _INTERNAL.on = prev


def _warn_legacy_constructor(name: str, mode: str) -> None:
    if getattr(_INTERNAL, "on", False):
        return
    warnings.warn(
        "constructing %s directly is deprecated; use "
        "repro.core.session.Session(ExecutionConfig(mode=%r)) — the unified "
        "facade over all execution modes" % (name, mode),
        DeprecationWarning, stacklevel=3,
    )


def build_operators(
    dag: OperatorDAG, kb: KnowledgeBase, config: RuntimeConfig
) -> Dict[str, SCEPOperator]:
    """Compile one :class:`SCEPOperator` per DAG node (shared by the
    single-program :class:`DSCEPRuntime` and the streaming
    :class:`~repro.core.pipeline.PipelinedRuntime`)."""
    op_cfg = OperatorConfig(
        window_capacity=config.window_capacity,
        max_windows=config.max_windows,
        out_stream_cap=config.out_stream_cap,
        window_step=config.window_step,
        incremental=config.incremental,
    )
    join_bm, join_bn = config.join_block_shapes or (None, None)
    operators: Dict[str, SCEPOperator] = {}
    for name, sub in dag.subqueries.items():
        # the paper's core move: each operator gets its own used-KB slice.
        # Pruning runs first so closure-pair materialization works on the
        # predicate-sized slice, not the full KB (prune_kb_for keeps every
        # edge a closure path traverses); capacity padding comes last so
        # the synthetic pair rows fit inside it.  With kb_method="auto" the
        # finished slice is profiled (the KB is static, so this is pure
        # plan time) and its statistics drive per-join method selection and
        # selectivity ordering in compile_query.
        op_kb = None
        kb_stats = None
        if sub.touches_kb:
            op_kb = prune_kb_for(sub.query, kb)
            op_kb = augment_kb_with_closures(
                sub.query, op_kb, use_pallas=config.use_pallas,
                interpret=config.interpret)
            if config.kb_method == "auto":
                kb_stats = collect_kb_stats(op_kb)
            if config.kb_capacity:
                op_kb = pad_to(op_kb, config.kb_capacity)
        plan = compile_query(
            sub.query,
            kb_method=config.kb_method,
            scan_cap=config.scan_cap,
            bind_cap=config.bind_cap,
            out_cap=(config.out_cap if name == dag.final
                     else min(config.intermediate_cap, config.out_cap)),
            use_pallas=config.use_pallas,
            fuse_compaction=config.fuse_compaction,
            join_bm=join_bm, join_bn=join_bn,
            interpret=config.interpret,
            kb_stats=kb_stats,
        )
        env = prepare_env(sub.query, kb, use_pallas=config.use_pallas,
                          interpret=config.interpret)
        operators[name] = SCEPOperator(name, plan, op_kb, env, op_cfg)
    return operators


# --------------------------------------------------------------------------
# split aggregation sink (see planner.split_agg_plan / engine's sink runners)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PubSpec:
    """How one upstream operator publishes its binding table to the sink."""

    vars: Tuple[str, ...]       # published variable names, table column order
    cols: Tuple[int, ...]       # upstream-plan columns, same order
    rows_cap: int               # windows-mode table rows (out_cap / templates)
    slide_rows_cap: int         # delta-mode table rows (the chain's bind_cap)


@dataclasses.dataclass(frozen=True)
class SplitSink:
    """A successfully split aggregation sink: the rewritten plan plus the
    per-upstream table publication specs.  ``delta=True`` routes the sink
    through the span-tagged slide path (one sink-chain pass per chunk)."""

    plan: Plan
    pub: Dict[str, PubSpec]
    delta: bool


def prepare_split_sink(
    dag: OperatorDAG, operators: Dict[str, SCEPOperator],
    config: RuntimeConfig, mesh: Optional[Mesh] = None,
) -> Optional[SplitSink]:
    """Try to split the aggregation sink for this DAG.

    Returns ``None`` — the caller keeps the augmented-window path — when the
    plan rewrite is outside the equivalent fragment
    (:func:`~repro.core.planner.split_agg_plan`), when a sharding mesh is
    attached (tables are not window-sharded), or when incremental mode is
    requested but any plan in the DAG cannot run the delta path (mixing
    per-window tables with a delta sink would need a third table format).

    ``rows_cap`` mirrors the triple path's clipping exactly: an upstream
    publishes ``templates-per-row * rows`` triples into ``out_cap``, so the
    decode path ever sees at most ``out_cap // templates`` complete rows —
    partial clipped rows decode to nothing.  The delta table instead carries
    the whole chunk-level chain state, which ``bind_cap`` already bounds.
    """
    if mesh is not None:
        return None
    res = split_agg_plan(operators[dag.final].plan, dag)
    if res is None:
        return None
    plan, pub_vars = res
    delta = False
    if config.incremental:
        if not all(plan_supports_delta(operators[u].plan) for u in pub_vars):
            return None
        if not plan_supports_delta(plan):
            return None
        delta = True
    pub = {
        u: PubSpec(
            vars=names,
            cols=tuple(operators[u].plan.var_col(v) for v in names),
            rows_cap=max(1, operators[u].plan.out_cap // max(1, len(names))),
            slide_rows_cap=operators[u].plan.bind_cap,
        )
        for u, names in pub_vars.items()
    }
    return SplitSink(plan=plan, pub=pub, delta=delta)


def augment_windows(
    dag: OperatorDAG, windows: Windows, upstream_out: Dict[str, TripleBatch]
) -> Windows:
    """Append upstream operator outputs to the very window that produced them.

    Window alignment is what makes decomposed == monolithic (paper: "All
    results are the same"); the concatenation order follows the final
    sub-query's declared inputs so every execution mode is bit-identical.
    """
    parts = [windows.triples] + [
        upstream_out[src]
        for src in dag.subqueries[dag.final].inputs
        if src != "stream"
    ]
    aug = TripleBatch(
        *(jnp.concatenate(cols, axis=-1) for cols in zip(*parts))
    )
    return Windows(aug, windows.window_valid)


class DSCEPRuntime:
    """Executes a decomposed query DAG over chunked input streams.

    The whole DAG traces into **one** XLA program per chunk shape: upstream
    sub-queries are independent dataflow branches (inter-operator parallelism
    — XLA schedules them concurrently), windows are the vmapped/shardable
    unit (intra-operator parallelism), and intermediate results stay
    **window-aligned**: operator G sees upstream outputs appended to the very
    window that produced them, which is what makes decomposed and monolithic
    results identical (paper: "All results are the same").
    """

    def __init__(
        self,
        dag: OperatorDAG,
        kb: KnowledgeBase,
        vocab: Vocab,
        config: Optional[RuntimeConfig] = None,
        mesh: Optional[Mesh] = None,
        data_axis: str = "data",
        tracer: Optional[Tracer] = None,
    ):
        _warn_legacy_constructor("DSCEPRuntime", "single_program")
        self.dag = dag
        self.config = config = config if config is not None else RuntimeConfig()
        self.mesh = mesh
        self.data_axis = data_axis
        self.vocab = vocab
        self.operators = build_operators(dag, kb, config)
        # split aggregation sink: upstream operators ship binding tables,
        # the sink joins them directly (None -> augmented-window path).
        # The sink operator's plan is swapped for the rewritten one so
        # every introspection surface (EXPLAIN, plan_caps, last_stats)
        # reports the plan that actually runs.
        self._split = prepare_split_sink(dag, self.operators, config, mesh)
        if self._split is not None:
            self.operators[dag.final].plan = self._split.plan
        self._jit_chunk = jax.jit(self._dag_impl)
        self.tracer = tracer
        self._collect = bool(tracer is not None and tracer.config.metrics)
        self._jit_chunk_stats = (
            jax.jit(functools.partial(self._dag_impl, with_stats=True))
            if self._collect else None)
        # lifetime device-side accumulators (host syncs only in reports)
        self._overflow_acc: Dict[str, jax.Array] = {
            n: jnp.zeros((), jnp.int32) for n in self.operators
        }
        self._stats_acc: Dict[str, Dict[str, jax.Array]] = {
            n: {} for n in self.operators
        }

    # -- the single-program DAG step -----------------------------------------
    def _dag_impl(
        self, chunk: TripleBatch, kbs: Dict[str, Optional[KnowledgeBase]],
        envs: Dict[str, Dict[str, jax.Array]], with_stats: bool = False,
    ):
        cfg = self.config
        merged = merge_streams([chunk])
        if self._split is not None:
            return self._dag_impl_split(merged, kbs, envs, with_stats)
        view = None
        if cfg.incremental and self.mesh is None:
            # delta evaluation needs the slide view; the materialized
            # windows still feed the aggregator (upstream outputs are
            # window-aligned batches with no slide structure to delta over)
            view = count_slides(
                merged, cfg.window_capacity, cfg.max_windows, cfg.window_step)
            windows = windows_from_slides(
                view, cfg.window_capacity, cfg.max_windows, cfg.window_step)
        else:
            windows = count_windows(
                merged, cfg.window_capacity, cfg.max_windows, cfg.window_step)
        if self.mesh is not None:
            windows = shard_windows(windows, self.mesh, self.data_axis)

        overflow: Dict[str, jax.Array] = {}
        stats: Dict[str, Dict[str, jax.Array]] = {}
        final = self.dag.final
        upstream_out: Dict[str, TripleBatch] = {}
        for name in self.dag.subqueries:
            if name == final:
                continue
            if view is not None:
                res = self.operators[name].process_slides(
                    view, kbs[name], envs[name], with_stats
                )
            else:
                res = self.operators[name].process_windows(
                    windows, kbs[name], envs[name], with_stats
                )
            if with_stats:
                out_w, ovf, stats[name] = res
            else:
                out_w, ovf = res
            upstream_out[name] = out_w
            overflow[name] = ovf

        # window-aligned augmentation for the aggregation operator
        aug_windows = augment_windows(self.dag, windows, upstream_out)
        res = self.operators[final].process_windows(
            aug_windows, kbs[final], envs[final], with_stats
        )
        if with_stats:
            out_w, ovf, stats[final] = res
        else:
            out_w, ovf = res
        overflow[final] = ovf
        out = self.operators[final]._publish(out_w)
        if with_stats:
            return out, overflow, stats
        return out, overflow

    def _dag_impl_split(
        self, merged: TripleBatch, kbs, envs, with_stats: bool = False,
    ):
        """The split-sink DAG step: upstream operators produce binding
        *tables* (windowed or span-tagged), the sink joins them via its
        rewritten BindingJoin plan over the raw windows — no augmented
        window, no binding-graph decode scans."""
        cfg = self.config
        split = self._split
        final = self.dag.final
        overflow: Dict[str, jax.Array] = {}
        stats: Dict[str, Dict[str, jax.Array]] = {}
        tables: Dict[str, Tuple[jax.Array, jax.Array]] = {}
        if split.delta:
            view = count_slides(
                merged, cfg.window_capacity, cfg.max_windows, cfg.window_step)
        else:
            windows = count_windows(
                merged, cfg.window_capacity, cfg.max_windows, cfg.window_step)
        for name in self.dag.subqueries:
            if name == final:
                continue
            spec = split.pub[name]
            if split.delta:
                res = self.operators[name].process_slide_tables(
                    view, spec.cols, spec.slide_rows_cap,
                    kbs[name], envs[name], with_stats)
            else:
                res = self.operators[name].process_window_tables(
                    windows, spec.cols, spec.rows_cap,
                    kbs[name], envs[name], with_stats)
            if with_stats:
                tables[name], ovf, stats[name] = res
            else:
                tables[name], ovf = res
            # delta tables are chunk-level: broadcast the scalar flag to the
            # per-window convention every overflow consumer expects
            overflow[name] = (jnp.broadcast_to(ovf, (cfg.max_windows,))
                              if ovf.ndim == 0 else ovf)
        if split.delta:
            res = self.operators[final].process_sink_slides(
                view, tables, kbs[final], envs[final], with_stats)
        else:
            res = self.operators[final].process_sink_windows(
                windows, tables, kbs[final], envs[final], with_stats)
        if with_stats:
            out_w, ovf_f, stats[final] = res
        else:
            out_w, ovf_f = res
        overflow[final] = ovf_f
        out = self.operators[final]._publish(out_w)
        if with_stats:
            return out, overflow, stats
        return out, overflow

    # -- orchestration ---------------------------------------------------------
    def process_chunk(self, chunk: TripleBatch) -> Tuple[TripleBatch, Dict[str, jax.Array]]:
        """Push one stream chunk through the DAG; returns (final output, overflow)."""
        kbs = {n: op.kb for n, op in self.operators.items()}
        envs = {n: op.env for n, op in self.operators.items()}
        with span_or_null(self.tracer, "chunk", mode="single_program") as sp:
            if self._collect:
                out, ovf, stats = self._jit_chunk_stats(chunk, kbs, envs)
                for name, st in stats.items():
                    merge_stats(self._stats_acc[name], st)
            else:
                out, ovf = self._jit_chunk(chunk, kbs, envs)
            sp.fence(out)
        for name, flags in ovf.items():
            self._overflow_acc[name] = (
                self._overflow_acc[name] + jnp.sum(flags.astype(jnp.int32)))
        return out, ovf

    def process_stream(
        self, chunks: Sequence[TripleBatch]
    ) -> Tuple[List[TripleBatch], Dict[str, int]]:
        """Push all chunks through the DAG, chunk-at-a-time.

        Returns ``(outputs, overflow)`` where ``overflow[op]`` counts windows
        whose capacities clipped results in operator ``op`` across this
        stream — per-operator flags are accumulated, never dropped, so the
        driver can assert capacity sufficiency (benchmarks do).  The counts
        accumulate device-side; the host syncs once at the end of the
        stream, not per chunk.
        """
        outs: List[TripleBatch] = []
        acc: Dict[str, jax.Array] = {
            n: jnp.zeros((), jnp.int32) for n in self.operators
        }
        for c in chunks:
            out, ovf = self.process_chunk(c)
            outs.append(out)
            for name, flags in ovf.items():
                acc[name] = acc[name] + jnp.sum(flags.astype(jnp.int32))
        return outs, {n: int(v) for n, v in acc.items()}

    # -- observability surfaces (uniform across all three runtimes) ----------
    def overflow_totals(self) -> Dict[str, int]:
        """Lifetime overflowed-window counts per operator."""
        return {n: int(v) for n, v in self._overflow_acc.items()}

    def channel_stats(self) -> Dict[str, Dict[str, int]]:
        """No inter-operator channels in the single-program mode — the DAG
        edges are dataflow inside one XLA program."""
        return {}

    def op_metrics(self) -> Dict[str, Dict[str, int]]:
        """Finalized per-operator engine metric counters (empty unless the
        runtime was built with a metrics-collecting tracer)."""
        return {n: finalize_stats(a) for n, a in self._stats_acc.items() if a}

    @property
    def degraded(self) -> bool:
        """Single-program mode has no channels to degrade around."""
        return False

    def recovery_stats(self) -> Dict[str, Any]:
        """Uniform recovery surface — fault machinery lives in the pipelined
        runtime only (one XLA program has no partial-failure boundary)."""
        from .recovery import empty_recovery_stats
        return empty_recovery_stats(False)


# --------------------------------------------------------------------------
# monolithic reference runtime (paper's "one C-SPARQL query" baseline)
# --------------------------------------------------------------------------

class MonolithicRuntime:
    """Single-operator execution of the *whole* query against the *full* KB.

    This is the paper's Table-2 baseline: one engine, no decomposition, no
    KB pruning.  Result equivalence with :class:`DSCEPRuntime` is the paper's
    "All results are the same" claim (tested in tests/test_equivalence.py).
    """

    def __init__(self, q, kb: KnowledgeBase, config: Optional[RuntimeConfig] = None,
                 tracer: Optional[Tracer] = None):
        _warn_legacy_constructor("MonolithicRuntime", "monolithic")
        config = config if config is not None else RuntimeConfig()
        join_bm, join_bn = config.join_block_shapes or (None, None)
        # closure-pair relations for variable-length paths (no-op otherwise)
        kb = augment_kb_with_closures(q, kb, use_pallas=config.use_pallas,
                                      interpret=config.interpret)
        plan = compile_query(
            q, kb_method=config.kb_method, scan_cap=config.scan_cap,
            bind_cap=config.bind_cap, out_cap=config.out_cap,
            use_pallas=config.use_pallas,
            fuse_compaction=config.fuse_compaction,
            join_bm=join_bm, join_bn=join_bn,
            interpret=config.interpret,
            kb_stats=(collect_kb_stats(kb)
                      if config.kb_method == "auto" and kb is not None
                      else None),
        )
        env = prepare_env(q, kb, use_pallas=config.use_pallas,
                          interpret=config.interpret)
        if config.kb_capacity:
            kb = pad_to(kb, config.kb_capacity)
        self.operator = SCEPOperator(
            q.name, plan, kb, env,
            OperatorConfig(config.window_capacity, config.max_windows,
                           config.out_stream_cap,
                           window_step=config.window_step,
                           incremental=config.incremental),
        )
        self.tracer = tracer
        self._collect = bool(tracer is not None and tracer.config.metrics)
        self._overflow_acc = jnp.zeros((), jnp.int32)
        self._stats_acc: Dict[str, jax.Array] = {}

    def process_chunk(self, chunk: TripleBatch) -> Tuple[TripleBatch, jax.Array]:
        op = self.operator
        with span_or_null(self.tracer, "chunk", mode="monolithic") as sp:
            if self._collect:
                out, ovf, stats = op.process_stats([chunk])
                merge_stats(self._stats_acc, stats)
            else:
                out, ovf = op.process([chunk])
            sp.fence(out)
        self._overflow_acc = self._overflow_acc + jnp.sum(ovf.astype(jnp.int32))
        return out, ovf

    # -- observability surfaces (uniform across all three runtimes) ----------
    def overflow_totals(self) -> Dict[str, int]:
        return {self.operator.name: int(self._overflow_acc)}

    def channel_stats(self) -> Dict[str, Dict[str, int]]:
        return {}

    def op_metrics(self) -> Dict[str, Dict[str, int]]:
        if not self._stats_acc:
            return {}
        return {self.operator.name: finalize_stats(self._stats_acc)}

    @property
    def degraded(self) -> bool:
        """The monolithic baseline *is* the degradation target — never set."""
        return False

    def recovery_stats(self) -> Dict[str, Any]:
        from .recovery import empty_recovery_stats
        return empty_recovery_stats(False)


# --------------------------------------------------------------------------
# SPMD window sharding (intra-operator parallelism on a mesh)
# --------------------------------------------------------------------------

def shard_windows(windows: Windows, mesh: Mesh, axis: str = "data") -> Windows:
    """Constrain a window batch to live across a mesh axis (jit-side).

    Each device gets a window slice and runs the identical engine program —
    the SPMD version of the paper's consumer-group load balancing.
    """
    return jax.tree.map(
        lambda leaf: jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, P(*((axis,) + (None,) * (leaf.ndim - 1)))),
        ),
        windows,
    )


def balance_windows(stream: TripleBatch, num_engines: int, window_capacity: int,
                    max_windows: int, window_step: Optional[int] = None) -> Windows:
    """Straggler-aware packing: windows padded to equal triple counts so every
    engine (device) receives balanced work before sharding."""
    w = count_windows(stream, window_capacity, max_windows, window_step)
    # count-based packing already equalizes triple counts up to one graph;
    # round window count up to a multiple of the engine count so the shard
    # axis divides evenly.
    W = w.num_windows
    if W % num_engines:
        pad = num_engines - (W % num_engines)
        w = Windows(
            triples=jax.tree.map(
                lambda col: jnp.concatenate(
                    [col, jnp.zeros((pad,) + col.shape[1:], col.dtype)]
                ),
                w.triples,
            ),
            window_valid=jnp.concatenate([w.window_valid, jnp.zeros((pad,), bool)]),
        )
    return w
