"""The vectorized RSP engine: executes compiled plans over triple windows.

A :class:`Plan` is a static list of steps (python-level control flow only);
executing it traces pure jnp ops, so a plan jit-compiles once per
(window-shape, KB-shape) and is ``vmap``-ed over the window axis — the
intra-operator parallel unit the runtime shards across the ``data`` mesh axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.obs.metrics import reduce_stats, stat_add, stat_max

from . import algebra
from .kb import KnowledgeBase
from .pattern import Bindings, CompiledPattern, compact_rows, universe_bindings
from .rdf import TripleBatch
from .window import SlideView, Windows


# --------------------------------------------------------------------------
# plan steps (static dataclasses — hashable, traceable control flow)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScanJoin:
    """Scan a stream pattern in the window, natural-join into the state."""

    pat: CompiledPattern
    shared: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class KBJoin:
    pat: CompiledPattern
    method: str = "scan"          # "scan" | "probe"  (paper's two methods)
    k_max: int = 8
    use_pallas: bool = False
    fuse_compaction: bool = False  # fused join->compaction (no [M, N] in HBM)
    bm: Optional[int] = None       # fused-kernel block shapes (None = autotune)
    bn: Optional[int] = None
    interpret: bool = True         # Pallas interpret mode (False on real TPU)


@dataclasses.dataclass(frozen=True)
class FilterNumStep:
    var: int
    op: str
    value_id: int


@dataclasses.dataclass(frozen=True)
class FilterBoolStep:
    """Boolean FILTER tree, compiled to a static nested-tuple expression:
    ``("cmp", col, op, value_id)`` leaves under ``("and"|"or"|"not", ...)``
    nodes (tuples keep the Plan hashable)."""

    expr: Tuple


@dataclasses.dataclass(frozen=True)
class FilterInStep:
    var: int
    set_name: str                 # env key holding a sorted uint32 id array


@dataclasses.dataclass(frozen=True)
class OptionalSteps:
    sub: Tuple["Step", ...]
    shared: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class UnionSteps:
    left: Tuple["Step", ...]
    right: Tuple["Step", ...]


@dataclasses.dataclass(frozen=True)
class DistinctStep:
    pass


@dataclasses.dataclass(frozen=True)
class ProjectStep:
    keep: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class BindingJoin:
    """Join a pre-joined upstream binding *table* into the state.

    The split aggregation sink (planner.split_agg_plan) replaces the
    binding-graph decode scans — one ScanJoin per published variable, each
    over the full augmented window — with a single natural join against the
    upstream operator's already-projected table of result rows.  ``cols[j]``
    is the sink-plan column the table's j-th column binds; ``shared`` are
    the columns joined on (recomputed by the rewriter from the actual
    bound-before set, like any ScanJoin).  ``replace=True`` marks the plan's
    very first step, where ``universe ⋈ T == T`` and the outer product is
    skipped entirely.
    """

    source: str
    cols: Tuple[int, ...]
    shared: Tuple[int, ...]
    replace: bool = False


Step = Union[
    ScanJoin, KBJoin, FilterNumStep, FilterBoolStep, FilterInStep,
    OptionalSteps, UnionSteps, DistinctStep, ProjectStep, BindingJoin,
]


@dataclasses.dataclass(frozen=True)
class Plan:
    """A compiled continuous query."""

    name: str
    num_vars: int
    var_names: Tuple[str, ...]            # col index -> variable name
    steps: Tuple[Step, ...]
    templates: Tuple[Tuple, ...]          # compiled construct templates
    scan_cap: int = 128                   # pattern-scan result capacity
    bind_cap: int = 256                   # working binding-table capacity
    out_cap: int = 512                    # constructed-triples capacity

    def var_col(self, name: str) -> int:
        return self.var_names.index(name)


# --------------------------------------------------------------------------
# execution
# --------------------------------------------------------------------------

Env = Dict[str, jax.Array]

# Optional per-step metrics dict (see repro.obs.metrics).  ``None`` — the
# default everywhere — means "collect nothing": every instrumentation site
# below is guarded by a *python-level* ``stats is not None`` branch, so the
# stats-off traced program is byte-identical to the pre-observability one
# (pinned by tests/test_obs.py).
Stats = Optional[Dict[str, jax.Array]]

# Upstream binding tables for the split aggregation sink: operator name ->
# ``(cols, valid)`` where ``cols`` is ``[rows, k]`` uint32 (one column per
# published variable; the delta variant appends the two span columns) and
# ``valid`` is ``[rows]`` bool.  Only BindingJoin steps consume these.
Tables = Optional[Dict[str, Tuple[jax.Array, jax.Array]]]


def _occ(b: Bindings) -> jax.Array:
    """Binding-table occupancy (valid rows) as an int32 scalar."""
    return jnp.sum(b.valid.astype(jnp.int32))


def plan_out_vars(plan: Plan) -> Tuple[int, ...]:
    """Columns the CONSTRUCT templates reference (the output signature)."""
    return tuple(sorted({
        val for tpl in plan.templates for kind, val in tpl if kind == "var"
    }))


def _binding_table(
    step: BindingJoin, tables: Tables, width: int, num_span: int = 0,
) -> Bindings:
    """Scatter an upstream table into a ``width``-column Bindings relation.

    ``num_span`` > 0 (the delta path) additionally maps the table's trailing
    span columns onto the state's span columns at ``width - num_span``.
    """
    assert tables is not None and step.source in tables, (
        "BindingJoin on %r but no table supplied — split-sink runners must "
        "pass the upstream tables" % step.source)
    tcols, tvalid = tables[step.source]
    k = len(step.cols)
    out = jnp.zeros((tcols.shape[0], width), jnp.uint32)
    for j, c in enumerate(step.cols):
        out = out.at[:, c].set(tcols[:, j])
    for j in range(num_span):
        out = out.at[:, width - num_span + j].set(tcols[:, k + j])
    # upstream clipping is reported as that operator's own overflow flag
    return Bindings(out, tvalid, jnp.zeros((), bool))


def _apply(
    step: Step, cur: Bindings, window: TripleBatch, kb: Optional[KnowledgeBase],
    env: Env, plan: Plan, stats: Stats = None, tables: Tables = None,
) -> Bindings:
    if isinstance(step, BindingJoin):
        b = _binding_table(step, tables, plan.num_vars)
        if stats is not None:
            stat_max(stats, "hw_scan", _occ(b))
        if step.replace:
            # first step: universe ⋈ T is T itself (shared is empty, the
            # max-merge with all-PAD is the identity) — clip to bind_cap
            # without the [1, rows] outer product
            rows, valid, ovf = compact_rows(b.cols, b.valid, plan.bind_cap)
            return Bindings(rows, valid, ovf | cur.overflow)
        return algebra.join(cur, b, step.shared, plan.bind_cap)
    if isinstance(step, ScanJoin):
        b = algebra.scan_pattern(window, step.pat, plan.num_vars, plan.scan_cap)
        if stats is not None:
            stat_max(stats, "hw_scan", _occ(b))
        return algebra.join(cur, b, step.shared, plan.bind_cap)
    if isinstance(step, KBJoin):
        assert kb is not None, "plan %s touches the KB but none attached" % plan.name
        return algebra.kb_join(
            cur, kb, step.pat, plan.bind_cap, method=step.method,
            k_max=step.k_max, use_pallas=step.use_pallas,
            fuse_compaction=step.fuse_compaction, bm=step.bm, bn=step.bn,
            interpret=step.interpret, stats=stats,
        )
    if isinstance(step, FilterNumStep):
        return algebra.filter_num(cur, step.var, step.op, step.value_id)
    if isinstance(step, FilterBoolStep):
        return algebra.filter_bool(cur, step.expr)
    if isinstance(step, FilterInStep):
        return algebra.filter_in(cur, step.var, env[step.set_name])
    if isinstance(step, OptionalSteps):
        sub = universe_bindings(plan.bind_cap, plan.num_vars)
        for s in step.sub:
            sub = _apply(s, sub, window, kb, env, plan, stats, tables)
        return algebra.optional_join(cur, sub, step.shared, plan.bind_cap)
    if isinstance(step, UnionSteps):
        left = cur
        for s in step.left:
            left = _apply(s, left, window, kb, env, plan, stats, tables)
        right = cur
        for s in step.right:
            right = _apply(s, right, window, kb, env, plan, stats, tables)
        return algebra.union(left, right, plan.bind_cap)
    if isinstance(step, DistinctStep):
        return algebra.distinct(cur)
    if isinstance(step, ProjectStep):
        return algebra.project(cur, step.keep)
    raise TypeError("unknown step %r" % (step,))


# Public alias: the serving layer (repro.serve.engine) drives step
# sequences directly — shared KB-join prefixes run once, per-query
# suffixes fan out — and must trace the exact ops run_plan would.
apply_step = _apply


def run_steps(
    plan: Plan, cur: Bindings, steps: Sequence[Step], window: TripleBatch,
    kb: Optional[KnowledgeBase], env: Env, stats: Stats = None,
    tables: Tables = None,
) -> Bindings:
    """Apply a step subsequence (same ops as the run_plan loop, including
    the per-step hw_bind gauge so stats stay comparable across paths)."""
    for step in steps:
        cur = _apply(step, cur, window, kb, env, plan, stats, tables)
        if stats is not None:
            stat_max(stats, "hw_bind", _occ(cur))
    return cur


def finalize_bindings(
    plan: Plan, cur: Bindings, ts: jax.Array,
    graph_base: jax.Array | int = 0, stats: Stats = None,
) -> Tuple[TripleBatch, jax.Array]:
    """The set-to-stream tail of :func:`run_plan`: project onto the
    CONSTRUCT variables, dedup, canonically order, construct.  Returns
    (output triples, overflow flag).  Split out so the serving layer's
    shared-prefix programs finalize each member with exactly these ops."""
    out_vars = plan_out_vars(plan)
    emit = cur
    if out_vars:
        # significance by variable *name*: column numbering is plan-local
        # (a decomposed aggregator numbers differently than the monolithic
        # plan), names are shared
        sig = tuple(sorted(out_vars, key=lambda c: plan.var_names[c]))
        emit = algebra.canonical_order(
            algebra.distinct(algebra.project(cur, out_vars)), sig)
    out, c_ovf = algebra.construct(emit, plan.templates, ts, plan.out_cap,
                                   graph_base)
    if stats is not None:
        stat_max(stats, "hw_out", jnp.sum(out.valid.astype(jnp.int32)))
    return out, cur.overflow | emit.overflow | c_ovf


def run_plan(
    plan: Plan, window: TripleBatch, kb: Optional[KnowledgeBase], env: Env,
    graph_base: jax.Array | int = 0, stats: Stats = None,
) -> Tuple[TripleBatch, Bindings, jax.Array]:
    """Execute ``plan`` on one window.

    Returns (constructed stream, final bindings, overflow flag).  Before
    CONSTRUCT the bindings are projected onto the template variables,
    deduplicated and **canonically ordered** — SPARQL CONSTRUCT emits a
    *graph* (set semantics), so join multiplicities in non-output variables
    must not inflate the output (they previously could silently exceed
    ``out_cap``), and the published row order (which assigns output graph
    ids) must be a function of the result *set*, never of the plan's join
    order — that is what makes monolithic and decomposed executions
    bit-identical for every query, not just the paper's.
    """
    cur = universe_bindings(plan.bind_cap, plan.num_vars)
    cur = run_steps(plan, cur, plan.steps, window, kb, env, stats)
    ts = jnp.max(jnp.where(window.valid, window.ts, 0))
    out, ovf = finalize_bindings(plan, cur, ts, graph_base, stats)
    return out, cur, ovf


def run_plan_windows(
    plan: Plan, windows: Windows, kb: Optional[KnowledgeBase], env: Env,
    with_stats: bool = False,
):
    """vmap the plan over a window batch.

    Returns a ``[W, out_cap]``-leaf TripleBatch plus a ``[W]`` overflow flag
    (monitoring hook: a set flag means capacities clipped that window).
    With ``with_stats`` a third element is returned: a flat dict of chunk
    scalars (per-window gauges reduced per the hw_/n_ convention, see
    repro.obs.metrics) — the stats-off call traces the exact same program
    as before instrumentation.
    """
    w = windows.num_windows

    def one(window, wid, wvalid):
        stats: Stats = {} if with_stats else None
        out, _, ovf = run_plan(
            plan, window, kb, env,
            graph_base=wid.astype(jnp.uint32) * plan.bind_cap, stats=stats,
        )
        out = out._replace(valid=out.valid & wvalid)
        if with_stats:
            return out, ovf, stats
        return out, ovf

    res = jax.vmap(one, in_axes=(0, 0, 0))(
        windows.triples, jnp.arange(w), windows.window_valid
    )
    if not with_stats:
        return res
    out, ovf, per_window = res
    stats = reduce_stats(per_window)
    stat_add(stats, "n_windows",
             jnp.sum(windows.window_valid.astype(jnp.int32)))
    return out, ovf, stats


# --------------------------------------------------------------------------
# incremental (delta) execution over slides
# --------------------------------------------------------------------------

def _apply_delta(
    step: Step, cur: Bindings, view: SlideView, kb: Optional[KnowledgeBase],
    env: Env, plan: Plan, max_span: int, stats: Stats = None,
    tables: Tables = None,
) -> Bindings:
    """One plan step over span-tracked bindings (``num_vars + 2`` columns).

    Every step here must be *monotone* (planner.plan_supports_delta gates
    plans to this vocabulary): stream scans stamp each match with its slide
    span, joins merge spans via the existing elementwise-max merge, and an
    eager retract after every stream join drops rows whose span can no
    longer fit inside any window.  KB joins and filters never look at the
    extra columns — they treat binding columns opaquely.

    BindingJoin is monotone too: an upstream table row carries the span of
    its contributing slides, the max-merge unions spans across the join, and
    a combined derivation fits a window iff every constituent span does —
    which is exactly the interval test ``delta_window_mask`` applies.
    """
    if isinstance(step, BindingJoin):
        b = _binding_table(step, tables, plan.num_vars + 2, num_span=2)
        if stats is not None:
            stat_max(stats, "hw_scan", _occ(b))
        if step.replace:
            rows, valid, ovf = compact_rows(b.cols, b.valid, plan.bind_cap)
            joined = Bindings(rows, valid, ovf | cur.overflow)
        else:
            joined = algebra.join(cur, b, step.shared, plan.bind_cap)
        retracted = algebra.delta_retract(joined, plan.num_vars, max_span)
        if stats is not None:
            stat_add(stats, "n_retract", _occ(joined) - _occ(retracted))
        return retracted
    if isinstance(step, ScanJoin):
        b = algebra.scan_pattern_delta(
            view.stream, step.pat, plan.num_vars, plan.scan_cap,
            view.slide_of_row,
        )
        if stats is not None:
            stat_max(stats, "hw_scan", _occ(b))
        joined = algebra.join(cur, b, step.shared, plan.bind_cap)
        retracted = algebra.delta_retract(joined, plan.num_vars, max_span)
        if stats is not None:
            stat_add(stats, "n_retract", _occ(joined) - _occ(retracted))
        return retracted
    if isinstance(step, KBJoin):
        assert kb is not None, "plan %s touches the KB but none attached" % plan.name
        return algebra.kb_join(
            cur, kb, step.pat, plan.bind_cap, method=step.method,
            k_max=step.k_max, use_pallas=step.use_pallas,
            fuse_compaction=step.fuse_compaction, bm=step.bm, bn=step.bn,
            interpret=step.interpret, stats=stats,
        )
    if isinstance(step, FilterNumStep):
        return algebra.filter_num(cur, step.var, step.op, step.value_id)
    if isinstance(step, FilterBoolStep):
        return algebra.filter_bool(cur, step.expr)
    if isinstance(step, FilterInStep):
        return algebra.filter_in(cur, step.var, env[step.set_name])
    if isinstance(step, UnionSteps):
        left = cur
        for s in step.left:
            left = _apply_delta(s, left, view, kb, env, plan, max_span,
                                stats, tables)
        right = cur
        for s in step.right:
            right = _apply_delta(s, right, view, kb, env, plan, max_span,
                                 stats, tables)
        return algebra.union(left, right, plan.bind_cap)
    raise TypeError(
        "step %r is not delta-safe — plan_supports_delta should have routed "
        "this plan to per-window recompute" % (step,)
    )


def run_plan_slides(
    plan: Plan, view: SlideView, slides_per_window: int, max_windows: int,
    kb: Optional[KnowledgeBase], env: Env, with_stats: bool = False,
    tables: Tables = None,
):
    """Incremental execution: one chunk-level pass, per-window selection.

    The join chain (the compute hotspot — every KBJoin is O(bind_cap x KB))
    runs ONCE over the merged stream with slide spans riding along, instead
    of once per window as in :func:`run_plan_windows`; each window then
    selects its rows with an O(bind_cap) interval test and runs only the
    cheap finalize tail (project -> distinct -> canonical_order ->
    construct).  Because that tail is the same set-to-stream function
    recompute uses and the selected binding *sets* are equal (monotone
    steps + exact span intervals), the published output is bit-identical to
    per-window recompute — the invariant the differential harness pins.

    Returns a ``[W, out_cap]``-leaf TripleBatch plus a ``[W]`` overflow
    flag (plus a chunk-scalar stats dict when ``with_stats`` — the delta
    chain runs once per chunk, so its gauges are chunk-level already).
    Note the chunk-level pass shares one scan_cap/bind_cap across
    the whole chunk where recompute gets them per window; overflow trips
    earlier here (size caps to the *sum* of window populations), which the
    flag reports exactly as usual.
    """
    r = slides_per_window
    stats: Stats = {} if with_stats else None
    cur = algebra.delta_universe(plan.bind_cap, plan.num_vars)
    for step in plan.steps:
        cur = _apply_delta(step, cur, view, kb, env, plan, r - 1, stats,
                           tables)
        if stats is not None:
            stat_max(stats, "hw_bind", _occ(cur))
    out_vars = plan_out_vars(plan)
    assert out_vars, (
        "plan %s has no output variables — plan_supports_delta should have "
        "routed it to per-window recompute" % plan.name)
    sig = tuple(sorted(out_vars, key=lambda c: plan.var_names[c]))
    chunk_ovf = cur.overflow

    widx = jnp.arange(max_windows)[:, None] + jnp.arange(r)[None, :]  # [W, R]
    w_ts = jnp.max(jnp.take(view.slide_ts, widx, axis=0), axis=1)
    w_valid = jnp.any(jnp.take(view.slide_valid, widx, axis=0), axis=1)

    def one(wid, ts, wvalid):
        memb = algebra.delta_window_mask(cur, plan.num_vars, wid, r)
        rows = Bindings(cur.cols[:, : plan.num_vars], memb, chunk_ovf)
        emit = algebra.canonical_order(
            algebra.distinct(algebra.project(rows, out_vars)), sig)
        out, c_ovf = algebra.construct(
            emit, plan.templates, ts, plan.out_cap,
            wid.astype(jnp.uint32) * plan.bind_cap,
        )
        out = out._replace(valid=out.valid & wvalid)
        return out, chunk_ovf | emit.overflow | c_ovf

    res = jax.vmap(one)(jnp.arange(max_windows), w_ts, w_valid)
    if not with_stats:
        return res
    out, ovf = res
    stat_max(stats, "hw_out",
             jnp.max(jnp.sum(out.valid.astype(jnp.int32), axis=-1)))
    stat_add(stats, "n_windows", jnp.sum(w_valid.astype(jnp.int32)))
    return out, ovf, stats


# --------------------------------------------------------------------------
# split aggregation sink: upstream table producers + sink runners
# --------------------------------------------------------------------------
#
# The binding-graph protocol (planner.decompose) ships upstream results as
# RDF triples — one graph event per result row — and the aggregation sink
# *re-parses* them: one decode ScanJoin per published variable over the
# augmented window, then the natural joins that stitch the row back
# together.  That re-parse dominated the sink stage (BENCH_pipeline
# stage_breakdown).  The split sink skips the round-trip entirely: each
# upstream publishes its final binding TABLE (already joined, projected,
# deduplicated and canonically ordered), and the rewritten sink plan
# (planner.split_agg_plan) joins those tables directly via BindingJoin.
# Output bits are unchanged: the published stream is a function of the
# binding *set* (finalize_bindings dedups and canonically orders), and the
# table rows are exactly the rows the decode scans would have reconstructed.

def _clip_table(
    emit: Bindings, pub_cols: Tuple[int, ...], rows_cap: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Gather ``pub_cols`` from the leading ``rows_cap`` rows of ``emit``.

    ``emit`` must keep its valid rows as a prefix (distinct/canonical_order
    guarantee that), so the prefix clip drops exactly the rows the
    triple-publication path would have clipped at ``out_cap``.  Returns
    ``(cols [rows_cap, k], valid [rows_cap], clipped [])``.
    """
    take = min(rows_cap, emit.capacity)
    cols = jnp.stack([emit.cols[:take, c] for c in pub_cols], axis=1)
    valid = emit.valid[:take]
    clipped = (jnp.any(emit.valid[take:]) if take < emit.capacity
               else jnp.zeros((), bool))
    if take < rows_cap:
        pad = rows_cap - take
        cols = jnp.concatenate(
            [cols, jnp.zeros((pad, len(pub_cols)), jnp.uint32)])
        valid = jnp.concatenate([valid, jnp.zeros((pad,), bool)])
    return cols, valid, clipped


def run_plan_window_tables(
    plan: Plan, windows: Windows, pub_cols: Tuple[int, ...], rows_cap: int,
    kb: Optional[KnowledgeBase], env: Env, with_stats: bool = False,
):
    """Upstream table producer, per-window: the operator's full step chain,
    then project → distinct → canonical_order (the exact emit relation the
    triple publication constructs from), clipped to ``rows_cap`` rows.

    Returns ``((cols [W, rows_cap, k], valid [W, rows_cap]), ovf [W])``
    (+ a chunk-scalar stats dict when ``with_stats``).
    """
    out_vars = plan_out_vars(plan)
    sig = tuple(sorted(out_vars, key=lambda c: plan.var_names[c]))

    def one(window, wvalid):
        stats: Stats = {} if with_stats else None
        cur = universe_bindings(plan.bind_cap, plan.num_vars)
        cur = run_steps(plan, cur, plan.steps, window, kb, env, stats)
        emit = algebra.canonical_order(
            algebra.distinct(algebra.project(cur, out_vars)), sig)
        cols, valid, clipped = _clip_table(emit, pub_cols, rows_cap)
        valid = valid & wvalid
        ovf = cur.overflow | emit.overflow | clipped
        if with_stats:
            stat_max(stats, "hw_out", jnp.sum(valid.astype(jnp.int32)))
            return (cols, valid), ovf, stats
        return (cols, valid), ovf

    res = jax.vmap(one)(windows.triples, windows.window_valid)
    if not with_stats:
        return res
    table, ovf, per_window = res
    stats = reduce_stats(per_window)
    stat_add(stats, "n_windows",
             jnp.sum(windows.window_valid.astype(jnp.int32)))
    return table, ovf, stats


def run_plan_slide_tables(
    plan: Plan, view: SlideView, pub_cols: Tuple[int, ...], rows_cap: int,
    slides_per_window: int, kb: Optional[KnowledgeBase], env: Env,
    with_stats: bool = False,
):
    """Upstream table producer, incremental: one chunk-level delta pass,
    emitting the span-tagged table (variable columns + the two span
    columns).  The sink's per-window interval test selects each window's
    rows, so the table is produced once per chunk, not once per window.

    Returns ``((cols [rows_cap, k+2], valid [rows_cap]), ovf [])``.
    """
    r = slides_per_window
    stats: Stats = {} if with_stats else None
    cur = algebra.delta_universe(plan.bind_cap, plan.num_vars)
    for step in plan.steps:
        cur = _apply_delta(step, cur, view, kb, env, plan, r - 1, stats)
        if stats is not None:
            stat_max(stats, "hw_bind", _occ(cur))
    nv = plan.num_vars
    out_vars = plan_out_vars(plan)
    # dedup over (variables, span): rows equal in both are interchangeable
    # for every window's interval test, so multiplicity can be dropped here
    emit = algebra.distinct(
        algebra.project(cur, tuple(out_vars) + (nv, nv + 1)))
    cols, valid, clipped = _clip_table(
        emit, tuple(pub_cols) + (nv, nv + 1), rows_cap)
    ovf = cur.overflow | emit.overflow | clipped
    if with_stats:
        stat_max(stats, "hw_out", jnp.sum(valid.astype(jnp.int32)))
        return (cols, valid), ovf, stats
    return (cols, valid), ovf


def run_sink_windows(
    plan: Plan, windows: Windows,
    tables: Dict[str, Tuple[jax.Array, jax.Array]],
    kb: Optional[KnowledgeBase], env: Env, with_stats: bool = False,
):
    """Split-sink twin of :func:`run_plan_windows`: vmaps the rewritten sink
    plan over the RAW windows with the per-window upstream tables as extra
    batched operands.  ``tables[name]`` leaves are ``[W, rows, k]`` /
    ``[W, rows]``.  The finalize tail (and therefore the published bits)
    is identical to the unsplit path — upstream publication triples carry
    their window's max timestamp, so the raw-window ts equals the augmented
    one.
    """
    w = windows.num_windows
    names = tuple(tables)

    def one(window, wid, wvalid, table_vals):
        stats: Stats = {} if with_stats else None
        tdict = dict(zip(names, table_vals))
        cur = universe_bindings(plan.bind_cap, plan.num_vars)
        cur = run_steps(plan, cur, plan.steps, window, kb, env, stats, tdict)
        ts = jnp.max(jnp.where(window.valid, window.ts, 0))
        out, ovf = finalize_bindings(
            plan, cur, ts, wid.astype(jnp.uint32) * plan.bind_cap, stats)
        out = out._replace(valid=out.valid & wvalid)
        if with_stats:
            return out, ovf, stats
        return out, ovf

    res = jax.vmap(one)(
        windows.triples, jnp.arange(w), windows.window_valid,
        tuple(tables[n] for n in names),
    )
    if not with_stats:
        return res
    out, ovf, per_window = res
    stats = reduce_stats(per_window)
    stat_add(stats, "n_windows",
             jnp.sum(windows.window_valid.astype(jnp.int32)))
    return out, ovf, stats


def run_sink_slides(
    plan: Plan, view: SlideView,
    tables: Dict[str, Tuple[jax.Array, jax.Array]],
    slides_per_window: int, max_windows: int,
    kb: Optional[KnowledgeBase], env: Env, with_stats: bool = False,
):
    """Split-sink twin of :func:`run_plan_slides`: the rewritten sink plan's
    delta pass over the merged chunk, joining chunk-level span-tagged
    upstream tables, then the standard per-window interval-select +
    finalize.  Shares :func:`run_plan_slides` outright so the set-to-stream
    tail can never diverge from the recompute path."""
    return run_plan_slides(plan, view, slides_per_window, max_windows,
                           kb, env, with_stats, tables=tables)
