"""Query compiler, decomposer and KB pruner.

Three responsibilities, mirroring the paper:

* ``compile_query``  — Query AST -> executable :class:`~repro.core.engine.Plan`
  (variable numbering, bound-mode resolution, filter placement).
* ``decompose``      — one query -> a DAG of sub-queries (inter-operator
  parallelism, paper Fig. 4): every KB-touching enrichment chain becomes its
  own operator; a final aggregation operator joins the intermediate streams.
* ``prune_kb_for``   — the "used KB" extraction per sub-query (the paper's
  future-work automatic KB division): predicate signature + subclass-closure
  narrowing of ``rdf:type`` objects.

Intermediate streams use the *binding-graph protocol*: each result row of a
sub-query is published as one RDF-graph event ``(row_node, var_pred_v, value)``
so any DSCEP operator (or external client) can consume it — §2's requirement
that "an output stream of one SCEP engine should be ready to be an input of
another SCEP engine".
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from . import query as Q
from .engine import (
    BindingJoin, DistinctStep, FilterBoolStep, FilterInStep, FilterNumStep,
    KBJoin, OptionalSteps, Plan, ProjectStep, ScanJoin, Step, UnionSteps,
    plan_out_vars,
)
from .kb import KBStats, KnowledgeBase, host_rows, kb_from_triples, prune
from .pattern import CompiledPattern, Slot, SlotMode
from .rdf import CLOSURE_PRED_BASE, NUM_BASE, PRED_SPACE, Vocab
from .reasoner import (
    adjacency_from_edges, build_class_index, descendants, subclass_edges,
)


# --------------------------------------------------------------------------
# variable-length paths: closure-pair relations under synthetic predicates
# --------------------------------------------------------------------------

def closure_path_specs(q: Q.Query) -> List[Tuple[int, int]]:
    """Distinct ``(pred, min_hops)`` closure-path specs in first-seen order.

    Spec *i* of a query owns the synthetic predicate ``CLOSURE_PRED_BASE + i``
    — the id the compiled plan's KBJoin probes and the KB augmentation
    materializes pairs under.  Both sides derive the index from this one
    function, so they can never disagree.
    """
    specs: List[Tuple[int, int]] = []
    for item in q.where:
        if isinstance(item, Q.PathClosure):
            key = (item.pred, item.min_hops)
            if key not in specs:
                specs.append(key)
    if len(specs) > PRED_SPACE - CLOSURE_PRED_BASE:
        raise ValueError(
            "query %r uses %d distinct closure paths; the synthetic "
            "predicate band holds %d"
            % (q.name, len(specs), PRED_SPACE - CLOSURE_PRED_BASE))
    return specs


def _host_reach_sets(edges: Sequence[Tuple[int, int]]) -> Dict[int, Set[int]]:
    """``node -> set of nodes it reaches (>= 0 edges, cycle-safe BFS)``."""
    out_edges: Dict[int, List[int]] = {}
    for s, o in edges:
        out_edges.setdefault(s, []).append(o)
    nodes = {x for e in edges for x in e}
    reach: Dict[int, Set[int]] = {}
    for start in nodes:
        seen = {start}
        frontier = [start]
        while frontier:
            nxt = []
            for n in frontier:
                for m in out_edges.get(n, ()):
                    if m not in seen:
                        seen.add(m)
                        nxt.append(m)
            frontier = nxt
        reach[start] = seen
    return reach


def _kernel_reach_set(
    edges: Sequence[Tuple[int, int]], root: int, interpret: bool,
    ancestors: bool,
) -> Set[int]:
    """One root's closure set via the fused descendants/ancestors kernel."""
    from repro.kernels.closure import ops as cl_ops

    idx, ids = build_class_index(edges)
    if root not in idx:
        return {root}
    adj = adjacency_from_edges(edges, idx)
    op = cl_ops.closure_ancestors if ancestors else cl_ops.closure_descendants
    got, count = op(np.asarray(adj), idx[root], out_cap=len(ids),
                    interpret=interpret)
    sel = np.asarray(got)[: int(count)]
    return {int(v) for v in ids[sel]}


def _closure_pairs(
    edges: Sequence[Tuple[int, int]], min_hops: int,
    uses: Sequence[Q.PathClosure], use_pallas: bool, interpret: bool,
) -> Set[Tuple[int, int]]:
    """The pair relation ``{(x, y) : x pred^n y, n >= min_hops}``.

    ``p*``'s zero-length pairs are reflexive over the predicate's edge-graph
    nodes plus the constant endpoints of the query's path expressions (the
    bounded reading of SPARQL's term-universe reflexivity — documented in
    :class:`repro.core.query.PathClosure`).  When every use anchors the same
    endpoint with a constant, only that endpoint's closure set is
    materialized (the fused descendants/ancestors kernel); otherwise the
    full reach matrix is closed once.
    """
    pairs: Set[Tuple[int, int]] = set()
    if min_hops == 0:
        refl = {x for e in edges for x in e}
        for u in uses:
            for t in (u.start, u.end):
                if isinstance(t, Q.Const):
                    refl.add(int(t.id))
        pairs |= {(x, x) for x in refl}
    if not edges:
        return pairs

    const_end = all(isinstance(u.end, Q.Const) for u in uses)
    const_start = all(isinstance(u.start, Q.Const) for u in uses)
    if const_end or const_start:
        # per-root closure set: kernel when Pallas is on, BFS otherwise.
        # p+ composes one explicit edge onto the p* set: the *first* edge
        # for descendants (x -> z ->* root), the *last* for ancestors
        # (root ->* z -> y).
        anchor = "end" if const_end else "start"   # both-const anchors on end
        roots = {int(getattr(u, anchor).id) for u in uses}
        for root in sorted(roots):
            if use_pallas:
                star = _kernel_reach_set(edges, root, interpret,
                                         ancestors=not const_end)
            elif const_end:
                star = {int(v) for v in descendants(edges, root)}
            else:
                star = {int(v) for v in descendants(
                    [(o, s) for s, o in edges], root)}
            if const_end:
                if min_hops == 0:
                    pairs |= {(x, root) for x in star}
                else:
                    pairs |= {(s, root) for s, o in edges if o in star}
            else:
                if min_hops == 0:
                    pairs |= {(root, y) for y in star}
                else:
                    pairs |= {(root, o) for s, o in edges if s in star}
        return pairs

    # mixed / variable endpoints: close the whole reach matrix once
    idx, ids = build_class_index(edges)
    if use_pallas:
        import jax.numpy as jnp
        from repro.kernels.closure import ops as cl_ops

        adj = adjacency_from_edges(edges, idx)
        reach = np.asarray(cl_ops.transitive_closure(
            jnp.asarray(adj), max_depth=len(idx), use_pallas=True,
            interpret=interpret))
        if min_hops == 1:
            reach = (adj @ reach.astype(np.float32)) > 0.5
        pairs |= {(int(ids[i]), int(ids[j]))
                  for i, j in zip(*np.nonzero(reach))}
        return pairs
    reach_sets = _host_reach_sets(edges)
    if min_hops == 0:
        for x, ys in reach_sets.items():
            pairs |= {(x, y) for y in ys}
    else:
        for s, o in edges:
            pairs |= {(s, y) for y in reach_sets[o]}
    return pairs


def augment_kb_with_closures(
    q: Q.Query, kb: KnowledgeBase,
    use_pallas: bool = False, interpret: bool = True,
) -> KnowledgeBase:
    """Materialize every variable-length path of ``q`` as closure-pair rows.

    For each distinct ``(pred, min_hops)`` spec, the predicate's edge graph
    is transitively closed (through :mod:`repro.kernels.closure` when
    ``use_pallas``, host BFS otherwise — identical pair sets) and the pairs
    appended to the KB as synthetic triples ``(x, CLOSURE_PRED_BASE+i, y)``.
    The compiled plan turns each ``PathClosure`` into one ordinary
    :class:`~repro.core.engine.KBJoin` against that relation — no unrolled
    join chain, and every KB-access method/kernel path applies unchanged.
    """
    specs = closure_path_specs(q)
    if not specs:
        return kb
    rows = host_rows(kb)
    out_rows: List[Tuple[int, int, int]] = [
        (int(s), int(p), int(o)) for s, p, o in rows
    ]
    for i, (pid, min_hops) in enumerate(specs):
        uses = [it for it in q.where if isinstance(it, Q.PathClosure)
                and (it.pred, it.min_hops) == (pid, min_hops)]
        m = rows[:, 1] == np.uint32(pid)
        edges = [(int(s), int(o)) for s, _, o in rows[m]]
        pairs = _closure_pairs(edges, min_hops, uses, use_pallas, interpret)
        cp = CLOSURE_PRED_BASE + i
        out_rows.extend((x, cp, y) for x, y in sorted(pairs))
    return kb_from_triples(out_rows)


# --------------------------------------------------------------------------
# KB-access cost model (``kb_method="auto"``)
# --------------------------------------------------------------------------

PROBE_K_CAP = 64    # largest k_max the planner will derive for a probe


def _round_up_k(fanout: int) -> int:
    """Derived probe width: observed max fan-out rounded up to a multiple of
    8 (gather-lane friendly), floor 8."""
    return max(8, ((int(fanout) + 7) // 8) * 8)


def _choose_kb_method(
    cp: CompiledPattern, kb_stats: Optional[KBStats], default_k: int,
) -> Tuple[str, int]:
    """Per-join access-method selection from host-side KB statistics.

    A probe requires a const predicate and an anchored endpoint; its
    derived ``k_max`` is the observed max probe-range width (composite-key
    collisions included, see :class:`repro.core.kb.PredStat`) rounded up —
    so a selected probe can never overflow its gather.  The cost comparison
    is the paper's Figs. 5-7 asymmetry: a scan pays the *whole* partition
    per join, a probe pays O(log N) + ``k_max`` gathers per binding row.
    Fan-outs above :data:`PROBE_K_CAP` fall back to the fused scan (wide
    gathers erase the probe's advantage and the scan vectorizes perfectly).
    """
    if kb_stats is None:
        return "scan", default_k
    if cp.p.mode != SlotMode.CONST or (
            cp.s.mode == SlotMode.FREE and cp.o.mode == SlotMode.FREE):
        return "scan", default_k
    stat = kb_stats.preds.get(int(cp.p.const))
    if stat is None:
        # predicate absent from this slice: every probe is an instant miss
        return "probe", _round_up_k(0)
    fanout = stat.k_ps if cp.s.mode != SlotMode.FREE else stat.k_po
    if fanout > PROBE_K_CAP:
        return "scan", default_k
    k = _round_up_k(fanout)
    n = max(1, kb_stats.total_rows)
    if math.ceil(math.log2(n + 1)) + k >= n:
        return "scan", default_k          # tiny partition: scan is cheaper
    return "probe", k


def _kb_item_var_names(item: Q.WhereItem) -> Set[str]:
    if isinstance(item, Q.Pattern):
        return set(item.vars())
    if isinstance(item, (Q.PathKB, Q.PathClosure)):
        return {t.name for t in (item.start, item.end)
                if isinstance(t, Q.Var)}
    if isinstance(item, Q.FilterSubclass):
        return {item.var}
    return set()


def _kb_item_cost(
    item: Q.WhereItem, kb_stats: KBStats,
    closure_specs: Sequence[Tuple[int, int]], bound_names: Set[str],
) -> float:
    """Estimated per-binding fan-out of one KB item (lower = more
    selective), given the variable names bound before it runs."""

    def pat_cost(s_term, pred: Optional[int], o_term) -> float:
        if pred is None:                       # variable predicate: full scan
            return float(kb_stats.total_rows)
        stat = kb_stats.preds.get(int(pred))
        if stat is None:
            return 0.0                         # empty relation: kills all rows

        def anchored(t) -> bool:
            return isinstance(t, Q.Const) or (
                isinstance(t, Q.Var) and t.name in bound_names)

        if anchored(s_term):
            return float(stat.k_ps)
        if anchored(o_term):
            return float(stat.k_po)
        return float(stat.rows)                # unanchored: rows x bindings

    if isinstance(item, Q.Pattern):
        pred = item.p.id if isinstance(item.p, Q.Const) else None
        return pat_cost(item.s, pred, item.o)
    if isinstance(item, Q.PathKB):
        end = item.end if len(item.preds) == 1 else Q.Var("__chain")
        return pat_cost(item.start, item.preds[0], end)
    if isinstance(item, Q.PathClosure):
        cp = CLOSURE_PRED_BASE + closure_specs.index(
            (item.pred, item.min_hops))
        return pat_cost(item.start, cp, item.end)
    if isinstance(item, Q.FilterSubclass):
        return pat_cost(Q.Var(item.var), item.type_pred, Q.Var("__cls"))
    return float("inf")


def order_kb_items(
    items: List[Q.WhereItem], kb_stats: KBStats,
    closure_specs: Sequence[Tuple[int, int]], bound_names: Set[str],
) -> List[Q.WhereItem]:
    """Greedy selectivity ordering of a query's KB-join sequence.

    At every step the cheapest remaining item under the current bound-name
    set runs next (anchored low-fan-out joins first), shrinking the
    intermediate binding population every downstream step sees.  Ties keep
    listed order, so the ordering is deterministic.  Safe by construction:
    the binding *set* a join sequence produces is order-independent, and
    since PR 4 the published row order is canonical
    (:func:`repro.core.algebra.canonical_order`), so reordering can never
    change the output stream.
    """
    names = set(bound_names)
    pending = list(enumerate(items))
    ordered: List[Q.WhereItem] = []
    while pending:
        idx, best = min(
            pending,
            key=lambda t: (_kb_item_cost(t[1], kb_stats, closure_specs,
                                         names), t[0]),
        )
        pending.remove((idx, best))
        ordered.append(best)
        names |= _kb_item_var_names(best)
    return ordered


# --------------------------------------------------------------------------
# compilation
# --------------------------------------------------------------------------

class _VarTable:
    def __init__(self) -> None:
        self.names: List[str] = []

    def col(self, name: str) -> int:
        if name not in self.names:
            self.names.append(name)
        return self.names.index(name)


def _slot(term: Q.Term, vt: _VarTable, bound: Set[int]) -> Slot:
    if isinstance(term, Q.Const):
        return Slot.const_(term.id)
    c = vt.col(term.name)
    return Slot.bound(c) if c in bound else Slot.free(c)


def _compile_pattern(
    pat: Q.Pattern, vt: _VarTable, bound: Set[int], scan: bool = False
) -> CompiledPattern:
    """Resolve slot modes.

    ``scan=True`` compiles a *window scan* pattern: every variable slot is
    FREE (the scan matches independently; equality with earlier bindings is
    enforced by the natural join on the shared columns).  KB patterns keep
    BOUND slots so the join condition is evaluated inside the KB probe/scan.
    """
    s = _slot(pat.s, vt, bound)
    p = _slot(pat.p, vt, bound)
    o = _slot(pat.o, vt, bound)
    if scan:
        s, p, o = (
            Slot.free(sl.var) if sl.mode != SlotMode.CONST else sl
            for sl in (s, p, o)
        )
    for sl in (s, p, o):
        if sl.mode == SlotMode.FREE:
            bound.add(sl.var)
    return CompiledPattern(s, p, o)


def _compile_filter_expr(e: Q.FilterExpr, vt: "_VarTable") -> Tuple:
    """FilterNum/FilterBool tree -> the engine's static tuple expression."""
    if isinstance(e, Q.FilterNum):
        return ("cmp", vt.col(e.var), e.op, e.value_id)
    if e.op == "not":
        return ("not", _compile_filter_expr(e.args[0], vt))
    return (e.op,) + tuple(_compile_filter_expr(a, vt) for a in e.args)


def plan_supports_delta(plan: Plan) -> bool:
    """Whether incremental (slide-delta) evaluation is valid for ``plan``.

    Delta evaluation (``engine.run_plan_slides``) tracks, per binding row,
    the span of slides its stream triples came from, and selects each
    window's rows by an interval test — which is only sound when every step
    is *monotone* (a derivation exists in a window iff all its contributing
    triples do): stream scans, KB joins (any method — the PR 5 cost model
    composes unchanged since the span columns ride outside the variable
    columns), filters, UNION, and BindingJoin (an upstream table row carries
    the union span of its contributing slides; the max-merge unions spans
    across the join, and a combined derivation fits a window iff every
    constituent span does).  OPTIONAL is non-monotone (a binding's
    extension depends on what else is in the window), and a plan without
    output variables skips the pre-CONSTRUCT distinct, making row
    multiplicity observable; both fall back to per-window recompute.
    """
    def steps_ok(steps: Sequence[Step]) -> bool:
        for s in steps:
            if isinstance(s, UnionSteps):
                if not (steps_ok(s.left) and steps_ok(s.right)):
                    return False
            elif not isinstance(s, (ScanJoin, KBJoin, FilterNumStep,
                                    FilterBoolStep, FilterInStep,
                                    BindingJoin)):
                return False
        return True

    has_out = any(
        kind == "var" for tpl in plan.templates for kind, _ in tpl)
    return has_out and steps_ok(plan.steps)


def compile_query(
    q: Q.Query,
    kb_method: str = "scan",
    scan_cap: int = 128,
    bind_cap: int = 256,
    out_cap: int = 512,
    k_max: int = 8,
    use_pallas: bool = False,
    fuse_compaction: bool = False,
    join_bm: int | None = None,
    join_bn: int | None = None,
    interpret: bool = True,
    kb_stats: Optional[KBStats] = None,
) -> Plan:
    """Compile the AST into a Plan.

    Ordering heuristic: stream patterns in listed order (they are selective —
    windows are small), then KB items anchored by already-bound variables,
    then filters as soon as their variable is bound, then OPTIONAL/UNION
    groups, preserving SPARQL's left-biased semantics for the shapes the
    paper uses.

    ``kb_method="auto"`` (with ``kb_stats`` from
    :func:`repro.core.kb.collect_kb_stats` over the operator's attached
    partition) turns the single global method knob into a per-join cost
    decision: each KB join independently picks probe — with a *derived*
    ``k_max`` covering the observed fan-out — or the fused scan
    (:func:`_choose_kb_method`), and the KB-join sequence itself is
    greedily selectivity-ordered (:func:`order_kb_items`) instead of
    executing in listed order.  Without stats, ``"auto"`` degrades to the
    scan method.
    """
    vt = _VarTable()
    bound: Set[int] = set()
    steps: List[Step] = []
    pending_filters: List[Q.WhereItem] = []
    aux = [0]
    closure_specs = closure_path_specs(q)

    def _kb_step(cp: CompiledPattern) -> KBJoin:
        method, k = kb_method, k_max
        if kb_method == "auto":
            method, k = _choose_kb_method(cp, kb_stats, k_max)
        return KBJoin(cp, method, k, use_pallas, fuse_compaction,
                      join_bm, join_bn, interpret)

    def fresh_aux() -> str:
        aux[0] += 1
        return "__aux%d" % aux[0]

    def _filter_vars(item) -> Tuple[str, ...]:
        return (item.var,) if isinstance(item, Q.FilterNum) else item.vars()

    def _filter_step(item) -> Step:
        if isinstance(item, Q.FilterNum):
            return FilterNumStep(vt.col(item.var), item.op, item.value_id)
        return FilterBoolStep(_compile_filter_expr(item, vt))

    def flush_filters():
        for item in list(pending_filters):
            if all(vt.col(v) in bound for v in _filter_vars(item)):
                steps.append(_filter_step(item))
                pending_filters.remove(item)

    # pass 1: stream patterns, greedily ordered so every pattern (after the
    # first) shares a variable with the already-joined set — avoids cross
    # joins that would blow the binding capacity (a standard join-order
    # optimization; keeps listed order among equally-connected candidates)
    remaining = [
        it for it in q.where if isinstance(it, Q.Pattern) and it.src == Q.STREAM
    ]
    for item in q.where:
        if isinstance(item, (Q.FilterNum, Q.FilterBool)):
            pending_filters.append(item)
    bound_names: Set[str] = set()
    while remaining:
        pick = next(
            (p for p in remaining if set(p.vars()) & bound_names), remaining[0]
        )
        remaining.remove(pick)
        shared_before = set(bound)
        cp = _compile_pattern(pick, vt, bound, scan=True)
        bound_names |= set(pick.vars())
        shared = tuple(
            sorted(
                {sl.var for sl in (cp.s, cp.p, cp.o) if sl.mode != SlotMode.CONST}
                & shared_before
            )
        )
        steps.append(ScanJoin(cp, shared))
        flush_filters()

    # pass 2: KB patterns / paths / subclass reasoning.  Listed order by
    # default; under kb_method="auto" with statistics the sequence is
    # greedily reordered by estimated selectivity (cheap anchored joins
    # first) — output-invariant thanks to algebra.canonical_order.
    kb_items: List[Q.WhereItem] = [
        it for it in q.where
        if (isinstance(it, Q.Pattern) and it.src == Q.KB)
        or isinstance(it, (Q.PathKB, Q.PathClosure, Q.FilterSubclass))
    ]
    if kb_method == "auto" and kb_stats is not None and len(kb_items) > 1:
        kb_items = order_kb_items(kb_items, kb_stats, closure_specs,
                                  bound_names)
    for item in kb_items:
        if isinstance(item, Q.Pattern) and item.src == Q.KB:
            cp = _compile_pattern(item, vt, bound)
            steps.append(_kb_step(cp))
        elif isinstance(item, Q.PathKB):
            cur: Q.Term = item.start
            for i, pid in enumerate(item.preds):
                nxt = item.end if i == len(item.preds) - 1 else Q.Var(fresh_aux())
                cp = _compile_pattern(
                    Q.Pattern(cur, Q.Const(pid), nxt, Q.KB), vt, bound
                )
                steps.append(_kb_step(cp))
                cur = nxt
        elif isinstance(item, Q.PathClosure):
            # one join against the materialized closure-pair relation (see
            # augment_kb_with_closures) — never an unrolled join chain
            cp_pred = CLOSURE_PRED_BASE + closure_specs.index(
                (item.pred, item.min_hops))
            cp = _compile_pattern(
                Q.Pattern(item.start, Q.Const(cp_pred), item.end, Q.KB),
                vt, bound,
            )
            steps.append(_kb_step(cp))
        elif isinstance(item, Q.FilterSubclass):
            cls_var = Q.Var(fresh_aux())
            cp = _compile_pattern(
                Q.Pattern(Q.Var(item.var), Q.Const(item.type_pred), cls_var, Q.KB),
                vt, bound,
            )
            steps.append(_kb_step(cp))
            steps.append(
                FilterInStep(vt.col(cls_var.name), "closure:%d" % item.super_class)
            )
        flush_filters()

    # pass 3: optional / union groups
    for item in q.where:
        if isinstance(item, Q.OptionalGroup):
            shared_before = set(bound)
            sub_steps: List[Step] = []
            sub_bound: Set[int] = set()
            for p in item.patterns:
                if p.src == Q.KB:
                    cp = _compile_pattern(p, vt, sub_bound)
                    sub_steps.append(_kb_step(cp))
                else:
                    before = set(sub_bound)
                    cp = _compile_pattern(p, vt, sub_bound, scan=True)
                    sub_shared = tuple(
                        sorted(
                            {sl.var for sl in (cp.s, cp.p, cp.o) if sl.mode != SlotMode.CONST}
                            & before
                        )
                    )
                    sub_steps.append(ScanJoin(cp, sub_shared))
            bound |= sub_bound
            shared = tuple(
                sorted(
                    shared_before
                    & {vt.col(v) for p in item.patterns for v in p.vars()}
                )
            )
            steps.append(OptionalSteps(tuple(sub_steps), shared))
        elif isinstance(item, Q.UnionGroup):
            union_before = set(bound)

            def _branch(pats: Tuple[Q.Pattern, ...]) -> Tuple[Step, ...]:
                bs: List[Step] = []
                br_bound = set(union_before)
                for p in pats:
                    if p.src == Q.KB:
                        cp = _compile_pattern(p, vt, br_bound)
                        bs.append(_kb_step(cp))
                    else:
                        before = set(br_bound)
                        cp = _compile_pattern(p, vt, br_bound, scan=True)
                        shared = tuple(
                            sorted(
                                {sl.var for sl in (cp.s, cp.p, cp.o) if sl.mode != SlotMode.CONST}
                                & before
                            )
                        )
                        bs.append(ScanJoin(cp, shared))
                bound.update(br_bound)
                return tuple(bs)

            steps.append(UnionSteps(_branch(item.left), _branch(item.right)))
        flush_filters()

    # any filters whose variables only appear in construct scope
    for item in pending_filters:
        steps.append(_filter_step(item))

    # construct templates
    def tslot(t):
        if isinstance(t, Q.RowId):
            return ("row", t.ns * (1 << 18))   # per-operator id namespace
        if isinstance(t, Q.Const):
            return ("const", t.id)
        return ("var", vt.col(t.name))

    templates = tuple(
        (tslot(t.s), tslot(t.p), tslot(t.o)) for t in q.construct
    )
    return Plan(
        name=q.name,
        num_vars=max(1, len(vt.names)),
        var_names=tuple(vt.names) or ("_",),
        steps=tuple(steps),
        templates=templates,
        scan_cap=scan_cap,
        bind_cap=bind_cap,
        out_cap=out_cap,
    )


# --------------------------------------------------------------------------
# plan sharing: fingerprints, const abstraction, shared prefixes
# --------------------------------------------------------------------------
#
# The serving layer (repro.serve.engine) runs hundreds of compiled plans on
# one stream and deduplicates shared work at three granularities:
#
# * identical plans   — ``plan_fingerprint`` (the plan minus its name):
#   equal fingerprints on the same (KB, env) produce identical outputs, so
#   the engine evaluates one representative and fans the result out;
# * identical shapes  — ``plan_shape`` abstracts every constant (slot
#   consts, filter literals, CONSTRUCT const ids, closure-set env keys)
#   into positional markers: plans with equal shapes differ only in a
#   ``uint32`` vector (``plan_consts``) and their env arrays, so a cohort
#   of them executes as ONE program ``vmap``-ed over the const axis, with
#   ``bind_plan_consts`` substituting the traced per-query constants back
#   into the step dataclasses inside the trace;
# * identical prefixes — ``shared_prefix_len`` finds the longest common
#   leading step run of two plans, letting the serving engine evaluate a
#   common KB-join prefix once and run only the differing suffixes per
#   query.

def plan_fingerprint(plan: Plan) -> Tuple:
    """Everything semantically significant about a compiled plan except its
    name.  Two plans with equal fingerprints, executed against the same KB
    and env, publish bit-identical output streams — the dedup key of the
    serving layer."""
    return (plan.num_vars, plan.var_names, plan.steps, plan.templates,
            plan.scan_cap, plan.bind_cap, plan.out_cap)


def _map_plan_consts(plan: Plan, const_fn, set_fn) -> Plan:
    """Rebuild ``plan`` with ``const_fn(value, ctx)`` applied to every
    constant (``ctx`` is ``"slot"``, ``"filter"`` or ``"template"``) and
    ``set_fn(name)`` to every :class:`FilterInStep` env key.  The one walk
    order shared by shape/extract/bind, so they can never disagree."""

    def map_slot(sl: Slot) -> Slot:
        if sl.mode != SlotMode.CONST:
            return sl
        return Slot(SlotMode.CONST, const=const_fn(sl.const, "slot"), var=-1)

    def map_pat(cp: CompiledPattern) -> CompiledPattern:
        return CompiledPattern(map_slot(cp.s), map_slot(cp.p), map_slot(cp.o))

    def map_expr(expr: Tuple) -> Tuple:
        if expr[0] == "cmp":
            _, var, op, value_id = expr
            return ("cmp", var, op, const_fn(value_id, "filter"))
        if expr[0] == "not":
            return ("not", map_expr(expr[1]))
        return (expr[0],) + tuple(map_expr(a) for a in expr[1:])

    def map_step(step: Step) -> Step:
        if isinstance(step, ScanJoin):
            return ScanJoin(map_pat(step.pat), step.shared)
        if isinstance(step, KBJoin):
            return dataclasses.replace(step, pat=map_pat(step.pat))
        if isinstance(step, FilterNumStep):
            return FilterNumStep(step.var, step.op,
                                 const_fn(step.value_id, "filter"))
        if isinstance(step, FilterBoolStep):
            return FilterBoolStep(map_expr(step.expr))
        if isinstance(step, FilterInStep):
            return FilterInStep(step.var, set_fn(step.set_name))
        if isinstance(step, OptionalSteps):
            return OptionalSteps(tuple(map_step(s) for s in step.sub),
                                 step.shared)
        if isinstance(step, UnionSteps):
            return UnionSteps(tuple(map_step(s) for s in step.left),
                              tuple(map_step(s) for s in step.right))
        return step

    def map_tpl(spec: Tuple) -> Tuple:
        kind, val = spec
        if kind == "const":
            return ("const", const_fn(val, "template"))
        return spec

    return dataclasses.replace(
        plan,
        steps=tuple(map_step(s) for s in plan.steps),
        templates=tuple(
            tuple(map_tpl(spec) for spec in tpl) for tpl in plan.templates
        ),
    )


def plan_shape(plan: Plan) -> Plan:
    """The plan with every constant replaced by a positional marker and
    every env key by a canonical ``__set%d`` name (the cohort-batching
    grouping key — a hashable Plan, name cleared).  Filter-literal markers
    additionally carry the term-vs-numeric classification, which selects
    comparison *semantics* and so must stay static per cohort."""
    counter = [0]
    sets: Dict[str, str] = {}

    def const_fn(value, ctx):
        i = counter[0]
        counter[0] += 1
        if ctx == "filter":
            return ("c%d" % i, bool(int(value) < int(NUM_BASE)))
        return "c%d" % i

    def set_fn(name):
        if name not in sets:
            sets[name] = "__set%d" % len(sets)
        return sets[name]

    return dataclasses.replace(
        _map_plan_consts(plan, const_fn, set_fn), name="")


def plan_consts(plan: Plan) -> np.ndarray:
    """The plan's constants as a ``uint32`` vector, in ``plan_shape``'s walk
    order — the only thing (besides env arrays) that distinguishes two
    plans with equal shapes."""
    vals: List[int] = []

    def const_fn(value, ctx):
        vals.append(int(value))
        return value

    _map_plan_consts(plan, const_fn, lambda n: n)
    return np.asarray(vals, np.uint32)


def plan_set_names(plan: Plan) -> Tuple[str, ...]:
    """FilterInStep env keys in first-appearance walk order — the caller
    stacks each query's env arrays under ``__set%d`` in this order."""
    names: List[str] = []

    def set_fn(name):
        if name not in names:
            names.append(name)
        return name

    _map_plan_consts(plan, lambda v, c: v, set_fn)
    return tuple(names)


def bind_plan_consts(plan: Plan, const_vec) -> Plan:
    """Substitute ``const_vec[i]`` (possibly traced uint32 scalars) for the
    plan's constants, renaming env keys canonically — the inside-the-trace
    half of cohort batching: one representative plan, ``vmap``-ed over the
    per-query const axis.  Filter literals keep their *static* term/numeric
    classification from the representative (part of the cohort shape), so
    the traced comparison ops are identical to the unbatched plan's."""
    from .algebra import BatchedConst

    counter = [0]
    sets: Dict[str, str] = {}

    def const_fn(value, ctx):
        i = counter[0]
        counter[0] += 1
        traced = const_vec[i]
        if ctx == "filter":
            return BatchedConst(traced, bool(int(value) < int(NUM_BASE)))
        return traced

    def set_fn(name):
        if name not in sets:
            sets[name] = "__set%d" % len(sets)
        return sets[name]

    return _map_plan_consts(plan, const_fn, set_fn)


def shared_prefix_len(a: Plan, b: Plan) -> int:
    """Longest common leading step run of two plans.  Only meaningful for
    sharing when the plans agree on ``num_vars`` and capacities (equal
    prefixes then bind exactly the same columns — compilation is
    deterministic), which the serving engine's grouping enforces."""
    n = 0
    for sa, sb in zip(a.steps, b.steps):
        if sa != sb:
            break
        n += 1
    return n


def count_kb_joins(steps: Sequence[Step]) -> int:
    """KB joins in a step sequence (the expensive work prefix sharing
    amortizes — used to decide whether a shared prefix is material)."""
    total = 0
    for s in steps:
        if isinstance(s, KBJoin):
            total += 1
        elif isinstance(s, OptionalSteps):
            total += count_kb_joins(s.sub)
        elif isinstance(s, UnionSteps):
            total += count_kb_joins(s.left) + count_kb_joins(s.right)
    return total


# --------------------------------------------------------------------------
# plan EXPLAIN — the cost model's decisions as a reportable artifact
# --------------------------------------------------------------------------

def plan_caps(plan: Plan) -> Dict[str, int]:
    """The plan's configured capacities plus the largest probe ``k_max`` any
    KBJoin carries — the denominators the engine's high-water gauges
    (repro.obs.metrics) saturate against."""
    def max_k(steps: Sequence[Step]) -> int:
        k = 0
        for s in steps:
            if isinstance(s, KBJoin) and s.method == "probe":
                k = max(k, s.k_max)
            elif isinstance(s, OptionalSteps):
                k = max(k, max_k(s.sub))
            elif isinstance(s, UnionSteps):
                k = max(k, max_k(s.left), max_k(s.right))
        return k

    return {"scan_cap": plan.scan_cap, "bind_cap": plan.bind_cap,
            "out_cap": plan.out_cap, "k_max": max_k(plan.steps)}


def _render_slot(slot: Slot, plan: Plan, vocab: Optional[Vocab]) -> str:
    if slot.mode == SlotMode.CONST:
        cid = int(slot.const)
        if CLOSURE_PRED_BASE <= cid < PRED_SPACE:
            return "<closure#%d>" % (cid - CLOSURE_PRED_BASE)
        return vocab.to_str(cid) if vocab is not None else "<%d>" % cid
    name = (plan.var_names[slot.var] if slot.var < len(plan.var_names)
            else "_%d" % slot.var)
    return "?" + name


def _render_pattern(cp: CompiledPattern, plan: Plan,
                    vocab: Optional[Vocab]) -> str:
    return " ".join(_render_slot(sl, plan, vocab) for sl in (cp.s, cp.p, cp.o))


def _names(plan: Plan, cols: Sequence[int]) -> List[str]:
    return [plan.var_names[c] if c < len(plan.var_names) else "_%d" % c
            for c in cols]


def _explain_steps(
    steps: Sequence[Step], plan: Plan, kb_stats: Optional[KBStats],
    vocab: Optional[Vocab],
) -> List[Dict]:
    out: List[Dict] = []
    for step in steps:
        if isinstance(step, ScanJoin):
            out.append({
                "step": "ScanJoin",
                "pattern": _render_pattern(step.pat, plan, vocab),
                "shared": _names(plan, step.shared),
            })
        elif isinstance(step, KBJoin):
            entry: Dict = {
                "step": "KBJoin",
                "pattern": _render_pattern(step.pat, plan, vocab),
                "method": step.method,
            }
            if step.method == "probe":
                entry["k_max"] = step.k_max
            cp = step.pat
            if cp.s.mode != SlotMode.FREE:
                entry["anchor"] = "s"
            elif cp.o.mode != SlotMode.FREE:
                entry["anchor"] = "o"
            if kb_stats is not None and cp.p.mode == SlotMode.CONST:
                stat = kb_stats.preds.get(int(cp.p.const))
                if stat is None:
                    entry["est_rows"], entry["est_fanout"] = 0, 0.0
                else:
                    entry["est_rows"] = int(stat.rows)
                    fan = (stat.k_ps if cp.s.mode != SlotMode.FREE
                           else stat.k_po if cp.o.mode != SlotMode.FREE
                           else stat.rows)
                    entry["est_fanout"] = float(fan)
            out.append(entry)
        elif isinstance(step, FilterNumStep):
            out.append({
                "step": "FilterNum",
                "pattern": "?%s %s %s" % (
                    plan.var_names[step.var], step.op,
                    vocab.to_str(step.value_id) if vocab is not None
                    else step.value_id),
            })
        elif isinstance(step, FilterBoolStep):
            out.append({"step": "FilterBool", "pattern": repr(step.expr)})
        elif isinstance(step, FilterInStep):
            out.append({
                "step": "FilterIn",
                "pattern": "?%s in env[%s]" % (
                    plan.var_names[step.var], step.set_name),
            })
        elif isinstance(step, OptionalSteps):
            out.append({
                "step": "Optional",
                "shared": _names(plan, step.shared),
                "sub": _explain_steps(step.sub, plan, kb_stats, vocab),
            })
        elif isinstance(step, UnionSteps):
            out.append({
                "step": "Union",
                "left": _explain_steps(step.left, plan, kb_stats, vocab),
                "right": _explain_steps(step.right, plan, kb_stats, vocab),
            })
        elif isinstance(step, BindingJoin):
            out.append({
                "step": "BindingJoin",
                "source": step.source,
                "cols": _names(plan, step.cols),
                "shared": _names(plan, step.shared),
                "replace": step.replace,
            })
        elif isinstance(step, DistinctStep):
            out.append({"step": "Distinct"})
        elif isinstance(step, ProjectStep):
            out.append({"step": "Project", "pattern": ", ".join(
                "?" + n for n in _names(plan, step.keep))})
        else:
            out.append({"step": type(step).__name__})
    return out


def explain_plan(
    plan: Plan, kb_stats: Optional[KBStats] = None,
    vocab: Optional[Vocab] = None,
) -> Dict:
    """The compiled plan's decisions as a JSON-ready artifact.

    Per step: the rendered pattern, the chosen KB-access method and derived
    ``k_max`` and — when ``kb_stats`` (from
    :func:`repro.core.kb.collect_kb_stats`) is supplied — the estimated
    per-binding fan-out the cost model compared (``est_fanout``) and the
    relation size (``est_rows``).  The step list order *is* the join order
    the cost model committed to.  Pure host-side introspection: nothing
    here touches the compiled step functions.
    """
    return {
        "plan": plan.name,
        "var_names": list(plan.var_names),
        "caps": plan_caps(plan),
        "delta_capable": plan_supports_delta(plan),
        "steps": _explain_steps(plan.steps, plan, kb_stats, vocab),
        "construct_templates": len(plan.templates),
    }


# --------------------------------------------------------------------------
# environment (closure sets) and KB pruning — the "used KB" machinery
# --------------------------------------------------------------------------

def prepare_env(
    q: Q.Query, kb: KnowledgeBase,
    use_pallas: bool = False, interpret: bool = True,
) -> Dict[str, np.ndarray]:
    """Compute closure sets required by the query's reasoning filters.

    ``use_pallas=True`` computes each subclass closure with the fused
    Pallas closure kernel (:func:`repro.kernels.closure.ops.closure_descendants`)
    instead of the host-side BFS — ``interpret`` selects the kernel's
    interpreter vs real-accelerator compilation (the config-plumbed knob).
    Both paths produce the identical sorted id set.
    """
    env: Dict[str, np.ndarray] = {}
    for item in q.where:
        if isinstance(item, Q.FilterSubclass):
            key, arr = closure_env_entry(
                kb, item.subclass_pred, item.super_class, use_pallas,
                interpret)
            env[key] = arr
    return env


def closure_env_entry(
    kb: KnowledgeBase, subclass_pred: int, super_class: int,
    use_pallas: bool = False, interpret: bool = True,
):
    """One :func:`prepare_env` entry: ``("closure:<super>", sorted id set)``.

    Factored out so the serving layer can materialize each distinct
    ``(subclass_pred, super_class)`` closure set ONCE and share the array
    across every registered query that filters on it."""
    import jax.numpy as jnp

    edges = subclass_edges(kb, subclass_pred)
    return "closure:%d" % super_class, jnp.asarray(
        _closure_set(edges, super_class, use_pallas, interpret))


def _closure_set(
    edges, root: int, use_pallas: bool, interpret: bool
) -> np.ndarray:
    if use_pallas and edges:
        idx, ids = build_class_index(edges)
        if root in idx:
            from repro.kernels.closure import ops as cl_ops

            adj = adjacency_from_edges(edges, idx)
            dids, count = cl_ops.closure_descendants(
                np.asarray(adj), idx[root], out_cap=len(ids),
                interpret=interpret)
            sel = np.asarray(dids)[: int(count)]
            return np.sort(ids[sel]).astype(np.uint32)
        # no subclass edge touches the root: closure is just {root}
        return np.asarray([root], np.uint32)
    return descendants(edges, root)


def kb_signature(q: Q.Query) -> Tuple[Tuple[int, ...], Dict[int, Set[int]]]:
    """(predicates, {pred: allowed objects}) this query can ever touch."""
    preds = tuple(q.kb_predicates())
    narrow: Dict[int, Set[int]] = {}
    return preds, narrow


def prune_kb_for(q: Q.Query, kb: KnowledgeBase, capacity: Optional[int] = None,
                 closure_narrow: bool = True) -> KnowledgeBase:
    """Extract this query's used KB (paper §6 future work, implemented).

    Keeps only triples whose predicate the query mentions; for
    ``FilterSubclass`` reasoning, ``rdf:type`` rows are additionally narrowed
    to the subclass closure of the filter's super-class.  Synthetic
    closure-pair predicates (``PathClosure`` lowering) are kept when the
    query declares the matching spec — pass the *augmented* KB
    (:func:`augment_kb_with_closures`) for closure-path queries.
    """
    specs = closure_path_specs(q)
    preds = tuple(sorted(set(kb_signature(q)[0]) | {
        CLOSURE_PRED_BASE + i for i in range(len(specs))
    }))
    closure_traversed = {pid for pid, _ in specs}
    objects_by_pred: Dict[int, Set[int]] = {}
    if closure_narrow:
        for item in q.where:
            if isinstance(item, Q.FilterSubclass):
                # never narrow a predicate a closure path traverses — the
                # pair materialization needs its full edge set (pruning may
                # legally run before augment_kb_with_closures)
                if item.type_pred in closure_traversed:
                    continue
                edges = subclass_edges(kb, item.subclass_pred)
                cls = set(int(c) for c in descendants(edges, item.super_class))
                objects_by_pred.setdefault(item.type_pred, set()).update(cls)
    return prune(kb, preds, objects_by_pred or None, capacity)


# --------------------------------------------------------------------------
# decomposition into an operator DAG (paper Fig. 4)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SubQuery:
    """One SCEP operator's query + its used-KB signature."""

    query: Q.Query
    inputs: Tuple[str, ...] = ("stream",)   # upstream operator names
    touches_kb: bool = False


@dataclasses.dataclass
class OperatorDAG:
    name: str
    subqueries: Dict[str, SubQuery]
    final: str                              # name of the aggregation sub-query
    var_preds: Dict[str, int]               # binding-graph protocol predicates
    row_base: int                           # term id base for row nodes


def _var_pred(vocab: Vocab, name: str) -> int:
    return vocab.pred("?:%s" % name)


def decompose(q: Q.Query, vocab: Vocab) -> OperatorDAG:
    """Split a query into KB-touching enrichment operators + an aggregator.

    Every KB item group (grouped by anchor variable — the stream variable the
    KB chain hangs off) becomes a sub-query that (a) scans the minimal stream
    patterns binding its anchor, (b) runs its KB chain, and (c) publishes its
    bindings on the binding-graph protocol.  Stream-only items stay in the
    final aggregation operator, which joins all intermediate streams on their
    shared variables (QueryG in the paper's Fig. 4: "only aggregates the
    resulting streams and correlates").
    """
    stream_pats = [
        it for it in q.where if isinstance(it, Q.Pattern) and it.src == Q.STREAM
    ]
    kb_items: List[Q.WhereItem] = [
        it for it in q.where
        if (isinstance(it, Q.Pattern) and it.src == Q.KB)
        or isinstance(it, (Q.PathKB, Q.PathClosure, Q.FilterSubclass))
    ]
    other_items = [
        it for it in q.where if it not in stream_pats and it not in kb_items
    ]

    def item_vars(it: Q.WhereItem) -> Set[str]:
        if isinstance(it, Q.Pattern):
            return set(it.vars())
        if isinstance(it, (Q.PathKB, Q.PathClosure)):
            return {t.name for t in (it.start, it.end) if isinstance(t, Q.Var)}
        if isinstance(it, Q.FilterSubclass):
            return {it.var}
        return set()

    stream_vars: Set[str] = set()
    for p in stream_pats:
        stream_vars |= set(p.vars())

    # group KB items into *connected components* (shared variables), so a
    # chain that hangs off the stream only transitively — e.g. cell -(KB)->
    # street -(KB)-> district, where only `cell` is a stream variable — stays
    # in one operator and its correlations survive.  Each component is
    # anchored at the first stream variable any of its members touches.
    n_items = len(kb_items)
    parent = list(range(n_items))

    def find(i: int) -> int:
        while parent[i] != i:
            parent[i] = parent[parent[i]]
            i = parent[i]
        return i

    for i in range(n_items):
        for j in range(i + 1, n_items):
            if item_vars(kb_items[i]) & item_vars(kb_items[j]):
                ri, rj = find(i), find(j)
                if ri != rj:
                    parent[max(ri, rj)] = min(ri, rj)

    components: Dict[int, List[int]] = {}
    for i in range(n_items):
        components.setdefault(find(i), []).append(i)

    groups: Dict[str, List[int]] = {}
    for root, idxs in sorted(components.items()):
        comp_vars: Set[str] = set()
        for i in idxs:
            comp_vars |= item_vars(kb_items[i])
        anchors = sorted(comp_vars & stream_vars)
        anchor = anchors[0] if anchors else "__global"
        groups.setdefault(anchor, []).extend(idxs)

    subqueries: Dict[str, SubQuery] = {}
    var_preds: Dict[str, int] = {}
    row_base = int(vocab.term("row:base"))

    def binding_templates(out_vars: Sequence[str], anchor: str,
                          op_index: int) -> Tuple[Q.ConstructTemplate, ...]:
        # one RDF-graph event per binding row, keyed by a synthetic row node
        # (rdf.ROW_BASE band, namespaced per operator): the aggregator joins
        # the published variables of the SAME row — exact correlation, no
        # cross products and no aliasing between operators
        ordered = [v for v in out_vars if v == anchor] + [
            v for v in out_vars if v != anchor
        ]
        tpls = []
        for v in ordered:
            var_preds.setdefault(v, _var_pred(vocab, v))
            tpls.append(
                Q.ConstructTemplate(Q.RowId(ns=op_index + 1),
                                    Q.Const(var_preds[v]), Q.Var(v))
            )
        return tuple(tpls)

    # enrichment operators (QueryA / QueryB analogues).  Each publishes ALL
    # variables of the stream patterns it consumed (paper Fig. 4: QueryA's
    # output carries the tweet id), so the aggregator can skip re-scanning
    # and re-joining those patterns — join elimination.
    covered_pats: List[Q.Pattern] = []
    for i, (anchor, idxs) in enumerate(sorted(groups.items())):
        items = [kb_items[j] for j in sorted(idxs)]   # preserve listed order
        name = "%s_kb%d_%s" % (q.name, i, anchor.strip("?_"))
        needed_vars = set()
        for it in items:
            needed_vars |= item_vars(it)
        anchor_pats = [
            p for p in stream_pats if set(p.vars()) & (needed_vars | {anchor})
        ]
        pat_vars = set()
        for p in anchor_pats:
            pat_vars |= set(p.vars())
        out_vars = sorted(
            (needed_vars | pat_vars | {anchor}) & set(q.variables())
        )
        where: List[Q.WhereItem] = list(anchor_pats) + list(items)
        sub_q = Q.Query(
            name=name,
            where=tuple(where),
            construct=binding_templates(out_vars, anchor, i),
        )
        subqueries[name] = SubQuery(sub_q, inputs=("stream",), touches_kb=True)
        # a stream pattern is fully covered if this operator consumed it and
        # republishes every one of its variables
        for p in anchor_pats:
            if set(p.vars()) <= set(out_vars):
                covered_pats.append(p)

    # final aggregation operator (QueryG): skips stream patterns whose
    # bindings arrive fully materialized on an intermediate stream
    final_name = "%s_agg" % q.name
    agg_where: List[Q.WhereItem] = [
        p for p in stream_pats if p not in covered_pats
    ] + list(other_items)
    # consume intermediate binding streams: (?row_i, var_pred, ?v)
    for name, sub in subqueries.items():
        row_var = "__row_%s" % name
        for tpl in sub.query.construct:
            assert isinstance(tpl.p, Q.Const)
            agg_where.append(
                Q.Pattern(Q.Var(row_var), Q.Const(tpl.p.id), tpl.o, Q.STREAM)
            )
    final_q = Q.Query(name=final_name, where=tuple(agg_where),
                      construct=q.construct, select=q.select)
    # KB patterns nested inside OPTIONAL/UNION groups stay with the
    # aggregator (their semantics are join-order dependent), so it needs its
    # own (pruned) KB slice when any are present
    subqueries[final_name] = SubQuery(
        final_q,
        inputs=tuple(sorted(subqueries)) + ("stream",),
        touches_kb=bool(final_q.kb_predicates()),
    )
    return OperatorDAG(
        name=q.name,
        subqueries=subqueries,
        final=final_name,
        var_preds=var_preds,
        row_base=row_base,
    )


# --------------------------------------------------------------------------
# split aggregation sink: rewrite the agg plan to join upstream TABLES
# --------------------------------------------------------------------------

def split_agg_plan(
    plan: Plan, dag: "OperatorDAG",
) -> Optional[Tuple[Plan, Dict[str, Tuple[str, ...]]]]:
    """Rewrite the aggregation-sink plan to consume upstream binding tables.

    The decomposed sink re-parses the binding-graph protocol: one decode
    ScanJoin per published variable — ``(?__row_u, var_pred_v, ?v)`` over
    the *augmented* window — then natural joins stitch the row back
    together.  That re-parse is the measured pipeline bottleneck
    (BENCH_pipeline ``stage_breakdown``).  This rewrite replaces each
    upstream's decode-scan group with ONE :class:`~repro.core.engine.
    BindingJoin` against the upstream's final binding table (which the
    upstream already computed before serializing it to triples), and runs
    the remaining scans over the RAW window — no augmentation, no decode.

    Semantics are preserved exactly:

    * a table row is precisely the variable tuple the decode scans would
      reconstruct for one published row node (row ids are unique per row,
      so decode joins never mix rows);
    * ``shared`` tuples are *replayed* over the new step order from the
      actual bound-before sets, so every cross-step equality the decode
      path enforced is enforced here (the max-merge treats non-shared
      overlapping columns as corruption — recomputing shared from scratch
      is what makes the rewrite safe, see ``ScanJoin``'s invariant);
    * filters stay in place; BindingJoin binds an upstream's variables at
      its *first* decode position, i.e. never later than the decode chain
      did, so every filter's variables remain bound at its position.

    Returns ``(rewritten plan, {upstream -> published var names in table
    column order})``, or ``None`` when the plan falls outside the provably
    equivalent fragment, in which case the caller keeps the augmented-window
    path:

    * a stream scan (top-level or inside OPTIONAL/UNION) with a variable
      predicate or a predicate inside the binding-protocol band — over the
      augmented window such a scan *matches the binding triples themselves*,
      so raw-window execution would change its match set;
    * a decode step appearing after a KBJoin / OPTIONAL / UNION — those
      steps keep their compiled bound-mode/shared wiring, which is only
      valid when every decode (and hence every BindingJoin) precedes them,
      as ``compile_query``'s pass structure normally guarantees;
    * an upstream with no decode step in the plan (nothing to splice), a
      plan with no output variables (row multiplicity observable), or a
      Distinct/Project step (not produced for sink plans).
    """
    upstreams = [n for n in dag.subqueries if n != dag.final]
    protocol_preds = set(dag.var_preds.values())
    if not plan_out_vars(plan):
        return None

    # classify each top-level step; map decode ScanJoins to their upstream
    row_cols = {}
    for u in upstreams:
        row_var = "__row_%s" % u
        if row_var in plan.var_names:
            row_cols[plan.var_col(row_var)] = u

    def scan_ok(cp: CompiledPattern) -> bool:
        # raw-window scans must have the same match set with and without
        # the binding-triple augmentation
        return (cp.p.mode == SlotMode.CONST
                and int(cp.p.const) not in protocol_preds)

    def group_ok(steps: Sequence[Step]) -> bool:
        # OPTIONAL/UNION bodies: stream scans pass the raw-window test, KB
        # joins and filters never read the window, anything else bails
        for s in steps:
            if isinstance(s, ScanJoin):
                if not scan_ok(s.pat):
                    return False
            elif isinstance(s, OptionalSteps):
                if not group_ok(s.sub):
                    return False
            elif isinstance(s, UnionSteps):
                if not (group_ok(s.left) and group_ok(s.right)):
                    return False
            elif not isinstance(s, (KBJoin, FilterNumStep, FilterBoolStep,
                                    FilterInStep)):
                return False
        return True

    decode_of: Dict[int, str] = {}              # step index -> upstream name
    tail = False   # seen a KBJoin/OPTIONAL/UNION (pass-2/3 territory)
    for i, step in enumerate(plan.steps):
        if isinstance(step, (FilterNumStep, FilterBoolStep, FilterInStep)):
            continue
        if isinstance(step, ScanJoin):
            cp = step.pat
            if (cp.s.mode == SlotMode.FREE and cp.s.var in row_cols
                    and cp.p.mode == SlotMode.CONST
                    and int(cp.p.const) in protocol_preds
                    and cp.o.mode == SlotMode.FREE):
                if tail:
                    return None
                decode_of[i] = row_cols[cp.s.var]
            elif not scan_ok(cp):
                return None
        elif isinstance(step, KBJoin):
            tail = True
        elif isinstance(step, OptionalSteps):
            if not group_ok(step.sub):
                return None
            tail = True
        elif isinstance(step, UnionSteps):
            if not (group_ok(step.left) and group_ok(step.right)):
                return None
            tail = True
        else:
            return None
    if set(decode_of.values()) != set(upstreams):
        return None

    # publication signature per upstream: the CONSTRUCT template order
    # (anchor first, then sorted — planner.decompose.binding_templates),
    # which is the column order of the table the runtime ships
    pub: Dict[str, Tuple[str, ...]] = {}
    for u in upstreams:
        names = tuple(
            tpl.o.name for tpl in dag.subqueries[u].query.construct)
        if any(n not in plan.var_names for n in names):
            return None
        pub[u] = names

    # splice: first decode step of each upstream becomes its BindingJoin,
    # the rest vanish; then replay the bound set to recompute every shared
    first_decode = {}
    for i, u in decode_of.items():
        first_decode.setdefault(u, i)
    spliced: List[Step] = []
    for i, step in enumerate(plan.steps):
        u = decode_of.get(i)
        if u is None:
            spliced.append(step)
        elif first_decode[u] == i:
            spliced.append(BindingJoin(
                source=u,
                cols=tuple(plan.var_col(n) for n in pub[u]),
                shared=(),
            ))

    def step_vars(s: Step) -> Set[int]:
        # every column a step can bind (for bound-set replay)
        if isinstance(s, BindingJoin):
            return set(s.cols)
        if isinstance(s, (ScanJoin, KBJoin)):
            return {sl.var for sl in (s.pat.s, s.pat.p, s.pat.o)
                    if sl.mode != SlotMode.CONST}
        if isinstance(s, OptionalSteps):
            return set().union(set(), *(step_vars(x) for x in s.sub))
        if isinstance(s, UnionSteps):
            return set().union(
                set(), *(step_vars(x) for x in s.left + s.right))
        return set()

    bound: Set[int] = set()
    steps: List[Step] = []
    for step in spliced:
        if isinstance(step, BindingJoin):
            shared = tuple(sorted(set(step.cols) & bound))
            steps.append(dataclasses.replace(
                step, shared=shared, replace=not steps and not shared))
        elif isinstance(step, ScanJoin):
            free = {sl.var for sl in (step.pat.s, step.pat.p, step.pat.o)
                    if sl.mode != SlotMode.CONST}
            steps.append(dataclasses.replace(
                step, shared=tuple(sorted(free & bound))))
        else:
            # KBJoin / OPTIONAL / UNION / filters keep their compiled wiring:
            # the gate guarantees every decode (and hence BindingJoin)
            # precedes them, and the bound sets they were compiled against
            # differ from the replayed ones only in the __row columns, which
            # no query-level pattern can reference
            steps.append(step)
        bound |= step_vars(step)

    return dataclasses.replace(plan, steps=tuple(steps)), pub
