"""Deterministic fault injection + ingest validation for the dataflow stack.

A *distributed* operator graph is only production-credible if an operator
stall, a lost channel payload, or a poisoned input does not silently corrupt
or kill the stream.  This module supplies the two host-side ingredients the
recovery layer (:mod:`repro.core.recovery`) builds on:

* :class:`FaultPlan` — a **seeded, exactly reproducible** schedule of fault
  events keyed by ``(stage, chunk_idx)``.  Five kinds cover the failure
  modes a Kafka-style deployment actually sees:

  - ``drop_payload``      — a stage's outbound channel payload is lost in
    transit (the push never lands);
  - ``duplicate_payload`` — the payload is delivered twice (at-least-once
    transport without dedup);
  - ``stall_stage``       — the stage's step exceeds its timeout once
    (surfaces as a :class:`~repro.core.recovery.StageTimeoutError`, exercised
    through the retry/backoff ladder);
  - ``crash_stage``       — the stage's step raises mid-chunk (exercises
    checkpoint restore + replay);
  - ``corrupt_chunk``     — the chunk is scribbled between the ingest gate
    and the window stage (exercises :func:`validate_chunk` + pristine-copy
    recovery from the replay buffer).

* :func:`validate_chunk` — the ingest gate: checks a
  :class:`~repro.core.rdf.TripleBatch` against the interned id-space bands
  *before* it reaches a jitted step, so malformed input produces a counted,
  attributable rejection instead of undefined uint32 arithmetic.

Everything here is host-side bookkeeping: with ``faults=None`` the pipelined
runtime never calls into this module from a traced function, so the
per-operator jaxprs are byte-identical to the fault-free build (pinned by
tests/test_faults.py).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from .rdf import NUM_BASE, PRED_SPACE, ROW_BASE, TERM_SPACE, TripleBatch, Vocab

# the five injectable failure modes (see module docstring)
FAULT_KINDS = (
    "drop_payload", "duplicate_payload", "stall_stage", "crash_stage",
    "corrupt_chunk",
)


class FaultError(RuntimeError):
    """Base class for injected faults (host-side, never traced)."""


class InjectedCrash(FaultError):
    """An injected ``crash_stage`` event firing inside a stage dispatch."""

    def __init__(self, stage: str, seq: int):
        super().__init__(
            "injected crash in stage %r while processing chunk seq %d"
            % (stage, seq))
        self.stage = stage
        self.seq = seq


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: ``kind`` fires when ``stage`` touches chunk
    ``chunk`` (the 0-based lifetime sequence number the driver assigns at
    ``feed()``).  ``drop_payload``/``duplicate_payload`` name the *producer*
    stage whose outbound payload is affected; ``corrupt_chunk`` ignores the
    stage (corruption happens at ingest, use ``"ingest"``)."""

    kind: str
    stage: str
    chunk: int

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                "unknown fault kind %r (expected one of %s)"
                % (self.kind, list(FAULT_KINDS)))
        if self.chunk < 0:
            raise ValueError("chunk index must be >= 0, got %d" % self.chunk)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, hashable schedule of :class:`FaultEvent`\\ s.

    Frozen so it can live inside the (frozen, hashable)
    :class:`~repro.core.session.ExecutionConfig`.  The plan itself carries no
    runtime state — each :class:`~repro.core.pipeline.PipelinedRuntime`
    builds its own :class:`FaultInjector` over it, and every event fires at
    most **once** per runtime: a replayed chunk does not re-trip the fault
    that crashed it, which is exactly the at-most-once semantics a
    deterministic chaos schedule needs to terminate.
    """

    events: Tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))

    @classmethod
    def seeded(
        cls,
        seed: int,
        stages: Sequence[str],
        num_chunks: int,
        n_events: int = 4,
        kinds: Sequence[str] = FAULT_KINDS,
    ) -> "FaultPlan":
        """A reproducible random schedule: ``n_events`` events drawn by
        ``random.Random(seed)`` over the given stages and chunk range.  The
        same ``(seed, stages, num_chunks, n_events, kinds)`` always yields
        the same plan — chaos runs replay exactly."""
        if num_chunks < 1:
            raise ValueError("num_chunks must be >= 1")
        for k in kinds:
            if k not in FAULT_KINDS:
                raise ValueError("unknown fault kind %r" % k)
        rng = random.Random(seed)
        events = []
        for _ in range(n_events):
            kind = rng.choice(list(kinds))
            stage = "ingest" if kind == "corrupt_chunk" else rng.choice(
                list(stages))
            events.append(FaultEvent(kind, stage, rng.randrange(num_chunks)))
        return cls(tuple(events))

    def counts(self) -> Dict[str, int]:
        """Scheduled events per kind (what a chaos test expects to fire)."""
        out = {k: 0 for k in FAULT_KINDS}
        for ev in self.events:
            out[ev.kind] += 1
        return out


class FaultInjector:
    """Per-runtime firing state over a :class:`FaultPlan`.

    ``take(kind, stage, chunk)`` consumes (fires) one matching un-fired
    event and returns ``True``; the driver calls it at each injection point
    (stage dispatch, channel push, ingest).  ``fired`` counts fired events
    per kind — `last_stats["recovery"]["injected"]` reports them so tests
    can assert the schedule was exercised *exactly*.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._pending: List[FaultEvent] = list(plan.events)
        self.fired: Dict[str, int] = {k: 0 for k in FAULT_KINDS}

    def take(self, kind: str, stage: str, chunk: int) -> bool:
        for i, ev in enumerate(self._pending):
            if ev.kind == kind and ev.chunk == chunk and (
                    ev.stage == stage or ev.kind == "corrupt_chunk"):
                del self._pending[i]
                self.fired[kind] += 1
                return True
        return False

    def pending(self) -> int:
        return len(self._pending)

    def fired_total(self) -> int:
        return sum(self.fired.values())


# --------------------------------------------------------------------------
# ingest validation
# --------------------------------------------------------------------------

def validate_chunk(
    chunk: TripleBatch,
    vocab: Optional[Vocab] = None,
    max_graph_size: Optional[int] = None,
) -> List[str]:
    """The ingest gate: reasons a :class:`TripleBatch` must not reach a
    jitted step (empty list = valid).  Host-side numpy over the valid rows:

    * predicate ids of valid rows must be interned — ``[1, vocab.num_preds)``
      (the synthetic closure band and id 0 never appear on the wire);
    * subject/object ids must be interned terms
      (``[PRED_SPACE, PRED_SPACE + vocab.num_terms)``) or numeric literals
      (``>= NUM_BASE``) — the synthetic row-node band is operator-internal;
    * the ``valid`` mask must be boolean (anything else makes ``count()``
      and window packing lie);
    * with ``max_graph_size``, no graph event may exceed it (a graph larger
      than the window capacity can never be windowed whole).

    Without a ``vocab`` the structural band bounds are used instead of the
    live interner extents.
    """
    reasons: List[str] = []
    v = np.asarray(chunk.valid)
    if v.dtype != np.bool_:
        return ["valid mask must be boolean, got dtype %s" % v.dtype]
    if not v.any():
        return reasons
    s = np.asarray(chunk.s)[v].astype(np.int64)
    p = np.asarray(chunk.p)[v].astype(np.int64)
    o = np.asarray(chunk.o)[v].astype(np.int64)
    g = np.asarray(chunk.graph)[v].astype(np.int64)
    pred_hi = vocab.num_preds if vocab is not None else PRED_SPACE
    term_hi = (PRED_SPACE + vocab.num_terms if vocab is not None
               else PRED_SPACE + TERM_SPACE)
    if ((p < 1) | (p >= pred_hi)).any():
        reasons.append(
            "predicate id outside the interned band [1, %d)" % pred_hi)

    def _bad_term(t: np.ndarray) -> np.ndarray:
        interned = (t >= PRED_SPACE) & (t < term_hi)
        numeric = t >= int(NUM_BASE)
        return ~(interned | numeric)

    if _bad_term(s).any():
        reasons.append(
            "subject id outside the vocab bands ([%d, %d) or numeric)"
            % (PRED_SPACE, term_hi))
    if _bad_term(o).any():
        reasons.append(
            "object id outside the vocab bands ([%d, %d) or numeric)"
            % (PRED_SPACE, term_hi))
    if ((s >= int(ROW_BASE)) & (s < int(NUM_BASE))).any() or (
            (o >= int(ROW_BASE)) & (o < int(NUM_BASE))).any():
        # row nodes are synthetic operator-internal ids; reaching ingest
        # means a publication leaked back into a source stream
        reasons.append("synthetic row-node id in an ingest stream")
    if max_graph_size is not None and g.size:
        _, counts = np.unique(g, return_counts=True)
        worst = int(counts.max())
        if worst > max_graph_size:
            reasons.append(
                "graph event of %d triples exceeds the %d-triple cap"
                % (worst, max_graph_size))
    return reasons


def corrupt_batch(chunk: TripleBatch) -> TripleBatch:
    """The deterministic in-transit scribble a ``corrupt_chunk`` event
    applies: the first row becomes a live triple whose predicate sits in the
    reserved closure band and whose subject falls in the dead zone between
    the term band and the numeric band — both caught by
    :func:`validate_chunk` whatever the vocab extents are.  Pure (returns a
    new batch); the pristine chunk stays in the driver's replay buffer.
    """
    import jax.numpy as jnp

    bad_p = jnp.asarray(PRED_SPACE - 1, chunk.p.dtype)       # closure band
    bad_s = jnp.asarray(int(ROW_BASE) + 7, chunk.s.dtype)    # row-node band
    return chunk._replace(
        s=chunk.s.at[0].set(bad_s),
        p=chunk.p.at[0].set(bad_p),
        valid=chunk.valid.at[0].set(True),
    )
