"""DSCEP core: distributed semantic complex event processing in JAX.

Public surface of the paper's contribution:

* :mod:`repro.core.rdf`      — dictionary-encoded triples and streams
* :mod:`repro.core.window`   — Aggregator window management
* :mod:`repro.core.kb`      — partitioned, probe-indexed knowledge base
* :mod:`repro.core.algebra`  — vectorized SPARQL-subset operators
* :mod:`repro.core.query`    — continuous-query AST
* :mod:`repro.core.planner`  — compile / decompose / prune-used-KB
* :mod:`repro.core.engine`   — plan executor (the RSP engine)
* :mod:`repro.core.operator` — SCEP operator (Aggregator→engine→Publisher)
* :mod:`repro.core.runtime`  — operator-DAG runtime (mono vs decomposed)
* :mod:`repro.core.reasoner` — subclass/sameAs reasoning support
"""
from . import algebra, engine, kb, pattern, planner, query, rdf, reasoner, runtime, stream, window  # noqa: F401

__all__ = [
    "algebra", "engine", "kb", "pattern", "planner", "query", "rdf",
    "reasoner", "runtime", "stream", "window",
]
