"""DSCEP core: distributed semantic complex event processing in JAX.

Public surface of the paper's contribution:

* :mod:`repro.core.rdf`      — dictionary-encoded triples and streams
* :mod:`repro.core.window`   — Aggregator window management
* :mod:`repro.core.kb`      — partitioned, probe-indexed knowledge base
* :mod:`repro.core.algebra`  — vectorized SPARQL-subset operators
* :mod:`repro.core.query`    — continuous-query AST
* :mod:`repro.core.sparql`   — C-SPARQL text frontend (parse / serialize)
* :mod:`repro.core.planner`  — compile / decompose / prune-used-KB
* :mod:`repro.core.engine`   — plan executor (the RSP engine)
* :mod:`repro.core.operator` — SCEP operator (Aggregator→engine→Publisher)
* :mod:`repro.core.runtime`  — operator-DAG runtime (mono vs decomposed)
* :mod:`repro.core.channel`  — bounded device ring-buffer channels (edges)
* :mod:`repro.core.pipeline` — streaming pipelined runtime over channels
* :mod:`repro.core.session`  — ``Session``/``ExecutionConfig`` facade (the
  public entry point over every execution mode)
* :mod:`repro.core.reasoner` — subclass/sameAs reasoning support
"""
from . import algebra, channel, engine, kb, pattern, pipeline, planner, query, rdf, reasoner, runtime, session, sparql, stream, window  # noqa: F401
from .session import ExecutionConfig, Session  # noqa: F401
from .sparql import parse_query, serialize_query  # noqa: F401

__all__ = [
    "algebra", "channel", "engine", "kb", "pattern", "pipeline", "planner",
    "query", "rdf", "reasoner", "runtime", "session", "sparql", "stream",
    "window",
    "ExecutionConfig", "Session", "parse_query", "serialize_query",
]
