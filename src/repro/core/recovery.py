"""Checkpoint/restart machinery for the pipelined dataflow runtime.

The pipelined mode is the paper's deployment shape — independently scheduled
operators over bounded queues — and therefore the mode where partial failure
is a *normal* event, not an exception: a stage wedges, a channel payload is
lost or delivered twice, a chunk arrives corrupted.  This module holds the
host-side recovery primitives :class:`~repro.core.pipeline.PipelinedRuntime`
drives:

* :class:`RecoveryConfig` — the knobs (checkpoint cadence, stage timeout,
  retry/backoff budget, restart budget, ingest validation);
* :class:`Checkpoint` — a full host-side snapshot of the driver + device
  state (channel rings, overflow/stat accumulators, dispatch queues,
  sequence watermarks, per-operator env) taken every ``checkpoint_every``
  emitted chunks;
* the error ladder (:class:`StageTimeoutError` → retry/backoff,
  :class:`ChannelDesyncError`/:class:`~repro.core.faults.InjectedCrash` →
  checkpoint restore + replay, :class:`RecoveryExhaustedError` when the
  budget is spent) plus the driver-misuse/ingest errors
  (:class:`PipelineStalledError`, :class:`ChunkRejectedError`).

Design invariant — **recovery is bit-exact**: a checkpoint captures every
array the jitted steps read or donate, the replay buffer retains the pristine
fed chunks past the checkpoint's emitted watermark, and the sink dedups
replayed outputs by sequence number, so the recovered output stream is
byte-identical to the fault-free run (tests/test_faults.py and the chaos
differential property in tests/test_differential.py adjudicate).  None of
this touches traced code: with ``faults=None`` and ``checkpoint_every=0`` the
per-operator jaxprs are byte-identical to a build without this module.
"""
from __future__ import annotations

import copy
import dataclasses
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class RecoveryConfig:
    """Fault-tolerance knobs for the pipelined runtime (frozen/hashable so
    it can ride inside :class:`~repro.core.session.ExecutionConfig`).

    * ``checkpoint_every`` — snapshot the driver + device state every N
      *emitted* chunks; ``0`` disables periodic checkpoints (the initial
      clean-state checkpoint is still taken, so crash recovery replays from
      the stream head — correct, just unbounded replay).
    * ``stage_timeout_s`` — per-stage wall-clock budget; ``None`` disables
      the watchdog (injected stalls still exercise the timeout path).
    * ``max_retries``/``backoff_s`` — bounded exponential backoff for a
      timed-out stage before escalating to a restart.
    * ``max_restarts`` — checkpoint restores attributable to one chunk
      before that chunk is *degraded*: re-evaluated through the channel-free
      fallback program (same plan, same canonical order ⇒ same bytes).
    * ``validate``/``max_graph_size`` — run the
      :func:`~repro.core.faults.validate_chunk` ingest gate on every fed
      chunk (``max_graph_size`` adds the optional per-event size cap).
    """

    checkpoint_every: int = 4
    stage_timeout_s: Optional[float] = None
    max_retries: int = 3
    backoff_s: float = 0.01
    max_restarts: int = 2
    validate: bool = True
    max_graph_size: Optional[int] = None

    def __post_init__(self):
        if self.checkpoint_every < 0:
            raise ValueError("checkpoint_every must be >= 0")
        if self.max_retries < 0 or self.max_restarts < 0:
            raise ValueError("max_retries/max_restarts must be >= 0")
        if self.stage_timeout_s is not None and self.stage_timeout_s <= 0:
            raise ValueError("stage_timeout_s must be positive or None")


# --------------------------------------------------------------------------
# the error ladder
# --------------------------------------------------------------------------

class StageTimeoutError(RuntimeError):
    """A stage's step exceeded its wall-clock budget (or an injected
    ``stall_stage`` event simulated one).  First rung of the ladder: the
    driver retries with exponential backoff up to ``max_retries``."""

    def __init__(self, stage: str, seq: int, timeout_s: Optional[float],
                 injected: bool = False):
        kind = "injected stall" if injected else (
            "no progress within %.3gs" % (timeout_s or 0.0))
        super().__init__(
            "stage %r timed out on chunk seq %d (%s)" % (stage, seq, kind))
        self.stage = stage
        self.seq = seq
        self.injected = injected


class ChannelDesyncError(RuntimeError):
    """An edge's occupancy disagrees with the chunks in flight — a payload
    was lost or duplicated in transport.  Detected before the sink pops
    (popping unmatched edges would silently join wrong windows); recovered
    by checkpoint restore + replay."""

    def __init__(self, edge: str, actual: int, expected: int):
        word = "lost" if actual < expected else "duplicated"
        super().__init__(
            "channel desync on edge %r: %d payload(s) queued where the "
            "schedule expects %d (a payload was %s in transport)"
            % (edge, actual, expected, word))
        self.edge = edge
        self.actual = actual
        self.expected = expected


class PipelineStalledError(RuntimeError):
    """The driver made no progress: work is queued but no stage can run and
    nothing is in flight to drain.  Diagnostic replacement for the former
    infinite ``while self._in_flight or self._src_q`` spin."""

    def __init__(self, detail: str):
        super().__init__("pipeline stalled: %s" % detail)


class ChunkRejectedError(ValueError):
    """The ingest gate rejected a fed chunk (malformed ids/mask/geometry).
    Carries the per-reason diagnostics; the pipeline state is untouched, so
    the caller may drop the chunk and continue the stream."""

    def __init__(self, reasons: List[str]):
        super().__init__(
            "chunk rejected at ingest: %s" % "; ".join(reasons))
        self.reasons = list(reasons)


class RecoveryExhaustedError(RuntimeError):
    """The global restart budget is spent and the stream still cannot make
    progress — the fault is persistent and not attributable to one chunk.
    Final rung: surface to the caller instead of looping forever."""


# --------------------------------------------------------------------------
# snapshots
# --------------------------------------------------------------------------

def snapshot_tree(tree: Any) -> Any:
    """Deep host copy of a pytree of device arrays (``None``-safe).

    ``jax.device_get`` blocks until the arrays are ready and materializes
    host ``ndarray``s — mandatory for channel state, whose buffers are
    *donated* to the next step and would otherwise be deleted from under
    the checkpoint."""
    if tree is None:
        return None
    return jax.device_get(tree)


def restore_tree(snap: Any, device=None) -> Any:
    """Re-materialize a host snapshot on device (``None``-safe)."""
    if snap is None:
        return None
    return jax.device_put(snap, device) if device is not None \
        else jax.device_put(snap)


def tree_bytes(tree: Any) -> int:
    """Total payload bytes of a host snapshot (checkpoint size metric)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        total += int(np.asarray(leaf).nbytes)
    return total


@dataclasses.dataclass
class Checkpoint:
    """One consistent cut of the pipelined driver + device state.

    ``fed``/``emitted`` are the sequence watermarks at snapshot time (seqs
    < ``fed`` had entered the driver; seqs <= ``emitted`` had been emitted).
    Channel rings and accumulators are host deep copies; queue payloads and
    raw chunks are *references* — they are produced by non-donating steps,
    so the arrays can never be freed from under the checkpoint.
    """

    fed: int
    emitted: int
    in_flight: int
    inflight_seqs: List[int]
    src_q: List[Tuple[int, Any]]
    disp_q: Dict[str, List[Tuple[int, Any]]]
    win_ch: Any                       # host snapshot (or None when lazy-unallocated)
    win_sig: Any
    out_ch: Dict[str, Any]            # host snapshots
    overflow_acc: Dict[str, Any]      # host scalars
    stats_acc: Dict[str, Dict[str, Any]]
    edge_stats: Dict[str, Dict[str, int]]
    envs: Dict[str, Any]              # per-operator env host snapshots
    degraded_out: Dict[int, Any]      # seq -> (out, overflow) refs
    nbytes: int = 0


def wait_until_ready(out: Any, timeout_s: float) -> bool:
    """Block on a step's outputs with a wall-clock budget.

    ``jax.block_until_ready`` has no timeout, so the wait runs on a daemon
    thread and the driver waits on an event: ``True`` = the arrays became
    ready in time, ``False`` = the budget elapsed (the device computation
    keeps running in the background — XLA dispatches cannot be cancelled —
    but the driver is free to restore a checkpoint and move on)."""
    done = threading.Event()

    def _wait():
        try:
            jax.block_until_ready(out)
        finally:
            done.set()

    t = threading.Thread(target=_wait, daemon=True)
    t.start()
    return done.wait(timeout_s)


def copy_edge_stats(edge_stats: Dict[str, Dict[str, int]]) -> Dict[str, Dict[str, int]]:
    return {e: dict(v) for e, v in edge_stats.items()}


def snapshot_stats_acc(stats_acc: Dict[str, Dict[str, Any]]) -> Dict[str, Dict[str, Any]]:
    return {n: dict(snapshot_tree(a)) if a else {} for n, a in stats_acc.items()}


def empty_recovery_stats(enabled: bool = False) -> Dict[str, Any]:
    """The uniform ``last_stats["recovery"]`` shape for runtimes without
    fault machinery (monolithic / single-program) and for fresh pipelines."""
    return {
        "enabled": enabled,
        "injected": {},
        "scheduled": {},
        "retries": 0,
        "restarts": 0,
        "replayed": 0,
        "deduped": 0,
        "checkpoints": 0,
        "checkpoint_bytes": 0,
        "degraded_chunks": [],
        "rejected": 0,
        "corrupt_recovered": 0,
    }
