"""The unified execution facade: one ``Session`` over all three runtimes.

Before this module, running a semantic continuous query meant choosing among
three runtime classes with divergent constructors and drive loops
(:class:`~repro.core.runtime.MonolithicRuntime` — chunk-at-a-time,
:class:`~repro.core.runtime.DSCEPRuntime` — whole-DAG single XLA program,
:class:`~repro.core.pipeline.PipelinedRuntime` — per-operator steps over
device channels).  ``Session`` collapses that into one code path::

    cfg = ExecutionConfig(mode="pipelined", window_capacity=256)
    sess = Session(cfg, vocab=vocab, kb=kb)
    reg = sess.register(open("query.rq").read())     # text or Query AST
    outs, overflow = reg.run(chunks)                 # whole stream
    for out in reg.stream(chunks): ...               # incremental

A single frozen :class:`ExecutionConfig` consolidates every knob that was
spread over ``RuntimeConfig``, ``OperatorConfig`` and per-runtime constructor
arguments: window geometry, engine capacities, KB-access method, Pallas
selection (``use_pallas`` / ``fuse_compaction`` / ``interpret``), the mesh
for SPMD window sharding (``single_program`` mode), and operator placement +
channel depth (``pipelined`` mode).

All modes produce **bit-identical** output streams for the paper's queries
(tests/test_session.py pins this for cquery1), so switching ``mode`` is a
pure deployment decision, never a semantics change.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.obs.report import attach_saturation
from repro.obs.trace import TraceConfig, Tracer, resolve_trace

from . import query as Q
from .faults import FaultPlan
from .kb import KnowledgeBase, collect_kb_stats
from .pipeline import PipelinedRuntime
from .recovery import RecoveryConfig
from .planner import OperatorDAG, decompose, explain_plan, plan_caps
from .rdf import TripleBatch, Vocab
from .runtime import (
    DSCEPRuntime, MonolithicRuntime, RuntimeConfig, _internal_construction,
)
from .sparql import ParseInfo, parse_query_info, serialize_query

MODES = ("monolithic", "single_program", "pipelined")
KB_METHODS = ("scan", "probe", "auto")


@dataclasses.dataclass(frozen=True)
class ExecutionConfig:
    """One frozen config for every execution mode.

    The first block mirrors :class:`~repro.core.runtime.RuntimeConfig` (which
    itself subsumes :class:`~repro.core.operator.OperatorConfig`); the second
    block holds the mode selector and the distribution knobs that used to be
    per-runtime constructor arguments.
    """

    # -- engine / window geometry (RuntimeConfig superset) ------------------
    window_capacity: int = 1000
    max_windows: int = 8
    out_stream_cap: int = 2048
    # sliding count windows: slide size in triples (C-SPARQL ``STEP m``).
    # None or >= window_capacity tumbles; otherwise windows overlap on
    # ceil(window_capacity / step) consecutive slides (see core/window.py
    # for the graph-preserving packing and rounding rules)
    window_step: Optional[int] = None
    # incremental (delta) evaluation: evaluate each chunk once with
    # slide-span state carried across slides instead of re-running the join
    # chain per window — bit-identical output, large speedup at high
    # overlap.  Per-operator fallback to recompute for non-monotone plans
    # (OPTIONAL); disabled under a sharding mesh.
    incremental: bool = False
    kb_method: str = "scan"            # "scan" | "probe" | "auto" (cost-based)
    kb_capacity: Optional[int] = None
    scan_cap: int = 128
    bind_cap: int = 256
    out_cap: int = 512
    intermediate_cap: int = 512
    use_pallas: bool = False
    fuse_compaction: bool = False
    join_block_shapes: Optional[Tuple[int, int]] = None
    # Pallas interpret mode: True = interpreter (CPU hosts), False = compile
    # the fused kernels for the real accelerator
    interpret: bool = True

    # -- execution mode and distribution ------------------------------------
    mode: str = "single_program"       # monolithic | single_program | pipelined
    mesh: Optional[Any] = None         # SPMD window sharding (single_program)
    data_axis: str = "data"
    placement: Union[str, Dict[str, Any], None] = "round_robin"  # pipelined
    channel_capacity: int = 4          # chunks in flight (pipelined)
    # per-query window geometry: when True, a registered query's
    # ``[RANGE TRIPLES n STEP m]`` clause overrides ``window_capacity`` for
    # that RegisteredQuery only, so one Session hosts queries with
    # heterogeneous windows (``window_capacity`` stays the default for
    # queries without a RANGE clause)
    window_from_query: bool = False
    # observability (repro.obs): None/False = off — the runtimes compile the
    # exact pre-observability programs (pinned by tests/test_obs.py); True =
    # default TraceConfig (host spans + device-side engine metrics); or an
    # explicit repro.obs.TraceConfig.  Surfaced via RegisteredQuery.last_stats
    # and RegisteredQuery.explain().
    trace: Union[None, bool, TraceConfig] = None
    # fault tolerance (pipelined mode only): ``faults`` is a seeded
    # repro.core.faults.FaultPlan injected deterministically into the
    # driver (chaos runs replay exactly); ``recovery`` tunes the
    # checkpoint/retry/restart/degradation ladder
    # (repro.core.recovery.RecoveryConfig — a FaultPlan alone implies the
    # default ladder).  Both None = the fault machinery does not exist:
    # per-operator programs are byte-identical (tests/test_faults.py pin).
    faults: Optional[FaultPlan] = None
    recovery: Optional[RecoveryConfig] = None

    def __post_init__(self):
        resolve_trace(self.trace)     # validates the field type eagerly
        if self.mode not in MODES:
            raise ValueError(
                "unknown mode %r (expected one of %s)" % (self.mode, list(MODES)))
        if self.kb_method not in KB_METHODS:
            raise ValueError(
                "unknown kb_method %r (expected one of %s)"
                % (self.kb_method, list(KB_METHODS)))
        if self.mode == "pipelined" and self.mesh is not None:
            raise ValueError(
                "pipelined mode distributes via placement=, not mesh= "
                "(window sharding belongs to single_program mode)")
        if self.window_step is not None and self.window_step < 1:
            raise ValueError(
                "window_step must be >= 1 (triples per slide), got %d"
                % self.window_step)
        if self.faults is not None and not isinstance(self.faults, FaultPlan):
            raise TypeError(
                "faults= takes a repro.core.faults.FaultPlan, got %r"
                % type(self.faults).__name__)
        if self.recovery is not None and not isinstance(
                self.recovery, RecoveryConfig):
            raise TypeError(
                "recovery= takes a repro.core.recovery.RecoveryConfig, "
                "got %r" % type(self.recovery).__name__)
        if (self.faults is not None or self.recovery is not None) \
                and self.mode != "pipelined":
            raise ValueError(
                "fault injection / recovery (faults=, recovery=) require "
                "mode='pipelined' — the monolithic and single-program modes "
                "run one XLA program with no partial-failure boundary")

    def runtime_config(self) -> RuntimeConfig:
        """The engine-level slice of this config (shared by every mode)."""
        return RuntimeConfig(
            window_capacity=self.window_capacity,
            max_windows=self.max_windows,
            out_stream_cap=self.out_stream_cap,
            window_step=self.window_step,
            incremental=self.incremental,
            kb_method=self.kb_method,
            kb_capacity=self.kb_capacity,
            scan_cap=self.scan_cap,
            bind_cap=self.bind_cap,
            out_cap=self.out_cap,
            intermediate_cap=self.intermediate_cap,
            use_pallas=self.use_pallas,
            fuse_compaction=self.fuse_compaction,
            join_block_shapes=self.join_block_shapes,
            interpret=self.interpret,
        )

    def replace(self, **changes) -> "ExecutionConfig":
        return dataclasses.replace(self, **changes)


class RegisteredQuery:
    """A continuous query registered with a :class:`Session`.

    Owns the compiled runtime for the session's execution mode and exposes
    the unified drive surface: :meth:`run` (whole stream, overflow totals),
    :meth:`stream` (incremental generator) and :meth:`process_chunk`.
    """

    def __init__(self, session: "Session", query: Q.Query,
                 info: Optional[ParseInfo] = None):
        self.session = session
        self.query = query
        self.info = info
        cfg = session.config
        # per-query window geometry: the registration's RANGE TRIPLES clause
        # (and its STEP overlap, or tumbling when STEP is absent) overrides
        # the session-wide default when the config opts in
        self._range_applied = bool(
            cfg.window_from_query and info is not None and info.window_triples)
        if self._range_applied:
            cfg = cfg.replace(window_capacity=info.window_triples,
                              window_step=info.window_step)
        self.config = cfg
        self.mode = cfg.mode
        self.dag: Optional[OperatorDAG] = None
        tcfg = resolve_trace(cfg.trace)
        self.tracer: Optional[Tracer] = Tracer(tcfg) if tcfg else None
        self._runtime = self._build_runtime()

    @property
    def window_geometry(self) -> Tuple[int, Optional[int]]:
        """``(window_triples, window_step)`` for this registration.

        ``window_triples`` is the effective per-query window capacity.
        ``window_step`` is the slide size: the registration's STEP clause
        whenever the query text carries one (reported even when
        ``window_from_query=False`` left it without effect), else the
        session-wide ``ExecutionConfig.window_step``.  A step that is None
        or >= the capacity means tumbling; smaller steps are real overlap —
        each window spans ``ceil(window_triples / step)`` slides.
        """
        step = self.config.window_step
        if self.info is not None and self.info.window_step:
            step = self.info.window_step
        return (self.config.window_capacity, step)

    # -- construction --------------------------------------------------------
    def _build_runtime(self):
        cfg = self.config
        rcfg = cfg.runtime_config()
        vocab, kb = self.session.vocab, self.session.kb
        if kb is None and self.query.kb_predicates():
            raise ValueError(
                "query %r touches the KB (GRAPH <kb> patterns) but the "
                "Session has no kb= attached" % self.query.name)
        with _internal_construction():
            if self.mode == "monolithic":
                return MonolithicRuntime(self.query, kb, rcfg,
                                         tracer=self.tracer)
            self.dag = decompose(self.query, vocab)
            if self.mode == "single_program":
                return DSCEPRuntime(self.dag, kb, vocab, rcfg,
                                    mesh=cfg.mesh, data_axis=cfg.data_axis,
                                    tracer=self.tracer)
            placement = cfg.placement
            if isinstance(placement, str):
                from repro.launch.mesh import place_operators
                placement = place_operators(
                    list(self.dag.subqueries), self.dag.final,
                    strategy=cfg.placement)
            return PipelinedRuntime(self.dag, kb, vocab, rcfg,
                                    placement=placement,
                                    channel_capacity=cfg.channel_capacity,
                                    tracer=self.tracer,
                                    faults=cfg.faults,
                                    recovery=cfg.recovery)

    # -- introspection -------------------------------------------------------
    @property
    def runtime(self):
        """The underlying runtime object (mode-dependent class)."""
        return self._runtime

    @property
    def operators(self) -> Dict[str, Any]:
        """Name -> SCEPOperator (one entry, the query itself, in monolithic)."""
        if self.mode == "monolithic":
            return {self.query.name: self._runtime.operator}
        return dict(self._runtime.operators)

    @property
    def text(self) -> str:
        """Canonical C-SPARQL serialization of the registered query (the
        original registration's PREFIX IRIs and dataset clauses — including
        per-query RANGE window geometry — are preserved when parsed from
        text)."""
        prefixes = dict(self.info.prefixes) if self.info else None
        return serialize_query(self.query, self.session.vocab, prefixes,
                               info=self.info)

    # -- unified drive surface ----------------------------------------------
    def process_chunk(self, chunk: TripleBatch) -> Tuple[TripleBatch, Dict[str, int]]:
        """Push one chunk through; returns (output chunk, overflow counts)."""
        out, ovf = self._runtime.process_chunk(chunk)
        return out, self._normalize_overflow(ovf)

    def run(self, chunks: Sequence[TripleBatch]) -> Tuple[List[TripleBatch], Dict[str, int]]:
        """Push a whole stream through; returns (outputs, overflow totals).

        Every mode returns one output chunk per input chunk, bit-identical
        across modes; ``overflow[op]`` counts windows whose engine capacities
        clipped results in operator ``op`` over this stream.
        """
        if self.mode == "monolithic":
            outs: List[TripleBatch] = []
            acc = jnp.zeros((), jnp.int32)
            for c in chunks:
                out, ovf = self._runtime.process_chunk(c)
                outs.append(out)
                acc = acc + jnp.sum(ovf.astype(jnp.int32))
            return outs, {self.query.name: int(acc)}
        outs, overflow = self._runtime.process_stream(chunks)
        return outs, dict(overflow)

    def stream(self, chunks: Sequence[TripleBatch]) -> Iterator[TripleBatch]:
        """Incremental execution: yield one output chunk per input chunk.

        In pipelined mode the schedule keeps ``channel_capacity`` chunks in
        flight, so outputs trail inputs by the pipeline depth; every mode
        still yields exactly ``len(chunks)`` outputs in input order.  The
        pipelined generator requires an idle runtime and drains any chunks
        left in flight when abandoned early, so a later ``run``/``stream``
        never sees another call's leftovers.
        """
        if self.mode != "pipelined":
            for c in chunks:
                yield self._runtime.process_chunk(c)[0]
            return
        rt = self._runtime
        rt._require_idle("stream")
        depth = self.config.channel_capacity
        try:
            for c in chunks:
                if rt._in_flight >= depth:
                    yield rt.drain()
                rt.feed(c)
            while rt._pending_count():
                yield rt.drain()
        finally:
            while rt._pending_count():          # generator closed mid-stream
                rt.drain()

    def overflow_totals(self) -> Dict[str, int]:
        """Lifetime per-operator overflow counts.  Uniform across all three
        modes: every runtime keeps device-side accumulators and syncs only
        when this is read."""
        return self._runtime.overflow_totals()

    def channel_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-edge channel statistics — populated in pipelined mode (the
        only mode with materialized inter-operator channels), ``{}``
        elsewhere, so callers never type-switch on the runtime."""
        return self._runtime.channel_stats()

    # -- observability --------------------------------------------------------
    @property
    def last_stats(self) -> Dict[str, Any]:
        """The uniform observability surface, identical in shape across all
        three modes::

            {
              "query": ..., "mode": ...,
              "overflow_totals": {op: windows clipped, ...},
              "channels": {edge: {...}, ...},      # {} outside pipelined
              "operators": {op: {"counters": ..., "caps": ...,
                                 "saturation": ...}, ...},
              "spans": {path: {"count", "first_s", "steady": {...}}, ...},
              "recovery": {"enabled", "injected", "retries", ...},
              "degraded": bool,
            }

        ``operators`` and ``spans`` fill in only when the session ran with
        ``ExecutionConfig(trace=...)`` enabled; ``recovery`` carries live
        counters only under pipelined ``faults=``/``recovery=``; the rest
        is always live.
        """
        ops: Dict[str, Any] = {}
        for name, counters in self._runtime.op_metrics().items():
            op = self.operators.get(name)
            caps = plan_caps(op.plan) if op is not None else {}
            ops[name] = attach_saturation(counters, caps)
        return {
            "query": self.query.name,
            "mode": self.mode,
            "overflow_totals": self._runtime.overflow_totals(),
            "channels": self._runtime.channel_stats(),
            "operators": ops,
            "spans": self.tracer.stats() if self.tracer is not None else {},
            "recovery": self._runtime.recovery_stats(),
            "degraded": self._runtime.degraded,
        }

    def explain(self) -> Dict[str, Any]:
        """The planner's decisions for this registration, per operator.

        Recomputes KB statistics for each operator's attached slice (pure
        host-side introspection over static data — never touches compiled
        step functions) so the reported estimates are exactly the numbers
        the ``kb_method="auto"`` cost model would compare.
        """
        win_cap, win_step = self.window_geometry
        operators: Dict[str, Any] = {}
        for name, op in self.operators.items():
            stats = collect_kb_stats(op.kb) if op.kb is not None else None
            entry = explain_plan(op.plan, stats, self.session.vocab)
            entry["kb_rows"] = stats.total_rows if stats is not None else 0
            operators[name] = entry
        return {
            "query": self.query.name,
            "mode": self.mode,
            "kb_method": self.config.kb_method,
            "incremental": self.config.incremental,
            "window": {"capacity": win_cap, "step": win_step},
            "operators": operators,
        }

    def _normalize_overflow(self, ovf) -> Dict[str, int]:
        if isinstance(ovf, dict):
            return {n: int(np.asarray(v).sum()) for n, v in ovf.items()}
        return {self.query.name: int(np.asarray(ovf).sum())}


class Session:
    """Entry point: register C-SPARQL text (or ASTs) and execute streams.

    ``vocab`` is the shared term interner the stream/KB encoders used (a
    fresh one is created when omitted — only useful for stream-only play);
    ``kb`` is the background knowledge base required by KB-touching queries.
    """

    def __init__(
        self,
        config: Optional[ExecutionConfig] = None,
        *,
        vocab: Optional[Vocab] = None,
        kb: Optional[KnowledgeBase] = None,
    ):
        self.config = config if config is not None else ExecutionConfig()
        self.vocab = vocab if vocab is not None else Vocab()
        self.kb = kb
        self.queries: Dict[str, RegisteredQuery] = {}

    def register(self, query: Union[str, Q.Query],
                 name: Optional[str] = None,
                 replace: bool = False) -> RegisteredQuery:
        """Register a continuous query: C-SPARQL text or a Query AST.

        Text is parsed against the session vocab (``REGISTER QUERY <n> AS``
        names the query; ``name=`` is the fallback).  Returns the
        :class:`RegisteredQuery` handle whose ``run``/``stream`` drive the
        configured execution mode.

        A duplicate query name raises ``ValueError`` showing both
        serializations (registering twice under one name used to *silently
        replace* the first runtime, orphaning its handle mid-stream);
        ``replace=True`` is the explicit escape hatch.
        """
        info: Optional[ParseInfo] = None
        if isinstance(query, str):
            query, info = parse_query_info(query, self.vocab, name)
        elif not isinstance(query, Q.Query):
            raise TypeError(
                "register() takes C-SPARQL text or a repro.core.query.Query, "
                "got %r" % type(query).__name__)
        existing = self.queries.get(query.name)
        if existing is not None and not replace:
            # checked before building the RegisteredQuery — runtime
            # construction compiles plans, too expensive to throw away
            prefixes = dict(info.prefixes) if info else None
            raise ValueError(
                "query %r is already registered.\n"
                "existing:\n%s\nnew:\n%s\n"
                "Pass replace=True to substitute the new registration."
                % (query.name, existing.text,
                   serialize_query(query, self.vocab, prefixes, info=info)))
        reg = RegisteredQuery(self, query, info)
        self.queries[query.name] = reg
        return reg

    def unregister(self, name: str) -> None:
        """Drop a registered query (its handle stays usable but unmanaged)."""
        del self.queries[name]

    def serve(self, **opts):
        """A multi-query :class:`~repro.serve.engine.ServeEngine` over this
        session — register hundreds of queries and process shared chunks
        with plan-dedup, shared KB-join prefixes and vmap cohort batching
        (outputs bit-identical to per-query single sessions)."""
        from repro.serve.engine import ServeEngine

        return ServeEngine(self, **opts)

    def register_file(self, path: str,
                      name: Optional[str] = None) -> RegisteredQuery:
        """Register a query from a ``.rq`` file."""
        with open(path) as f:
            return self.register(f.read(), name=name)
