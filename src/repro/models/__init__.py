from . import attention, common, lm, mamba, mlp, moe  # noqa: F401
