"""LM assembly: init / train forward / loss / decode step for every pool arch.

The stack is a ``lax.scan`` over *periods* (repeating groups of sub-layers,
see :class:`repro.configs.base.ModelConfig.layer_pattern`), so HLO size is
O(period) regardless of depth — essential for compiling 60-layer models on
the dry-run host.  Heterogeneous stacks (Jamba) are one period of mixed
sub-layer specs.

Weights are nested dicts; every leaf was registered with logical axes
(:mod:`repro.models.common`) which the sharding layer maps to the mesh.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from .attention import attn_cache_shape, attn_forward, init_attention
from .common import (
    EMBED, LAYERS, VOCAB, ParamSpec, apply_norm, dense, dtype_of, ones_param,
    param,
)
from .mamba import init_mamba, mamba_cache_shape, mamba_forward
from .mlp import init_mlp, mlp_forward
from .moe import init_moe, moe_forward


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_sub(key, cfg: ModelConfig, spec_i: LayerSpec, spec: ParamSpec,
              path: str, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    if cfg.norm == "rmsnorm":
        p["nm"] = ones_param((cfg.d_model,), (EMBED,), spec, path + "/nm", dtype)
        if spec_i.ffn:
            p["nf"] = ones_param((cfg.d_model,), (EMBED,), spec, path + "/nf", dtype)
    if spec_i.mixer == "attn":
        p["attn"] = init_attention(ks[0], cfg, spec, path + "/attn", dtype)
    else:
        p["mamba"] = init_mamba(ks[0], cfg, spec, path + "/mamba", dtype)
    if spec_i.ffn == "dense":
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, spec, path + "/mlp", dtype)
    elif spec_i.ffn == "moe":
        p["moe"] = init_moe(ks[1], cfg.d_model, cfg.moe, spec, path + "/moe", dtype)
    return p


def init_model(key: jax.Array, cfg: ModelConfig) -> Tuple[Dict, ParamSpec]:
    dtype = dtype_of(cfg.dtype)
    spec = ParamSpec()
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    params: Dict[str, Any] = {}

    vp = cfg.padded_vocab
    if cfg.num_codebooks:
        params["embed"] = param(
            k_embed, (cfg.num_codebooks, vp, cfg.d_model),
            (None, VOCAB, EMBED), spec, "embed", dtype, scale=0.02,
        )
    else:
        params["embed"] = param(
            k_embed, (vp, cfg.d_model), (VOCAB, EMBED), spec,
            "embed", dtype, scale=0.02,
        )

    blocks: Dict[str, Any] = {}
    n = cfg.num_periods
    for i, spec_i in enumerate(cfg.layer_pattern):
        sub_path = "blocks/sub%d" % i
        keys = jax.random.split(jax.random.fold_in(k_blocks, i), n)
        sub = jax.vmap(
            lambda k: _init_sub(k, cfg, spec_i, spec, sub_path, dtype)
        )(keys)
        blocks["sub%d" % i] = sub
    # stacked leading axis is the scan (layers) axis
    for path in list(spec.axes):
        if path.startswith("blocks/"):
            spec.axes[path] = (LAYERS,) + spec.axes[path]
    params["blocks"] = blocks

    if cfg.norm == "rmsnorm":
        params["final_norm"] = ones_param((cfg.d_model,), (EMBED,), spec,
                                          "final_norm", dtype)
    if not cfg.tie_embeddings:
        out_width = cfg.padded_vocab * max(1, cfg.num_codebooks)
        params["lm_head"] = param(k_head, (cfg.d_model, out_width),
                                  (EMBED, VOCAB), spec, "lm_head", dtype, scale=0.02)
    return params, spec


# --------------------------------------------------------------------------
# embedding / head
# --------------------------------------------------------------------------

def embed_tokens(params: Dict, cfg: ModelConfig, batch: Dict) -> jax.Array:
    if "embeds" in batch:                     # vlm/audio frontend stub output
        return batch["embeds"].astype(dtype_of(cfg.dtype))
    tokens = batch["tokens"]
    if cfg.num_codebooks:
        # [B, T, K] -> sum over codebook embeddings
        emb = params["embed"]                 # [K, V, d]
        outs = [
            jnp.take(emb[k], tokens[..., k], axis=0)
            for k in range(cfg.num_codebooks)
        ]
        return functools.reduce(jnp.add, outs)
    return jnp.take(params["embed"], tokens, axis=0)


def lm_logits(params: Dict, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    vp = cfg.padded_vocab
    if cfg.tie_embeddings:
        w = params["embed"].T
    else:
        w = params["lm_head"]
    logits = jnp.einsum("btd,dv->btv", h, w.astype(h.dtype))
    if cfg.num_codebooks:
        b, t, _ = logits.shape
        logits = logits.reshape(b, t, cfg.num_codebooks, vp)
    if vp != cfg.vocab_size:   # mask padded vocab rows out of the softmax
        mask = jnp.arange(vp) < cfg.vocab_size
        logits = jnp.where(mask, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _sub_forward(p, cfg: ModelConfig, spec_i: LayerSpec, h, positions,
                 cache=None, impl="xla", dropless=False, moe_groups=1,
                 moe_axes=None, moe_combine=None):
    aux = jnp.zeros((), jnp.float32)
    hn = apply_norm(cfg.norm, h, p.get("nm"))
    if spec_i.mixer == "attn":
        out, new_cache = attn_forward(p["attn"], cfg, hn, positions,
                                      cache.get("attn") if cache else None, impl)
    else:
        out, new_cache = mamba_forward(p["mamba"], cfg, hn,
                                       cache.get("mamba") if cache else None, impl)
    h = h + out
    if spec_i.ffn:
        hn = apply_norm(cfg.norm, h, p.get("nf"))
        if spec_i.ffn == "dense":
            h = h + mlp_forward(p["mlp"], hn)
        else:
            y, aux = moe_forward(p["moe"], cfg.moe, hn, dropless=dropless,
                                 dispatch_groups=moe_groups,
                                 group_axes=moe_axes, combine_axes=moe_combine)
            h = h + y
    kind = "attn" if spec_i.mixer == "attn" else "mamba"
    return h, ({kind: new_cache} if new_cache is not None else None), aux


def forward_hidden(
    params: Dict, cfg: ModelConfig, batch: Dict,
    impl: str = "xla", remat: str = "none", dropless: bool = False,
    unroll: int = 1, act_shard=None, moe_groups: int = 1, moe_axes=None,
    moe_combine=None,
) -> Tuple[jax.Array, jax.Array]:
    """Backbone only: embeddings -> blocks -> final norm.

    Returns (hidden [B,T,d], aux_loss) — the LM head is applied separately so
    serve-time prefill can project ONLY the last position (computing the full
    [B,T,V] logits tensor is pure waste for prefill, and with a vocab-sharded
    head it drags a huge all-gather with it).

    ``act_shard``: optional PartitionSpec constraint applied to the residual
    stream after every sub-layer (sequence-parallel activations: GSPMD then
    lowers the TP boundary as reduce-scatter + all-gather in the activation
    dtype instead of a full all-reduce).
    """
    h = embed_tokens(params, cfg, batch)
    b, t, _ = h.shape
    if "positions" in batch:
        positions = batch["positions"]
    elif cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(jnp.arange(t)[None, None], (3, b, t))
    else:
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))

    def period_fn(h, p_period):
        aux_total = jnp.zeros((), jnp.float32)
        for i, spec_i in enumerate(cfg.layer_pattern):
            h, _, aux = _sub_forward(p_period["sub%d" % i], cfg, spec_i, h,
                                     positions, None, impl, dropless,
                                     moe_groups, moe_axes, moe_combine)
            if act_shard is not None:
                h = jax.lax.with_sharding_constraint(h, act_shard)
            aux_total = aux_total + aux
        return h, aux_total

    if remat == "full":
        period_fn = jax.checkpoint(period_fn)
    elif remat == "dots":
        period_fn = jax.checkpoint(
            period_fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )

    h, auxs = jax.lax.scan(period_fn, h, params["blocks"], unroll=unroll)
    h = apply_norm(cfg.norm, h, params.get("final_norm"))
    return h, jnp.sum(auxs)


def forward(
    params: Dict, cfg: ModelConfig, batch: Dict,
    impl: str = "xla", remat: str = "none", dropless: bool = False,
    unroll: int = 1, act_shard=None, moe_groups: int = 1, moe_axes=None,
    moe_combine=None,
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits, aux_loss).

    ``dropless=False`` (training): MoE capacity clipping per
    ``capacity_factor``.  ``dropless=True`` (serve reference): exact MoE —
    matches the decode path, which is always dropless.

    ``unroll`` is passed to the period scan; the dry-run lowers at
    ``unroll=1`` and ``unroll=2`` to recover exact per-period cost terms
    (XLA's cost analysis counts a while-loop body once).
    """
    h, aux = forward_hidden(params, cfg, batch, impl, remat, dropless,
                            unroll, act_shard, moe_groups, moe_axes,
                            moe_combine)
    return lm_logits(params, cfg, h), aux


def loss_fn(params: Dict, cfg: ModelConfig, batch: Dict,
            impl: str = "xla", remat: str = "none",
            unroll: int = 1, act_shard=None,
            moe_groups: int = 1, moe_axes=None,
            moe_combine=None) -> Tuple[jax.Array, Dict]:
    logits, aux = forward(params, cfg, batch, impl, remat, unroll=unroll,
                          act_shard=act_shard, moe_groups=moe_groups,
                          moe_axes=moe_axes, moe_combine=moe_combine)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    if cfg.num_codebooks:
        onehot = jax.nn.one_hot(labels, cfg.padded_vocab, dtype=jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.sum(onehot * logp, axis=-1)          # [B, T, K]
        ce = jnp.mean(nll)
    else:
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        ce = jnp.mean(nll)
    total = ce + aux
    return total, {"ce": ce, "aux": aux}


# --------------------------------------------------------------------------
# decode (serve)
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               per_seq: bool = False) -> Dict:
    """Stacked per-period decode state for every sub-layer position.

    ``per_seq=True`` gives each sequence its own cache length (the continuous
    batcher's slot lanes); default is one shared position (SPMD decode)."""
    dtype = dtype_of(cfg.dtype)
    n = cfg.num_periods
    caches: Dict[str, Any] = {}
    for i, spec_i in enumerate(cfg.layer_pattern):
        if spec_i.mixer == "attn":
            template = {"attn": attn_cache_shape(cfg, batch, max_len, dtype, per_seq)}
        else:
            template = {"mamba": mamba_cache_shape(cfg, batch, dtype)}
        caches["sub%d" % i] = jax.tree.map(
            lambda x: jnp.zeros((n,) + x.shape, x.dtype), template
        )
    return caches


def decode_step(
    params: Dict, cfg: ModelConfig, batch: Dict, caches: Dict,
    pos: jax.Array, impl: str = "xla", unroll: int = 1,
    moe_groups: int = 1, moe_axes=None, moe_combine=None,
    loop: str = "scan",
) -> Tuple[jax.Array, Dict]:
    """One decode step: new token(s) + cached state -> (logits, new caches).

    ``batch`` carries ``tokens [B, T_new(, K)]`` (or ``embeds``); ``pos`` is
    the absolute position of the first new token.

    ``loop="scan"`` carries the caches as scan xs->ys, which XLA's buffer
    assigner materializes with extra cache-sized temporaries (~3x the cache
    in measured decode cells).  ``loop="fori"`` keeps the caches in the
    fori_loop CARRY and updates the current period's slice in place — same
    math, aliasing-friendly buffers (the §Perf memory lever for decode).
    """
    h = embed_tokens(params, cfg, batch)
    b, t, _ = h.shape
    pos1d = jnp.asarray(pos)[..., None] + jnp.arange(t)   # [t] or [B, t]
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(pos1d[None] if pos1d.ndim == 2
                                     else pos1d[None, None], (3, b, t))
    else:
        positions = jnp.broadcast_to(pos1d if pos1d.ndim == 2
                                     else pos1d[None], (b, t))

    def period_fn(h, p_period, cache_period):
        new_caches = {}
        for i, spec_i in enumerate(cfg.layer_pattern):
            h, nc, _ = _sub_forward(p_period["sub%d" % i], cfg, spec_i, h,
                                    positions, cache_period["sub%d" % i], impl,
                                    dropless=True,   # serve path: exact MoE
                                    moe_groups=moe_groups, moe_axes=moe_axes,
                                    moe_combine=moe_combine)
            new_caches["sub%d" % i] = nc
        return h, new_caches

    if loop == "fori":
        def body(i, carry):
            h, cc = carry
            p_period = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, keepdims=False),
                params["blocks"])
            cache_period = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, keepdims=False),
                cc)
            h, new_caches = period_fn(h, p_period, cache_period)
            cc = jax.tree.map(
                lambda c, nc: jax.lax.dynamic_update_index_in_dim(
                    c, nc.astype(c.dtype), i, 0), cc, new_caches)
            return h, cc
        h, new_caches = jax.lax.fori_loop(0, cfg.num_periods, body,
                                          (h, caches))
    else:
        h, new_caches = jax.lax.scan(
            lambda h, xs: period_fn(h, xs[0], xs[1]),
            h, (params["blocks"], caches), unroll=unroll)
    h = apply_norm(cfg.norm, h, params.get("final_norm"))
    return lm_logits(params, cfg, h), new_caches
