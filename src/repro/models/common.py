"""Shared model primitives: norms, RoPE (incl. M-RoPE), init, logical axes.

Weights are plain pytrees (nested dicts of jnp arrays).  Every parameter is
created through :func:`param` with a *logical axis* tuple; the sharding layer
(:mod:`repro.sharding.partition`) maps logical axes -> mesh axes, so the same
model code runs single-device, TP, EP or multi-pod without edits.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# logical axis names used across the stack
EMBED = "embed"          # d_model
VOCAB = "vocab"
HEADS = "heads"          # q heads (TP-sharded)
KV_HEADS = "kv_heads"
HEAD_DIM = "head_dim"
FF = "ff"                # MLP intermediate (TP-sharded)
EXPERT = "expert"        # MoE experts (EP-sharded)
SSM_INNER = "ssm_inner"  # mamba d_inner (TP-sharded)
SSM_STATE = "ssm_state"
LAYERS = "layers"        # stacked scan axis (never sharded)
LORA = "lora"


class ParamSpec:
    """Accumulates (path -> logical axes) while init builds the pytree."""

    def __init__(self) -> None:
        self.axes: Dict[str, Tuple[Optional[str], ...]] = {}

    def record(self, path: str, axes: Tuple[Optional[str], ...]):
        self.axes[path] = axes


def param(
    key: jax.Array, shape: Sequence[int], axes: Tuple[Optional[str], ...],
    spec: ParamSpec, path: str, dtype=jnp.float32, scale: Optional[float] = None,
) -> jax.Array:
    assert len(shape) == len(axes), (path, shape, axes)
    spec.record(path, tuple(axes))
    if scale is None:
        fan_in = shape[0] if len(shape) > 1 else shape[-1]
        scale = 1.0 / np.sqrt(max(1, fan_in))
    return (jax.random.normal(key, tuple(shape), jnp.float32) * scale).astype(dtype)


def zeros_param(shape, axes, spec: ParamSpec, path: str, dtype=jnp.float32):
    spec.record(path, tuple(axes))
    return jnp.zeros(tuple(shape), dtype)


def ones_param(shape, axes, spec: ParamSpec, path: str, dtype=jnp.float32):
    spec.record(path, tuple(axes))
    return jnp.ones(tuple(shape), dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: Optional[jax.Array], eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    if weight is not None:
        out = out * weight.astype(jnp.float32)
    return out.astype(dtype)


def layer_norm_nonparam(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo-style non-parametric LayerNorm (no scale, no bias)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(dtype)


def apply_norm(kind: str, x: jax.Array, weight: Optional[jax.Array]) -> jax.Array:
    if kind == "rmsnorm":
        return rms_norm(x, weight)
    if kind == "layernorm_nonparam":
        return layer_norm_nonparam(x)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# RoPE / M-RoPE
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(
    x: jax.Array,                  # [..., T, H, D] or [..., T, D]
    positions: jax.Array,          # [..., T]
    theta: float = 10_000.0,
) -> jax.Array:
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # [d/2]
    ang = positions.astype(jnp.float32)[..., None] * freqs   # [..., T, d/2]
    if x.ndim == ang.ndim + 1:                          # [..., T, H, D]
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,                  # [B, T, H, D]
    positions: jax.Array,          # [3, B, T] (temporal, height, width)
    sections: Tuple[int, int, int],
    theta: float = 10_000.0,
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: head_dim half-split into 3 frequency
    sections, each rotated by its own position stream (t/h/w)."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(d, theta)                        # [half]
    # section s of the frequency vector gets position stream s
    sec_idx = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half
    )                                                   # [half]
    pos = positions.astype(jnp.float32)                 # [3, B, T]
    pos_per_freq = jnp.take(pos, sec_idx, axis=0)       # [half, B, T]
    ang = jnp.moveaxis(pos_per_freq, 0, -1) * freqs     # [B, T, half]
    ang = ang[..., None, :]                             # [B, T, 1, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# misc
# --------------------------------------------------------------------------

def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    out = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        out = out + b.astype(x.dtype)
    return out


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]
