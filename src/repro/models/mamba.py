"""Mamba mixers: Mamba-1 (selective scan, used by Jamba) and Mamba-2 (SSD).

Mamba-2's chunked SSD is matmul-dominated (MXU-friendly); the default path is
the pure-jnp reference scan (lowers/shards cleanly everywhere) and
``impl="pallas"`` switches to :mod:`repro.kernels.ssd`.  Mamba-1's recurrence
is evaluated with ``jax.lax.associative_scan`` over the time axis.

Decode carries O(1) state per layer — conv tail + SSM state — which is what
makes the SSM/hybrid architectures the ``long_500k`` family.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MambaConfig, ModelConfig
from .common import EMBED, SSM_INNER, SSM_STATE, ParamSpec, dense, param, ones_param, zeros_param


def _dt_rank(d_model: int) -> int:
    return max(1, math.ceil(d_model / 16))


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_mamba(key, cfg: ModelConfig, spec: ParamSpec, path: str, dtype) -> Dict:
    mc = cfg.mamba
    d = cfg.d_model
    di = mc.d_inner(d)
    ks = jax.random.split(key, 8)
    if mc.version == 2:
        nh = mc.nheads(d)
        g, s = mc.ngroups, mc.d_state
        conv_ch = di + 2 * g * s
        p = {
            "in_proj": param(ks[0], (d, 2 * di + 2 * g * s + nh), (EMBED, SSM_INNER),
                             spec, path + "/in_proj", dtype),
            "conv_w": param(ks[1], (mc.d_conv, conv_ch), (None, SSM_INNER),
                            spec, path + "/conv_w", dtype, scale=0.5),
            "conv_b": zeros_param((conv_ch,), (SSM_INNER,), spec, path + "/conv_b", dtype),
            "A_log": zeros_param((nh,), (None,), spec, path + "/A_log", jnp.float32),
            "D": ones_param((nh,), (None,), spec, path + "/D", jnp.float32),
            "dt_bias": zeros_param((nh,), (None,), spec, path + "/dt_bias", jnp.float32),
            "norm_w": ones_param((di,), (SSM_INNER,), spec, path + "/norm_w", dtype),
            "out_proj": param(ks[2], (di, d), (SSM_INNER, EMBED), spec,
                              path + "/out_proj", dtype),
        }
        return p
    r = _dt_rank(d)
    s = mc.d_state
    return {
        "in_proj": param(ks[0], (d, 2 * di), (EMBED, SSM_INNER), spec, path + "/in_proj", dtype),
        "conv_w": param(ks[1], (mc.d_conv, di), (None, SSM_INNER), spec,
                        path + "/conv_w", dtype, scale=0.5),
        "conv_b": zeros_param((di,), (SSM_INNER,), spec, path + "/conv_b", dtype),
        "x_proj": param(ks[2], (di, r + 2 * s), (SSM_INNER, None), spec, path + "/x_proj", dtype),
        "dt_proj": param(ks[3], (r, di), (None, SSM_INNER), spec, path + "/dt_proj", dtype),
        "dt_bias": zeros_param((di,), (SSM_INNER,), spec, path + "/dt_bias", jnp.float32),
        "A_log": zeros_param((di, s), (SSM_INNER, SSM_STATE), spec, path + "/A_log", jnp.float32),
        "D": ones_param((di,), (SSM_INNER,), spec, path + "/D", jnp.float32),
        "out_proj": param(ks[4], (di, d), (SSM_INNER, EMBED), spec, path + "/out_proj", dtype),
    }


# --------------------------------------------------------------------------
# causal depthwise conv
# --------------------------------------------------------------------------

def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """x [B,T,C], w [K,C] depthwise.  Returns (y [B,T,C], new tail [B,K-1,C])."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([tail, x], axis=1)               # [B, T+K-1, C]
    y = sum(
        xx[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    ) + b[None, None, :]
    new_tail = xx[:, -(k - 1):, :] if k > 1 else jnp.zeros_like(tail)
    return y, new_tail


# --------------------------------------------------------------------------
# Mamba-2 forward
# --------------------------------------------------------------------------

def mamba2_forward(
    p: Dict, cfg: ModelConfig, x: jax.Array,
    cache: Optional[Dict] = None, impl: str = "xla",
) -> Tuple[jax.Array, Optional[Dict]]:
    mc = cfg.mamba
    b, t, d = x.shape
    di = mc.d_inner(d)
    nh = mc.nheads(d)
    g, s, hd = mc.ngroups, mc.d_state, mc.headdim

    zxbcdt = dense(x, p["in_proj"])
    z, xb, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * g * s], axis=-1)
    conv_tail = cache["conv"] if cache is not None else None
    xb, new_tail = _causal_conv(xb, p["conv_w"], p["conv_b"], conv_tail)
    xb = jax.nn.silu(xb)
    xs, Bm, Cm = jnp.split(xb, [di, di + g * s], axis=-1)
    xs = xs.reshape(b, t, nh, hd)
    Bm = Bm.reshape(b, t, g, s)
    Cm = Cm.reshape(b, t, g, s)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])    # [b,t,nh]
    A = -jnp.exp(p["A_log"])                                           # [nh]

    if cache is None:
        if impl == "pallas":
            from repro.kernels.ssd import ops as ssd_ops
            y = ssd_ops.ssd(xs, dt, A, Bm, Cm, p["D"], use_pallas=True)
            new_cache = None
        else:
            from repro.kernels.ssd.ref import ssd_ref
            y, _ = ssd_ref(xs, dt, A, Bm, Cm, p["D"])
            new_cache = None
    elif t > 1:
        # prefill with state carry: full scan, emit final state into the cache
        from repro.kernels.ssd.ref import ssd_ref
        y, final_state = ssd_ref(xs, dt, A, Bm, Cm, p["D"],
                                 init_state=cache["ssm"])
        new_cache = {"conv": new_tail, "ssm": final_state}
    else:
        # single-step recurrence on the carried state
        state = cache["ssm"]                                           # [b,nh,s,hd]
        rep = nh // g
        Bh = jnp.repeat(Bm, rep, axis=2)[:, 0]                         # [b,nh,s]
        Ch = jnp.repeat(Cm, rep, axis=2)[:, 0]
        a = jnp.exp(dt[:, 0] * A)                                      # [b,nh]
        upd = (dt[:, 0, :, None] * Bh)[..., None] * xs[:, 0, :, None, :].astype(jnp.float32)
        state = a[..., None, None] * state + upd
        y = jnp.einsum("bhs,bhsp->bhp", Ch, state)
        y = y + p["D"][None, :, None] * xs[:, 0].astype(jnp.float32)
        y = y[:, None].astype(x.dtype)                                 # [b,1,nh,hd]
        new_cache = {"conv": new_tail, "ssm": state}

    y = y.reshape(b, t, di)
    # gated RMSNorm (Mamba-2 block epilogue)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * p["norm_w"].astype(jnp.float32)).astype(x.dtype)
    return dense(y, p["out_proj"]), new_cache


# --------------------------------------------------------------------------
# Mamba-1 forward (selective scan)
# --------------------------------------------------------------------------

def mamba1_forward(
    p: Dict, cfg: ModelConfig, x: jax.Array,
    cache: Optional[Dict] = None, impl: str = "xla",
) -> Tuple[jax.Array, Optional[Dict]]:
    mc = cfg.mamba
    b, t, d = x.shape
    di = mc.d_inner(d)
    s = mc.d_state
    r = _dt_rank(d)

    xz = dense(x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)
    conv_tail = cache["conv"] if cache is not None else None
    xs, new_tail = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_tail)
    xs = jax.nn.silu(xs)

    proj = dense(xs, p["x_proj"])
    dt_r, Bm, Cm = jnp.split(proj, [r, r + s], axis=-1)
    dt = jax.nn.softplus(dense(dt_r, p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"])                               # [b,t,di]
    A = -jnp.exp(p["A_log"])                                           # [di,s]
    xf = xs.astype(jnp.float32)

    a = jnp.exp(dt[..., None] * A[None, None])                         # [b,t,di,s]
    u = (dt * xf)[..., None] * Bm[:, :, None, :].astype(jnp.float32)   # [b,t,di,s]

    if cache is None or t > 1:
        def combine(l, rgt):
            al, bl = l
            ar, br = rgt
            return al * ar, br + ar * bl
        aa, hh = jax.lax.associative_scan(combine, (a, u), axis=1)
        if cache is not None:   # prefill with carried initial state
            hh = hh + aa * cache["ssm"][:, None]
        y = jnp.einsum("bts,btds->btd", Cm.astype(jnp.float32), hh)
        new_cache = (
            {"conv": new_tail, "ssm": hh[:, -1]} if cache is not None else None
        )
    else:
        state = cache["ssm"]                                           # [b,di,s]
        state = a[:, 0] * state + u[:, 0]
        y = jnp.einsum("bs,bds->bd", Cm[:, 0].astype(jnp.float32), state)[:, None]
        new_cache = {"conv": new_tail, "ssm": state}

    y = y + p["D"] * xf
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    return dense(y, p["out_proj"]), new_cache


def mamba_forward(p, cfg, x, cache=None, impl="xla"):
    if cfg.mamba.version == 2:
        return mamba2_forward(p, cfg, x, cache, impl)
    return mamba1_forward(p, cfg, x, cache, impl)


def mamba_cache_shape(cfg: ModelConfig, batch: int, dtype) -> Dict:
    mc = cfg.mamba
    d = cfg.d_model
    di = mc.d_inner(d)
    if mc.version == 2:
        conv_ch = di + 2 * mc.ngroups * mc.d_state
        return {
            "conv": jnp.zeros((batch, mc.d_conv - 1, conv_ch), dtype),
            "ssm": jnp.zeros((batch, mc.nheads(d), mc.d_state, mc.headdim), jnp.float32),
        }
    return {
        "conv": jnp.zeros((batch, mc.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, mc.d_state), jnp.float32),
    }
