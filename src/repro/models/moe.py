"""Mixture-of-Experts FFN: top-k routing with sort-based capacity dispatch.

Dispatch is Megablocks-style sort/gather rather than a one-hot dispatch
matmul: tokens are ranked per expert with a stable sort, clipped to a static
capacity, gathered into dense ``[E, C, d]`` blocks for the batched expert
GEMMs, and scatter-added back with their router weights.  Compiled FLOPs
scale with *active* parameters (E·C ≈ tokens·top_k·capacity_factor), which
keeps the roofline's MODEL_FLOPS/HLO_FLOPs ratio honest.

Experts shard over the ``model`` mesh axis (expert parallelism): the ``E``
leading dim of every expert weight and of the dispatched activations carries
the EXPERT logical axis.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from .common import EMBED, EXPERT, FF, ParamSpec, dense, param
from .mlp import init_mlp, mlp_forward


def init_moe(key, d_model: int, mo: MoEConfig, spec: ParamSpec, path: str, dtype) -> Dict:
    k_router, k_experts, k_shared = jax.random.split(key, 3)
    e, f = mo.num_experts, mo.expert_ff
    ks = jax.random.split(k_experts, 3)
    p = {
        "router": param(k_router, (d_model, e), (EMBED, EXPERT), spec,
                        path + "/router", jnp.float32),   # router in f32
        "wi": param(ks[0], (e, d_model, f), (EXPERT, EMBED, FF), spec, path + "/wi", dtype),
        "wg": param(ks[1], (e, d_model, f), (EXPERT, EMBED, FF), spec, path + "/wg", dtype),
        "wo": param(ks[2], (e, f, d_model), (EXPERT, FF, EMBED), spec, path + "/wo", dtype),
    }
    if mo.num_shared:
        p["shared"] = init_mlp(
            k_shared, d_model, (mo.shared_ff or mo.expert_ff) * mo.num_shared,
            spec, path + "/shared", dtype,
        )
    return p


def _capacity(num_tokens: int, mo: MoEConfig) -> int:
    c = int(math.ceil(num_tokens * mo.top_k * mo.capacity_factor / mo.num_experts))
    return max(4, -(-c // 4) * 4)     # round up to a multiple of 4


def _topk_router(probs: jax.Array, k: int):
    """Partition-friendly top-k: k iterated argmaxes over the expert dim.

    ``lax.top_k`` lowers through a sort custom-call that GSPMD cannot
    partition on batch dims (measured: it all-gathers the full [n, e] router
    probabilities on every device).  For router-sized k (<= 8) k argmax
    passes are pure elementwise/reduce ops that shard cleanly.
    """
    e = probs.shape[-1]
    p = probs
    ws, idxs = [], []
    for _ in range(k):
        i = jnp.argmax(p, axis=-1)
        ws.append(jnp.max(p, axis=-1))
        idxs.append(i)
        p = jnp.where(jax.nn.one_hot(i, e, dtype=bool), -jnp.inf, p)
    return jnp.stack(ws, axis=-1), jnp.stack(idxs, axis=-1).astype(jnp.int32)


def _dispatch_group(xf, probs, k: int, e: int, cap: int):
    """Sort-based dispatch of one token group: returns (xe [e,cap,d],
    tok_for_slot [e*cap], w_for_slot [e*cap])."""
    n = xf.shape[0]
    weights, sel = _topk_router(probs, k)                        # [n, k]
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    flat_e = sel.reshape(n * k)
    flat_tok = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    flat_w = weights.reshape(n * k)
    order = jnp.argsort(flat_e, stable=True)
    se = jnp.take(flat_e, order)
    st = jnp.take(flat_tok, order)
    sw = jnp.take(flat_w, order)
    idx = jnp.arange(n * k, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), se[1:] != se[:-1]])
    run_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, idx, 0))
    pos_in_e = idx - run_start
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, e * cap)         # overflow slot

    tok_for_slot = jnp.full((e * cap + 1,), -1, jnp.int32).at[slot].set(
        jnp.where(keep, st, -1), mode="drop")[: e * cap]
    w_for_slot = jnp.zeros((e * cap + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, sw, 0.0), mode="drop")[: e * cap]

    xe = jnp.where(
        (tok_for_slot >= 0)[:, None],
        jnp.take(xf, jnp.maximum(tok_for_slot, 0), axis=0),
        0.0,
    ).reshape(e, cap, xf.shape[-1])
    return xe, tok_for_slot, w_for_slot, sel


def moe_forward(
    p: Dict, mo: MoEConfig, x: jax.Array,   # [B, T, d]
    dropless: bool = False,
    dispatch_groups: int = 1,
    group_axes=None,
    combine_axes=None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B,T,d], aux load-balancing loss scalar).

    ``dropless=True`` sets capacity to ``n`` (each expert can absorb every
    token), making the output independent of batch composition — required for
    the serve path's prefill ≡ decode invariant.  Training keeps the standard
    ``capacity_factor`` clipping (token drops under router imbalance are the
    usual training-time trade; serve chunks keep ``n`` bounded instead).

    ``dispatch_groups > 1`` runs a **hierarchical dispatch**: tokens are
    split into G groups (aligned with the data-parallel sharding of the
    batch) and the sort/gather/scatter machinery runs *per group*.  Under
    GSPMD a global dispatch lowers to giant all-gathers/all-reduces of the
    [e, cap, d] buffers (the sort permutes tokens across devices); per-group
    dispatch keeps all of it device-local, and only the expert GEMMs touch
    the EP axis — the §Perf lever for every MoE cell.  With ``dropless=True``
    the result is exactly equal for any G; in capacity mode each group gets
    ``cap/G`` slots (per-device capacity — standard at scale).

    ``group_axes``: mesh axis name(s) to pin the G dim to (e.g. ``("data",)``)
    — without the explicit constraint GSPMD does not reliably infer that the
    vmapped dispatch is group-local and falls back to all-gathering the
    dispatch buffers (measured; see EXPERIMENTS.md §Perf).
    """
    b, t, d = x.shape
    n = b * t
    e, k = mo.num_experts, mo.top_k
    G = dispatch_groups
    if n % G or (n // G) < 4:
        G = 1
    ng = n // G
    cap = max(4, -(-ng // 4) * 4) if dropless else _capacity(ng, mo)
    xf = x.reshape(n, d)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # [n, e]

    def pin(arr):
        if group_axes is None or G == 1:
            return arr
        from jax.sharding import PartitionSpec as P
        spec = P(tuple(group_axes), *([None] * (arr.ndim - 1)))
        return jax.lax.with_sharding_constraint(arr, spec)

    xe, tok_for_slot, w_for_slot, sel = jax.vmap(
        lambda xg, pg: _dispatch_group(xg, pg, k, e, cap)
    )(pin(xf.reshape(G, ng, d)), pin(probs.reshape(G, ng, e)))
    # xe: [G, e, cap, d]; tok/w_for_slot: [G, e*cap]; sel: [G, ng, k]
    xe = pin(xe)
    tok_for_slot = pin(tok_for_slot)
    w_for_slot = pin(w_for_slot)

    # ---- batched expert GEMMs (EP-sharded over the E axis) -------------------
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wg"].astype(xe.dtype)))
    h = h * jnp.einsum("gecd,edf->gecf", xe, p["wi"].astype(xe.dtype))
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(xe.dtype))

    if combine_axes is not None and G > 1:
        # EP combine: ye leaves the expert GEMM sharded on E (each device
        # holds its local experts' outputs), but the token scatter below
        # needs every expert's rows for its group.  Left alone, GSPMD
        # ALL-GATHERS the full [e, cap, d] buffer per group (measured: the
        # dominant collective of every EP cell).  Re-constraining ye with the
        # EP axis moved from E to CAP turns the reshard into an all-to-all
        # (each device keeps 1/|EP| of every expert's rows) — ~|EP|x less
        # wire than the gather; the scatter then runs on cap-shards and the
        # final psum over the EP axis is one [ng, d] reduction.
        from jax.sharding import PartitionSpec as P
        spec = P(tuple(group_axes) if group_axes else None, None,
                 tuple(combine_axes), None)
        ye = jax.lax.with_sharding_constraint(ye, spec)
    else:
        ye = pin(ye)

    # ---- weighted combine (per group) ----------------------------------------
    # scatter-add with 2-D [e, cap] indices: merging (e, cap) -> e*cap rows
    # before the scatter would merge a sharded-inner dim, which GSPMD can
    # only lower by all-gathering the whole buffer — the 2-D scatter keeps
    # cap-shards local and reduces partials with one [ng, d] psum
    def _combine(ye_g, tok_g, w_g):
        tok2 = tok_g.reshape(e, cap)
        w2 = w_g.reshape(e, cap)
        src = ye_g * w2[..., None].astype(ye_g.dtype)          # [e, cap, d]
        return jnp.zeros((ng + 1, d), ye_g.dtype).at[
            jnp.where(tok2 >= 0, tok2, ng)
        ].add(src, mode="drop")[:ng]

    y = pin(jax.vmap(_combine)(ye, tok_for_slot, w_for_slot)).reshape(n, d)

    if "shared" in p:
        y = y + mlp_forward(p["shared"], xf).astype(y.dtype)

    # ---- aux load-balance loss (Switch-style, global statistics) -------------
    frac_tokens = jnp.mean(
        jax.nn.one_hot(sel.reshape(n, k)[:, 0], e, dtype=jnp.float32), axis=0
    )
    frac_probs = jnp.mean(probs, axis=0)
    aux = jnp.sum(frac_tokens * frac_probs) * e * mo.aux_loss_weight
    return y.reshape(b, t, d).astype(x.dtype), aux
