"""Dense SwiGLU MLP (the pool's universal FFN shape)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import EMBED, FF, ParamSpec, dense, param


def init_mlp(key, d_model: int, d_ff: int, spec: ParamSpec, path: str, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": param(k1, (d_model, d_ff), (EMBED, FF), spec, path + "/wi", dtype),
        "wg": param(k2, (d_model, d_ff), (EMBED, FF), spec, path + "/wg", dtype),
        "wo": param(k3, (d_ff, d_model), (FF, EMBED), spec, path + "/wo", dtype),
    }


def mlp_forward(p: Dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(dense(x, p["wg"])) * dense(x, p["wi"])
    return dense(h, p["wo"])
