"""Attention mixers: GQA (optionally SWA / QKV-bias / M-RoPE) and MLA.

Two execution paths share one math definition:

* ``mode="train"``  — full-sequence causal attention (optionally windowed);
* ``mode="decode"`` — single-step with a KV cache laid out ``[B, S, Hk, D]``
  (MLA caches the compressed latent ``[B, S, r]`` + shared rope key instead —
  the paper-pool architectures' serve-memory win).

The jnp path is the default (it lowers/shards cleanly under pjit for the
dry-run); ``impl="pallas"`` switches the hot loop to the flash kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from .common import (
    EMBED, HEAD_DIM, HEADS, KV_HEADS, LORA, ParamSpec, apply_mrope, apply_rope,
    dense, param, zeros_param,
)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_attention(
    key: jax.Array, cfg: ModelConfig, spec: ParamSpec, path: str, dtype,
) -> Dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 8)
    if cfg.mla is not None:
        m = cfg.mla
        qdim = cfg.num_heads * (m.nope_head_dim + m.rope_head_dim)
        p: Dict = {}
        if m.q_lora_rank:
            p["wq_a"] = param(ks[0], (d, m.q_lora_rank), (EMBED, LORA), spec, path + "/wq_a", dtype)
            p["wq_b"] = param(ks[1], (m.q_lora_rank, qdim), (LORA, HEADS), spec, path + "/wq_b", dtype)
        else:
            p["wq"] = param(ks[0], (d, qdim), (EMBED, HEADS), spec, path + "/wq", dtype)
        p["wkv_a"] = param(ks[2], (d, m.kv_lora_rank), (EMBED, LORA), spec, path + "/wkv_a", dtype)
        p["wk_rope"] = param(ks[3], (d, m.rope_head_dim), (EMBED, HEAD_DIM), spec, path + "/wk_rope", dtype)
        p["wkv_b"] = param(
            ks[4], (m.kv_lora_rank, cfg.num_heads * (m.nope_head_dim + m.v_head_dim)),
            (LORA, HEADS), spec, path + "/wkv_b", dtype,
        )
        p["wo"] = param(ks[5], (cfg.num_heads * m.v_head_dim, d), (HEADS, EMBED), spec, path + "/wo", dtype)
        return p
    p = {
        "wq": param(ks[0], (d, cfg.num_heads * hd), (EMBED, HEADS), spec, path + "/wq", dtype),
        "wk": param(ks[1], (d, cfg.num_kv_heads * hd), (EMBED, KV_HEADS), spec, path + "/wk", dtype),
        "wv": param(ks[2], (d, cfg.num_kv_heads * hd), (EMBED, KV_HEADS), spec, path + "/wv", dtype),
        "wo": param(ks[3], (cfg.num_heads * hd, d), (HEADS, EMBED), spec, path + "/wo", dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_param((cfg.num_heads * hd,), (HEADS,), spec, path + "/bq", dtype)
        p["bk"] = zeros_param((cfg.num_kv_heads * hd,), (KV_HEADS,), spec, path + "/bk", dtype)
        p["bv"] = zeros_param((cfg.num_kv_heads * hd,), (KV_HEADS,), spec, path + "/bv", dtype)
    return p


# --------------------------------------------------------------------------
# shared attention math
# --------------------------------------------------------------------------

def _sdpa(
    q: jax.Array,            # [B, T, Hq, D]
    k: jax.Array,            # [B, S, Hk, D]
    v: jax.Array,            # [B, S, Hk, Dv]
    causal: bool,
    window: Optional[int],
    q_offset,
    impl: str = "xla",
) -> jax.Array:
    b, t, hq, dd = q.shape
    s, hk = k.shape[1], k.shape[2]
    if impl == "pallas" and t == 1 and causal and window is None:
        # decode fast path: one query row against the cache, per-sequence
        # valid length = q_offset + 1 (the just-written position)
        from repro.kernels.decode_attention import ops as da_ops
        off = jnp.asarray(q_offset)
        lengths = jnp.broadcast_to(off + 1, (b,)).astype(jnp.int32)
        out = da_ops.decode_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), lengths,
        )
        return out.transpose(0, 2, 1, 3).astype(q.dtype)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, window=window,
            q_offset=int(q_offset),
        )
        return out.transpose(0, 2, 1, 3)
    group = hq // hk
    scale = 1.0 / jnp.sqrt(jnp.asarray(dd, jnp.float32))
    qf = q.reshape(b, t, hk, group, dd).astype(jnp.float32)
    logits = jnp.einsum("bthgd,bshd->bhgts", qf, k.astype(jnp.float32)) * scale
    off = jnp.asarray(q_offset)
    # qpos: [t] when offset is scalar, [B, t] when per-sequence (batcher)
    qpos = off[..., None] + jnp.arange(t)
    kpos = jnp.arange(s)
    mask = jnp.ones(qpos.shape + (s,), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[..., None]
    if window is not None:
        mask &= kpos[None, :] > qpos[..., None] - window
    mask_b = mask[None, None, None] if mask.ndim == 2 else mask[:, None, None]
    logits = jnp.where(mask_b, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgts,bshd->bthgd", probs, v.astype(jnp.float32))
    return out.reshape(b, t, hq, v.shape[-1]).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA forward (train + decode)
# --------------------------------------------------------------------------

def gqa_forward(
    p: Dict, cfg: ModelConfig, x: jax.Array,
    positions: jax.Array,                 # [B, T] (or [3, B, T] for M-RoPE)
    cache: Optional[Dict] = None,         # {"k": [B,S,Hk,D], "v":..., "len": []}
    impl: str = "xla",
) -> Tuple[jax.Array, Optional[Dict]]:
    b, t, d = x.shape
    hd = cfg.resolved_head_dim
    q = dense(x, p["wq"], p.get("bq")).reshape(b, t, cfg.num_heads, hd)
    k = dense(x, p["wk"], p.get("bk")).reshape(b, t, cfg.num_kv_heads, hd)
    v = dense(x, p["wv"], p.get("bv")).reshape(b, t, cfg.num_kv_heads, hd)

    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
        pos1d = positions[0]
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        pos1d = positions

    if cache is None:
        out = _sdpa(q, k, v, causal=True, window=cfg.swa_window, q_offset=0,
                    impl=impl)
        new_cache = None
    else:
        idx = cache["len"]                  # [] shared or [B] per-sequence
        if idx.ndim == 0:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
        else:
            upd = jax.vmap(
                lambda c, x, i: jax.lax.dynamic_update_slice(c, x, (i, 0, 0))
            )
            ck = upd(cache["k"], k.astype(cache["k"].dtype), idx)
            cv = upd(cache["v"], v.astype(cache["v"].dtype), idx)
        out = _sdpa(q, ck, cv, causal=True, window=cfg.swa_window,
                    q_offset=idx, impl="xla" if idx.ndim else impl)
        new_cache = {"k": ck, "v": cv, "len": idx + t}
    return dense(out.reshape(b, t, cfg.num_heads * hd), p["wo"]), new_cache


def gqa_cache_shape(cfg: ModelConfig, batch: int, max_len: int, dtype,
                    per_seq: bool = False) -> Dict:
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), dtype),
        "len": jnp.zeros((batch,) if per_seq else (), jnp.int32),
    }


# --------------------------------------------------------------------------
# MLA forward (train + decode) — latent-compressed KV cache
# --------------------------------------------------------------------------

def mla_forward(
    p: Dict, cfg: ModelConfig, x: jax.Array,
    positions: jax.Array,
    cache: Optional[Dict] = None,   # {"ckv": [B,S,r], "krope": [B,S,dr], "len"}
    impl: str = "xla",
) -> Tuple[jax.Array, Optional[Dict]]:
    m: MLAConfig = cfg.mla
    b, t, d = x.shape
    h = cfg.num_heads
    dn, dr, dv = m.nope_head_dim, m.rope_head_dim, m.v_head_dim

    if m.q_lora_rank:
        q = dense(dense(x, p["wq_a"]), p["wq_b"])
    else:
        q = dense(x, p["wq"])
    q = q.reshape(b, t, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = dense(x, p["wkv_a"])                       # [B, T, r] latent
    k_rope = apply_rope(
        dense(x, p["wk_rope"]).reshape(b, t, 1, dr), positions, cfg.rope_theta
    ).reshape(b, t, dr)                              # shared across heads

    if cache is not None:
        idx = cache["len"]
        if idx.ndim == 0:
            ckv_all = jax.lax.dynamic_update_slice(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, idx, 0))
            kr_all = jax.lax.dynamic_update_slice(
                cache["krope"], k_rope.astype(cache["krope"].dtype), (0, idx, 0))
        else:
            upd = jax.vmap(
                lambda c, x, i: jax.lax.dynamic_update_slice(c, x, (i, 0))
            )
            ckv_all = upd(cache["ckv"], ckv.astype(cache["ckv"].dtype), idx)
            kr_all = upd(cache["krope"], k_rope.astype(cache["krope"].dtype), idx)
        new_cache = {"ckv": ckv_all, "krope": kr_all, "len": idx + t}
        q_offset = idx
    else:
        ckv_all, kr_all = ckv, k_rope
        new_cache = None
        q_offset = 0

    if cache is not None and cfg.mla_absorbed:
        # --- absorbed decode: attention runs IN LATENT SPACE --------------
        # Naively expanding the cached latent to per-head K/V re-projects the
        # whole [B, S, r] cache through wkv_b every step: O(S·h·(dn+dv)·r)
        # FLOPs + an [B, S, h, dn+dv] materialization per layer per token.
        # Absorption folds wkv_b's key half into the QUERY (q_lat = q_nope @
        # W_k^T, one O(t·h·dn·r) matmul) and applies the value half AFTER the
        # [B, h, t, S] x [B, S, r] contraction, so per-step cost is O(S·r)
        # per head-group and the big expansion disappears.
        wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, h, dn + dv)
        w_k = wkv_b[..., :dn]                                  # [r, h, dn]
        w_v = wkv_b[..., dn:]                                  # [r, h, dv]
        q_lat = jnp.einsum("bthd,rhd->bthr", q_nope.astype(jnp.float32),
                           w_k.astype(jnp.float32))            # [B,t,h,r]
        s = ckv_all.shape[1]
        scale = 1.0 / jnp.sqrt(jnp.asarray(dn + dr, jnp.float32))
        logits = (
            jnp.einsum("bthr,bsr->bhts", q_lat, ckv_all.astype(jnp.float32))
            + jnp.einsum("bthd,bsd->bhts", q_rope.astype(jnp.float32),
                         kr_all.astype(jnp.float32))
        ) * scale
        qpos = (jnp.asarray(q_offset)[..., None] + jnp.arange(t))
        kpos = jnp.arange(s)
        mask = kpos[None, :] <= qpos[..., None]                # causal
        mask_b = mask[None, None] if mask.ndim == 2 else mask[:, None]
        logits = jnp.where(mask_b, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)                # [B,h,t,S]
        ctx = jnp.einsum("bhts,bsr->bthr", probs,
                         ckv_all.astype(jnp.float32))          # [B,t,h,r]
        out = jnp.einsum("bthr,rhd->bthd", ctx, w_v.astype(jnp.float32))
        out = out.astype(x.dtype)
        return dense(out.reshape(b, t, h * dv), p["wo"]), new_cache

    # expand latent -> per-head keys/values (training / reference path; the
    # cache object is still the small latent)
    kv = dense(ckv_all, p["wkv_b"]).reshape(b, -1, h, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_all[:, :, None, :], k_nope.shape[:3] + (dr,))],
        axis=-1,
    )
    qk = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = _sdpa(qk, k, v, causal=True, window=cfg.swa_window,
                q_offset=q_offset, impl=impl)
    return dense(out.reshape(b, t, h * dv), p["wo"]), new_cache


def mla_cache_shape(cfg: ModelConfig, batch: int, max_len: int, dtype,
                    per_seq: bool = False) -> Dict:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, m.rope_head_dim), dtype),
        "len": jnp.zeros((batch,) if per_seq else (), jnp.int32),
    }


def attn_forward(p, cfg, x, positions, cache=None, impl="xla"):
    if cfg.mla is not None:
        return mla_forward(p, cfg, x, positions, cache, impl)
    return gqa_forward(p, cfg, x, positions, cache, impl)


def attn_cache_shape(cfg, batch, max_len, dtype, per_seq: bool = False):
    if cfg.mla is not None:
        return mla_cache_shape(cfg, batch, max_len, dtype, per_seq)
    return gqa_cache_shape(cfg, batch, max_len, dtype, per_seq)
