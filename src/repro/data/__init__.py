from . import dbpedia, tokens, tweets  # noqa: F401
