"""Synthetic DBpedia-like background KB generator (paper §4.1, dataset B).

Emits the KB structure the paper's queries need:

* a class hierarchy under ``dbo:MusicalArtist`` / ``dbo:TelevisionShow``
  (rdfs:subClassOf, depth <= 3) for hierarchy reasoning (Q15),
* ``rdf:type`` rows linking entities to (sub)classes,
* property-path chains ``entity -> birthPlace -> country -> countryCode``
  (max path length 3, Q16 / CQuery1),
* arbitrary "unused" filler triples so total-KB-size vs used-KB-size
  experiments (Figs. 5-7) can be driven independently.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core.kb import KnowledgeBase, kb_from_triples
from repro.core.rdf import Vocab


@dataclasses.dataclass
class KBSchema:
    rdf_type: int
    subclass_of: int
    same_as: int
    birth_place: int
    country: int
    country_code: int
    musical_artist: int       # root class
    television_show: int      # root class

    @staticmethod
    def create(vocab: Vocab) -> "KBSchema":
        return KBSchema(
            rdf_type=vocab.pred("rdf:type"),
            subclass_of=vocab.pred("rdfs:subClassOf"),
            same_as=vocab.pred("owl:sameAs"),
            birth_place=vocab.pred("dbo:birthPlace"),
            country=vocab.pred("dbo:country"),
            country_code=vocab.pred("dbo:countryCode"),
            musical_artist=vocab.term("dbo:MusicalArtist"),
            television_show=vocab.term("dbo:TelevisionShow"),
        )


@dataclasses.dataclass
class KBConfig:
    num_artist_classes: int = 8       # subclasses under MusicalArtist
    num_show_classes: int = 4
    num_artists: int = 128
    num_shows: int = 64
    num_places: int = 32
    num_countries: int = 8
    filler_triples: int = 0           # "unused KB" padding (Figs. 6/7)
    seed: int = 0


@dataclasses.dataclass
class KBData:
    kb: KnowledgeBase
    schema: KBSchema
    artist_ids: np.ndarray
    show_ids: np.ndarray
    rows: List[Tuple[int, int, int]]


def generate_kb(vocab: Vocab, cfg: KBConfig) -> KBData:
    rng = np.random.default_rng(cfg.seed)
    schema = KBSchema.create(vocab)
    rows: List[Tuple[int, int, int]] = []

    # class hierarchy (depth up to 3: leaf -> mid -> root)
    def hierarchy(root: int, n: int, tag: str) -> List[int]:
        classes = [root]
        mids = []
        for i in range(max(1, n // 3)):
            mid = vocab.term("class:%s:mid%d" % (tag, i))
            rows.append((mid, schema.subclass_of, root))
            mids.append(mid)
            classes.append(mid)
        for i in range(n):
            leaf = vocab.term("class:%s:leaf%d" % (tag, i))
            parent = mids[i % len(mids)] if mids else root
            rows.append((leaf, schema.subclass_of, parent))
            classes.append(leaf)
        return classes

    artist_classes = hierarchy(schema.musical_artist, cfg.num_artist_classes, "artist")
    show_classes = hierarchy(schema.television_show, cfg.num_show_classes, "show")

    places = [vocab.term("place:%d" % i) for i in range(cfg.num_places)]
    countries = [vocab.term("country:%d" % i) for i in range(cfg.num_countries)]
    for i, c in enumerate(countries):
        rows.append((c, schema.country_code, vocab.term("cc:%d" % i)))
    for p in places:
        rows.append((p, schema.country, int(rng.choice(countries))))

    artist_ids = []
    for i in range(cfg.num_artists):
        a = vocab.term("artist:%d" % i)
        artist_ids.append(a)
        rows.append((a, schema.rdf_type, int(rng.choice(artist_classes[1:] or artist_classes))))
        rows.append((a, schema.birth_place, int(rng.choice(places))))
    show_ids = []
    for i in range(cfg.num_shows):
        s = vocab.term("show:%d" % i)
        show_ids.append(s)
        rows.append((s, schema.rdf_type, int(rng.choice(show_classes[1:] or show_classes))))

    # unused filler (drives the paper's total-KB-size axis)
    filler_pred = vocab.pred("filler:pred")
    for i in range(cfg.filler_triples):
        rows.append(
            (vocab.term("filler:s%d" % (i % 997)), filler_pred, vocab.term("filler:o%d" % i))
        )

    return KBData(
        kb=kb_from_triples(rows),
        schema=schema,
        artist_ids=np.asarray(artist_ids, np.uint32),
        show_ids=np.asarray(show_ids, np.uint32),
        rows=rows,
    )
