"""Deterministic synthetic token pipeline for LM training/serving.

Host-sharded, reproducible, infinite: each (epoch, step, host) triple maps to
a unique PRNG stream, so elastic restarts and data-parallel hosts never see
duplicate or skipped batches — the property a 1000-node run needs from its
data layer (no global shuffle state to lose on failure).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenDatasetConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0


def _batch_rng(cfg: TokenDatasetConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id])
    )


def host_batch_shape(cfg: TokenDatasetConfig) -> Tuple[int, int]:
    assert cfg.global_batch % cfg.num_hosts == 0, "batch must divide hosts"
    return (cfg.global_batch // cfg.num_hosts, cfg.seq_len)


def batch_at_step(cfg: TokenDatasetConfig, step: int) -> dict:
    """Materialize this host's batch for an absolute step index."""
    shape = host_batch_shape(cfg)
    rng = _batch_rng(cfg, step)
    # zipf-ish marginal so losses move like natural text rather than uniform noise
    z = rng.zipf(1.3, size=shape).astype(np.int64)
    tokens = np.minimum(z, cfg.vocab_size - 1).astype(np.int32)
    labels = np.roll(tokens, -1, axis=-1)
    labels[:, -1] = 0
    return {"tokens": tokens, "labels": labels}


def token_stream(cfg: TokenDatasetConfig, start_step: int = 0) -> Iterator[dict]:
    """Resumable batch iterator: checkpoint `step`, restart from `start_step`."""
    step = start_step
    while True:
        yield batch_at_step(cfg, step)
        step += 1
