"""Synthetic TweetsKB-like RDF stream generator (paper §4.1, dataset A).

Reproduces the structure the paper's queries rely on: each tweet is one RDF
graph event containing mentions (entities linked to the KB), a sentiment
score, and like/share counts; every triple is stamped with the tweet's
creation time.  Sizes are parameterized; defaults target container scale
(the paper streams 60k tweets / 2.3M triples).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Tuple

import numpy as np

from repro.core.rdf import TripleBatch, Vocab, make_triples


@dataclasses.dataclass
class TweetSchema:
    """Predicate/vocabulary handles shared by stream and queries."""

    mentions: int
    sentiment_pos: int
    sentiment_neg: int
    likes: int
    shares: int

    @staticmethod
    def create(vocab: Vocab) -> "TweetSchema":
        return TweetSchema(
            mentions=vocab.pred("schema:mentions"),
            sentiment_pos=vocab.pred("onyx:positiveEmotion"),
            sentiment_neg=vocab.pred("onyx:negativeEmotion"),
            likes=vocab.pred("schema:likes"),
            shares=vocab.pred("schema:shares"),
        )


@dataclasses.dataclass
class TweetStreamConfig:
    num_tweets: int = 512
    mentions_min: int = 1
    mentions_max: int = 3
    chunk_tweets: int = 64          # tweets per pulled chunk
    triples_per_tweet_cap: int = 8
    start_ts: int = 1000
    ts_step: int = 1                # monotone timestamps (paper assumption 3)
    seed: int = 0


def generate_tweets(
    vocab: Vocab,
    schema: TweetSchema,
    entity_ids: np.ndarray,
    cfg: TweetStreamConfig,
) -> List[Tuple[int, int, int, int, int]]:
    """All (s,p,o,ts,graph) rows for the configured tweet stream."""
    rng = np.random.default_rng(cfg.seed)
    rows: List[Tuple[int, int, int, int, int]] = []
    for i in range(cfg.num_tweets):
        tweet = vocab.term("tweet:%d" % i)
        ts = cfg.start_ts + i * cfg.ts_step
        graph = i + 1
        k = int(rng.integers(cfg.mentions_min, cfg.mentions_max + 1))
        ments = rng.choice(entity_ids, size=min(k, len(entity_ids)), replace=False)
        for e in ments:
            rows.append((tweet, schema.mentions, int(e), ts, graph))
        rows.append(
            (tweet, schema.sentiment_pos, Vocab.number(float(rng.uniform(0, 5))), ts, graph)
        )
        rows.append(
            (tweet, schema.sentiment_neg, Vocab.number(float(rng.uniform(0, 5))), ts, graph)
        )
        if rng.random() < 0.8:  # likes/shares optional (exercises OPTIONAL)
            rows.append(
                (tweet, schema.likes, Vocab.number(float(rng.integers(0, 1000))), ts, graph)
            )
            rows.append(
                (tweet, schema.shares, Vocab.number(float(rng.integers(0, 500))), ts, graph)
            )
    return rows


def stream_chunks(
    rows: List[Tuple[int, int, int, int, int]],
    chunk_capacity: int,
) -> Iterator[TripleBatch]:
    """Chunk rows into fixed-capacity TripleBatches, graph events intact."""
    cur: List[Tuple[int, int, int, int, int]] = []
    i = 0
    while i < len(rows):
        g = rows[i][4]
        graph_rows = []
        j = i
        while j < len(rows) and rows[j][4] == g:
            graph_rows.append(rows[j])
            j += 1
        if len(cur) + len(graph_rows) > chunk_capacity and cur:
            yield make_triples(cur, chunk_capacity)
            cur = []
        cur.extend(graph_rows[:chunk_capacity])
        i = j
    if cur:
        yield make_triples(cur, chunk_capacity)
