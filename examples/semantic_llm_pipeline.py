"""DSCEP x LM composition: a semantic stream feeding an LM scoring operator.

The full three-stage pipeline from DESIGN.md §3:

  1. **SCEP stage** — the tweet stream is filtered/enriched by a semantic
     query (hierarchy reasoning against the KB): only tweets mentioning
     MusicalArtist subclasses pass.
  2. **LM operator** — matched events are routed to an LM serving operator
     (Aggregator = request batcher over slot lanes, engine = decode steps,
     Publisher = stamper): the LM "scores" each matched artist mention by
     generating a continuation from a prompt encoding of the event.
  3. **Publish** — scores are emitted back as RDF triples, ready to be
     consumed by any downstream SCEP operator (§2: an output stream of one
     SCEP engine is an input of another).

    PYTHONPATH=src python examples/semantic_llm_pipeline.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.rdf import Vocab, to_host_rows
from repro.core.session import ExecutionConfig, Session
from repro.data.dbpedia import KBConfig, generate_kb
from repro.data.tweets import (
    TweetSchema, TweetStreamConfig, generate_tweets, stream_chunks,
)
from repro.models import lm
from repro.serve.engine import generate

ARTIST_FILTER_RQ = """
REGISTER QUERY artist_filter AS
PREFIX schema: <urn:dscep:schema>
PREFIX onyx: <urn:dscep:onyx>
PREFIX rdf: <http://www.w3.org/1999/02/22-rdf-syntax-ns#>
PREFIX rdfs: <http://www.w3.org/2000/01/rdf-schema#>
PREFIX dbo: <http://dbpedia.org/ontology/>
PREFIX out: <urn:dscep:out>
CONSTRUCT {
  ?tweet out:match ?artist .
  ?tweet out:pos ?pos .
}
FROM STREAM <stream> [RANGE TRIPLES 128 STEP 1]
FROM <kb>
WHERE {
  ?tweet schema:mentions ?artist .
  ?tweet onyx:positiveEmotion ?pos .
  GRAPH <kb> { ?artist rdf:type/rdfs:subClassOf* dbo:MusicalArtist . }
}
"""


def main():
    # ---- stage 1: semantic filter over the stream ---------------------------
    vocab = Vocab()
    kbd = generate_kb(vocab, KBConfig(num_artists=24, num_shows=8,
                                      filler_triples=200))
    tweets = TweetSchema.create(vocab)
    rows = generate_tweets(vocab, tweets, kbd.artist_ids,
                           TweetStreamConfig(num_tweets=24))
    sess = Session(ExecutionConfig(mode="single_program", window_capacity=128,
                                   max_windows=4),
                   vocab=vocab, kb=kbd.kb)
    reg = sess.register(ARTIST_FILTER_RQ)
    matched = []
    for out in reg.stream(list(stream_chunks(rows, 256))):
        matched += [r for r in to_host_rows(out)
                    if r[1] == vocab.pred("out:match")]
    print(f"[scep] {len(matched)} (tweet, artist) events matched the "
          f"semantic filter")
    assert matched

    # ---- stage 2: LM scoring operator ---------------------------------------
    cfg = smoke_variant(get_config("qwen2-1.5b"))
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)

    # encode each matched event as a short token prompt (ids folded into the
    # LM vocab) — stand-in for a learned template/tokenizer frontend
    def event_prompt(tweet_id, artist_id):
        base = np.asarray([tweet_id, artist_id, tweet_id ^ artist_id],
                          np.int64)
        return (base % cfg.vocab_size).astype(np.int32)

    prompts = np.stack([event_prompt(r[0], r[2]) for r in matched[:8]])
    gen = generate(params, cfg, jnp.asarray(prompts), max_new=4)
    # score = first generated token id, normalized (toy "sentiment head")
    scores = np.asarray(gen[:, 0]) % 1000

    # ---- stage 3: publish scores as an RDF stream ---------------------------
    score_pred = vocab.pred("out:lmScore")
    published = [
        (int(matched[i][0]), score_pred, Vocab.number(float(scores[i]) / 100))
        for i in range(len(scores))
    ]
    print(f"[llm]  scored {len(published)} events with the "
          f"{cfg.name} backbone; sample:")
    for s, p, o in published[:3]:
        print(f"       ({s}, out:lmScore, {o})")
    print("pipeline OK: stream -> semantic filter (KB reasoning) -> "
          "LM operator -> published RDF scores")


if __name__ == "__main__":
    main()
