"""Run the shipped ``.rq`` query files through every execution mode.

The end-to-end proof of the declarative frontend + unified Session API:

1. parse each ``examples/queries/*.rq`` file (the paper's Q15/Q16/CQuery1
   as C-SPARQL text),
2. execute it under all three ``ExecutionConfig`` modes — ``monolithic``,
   ``single_program`` and ``pipelined`` — through the one Session code path,
3. assert the output streams are **bit-identical** across modes (the paper's
   "All results are the same", now a switchable deployment knob).

    PYTHONPATH=src python examples/rq_session.py            # full stream
    PYTHONPATH=src python examples/rq_session.py --smoke    # CI: one chunk
"""
import argparse
import glob
import os

import numpy as np

from repro.core.rdf import Vocab, to_host_rows
from repro.core.session import ExecutionConfig, MODES, Session
from repro.core.sparql import parse_query, serialize_query
from repro.data.dbpedia import KBConfig, generate_kb
from repro.data.tweets import (
    TweetSchema, TweetStreamConfig, generate_tweets, stream_chunks,
)

QUERY_DIR = os.path.join(os.path.dirname(__file__), "queries")


def build_world(smoke: bool):
    vocab = Vocab()
    kbd = generate_kb(vocab, KBConfig(
        num_artists=16 if smoke else 48,
        num_shows=8 if smoke else 24,
        filler_triples=50 if smoke else 500))
    tweets = TweetSchema.create(vocab)
    pool = np.concatenate([kbd.artist_ids, kbd.show_ids])
    rows = generate_tweets(vocab, tweets, pool, TweetStreamConfig(
        num_tweets=24 if smoke else 96, mentions_min=2, mentions_max=3))
    chunks = list(stream_chunks(rows, 192))
    return vocab, kbd, chunks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + first chunk only (CI mode)")
    args = ap.parse_args(argv)

    vocab, kbd, chunks = build_world(args.smoke)
    if args.smoke:
        chunks = chunks[:1]
    base = ExecutionConfig(
        window_capacity=96, max_windows=4, bind_cap=1024, scan_cap=256,
        out_cap=1024, intermediate_cap=512)

    rq_files = sorted(glob.glob(os.path.join(QUERY_DIR, "*.rq")))
    assert rq_files, "no .rq files shipped under %s" % QUERY_DIR

    for path in rq_files:
        text = open(path).read()
        # round-trip sanity: canonical serialization re-parses to the same AST
        q = parse_query(text, vocab)
        assert parse_query(serialize_query(q, vocab), vocab) == q

        outs = {}
        for mode in MODES:
            sess = Session(base.replace(mode=mode), vocab=vocab, kb=kbd.kb)
            reg = sess.register(text)
            outs[mode], overflow = reg.run(chunks)
            clipped = {k: v for k, v in overflow.items() if v}
            assert not clipped, (q.name, mode, clipped)

        ref = outs[MODES[0]]
        for mode in MODES[1:]:
            for i, (a, b) in enumerate(zip(ref, outs[mode])):
                for col, ca, cb in zip(a._fields, a, b):
                    assert bool(np.all(np.asarray(ca) == np.asarray(cb))), (
                        "%s: %s diverges from %s at chunk %d column %s"
                        % (q.name, mode, MODES[0], i, col))
        n_out = sum(len(to_host_rows(o)) for o in ref)
        print(f"{os.path.basename(path):14s} {q.name:10s} "
              f"{len(chunks)} chunk(s) -> {n_out:4d} triples, "
              f"bit-identical across {'/'.join(MODES)}")
    print("all shipped .rq queries agree across every execution mode")


if __name__ == "__main__":
    main()
