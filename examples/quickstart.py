"""Quickstart: the DSCEP public API in ~50 lines.

Builds a tiny tweet stream + knowledge base, states a *semantic* continuous
query as C-SPARQL text (hierarchy reasoning against the KB), and lets the
Session facade do the rest: parse -> decompose into SCEP operators with
pruned used-KB slices -> execute in the configured mode.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.rdf import Vocab, to_host_rows
from repro.core.session import ExecutionConfig, Session
from repro.data.dbpedia import KBConfig, generate_kb
from repro.data.tweets import (
    TweetSchema, TweetStreamConfig, generate_tweets, stream_chunks,
)

# the continuous query: tweets mentioning any MusicalArtist subclass
# (rdfs:subClassOf* reasoning over the KB — a SCEP query, not plain CEP)
ARTIST_MENTIONS_RQ = """
REGISTER QUERY artist_mentions AS
PREFIX schema: <urn:dscep:schema>
PREFIX rdf: <http://www.w3.org/1999/02/22-rdf-syntax-ns#>
PREFIX rdfs: <http://www.w3.org/2000/01/rdf-schema#>
PREFIX dbo: <http://dbpedia.org/ontology/>
PREFIX out: <urn:dscep:out>
CONSTRUCT { ?tweet out:artistTweet ?ent . }
FROM STREAM <stream> [RANGE TRIPLES 128 STEP 1]
FROM <kb>
WHERE {
  ?tweet schema:mentions ?ent .
  GRAPH <kb> { ?ent rdf:type/rdfs:subClassOf* dbo:MusicalArtist . }
}
"""


def main():
    # 1. a shared vocabulary: every URI / literal becomes a dense uint32 id
    vocab = Vocab()

    # 2. background knowledge (DBpedia-like): class hierarchy, types, paths
    kbd = generate_kb(vocab, KBConfig(num_artists=24, num_shows=8,
                                      filler_triples=200))

    # 3. an RDF stream (TweetsKB-like): each tweet is one RDF-graph event
    tweets = TweetSchema.create(vocab)
    rows = generate_tweets(vocab, tweets, kbd.artist_ids,
                           TweetStreamConfig(num_tweets=32))
    chunks = list(stream_chunks(rows, 256))

    # 4. one Session = one ExecutionConfig over any execution mode
    #    ("single_program" decomposes into the SCEP operator DAG; swap to
    #    "monolithic" or "pipelined" without touching anything else)
    sess = Session(ExecutionConfig(mode="single_program", window_capacity=128,
                                   max_windows=4),
                   vocab=vocab, kb=kbd.kb)
    reg = sess.register(ARTIST_MENTIONS_RQ)

    # 5. each KB operator received only its used-KB slice (the paper's core
    #    technique); inspect the decomposition
    for name, op in reg.operators.items():
        used = "--" if op.kb is None else int(np.asarray(op.kb.count()))
        print(f"operator {name:28s} used-KB: {used} "
              f"(full KB: {int(np.asarray(kbd.kb.count()))})")

    # 6. stream the chunks through
    total = sum(len(to_host_rows(out)) for out in reg.stream(chunks))
    print(f"matched {total} (tweet, out:artistTweet, artist) triples")
    assert total > 0


if __name__ == "__main__":
    main()
