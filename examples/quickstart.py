"""Quickstart: the DSCEP public API in ~60 lines.

Builds a tiny tweet stream + knowledge base, declares a semantic continuous
query (hierarchy reasoning against the KB), lets the planner decompose it
into SCEP operators with pruned used-KB slices, and streams data through.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import query as Q
from repro.core.planner import decompose
from repro.core.rdf import Vocab, to_host_rows
from repro.core.runtime import DSCEPRuntime, RuntimeConfig
from repro.data.dbpedia import KBConfig, generate_kb
from repro.data.tweets import (
    TweetSchema, TweetStreamConfig, generate_tweets, stream_chunks,
)


def main():
    # 1. a shared vocabulary: every URI / literal becomes a dense uint32 id
    vocab = Vocab()

    # 2. background knowledge (DBpedia-like): class hierarchy, types, paths
    kbd = generate_kb(vocab, KBConfig(num_artists=24, num_shows=8,
                                      filler_triples=200))

    # 3. an RDF stream (TweetsKB-like): each tweet is one RDF-graph event
    tweets = TweetSchema.create(vocab)
    rows = generate_tweets(vocab, tweets, kbd.artist_ids,
                           TweetStreamConfig(num_tweets=32))
    chunks = list(stream_chunks(rows, 256))

    # 4. a continuous query: tweets mentioning any MusicalArtist subclass
    #    (rdfs:subClassOf reasoning over the KB — a SCEP query, not plain CEP)
    q = Q.Query(
        name="artist_mentions",
        where=(
            Q.Pattern(Q.Var("tweet"), Q.Const(tweets.mentions),
                      Q.Var("ent"), Q.STREAM),
            Q.FilterSubclass("ent", kbd.schema.rdf_type,
                             kbd.schema.subclass_of,
                             kbd.schema.musical_artist),
        ),
        construct=(
            Q.ConstructTemplate(Q.Var("tweet"),
                                Q.Const(vocab.pred("out:artistTweet")),
                                Q.Var("ent")),
        ),
    )

    # 5. decompose into the SCEP operator DAG; each KB operator receives only
    #    its used-KB slice (the paper's core technique)
    dag = decompose(q, vocab)
    rt = DSCEPRuntime(dag, kbd.kb, vocab, RuntimeConfig(
        window_capacity=128, max_windows=4))
    for name, op in rt.operators.items():
        used = "--" if op.kb is None else int(np.asarray(op.kb.count()))
        print(f"operator {name:28s} used-KB: {used} "
              f"(full KB: {int(np.asarray(kbd.kb.count()))})")

    # 6. stream the chunks through
    total = 0
    for chunk in chunks:
        out, _ = rt.process_chunk(chunk)
        res = to_host_rows(out)
        total += len(res)
    print(f"matched {total} (tweet, out:artistTweet, artist) triples")
    assert total > 0


if __name__ == "__main__":
    main()
