"""The paper's S1 scenario: semantic traffic analysis (Introduction, §1).

Two heterogeneous streams — GPS readings from drivers' phones and a tweet
stream — are correlated with a static map KB (streets, districts, allowed
flow) to (a) infer which street each driver is on and flag slow traffic,
and (b) find candidate *explanations* for the slowdown from tweets that
mention entities located on the same street.  This is exactly the paper's
motivating use case: the query is impossible without background knowledge
(street topology), and DSCEP decomposes it into KB-operators + aggregator.

    PYTHONPATH=src python examples/traffic_scep.py
"""
import numpy as np

from repro.core.rdf import Vocab, to_host_rows
from repro.core.session import ExecutionConfig, Session
from repro.core.kb import kb_from_triples
from repro.data.tweets import stream_chunks

# continuous query: slow drivers -> street (KB) -> co-located tweet venues.
# Length-1 KB paths are written parenthesized — ``(map:onStreet)`` — the
# text form of a PathKB hop; the OPTIONAL mixes a stream pattern with a KB
# pattern (slow traffic is reported whether or not anyone tweeted about it).
SLOW_TRAFFIC_RQ = """
REGISTER QUERY slow_traffic_explained AS
PREFIX gps: <urn:dscep:gps>
PREFIX map: <urn:dscep:map>
PREFIX schema: <urn:dscep:schema>
PREFIX out: <urn:dscep:out>
CONSTRUCT {
  ?street out:slowTraffic ?v .
  ?street out:possibleCause ?tweet .
}
FROM STREAM <stream> [RANGE TRIPLES 256 STEP 1]
FROM <kb>
WHERE {
  ?reading gps:atCell ?cell .
  ?reading gps:speed ?v .
  FILTER(?v < 20.00)
  GRAPH <kb> {
    ?cell (map:onStreet) ?street .
    ?street (map:locatedIn) ?district .
  }
  OPTIONAL {
    ?tweet schema:mentions ?venue .
    GRAPH <kb> { ?venue map:onStreet ?street . }
  }
}
"""


def build_map_kb(vocab, n_streets=24, n_districts=4, seed=0):
    """Static map: cell -> street -> district -> region + venue locations."""
    rng = np.random.default_rng(seed)
    located_in = vocab.pred("map:locatedIn")
    on_street = vocab.pred("map:onStreet")
    rdf_type = vocab.pred("rdf:type")
    venue_cls = vocab.term("class:Venue")
    region = vocab.term("region:metro")
    rows = []
    districts = [vocab.term("district:%d" % i) for i in range(n_districts)]
    for d in districts:
        rows.append((d, located_in, region))
    streets, cells, venues = [], {}, []
    for i in range(n_streets):
        s = vocab.term("street:%d" % i)
        streets.append(s)
        rows.append((s, located_in, int(rng.choice(districts))))
        # each street covered by GPS grid cells
        for j in range(3):
            c = vocab.term("cell:%d:%d" % (i, j))
            cells.setdefault(s, []).append(c)
            rows.append((c, on_street, s))
        # venues on the street (tweets mention these)
        v = vocab.term("venue:%d" % i)
        venues.append(v)
        rows.append((v, rdf_type, venue_cls))
        rows.append((v, on_street, s))
    schema = dict(located_in=located_in, on_street=on_street,
                  rdf_type=rdf_type, venue_cls=venue_cls)
    return kb_from_triples(rows), schema, streets, cells, venues


def build_streams(vocab, streets, cells, venues, n_events=64, seed=0):
    """GPS stream (driver, atCell, speed) + tweet stream (tweet mentions venue)."""
    rng = np.random.default_rng(seed)
    at_cell = vocab.pred("gps:atCell")
    speed = vocab.pred("gps:speed")
    mentions = vocab.pred("schema:mentions")
    rows = []
    slow_streets = set(int(s) for s in rng.choice(streets, size=4, replace=False))
    observed_slow = set()
    for i in range(n_events):
        ts, graph = 1000 + i, i + 1
        # one RDF-graph event per GPS reading: the reading node ties the cell
        # and the speed of the SAME observation together (a driver appears in
        # many readings; joining on the driver would mix observations)
        reading = vocab.term("reading:%d" % i)
        street = int(rng.choice(streets))
        cell = int(rng.choice([int(c) for c in cells[street]]))
        # slow streets produce slow speeds
        v = rng.uniform(2, 15) if street in slow_streets else rng.uniform(35, 90)
        if v < 20.0:
            observed_slow.add(street)     # ground truth = what the stream saw
        rows.append((reading, at_cell, cell, ts, graph))
        rows.append((reading, speed, Vocab.number(float(v)), ts, graph))
        # tweets sometimes mention a venue (possible explanation)
        if rng.random() < 0.5:
            tweet = vocab.term("tweet:%d" % i)
            venue = int(rng.choice(venues))
            rows.append((tweet, mentions, venue, ts, i + 1000))
    return rows, dict(at_cell=at_cell, speed=speed, mentions=mentions), observed_slow


def main():
    vocab = Vocab()
    kb, ks, streets, cells, venues = build_map_kb(vocab)
    rows, ss, slow_truth = build_streams(vocab, streets, cells, venues)
    chunks = list(stream_chunks(rows, 512))

    cfg = ExecutionConfig(window_capacity=256, max_windows=4, bind_cap=2048,
                          scan_cap=512, out_cap=2048)
    mono = Session(cfg.replace(mode="monolithic"), vocab=vocab,
                   kb=kb).register(SLOW_TRAFFIC_RQ)
    split = Session(cfg.replace(mode="single_program"), vocab=vocab,
                    kb=kb).register(SLOW_TRAFFIC_RQ)
    print(f"operators: {sorted(split.dag.subqueries)}")

    slow_pred = vocab.pred("out:slowTraffic")
    flagged, results_m, results_s = set(), [], []
    for chunk in chunks:
        rm = to_host_rows(mono.process_chunk(chunk)[0])
        rs = to_host_rows(split.process_chunk(chunk)[0])
        results_m += [(r[0], r[1], r[2]) for r in rm]
        results_s += [(r[0], r[1], r[2]) for r in rs]
        flagged |= {r[0] for r in rs if r[1] == slow_pred}

    assert sorted(set(results_m)) == sorted(set(results_s)), \
        "decomposed != monolithic"
    print(f"streets flagged slow: {len(flagged)} "
          f"(ground truth slow streets: {len(slow_truth)})")
    assert flagged == slow_truth, (flagged, slow_truth)
    causes = {r for r in set(results_s) if r[1] == vocab.pred('out:possibleCause')}
    print(f"candidate tweet explanations attached: {len(causes)}")
    print("S1 scenario OK: slow streets detected and explained via KB joins")


if __name__ == "__main__":
    main()
