"""Train a pool-architecture LM end to end with the production driver.

Runs the full fault-tolerant path: sharded train step, async atomic
checkpoints, a *simulated node failure* mid-run, and automatic restart from
the latest checkpoint.  The default is container-scale (a reduced Qwen2
config); on a pod the same driver trains the full config — only
``--smoke`` and the mesh change.

    PYTHONPATH=src python examples/train_lm.py
"""
import shutil
import tempfile

from repro.launch import train as train_driver


def main():
    ckpt_dir = tempfile.mkdtemp(prefix="repro_train_lm_")
    try:
        args = train_driver.main.__wrapped__ if hasattr(
            train_driver.main, "__wrapped__") else None
        # drive through the CLI surface so the example exercises exactly what
        # an operator would run
        argv = [
            "--arch", "qwen2-1.5b", "--smoke",
            "--steps", "14", "--batch", "8", "--seq", "64",
            "--ckpt-dir", ckpt_dir, "--ckpt-every", "4",
            "--log-every", "2",
            "--fail-at", "9",        # kill a "node" at step 9 ...
            "--retries", "1",        # ... and watch the relaunch resume
        ]
        train_driver.main(argv)
        print("train_lm example OK: loss decreased across a simulated "
              "failure + restart")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
