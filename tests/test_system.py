"""End-to-end system tests: the paper's CQuery1 pipeline (§4.3-4.4).

Covers the full DSCEP path — query decomposition into the Fig. 4 operator
DAG, used-KB pruning per operator, monolithic == decomposed result
equivalence under both KB-access methods, SPMD window sharding on a mesh,
and the straggler-balancing window packer.
"""
import jax
import numpy as np
import pytest

from repro.core import paper_queries as PQ
from repro.core.rdf import Vocab, to_host_rows
from repro.core.runtime import balance_windows
from repro.core.session import ExecutionConfig, Session
from repro.data.dbpedia import KBConfig, generate_kb
from repro.data.tweets import (
    TweetSchema, TweetStreamConfig, generate_tweets, stream_chunks,
)

CFG = ExecutionConfig(window_capacity=128, max_windows=4, bind_cap=1024,
                      scan_cap=128, out_cap=1024)


def register(world, q, cfg):
    return Session(cfg, vocab=world.vocab, kb=world.kbd.kb).register(q)


class CoWorld:
    """Stream whose tweets co-mention artists *and* shows (CQuery1's shape)."""

    def __init__(self, num_tweets=40, seed=0, filler=100):
        self.vocab = Vocab()
        self.kbd = generate_kb(
            self.vocab,
            KBConfig(num_artists=32, num_shows=16, filler_triples=filler,
                     seed=seed),
        )
        self.tweets = TweetSchema.create(self.vocab)
        pool = np.concatenate([self.kbd.artist_ids, self.kbd.show_ids])
        self.rows = generate_tweets(
            self.vocab, self.tweets, pool,
            TweetStreamConfig(num_tweets=num_tweets, mentions_min=2,
                              mentions_max=4, seed=seed),
        )
        self.chunks = list(stream_chunks(self.rows, 256))


@pytest.fixture(scope="module")
def co_world():
    return CoWorld()


def _results(out):
    return sorted(set((r[0], r[1], r[2]) for r in to_host_rows(out)))


def _run(rt, chunks):
    res = []
    for c in chunks:
        res += _results(rt.process_chunk(c)[0])
    return sorted(res)


# --------------------------------------------------------------------------
# CQuery1: the paper's central experiment
# --------------------------------------------------------------------------

def test_cquery1_dag_shape_matches_fig4(co_world):
    """Decomposition produces the Fig. 4 topology: artist-KB operator
    (QueryA), show-KB operator (QueryB), final aggregator (QueryG)."""
    q = PQ.cquery1(co_world.vocab, co_world.tweets, co_world.kbd.schema)
    dag = register(co_world, q, CFG).dag
    kb_ops = [n for n, s in dag.subqueries.items() if s.touches_kb]
    assert len(kb_ops) == 2
    final = dag.subqueries[dag.final]
    assert not final.touches_kb
    assert set(kb_ops) <= set(final.inputs)


def test_cquery1_mono_equals_split_scan(co_world):
    q = PQ.cquery1(co_world.vocab, co_world.tweets, co_world.kbd.schema)
    mono = register(co_world, q, CFG.replace(mode="monolithic"))
    split = register(co_world, q, CFG.replace(mode="single_program"))
    rm, rs = _run(mono, co_world.chunks), _run(split, co_world.chunks)
    assert len(rm) > 0
    assert rm == rs


def test_cquery1_mono_equals_split_probe(co_world):
    q = PQ.cquery1(co_world.vocab, co_world.tweets, co_world.kbd.schema)
    mono = register(co_world, q, CFG.replace(mode="monolithic",
                                             kb_method="probe"))
    split = register(co_world, q, CFG.replace(mode="single_program",
                                              kb_method="probe"))
    rm, rs = _run(mono, co_world.chunks), _run(split, co_world.chunks)
    assert len(rm) > 0
    assert rm == rs


def test_cquery1_used_kb_partition(co_world):
    """Every KB operator's slice is strictly smaller than the full KB; the
    artist slice (subclass closure + 3-step path) dominates the show slice
    (closure only) — the paper's QueryA-vs-QueryB asymmetry."""
    q = PQ.cquery1(co_world.vocab, co_world.tweets, co_world.kbd.schema)
    rt = register(co_world, q, CFG)
    total = int(np.asarray(co_world.kbd.kb.count()))
    used = {
        n: int(np.asarray(op.kb.count()))
        for n, op in rt.operators.items() if op.kb is not None
    }
    assert len(used) == 2
    assert all(0 < u < total for u in used.values())
    artist = next(v for k, v in used.items() if "artist" in k)
    show = next(v for k, v in used.items() if "show" in k)
    assert artist > show


def test_cquery1_output_schema(co_world):
    """Constructed triples use exactly the declared output predicates."""
    v = co_world.vocab
    expect = {
        v.pred("out:coMentionedWith"), v.pred("out:posSentiment"),
        v.pred("out:negSentiment"), v.pred("out:countryCode"),
    }
    q = PQ.cquery1(v, co_world.tweets, co_world.kbd.schema)
    mono = register(co_world, q, CFG.replace(mode="monolithic"))
    preds = {r[1] for r in _run(mono, co_world.chunks)}
    assert preds <= expect
    assert v.pred("out:coMentionedWith") in preds


def test_q15_q16_on_shared_world(co_world):
    """First-step queries run on the same world (Table 1 setup)."""
    for builder in (PQ.q15, PQ.q16):
        q = builder(co_world.vocab, co_world.tweets, co_world.kbd.schema)
        mono = register(co_world, q, CFG.replace(mode="monolithic"))
        split = register(co_world, q, CFG.replace(mode="single_program"))
        rm, rs = _run(mono, co_world.chunks), _run(split, co_world.chunks)
        assert len(rm) > 0 and rm == rs


# --------------------------------------------------------------------------
# distribution machinery
# --------------------------------------------------------------------------

def test_runtime_on_mesh_matches_unsharded(co_world):
    """Intra-operator SPMD (windows sharded over `data`) must not change
    results — sharding neutrality on whatever devices exist."""
    q = PQ.q15(co_world.vocab, co_world.tweets, co_world.kbd.schema)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    plain = register(co_world, q, CFG)
    meshed = register(co_world, q, CFG.replace(mesh=mesh))
    assert _run(plain, co_world.chunks) == _run(meshed, co_world.chunks)


def test_balance_windows_rounds_and_preserves(co_world):
    merged = co_world.chunks[0]
    for engines in (3, 4, 5):
        w = balance_windows(merged, engines, window_capacity=64, max_windows=6)
        assert w.window_valid.shape[0] % engines == 0
        # padding windows are invalid; no real window lost
        assert int(np.asarray(w.window_valid.sum())) > 0
        # every valid input triple still present across windows
        total_in = int(np.asarray(merged.valid.sum()))
        total_w = int(np.asarray(w.triples.valid.sum()))
        assert total_w == total_in


def test_monotone_timestamps_across_published_stream(co_world):
    """Publisher output is ordered (paper assumption 3 holds downstream)."""
    q = PQ.q15(co_world.vocab, co_world.tweets, co_world.kbd.schema)
    mono = register(co_world, q, CFG.replace(mode="monolithic"))
    for c in co_world.chunks:
        out, _ = mono.process_chunk(c)
        rows = to_host_rows(out)
        ts = [r[3] for r in rows]
        assert ts == sorted(ts)
