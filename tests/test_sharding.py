"""Sharding rules: divisibility-aware PartitionSpecs for every arch, batch
and cache shardings, and host-mesh neutrality of the sharded train step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ALL_ARCHS, get_config, smoke_variant
from repro.models import lm
from repro.sharding.partition import (
    batch_sharding, cache_shardings, dp_axes_for, param_shardings, spec_for,
)


def fake_mesh(shape, axes):
    """An abstract mesh over virtual devices — enough to build PartitionSpecs
    (tests never allocate on it)."""
    devs = np.asarray(jax.devices() * int(np.prod(shape)))[: int(np.prod(shape))]
    return Mesh(devs.reshape(shape), axes)


MESH = fake_mesh((16, 16), ("data", "model"))
POD_MESH = fake_mesh((2, 16, 16), ("pod", "data", "model"))


def test_spec_for_divisibility_fallback():
    from repro.models import common as C
    # 8 experts on a 16-way model axis: must NOT claim the axis
    assert spec_for((C.EXPERT, C.EMBED, C.FF), (8, 64, 256), MESH) == \
        P(None, None, "model")
    # 160 experts divide 16: claims it
    assert spec_for((C.EXPERT, C.EMBED, C.FF), (160, 64, 256), MESH) == \
        P("model", None, None)


def test_param_shardings_all_archs_cover_every_leaf():
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        import functools
        holder = {}

        def build(key):
            params, spec = lm.init_model(key, cfg)
            holder["spec"] = spec
            return params

        params = jax.eval_shape(build, jax.random.PRNGKey(0))
        sh = param_shardings(holder["spec"].axes, params, MESH)
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))
        assert len(flat_p) == len(flat_s)
        for p, s in zip(flat_p, flat_s):
            # every sharded dim divides
            for dim, name in zip(p.shape, tuple(s.spec) + (None,) * 8):
                if name is None:
                    continue
                names = name if isinstance(name, tuple) else (name,)
                size = int(np.prod([MESH.shape[n] for n in names]))
                assert dim % size == 0, (arch, p.shape, s.spec)


@pytest.mark.parametrize("arch", ["deepseek-v2-236b", "mixtral-8x22b"])
def test_expert_weights_sharded_on_model(arch):
    cfg = get_config(arch)
    holder = {}

    def build(key):
        params, spec = lm.init_model(key, cfg)
        holder["spec"] = spec
        return params

    params = jax.eval_shape(build, jax.random.PRNGKey(0))
    sh = param_shardings(holder["spec"].axes, params, MESH)
    flat, _ = jax.tree_util.tree_flatten_with_path(sh)
    moe_specs = [s.spec for kp, s in flat if "moe" in str(kp) and "wi" in str(kp)]
    assert moe_specs, "no MoE expert weights found"
    for spec in moe_specs:
        assert "model" in jax.tree.leaves(tuple(spec)), spec


def test_dp_axes_divisibility():
    assert dp_axes_for(MESH, 256) == ("data",)
    assert dp_axes_for(POD_MESH, 256) == ("pod", "data")
    assert dp_axes_for(POD_MESH, 2) == ("pod",)
    assert dp_axes_for(POD_MESH, 1) == ()
    assert dp_axes_for(MESH, 1) == ()


def test_batch_sharding_positions_batch_dim():
    s = batch_sharding(POD_MESH, (3, 256, 4096), batch_dim=1)
    assert s.spec == P(None, ("pod", "data"), None)
    s1 = batch_sharding(MESH, (1, 1))           # long_500k decode
    assert s1.spec == P(None, None)


def test_cache_shardings_long_context_seq_parallel():
    cfg = get_config("mixtral-8x22b")
    caches = jax.eval_shape(lambda: lm.init_cache(cfg, 1, 8192))
    sh = cache_shardings(cfg, caches, MESH)
    kv_specs = [
        s.spec for c, s in zip(jax.tree.leaves(caches),
                               jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))
        if c.ndim == 5
    ]
    assert kv_specs
    for spec in kv_specs:
        assert spec[2] == "data", spec     # sequence dim sharded (batch=1)
        assert spec[1] is None


def test_cache_shardings_batched_decode_data_parallel():
    cfg = get_config("qwen2-1.5b")
    caches = jax.eval_shape(lambda: lm.init_cache(cfg, 128, 1024))
    sh = cache_shardings(cfg, caches, MESH)
    for c, s in zip(jax.tree.leaves(caches),
                    jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))):
        if c.ndim >= 2:
            assert s.spec[1] == "data", (c.shape, s.spec)


def test_sharded_step_matches_unsharded_on_host_mesh():
    """Loss parity: jit with explicit shardings on the 1-device host mesh ==
    plain jit (sharding neutrality smoke)."""
    from repro.launch.mesh import make_host_mesh
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.train_loop import TrainConfig, make_train_step

    cfg = smoke_variant(get_config("olmo-1b"))
    tcfg = TrainConfig(opt=AdamWConfig(peak_lr=1e-3, warmup_steps=2,
                                       total_steps=10))
    params, spec = lm.init_model(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    batch = {
        "tokens": jnp.zeros((4, 16), jnp.int32),
        "labels": jnp.ones((4, 16), jnp.int32),
    }
    plain = jax.jit(make_train_step(cfg, tcfg))(params, opt, batch)

    mesh = make_host_mesh()
    p_sh = param_shardings(spec.axes, params, mesh)
    with mesh:
        sharded = jax.jit(
            make_train_step(cfg, tcfg), in_shardings=(p_sh, None, None)
        )(params, opt, batch)
    np.testing.assert_allclose(float(plain[2]["loss"]),
                               float(sharded[2]["loss"]), rtol=1e-5)
