import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.rdf import (
    NUM_BASE, PAD_ID, PRED_SPACE, TERM_BITS, TripleBatch, Vocab,
    composite_key, concat_triples, make_triples, sort_by_timestamp,
    take_rows, to_host_rows,
)


def test_vocab_spaces():
    v = Vocab()
    p = v.pred("rdf:type")
    t = v.term("dbo:Artist")
    assert 1 <= p < PRED_SPACE
    assert t >= PRED_SPACE
    assert v.pred("rdf:type") == p            # interning is stable
    assert v.term("dbo:Artist") == t
    assert v.to_str(p) == "rdf:type"
    assert v.to_str(t) == "dbo:Artist"


def test_numeric_literals_roundtrip_and_order():
    a = Vocab.number(1.25)
    b = Vocab.number(4.75)
    assert a >= int(NUM_BASE) and b >= int(NUM_BASE)
    assert a < b                                # order-isomorphic encoding
    assert Vocab.decode_number(a) == pytest.approx(1.25)
    assert Vocab.decode_number(b) == pytest.approx(4.75)


def test_composite_key_disjoint():
    v = Vocab()
    p1, p2 = v.pred("p1"), v.pred("p2")
    t1, t2 = v.term("t1"), v.term("t2")
    keys = {
        int(composite_key(p, t)) for p in (p1, p2) for t in (t1, t2)
    }
    assert len(keys) == 4                       # no collisions across (p, t)


def test_make_sort_take():
    rows = [(5, 1, 6, 30, 3), (7, 1, 8, 10, 1), (9, 2, 10, 20, 2)]
    tb = make_triples(rows, capacity=6)
    assert int(tb.count()) == 3
    s = sort_by_timestamp(tb)
    ts_valid = np.asarray(s.ts)[np.asarray(s.valid)]
    assert list(ts_valid) == [10, 20, 30]
    # invalid rows at the tail
    assert not np.asarray(s.valid)[3:].any()
    taken = take_rows(tb, jnp.asarray([1, -1, 0]))
    assert list(np.asarray(taken.valid)) == [True, False, True]
    assert int(taken.s[0]) == 7 and int(taken.s[2]) == 5


def test_concat_and_host_rows():
    a = make_triples([(1, 1, 2, 0, 1)], capacity=2)
    b = make_triples([(3, 1, 4, 1, 2)], capacity=2)
    c = concat_triples([a, b])
    assert c.capacity == 4
    assert len(to_host_rows(c)) == 2
