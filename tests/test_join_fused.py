"""Fused join->compaction pipeline: bit-exact parity with the unfused path.

Covers the acceptance matrix for the fused Pallas kernel
(:func:`repro.kernels.hash_join.kernel.join_compact_pallas`) and the fused
jnp gather path, against the materialize-and-compact oracle
(:func:`repro.kernels.hash_join.ref.join_compact_ref`):

* edge shapes — empty window, all-match, overflow exactly at ``out_cap``,
  M/N not multiples of the block shapes;
* every pattern slot-mode combination the engine emits;
* the engine integration (``kb_join_scan`` fused == unfused, and the
  vmapped ``DSCEPRuntime`` end-to-end with ``fuse_compaction=True``);
* the fused closure-descendants kernel vs its oracle.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import algebra
from repro.core.kb import kb_from_triples
from repro.core.pattern import Bindings, CompiledPattern, Slot
from repro.kernels.hash_join import ops as hj_ops
from repro.kernels.hash_join.ref import join_compact_ref
from repro.kernels.closure import ops as cl_ops
from repro.kernels.closure.ref import descendants_ref


def _world(m=32, n=128, nv=3, seed=0, spread=30, kb_rows=None):
    rng = np.random.default_rng(seed)
    base = 5000
    cols = rng.integers(base, base + spread, size=(m, nv)).astype(np.uint32)
    bvalid = rng.random(m) < 0.9
    if kb_rows is None:
        kb_rows = [
            (int(rng.integers(base, base + spread)), int(rng.integers(1, 4)),
             int(rng.integers(base, base + spread)))
            for _ in range(max(0, n - 4))
        ]
    kb = kb_from_triples(kb_rows, capacity=n)
    bind = Bindings(jnp.asarray(cols), jnp.asarray(bvalid), jnp.zeros((), bool))
    return bind, kb


def _assert_fused_matches_oracle(bind, kb, pat, out_cap, bm=None, bn=None):
    rows, valid, ovf = join_compact_ref(
        bind.cols, bind.valid, kb.s_ps, kb.p_ps, kb.o_ps, kb.valid, pat,
        out_cap,
    )
    for got in (
        hj_ops.join_compact(bind, kb, pat, out_cap, bm=bm, bn=bn),
        hj_ops.join_compact_jnp(bind, kb, pat, out_cap),
    ):
        np.testing.assert_array_equal(np.asarray(got.cols), np.asarray(rows))
        np.testing.assert_array_equal(np.asarray(got.valid), np.asarray(valid))
        assert bool(got.overflow) == bool(ovf)


PATTERNS = {
    "bound_const_free": CompiledPattern(Slot.bound(0), Slot.const_(2), Slot.free(1)),
    "free_const_bound": CompiledPattern(Slot.free(0), Slot.const_(1), Slot.bound(1)),
    "const_bound_free": CompiledPattern(Slot.const_(5003), Slot.bound(0), Slot.free(1)),
    "free_const_free": CompiledPattern(Slot.free(0), Slot.const_(1), Slot.free(1)),
    "repeated_free": CompiledPattern(Slot.free(0), Slot.const_(1), Slot.free(0)),
}


@pytest.mark.parametrize("pat_kind", sorted(PATTERNS))
@pytest.mark.parametrize("m,n", [(16, 64), (64, 256), (128, 512)])
def test_fused_matches_oracle(m, n, pat_kind):
    bind, kb = _world(m=m, n=n, seed=m + n)
    _assert_fused_matches_oracle(bind, kb, PATTERNS[pat_kind], out_cap=128)


def test_fused_empty_window():
    """No valid binding rows: zero matches, no overflow, all-zero output."""
    bind, kb = _world(m=16, n=64, seed=1)
    bind = bind._replace(valid=jnp.zeros_like(bind.valid))
    pat = PATTERNS["bound_const_free"]
    _assert_fused_matches_oracle(bind, kb, pat, out_cap=32)
    got = hj_ops.join_compact(bind, kb, pat, 32)
    assert int(np.asarray(got.count())) == 0 and not bool(got.overflow)


def test_fused_all_match_overflow():
    """Every (row, kb-row) pair matches: the compactor clips at out_cap."""
    rows = [(7000, 1, 7000 + i) for i in range(32)]
    bind, kb = _world(m=16, n=32, seed=2, kb_rows=rows)
    bind = bind._replace(
        cols=jnp.full_like(bind.cols, 7000), valid=jnp.ones_like(bind.valid)
    )
    pat = CompiledPattern(Slot.bound(0), Slot.const_(1), Slot.free(1))
    _assert_fused_matches_oracle(bind, kb, pat, out_cap=64)   # 16*32 >> 64
    got = hj_ops.join_compact(bind, kb, pat, 64)
    assert bool(got.overflow) and int(np.asarray(got.count())) == 64


def test_fused_overflow_exactly_at_capacity():
    """total == out_cap must NOT flag overflow; out_cap - 1 must."""
    rows = [(7000, 1, 7100 + i) for i in range(10)]
    bind, kb = _world(m=1, n=16, seed=3, nv=2, kb_rows=rows)
    bind = bind._replace(
        cols=jnp.full_like(bind.cols, 7000), valid=jnp.ones_like(bind.valid)
    )
    pat = CompiledPattern(Slot.bound(0), Slot.const_(1), Slot.free(1))
    exact = hj_ops.join_compact(bind, kb, pat, out_cap=10)
    assert int(np.asarray(exact.count())) == 10 and not bool(exact.overflow)
    clipped = hj_ops.join_compact(bind, kb, pat, out_cap=9)
    assert int(np.asarray(clipped.count())) == 9 and bool(clipped.overflow)
    _assert_fused_matches_oracle(bind, kb, pat, out_cap=10)
    _assert_fused_matches_oracle(bind, kb, pat, out_cap=9)


@pytest.mark.parametrize("m,n,bm,bn", [
    (50, 300, 16, 128),     # both padded
    (33, 129, 32, 128),     # barely over one block
    (8, 128, 128, 1024),    # blocks larger than the data
])
def test_fused_non_multiple_block_shapes(m, n, bm, bn):
    bind, kb = _world(m=m, n=n, seed=m * n)
    _assert_fused_matches_oracle(
        bind, kb, PATTERNS["bound_const_free"], out_cap=64, bm=bm, bn=bn
    )


def test_autotune_block_shapes_are_legal():
    for m, n, nv in [(1, 1, 2), (256, 8192, 4), (33, 100, 8), (512, 100000, 3)]:
        bm, bn = hj_ops.autotune_block_shapes(m, n, nv)
        assert bm % 8 == 0 and bn % 128 == 0 and bm >= 8 and bn >= 128
        # a scatter tile must fit the VMEM budget it was tuned for
        assert 4 * bm * bn * (nv + 2) <= 4 * 1024 * 1024 or bm == 8


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 500), out_cap=st.sampled_from([8, 64, 200]))
def test_fused_property_random(seed, out_cap):
    bind, kb = _world(m=24, n=96, seed=seed, spread=12)
    _assert_fused_matches_oracle(bind, kb, PATTERNS["bound_const_free"],
                                 out_cap=out_cap)


# --------------------------------------------------------------------------
# engine integration
# --------------------------------------------------------------------------

def test_kb_join_scan_fused_equals_unfused():
    bind, kb = _world(m=16, n=64, seed=5)
    pat = PATTERNS["bound_const_free"]
    want = algebra.kb_join_scan(bind, kb, pat, out_cap=128)
    for kwargs in (
        {"fuse_compaction": True},
        {"fuse_compaction": True, "use_pallas": True},
        {"use_pallas": True},
    ):
        got = algebra.kb_join_scan(bind, kb, pat, out_cap=128, **kwargs)
        np.testing.assert_array_equal(np.asarray(got.cols), np.asarray(want.cols))
        np.testing.assert_array_equal(np.asarray(got.valid), np.asarray(want.valid))
        assert bool(got.overflow) == bool(want.overflow)


def test_runtime_fused_end_to_end(world):
    """Decomposed execution produces identical streams fused/unfused."""
    from repro.core import query as Q
    from repro.core.rdf import to_host_rows
    from repro.core.session import ExecutionConfig, Session

    ts, kbd, vocab = world.tweets, world.kbd, world.vocab
    q = Q.Query(
        name="fused_e2e",
        where=(
            Q.Pattern(Q.Var("tweet"), Q.Const(ts.mentions), Q.Var("ent"),
                      Q.STREAM),
            Q.FilterSubclass("ent", kbd.schema.rdf_type,
                             kbd.schema.subclass_of,
                             kbd.schema.musical_artist),
        ),
        construct=(
            Q.ConstructTemplate(Q.Var("tweet"),
                                Q.Const(vocab.pred("out:artistTweet")),
                                Q.Var("ent")),
        ),
    )
    outs = {}
    for fused in (False, True):
        cfg = ExecutionConfig(window_capacity=128, max_windows=4,
                              fuse_compaction=fused)
        reg = Session(cfg, vocab=vocab, kb=kbd.kb).register(q)
        outs[fused] = [
            sorted((r[0], r[1], r[2]) for r in to_host_rows(out))
            for out in reg.run(world.chunks)[0]
        ]
    assert outs[True] == outs[False]


# --------------------------------------------------------------------------
# fused closure descendants
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n,root,out_cap", [
    (10, 0, 16), (64, 3, 32), (130, 7, 64), (256, 0, 300),
])
def test_closure_descendants_matches_ref(n, root, out_cap):
    rng = np.random.default_rng(n + root)
    adj = (rng.random((n, n)) < 0.05).astype(np.float32)
    steps = max(1, int(np.ceil(np.log2(max(2, n)))))
    ids, count = cl_ops.closure_descendants(
        jnp.asarray(adj), root=root, out_cap=out_cap, max_depth=n
    )
    want_ids, want_count = descendants_ref(jnp.asarray(adj), root, steps,
                                           out_cap)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(want_ids))
    assert int(count) == int(want_count)


def test_closure_descendants_overflow_and_chain():
    n = 12
    adj = np.zeros((n, n), np.float32)
    for i in range(n - 1):
        adj[i, i + 1] = 1.0                     # chain: all reach the last
    ids, count = cl_ops.closure_descendants(
        jnp.asarray(adj), root=n - 1, out_cap=4, max_depth=n
    )
    assert int(count) == n and bool(int(count) > 4)
    np.testing.assert_array_equal(np.asarray(ids), np.arange(4))


# --------------------------------------------------------------------------
# closure-pair materialization (PathClosure lowering) edge cases:
# empty KB, root without an edge, cycles — Pallas path vs host BFS path
# --------------------------------------------------------------------------

from repro.core import query as Q
from repro.core.kb import host_rows
from repro.core.planner import augment_kb_with_closures, closure_path_specs
from repro.core.rdf import CLOSURE_PRED_BASE


def _closure_query(pred, start, end, min_hops):
    return Q.Query(
        name="cq", where=(
            Q.Pattern(Q.Var("t"), Q.Const(1), Q.Var("e"), Q.STREAM),
            Q.PathClosure(start, pred, end, min_hops=min_hops),
        ),
        construct=(Q.ConstructTemplate(Q.Var("t"), Q.Const(2), Q.Var("e")),),
    )


def _pair_rows(kb, q):
    cp = CLOSURE_PRED_BASE + 0
    assert closure_path_specs(q), "query must carry a closure path"
    return sorted(
        (int(s), int(o)) for s, p, o in host_rows(kb) if int(p) == cp
    )


@pytest.mark.parametrize("min_hops,endpoint", [
    (0, "var"), (1, "var"), (0, "const"), (1, "const"),
])
def test_closure_pairs_pallas_matches_host(min_hops, endpoint):
    sub = 7
    C = list(range(9000, 9006))
    rows = [
        (C[1], sub, C[0]), (C[2], sub, C[0]), (C[3], sub, C[1]),
        (C[3], sub, C[2]),                       # diamond
        (C[4], sub, C[5]), (C[5], sub, C[4]),    # detached 2-cycle
    ]
    kb = kb_from_triples(rows)
    end = Q.Const(C[0]) if endpoint == "const" else Q.Var("y")
    q = _closure_query(sub, Q.Var("x"), end, min_hops)
    pal = _pair_rows(augment_kb_with_closures(q, kb, use_pallas=True), q)
    host = _pair_rows(augment_kb_with_closures(q, kb, use_pallas=False), q)
    assert pal == host and pal
    if endpoint == "const":
        # descendants of the diamond root: {C0..C3} (*) / {C1..C3} (+)
        want = {(c, C[0]) for c in C[:4]} if min_hops == 0 else {
            (c, C[0]) for c in C[1:4]}
        assert {p for p in pal if p[1] == C[0]} == want
    if min_hops == 0:
        assert all((x, x) in pal for x, _ in pal)   # star is reflexive


def test_closure_pairs_cycle_plus_is_reflexive_on_cycle():
    """In a cycle every node reaches itself in >= 1 hops: p+ must contain
    the diagonal for cycle members (unlike a DAG, where it must not)."""
    sub = 7
    a, b, c, d = 9100, 9101, 9102, 9103
    kb = kb_from_triples([(a, sub, b), (b, sub, a), (c, sub, d)])
    q = _closure_query(sub, Q.Var("x"), Q.Var("y"), 1)
    for use_pallas in (True, False):
        pairs = set(_pair_rows(
            augment_kb_with_closures(q, kb, use_pallas=use_pallas), q))
        assert {(a, a), (b, b), (a, b), (b, a), (c, d)} <= pairs
        assert (d, d) not in pairs and (c, c) not in pairs


def test_closure_pairs_empty_kb_and_rootless_star():
    """No edges at all: p+ is empty; p* toward a constant endpoint still
    contains that endpoint's reflexive pair (zero-length path)."""
    kb = kb_from_triples([(9200, 3, 9201)])      # KB without the path pred
    root = 9300
    for use_pallas in (True, False):
        q_plus = _closure_query(7, Q.Var("x"), Q.Const(root), 1)
        assert _pair_rows(
            augment_kb_with_closures(q_plus, kb, use_pallas=use_pallas),
            q_plus) == []
        q_star = _closure_query(7, Q.Var("x"), Q.Const(root), 0)
        assert _pair_rows(
            augment_kb_with_closures(q_star, kb, use_pallas=use_pallas),
            q_star) == [(root, root)]


def test_closure_pairs_root_not_in_edge_graph():
    """Edges exist but none touches the constant root: its p* set is just
    itself, its p+ set empty — for the kernel path and the host path."""
    sub = 7
    kb = kb_from_triples([(9400, sub, 9401)])
    lone = 9500
    for use_pallas in (True, False):
        q_star = _closure_query(sub, Q.Var("x"), Q.Const(lone), 0)
        pairs = _pair_rows(
            augment_kb_with_closures(q_star, kb, use_pallas=use_pallas),
            q_star)
        assert (lone, lone) in pairs
        assert all(y != lone or x == lone for x, y in pairs)
        q_plus = _closure_query(sub, Q.Var("x"), Q.Const(lone), 1)
        plus = _pair_rows(
            augment_kb_with_closures(q_plus, kb, use_pallas=use_pallas),
            q_plus)
        assert all(y != lone for x, y in plus)


def test_closure_pairs_both_endpoints_constant():
    """`C3 sub* C0 .` / `C3 sub+ C0 .` — a degenerate static check: the
    relation must contain exactly the anchored pair when the path holds
    (regression: the both-const case must anchor descendants on the end,
    not ancestors on the start)."""
    sub = 7
    C = list(range(9600, 9604))
    kb = kb_from_triples([(C[1], sub, C[0]), (C[2], sub, C[1]),
                          (C[3], sub, C[2])])
    for min_hops in (0, 1):
        q = _closure_query(sub, Q.Const(C[3]), Q.Const(C[0]), min_hops)
        for use_pallas in (True, False):
            pairs = set(_pair_rows(
                augment_kb_with_closures(q, kb, use_pallas=use_pallas), q))
            assert (C[3], C[0]) in pairs, (min_hops, use_pallas)
            # and the reverse direction must NOT hold
            assert (C[0], C[3]) not in pairs
