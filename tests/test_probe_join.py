"""Cost-based KB access: probe-path coverage + fused probe kernel parity.

The acceptance matrix for the ``kb_method="auto"`` work:

* bit-exact parity of the three probe implementations (unfused jnp, fused
  winner-gather twin, fused Pallas kernel in interpret mode) against the
  materialize-and-compact oracle across every anchored slot-mode shape;
* ``k_max`` overflow propagation (probe ranges wider than ``k_max`` flag
  the result), empty KB, duplicate keys spanning one probe range, and the
  composite-key collision re-check (hashed numeric anchors);
* the planner's cost model: per-join method selection, derived ``k_max``,
  greedy selectivity ordering, and scan-vs-probe-vs-auto bit-identity of a
  full Session run;
* Pallas ``interpret=True`` vs ``False`` parity (try/skip on CPU hosts,
  repo convention).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algebra
from repro.core import query as Q
from repro.core.engine import KBJoin
from repro.core.kb import (
    collect_kb_stats, kb_from_triples, probe_view,
)
from repro.core.pattern import Bindings, CompiledPattern, Slot
from repro.core.planner import (
    PROBE_K_CAP, _choose_kb_method, compile_query,
)
from repro.core.rdf import NUM_BASE, TERM_BITS, TERM_SPACE, Vocab
from repro.kernels.hash_join import ops as hj_ops
from repro.kernels.hash_join.ref import probe_compact_ref


BASE = 5000


def _world(m=24, n=160, nv=3, seed=0, spread=30, kb_rows=None):
    rng = np.random.default_rng(seed)
    cols = rng.integers(BASE, BASE + spread, size=(m, nv)).astype(np.uint32)
    bvalid = rng.random(m) < 0.9
    if kb_rows is None:
        kb_rows = [
            (int(rng.integers(BASE, BASE + spread)), int(rng.integers(1, 4)),
             int(rng.integers(BASE, BASE + spread)))
            for _ in range(max(0, n - 4))
        ]
    kb = kb_from_triples(kb_rows, capacity=n)
    bind = Bindings(jnp.asarray(cols), jnp.asarray(bvalid),
                    jnp.zeros((), bool))
    return bind, kb


PATTERNS = {
    "s_bound": CompiledPattern(Slot.bound(0), Slot.const_(1), Slot.free(1)),
    "o_bound": CompiledPattern(Slot.free(0), Slot.const_(2), Slot.bound(1)),
    "s_const": CompiledPattern(Slot.const_(BASE + 3), Slot.const_(1),
                               Slot.free(2)),
    "both_bound": CompiledPattern(Slot.bound(0), Slot.const_(2),
                                  Slot.bound(1)),
}


def _assert_probe_paths_match_oracle(bind, kb, pat, out_cap, k_max, bm=None):
    keys, (vs, vp, vo), _, anchor_is_s = probe_view(kb, pat)
    rows, valid, ovf = probe_compact_ref(
        bind.cols, bind.valid, vs, vp, vo, keys, pat, anchor_is_s,
        out_cap, k_max)
    ovf = bool(ovf) or bool(bind.overflow)
    for name, got in (
        ("unfused", algebra.kb_join_probe(bind, kb, pat, out_cap, k_max)),
        ("jnp-twin", hj_ops.probe_compact_jnp(bind, kb, pat, out_cap, k_max)),
        ("pallas", hj_ops.probe_compact(bind, kb, pat, out_cap, k_max,
                                        bm=bm)),
    ):
        np.testing.assert_array_equal(
            np.asarray(got.cols), np.asarray(rows), err_msg=name)
        np.testing.assert_array_equal(
            np.asarray(got.valid), np.asarray(valid), err_msg=name)
        assert bool(got.overflow) == ovf, name


@pytest.mark.parametrize("pat_kind", sorted(PATTERNS))
@pytest.mark.parametrize("m,n,k_max,cap", [
    (16, 64, 4, 32), (24, 160, 8, 64), (50, 300, 8, 128),
])
def test_probe_paths_match_oracle(m, n, k_max, cap, pat_kind):
    bind, kb = _world(m=m, n=n, seed=m + n)
    _assert_probe_paths_match_oracle(bind, kb, PATTERNS[pat_kind], cap, k_max)


def test_probe_non_multiple_block_shape():
    bind, kb = _world(m=33, n=129, seed=7)
    _assert_probe_paths_match_oracle(bind, kb, PATTERNS["s_bound"], 64, 8,
                                     bm=16)


def test_probe_kmax_overflow_propagates():
    """Fan-out past k_max clips the gather and must set the overflow flag
    in every probe path, with all paths still bit-identical."""
    rows = [(BASE, 1, BASE + 100 + i) for i in range(12)]    # fan-out 12
    bind, kb = _world(m=4, n=16, kb_rows=rows)
    bind = bind._replace(cols=jnp.full_like(bind.cols, BASE),
                         valid=jnp.ones_like(bind.valid))
    pat = PATTERNS["s_bound"]
    for got in (
        algebra.kb_join_probe(bind, kb, pat, 64, 8),
        hj_ops.probe_compact_jnp(bind, kb, pat, 64, 8),
        hj_ops.probe_compact(bind, kb, pat, 64, 8),
    ):
        assert bool(got.overflow)
        assert int(np.asarray(got.count())) == 4 * 8   # clipped at k_max
    _assert_probe_paths_match_oracle(bind, kb, pat, 64, 8)
    # k_max covering the fan-out clears the flag and returns every match
    wide = algebra.kb_join_probe(bind, kb, pat, 64, 16)
    assert not bool(wide.overflow)
    assert int(np.asarray(wide.count())) == 4 * 12
    _assert_probe_paths_match_oracle(bind, kb, pat, 64, 16)


def test_probe_empty_kb():
    bind, kb = _world(m=8, n=4, kb_rows=[])
    for pat_kind in sorted(PATTERNS):
        _assert_probe_paths_match_oracle(bind, kb, PATTERNS[pat_kind], 16, 8)
        got = algebra.kb_join_probe(bind, kb, PATTERNS[pat_kind], 16, 8)
        assert int(np.asarray(got.count())) == 0 and not bool(got.overflow)


def test_probe_duplicate_keys_span_range():
    """Duplicate (p, s) rows must all surface from one probe range, in the
    sorted view's row order (bit-identical to the scan)."""
    rows = [(BASE, 1, BASE + 50 + i) for i in range(5)]
    rows += [(BASE + 1, 1, BASE + 90)]
    bind, kb = _world(m=2, n=8, kb_rows=rows)
    bind = bind._replace(cols=jnp.full_like(bind.cols, BASE),
                         valid=jnp.ones_like(bind.valid))
    pat = PATTERNS["s_bound"]
    got = algebra.kb_join_probe(bind, kb, pat, 32, 8)
    want = algebra.kb_join_scan(bind, kb, pat, 32)
    np.testing.assert_array_equal(np.asarray(got.cols), np.asarray(want.cols))
    np.testing.assert_array_equal(np.asarray(got.valid),
                                  np.asarray(want.valid))
    assert int(np.asarray(got.count())) == 2 * 5
    _assert_probe_paths_match_oracle(bind, kb, pat, 32, 8)


def _colliding_numeric(t1: int) -> int:
    """A different numeric id whose composite-key low bits collide with t1."""
    def low(t):
        return (t ^ (t >> TERM_BITS)) & (TERM_SPACE - 1)
    want = low(t1)
    for cand in range(t1 + 1, t1 + (1 << 22)):
        if low(cand) == want:
            return cand
    raise AssertionError("no collision found")


def test_probe_composite_collision_recheck():
    """Numeric anchors hash into the composite key; colliding ids share a
    probe range and must be filtered by the exact re-check."""
    t1 = int(NUM_BASE) + 5
    t2 = _colliding_numeric(t1)
    # KB rows under one predicate, subjects are the colliding numeric ids
    rows = [(t2, 1, BASE + 10), (t2, 1, BASE + 11), (t1, 1, BASE + 12)]
    kb = kb_from_triples(rows, capacity=8)
    cols = np.full((4, 3), t1, dtype=np.uint32)
    bind = Bindings(jnp.asarray(cols), jnp.ones((4,), bool),
                    jnp.zeros((), bool))
    pat = PATTERNS["s_bound"]
    # the shared composite key makes the probe range span t2's rows too
    keys, _, _, _ = probe_view(kb, pat)
    from repro.core.rdf import composite_key
    qk = composite_key(jnp.uint32(1), jnp.uint32(t1))
    width = int(jnp.searchsorted(keys, qk, side="right")
                - jnp.searchsorted(keys, qk, side="left"))
    assert width == 3, "collision did not share a probe range"
    got = algebra.kb_join_probe(bind, kb, pat, 32, 8)
    want = algebra.kb_join_scan(bind, kb, pat, 32)
    np.testing.assert_array_equal(np.asarray(got.cols), np.asarray(want.cols))
    assert int(np.asarray(got.count())) == 4      # only t1's own row matches
    _assert_probe_paths_match_oracle(bind, kb, pat, 32, 8)


def test_probe_interpret_parity():
    """interpret=True (Pallas interpreter) vs interpret=False (compiled)
    must agree bit-exactly; skipped when no accelerator can compile it."""
    bind, kb = _world(m=16, n=64, seed=3)
    pat = PATTERNS["s_bound"]
    want = hj_ops.probe_compact(bind, kb, pat, 32, 8, interpret=True)
    try:
        got = hj_ops.probe_compact(bind, kb, pat, 32, 8, interpret=False)
        got = np.asarray(got.cols)
    except Exception as e:                                    # noqa: BLE001
        pytest.skip("interpret=False needs a real accelerator: %r" % (e,))
    np.testing.assert_array_equal(got, np.asarray(want.cols))


# --------------------------------------------------------------------------
# the planner's cost model
# --------------------------------------------------------------------------

def test_collect_kb_stats():
    rows = [(BASE, 1, BASE + 10), (BASE, 1, BASE + 11), (BASE + 1, 1, BASE + 10),
            (BASE + 7, 2, BASE + 10)]
    stats = collect_kb_stats(kb_from_triples(rows, capacity=16))
    assert stats.total_rows == 4
    assert stats.preds[1].rows == 3
    assert stats.preds[1].k_ps == 2        # subject BASE carries two rows
    assert stats.preds[1].k_po == 2        # object BASE+10 carries two rows
    assert stats.preds[2] == (1, 1, 1)
    empty = collect_kb_stats(kb_from_triples([]))
    assert empty.total_rows == 0 and not empty.preds


def _fanout_kb(fanout: int, n_subjects: int = 20):
    rows = [(BASE + s, 1, BASE + 100 + s * fanout + i)
            for s in range(n_subjects) for i in range(fanout)]
    return kb_from_triples(rows)


def test_auto_selects_probe_with_derived_kmax():
    stats = collect_kb_stats(_fanout_kb(10))
    method, k = _choose_kb_method(PATTERNS["s_bound"], stats, 8)
    assert (method, k) == ("probe", 16)    # fan-out 10 rounds up to 16
    # un-anchored pattern: probe ineligible
    free_free = CompiledPattern(Slot.free(0), Slot.const_(1), Slot.free(1))
    assert _choose_kb_method(free_free, stats, 8) == ("scan", 8)
    # fan-out past the cap: fused scan wins
    wide = collect_kb_stats(_fanout_kb(PROBE_K_CAP + 1, n_subjects=4))
    assert _choose_kb_method(PATTERNS["s_bound"], wide, 8) == ("scan", 8)
    # predicate absent from the slice: probe is an instant miss
    method, k = _choose_kb_method(
        CompiledPattern(Slot.bound(0), Slot.const_(3), Slot.free(1)),
        stats, 8)
    assert (method, k) == ("probe", 8)
    # no statistics (kb_method="auto" without a KB): degrade to scan
    assert _choose_kb_method(PATTERNS["s_bound"], None, 8) == ("scan", 8)


def _two_join_query(v: Vocab):
    """Stream anchor + a high-fan-out join listed before a selective one."""
    ps = v.pred("tp:stream")
    p_wide = v.pred("tp:wide")
    p_narrow = v.pred("tp:narrow")
    q = Q.Query(
        name="order",
        where=(
            Q.Pattern(Q.Var("t"), Q.Const(ps), Q.Var("e"), Q.STREAM),
            # listed first, but unanchored until ?x exists: expensive
            Q.Pattern(Q.Var("y"), Q.Const(p_wide), Q.Var("x"), Q.KB),
            # anchored on the stream variable, fan-out 1: cheap
            Q.Pattern(Q.Var("e"), Q.Const(p_narrow), Q.Var("y"), Q.KB),
        ),
        construct=(Q.ConstructTemplate(Q.Var("t"), Q.Const(ps), Q.Var("x")),),
    )
    rows = [(BASE + i, p_narrow, BASE + 100 + i) for i in range(8)]
    rows += [(BASE + 100 + i, p_wide, BASE + 200 + (i % 3)) for i in range(8)]
    return q, kb_from_triples(rows), p_wide, p_narrow


def test_auto_orders_joins_by_selectivity():
    v = Vocab()
    q, kb, p_wide, p_narrow = _two_join_query(v)
    listed = compile_query(q, kb_method="scan")
    auto = compile_query(q, kb_method="auto",
                         kb_stats=collect_kb_stats(kb))
    def join_preds(plan):
        return [s.pat.p.const for s in plan.steps if isinstance(s, KBJoin)]
    assert join_preds(listed) == [p_wide, p_narrow]
    # the anchored narrow join runs first under the cost model, which also
    # anchors ?y and makes the wide join a probe instead of a scan
    assert join_preds(auto) == [p_narrow, p_wide]
    methods = [s.method for s in auto.steps if isinstance(s, KBJoin)]
    assert methods == ["probe", "probe"]


def test_auto_without_kb_runs_stream_only_query():
    """kb_method="auto" on a Session with no kb= must not try to profile a
    KB for stream-only queries (regression: MonolithicRuntime crashed)."""
    from repro.core.rdf import make_triples
    from repro.core.session import ExecutionConfig, Session

    v = Vocab()
    ps = v.pred("nk:p")
    q = Q.Query(
        name="streamonly",
        where=(Q.Pattern(Q.Var("a"), Q.Const(ps), Q.Var("b"), Q.STREAM),),
        construct=(Q.ConstructTemplate(Q.Var("a"), Q.Const(ps),
                                       Q.Var("b")),),
    )
    chunk = make_triples([(BASE + i, ps, BASE + 10 + i, i + 1, i + 1)
                          for i in range(4)], capacity=8)
    for mode in ("monolithic", "single_program"):
        cfg = ExecutionConfig(mode=mode, window_capacity=8, max_windows=2,
                              bind_cap=64, scan_cap=32, out_cap=64,
                              kb_method="auto")
        out, ovf = Session(cfg, vocab=v).register(q).process_chunk(chunk)
        assert not any(ovf.values())
        assert int(np.asarray(out.valid.sum())) == 4


def test_scan_probe_auto_sessions_bit_identical():
    """End-to-end: one query, one stream, three kb_method settings — the
    published streams must be bit-identical with zero overflow."""
    from repro.core.rdf import make_triples
    from repro.core.session import ExecutionConfig, Session

    v = Vocab()
    q, kb, _, _ = _two_join_query(v)
    ps = v.pred("tp:stream")
    chunk = make_triples(
        [(BASE + 200 + i, ps, BASE + (i % 8), i + 1, i + 1)
         for i in range(12)], capacity=32)
    outs = {}
    for method in ("scan", "probe", "auto"):
        cfg = ExecutionConfig(mode="monolithic", window_capacity=32,
                              max_windows=2, bind_cap=256, scan_cap=64,
                              out_cap=256, kb_method=method)
        reg = Session(cfg, vocab=v, kb=kb).register(q)
        out, ovf = reg.process_chunk(chunk)
        assert not any(ovf.values()), (method, ovf)
        outs[method] = out
    for method in ("probe", "auto"):
        for col, ca, cb in zip(outs["scan"]._fields, outs["scan"],
                               outs[method]):
            np.testing.assert_array_equal(
                np.asarray(ca), np.asarray(cb),
                err_msg="%s/%s" % (method, col))
