"""Generative cross-mode differential harness + pure-Python oracle.

Two layers of defence for the frontend expansion (variable-length closure
paths, boolean FILTER trees, SELECT, per-query windows):

* **cross-mode**: every generated query + random stream must produce
  bit-identical output chunks and overflow counts across ``monolithic``,
  ``single_program`` and ``pipelined`` — the paper's "All results are the
  same" claim, now property-tested over a query *grammar* instead of three
  golden queries;
* **oracle**: a pure-Python triple-store evaluator (no JAX anywhere in the
  oracle path) independently computes each window's result set — windowing
  (greedy graph-preserving packing), join/closure/filter semantics and
  CONSTRUCT/SELECT projection — and must agree with the engine per chunk.

Failing examples are dumped as reprs under ``diff_failures/`` so the CI
``differential-smoke`` job can upload them as artifacts.

Example budgets honour ``DSCEP_DIFF_EXAMPLES`` (reduced in CI smoke).
"""
from __future__ import annotations

import os
import random
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np
import pytest
from hypothesis import given, settings

import hypothesis.strategies as st

from repro.core import query as Q
from repro.core.engine import KBJoin
from repro.core.kb import kb_from_triples
from repro.core.planner import closure_path_specs, compile_query
from repro.core.faults import FaultPlan
from repro.core.rdf import (
    CLOSURE_PRED_BASE, NUM_BASE, ROW_BASE, Vocab, make_triples, to_host_rows,
)
from repro.core.recovery import RecoveryConfig
from repro.core.session import ExecutionConfig, MODES, Session

from strategies import incremental_configs, sliding_geometries

N_EXAMPLES = int(os.environ.get("DSCEP_DIFF_EXAMPLES", "6"))
FAIL_DIR = os.path.join(os.path.dirname(__file__), "..", "diff_failures")


def _dump_failure(tag: str, payload: str) -> None:
    os.makedirs(FAIL_DIR, exist_ok=True)
    path = os.path.join(FAIL_DIR, "%s.txt" % tag)
    with open(path, "a") as f:
        f.write(payload + "\n" + "-" * 72 + "\n")


# --------------------------------------------------------------------------
# a deterministic executable world (cycle + diamond in both closure graphs)
# --------------------------------------------------------------------------

class DiffWorld:
    def __init__(self) -> None:
        v = self.vocab = Vocab()
        self.mentions = v.pred("ds:mentions")
        self.score = v.pred("ds:score")
        self.tag = v.pred("ds:tag")
        self.out = v.pred("ds:out")
        self.type_pred = v.pred("dk:type")
        self.sub_pred = v.pred("dk:sub")
        self.link = v.pred("dk:link")
        self.classes = [v.term("dk:C%d" % i) for i in range(5)]
        self.entities = [v.term("dk:e%d" % i) for i in range(8)]
        self.tweets = [v.term("dt:t%d" % i) for i in range(4)]
        C, E = self.classes, self.entities
        rows = [
            (C[1], self.sub_pred, C[0]),
            (C[2], self.sub_pred, C[0]),
            (C[3], self.sub_pred, C[1]),
            (C[3], self.sub_pred, C[2]),      # diamond under C0
            (C[4], self.sub_pred, C[3]),
            (C[0], self.sub_pred, C[4]),      # cycle back to the root
        ]
        for i, e in enumerate(E):
            rows.append((e, self.type_pred, C[i % len(C)]))
            rows.append((e, self.link, E[(i + 3) % len(E)]))
        self.kb_rows = [tuple(int(x) for x in r) for r in rows]
        self.kb = kb_from_triples(self.kb_rows)

    def stream_rows(self, seed: int, n_events: int = 8):
        rng = random.Random(seed)
        rows = []
        for i in range(1, n_events + 1):
            t = rng.choice(self.tweets)
            g = i
            rows.append((t, self.mentions, rng.choice(self.entities), i, g))
            rows.append((t, self.score, int(NUM_BASE) + rng.randrange(300),
                         i, g))
            if rng.random() < 0.6:
                rows.append((t, self.tag, rng.choice(self.entities), i, g))
        return [tuple(int(x) for x in r) for r in rows]


DW = DiffWorld()


# --------------------------------------------------------------------------
# the pure-Python oracle (no JAX)
# --------------------------------------------------------------------------

def oracle_windows(rows, capacity: int, max_windows: int,
                   step: Optional[int] = None):
    """Greedy graph-preserving packing — mirrors window.count_windows.

    Sliding count windows (``step < capacity``) pack the stream into slides
    of ``step`` triples with the same graph-preserving greedy rule, and
    window ``w`` is the concatenation of slides ``w .. w + R - 1`` with
    ``R = ceil(capacity / step)`` — an independent reimplementation of the
    slide geometry the engine uses, sliding one python list at a time.
    """
    if step is None or step >= capacity:
        unit_cap, r = capacity, 1
    else:
        unit_cap, r = step, -(-capacity // step)
    max_units = max_windows + r - 1
    rows = sorted(rows, key=lambda row: (row[3], row[4]))   # stable (ts, graph)
    runs: List[List[tuple]] = []
    for row in rows:
        if runs and runs[-1][-1][4] == row[4]:
            runs[-1].append(row)
        else:
            runs.append([row])
    units: List[List[tuple]] = [[]]
    fill, uid = 0, 0
    for run in runs:
        size = min(len(run), unit_cap)
        if fill + size > unit_cap:
            uid += 1
            fill = size
            units.append([])
        else:
            fill += size
        if uid < max_units:
            units[uid].extend(run[:size])
    units = units[:max_units]
    units += [[] for _ in range(max_units - len(units))]
    windows = [
        sum((units[u] for u in range(w, w + r)), [])
        for w in range(max_windows)
    ]
    return [w for w in windows if w]


def _reach_star(edges) -> Dict[int, Set[int]]:
    out_edges: Dict[int, List[int]] = {}
    for s, o in edges:
        out_edges.setdefault(s, []).append(o)
    reach: Dict[int, Set[int]] = {}
    for start in {x for e in edges for x in e}:
        seen, frontier = {start}, [start]
        while frontier:
            nxt = []
            for n in frontier:
                for m in out_edges.get(n, ()):
                    if m not in seen:
                        seen.add(m)
                        nxt.append(m)
            frontier = nxt
        reach[start] = seen
    return reach


def oracle_closure_pairs(kb_rows, q: Q.Query, pred: int,
                         min_hops: int) -> Set[Tuple[int, int]]:
    """Mirror of planner._closure_pairs semantics, independently derived."""
    edges = [(s, o) for s, p, o in kb_rows if p == pred]
    pairs: Set[Tuple[int, int]] = set()
    if min_hops == 0:
        refl = {x for e in edges for x in e}
        for it in q.where:
            if (isinstance(it, Q.PathClosure)
                    and (it.pred, it.min_hops) == (pred, 0)):
                for t in (it.start, it.end):
                    if isinstance(t, Q.Const):
                        refl.add(int(t.id))
        pairs |= {(x, x) for x in refl}
    reach = _reach_star(edges)
    if min_hops == 0:
        for x, ys in reach.items():
            pairs |= {(x, y) for y in ys}
    else:
        for s, o in edges:
            pairs |= {(s, y) for y in reach[o]}
    return pairs


def _match(pat_terms, triples) -> List[dict]:
    out = []
    for row in triples:
        b, ok = {}, True
        for term, val in zip(pat_terms, row):
            if isinstance(term, Q.Const):
                ok = int(term.id) == val
            elif isinstance(term, Q.Var):
                if term.name in b and b[term.name] != val:
                    ok = False
                else:
                    b[term.name] = val
            else:
                ok = False
            if not ok:
                break
        if ok:
            out.append(b)
    return out


def _join(cur: List[dict], rows: List[dict], shared) -> List[dict]:
    out = []
    for b in cur:
        for r in rows:
            if all(b.get(v, 0) == r.get(v, 0) for v in shared):
                m = dict(b)
                for k, val in r.items():
                    if m.get(k, 0) == 0:
                        m[k] = val
                out.append(m)
    return out


def _eval_filter(e, b) -> Optional[bool]:
    """SPARQL three-valued logic: True / False / None (= error)."""
    if isinstance(e, Q.FilterNum):
        v = b.get(e.var, 0)
        t = e.value_id
        if t < int(NUM_BASE):
            # term equality on an IRI/string id: unbound is an error,
            # everything else compares ids exactly (no numeric coercion)
            if v == 0:
                return None
            return v == t if e.op == "eq" else v != t
        if v < int(NUM_BASE):
            return None
        return {"lt": v < t, "le": v <= t, "gt": v > t, "ge": v >= t,
                "eq": v == t, "ne": v != t}[e.op]
    vals = [_eval_filter(a, b) for a in e.args]
    if e.op == "not":
        return None if vals[0] is None else not vals[0]
    if e.op == "and":
        if any(v is False for v in vals):
            return False
        return None if any(v is None for v in vals) else True
    if any(v is True for v in vals):
        return True
    return None if any(v is None for v in vals) else False


def oracle_window_result(q: Q.Query, win_rows, kb_rows,
                         world: DiffWorld) -> Set[tuple]:
    """One window's output triples as comparison keys.

    Row-node subjects (SELECT / binding-graph templates) depend on engine
    row order, so their keys drop the subject: ``("row", p, o, ts)``;
    ordinary triples key as ``("spo", s, p, o, ts)``.
    """
    spo = [(s, p, o) for (s, p, o, ts, g) in win_rows]
    ts_max = max(ts for (_, _, _, ts, _) in win_rows)

    closures = {
        spec: oracle_closure_pairs(kb_rows, q, *spec)
        for spec in closure_path_specs(q)
    }
    sub_star = _reach_star(
        [(s, o) for s, p, o in kb_rows if p == world.sub_pred])

    bindings: List[dict] = [{}]
    bound: Set[str] = set()
    filters: List[Q.WhereItem] = []
    groups: List[Q.WhereItem] = []
    aux = [0]

    def join_item(cur, terms, rows):
        names = {t.name for t in terms if isinstance(t, Q.Var)}
        matched = _match(terms, rows)
        out = _join(cur, matched, sorted(names & bound))
        bound.update(names)
        return out

    for item in q.where:
        if isinstance(item, Q.Pattern):
            rows = spo if item.src == Q.STREAM else [
                (s, p, o) for s, p, o in kb_rows]
            bindings = join_item(bindings, (item.s, item.p, item.o), rows)
        elif isinstance(item, Q.PathKB):
            cur_t = item.start
            for i, pid in enumerate(item.preds):
                aux[0] += 1
                nxt = item.end if i == len(item.preds) - 1 else (
                    Q.Var("__ora%d" % aux[0]))
                bindings = join_item(
                    bindings, (cur_t, Q.Const(pid), nxt), kb_rows)
                cur_t = nxt
        elif isinstance(item, Q.PathClosure):
            pairs = closures[(item.pred, item.min_hops)]
            bindings = join_item(
                bindings, (item.start, item.end),
                [(x, y) for x, y in sorted(pairs)])
        elif isinstance(item, Q.FilterSubclass):
            # classes reaching the super-class (descendants), incl. itself
            allowed = {c for c, ys in sub_star.items()
                       if item.super_class in ys} | {item.super_class}
            bindings = [
                b for b in bindings
                if any(s == b.get(item.var, 0) and p == item.type_pred
                       and o in allowed for s, p, o in kb_rows)
            ]
            bound.add(item.var)
        elif isinstance(item, (Q.FilterNum, Q.FilterBool)):
            filters.append(item)
        else:
            groups.append(item)

    for item in groups:
        if isinstance(item, Q.OptionalGroup):
            gvars = {v for p in item.patterns for v in p.vars()}
            shared = sorted(gvars & bound)
            sub: List[dict] = [{}]
            sub_bound: Set[str] = set()
            for p in item.patterns:
                rows = spo if p.src == Q.STREAM else [
                    (s, pp, o) for s, pp, o in kb_rows]
                names = set(p.vars())
                sub = _join(sub, _match((p.s, p.p, p.o), rows),
                            sorted(names & sub_bound))
                sub_bound |= names
            out = []
            for b in bindings:
                hits = [s for s in sub
                        if all(b.get(v, 0) == s.get(v, 0) for v in shared)]
                if hits:
                    for s in hits:
                        m = dict(b)
                        for k, val in s.items():
                            if m.get(k, 0) == 0:
                                m[k] = val
                        out.append(m)
                else:
                    out.append(b)
            bindings = out
            bound |= gvars
        elif isinstance(item, Q.UnionGroup):
            def branch(pats):
                ext = bindings
                br_bound = set(bound)
                for p in pats:
                    rows = spo if p.src == Q.STREAM else [
                        (s, pp, o) for s, pp, o in kb_rows]
                    names = set(p.vars())
                    ext = _join(ext, _match((p.s, p.p, p.o), rows),
                                sorted(names & br_bound))
                    br_bound |= names
                bound.update(br_bound)
                return ext

            bindings = branch(item.left) + branch(item.right)

    for f in filters:
        bindings = [b for b in bindings if _eval_filter(f, b) is True]

    out_vars = sorted({
        t.name for tpl in q.construct for t in (tpl.s, tpl.p, tpl.o)
        if isinstance(t, Q.Var)
    })
    projected = {tuple(b.get(v, 0) for v in out_vars) for b in bindings}

    keys: Set[tuple] = set()
    for row in projected:
        b = dict(zip(out_vars, row))

        def val(t):
            if isinstance(t, Q.Const):
                return int(t.id)
            if isinstance(t, Q.Var):
                return b[t.name]
            return None                      # RowId

        for tpl in q.construct:
            s, p, o = val(tpl.s), val(tpl.p), val(tpl.o)
            if s is None:
                keys.add(("row", p, o, ts_max))
            else:
                keys.add(("spo", s, p, o, ts_max))
    return keys


def oracle_chunk_result(q, chunk_rows, kb_rows, world,
                        capacity, max_windows,
                        step: Optional[int] = None) -> Set[tuple]:
    keys: Set[tuple] = set()
    for win in oracle_windows(chunk_rows, capacity, max_windows, step):
        keys |= oracle_window_result(q, win, kb_rows, world)
    return keys


def engine_chunk_keys(out_batch) -> Set[tuple]:
    keys = set()
    for s, p, o, ts, g in to_host_rows(out_batch):
        if int(ROW_BASE) <= s < int(NUM_BASE):
            keys.add(("row", p, o, ts))
        else:
            keys.add(("spo", s, p, o, ts))
    return keys


# --------------------------------------------------------------------------
# constrained executable-query generator (every var chains off the stream)
# --------------------------------------------------------------------------

@st.composite
def exec_queries(draw, world: DiffWorld = DW):
    where: List[Q.WhereItem] = [
        Q.Pattern(Q.Var("t"), Q.Const(world.mentions), Q.Var("e"), Q.STREAM),
        Q.Pattern(Q.Var("t"), Q.Const(world.score), Q.Var("s"), Q.STREAM),
    ]
    kind = draw(st.sampled_from(
        ("plus_const", "star_const", "plus_var", "star_var", "typed_closure",
         "subclass", "pathkb")))
    if kind in ("plus_const", "star_const"):
        where.append(Q.Pattern(Q.Var("e"), Q.Const(world.type_pred),
                               Q.Var("c"), Q.KB))
        where.append(Q.PathClosure(
            Q.Var("c"), world.sub_pred,
            Q.Const(draw(st.sampled_from(world.classes))),
            min_hops=1 if kind == "plus_const" else 0))
    elif kind in ("plus_var", "star_var"):
        where.append(Q.PathClosure(
            Q.Var("e"), world.link, Q.Var("x"),
            min_hops=1 if kind == "plus_var" else 0))
    elif kind == "typed_closure":
        where.append(Q.Pattern(Q.Var("e"), Q.Const(world.type_pred),
                               Q.Var("c"), Q.KB))
        where.append(Q.PathClosure(Q.Var("c"), world.sub_pred, Q.Var("d"),
                                   min_hops=draw(st.integers(0, 1))))
    elif kind == "subclass":
        where.append(Q.FilterSubclass(
            "e", world.type_pred, world.sub_pred,
            draw(st.sampled_from(world.classes))))
    else:
        where.append(Q.PathKB(Q.Var("e"), (world.link, world.link),
                              Q.Var("x")))

    f_kind = draw(st.sampled_from(("none", "num", "bool", "term")))
    thresh = int(NUM_BASE) + draw(st.integers(0, 299))
    if f_kind == "num":
        where.append(Q.FilterNum("s", draw(st.sampled_from(
            ("lt", "le", "gt", "ge"))), thresh))
    elif f_kind == "term":
        # term equality on an IRI id (satellite: FILTER =/!= on non-numerics)
        where.append(Q.FilterNum(
            "e", draw(st.sampled_from(("eq", "ne"))),
            draw(st.sampled_from(world.entities))))
    elif f_kind == "bool":
        lo = int(NUM_BASE) + draw(st.integers(0, 150))
        where.append(Q.FilterBool("or", (
            Q.FilterNum("s", "ge", thresh),
            Q.FilterBool("and", (
                Q.FilterNum("s", "lt", lo),
                Q.FilterBool("not", (Q.FilterNum("e", "ge", lo),)),
            )),
        )))
    if draw(st.booleans()):
        where.append(Q.OptionalGroup((
            Q.Pattern(Q.Var("t"), Q.Const(world.tag), Q.Var("g"), Q.STREAM),
        )))

    bound = sorted(Q.Query(name="tmp", where=tuple(where),
                           construct=()).variables())
    if draw(st.booleans()):
        k = draw(st.integers(1, min(2, len(bound))))
        names = tuple(bound[:k])
        construct = tuple(
            Q.ConstructTemplate(Q.RowId(0),
                                Q.Const(world.vocab.pred("?:" + n)),
                                Q.Var(n))
            for n in names
        )
        return Q.Query(name="dq", where=tuple(where), construct=construct,
                       select=names)
    obj = draw(st.sampled_from(bound))
    construct = (Q.ConstructTemplate(Q.Var("t"), Q.Const(world.out),
                                     Q.Var(obj)),)
    return Q.Query(name="dq", where=tuple(where), construct=construct)


CFG = ExecutionConfig(window_capacity=48, max_windows=4, bind_cap=2048,
                      scan_cap=256, out_cap=2048, out_stream_cap=4096,
                      intermediate_cap=1024)


def _chunks_for(seed: int):
    rows_a = DW.stream_rows(seed, n_events=8)
    rows_b = DW.stream_rows(seed + 1000, n_events=8)
    rows_b = [(s, p, o, ts + 8, g + 8) for s, p, o, ts, g in rows_b]
    return [rows_a, rows_b], [make_triples(rows_a, capacity=48),
                              make_triples(rows_b, capacity=48)]


# --------------------------------------------------------------------------
# properties
# --------------------------------------------------------------------------

@settings(max_examples=N_EXAMPLES, deadline=None, derandomize=True)
@given(q=exec_queries(), seed=st.integers(0, 2**16))
def test_engine_matches_python_oracle(q, seed):
    host_rows, chunks = _chunks_for(seed)
    sess = Session(CFG.replace(mode="monolithic"), vocab=DW.vocab, kb=DW.kb)
    reg = sess.register(q)
    try:
        for rows, chunk in zip(host_rows, chunks):
            out, overflow = reg.process_chunk(chunk)
            assert not any(overflow.values()), (
                "capacities clipped a differential example", overflow)
            want = oracle_chunk_result(q, rows, DW.kb_rows, DW,
                                       CFG.window_capacity, CFG.max_windows)
            got = engine_chunk_keys(out)
            assert got == want, {
                "only_engine": sorted(got - want)[:10],
                "only_oracle": sorted(want - got)[:10],
            }
    except AssertionError:
        _dump_failure("oracle", "seed=%d\nquery=%r" % (seed, q))
        raise


@settings(max_examples=max(2, N_EXAMPLES // 2), deadline=None,
          derandomize=True)
@given(q=exec_queries(), seed=st.integers(0, 2**16),
       method=st.sampled_from(("scan", "auto")),
       depth=st.sampled_from((1, 3, 6)))
def test_modes_bit_identical_on_generated_queries(q, seed, method, depth):
    """Cross-mode bit-identity, under both the scan baseline and the
    cost-based access planner (kb_method="auto" profiles each mode's own
    used-KB slices, so monolithic and decomposed plans may pick different
    per-join methods/orders — the published streams must not care).

    The pipelined runtime is additionally driven at a sampled schedule
    depth: 1 (serial), 3 (in-flight overlap) and 6 (beyond the channel
    capacity, so chunks wait in the host-side source queue) — the schedule
    is an execution detail the published bytes must not depend on."""
    _, chunks = _chunks_for(seed)
    try:
        outs, ovfs = {}, {}
        for mode in MODES:
            sess = Session(CFG.replace(mode=mode, kb_method=method),
                           vocab=DW.vocab, kb=DW.kb)
            reg = sess.register(q)
            if mode == "pipelined":
                outs[mode], ovf = reg.runtime.process_stream(chunks,
                                                             depth=depth)
                ovfs[mode] = dict(ovf)
            else:
                outs[mode], ovfs[mode] = reg.run(chunks)
        for mode in MODES:
            assert not any(ovfs[mode].values()), (mode, ovfs[mode])
        for mode in MODES[1:]:
            for i, (a, b) in enumerate(zip(outs[MODES[0]], outs[mode])):
                for col, ca, cb in zip(a._fields, a, b):
                    assert bool(np.all(np.asarray(ca) == np.asarray(cb))), (
                        mode, i, col)
        assert ovfs["single_program"] == ovfs["pipelined"]
    except AssertionError:
        _dump_failure("cross_mode", "seed=%d method=%s depth=%d\nquery=%r"
                      % (seed, method, depth, q))
        raise


@settings(max_examples=max(2, N_EXAMPLES // 2), deadline=None,
          derandomize=True)
@given(q=exec_queries(), seed=st.integers(0, 2**16),
       checkpoint_every=st.sampled_from((0, 1, 2)))
def test_chaos_recovery_bit_identical_to_fault_free(q, seed,
                                                    checkpoint_every):
    """Seeded chaos differential — the robustness acceptance gate.

    A random FaultPlan (drawn over all five kinds; every non-corrupt event
    targets the ``source`` stage so the schedule is complete without
    knowing the generated query's DAG) is injected into a pipelined run of
    a *generated* query.  The recovered output must be byte-identical to
    the fault-free monolithic run — zero lost rows, zero duplicated rows —
    and ``last_stats`` must account for every scheduled event exactly.
    ``checkpoint_every`` sweeps 0 (replay from the stream head, heavy
    sequence-number dedup), 1 (checkpoint per emission, no dedup) and 2."""
    _, chunks = _chunks_for(seed)
    plan = FaultPlan.seeded(seed, ("source",), num_chunks=len(chunks),
                            n_events=3)
    try:
        mono = Session(CFG.replace(mode="monolithic"),
                       vocab=DW.vocab, kb=DW.kb).register(q)
        base, base_ovf = mono.run(chunks)
        assert not any(base_ovf.values()), base_ovf
        reg = Session(
            CFG.replace(mode="pipelined", faults=plan,
                        recovery=RecoveryConfig(
                            checkpoint_every=checkpoint_every)),
            vocab=DW.vocab, kb=DW.kb).register(q)
        outs, ovf = reg.run(chunks)
        assert not any(ovf.values()), ovf
        assert len(outs) == len(base)
        for i, (a, b) in enumerate(zip(base, outs)):
            for col, ca, cb in zip(a._fields, a, b):
                assert bool(np.all(np.asarray(ca) == np.asarray(cb))), (
                    "chaos output diverges from fault-free", i, col)
        rec = reg.last_stats["recovery"]
        assert rec["enabled"]
        assert rec["injected"] == plan.counts() == rec["scheduled"], (
            "scheduled faults must fire exactly", rec)
        assert rec["checkpoints"] >= 1       # at least the clean-state cut
    except AssertionError:
        _dump_failure("chaos", "seed=%d checkpoint_every=%d plan=%r\nquery=%r"
                      % (seed, checkpoint_every, plan, q))
        raise


@settings(max_examples=max(2, N_EXAMPLES // 2), deadline=None,
          derandomize=True)
@given(q=exec_queries(), seed=st.integers(0, 2**16))
def test_kb_methods_bit_identical_on_generated_queries(q, seed):
    """scan vs probe vs auto on the same generated query + stream: the
    access method (and auto's join reordering) is an execution detail —
    published streams must agree bit-exactly with zero overflow."""
    _, chunks = _chunks_for(seed)
    try:
        outs = {}
        for method in ("scan", "probe", "auto"):
            sess = Session(CFG.replace(mode="monolithic", kb_method=method),
                           vocab=DW.vocab, kb=DW.kb)
            outs[method], ovf = sess.register(q).run(chunks)
            assert not any(ovf.values()), (method, ovf)
        for method in ("probe", "auto"):
            for i, (a, b) in enumerate(zip(outs["scan"], outs[method])):
                for col, ca, cb in zip(a._fields, a, b):
                    assert bool(np.all(np.asarray(ca) == np.asarray(cb))), (
                        method, i, col)
    except AssertionError:
        _dump_failure("kb_method", "seed=%d\nquery=%r" % (seed, q))
        raise


@settings(max_examples=N_EXAMPLES, deadline=None, derandomize=True)
@given(q=exec_queries(), seed=st.integers(0, 2**16),
       cfg=incremental_configs(CFG), geom=sliding_geometries())
def test_sliding_windows_match_python_oracle(q, seed, cfg, geom):
    """Sliding-window adjudication: any runtime, delta or recompute, must
    agree with the pure-Python oracle sliding independently over its own
    greedy slide packing — the tentpole's semantic ground truth."""
    cap, step = geom
    host_rows, chunks = _chunks_for(seed)
    cfg = cfg.replace(window_capacity=cap, window_step=step)
    sess = Session(cfg, vocab=DW.vocab, kb=DW.kb)
    reg = sess.register(q)
    try:
        for rows, chunk in zip(host_rows, chunks):
            out, overflow = reg.process_chunk(chunk)
            assert not any(overflow.values()), (
                "capacities clipped a sliding-window example", overflow)
            want = oracle_chunk_result(q, rows, DW.kb_rows, DW, cap,
                                       CFG.max_windows, step=step)
            got = engine_chunk_keys(out)
            assert got == want, {
                "only_engine": sorted(got - want)[:10],
                "only_oracle": sorted(want - got)[:10],
            }
    except AssertionError:
        _dump_failure("sliding_oracle",
                      "seed=%d mode=%s incremental=%r geom=%r\nquery=%r"
                      % (seed, cfg.mode, cfg.incremental, geom, q))
        raise


@settings(max_examples=N_EXAMPLES, deadline=None, derandomize=True)
@given(q=exec_queries(), seed=st.integers(0, 2**16),
       geom=sliding_geometries())
def test_incremental_bit_identical_to_recompute_across_modes(q, seed, geom):
    """Delta-mode acceptance: every runtime with ``incremental=True`` emits
    the exact bytes of the monolithic full-recompute baseline on generated
    sliding-window queries, with zero overflow everywhere."""
    cap, step = geom
    _, chunks = _chunks_for(seed)
    base_cfg = CFG.replace(window_capacity=cap, window_step=step)
    try:
        sess = Session(base_cfg.replace(mode="monolithic"),
                       vocab=DW.vocab, kb=DW.kb)
        base, ovf = sess.register(q).run(chunks)
        assert not any(ovf.values()), ovf
        for mode in MODES:
            sess = Session(base_cfg.replace(mode=mode, incremental=True),
                           vocab=DW.vocab, kb=DW.kb)
            outs, ovf = sess.register(q).run(chunks)
            assert not any(ovf.values()), (mode, ovf)
            for i, (a, b) in enumerate(zip(base, outs)):
                for col, ca, cb in zip(a._fields, a, b):
                    assert bool(np.all(np.asarray(ca) == np.asarray(cb))), (
                        mode, i, col)
    except AssertionError:
        _dump_failure("incremental",
                      "seed=%d geom=%r\nquery=%r" % (seed, geom, q))
        raise


# --------------------------------------------------------------------------
# multi-device dataflow: XLA_FLAGS must be set before the backend comes up,
# so the forced-device-count configuration runs in a fresh subprocess
# --------------------------------------------------------------------------

_MULTI_DEVICE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
assert len(jax.devices()) == 4, jax.devices()
import numpy as np
from repro.core import paper_queries as PQ
from repro.core.rdf import Vocab
from repro.core.session import ExecutionConfig, Session
from repro.data.dbpedia import KBConfig, generate_kb
from repro.data.tweets import (
    TweetSchema, TweetStreamConfig, generate_tweets, stream_chunks)

vocab = Vocab()
kbd = generate_kb(vocab, KBConfig(num_artists=12, num_shows=6,
                                  filler_triples=40, seed=0))
tweets = TweetSchema.create(vocab)
pool = np.concatenate([kbd.artist_ids, kbd.show_ids])
rows = generate_tweets(vocab, tweets, pool,
                       TweetStreamConfig(num_tweets=24, mentions_min=2,
                                         mentions_max=3, seed=0))
chunks = list(stream_chunks(rows, 64))
assert len(chunks) >= 2
cfg = ExecutionConfig(window_capacity=64, max_windows=4, bind_cap=512,
                      scan_cap=128, out_cap=512, intermediate_cap=256)
q = PQ.q15(vocab, tweets, kbd.schema)
single = Session(cfg.replace(mode="single_program"),
                 vocab=vocab, kb=kbd.kb).register(q)
piped = Session(cfg.replace(mode="pipelined"),
                vocab=vocab, kb=kbd.kb).register(q)
spread = {str(d) for d in piped.runtime.placement.values()}
assert len(spread) >= 2, piped.runtime.placement
outs_s, ovf_s = single.run(chunks)
outs_p, ovf_p = piped.run(chunks)
assert ovf_p == ovf_s, (ovf_p, ovf_s)
for a, b in zip(outs_s, outs_p):
    for ca, cb in zip(a, b):
        assert bool((np.asarray(ca) == np.asarray(cb)).all())
assert piped.runtime.depth_hw >= 2, piped.runtime.depth_hw
print("MULTI_DEVICE_OK devices=%d spread=%d depth_hw=%d"
      % (len(jax.devices()), len(spread), piped.runtime.depth_hw))
"""


def test_pipelined_bit_identical_across_forced_host_devices():
    """Cross-device transport differential: with the CPU backend forced to
    expose 4 devices, round_robin placement spreads the operators (channel
    pushes become D2D copies) and the pipelined stream must still match the
    single-program bytes exactly."""
    import subprocess
    import sys

    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p])
    res = subprocess.run(
        [sys.executable, "-c", _MULTI_DEVICE_SCRIPT], env=env,
        capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert "MULTI_DEVICE_OK" in res.stdout, res.stdout


# --------------------------------------------------------------------------
# multi-query serving: one ServeEngine == N independent sessions, bit-exact
# --------------------------------------------------------------------------

@settings(max_examples=max(2, N_EXAMPLES // 2), deadline=None,
          derandomize=True)
@given(qs=st.lists(exec_queries(), min_size=2, max_size=3),
       seed=st.integers(0, 2**16), dedup=st.booleans())
def test_serving_engine_matches_independent_sessions(qs, seed, dedup):
    """The serving layer's acceptance property: N generated queries hosted
    in ONE ServeEngine (shared-plan dedup on and off) publish the exact
    bytes — and report the exact overflow counts — of N single-query
    Sessions run independently.  Duplicate draws are kept on purpose: they
    exercise the fingerprint-dedup fan-out path."""
    import dataclasses as _dc

    qs = [_dc.replace(q, name="dq%d" % i) for i, q in enumerate(qs)]
    _, chunks = _chunks_for(seed)
    cfg = CFG.replace(mode="monolithic")
    try:
        ref, ref_ovf = {}, {}
        for q in qs:
            sess = Session(cfg, vocab=DW.vocab, kb=DW.kb)
            ref[q.name], ovf = sess.register(q).run(chunks)
            ref_ovf[q.name] = ovf[q.name]
        eng = Session(cfg, vocab=DW.vocab, kb=DW.kb).serve(dedup=dedup)
        for q in qs:
            eng.register(q)
        outs, ovfs = eng.run(chunks)
        assert set(outs) == set(ref)
        for name in ref:
            for i, (a, b) in enumerate(zip(outs[name], ref[name])):
                for col, ca, cb in zip(a._fields, a, b):
                    assert bool(np.all(np.asarray(ca) == np.asarray(cb))), (
                        dedup, name, i, col)
            assert ovfs[name] == ref_ovf[name], (name, ovfs, ref_ovf)
    except AssertionError:
        _dump_failure("serving", "seed=%d dedup=%r\nqueries=%r"
                      % (seed, dedup, qs))
        raise


# --------------------------------------------------------------------------
# acceptance: closure compiles through the kernel relation (no join chain),
# and one Session runs two .rq queries with different RANGE windows
# --------------------------------------------------------------------------

def test_closure_path_compiles_to_single_kb_join():
    q = Q.Query(
        name="c", where=(
            Q.Pattern(Q.Var("t"), Q.Const(DW.mentions), Q.Var("e"), Q.STREAM),
            Q.PathClosure(Q.Var("e"), DW.link, Q.Var("x"), min_hops=1),
        ),
        construct=(Q.ConstructTemplate(Q.Var("t"), Q.Const(DW.out),
                                       Q.Var("x")),),
    )
    plan = compile_query(q)
    joins = [s for s in plan.steps if isinstance(s, KBJoin)]
    assert len(joins) == 1, "closure must not unroll into a join chain"
    assert joins[0].pat.p.const >= CLOSURE_PRED_BASE


RQ_SMALL = """\
REGISTER QUERY win_small AS
PREFIX ds: <urn:dscep:ds>
CONSTRUCT { ?t ds:out ?e . }
FROM STREAM <stream> [RANGE TRIPLES 24 STEP 8]
FROM <kb>
WHERE { ?t ds:mentions ?e . }
"""

RQ_LARGE = """\
REGISTER QUERY win_large AS
PREFIX ds: <urn:dscep:ds>
PREFIX dk: <urn:dscep:dk>
CONSTRUCT { ?t ds:out ?c . }
FROM STREAM <stream> [RANGE TRIPLES 80 STEP 80]
FROM <kb>
WHERE {
  ?t ds:mentions ?e .
  GRAPH <kb> {
    ?e dk:type ?c .
    ?c dk:sub+ dk:C0 .
  }
}
"""


def test_two_rq_with_different_windows_in_one_session():
    """The per-query window acceptance criterion: one Session hosts two
    ``.rq`` registrations whose RANGE TRIPLES clauses differ, both run
    concurrently in every mode, each bit-identical across modes."""
    host_rows, chunks = _chunks_for(7)
    outs = {name: {} for name in ("win_small", "win_large")}
    geoms = {}
    for mode in MODES:
        sess = Session(
            CFG.replace(mode=mode, window_from_query=True),
            vocab=DW.vocab, kb=DW.kb)
        regs = [sess.register(RQ_SMALL), sess.register(RQ_LARGE)]
        assert set(sess.queries) == {"win_small", "win_large"}
        for reg in regs:
            geoms[reg.query.name] = reg.window_geometry
            outs[reg.query.name][mode], overflow = reg.run(chunks)
            assert not any(overflow.values()), (mode, overflow)
    assert geoms == {"win_small": (24, 8), "win_large": (80, 80)}
    for name, per_mode in outs.items():
        for mode in MODES[1:]:
            for i, (a, b) in enumerate(zip(per_mode[MODES[0]],
                                           per_mode[mode])):
                for col, ca, cb in zip(a._fields, a, b):
                    assert bool(np.all(np.asarray(ca) == np.asarray(cb))), (
                        name, mode, i, col)
    # the small window also agrees with the oracle evaluated at RANGE 24
    q_small = sess.queries["win_small"].query
    for rows, chunk in zip(host_rows, chunks):
        out, _ = sess.queries["win_small"].process_chunk(chunk)
        want = oracle_chunk_result(q_small, rows, DW.kb_rows, DW, 24,
                                   CFG.max_windows, step=8)
        assert engine_chunk_keys(out) == want
