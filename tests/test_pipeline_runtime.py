"""pipelined == single_program == monolithic (the dataflow layer).

The streaming runtime cuts the DAG at channel boundaries instead of fusing
it into one XLA program; results must stay **bit-identical** per chunk on
all three paper queries, with >= 2 chunks in flight, including when window
capacities overflow (flags must match too, never be dropped).  All modes
are constructed and driven through the unified Session API.
"""
import jax
import numpy as np
import pytest

from repro.core import paper_queries as PQ
from repro.core.rdf import Vocab, to_host_rows
from repro.core.session import ExecutionConfig, Session
from repro.data.dbpedia import KBConfig, generate_kb
from repro.data.tweets import (
    TweetSchema, TweetStreamConfig, generate_tweets, stream_chunks,
)
from repro.launch.mesh import place_operators

CFG = ExecutionConfig(window_capacity=96, max_windows=4, bind_cap=1024,
                      scan_cap=128, out_cap=1024, intermediate_cap=512)
QUERIES = {"q15": PQ.q15, "q16": PQ.q16, "cquery1": PQ.cquery1}


class PipeWorld:
    """Co-mention stream split into several chunks (multi-chunk pipelining)."""

    def __init__(self, num_tweets=36, seed=0):
        self.vocab = Vocab()
        self.kbd = generate_kb(
            self.vocab,
            KBConfig(num_artists=24, num_shows=12, filler_triples=80,
                     seed=seed),
        )
        self.tweets = TweetSchema.create(self.vocab)
        pool = np.concatenate([self.kbd.artist_ids, self.kbd.show_ids])
        self.rows = generate_tweets(
            self.vocab, self.tweets, pool,
            TweetStreamConfig(num_tweets=num_tweets, mentions_min=2,
                              mentions_max=3, seed=seed),
        )
        self.chunks = list(stream_chunks(self.rows, 96))


@pytest.fixture(scope="module")
def pworld():
    w = PipeWorld()
    assert len(w.chunks) >= 3, "need a multi-chunk stream to pipeline"
    return w


_RT_CACHE = {}


def runtimes(world, qname, cfg=CFG):
    """(single-program, pipelined) registrations for one query, built once."""
    key = (qname, cfg)     # ExecutionConfig is frozen, hence hashable
    if key not in _RT_CACHE:
        q = QUERIES[qname](world.vocab, world.tweets, world.kbd.schema)
        single = Session(cfg.replace(mode="single_program"),
                         vocab=world.vocab, kb=world.kbd.kb).register(q)
        piped = Session(cfg.replace(mode="pipelined"),
                        vocab=world.vocab, kb=world.kbd.kb).register(q)
        _RT_CACHE[key] = (q, single, piped)
    return _RT_CACHE[key]


def assert_bit_identical(outs_a, outs_b, tag=""):
    assert len(outs_a) == len(outs_b)
    for i, (a, b) in enumerate(zip(outs_a, outs_b)):
        for col_a, col_b in zip(a, b):
            assert bool(np.all(np.asarray(col_a) == np.asarray(col_b))), (
                f"{tag} chunk {i} diverges")


@pytest.mark.parametrize("qname", sorted(QUERIES))
def test_pipelined_bit_identical_to_single_program(pworld, qname):
    q, single, piped = runtimes(pworld, qname)
    outs_s, ovf_s = single.run(pworld.chunks)
    outs_p, ovf_p = piped.run(pworld.chunks)
    assert_bit_identical(outs_s, outs_p, qname)
    # per-call overflow deltas match even on a reused (module-scoped) runtime
    assert ovf_p == ovf_s
    # and the paper's claim transitively: pipelined == monolithic result set
    mono = Session(CFG.replace(mode="monolithic"), vocab=pworld.vocab,
                   kb=pworld.kbd.kb).register(q)
    res_m, res_p = [], []
    for c, o in zip(pworld.chunks, outs_p):
        res_m += sorted(set((r[0], r[1], r[2])
                            for r in to_host_rows(mono.process_chunk(c)[0])))
        res_p += sorted(set((r[0], r[1], r[2]) for r in to_host_rows(o)))
    assert len(res_p) > 0
    assert sorted(res_m) == sorted(res_p)


def test_schedule_keeps_two_chunks_in_flight(pworld):
    """Manual drive of the software-pipelined schedule: the sink consumes
    chunk t only after chunk t+1's producers were dispatched."""
    _, single, reg_p = runtimes(pworld, "q15")
    piped = reg_p.runtime
    outs_s, _ = single.run(pworld.chunks)
    outs_p = []
    max_in_flight = 0
    try:
        for c in pworld.chunks:
            if piped._in_flight >= 2:
                outs_p.append(piped.drain())
            piped.feed(c)
            max_in_flight = max(max_in_flight, piped._in_flight)
    finally:
        while piped._in_flight:       # never leave the cached runtime dirty
            outs_p.append(piped.drain())
    jax.block_until_ready(outs_p[-1])
    assert max_in_flight >= 2
    assert_bit_identical(outs_s, outs_p, "q15 manual schedule")


def test_overflow_case_flags_match_and_streams_stay_identical(pworld):
    """Capacities small enough to clip: both runtimes must report the same
    per-operator overflowed-window counts (observable, never dropped) and
    still publish bit-identical (clipped) streams."""
    tiny = ExecutionConfig(window_capacity=96, max_windows=4, bind_cap=1024,
                           scan_cap=128, out_cap=16, intermediate_cap=8)
    q, single, piped = runtimes(pworld, "cquery1", tiny)
    outs_s, ovf_s = single.run(pworld.chunks)
    outs_p, ovf_p = piped.run(pworld.chunks)
    assert sum(ovf_s.values()) > 0, "intended an overflowing configuration"
    assert ovf_p == ovf_s
    assert_bit_identical(outs_s, outs_p, "cquery1 overflow")


def test_channels_drained_and_lossless_after_stream(pworld):
    _, _, reg_p = runtimes(pworld, "q15")
    reg_p.run(pworld.chunks)
    for edge, st in reg_p.runtime.channel_stats().items():
        assert st["size"] == 0, edge
        assert st["overflows"] == 0, edge


def test_driver_misuse_raises_and_feed_queues_past_capacity(pworld):
    _, _, reg_p = runtimes(pworld, "q16")
    piped = reg_p.runtime
    cap = piped.channel_capacity
    with pytest.raises(RuntimeError):
        piped.drain()
    try:
        # feed never raises on a full pipeline: chunks beyond the channel
        # capacity wait in the host-side source queue instead
        for _ in range(cap + 2):
            piped.feed(pworld.chunks[0])
        assert piped._in_flight == cap
        assert len(piped._src_q) == 2
        with pytest.raises(RuntimeError):
            piped.process_stream(pworld.chunks)   # in-flight would leak in
        with pytest.raises(RuntimeError):
            piped.process_chunk(pworld.chunks[1])
        piped.drain()
        # draining freed a slot; the queue backfills it in the same call
        assert piped._in_flight == cap and len(piped._src_q) == 1
    finally:
        while piped._in_flight or piped._src_q:   # never leave it dirty
            piped.drain()


def test_pipeline_requires_double_buffering(pworld):
    q = QUERIES["q15"](pworld.vocab, pworld.tweets, pworld.kbd.schema)
    with pytest.raises(ValueError):
        Session(CFG.replace(mode="pipelined", channel_capacity=1),
                vocab=pworld.vocab, kb=pworld.kbd.kb).register(q)


def test_place_operators_policies():
    names = ["a_kb0", "b_kb1", "agg"]
    devs = ["d0", "d1", "d2"]
    single = place_operators(names, "agg", devices=devs, strategy="single")
    assert single == {"a_kb0": "d0", "b_kb1": "d0", "agg": "d0"}
    rr = place_operators(names, "agg", devices=devs)
    assert rr["agg"] == "d0"                       # sink on the host device
    assert rr["a_kb0"] == "d1" and rr["b_kb1"] == "d2"
    one = place_operators(names, "agg", devices=["d0"])
    assert set(one.values()) == {"d0"}
    with pytest.raises(ValueError):
        place_operators(names, "missing", devices=devs)
    with pytest.raises(ValueError):
        place_operators(names, "agg", devices=[])
