"""Observability subsystem tests: tracer, metrics, uniform surfaces, and
the zero-overhead-off guarantee.

The hard acceptance bar of the observability PR is pinned here: with
``trace`` disabled the traced jaxpr of every operator step is *unchanged*
(no stats code executes on the off path at all), and enabling tracing
changes measured programs but never results — traced runs stay
bit-identical to untraced runs in all three execution modes.
"""
import json
import time

import jax
import numpy as np
import pytest

from repro.core import paper_queries as PQ
from repro.core.rdf import Vocab, to_host_rows
from repro.core.session import ExecutionConfig, MODES, Session
from repro.data.dbpedia import KBConfig, generate_kb
from repro.data.tweets import (
    TweetSchema, TweetStreamConfig, generate_tweets, stream_chunks,
)
from repro.obs.metrics import (
    finalize_stats, merge_stats, reduce_stats, saturation, stat_add, stat_max,
)
from repro.obs.report import (
    attach_saturation, bottleneck_stage, format_explain, format_metrics_table,
    format_stage_table, to_json,
)
from repro.obs.trace import TraceConfig, Tracer, resolve_trace, span_or_null

CFG = ExecutionConfig(window_capacity=96, max_windows=4, bind_cap=1024,
                      scan_cap=128, out_cap=1024, intermediate_cap=512)


class ObsWorld:
    def __init__(self, num_tweets=36, seed=0):
        self.vocab = Vocab()
        self.kbd = generate_kb(
            self.vocab,
            KBConfig(num_artists=24, num_shows=12, filler_triples=80,
                     seed=seed),
        )
        self.tweets = TweetSchema.create(self.vocab)
        pool = np.concatenate([self.kbd.artist_ids, self.kbd.show_ids])
        rows = generate_tweets(
            self.vocab, self.tweets, pool,
            TweetStreamConfig(num_tweets=num_tweets, mentions_min=2,
                              mentions_max=3, seed=seed),
        )
        self.chunks = list(stream_chunks(rows, 96))

    def session(self, cfg):
        return Session(cfg, vocab=self.vocab, kb=self.kbd.kb)


@pytest.fixture(scope="module")
def oworld():
    w = ObsWorld()
    assert len(w.chunks) >= 3
    return w


def assert_bit_identical(outs_a, outs_b, tag=""):
    assert len(outs_a) == len(outs_b)
    for i, (a, b) in enumerate(zip(outs_a, outs_b)):
        for col, ca, cb in zip(a._fields, a, b):
            assert bool(np.all(np.asarray(ca) == np.asarray(cb))), (
                f"{tag} chunk {i} column {col} diverges")


# --------------------------------------------------------------------------
# tracer units: nesting, compile/steady split, config resolution
# --------------------------------------------------------------------------

def test_span_nesting_builds_paths():
    tr = Tracer(TraceConfig(fence=False))
    with tr.span("chunk"):
        with tr.span("stage:a"):
            pass
        with tr.span("stage:b"):
            with tr.span("probe"):
                pass
    with tr.span("chunk"):
        with tr.span("stage:a"):
            pass
    stats = tr.stats()
    assert set(stats) == {"chunk", "chunk/stage:a", "chunk/stage:b",
                          "chunk/stage:b/probe"}
    assert stats["chunk"]["count"] == 2
    assert stats["chunk/stage:a"]["count"] == 2
    assert stats["chunk/stage:b"]["count"] == 1


def test_first_sample_separated_from_steady():
    tr = Tracer(TraceConfig(fence=False))
    for _ in range(4):
        with tr.span("step"):
            time.sleep(0.001)
    s = tr.stats()["step"]
    assert s["count"] == 4
    assert s["steady"]["count"] == 3
    # the first (compile-inclusive) sample never enters the steady totals
    assert s["steady"]["total_s"] == pytest.approx(
        s["steady"]["mean_s"] * 3)
    assert s["first_s"] > 0.0
    tr.reset()
    assert tr.stats() == {}


def test_span_fence_blocks_on_device_value():
    tr = Tracer(TraceConfig())
    with tr.span("jit") as sp:
        out = sp.fence(jax.jit(lambda x: x * 2)(np.arange(8)))
    assert bool(np.all(np.asarray(out) == np.arange(8) * 2))
    assert tr.stats()["jit"]["count"] == 1


def test_resolve_trace_normalization():
    assert resolve_trace(None) is None
    assert resolve_trace(False) is None
    assert resolve_trace(True) == TraceConfig()
    cfg = TraceConfig(spans=False, metrics=True)
    assert resolve_trace(cfg) is cfg
    with pytest.raises(TypeError):
        resolve_trace("yes")


def test_spans_off_and_null_span_are_noop():
    tr = Tracer(TraceConfig(spans=False))
    with tr.span("ignored") as sp:
        assert sp.fence(123) == 123
    assert tr.stats() == {}
    with span_or_null(None, "also-ignored") as sp:
        assert sp.fence("v") == "v"


# --------------------------------------------------------------------------
# metric units: merge conventions encoded in the key names
# --------------------------------------------------------------------------

def test_stat_helpers_are_none_safe():
    stat_max(None, "hw_bind", 5)
    stat_add(None, "n_windows", 1)
    stats = {}
    stat_max(stats, "hw_bind", np.int32(3))
    stat_max(stats, "hw_bind", np.int32(7))
    stat_max(stats, "hw_bind", np.int32(2))
    stat_add(stats, "n_windows", np.int32(2))
    stat_add(stats, "n_windows", np.int32(3))
    assert int(stats["hw_bind"]) == 7
    assert int(stats["n_windows"]) == 5


def test_reduce_and_merge_follow_hw_vs_n_convention():
    # vmapped per-window stats: hw_* gauges reduce by max, n_* counters by sum
    per_window = {
        "hw_bind": np.array([3, 9, 4]),
        "n_retract": np.array([1, 0, 2]),
    }
    red = reduce_stats(per_window)
    assert int(red["hw_bind"]) == 9
    assert int(red["n_retract"]) == 3
    acc = {}
    merge_stats(acc, {"hw_bind": np.int32(5), "n_windows": np.int32(2)})
    merge_stats(acc, {"hw_bind": np.int32(3), "n_windows": np.int32(4)})
    fin = finalize_stats(acc)
    assert fin == {"hw_bind": 5, "n_windows": 6}
    assert all(isinstance(v, int) for v in fin.values())


def test_saturation_vs_caps():
    sat = saturation({"hw_bind": 512, "hw_probe_k": 8, "n_windows": 7},
                     {"bind_cap": 1024, "k_max": 8})
    assert sat["hw_bind"] == pytest.approx(0.5)
    assert sat["hw_probe_k"] == pytest.approx(1.0)
    assert "n_windows" not in sat      # counters have no capacity to saturate


# --------------------------------------------------------------------------
# report units
# --------------------------------------------------------------------------

def _span(first, steady):
    return {
        "count": 1 + len(steady), "first_s": first,
        "steady": {"count": len(steady), "total_s": sum(steady),
                   "mean_s": sum(steady) / len(steady) if steady else 0.0,
                   "min_s": min(steady) if steady else 0.0,
                   "max_s": max(steady) if steady else 0.0},
    }


def test_bottleneck_stage_prefix_and_compile_fallback():
    spans = {
        "chunk": _span(9.0, [5.0, 5.0]),            # enclosing span, excluded
        "chunk/stage:a": _span(8.0, [0.5, 0.4]),
        "chunk/stage:b": _span(1.0, [2.0, 2.1]),
    }
    # prefix matches the *last* path segment, skipping the chunk wrapper
    assert bottleneck_stage(spans, prefix="stage") == "chunk/stage:b"
    assert bottleneck_stage(spans) == "chunk"
    # single-pass traces (no steady samples) compete on the first sample
    only_first = {"chunk/stage:a": _span(8.0, []),
                  "chunk/stage:b": _span(1.0, [])}
    assert bottleneck_stage(only_first, prefix="stage") == "chunk/stage:a"
    assert bottleneck_stage({}, prefix="stage") is None


def test_tables_render():
    spans = {"stage:a": _span(0.5, [0.01, 0.02])}
    ops = {"op0": attach_saturation({"hw_bind": 10, "n_windows": 2},
                                    {"bind_cap": 100})}
    assert "stage:a" in format_stage_table(spans)
    table = format_metrics_table(ops)
    assert "hw_bind" in table and "10%" in table


# --------------------------------------------------------------------------
# uniform runtime surfaces: identical shape in all three modes
# --------------------------------------------------------------------------

def test_last_stats_uniform_across_modes_trace_off(oworld):
    for mode in MODES:
        reg = oworld.session(CFG.replace(mode=mode)).register(PQ.CQUERY1_RQ)
        reg.run(oworld.chunks)
        stats = reg.last_stats
        assert set(stats) == {"query", "mode", "overflow_totals", "channels",
                              "operators", "spans", "recovery", "degraded"}
        assert stats["mode"] == mode
        assert stats["recovery"]["enabled"] is False
        assert stats["degraded"] is False
        assert stats["operators"] == {}    # metrics need trace= enabled
        assert stats["spans"] == {}
        assert all(v == 0 for v in stats["overflow_totals"].values())
        if mode == "pipelined":
            assert stats["channels"]           # edges materialize here only
            for entry in stats["channels"].values():
                assert {"pushes", "pops", "depth_hw"} <= set(entry)
        else:
            assert stats["channels"] == {}
        json.dumps(stats)                  # surface is always serializable


def test_traced_metrics_agree_across_decomposed_modes(oworld):
    metrics = {}
    for mode in ("single_program", "pipelined"):
        reg = oworld.session(
            CFG.replace(mode=mode, trace=True)).register(PQ.CQUERY1_RQ)
        reg.run(oworld.chunks)
        stats = reg.last_stats
        assert stats["operators"], mode
        for entry in stats["operators"].values():
            assert {"counters", "caps", "saturation"} == set(entry)
        metrics[mode] = {
            op: entry["counters"]
            for op, entry in stats["operators"].items()
        }
        assert stats["spans"], mode        # spans recorded too
    # both decomposed modes run the same per-operator programs over the same
    # stream — the device-side counters must agree exactly
    assert metrics["single_program"] == metrics["pipelined"]


def test_monolithic_hw_out_matches_published_rows(oworld):
    reg = oworld.session(
        CFG.replace(mode="monolithic", trace=True)).register(PQ.CQUERY1_RQ)
    outs, _ = reg.run(oworld.chunks)
    counters = reg.last_stats["operators"][reg.query.name]["counters"]
    # hand-computed cross-check: the constructed-output high-water of the
    # single monolithic operator is exactly the largest published chunk
    hand_hw_out = max(len(to_host_rows(o)) for o in outs)
    assert counters["hw_out"] == hand_hw_out
    assert counters["n_windows"] >= len(oworld.chunks)
    assert 0 < counters["hw_bind"] <= CFG.bind_cap
    assert 0 < counters["hw_scan"] <= CFG.scan_cap


def test_pipelined_stage_spans_cover_every_operator(oworld):
    reg = oworld.session(
        CFG.replace(mode="pipelined", trace=True)).register(PQ.CQUERY1_RQ)
    reg.run(oworld.chunks)
    reg.run(oworld.chunks)                 # second pass fills steady samples
    spans = reg.last_stats["spans"]
    stages = {p.split("/")[-1] for p in spans
              if p.split("/")[-1].startswith("stage:")}
    expected = {"stage:source"} | {
        "stage:%s" % name for name in reg.operators}
    assert stages == expected
    for path, s in spans.items():
        if path.split("/")[-1].startswith("stage:"):
            assert s["count"] > 0 and s["steady"]["count"] > 0, path
    assert bottleneck_stage(spans, prefix="stage") in {
        p for p in spans if p.split("/")[-1].startswith("stage:")}


# --------------------------------------------------------------------------
# the hard constraint: tracing off = zero overhead, tracing on = same bits
# --------------------------------------------------------------------------

def test_off_path_jaxpr_unchanged_and_stats_free(oworld, monkeypatch):
    """With tracing off the operator step must trace the *same program* as a
    build with no observability at all: no stats helper runs during trace
    (proved by poisoning them), and the stats twin traces a different
    program (the metrics really are new ops, not free)."""
    reg = oworld.session(CFG.replace(mode="single_program")).register(
        PQ.CQUERY1_RQ)
    op = next(iter(reg.operators.values()))
    args = (tuple(oworld.chunks[:1]), op.kb, op.env)
    jaxpr_off = jax.make_jaxpr(op._process_impl)(*args)

    def poisoned(*a, **k):
        raise AssertionError("stats helper executed on the trace-off path")

    import repro.core.algebra as algebra
    import repro.core.engine as engine
    import repro.obs.metrics as metrics
    for mod in (engine, algebra, metrics):
        for name in ("stat_max", "stat_add", "reduce_stats"):
            if hasattr(mod, name):
                monkeypatch.setattr(mod, name, poisoned)
    jaxpr_off_poisoned = jax.make_jaxpr(op._process_impl)(*args)
    assert str(jaxpr_off) == str(jaxpr_off_poisoned)
    monkeypatch.undo()

    import functools
    jaxpr_on = jax.make_jaxpr(
        functools.partial(op._process_impl, with_stats=True))(*args)
    assert str(jaxpr_on) != str(jaxpr_off)


def test_traced_outputs_bit_identical_to_untraced(oworld):
    for mode in MODES:
        off = oworld.session(CFG.replace(mode=mode)).register(PQ.CQUERY1_RQ)
        on = oworld.session(
            CFG.replace(mode=mode, trace=True)).register(PQ.CQUERY1_RQ)
        outs_off, ovf_off = off.run(oworld.chunks)
        outs_on, ovf_on = on.run(oworld.chunks)
        assert_bit_identical(outs_off, outs_on, mode)
        assert ovf_off == ovf_on


# --------------------------------------------------------------------------
# explain
# --------------------------------------------------------------------------

def test_explain_reports_planner_decisions(oworld):
    reg = oworld.session(
        CFG.replace(mode="single_program", kb_method="auto")).register(
        PQ.CQUERY1_RQ)
    art = reg.explain()
    assert art["query"] == reg.query.name
    assert art["kb_method"] == "auto"
    assert set(art["operators"]) == set(reg.operators)
    saw_kb_join = False
    for name, op_art in art["operators"].items():
        assert {"scan_cap", "bind_cap", "out_cap", "k_max"} <= set(
            op_art["caps"])
        assert isinstance(op_art["delta_capable"], bool)
        for step in op_art["steps"]:
            if step["step"] == "KBJoin":
                saw_kb_join = True
                assert step["method"] in ("scan", "probe")
                assert step.get("est_rows") is not None
                if step["method"] == "probe":
                    assert step["k_max"] >= 1
    assert saw_kb_join
    rendered = format_explain(art)
    assert reg.query.name in rendered and "KBJoin" in rendered
    json.dumps(art)


def test_to_json_bundles_stats_and_explain(oworld):
    reg = oworld.session(
        CFG.replace(mode="monolithic", trace=True)).register(PQ.CQUERY1_RQ)
    reg.run(oworld.chunks[:1])
    payload = to_json(reg.last_stats, explain=reg.explain())
    assert payload["query"] == reg.query.name
    assert "explain" in payload and "spans" in payload
    json.dumps(payload)
