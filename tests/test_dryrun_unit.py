"""Dry-run machinery unit tests (no 512-device trick needed — these test the
host-side logic: HLO collective parsing, input specs, skip policy, EP-combine
axis selection)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# NOTE: importing repro.launch.dryrun would set XLA_FLAGS; import the module
# WITHOUT triggering re-initialization concerns (jax is already initialized
# with one device by earlier imports, so the flag is inert here).
from repro.launch import dryrun
from repro.configs import get_config, get_shape


def test_collective_parser_counts_bytes():
    hlo = """
  %ar = f32[16,4096,2048]{2,1,0} all-reduce(%x), replica_groups=[16,16]<=[256]
  %ag.1 = bf16[1024]{0} all-gather(%y), dimensions={0}
  %s = (f32[8]{0}, u32[]) all-to-all-start(%z), channel_id=3
  %d = f32[8]{0} all-to-all-done(%s)
  %rs = (f32[64,32]{1,0}, f32[64]{0}) reduce-scatter(%a, %b), dimensions={0}
  %cp = u8[100]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %not_a_coll = f32[4]{0} add(%p, %q)
"""
    out = dryrun.collective_bytes(hlo)
    assert out["all-reduce"] == 16 * 4096 * 2048 * 4
    assert out["all-gather"] == 1024 * 2
    assert out["all-to-all"] == 8 * 4 + 4            # tuple incl. u32[] scalar
    assert out["reduce-scatter"] == 64 * 32 * 4 + 64 * 4
    assert out["collective-permute"] == 100
    assert out["count"] == 5                         # -done not double counted


def test_shape_bytes_handles_layouts_and_tuples():
    assert dryrun._shape_bytes("f32[2,3]{1,0}") == 24
    assert dryrun._shape_bytes("(bf16[4]{0}, s32[2]{0})") == 8 + 8
    assert dryrun._shape_bytes("pred[8]") == 8


def test_input_specs_shapes():
    cfg = get_config("qwen2-1.5b")
    tr = dryrun.input_specs(cfg, get_shape("train_4k"))
    assert tr["tokens"].shape == (256, 4096)
    assert tr["labels"].shape == (256, 4096)
    pf = dryrun.input_specs(cfg, get_shape("prefill_32k"))
    assert pf["tokens"].shape == (32, 32768)
    dc = dryrun.input_specs(cfg, get_shape("decode_32k"))
    assert dc["tokens"].shape == (128, 1)

    vlm = get_config("qwen2-vl-7b")
    trv = dryrun.input_specs(vlm, get_shape("train_4k"))
    assert trv["embeds"].shape == (256, 4096, vlm.d_model)
    assert trv["positions"].shape == (3, 256, 4096)   # M-RoPE 3D positions

    mg = get_config("musicgen-large")
    trm = dryrun.input_specs(mg, get_shape("train_4k"))
    assert trm["embeds"].shape == (256, 4096, mg.d_model)

    dcm = dryrun.input_specs(mg, get_shape("decode_32k"))
    assert dcm["tokens"].shape == (128, 1, mg.num_codebooks)


def test_skip_policy_matches_design():
    long = get_shape("long_500k")
    expect_skip = {"qwen2-vl-7b", "deepseek-v2-236b", "minicpm3-4b",
                   "qwen2-1.5b", "olmo-1b", "musicgen-large"}
    expect_run = {"mixtral-8x22b", "h2o-danube-1.8b", "mamba2-130m",
                  "jamba-v0.1-52b"}
    for arch in expect_skip:
        assert dryrun.should_skip(get_config(arch), long), arch
    for arch in expect_run:
        assert dryrun.should_skip(get_config(arch), long) is None, arch
    # every other shape always runs
    for arch in expect_skip | expect_run:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert dryrun.should_skip(get_config(arch), get_shape(s)) is None


def test_ep_combine_axes_divisibility():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    ds = get_config("deepseek-v2-236b")      # 160 experts % 16 == 0
    assert dryrun._ep_combine_axes(ds, FakeMesh(), 16) == ("model",)
    mx = get_config("mixtral-8x22b")          # 8 experts % 16 != 0
    assert dryrun._ep_combine_axes(mx, FakeMesh(), 16) is None
    dense = get_config("olmo-1b")
    assert dryrun._ep_combine_axes(dense, FakeMesh(), 16) is None
    # no grouping -> no combine constraint
    assert dryrun._ep_combine_axes(ds, FakeMesh(), 1) is None


def test_two_point_extrapolation_math():
    """corrected = u1 + (n-1)*(u2-u1): exact for linear-in-periods costs."""
    n = 24
    outside, per_period = 7.0, 3.0
    u1 = outside + 1 * per_period
    u2 = outside + 2 * per_period
    corrected = u1 + (n - 1) * max(0.0, u2 - u1)
    assert corrected == outside + n * per_period
