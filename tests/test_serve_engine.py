"""Multi-query serving layer tests: ServeEngine + QueryAdmission.

The acceptance bar of the serving subsystem: a population of standing
queries (exact duplicates, class variants sharing a KB-join prefix,
filter-threshold variants) served by ONE engine must publish streams
**bit-identical** to each query running in its own single-query Session —
with shared-plan dedup on and off — while ``last_stats`` proves the
sharing actually happened (plan groups, prefix groups, vmap cohorts).
"""
import warnings

import numpy as np
import pytest

from repro.core.rdf import Vocab
from repro.core.session import ExecutionConfig, Session
from repro.data.dbpedia import KBConfig, generate_kb
from repro.data.tweets import (
    TweetSchema, TweetStreamConfig, generate_tweets, stream_chunks,
)
from repro.launch.dscep_run import serve_population
from repro.serve.batcher import QueryAdmission, QueryRequest
from repro.serve.engine import ServeEngine

CFG = ExecutionConfig(mode="monolithic", window_capacity=96, max_windows=4,
                      bind_cap=1024, scan_cap=128, out_cap=1024,
                      out_stream_cap=2048, intermediate_cap=512)


class ServeWorld:
    def __init__(self, num_tweets=36, seed=0):
        self.vocab = Vocab()
        self.kbd = generate_kb(
            self.vocab,
            KBConfig(num_artists=24, num_shows=12, filler_triples=80,
                     seed=seed),
        )
        self.tweets = TweetSchema.create(self.vocab)
        pool = np.concatenate([self.kbd.artist_ids, self.kbd.show_ids])
        rows = generate_tweets(
            self.vocab, self.tweets, pool,
            TweetStreamConfig(num_tweets=num_tweets, mentions_min=2,
                              mentions_max=3, seed=seed),
        )
        self.chunks = list(stream_chunks(rows, 96))
        # the benchmark population: dup* (plan dedup) / cls* (shared
        # KB-join prefix) / thr* (vmap cohort of filter constants)
        self.texts = serve_population(9)

    def session(self, cfg=CFG):
        return Session(cfg, vocab=self.vocab, kb=self.kbd.kb)


@pytest.fixture(scope="module")
def world():
    w = ServeWorld()
    assert len(w.chunks) >= 3
    return w


@pytest.fixture(scope="module")
def reference(world):
    """Every population query in its own single-query Session."""
    outs, ovf = {}, {}
    for t in world.texts:
        reg = world.session().register(t)
        outs[reg.query.name], o = reg.run(world.chunks)
        ovf[reg.query.name] = o[reg.query.name]
    return outs, ovf


def assert_bit_identical(outs_a, outs_b, tag=""):
    assert len(outs_a) == len(outs_b), tag
    for i, (a, b) in enumerate(zip(outs_a, outs_b)):
        for col, ca, cb in zip(a._fields, a, b):
            assert bool(np.all(np.asarray(ca) == np.asarray(cb))), (
                f"{tag} chunk {i} column {col} diverges")


# --------------------------------------------------------------------------
# bit-identity vs independent sessions, dedup on AND off
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dedup", [True, False])
def test_serving_bit_identical_to_independent_sessions(world, reference,
                                                       dedup):
    ref_outs, ref_ovf = reference
    eng = world.session().serve(dedup=dedup)
    for t in world.texts:
        eng.register(t)
    outs, ovf = eng.run(world.chunks)
    assert set(outs) == set(ref_outs)
    for name in ref_outs:
        assert_bit_identical(outs[name], ref_outs[name],
                             f"dedup={dedup} {name}")
        assert ovf[name] == ref_ovf[name], (name, ovf[name], ref_ovf[name])


def test_process_chunk_matches_run(world):
    eng = world.session().serve()
    for t in world.texts:
        eng.register(t)
    ref, _ = eng.run(world.chunks)
    eng2 = world.session().serve()
    for t in world.texts:
        eng2.register(t)
    for i, chunk in enumerate(world.chunks):
        outs = eng2.process_chunk(chunk)
        for name, o in outs.items():
            assert_bit_identical([o], [ref[name][i]], f"{name} chunk {i}")


# --------------------------------------------------------------------------
# the schedule actually shares
# --------------------------------------------------------------------------

def test_last_stats_reports_sharing(world):
    eng = world.session().serve()
    for t in world.texts:
        eng.register(t)
    eng.run(world.chunks)
    st = eng.last_stats
    assert st["queries"] == len(world.texts)
    # the three dup* registrations collapse into one group
    assert st["distinct_plans"] < st["queries"]
    assert st["shared_plan_hits"] > 0
    # cls* variants share their KB-join prefix
    assert st["prefix_groups"], st
    for pg in st["prefix_groups"]:
        assert pg["prefix_len"] >= 1
        assert pg["kb_joins_shared"] >= 1
        assert len(pg["queries"]) >= 2
    assert st["shared_prefix_hits"] > 0
    # thr* variants vmap-batch into one cohort
    assert st["batch_sizes"] and max(st["batch_sizes"]) >= 2
    assert set(st["overflow_totals"]) == set(eng.units)
    assert st["chunks"] == len(world.chunks)


def test_dedup_off_keeps_cohorts_but_no_groups(world):
    eng = world.session().serve(dedup=False)
    for t in world.texts:
        eng.register(t)
    st = eng.last_stats
    assert st["distinct_plans"] == len(world.texts)
    assert not st["prefix_groups"]
    assert st["batch_sizes"] and max(st["batch_sizes"]) >= 2


def test_batch_off_reduces_to_operators(world):
    eng = world.session().serve(dedup=False, batch=False)
    for t in world.texts:
        eng.register(t)
    st = eng.last_stats
    assert not st["batch_sizes"] and not st["prefix_groups"]
    assert st["singletons"] == len(world.texts)


def test_trace_metrics_populate_per_query_operator_stats(world):
    eng = world.session(CFG.replace(trace=True)).serve()
    for t in world.texts[:4]:
        eng.register(t)
    eng.process_chunk(world.chunks[0])
    st = eng.last_stats
    assert st["operators"], "trace=True must collect per-query metrics"
    for name, rep in st["operators"].items():
        assert name in eng.units
        assert "n_windows" in rep["counters"]
        assert rep["counters"]["n_windows"] > 0
    # trace off: no per-query metrics collected
    eng2 = world.session().serve()
    eng2.register(world.texts[0])
    eng2.process_chunk(world.chunks[0])
    assert not eng2.last_stats["operators"]


# --------------------------------------------------------------------------
# registration surface
# --------------------------------------------------------------------------

def test_duplicate_name_raises_with_both_texts_and_replace_works(world):
    eng = world.session().serve()
    eng.register(world.texts[0])
    name = next(iter(eng.units))
    with pytest.raises(ValueError, match="already registered") as ei:
        eng.register(world.texts[0])
    msg = str(ei.value)
    assert "existing:" in msg and "new:" in msg and "replace=True" in msg
    unit = eng.register(world.texts[0], replace=True)
    assert unit.name == name and eng.units[name] is unit


def test_unregister_drops_query_and_stats(world):
    eng = world.session().serve()
    for t in world.texts[:3]:
        eng.register(t)
    eng.process_chunk(world.chunks[0])
    victim = next(iter(eng.units))
    eng.unregister(victim)
    assert victim not in eng.units
    assert victim not in eng.overflow_totals()
    outs = eng.process_chunk(world.chunks[1])
    assert victim not in outs
    with pytest.raises(KeyError):
        eng.unregister(victim)


def test_session_serve_factory(world):
    eng = world.session().serve(dedup=False)
    assert isinstance(eng, ServeEngine) and eng.dedup is False


# --------------------------------------------------------------------------
# admission front-end
# --------------------------------------------------------------------------

def test_admission_slots_queue_and_backpressure(world):
    eng = world.session().serve()
    adm = eng.admission(num_slots=2, queue_cap=2)
    reqs = [QueryRequest(t) for t in world.texts[:5]]
    assert adm.submit(reqs[0]) and adm.submit(reqs[1])
    assert len(adm.active()) == 2                 # slots full
    assert adm.submit(reqs[2]) and adm.submit(reqs[3])
    assert len(adm.queue) == 2                    # queued, no free slot
    assert not adm.submit(reqs[4])                # queue full -> rejected
    assert adm.counters["rejected_queries"] == 1
    first = adm.active()[0]
    adm.retire(first)                             # frees slot, backfills
    assert first not in adm.active() and len(adm.active()) == 2
    assert adm.counters["retired"] == 1
    with pytest.raises(KeyError):
        adm.retire("nope")
    st = adm.stats()
    assert st["occupied_slots"] == 2 and st["slots"] == 2
    assert eng.last_stats["admission"]["admitted"] == adm.counters["admitted"]


def test_admission_chunk_queues_round_robin_and_drain(world, reference):
    ref_outs, _ = reference
    eng = world.session().serve()
    adm = eng.admission(num_slots=4, chunk_queue_cap=2)
    for t in world.texts[:3]:
        adm.submit(QueryRequest(t))
    assert adm.offer_chunk(world.chunks[0], tenant="a")
    assert adm.offer_chunk(world.chunks[1], tenant="a")
    assert not adm.offer_chunk(world.chunks[2], tenant="a")   # bounded
    assert adm.counters["chunks_rejected"] == 1
    assert adm.offer_chunk(world.chunks[2], tenant="b")
    # round-robin: a, then b, then a again
    tenants = []
    results = []
    while adm.pending_chunks():
        tenant, outs = adm.tick()
        tenants.append(tenant)
        results.append(outs)
    assert tenants == ["a", "b", "a"]
    assert adm.tick() is None
    # served outputs are the single-session bytes for those chunks
    for outs, chunk_idx in zip(results, (0, 2, 1)):
        for name, o in outs.items():
            assert_bit_identical([o], [ref_outs[name][chunk_idx]],
                                 f"admission {name} chunk {chunk_idx}")
    assert adm.counters["chunks_processed"] == 3


def test_admission_drain_empties_all_tenants(world):
    eng = world.session().serve()
    adm = eng.admission(num_slots=2)
    adm.submit(QueryRequest(world.texts[0]))
    adm.offer_chunk(world.chunks[0], tenant="x")
    adm.offer_chunk(world.chunks[1], tenant="y")
    outs = adm.drain()
    assert len(outs) == 2 and adm.pending_chunks() == 0


def test_retire_tears_down_tenant_and_keeps_round_robin_fair(world):
    """Regression: retiring a tenant's last query used to leave its chunk
    queue and round-robin membership behind forever (a burned tick slot per
    revolution), and removing it without re-anchoring the cursor would skip
    or double-serve a neighbouring tenant."""
    eng = world.session().serve()
    adm = eng.admission(num_slots=8, chunk_queue_cap=4)
    names = {}
    for tenant, text in zip(("a", "b", "c"), world.texts[:3]):
        adm.submit(QueryRequest(text, tenant=tenant))
        names[tenant] = adm.active()[-1]
    adm.submit(QueryRequest(world.texts[3], tenant="c"))
    second_c = adm.active()[-1]
    for t in ("a", "b", "c"):
        adm.offer_chunk(world.chunks[0], tenant=t)
        adm.offer_chunk(world.chunks[1], tenant=t)
    # advance the rotation so the cursor sits just past tenant a
    tenant, _ = adm.tick()
    assert tenant == "a"
    # retire a's only query while a chunk is still queued: drop policy
    adm.retire(names["a"], drain=False)
    assert adm.counters["chunks_dropped"] == 1
    assert "a" not in adm.chunk_queues and "a" not in adm._rr
    # the rotation resumes at a's neighbour and alternates fairly
    served = [adm.tick()[0] for _ in range(4)]
    assert served == ["b", "c", "b", "c"]
    assert adm.tick() is None
    # retiring one of two queries of a live tenant keeps its queue
    adm.offer_chunk(world.chunks[0], tenant="c")
    adm.retire(second_c)
    assert "c" in adm.chunk_queues and "c" in adm._rr
    assert adm.pending_chunks() == 1
    # drain policy: the retiring query still sees its tenant's last chunks
    processed = adm.counters["chunks_processed"]
    adm.retire(names["c"], drain=True)
    assert adm.counters["chunks_processed"] == processed + 1
    assert "c" not in adm.chunk_queues and adm._rr == ["b"]
    assert adm.pending_chunks() == 0


# --------------------------------------------------------------------------
# deprecation shims: the LM scaffolding moved to repro.serve.lm
# --------------------------------------------------------------------------

def test_lm_shims_warn_and_resolve():
    import repro.serve.batcher as batcher_mod
    import repro.serve.engine as engine_mod
    from repro.serve import lm

    for mod, names in ((batcher_mod, ("ContinuousBatcher", "Request",
                                      "SlotState")),
                       (engine_mod, ("make_serve_fns", "greedy_token",
                                     "sample_token", "generate"))):
        for n in names:
            with warnings.catch_warnings(record=True) as w:
                warnings.simplefilter("always")
                obj = getattr(mod, n)
            assert obj is getattr(lm, n)
            assert any(issubclass(x.category, DeprecationWarning)
                       and "repro.serve.lm" in str(x.message) for x in w), n
    with pytest.raises(AttributeError):
        engine_mod.not_a_thing


def test_direct_lm_import_does_not_warn():
    import importlib

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        import repro.serve.lm as lm
        importlib.reload(lm)
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)], (
        [str(x.message) for x in w])
