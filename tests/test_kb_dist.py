"""Distributed (row-sharded) KB join: per-block union ≡ full-KB join, the
shard_map path on the host mesh, and probe-per-shard correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import algebra
from repro.core.kb import kb_from_triples, shard_rows
from repro.core.kb_dist import kb_join_blocks_reference, kb_join_sharded
from repro.core.pattern import Bindings, CompiledPattern, Slot


def _world(n_rows=96, seed=0, cap=128):
    rng = np.random.default_rng(seed)
    base = 5000
    rows = [
        (int(rng.integers(base, base + 40)), int(rng.integers(1, 4)),
         int(rng.integers(base, base + 40)))
        for _ in range(n_rows)
    ]
    kb = kb_from_triples(rows, capacity=cap)
    cols = rng.integers(base, base + 40, size=(16, 2)).astype(np.uint32)
    bind = Bindings(jnp.asarray(cols), jnp.ones((16,), bool),
                    jnp.zeros((), bool))
    pat = CompiledPattern(Slot.bound(0), Slot.const_(2), Slot.free(1))
    return kb, bind, pat


def _rows(b: Bindings):
    c = np.asarray(b.cols)[np.asarray(b.valid)]
    return sorted(map(tuple, c.tolist()))


@pytest.mark.parametrize("n_shards", [1, 2, 4, 8])
@pytest.mark.parametrize("method", ["scan", "probe"])
def test_block_union_equals_full_join(n_shards, method):
    kb, bind, pat = _world()
    blocks = shard_rows(kb, n_shards)
    full = algebra.kb_join(bind, kb, pat, out_cap=512, method=method)
    split = kb_join_blocks_reference(bind, blocks, pat, out_cap=512,
                                     n=n_shards, method=method)
    assert _rows(split) == _rows(full)
    assert not bool(split.overflow)


def test_shard_map_path_matches_reference():
    kb, bind, pat = _world(seed=3)
    n = jax.device_count()              # 1 on the CPU host — structural test
    blocks = shard_rows(kb, n)
    mesh = jax.make_mesh((n,), ("model",))
    got = kb_join_sharded(bind, blocks, pat, out_cap=512, mesh=mesh)
    want = kb_join_blocks_reference(bind, blocks, pat, out_cap=512, n=n)
    assert _rows(got) == _rows(want)
    np.testing.assert_array_equal(np.asarray(got.overflow),
                                  np.asarray(want.overflow))


def test_shard_local_overflow_reported():
    kb, bind, pat = _world(seed=5)
    blocks = shard_rows(kb, 4)
    # absurdly small per-shard capacity forces a local clip
    out = kb_join_blocks_reference(bind, blocks, pat, out_cap=8, n=4)
    assert bool(out.overflow)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_block_union_fused_equals_unfused(use_pallas):
    """Per-shard fused join->compaction must not change the shard union."""
    kb, bind, pat = _world(seed=7)
    blocks = shard_rows(kb, 4)
    want = kb_join_blocks_reference(bind, blocks, pat, out_cap=512, n=4)
    got = kb_join_blocks_reference(bind, blocks, pat, out_cap=512, n=4,
                                   use_pallas=use_pallas, fuse_compaction=True)
    np.testing.assert_array_equal(np.asarray(got.cols), np.asarray(want.cols))
    np.testing.assert_array_equal(np.asarray(got.valid), np.asarray(want.valid))
    np.testing.assert_array_equal(np.asarray(got.overflow),
                                  np.asarray(want.overflow))


@pytest.mark.parametrize("use_pallas", [False, True])
def test_shard_map_fused_matches_reference(use_pallas):
    """The fused join under shard_map keeps the no-collective union exact."""
    kb, bind, pat = _world(seed=11)
    n = jax.device_count()              # 1 on the CPU host — structural test
    blocks = shard_rows(kb, n)
    got = kb_join_sharded(bind, blocks, pat, out_cap=512, mesh=jax.make_mesh(
        (n,), ("model",)), use_pallas=use_pallas, fuse_compaction=True)
    want = kb_join_blocks_reference(bind, blocks, pat, out_cap=512, n=n)
    np.testing.assert_array_equal(np.asarray(got.cols), np.asarray(want.cols))
    np.testing.assert_array_equal(np.asarray(got.valid), np.asarray(want.valid))
    np.testing.assert_array_equal(np.asarray(got.overflow),
                                  np.asarray(want.overflow))


def test_shard_local_overflow_reported_fused():
    kb, bind, pat = _world(seed=5)
    blocks = shard_rows(kb, 4)
    out = kb_join_blocks_reference(bind, blocks, pat, out_cap=8, n=4,
                                   fuse_compaction=True)
    assert bool(out.overflow)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 200), n_shards=st.sampled_from([2, 4]))
def test_block_union_property(seed, n_shards):
    kb, bind, pat = _world(seed=seed)
    blocks = shard_rows(kb, n_shards)
    full = algebra.kb_join(bind, kb, pat, out_cap=512)
    split = kb_join_blocks_reference(bind, blocks, pat, out_cap=512,
                                     n=n_shards)
    assert _rows(split) == _rows(full)
