"""Fault injection, checkpoint/restart and graceful degradation.

The robustness acceptance gates:

* **chaos bit-exactness** — a pipelined run under a seeded
  :class:`~repro.core.faults.FaultPlan` covering all five fault kinds must
  publish byte-identical outputs (and overflow counts) to the fault-free
  single-program run, with every scheduled event actually fired and zero
  lost or duplicated sink rows;
* **graceful degradation** — a chunk past ``max_restarts`` is routed
  through the channel-free monolithic fallback, still bit-exact, with
  ``last_stats["degraded"]`` raised;
* **zero overhead** — with ``faults=None`` the per-stage jaxprs are
  byte-identical to a build with the chaos machinery enabled (everything is
  host-side);
* **diagnosable stalls** — a wedged schedule raises
  :class:`~repro.core.recovery.PipelineStalledError` naming the blocked
  edge instead of spinning;
* **ingest hygiene** — malformed chunks are rejected at the gate
  (:class:`~repro.core.recovery.ChunkRejectedError`), a malformed ``.rq``
  file exits the launcher with code 2 + line/column context, and a
  repeatedly-faulting serving tenant is quarantined without taking the
  engine down.
"""
import functools
import os
import subprocess
import sys
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channel as chmod
from repro.core import paper_queries as PQ
from repro.core.faults import (
    FAULT_KINDS, FaultEvent, FaultInjector, FaultPlan, corrupt_batch,
    validate_chunk,
)
from repro.core.recovery import (
    ChunkRejectedError, PipelineStalledError, RecoveryConfig,
    empty_recovery_stats,
)
from repro.core.rdf import Vocab
from repro.core.session import ExecutionConfig, Session
from repro.data.dbpedia import KBConfig, generate_kb
from repro.data.tweets import (
    TweetSchema, TweetStreamConfig, generate_tweets, stream_chunks,
)
from repro.obs.report import format_recovery_table
from repro.serve.batcher import QueryAdmission, QueryRequest

CFG = ExecutionConfig(window_capacity=96, max_windows=4, bind_cap=1024,
                      scan_cap=128, out_cap=1024, intermediate_cap=512)


class ChaosWorld:
    """Multi-chunk co-mention stream (same shape the pipeline tests use)."""

    def __init__(self, num_tweets=36, seed=0):
        self.vocab = Vocab()
        self.kbd = generate_kb(
            self.vocab,
            KBConfig(num_artists=24, num_shows=12, filler_triples=80,
                     seed=seed),
        )
        self.tweets = TweetSchema.create(self.vocab)
        pool = np.concatenate([self.kbd.artist_ids, self.kbd.show_ids])
        self.rows = generate_tweets(
            self.vocab, self.tweets, pool,
            TweetStreamConfig(num_tweets=num_tweets, mentions_min=2,
                              mentions_max=3, seed=seed),
        )
        self.chunks = list(stream_chunks(self.rows, 96))

    def session(self, **over):
        cfg = CFG.replace(**over) if over else CFG
        return Session(cfg, vocab=self.vocab, kb=self.kbd.kb)


@pytest.fixture(scope="module")
def world():
    w = ChaosWorld()
    assert len(w.chunks) >= 3, "need a multi-chunk stream for chaos"
    return w


@pytest.fixture(scope="module")
def baseline(world):
    """Fault-free single-program run of q15 — the bit-exactness referee."""
    q = PQ.q15(world.vocab, world.tweets, world.kbd.schema)
    reg = world.session(mode="single_program").register(q)
    outs, ovf = reg.run(world.chunks)
    return q, reg, outs, ovf


def assert_bit_identical(outs_a, outs_b, tag=""):
    assert len(outs_a) == len(outs_b), tag
    for i, (a, b) in enumerate(zip(outs_a, outs_b)):
        for col, ca, cb in zip(a._fields, a, b):
            assert bool(np.all(np.asarray(ca) == np.asarray(cb))), (
                f"{tag} chunk {i} col {col} diverges")


# --------------------------------------------------------------------------
# the plan / injector / validator layer (pure host, no jit)
# --------------------------------------------------------------------------

def test_fault_plan_seeded_is_deterministic():
    a = FaultPlan.seeded(7, ("source", "opA"), num_chunks=5, n_events=6)
    b = FaultPlan.seeded(7, ("source", "opA"), num_chunks=5, n_events=6)
    c = FaultPlan.seeded(8, ("source", "opA"), num_chunks=5, n_events=6)
    assert a == b and a.events == b.events
    assert a != c
    assert sum(a.counts().values()) == 6
    for ev in a.events:
        assert ev.kind in FAULT_KINDS
        assert 0 <= ev.chunk < 5
        if ev.kind == "corrupt_chunk":
            assert ev.stage == "ingest"
        else:
            assert ev.stage in ("source", "opA")


def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent("explode", "source", 0)
    with pytest.raises(ValueError):
        FaultEvent("crash_stage", "source", -1)
    with pytest.raises(ValueError):
        FaultPlan.seeded(0, ("source",), num_chunks=0)


def test_fault_injector_fires_each_event_once():
    plan = FaultPlan((FaultEvent("crash_stage", "s", 1),
                      FaultEvent("corrupt_chunk", "ingest", 2)))
    inj = FaultInjector(plan)
    assert not inj.take("crash_stage", "s", 0)      # wrong chunk
    assert not inj.take("crash_stage", "t", 1)      # wrong stage
    assert inj.take("crash_stage", "s", 1)
    assert not inj.take("crash_stage", "s", 1)      # fires once
    # corrupt_chunk matches regardless of the stage the caller names
    assert inj.take("corrupt_chunk", "whatever", 2)
    assert inj.pending() == 0
    assert inj.fired == {"crash_stage": 1, "corrupt_chunk": 1,
                         "drop_payload": 0, "duplicate_payload": 0,
                         "stall_stage": 0}
    assert inj.fired_total() == 2


def test_validate_chunk_and_corrupt_batch(world):
    chunk = world.chunks[0]
    assert validate_chunk(chunk, world.vocab) == []
    bad = corrupt_batch(chunk)
    reasons = validate_chunk(bad, world.vocab)
    assert reasons, "corrupt_batch must trip the gate"
    assert any("predicate" in r for r in reasons)
    assert any("row-node" in r for r in reasons)
    # the gate also works without a vocab (structural band bounds)
    assert validate_chunk(bad) != []
    # a non-boolean valid mask is rejected outright
    intmask = chunk._replace(valid=chunk.valid.astype(jnp.int32))
    assert validate_chunk(intmask, world.vocab) == [
        "valid mask must be boolean, got dtype int32"]
    # per-event size cap: every graph in this stream is small
    assert validate_chunk(chunk, world.vocab, max_graph_size=1) != []


def test_channel_snapshot_restore_roundtrip():
    example = {"x": jnp.zeros((4,), jnp.int32)}
    ch = chmod.make_channel(example, 3)
    ch = chmod.push_jit(ch, {"x": jnp.arange(4, dtype=jnp.int32)})
    snap = chmod.snapshot(ch)
    assert isinstance(np.asarray(jax.tree.leaves(snap)[0]), np.ndarray)
    restored = chmod.restore(snap)
    restored, payload, ok = chmod.pop_jit(restored)
    assert bool(ok)
    assert np.array_equal(np.asarray(payload["x"]), np.arange(4))
    assert int(restored.size) == 0


# --------------------------------------------------------------------------
# chaos: every fault kind, recovered bit-exact
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def chaos(world, baseline):
    """One pipelined run under a plan covering all five fault kinds."""
    q, reg_s, _, _ = baseline
    dag = reg_s.dag
    up = [n for n in dag.subqueries if n != dag.final]
    drop_stage = up[0] if up else "source"
    plan = FaultPlan((
        FaultEvent("corrupt_chunk", "ingest", 0),
        FaultEvent("stall_stage", dag.final, 0),
        FaultEvent("drop_payload", drop_stage, 1),
        FaultEvent("crash_stage", "source", 2),
        FaultEvent("duplicate_payload", "source", 2),
    ))
    reg_p = world.session(
        mode="pipelined", faults=plan,
        recovery=RecoveryConfig(checkpoint_every=2),
    ).register(q)
    outs, ovf = reg_p.run(world.chunks)
    return plan, reg_p, outs, ovf


def test_chaos_all_kinds_recover_bit_exact(baseline, chaos):
    _, _, outs_s, ovf_s = baseline
    plan, reg_p, outs_p, ovf_p = chaos
    assert_bit_identical(outs_s, outs_p, "chaos vs fault-free")
    assert ovf_p == ovf_s


def test_chaos_exercises_every_scheduled_event(chaos):
    plan, reg_p, _, _ = chaos
    rec = reg_p.last_stats["recovery"]
    assert rec["enabled"]
    assert rec["injected"] == plan.counts() == rec["scheduled"], (
        "every scheduled fault must fire exactly once")
    assert rec["retries"] >= 1          # the injected stall was retried
    assert rec["restarts"] >= 2         # crash + at least one desync restore
    assert rec["replayed"] >= 1
    assert rec["checkpoints"] >= 2      # initial + cadence/boundary
    assert rec["checkpoint_bytes"] > 0
    assert rec["corrupt_recovered"] == 1
    assert rec["degraded_chunks"] == []
    assert reg_p.last_stats["degraded"] is False


def test_chaos_leaves_channels_drained(chaos):
    _, reg_p, _, _ = chaos
    for edge, st in reg_p.runtime.channel_stats().items():
        assert st["size"] == 0, edge
        assert st["overflows"] == 0, edge
        assert st["pushes"] >= st["pops"], edge


def test_recovery_table_renders(chaos):
    _, reg_p, _, _ = chaos
    txt = format_recovery_table(reg_p.last_stats["recovery"])
    assert "injected:crash_stage" in txt
    assert "restarts" in txt and "deduped" in txt
    # the empty surface renders too (monolithic/single-program sessions)
    assert "degraded_chunks" in format_recovery_table(empty_recovery_stats())


def test_resilient_runtime_rejects_malformed_ingest(chaos, world):
    _, reg_p, _, _ = chaos
    rt = reg_p.runtime
    before = rt.recovery_stats()["rejected"]
    with pytest.raises(ChunkRejectedError) as ei:
        rt.feed(corrupt_batch(world.chunks[0]))
    assert ei.value.reasons
    assert rt.recovery_stats()["rejected"] == before + 1
    assert rt._pending_count() == 0, "a rejected chunk must leave no state"


def test_degraded_chunk_takes_lossless_fallback(world, baseline):
    """max_restarts=0: the first fault attributable to a chunk degrades it;
    the fallback program must still publish the exact fault-free bytes."""
    q, _, outs_s, ovf_s = baseline
    plan = FaultPlan((FaultEvent("crash_stage", "source", 1),))
    reg = world.session(
        mode="pipelined", faults=plan,
        recovery=RecoveryConfig(checkpoint_every=0, max_restarts=0),
    ).register(q)
    outs, ovf = reg.run(world.chunks)
    assert_bit_identical(outs_s, outs, "degraded vs fault-free")
    assert ovf == ovf_s
    st = reg.last_stats
    assert st["degraded"] is True
    rec = st["recovery"]
    assert rec["degraded_chunks"] == [1]
    assert rec["restarts"] >= 1
    assert rec["injected"]["crash_stage"] == 1


def test_operator_state_roundtrip(chaos):
    _, reg_p, _, _ = chaos
    for op in reg_p.runtime.operators.values():
        snap = op.state()
        for leaf in jax.tree.leaves(snap):
            assert isinstance(np.asarray(leaf), np.ndarray)
        before = jax.device_get(op.env)
        op.restore_state(snap)
        after = jax.device_get(op.env)
        ba, aa = jax.tree.leaves(before), jax.tree.leaves(after)
        assert all(np.array_equal(x, y) for x, y in zip(ba, aa))


# --------------------------------------------------------------------------
# zero-overhead pin: faults-off stage programs == faults-on stage programs
# --------------------------------------------------------------------------

def test_fault_machinery_never_touches_traced_programs(world, baseline,
                                                       chaos):
    """The per-stage jaxprs must be byte-identical whether or not the chaos
    machinery is enabled — all of it lives on the host driver."""
    q = baseline[0]
    plain = world.session(mode="pipelined").register(q).runtime
    chaotic = chaos[1].runtime
    chunk = world.chunks[0]

    def jp(fn, *args):
        return str(jax.make_jaxpr(fn)(*args))

    assert jp(plain._windows_impl, chunk) == jp(chaotic._windows_impl, chunk)
    _, opp_shape = jax.eval_shape(plain._windows_impl, chunk)
    op_payload = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                              opp_shape)
    for name in plain.upstream:
        pa, pb = plain.operators[name], chaotic.operators[name]
        assert jp(functools.partial(plain._op_impl, name),
                  op_payload, pa.kb, pa.env) == \
               jp(functools.partial(chaotic._op_impl, name),
                  op_payload, pb.kb, pb.env), name
    if plain._agg_win_ch is not None and chaotic._agg_win_ch is not None:
        fa = plain.operators[plain.final]
        fb = chaotic.operators[chaotic.final]
        assert jp(plain._sink_impl, plain._agg_win_ch, plain._out_ch,
                  fa.kb, fa.env) == \
               jp(chaotic._sink_impl, chaotic._agg_win_ch, chaotic._out_ch,
                  fb.kb, fb.env)


# --------------------------------------------------------------------------
# no-progress watchdog
# --------------------------------------------------------------------------

def test_stalled_pipeline_raises_diagnostic_not_spin(world, baseline):
    """A wedged edge must surface as PipelineStalledError naming the edge,
    not an infinite drain loop."""
    q = baseline[0]
    rt = world.session(mode="pipelined").register(q).runtime
    edge = "source->%s" % rt.final
    # wedge the source edge: the ledger says it is full, so _pump cannot
    # window the fed chunk and nothing ever enters flight
    rt._edge_stats[edge]["pushes"] += rt.channel_capacity
    rt.feed(world.chunks[0])
    assert rt._in_flight == 0 and len(rt._src_q) == 1
    with pytest.raises(PipelineStalledError) as ei:
        rt.drain()
    assert edge in str(ei.value)
    # an idle pipeline still reports plain driver misuse, not a stall
    idle = world.session(mode="pipelined").register(q).runtime
    with pytest.raises(RuntimeError, match="feed"):
        idle.drain()


def test_config_rejects_faults_outside_pipelined():
    plan = FaultPlan((FaultEvent("crash_stage", "source", 0),))
    with pytest.raises(ValueError, match="pipelined"):
        ExecutionConfig(mode="monolithic", faults=plan)
    with pytest.raises(ValueError, match="pipelined"):
        ExecutionConfig(mode="single_program", recovery=RecoveryConfig())
    with pytest.raises(TypeError):
        ExecutionConfig(mode="pipelined", faults="not a plan")
    with pytest.raises(TypeError):
        ExecutionConfig(mode="pipelined", recovery="not a config")
    with pytest.raises(ValueError):
        RecoveryConfig(checkpoint_every=-1)
    with pytest.raises(ValueError):
        RecoveryConfig(stage_timeout_s=0.0)


def test_nonpipelined_modes_report_inert_recovery_surface(baseline):
    st = baseline[1].last_stats
    assert st["recovery"] == empty_recovery_stats(enabled=False)
    assert st["degraded"] is False


# --------------------------------------------------------------------------
# serving-layer quarantine (host-only stub engine)
# --------------------------------------------------------------------------

class _StubEngine:
    """The four methods QueryAdmission needs, with poison-chunk faults."""

    def __init__(self):
        self.registered = {}
        self.processed = []
        self._n = 0

    def register(self, query, name=None):
        self._n += 1
        nm = name or "q%d" % self._n
        self.registered[nm] = query
        return types.SimpleNamespace(name=nm)

    def unregister(self, name):
        del self.registered[name]

    def process_chunk(self, chunk):
        if chunk == "poison":
            raise RuntimeError("poisoned feed")
        self.processed.append(chunk)
        return {}


def test_admission_quarantines_repeatedly_faulting_tenant():
    eng = _StubEngine()
    adm = QueryAdmission(eng, num_slots=4, max_tenant_faults=2)
    assert adm.submit(QueryRequest("qa", tenant="a", name="qa"))
    assert adm.submit(QueryRequest("qb", tenant="b", name="qb"))
    for _ in range(2):
        assert adm.offer_chunk("poison", tenant="a")
    assert adm.offer_chunk("good", tenant="b")
    while adm.pending_chunks() and "a" not in adm.quarantined:
        adm.tick()
    assert "a" in adm.quarantined
    assert adm.counters["tenant_faults"] == 2
    assert adm.counters["quarantined_tenants"] == 1
    # a's standing query is retired, b keeps running
    assert set(eng.registered) == {"qb"}
    assert adm.drain() == [("b", {})] or "good" in eng.processed
    # further traffic from a is refused at both boundaries
    assert not adm.offer_chunk("good", tenant="a")
    assert not adm.submit(QueryRequest("qa2", tenant="a"))
    st = adm.stats()
    assert st["quarantined"] == ["a"]


def test_admission_validator_rejects_and_counts():
    eng = _StubEngine()
    adm = QueryAdmission(
        eng, validator=lambda c: ["bad band"] if c == "bad" else [])
    assert adm.submit(QueryRequest("qa", tenant="t"))
    assert not adm.offer_chunk("bad", tenant="t")
    assert adm.offer_chunk("ok", tenant="t")
    assert adm.counters["chunks_invalid"] == 1
    assert adm.stats()["invalid_reasons"] == {"t": ["bad band"]}
    adm.drain()
    assert eng.processed == ["ok"]
    # a success resets the consecutive-fault count: no quarantine
    assert adm.quarantined == set()


def test_serve_engine_defaults_ingest_validator(world):
    eng = world.session(mode="monolithic").serve()
    adm = eng.admission(num_slots=2)
    assert adm.validator is not None
    assert not adm.offer_chunk(corrupt_batch(world.chunks[0]), tenant="t")
    assert adm.counters["chunks_invalid"] == 1
    assert adm.offer_chunk(world.chunks[0], tenant="t")


# --------------------------------------------------------------------------
# launcher: malformed .rq exits 2 with line/column + offending source line
# --------------------------------------------------------------------------

def test_malformed_rq_exits_with_code_2(tmp_path):
    bad = tmp_path / "bad.rq"
    bad.write_text(
        "REGISTER QUERY broken AS\n"
        "CONSTRUCT { ?t §oops }\n"
        "FROM STREAM <stream> [RANGE TRIPLES 8 STEP 8]\n"
        "WHERE { ?t ds:mentions ?e . }\n"
    )
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p])
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dscep_run", "--rq", str(bad),
         "--tweets", "8", "--artists", "4", "--shows", "2", "--filler", "10"],
        env=env, capture_output=True, text=True, timeout=900)
    assert res.returncode == 2, res.stdout + "\n" + res.stderr
    assert "line 2" in res.stderr, res.stderr
    assert "§oops" in res.stderr, res.stderr      # the offending source line
    assert "^" in res.stderr, res.stderr
